"""Width-autotuning benchmark (DESIGN.md §14): the offline tuner's
frontier selection, the tuned-vs-default cost win, and the adaptive
rung ladder's serving contracts.

    PYTHONPATH=src python benchmarks/autotune.py --smoke --check \\
        --out results/BENCH_autotune.json                         # CI
    PYTHONPATH=src python benchmarks/autotune.py                  # full

Three stages:

  · **tune** (in-process): build one refine-codec index, run
    ``repro.launch.tune.tune_index`` over the shared grid against the
    exact oracle, and evaluate three operating points on the held-out
    queries — the hand-picked default (``serve.DEFAULT_KC/K2``), the
    tuned-static selection, and the adaptive ladder (per-query rung by
    dispatch margin, cost averaged over the resolved rungs).
  · **variants** (subprocess, 2 emulated devices): with adaptivity off
    and explicit widths, every serving layout (plain / sharded /
    mutable / sharded-mutable) returns rows bit-identical to the
    direct variant search at those widths — and a default-config
    server (kc/k2 unset) returns the same rows, proving the
    resolution fallback IS the pre-§14 constants.
  · **runtime** (subprocess, cold jit cache): adaptive serving through
    the micro-batching runtime — warmup compiles exactly one program
    per (batch-bucket, width-rung), serving compiles nothing, every
    row is bit-identical to the direct search at its resolved rung's
    widths, the replay pass hits the cache on every repeat, and the
    cache key is structurally distinct across rungs.

``--check`` enforces the §14 acceptance contracts: (a) tuned-static
meets the recall target at strictly lower candidate cost than the
default, (b) the adaptive ladder's mean per-query cost is <= tuned-
static at equal-or-better recall, (c) explicit-width bit-identity on
all four variants, (d) one compile per (bucket, rung) and zero
serving-time compiles, (e) no cross-rung cache replay.  All report
fields are deterministic (no wall-clock), so the regression gate
compares them bit-exactly.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

import numpy as np

LAYOUTS = ("plain", "sharded", "mutable", "sharded_mutable")
CODEC = "refine:pq:4"
REFINE_MULTS = (2, 4, 8)

#: oracle width: the tune scores recall@top_r of the exact top-10
#: neighbors (the standard ANN ground-truth framing) — an exact top-100
#: target does not saturate at bench scale, so every sweep point would
#: sit on the steep part of the curve and the hand-picked default would
#: never be over-provisioned
ORACLE_WIDTH = 10

#: the tuner's recall@R target as a fraction of the DEFAULT config's
#: measured recall — the tune must hold (almost all of) the hand-picked
#: operating point's quality while spending strictly less
TARGET_FRAC = 0.96


def _scale(args) -> None:
    # geometry note: few large clusters + tight topics (sigma_doc) put
    # the default (6, 8) past the knee of the recall curve — the
    # regime the tuner exists for (an under-provisioned default is
    # correctly left alone, but proves nothing)
    if args.smoke:
        args.docs, args.queries = 4000, 256
        args.hidden, args.vocab, args.clusters = 32, 2048, 16
        args.pq_m, args.pq_k, args.kmeans_iters = 4, 64, 5
        args.max_batch = args.max_batch or 32
    else:
        args.docs, args.queries = 8000, 384
        args.hidden, args.vocab, args.clusters = 64, 4096, 32
        args.pq_m, args.pq_k, args.kmeans_iters = 8, 256, 8
        args.max_batch = args.max_batch or 64


def _build(args):
    """The one deterministic corpus + index every stage rebuilds (same
    seed and params -> bit-identical planes, so the tuned record from
    the tune stage applies verbatim in the subprocess stages)."""
    import jax
    import jax.numpy as jnp
    from repro.core import hybrid_index as hi
    from repro.data import synthetic

    corpus = synthetic.generate(seed=0, n_docs=args.docs,
                                n_queries=args.queries,
                                hidden=args.hidden,
                                vocab_size=args.vocab,
                                n_topics=args.clusters, sigma_doc=0.18)
    index = hi.build(jax.random.key(0), jnp.asarray(corpus.doc_emb),
                     jnp.asarray(corpus.doc_tokens), corpus.vocab_size,
                     n_clusters=args.clusters, k1_terms=8, codec=CODEC,
                     pq_m=args.pq_m, pq_k=args.pq_k,
                     cluster_capacity=512, term_capacity=96,
                     kmeans_iters=args.kmeans_iters)
    return corpus, index


def _equal(a, b) -> bool:
    return (np.array_equal(np.asarray(a.doc_ids), np.asarray(b.doc_ids))
            and np.array_equal(np.asarray(a.scores), np.asarray(b.scores))
            and np.array_equal(np.asarray(a.n_candidates),
                               np.asarray(b.n_candidates)))


# --------------------------------------------------------------------------
# stage: tune (in-process)
# --------------------------------------------------------------------------

def run_tune(args) -> tuple:
    import jax.numpy as jnp
    from repro.core import hybrid_index as hi
    from repro.core.exec import frontier
    from repro.launch import serve, tune

    corpus, index = _build(args)
    top_r = args.top_r
    qe, qt = jnp.asarray(corpus.query_emb), jnp.asarray(corpus.query_tokens)
    oracle = tune.exact_oracle(corpus.doc_emb, corpus.query_emb,
                               ORACLE_WIDTH)

    # the pre-§14 operating point: hand-picked widths, as-built codec
    d_res = hi.search(index, qe, qt, kc=serve.DEFAULT_KC,
                      k2=serve.DEFAULT_K2, top_r=top_r)
    d_recall = float(tune.per_query_recall(d_res.doc_ids, oracle,
                                           top_r).mean())
    d_cost = hi.candidate_cost(index, serve.DEFAULT_KC, serve.DEFAULT_K2,
                               top_r)
    target = round(TARGET_FRAC * d_recall, 4)

    tuned, points = tune.tune_index(index, corpus.query_emb,
                                    corpus.query_tokens, oracle,
                                    recall_target=target, top_r=top_r,
                                    refine_mults=REFINE_MULTS)
    tuned_idx = tune.apply_tuned(index, tuned)

    # adaptive ladder on the held-out sample: per-query rung by margin,
    # recall composed from the per-rung searches, cost averaged
    m = frontier.margins(index.cluster_sel.embeddings, corpus.query_emb)
    rung = frontier.resolve_rung(m, tuned.margin_cuts)
    rung_recall, rung_cost = [], []
    for kc, k2 in tuned.rungs:
        res = hi.search(tuned_idx, qe, qt, kc=kc, k2=k2, top_r=top_r)
        rung_recall.append(tune.per_query_recall(res.doc_ids, oracle,
                                                 top_r))
        rung_cost.append(hi.candidate_cost(tuned_idx, kc, k2, top_r))
    per_q = np.stack(rung_recall)[rung, np.arange(rung.shape[0])]
    costs = np.asarray(rung_cost, np.float64)[rung]
    report = {
        "codec": CODEC,
        "top_r": top_r,
        "oracle_width": ORACLE_WIDTH,
        "recall_target": target,
        "default": {"kc": serve.DEFAULT_KC, "k2": serve.DEFAULT_K2,
                    "refine_mult": 4, "cost": int(d_cost),
                    "recall": round(d_recall, 4)},
        "tuned": frontier.to_json(tuned),
        "pareto_frontier": [
            {"kc": p.kc, "k2": p.k2, "refine_mult": p.refine_mult,
             "cost": p.cost, "recall": round(p.recall, 4)}
            for p in frontier.pareto_frontier(points)],
        "adaptive": {
            "n_rungs": len(tuned.rungs),
            "rung_fractions": [round(float((rung == r).mean()), 4)
                               for r in range(len(tuned.rungs))],
            "mean_cost": round(float(costs.mean()), 1),
            "recall": round(float(per_q.mean()), 4),
        },
    }
    return report, tuned


# --------------------------------------------------------------------------
# stage: variants (subprocess; explicit-width bit-identity)
# --------------------------------------------------------------------------

def run_variants(args) -> dict:
    import jax
    import jax.numpy as jnp
    from repro.core import hybrid_index as hi
    from repro.core import segments as seg
    from repro.launch import serve

    corpus, index = _build(args)
    b = args.max_batch
    qe, qt = corpus.query_emb[:b], corpus.query_tokens[:b]
    kc, k2 = serve.DEFAULT_KC, serve.DEFAULT_K2

    def build_mut():
        return seg.MutableHybridIndex.create(
            jax.random.key(0), corpus.doc_emb, corpus.doc_tokens,
            corpus.vocab_size, delta_capacity=256, n_clusters=args.clusters,
            k1_terms=8, codec=CODEC, pq_m=args.pq_m, pq_k=args.pq_k,
            cluster_capacity=512, term_capacity=96,
            kmeans_iters=args.kmeans_iters)

    def make(layout, cfg):
        if layout in ("mutable", "sharded_mutable"):
            return serve.make_mutable_server(build_mut(), cfg)
        return serve.make_server(index, cfg)

    report = {}
    for layout in LAYOUTS:
        sharded = layout in ("sharded", "sharded_mutable")
        kw = dict(top_r=args.top_r, max_batch=b,
                  n_shards=2 if sharded else 1,
                  mutable=layout in ("mutable", "sharded_mutable"),
                  delta_capacity=256)
        explicit = make(layout, serve.ServeConfig(kc=kc, k2=k2, **kw))
        default = make(layout, serve.ServeConfig(**kw))
        # the direct pre-§14 call for this layout, at the same widths
        if layout in ("mutable", "sharded_mutable"):
            direct = explicit.mut.search(jnp.asarray(qe), jnp.asarray(qt),
                                         kc=kc, k2=k2, top_r=args.top_r)
        else:
            direct = hi.search(index, jnp.asarray(qe), jnp.asarray(qt),
                               kc=kc, k2=k2, top_r=args.top_r)
        e_rows = explicit.query(qe, qt)
        d_rows = default.query(qe, qt)
        report[layout] = {
            "resolved_widths": [default.kc, default.k2],
            "width_source_default_cfg": default.width_source,
            "explicit_equals_direct": _equal(e_rows, direct),
            "default_equals_explicit": _equal(d_rows, e_rows),
        }
    return report


# --------------------------------------------------------------------------
# stage: runtime (subprocess, cold jit; adaptive serving contracts)
# --------------------------------------------------------------------------

def run_runtime(args) -> dict:
    import jax.numpy as jnp
    from repro.core import hybrid_index as hi
    from repro.core.exec import frontier
    from repro.launch import runtime as rt_mod
    from repro.launch import serve, tune

    tuned = frontier.from_json(json.loads(args.tuned_json))
    corpus, index = _build(args)
    idx = tune.apply_tuned(index, tuned)
    server = serve.Server(idx, serve.ServeConfig(
        adaptive=True, top_r=args.top_r, max_batch=args.max_batch))
    n = corpus.query_emb.shape[0]
    rt = rt_mod.ServingRuntime(server, rt_mod.RuntimeConfig(
        linger_ms=1.0, queue_depth=max(256, 2 * n), cache_size=2 * n))
    rt.warmup(args.hidden, corpus.query_tokens.shape[1])

    futures = [rt.submit(corpus.query_emb[i], corpus.query_tokens[i])
               for i in range(n)]
    rows = [f.result() for f in futures]
    stats = rt.stats()

    # replay: every repeat must hit the cache (runtime idle in between)
    hits0 = stats["cache"]["hits"]
    replay = [rt.submit(corpus.query_emb[i], corpus.query_tokens[i])
              for i in range(n)]
    replay_rows = [f.result() for f in replay]
    replay_hits = rt.stats()["cache"]["hits"] - hits0
    replay_identical = all(_equal(a, b) for a, b in zip(rows, replay_rows))
    rt.close(drain=True)

    # per-rung bit-identity: each row == the direct search at its
    # resolved rung's widths (batch-size invariance makes the full-
    # batch direct call the reference for every row)
    m = frontier.margins(idx.cluster_sel.embeddings, corpus.query_emb)
    rung = frontier.resolve_rung(m, server.margin_cuts)
    qe, qt = jnp.asarray(corpus.query_emb), jnp.asarray(corpus.query_tokens)
    identical = True
    for r, (kc, k2) in enumerate(server.rungs):
        ref = hi.search(idx, qe, qt, kc=kc, k2=k2, top_r=args.top_r)
        ids, sc = np.asarray(ref.doc_ids), np.asarray(ref.scores)
        for i in np.nonzero(rung == r)[0]:
            identical &= (np.array_equal(np.asarray(rows[i].doc_ids),
                                         ids[i])
                          and np.array_equal(np.asarray(rows[i].scores),
                                             sc[i]))
    q0, t0 = (np.asarray(corpus.query_emb[0], np.float32),
              np.asarray(corpus.query_tokens[0], np.int32))
    return {
        "width_source": stats["width_source"],
        "rungs": stats["rungs"],
        "buckets": stats["buckets"],
        "warm_compiles": {str(k): v for k, v in
                          sorted(stats["warm_traces"].items())},
        "post_warmup_compiles": stats["post_warmup_traces"],
        "rung_dispatch": {str(k): v for k, v in
                          sorted(stats["rung_dispatch"].items())},
        "per_rung_bit_identical": bool(identical),
        "replay_hits": int(replay_hits),
        "replay_queries": n,
        "replay_bit_identical": bool(replay_identical),
        "cross_rung_key_distinct": bool(
            rt._key(q0, t0, None, 0) != rt._key(q0, t0, None, 1)),
    }


# --------------------------------------------------------------------------
# orchestration + checks
# --------------------------------------------------------------------------

def _spawn(stage: str, argv: list, devices: int = 1) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = f"src:{env.get('PYTHONPATH', '')}".rstrip(":")
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={devices}").strip()
    r = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--stage", stage,
         *argv], capture_output=True, text=True, env=env)
    if r.returncode != 0:
        sys.exit(f"autotune --stage {stage} failed:\n"
                 f"{r.stdout}\n{r.stderr}")
    return json.loads(r.stdout[r.stdout.index("{"):])


def _check(report: dict) -> list:
    fails = []
    tuned, default = report["tuned"], report["default"]
    adaptive = report["adaptive"]
    # (a) tuned-static: meets target, strictly cheaper than the default
    if tuned["recall"] < report["recall_target"]:
        fails.append(f"tuned recall {tuned['recall']} misses the target "
                     f"{report['recall_target']}")
    if not tuned["cost"] < default["cost"]:
        fails.append(f"tuned cost {tuned['cost']} not strictly below the "
                     f"default {default['cost']}")
    # (b) adaptive: cheaper-or-equal mean cost at equal-or-better recall
    if adaptive["mean_cost"] > tuned["cost"]:
        fails.append(f"adaptive mean cost {adaptive['mean_cost']} above "
                     f"tuned-static {tuned['cost']}")
    if adaptive["recall"] < tuned["recall"] - 1e-9:
        fails.append(f"adaptive recall {adaptive['recall']} below "
                     f"tuned-static {tuned['recall']}")
    if adaptive["n_rungs"] < 2:
        fails.append("calibration produced no adaptive ladder "
                     "(single rung) — adaptivity is untested")
    # (c) explicit widths, adaptivity off: bit-identical on all layouts
    for layout, rep in report["variants"].items():
        if not rep["explicit_equals_direct"]:
            fails.append(f"{layout}: explicit-width serving != direct "
                         "search")
        if not rep["default_equals_explicit"]:
            fails.append(f"{layout}: default-config serving != explicit "
                         f"{report['default']['kc']}/"
                         f"{report['default']['k2']}")
    # (d) one compile per (bucket, rung), zero serving-time compiles
    rt = report["runtime"]
    want = len(rt["buckets"]) * len(rt["rungs"])
    if len(rt["warm_compiles"]) != want:
        fails.append(f"warm ledger has {len(rt['warm_compiles'])} "
                     f"programs, want {want} (buckets x rungs)")
    bad = {k: v for k, v in rt["warm_compiles"].items() if v != 1}
    if bad:
        fails.append(f"warmup compiles per (bucket, rung) != 1: {bad}")
    if rt["post_warmup_compiles"]:
        fails.append(f"{rt['post_warmup_compiles']} compiles caused by "
                     "adaptive serving after warmup")
    if rt["width_source"] != "tuned":
        fails.append(f"runtime width source {rt['width_source']!r}, "
                     "want 'tuned'")
    if sorted(int(k) for k, v in rt["rung_dispatch"].items() if v) \
            != list(range(len(rt["rungs"]))):
        fails.append(f"not every rung dispatched: {rt['rung_dispatch']}")
    if not rt["per_rung_bit_identical"]:
        fails.append("adaptive rows != direct search at the resolved "
                     "rung's widths")
    # (e) cache can never replay across rungs
    if not rt["cross_rung_key_distinct"]:
        fails.append("cache key does not separate rungs")
    if rt["replay_hits"] != rt["replay_queries"]:
        fails.append(f"replay hit {rt['replay_hits']}"
                     f"/{rt['replay_queries']}")
    if not rt["replay_bit_identical"]:
        fails.append("replayed rows != first-pass rows")
    return fails


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small corpus (CI scale)")
    ap.add_argument("--stage", default=None,
                    choices=("variants", "runtime"),
                    help="run ONE stage in-process (internal: the "
                         "default orchestrates the subprocess stages)")
    ap.add_argument("--top-r", type=int, default=100)
    ap.add_argument("--max-batch", type=int, default=None)
    ap.add_argument("--tuned-json", default=None,
                    help="TunedWidths JSON for --stage runtime")
    ap.add_argument("--out", default=None,
                    help="write BENCH_autotune.json here")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero unless the §14 acceptance "
                         "contracts (a)-(e) hold")
    args = ap.parse_args(argv)
    _scale(args)

    if args.stage == "variants":
        report = run_variants(args)
    elif args.stage == "runtime":
        if not args.tuned_json:
            sys.exit("--stage runtime needs --tuned-json")
        report = run_runtime(args)
    else:
        tune_rep, tuned = run_tune(args)
        from repro.core.exec import frontier
        sub = ["--top-r", str(args.top_r),
               "--max-batch", str(args.max_batch)]
        if args.smoke:
            sub.append("--smoke")
        report = {
            "bench": "autotune",
            "smoke": bool(args.smoke),
            "n_docs": args.docs,
            "n_queries": args.queries,
            "max_batch": args.max_batch,
            **tune_rep,
            "variants": _spawn("variants", sub, devices=2),
            "runtime": _spawn(
                "runtime",
                sub + ["--tuned-json",
                       json.dumps(frontier.to_json(tuned))]),
        }

    text = json.dumps(report, indent=2)
    print(text)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            f.write(text + "\n")
    if args.check and args.stage is None:
        failures = _check(report)
        if failures:
            sys.exit("; ".join(failures))


if __name__ == "__main__":
    main()
