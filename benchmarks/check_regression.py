"""Bench regression gate: compare fresh CI benchmark JSONs against the
baselines committed under ``results/``.

    PYTHONPATH=src:. python benchmarks/check_regression.py \\
        --baseline-dir results --fresh-dir ci_results

Keeps the bench trajectory honest: quality and structural fields
(recall, candidate counts, bytes, budgets, equality flags) must match
the committed baseline **exactly** — they are deterministic functions of
the code, so any drift is a real behaviour change that belongs in the
same commit as a refreshed baseline.  Wall-clock fields (``*_us*``,
``*seconds*``, ``qps``, ``speedup*``) vary with the runner and are only
checked directionally within ``--timing-ratio``.

Exit status is nonzero on any regression, listing every mismatch with
its JSON path.  To update a baseline intentionally, rerun the benchmark
with ``--out results/<file>`` and commit the diff alongside the change
that caused it.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

#: benchmark files the gate covers (committed baseline name = fresh name)
DEFAULT_FILES = ("BENCH_codec.json", "sharded_search.json",
                 "BENCH_streaming.json", "BENCH_filtered.json",
                 "BENCH_serving.json", "BENCH_kernels.json",
                 "BENCH_mesh.json", "BENCH_hybrid.json",
                 "BENCH_autotune.json", "BENCH_sup.json")

_HIGHER_BETTER = ("qps", "speedup")
_LOWER_BETTER = ("us_per_batch", "us_per_call", "_us", "us", "seconds",
                 "_s", "ms")


def timing_direction(key: str):
    """'higher'/'lower' for wall-clock-dependent keys, None for exact
    fields.  Matched on key names so new benchmarks get the right
    treatment by following the naming convention."""
    k = key.lower()
    if any(k == p or k.startswith(p) for p in _HIGHER_BETTER):
        return "higher"
    if "seconds" in k or "us_per" in k:    # add_seconds_total, us_per_batch
        return "lower"
    if any(k == p or k.endswith(p) for p in _LOWER_BETTER):
        return "lower"
    return None


def compare(baseline, fresh, *, timing_ratio: float, float_tol: float,
            path: str = "$", key: str = "") -> list[str]:
    """Recursively diff two JSON documents; returns failure strings."""
    fails = []
    if type(baseline) is not type(fresh) and not (
            isinstance(baseline, (int, float))
            and isinstance(fresh, (int, float))
            and not isinstance(baseline, bool)
            and not isinstance(fresh, bool)):
        return [f"{path}: type changed "
                f"{type(baseline).__name__} -> {type(fresh).__name__}"]
    if isinstance(baseline, dict):
        for k in sorted(baseline.keys() | fresh.keys()):
            sub = f"{path}.{k}"
            if k not in fresh:
                fails.append(f"{sub}: missing from fresh run")
            elif k not in baseline:
                fails.append(f"{sub}: not in baseline (refresh the "
                             "baseline to admit new fields)")
            else:
                fails += compare(baseline[k], fresh[k],
                                 timing_ratio=timing_ratio,
                                 float_tol=float_tol, path=sub, key=k)
    elif isinstance(baseline, list):
        if len(baseline) != len(fresh):
            fails.append(f"{path}: length {len(baseline)} -> {len(fresh)}")
        else:
            for i, (b, f) in enumerate(zip(baseline, fresh)):
                fails += compare(b, f, timing_ratio=timing_ratio,
                                 float_tol=float_tol, path=f"{path}[{i}]",
                                 key=key)
    elif isinstance(baseline, bool) or isinstance(baseline, str) \
            or baseline is None:
        if baseline != fresh:
            fails.append(f"{path}: {baseline!r} -> {fresh!r}")
    elif isinstance(baseline, (int, float)):
        direction = timing_direction(key)
        if direction is None:
            if abs(float(baseline) - float(fresh)) > float_tol:
                fails.append(f"{path}: {baseline} -> {fresh} "
                             f"(exact field, tol={float_tol})")
        elif direction == "lower":
            if float(fresh) > float(baseline) * timing_ratio:
                fails.append(f"{path}: {fresh} > {timing_ratio}x baseline "
                             f"{baseline} (slower)")
        else:
            if float(fresh) < float(baseline) / timing_ratio:
                fails.append(f"{path}: {fresh} < baseline {baseline} / "
                             f"{timing_ratio} (slower)")
    else:
        fails.append(f"{path}: unhandled JSON type "
                     f"{type(baseline).__name__}")
    return fails


def check_files(baseline_dir: str, fresh_dir: str, files, *,
                timing_ratio: float, float_tol: float) -> list[str]:
    fails = []
    for name in files:
        b_path = os.path.join(baseline_dir, name)
        f_path = os.path.join(fresh_dir, name)
        if not os.path.exists(b_path):
            fails.append(f"{name}: no committed baseline at {b_path} — "
                         "generate it and commit it")
            continue
        if not os.path.exists(f_path):
            fails.append(f"{name}: fresh run missing at {f_path}")
            continue
        with open(b_path) as f:
            baseline = json.load(f)
        with open(f_path) as f:
            fresh = json.load(f)
        fails += [f"{name} {msg}" for msg in
                  compare(baseline, fresh, timing_ratio=timing_ratio,
                          float_tol=float_tol)]
    return fails


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline-dir", default="results",
                    help="directory with the committed baseline JSONs")
    ap.add_argument("--fresh-dir", required=True,
                    help="directory with this run's benchmark JSONs")
    ap.add_argument("--files", nargs="*", default=list(DEFAULT_FILES))
    ap.add_argument("--timing-ratio", type=float, default=4.0,
                    help="allowed slowdown factor for wall-clock fields")
    ap.add_argument("--float-tol", type=float, default=0.0,
                    help="absolute tolerance for exact numeric fields "
                         "(default: bit-exact)")
    args = ap.parse_args(argv)

    fails = check_files(args.baseline_dir, args.fresh_dir, args.files,
                        timing_ratio=args.timing_ratio,
                        float_tol=args.float_tol)
    if fails:
        print(f"REGRESSION: {len(fails)} mismatch(es) vs "
              f"{args.baseline_dir}/", file=sys.stderr)
        for msg in fails:
            print(f"  {msg}", file=sys.stderr)
        sys.exit(1)
    print(f"ok: {', '.join(args.files)} match the committed baselines "
          f"(timing within {args.timing_ratio}x)")


if __name__ == "__main__":
    main()
