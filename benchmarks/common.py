"""Shared benchmark fixtures: one corpus + one trained HI²_sup per process
(build once, reuse across tables), plus timing helpers."""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hybrid_index as hi, metrics
from repro.data import synthetic
from repro.launch import train as tr

# benchmark-scale corpus (≈ laptop-scale stand-in for MS MARCO; DESIGN.md §2)
N_DOCS = 20_000
N_QUERIES = 800
HIDDEN = 64
VOCAB = 8_192
N_CLUSTERS = 256

COMMON_INDEX = dict(k1_terms=12, codec="opq", pq_m=8, pq_k=256,
                    cluster_capacity=256, term_capacity=128)
KC, K2, TOP_R = 6, 8, 100


@functools.lru_cache(maxsize=2)
def corpus(seed: int = 0) -> synthetic.Corpus:
    return synthetic.generate(seed=seed, n_docs=N_DOCS, n_queries=N_QUERIES,
                              hidden=HIDDEN, vocab_size=VOCAB, n_topics=128)


@functools.lru_cache(maxsize=1)
def unsup_index():
    c = corpus()
    return hi.build(jax.random.key(0), jnp.asarray(c.doc_emb),
                    jnp.asarray(c.doc_tokens), c.vocab_size,
                    n_clusters=N_CLUSTERS, kmeans_iters=10, **COMMON_INDEX)


@functools.lru_cache(maxsize=1)
def sup_artifacts():
    c = corpus()
    cfg = tr.SupTrainConfig(n_clusters=N_CLUSTERS, n_steps=200,
                            batch_queries=32, lr=2e-3)
    params, enc_cfg, assign, _ = tr.train_hi2_sup(c, cfg, log_every=0)
    return params, enc_cfg, assign


@functools.lru_cache(maxsize=1)
def sup_index():
    c = corpus()
    params, enc_cfg, assign = sup_artifacts()
    return tr.build_sup_index(c, params, enc_cfg, assign, **COMMON_INDEX)


def queries():
    c = corpus()
    return jnp.asarray(c.query_emb), jnp.asarray(c.query_tokens)


def evaluate(result, qrels=None) -> dict:
    c = corpus()
    qrels = c.qrels if qrels is None else qrels
    return {
        "R@10": metrics.recall_at_k(result.doc_ids, qrels, 10),
        "R@100": metrics.recall_at_k(result.doc_ids, qrels, 100),
        "MRR@10": metrics.mrr_at_k(result.doc_ids, qrels, 10),
        "candidates": float(result.n_candidates.mean()),
    }


def index_size_bytes(index: hi.HybridIndex) -> int:
    total = 0
    for leaf in jax.tree.leaves(index):
        total += np.asarray(leaf).nbytes
    return total


def time_call(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Mean wall time per call in microseconds (post-jit)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6
