"""Paper Figure 3 — effectiveness/efficiency trade-off curves.

For each method, sweep the dispatch width and report
(candidate budget, R@100) pairs — the paper's recall-latency curve with
candidates as the latency proxy (§5.1).
"""
from __future__ import annotations

from benchmarks import common
from repro.core import hybrid_index as hi


def run() -> dict[str, list[tuple[float, float]]]:
    qe, qt = common.queries()
    idx, sup = common.unsup_index(), common.sup_index()
    curves: dict[str, list[tuple[float, float]]] = {}

    def point(res):
        ev = common.evaluate(res)
        return (ev["candidates"], ev["R@100"])

    curves["IVF-OPQ"] = [
        point(hi.search_ivf(idx, qe, qt, kc=kc, top_r=common.TOP_R))
        for kc in (1, 2, 4, 8, 12, 16)]
    curves["HI2_unsup"] = [
        point(hi.search(idx, qe, qt, kc=kc, k2=k2, top_r=common.TOP_R))
        for kc, k2 in ((1, 2), (2, 4), (4, 6), (6, 8), (8, 12), (12, 16))]
    curves["HI2_sup"] = [
        point(hi.search(sup, qe, qt, kc=kc, k2=k2, top_r=common.TOP_R))
        for kc, k2 in ((1, 2), (2, 4), (4, 6), (6, 8), (8, 12), (12, 16))]
    return curves


def main():
    for name, pts in run().items():
        print(name, " ".join(f"({c:.0f},{r:.3f})" for c, r in pts))


if __name__ == "__main__":
    main()
