"""Paper Figure 3 — effectiveness/efficiency trade-off curves.

For each method, sweep the dispatch width and report
(candidate budget, R@100) pairs — the paper's recall-latency curve with
candidates as the latency proxy (§5.1).

The sweep rides :func:`repro.core.exec.frontier.sweep` over the shared
:data:`~repro.core.exec.frontier.WIDTH_GRID` /
:data:`~repro.core.exec.frontier.IVF_KC_GRID` — the same grids the
offline width autotuner (``repro.launch.tune``, DESIGN.md §14)
optimizes over, so this figure and the tuner can never disagree on the
operating points.
"""
from __future__ import annotations

from benchmarks import common
from repro.core import hybrid_index as hi
from repro.core.exec import frontier


def _curve(search_fn, grid) -> list[tuple[float, float]]:
    """One (cost, recall) curve: fig3 reports the MEASURED mean
    candidate count as the cost axis (the tuner uses the static
    candidate_cost proxy; same grid, same point schema)."""

    def run(kc, k2):
        ev = common.evaluate(search_fn(kc, k2))
        return ev["R@100"], ev["candidates"]

    return [(p.cost, p.recall) for p in frontier.sweep(run, grid)]


def run() -> dict[str, list[tuple[float, float]]]:
    qe, qt = common.queries()
    idx, sup = common.unsup_index(), common.sup_index()
    return {
        "IVF-OPQ": _curve(
            lambda kc, k2: hi.search_ivf(idx, qe, qt, kc=kc,
                                         top_r=common.TOP_R),
            tuple((kc, 1) for kc in frontier.IVF_KC_GRID)),
        "HI2_unsup": _curve(
            lambda kc, k2: hi.search(idx, qe, qt, kc=kc, k2=k2,
                                     top_r=common.TOP_R),
            frontier.WIDTH_GRID),
        "HI2_sup": _curve(
            lambda kc, k2: hi.search(sup, qe, qt, kc=kc, k2=k2,
                                     top_r=common.TOP_R),
            frontier.WIDTH_GRID),
    }


def main():
    for name, pts in run().items():
        print(name, " ".join(f"({c:.0f},{r:.3f})" for c, r in pts))


if __name__ == "__main__":
    main()
