"""Paper Figure 4 — ablation: w.o. Term vs w.o. Clus vs full hybrid,
at matched dispatch widths (RQ2 complementarity)."""
from __future__ import annotations

from benchmarks import common
from repro.core import hybrid_index as hi


def run() -> dict[str, list[tuple[float, float]]]:
    qe, qt = common.queries()
    idx = common.unsup_index()

    def point(res):
        ev = common.evaluate(res)
        return (ev["candidates"], ev["R@100"])

    return {
        "w.o.Term(IVF)": [
            point(hi.search_ivf(idx, qe, qt, kc=kc, top_r=common.TOP_R))
            for kc in (2, 4, 8, 12, 16)],
        "w.o.Clus(term-only)": [
            point(hi.search_term_only(idx, qe, qt, k2=k2,
                                       top_r=common.TOP_R))
            for k2 in (2, 4, 8, 12, 16)],
        "HI2(full)": [
            point(hi.search(idx, qe, qt, kc=kc, k2=k2, top_r=common.TOP_R))
            for kc, k2 in ((1, 2), (2, 4), (4, 8), (6, 12), (8, 16))],
    }


def main():
    for name, pts in run().items():
        print(name, " ".join(f"({c:.0f},{r:.3f})" for c, r in pts))


if __name__ == "__main__":
    main()
