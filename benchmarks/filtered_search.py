"""Filtered-search benchmark: recall vs filter selectivity (DESIGN.md §9).

    PYTHONPATH=src python benchmarks/filtered_search.py --smoke --check \\
        --out results/BENCH_filtered.json                           # CI
    PYTHONPATH=src python benchmarks/filtered_search.py             # full

Partitions the corpus into N namespaces, then sweeps the per-query
filter from pass-everything down to a single namespace.  At each
selectivity point it reports:

  · recall@R against the *filtered* exact oracle (brute-force top-R
    restricted to each query's allowed namespaces) — the quality a
    tenant actually experiences;
  · mean surviving candidates (the paper's QL under filtering) next to
    the static candidate budget — the budget is selectivity-independent
    (the §2 fixed-shape contract: filtering masks slots, it never
    shrinks the compute), which is exactly what makes filtered latency
    flat;
  · tenant isolation (no returned doc outside the allowed set).

With ``--check`` it exits nonzero if isolation is violated or if the
pass-everything filter is not bit-identical to unfiltered search (the
filter stage must be a no-op at selectivity 1.0 — the §9 contract).
All quality fields are deterministic; ``benchmarks/check_regression.py``
gates them bit-exactly against ``results/BENCH_filtered.json``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import codecs, hybrid_index as hi, metrics
from repro.core.codecs import flat
from repro.core.exec import filters as ns_filters
from repro.data import synthetic


def _time_call(fn, *a, warmup=2, iters=5) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*a))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*a))
    return (time.perf_counter() - t0) / iters * 1e6  # µs per call


def _filtered_oracle(qe, doc_emb, doc_ns, allowed_sets, top_r) -> np.ndarray:
    """Exact top-R per query restricted to its allowed namespaces, via
    one brute-force pass per distinct namespace set (fixed shapes)."""
    out = np.full((qe.shape[0], top_r), -1, np.int64)
    ns = np.asarray(doc_ns)
    for key in sorted({tuple(s) for s in allowed_sets}):
        rows = [i for i, s in enumerate(allowed_sets) if tuple(s) == key]
        mask = np.isin(ns, list(key))
        sub = np.flatnonzero(mask)
        _, ids = flat.search(jnp.asarray(np.asarray(qe)[rows]),
                             jnp.asarray(np.asarray(doc_emb)[sub]),
                             k=min(top_r, sub.size))
        ids = np.asarray(ids)
        mapped = np.where(ids >= 0, sub[np.clip(ids, 0, None)], -1)
        out[rows, :mapped.shape[1]] = mapped
    return out


def run(args) -> dict:
    codec = args.codec or codecs.DEFAULT
    codecs.get(codec)    # fail fast on typos, listing registered names

    if args.smoke:
        n_docs, n_queries, n_ns = 4000, 64, 16
        build_kwargs = dict(n_clusters=64, k1_terms=8, codec=codec,
                            pq_m=4, pq_k=64, cluster_capacity=192,
                            term_capacity=96, kmeans_iters=5)
        vocab, hidden, topics = 2048, 32, 32
    else:
        n_docs, n_queries, n_ns = 20_000, 256, 16
        build_kwargs = dict(n_clusters=256, k1_terms=12, codec=codec,
                            pq_m=8, pq_k=256, cluster_capacity=256,
                            term_capacity=128, kmeans_iters=10)
        vocab, hidden, topics = 8192, 64, 128

    corpus = synthetic.generate(seed=0, n_docs=n_docs, n_queries=n_queries,
                                hidden=hidden, vocab_size=vocab,
                                n_topics=topics)
    qe = jnp.asarray(corpus.query_emb)
    qt = jnp.asarray(corpus.query_tokens)
    kc, k2, top_r = 6, 8, args.top_r
    rng = np.random.RandomState(0)
    doc_ns = rng.randint(0, n_ns, size=n_docs).astype(np.int32)

    index = hi.build(jax.random.key(0), jnp.asarray(corpus.doc_emb),
                     jnp.asarray(corpus.doc_tokens), corpus.vocab_size,
                     doc_namespaces=doc_ns, **build_kwargs)
    hist = ns_filters.namespace_histogram(doc_ns, n_ns)

    report = {
        "bench": "filtered",
        "smoke": bool(args.smoke),
        "codec": codec,
        "n_docs": n_docs,
        "n_queries": n_queries,
        "n_namespaces": n_ns,
        "namespace_docs_min": int(hist.min()),
        "namespace_docs_max": int(hist.max()),
        "top_r": top_r,
        "candidate_budget": hi.candidate_budget(index, kc, k2),
        "candidate_cost": hi.candidate_cost(index, kc, k2, top_r),
        "points": [],
    }
    failures = []

    # --- selectivity 1.0 sanity: all-namespaces filter == no filter ------
    ref = hi.search(index, qe, qt, kc=kc, k2=k2, top_r=top_r)
    allow_all = ns_filters.allow_all(n_queries, n_ns)
    full = hi.search(index, qe, qt, kc=kc, k2=k2, top_r=top_r,
                     filter=allow_all)
    noop = (np.array_equal(np.asarray(ref.doc_ids), np.asarray(full.doc_ids))
            and np.array_equal(np.asarray(ref.scores),
                               np.asarray(full.scores)))
    report["allow_all_equals_unfiltered"] = bool(noop)
    if not noop:
        failures.append("pass-everything filter changed results")

    # --- selectivity sweep: k allowed namespaces per query ---------------
    for k_ns in (n_ns, n_ns // 2, n_ns // 4, 2, 1):
        # query b sees namespaces {b, b+1, ..., b+k-1} mod N — spread so
        # every namespace is exercised at every selectivity
        allowed = [[(b + j) % n_ns for j in range(k_ns)]
                   for b in range(n_queries)]
        bitmap = ns_filters.make_filter(allowed, n_ns)
        res = hi.search(index, qe, qt, kc=kc, k2=k2, top_r=top_r,
                        filter=bitmap)
        us = _time_call(lambda: hi.search(index, qe, qt, kc=kc, k2=k2,
                                          top_r=top_r, filter=bitmap))
        ids = np.asarray(res.doc_ids)
        # tenant isolation: every returned doc inside the allowed set
        isolated = all(
            np.isin(doc_ns[row[row >= 0]], allowed[b]).all()
            for b, row in enumerate(ids))
        if not isolated:
            failures.append(f"isolation violated at k_ns={k_ns}")
        oracle = _filtered_oracle(corpus.query_emb, corpus.doc_emb, doc_ns,
                                  allowed, top_r)
        # mean fraction of the corpus each query may see (≈ k/N for the
        # uniform assignment; exact from the namespace histogram)
        pass_frac = float(np.mean([hist[a].sum() for a in allowed])
                          / n_docs)
        report["points"].append({
            "allowed_namespaces": k_ns,
            "pass_rate": round(k_ns / n_ns, 4),
            "corpus_pass_fraction": round(pass_frac, 4),
            "R@R_vs_filtered_oracle": metrics.recall_at_k(
                res.doc_ids, oracle, top_r),
            "mean_candidates": float(np.asarray(res.n_candidates).mean()),
            "tenant_isolated": bool(isolated),
            "search_us_per_batch": round(us, 1),
        })

    report["check_failures"] = failures
    return report


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized corpus")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero on isolation/no-op violations")
    ap.add_argument("--codec", default=None,
                    help="codec spec (default: registry default)")
    ap.add_argument("--top-r", type=int, default=100)
    ap.add_argument("--out", default=None, help="also write JSON here")
    args = ap.parse_args(argv)

    report = run(args)
    text = json.dumps(report, indent=1, sort_keys=True)
    print(text)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            f.write(text + "\n")
    if args.check and report["check_failures"]:
        sys.exit("filtered-search contract violated: "
                 + "; ".join(report["check_failures"]))


if __name__ == "__main__":
    main()
