"""Hybrid dense∥sparse fusion benchmark: recall vs fusion weight
(DESIGN.md §13).

    PYTHONPATH=src python benchmarks/hybrid_fusion.py --smoke --check \\
        --out results/BENCH_hybrid.json                           # CI
    PYTHONPATH=src python benchmarks/hybrid_fusion.py             # full

Builds the index with the BM25 impact plane (``sparse=True``) over the
*weaker* model-B encoder of the synthetic corpus — the paper's
robustness setting (§5.3): when the dense model is imperfect, the
lexical channel rescues queries the embedding space misses.  Then
sweeps the RRF dense weight from 0.0 (pure lexical) to 1.0 (pure
dense) and reports recall@R against the generator's qrels at each
point, next to the dense-only baseline.

With ``--check`` it exits nonzero if

  · ``fusion_weight=1.0`` is not bit-identical to dense-only search
    (the §13 degenerate-weight contract: zero sparse contributions
    must change nothing), or
  · a FusionSpec on an index without the impact plane does not fall
    back to the exact dense result (ids AND scores), or
  · the best fused recall@R falls below dense-only recall@R — fusion
    must never cost quality at its operating point.

All quality fields are deterministic; ``benchmarks/check_regression.py``
gates them bit-exactly against ``results/BENCH_hybrid.json``.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import codecs, hybrid_index as hi, metrics
from repro.core import exec as qexec
from repro.data import synthetic

WEIGHTS = (0.0, 0.25, 0.5, 0.75, 1.0)


def _time_call(fn, *a, warmup=2, iters=5) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*a))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*a))
    return (time.perf_counter() - t0) / iters * 1e6  # µs per call


def run(args) -> dict:
    codec = args.codec or codecs.DEFAULT
    codecs.get(codec)    # fail fast on typos, listing registered names

    if args.smoke:
        n_docs, n_queries = 4000, 64
        build_kwargs = dict(n_clusters=64, k1_terms=8, codec=codec,
                            pq_m=4, pq_k=64, cluster_capacity=192,
                            term_capacity=96, kmeans_iters=5)
        vocab, hidden, topics = 2048, 32, 32
    else:
        n_docs, n_queries = 20_000, 256
        build_kwargs = dict(n_clusters=256, k1_terms=12, codec=codec,
                            pq_m=8, pq_k=256, cluster_capacity=256,
                            term_capacity=128, kmeans_iters=10)
        vocab, hidden, topics = 8192, 64, 128

    corpus = synthetic.generate(seed=0, n_docs=n_docs, n_queries=n_queries,
                                hidden=hidden, vocab_size=vocab,
                                n_topics=topics)
    # model B: the degraded encoder — the robustness setting where the
    # sparse channel has signal the dense one lacks
    qe = jnp.asarray(corpus.query_emb_b)
    qt = jnp.asarray(corpus.query_tokens)
    kc, k2, top_r = 6, 8, args.top_r

    index = hi.build(jax.random.key(0), jnp.asarray(corpus.doc_emb_b),
                     jnp.asarray(corpus.doc_tokens), corpus.vocab_size,
                     sparse=True, **build_kwargs)

    report = {
        "bench": "hybrid",
        "smoke": bool(args.smoke),
        "codec": codec,
        "encoder": "model_b",
        "n_docs": n_docs,
        "n_queries": n_queries,
        "top_r": top_r,
        "rrf_k": qexec.FusionSpec().rrf_k,
        "candidate_budget": hi.candidate_budget(index, kc, k2),
        "points": [],
    }
    failures = []

    # --- dense-only baseline ---------------------------------------------
    dense = hi.search(index, qe, qt, kc=kc, k2=k2, top_r=top_r)
    dense_recall = metrics.recall_at_k(dense.doc_ids, corpus.qrels, top_r)
    report["dense_only"] = {
        f"R@{top_r}": dense_recall,
        "mean_candidates": float(np.asarray(dense.n_candidates).mean()),
        "search_us_per_batch": round(_time_call(
            lambda: hi.search(index, qe, qt, kc=kc, k2=k2,
                              top_r=top_r)), 1),
    }

    # --- fallback contract: FusionSpec without the impact plane ----------
    stripped = dataclasses.replace(index, sparse_weights=None)
    fb = hi.search(stripped, qe, qt, kc=kc, k2=k2, top_r=top_r,
                   fusion=qexec.FusionSpec(weight=0.5))
    fallback_ok = (
        np.array_equal(np.asarray(dense.doc_ids), np.asarray(fb.doc_ids))
        and np.array_equal(np.asarray(dense.scores), np.asarray(fb.scores)))
    report["fallback_equals_dense"] = bool(fallback_ok)
    if not fallback_ok:
        failures.append("dense-only fallback is not bit-identical")

    # --- fusion-weight sweep ---------------------------------------------
    best_weight, best_recall = None, -1.0
    for w in WEIGHTS:
        fus = qexec.FusionSpec(weight=w)
        res = hi.search(index, qe, qt, kc=kc, k2=k2, top_r=top_r,
                        fusion=fus)
        us = _time_call(lambda: hi.search(index, qe, qt, kc=kc, k2=k2,
                                          top_r=top_r, fusion=fus))
        recall = metrics.recall_at_k(res.doc_ids, corpus.qrels, top_r)
        point = {
            "fusion_weight": w,
            f"R@{top_r}": recall,
            "mean_candidates": float(np.asarray(res.n_candidates).mean()),
            "search_us_per_batch": round(us, 1),
        }
        if w == 1.0:
            identical = np.array_equal(np.asarray(res.doc_ids),
                                       np.asarray(dense.doc_ids))
            point["ids_equal_dense_only"] = bool(identical)
            if not identical:
                failures.append("fusion_weight=1.0 is not bit-identical "
                                "to dense-only search")
        report["points"].append(point)
        if recall > best_recall:
            best_weight, best_recall = w, recall

    report["best_weight"] = best_weight
    report[f"best_R@{top_r}"] = best_recall
    report["fused_ge_dense"] = bool(best_recall >= dense_recall)
    if best_recall < dense_recall:
        failures.append(
            f"best fused R@{top_r} {best_recall:.4f} < dense-only "
            f"{dense_recall:.4f}")

    report["check_failures"] = failures
    return report


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized corpus")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero on contract violations")
    ap.add_argument("--codec", default=None,
                    help="codec spec (default: registry default)")
    ap.add_argument("--top-r", type=int, default=100)
    ap.add_argument("--out", default=None, help="also write JSON here")
    args = ap.parse_args(argv)

    report = run(args)
    text = json.dumps(report, indent=1, sort_keys=True)
    print(text)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            f.write(text + "\n")
    if args.check and report["check_failures"]:
        sys.exit("hybrid-fusion contract violated: "
                 + "; ".join(report["check_failures"]))


if __name__ == "__main__":
    main()
