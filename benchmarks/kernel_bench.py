"""Kernel benchmark: fused vs unfused vs oracle on the scoring hot path
(DESIGN.md §11).

    PYTHONPATH=src python benchmarks/kernel_bench.py --smoke --check \\
        --out results/BENCH_kernels.json                            # CI
    PYTHONPATH=src python benchmarks/kernel_bench.py                # full

Three comparisons, each at the same candidate shapes the search engine
produces:

  · ``pq_adc``: the fused gather+ADC kernel (``pq_adc_fused`` — gathers
    the (N, m) resident plane in-kernel, masks in-kernel) against the
    unfused kernel path (XLA gather to (B, C, m) then the ADC kernel)
    and the pure-jnp oracle;
  · ``sq8_dot``: the fused gather+dequantized-dot kernel against the
    unfused einsum path;
  · ``assign_topk``: the running-top-k dispatch kernel against
    ``lax.top_k`` over the full score plane.

Timing fields follow the ``check_regression`` naming convention
(``us_per_call`` lower-better, ``qps_candidates`` higher-better) so the
gate treats them directionally; the parity fields (``matches_ref``,
``ids_bit_identical``) are deterministic booleans gated bit-exactly.
On CPU the kernels run in interpret mode — absolute numbers measure the
interpreter, not TPU silicon; the gate only catches order-of-magnitude
rot and parity breaks.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np


def _time_call(fn, *a, warmup=1, iters=3) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*a))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*a))
    return (time.perf_counter() - t0) / iters * 1e6  # µs per call


def _allclose(a, b, tol=1e-3) -> bool:
    a, b = np.asarray(a), np.asarray(b)
    if not (np.isinf(a) == np.isinf(b)).all():
        return False
    fin = np.isfinite(a)
    return bool(np.allclose(a[fin], b[fin], atol=tol, rtol=tol))


def _bench_pq_adc(b, m, k, n, c, c_blk) -> dict:
    from repro.kernels.pq_adc import ops, ref

    key = jax.random.key(0)
    lut = jax.random.normal(key, (b, m, k), jnp.float32)
    plane = jax.random.randint(jax.random.fold_in(key, 1), (n, m),
                               0, k).astype(jnp.uint8)
    ids = jax.random.randint(jax.random.fold_in(key, 2), (b, c),
                             0, n, jnp.int32)
    live = jax.random.bernoulli(jax.random.fold_in(key, 3), 0.9,
                                (b, c)).astype(jnp.int32)

    fused = lambda: ops.pq_adc_fused(lut, plane, ids, live,   # noqa: E731
                                     c_blk=c_blk)

    def unfused():
        codes = plane[ids].astype(jnp.int32)        # (B, C, m) in HBM
        return jnp.where(live.astype(bool), ops.pq_adc(lut, codes),
                         -jnp.inf)

    unfused = jax.jit(unfused)
    oracle = jax.jit(lambda: ref.pq_adc_fused(lut, plane, ids, live))

    want = oracle()
    us_f = _time_call(fused)
    us_u = _time_call(unfused)
    us_r = _time_call(oracle)
    cands = b * c
    return {
        "shape": {"B": b, "m": m, "k": k, "N": n, "C": c, "c_blk": c_blk},
        "fused_us_per_call": round(us_f, 1),
        "unfused_us_per_call": round(us_u, 1),
        "ref_us_per_call": round(us_r, 1),
        "qps_candidates_fused": round(cands / us_f * 1e6, 0),
        "qps_candidates_unfused": round(cands / us_u * 1e6, 0),
        "fused_matches_ref": _allclose(fused(), want),
        "unfused_matches_ref": _allclose(unfused(), want),
    }


def _bench_sq8(b, h, n, c, c_blk) -> dict:
    from repro.kernels.sq8_dot import ops, ref

    key = jax.random.key(1)
    q = jax.random.normal(key, (b, h), jnp.float32)
    plane = jax.random.randint(jax.random.fold_in(key, 1), (n, h),
                               0, 256).astype(jnp.uint8)
    ids = jax.random.randint(jax.random.fold_in(key, 2), (b, c),
                             0, n, jnp.int32)
    live = jax.random.bernoulli(jax.random.fold_in(key, 3), 0.9,
                                (b, c)).astype(jnp.int32)

    fused = lambda: ops.sq8_dot_fused(q, plane, ids, live,    # noqa: E731
                                      c_blk=c_blk)

    def unfused():
        rows = plane[ids].astype(jnp.float32)       # (B, C, h) in HBM
        return jnp.where(live.astype(bool),
                         jnp.einsum("bh,bch->bc", q, rows), -jnp.inf)

    unfused = jax.jit(unfused)
    want = ref.sq8_dot_fused(q, plane, ids, live)
    us_f = _time_call(fused)
    us_u = _time_call(unfused)
    cands = b * c
    return {
        "shape": {"B": b, "h": h, "N": n, "C": c, "c_blk": c_blk},
        "fused_us_per_call": round(us_f, 1),
        "unfused_us_per_call": round(us_u, 1),
        "qps_candidates_fused": round(cands / us_f * 1e6, 0),
        "qps_candidates_unfused": round(cands / us_u * 1e6, 0),
        "fused_matches_ref": _allclose(fused(), want),
    }


def _bench_topk(b, l, h, k) -> dict:
    from repro.kernels.assign_topk import ops, ref

    key = jax.random.key(2)
    x = jax.random.normal(key, (b, h), jnp.float32)
    emb = jax.random.normal(jax.random.fold_in(key, 1), (l, h),
                            jnp.float32)

    fused = lambda: ops.topk_scores(x, emb, k)                # noqa: E731
    unfused = jax.jit(lambda: ref.topk_scores(x, emb, k))

    ws, wi = unfused()
    gs, gi = fused()
    us_f = _time_call(fused)
    us_u = _time_call(unfused)
    return {
        "shape": {"B": b, "L": l, "h": h, "k": k},
        "fused_us_per_call": round(us_f, 1),
        "unfused_us_per_call": round(us_u, 1),
        "ids_bit_identical": bool(np.array_equal(np.asarray(wi),
                                                 np.asarray(gi))),
        "scores_match": _allclose(gs, ws, tol=1e-5),
    }


def run(args) -> dict:
    if args.smoke:
        adc = _bench_pq_adc(b=8, m=4, k=64, n=4000, c=512, c_blk=128)
        sq8 = _bench_sq8(b=8, h=32, n=4000, c=512, c_blk=128)
        topk = _bench_topk(b=8, l=128, h=32, k=6)
    else:
        adc = _bench_pq_adc(b=64, m=8, k=256, n=100_000, c=2048, c_blk=256)
        sq8 = _bench_sq8(b=64, h=64, n=100_000, c=2048, c_blk=256)
        topk = _bench_topk(b=64, l=1024, h=64, k=6)

    failures = []
    for name, rep, keys in (
            ("pq_adc", adc, ("fused_matches_ref", "unfused_matches_ref")),
            ("sq8_dot", sq8, ("fused_matches_ref",)),
            ("assign_topk", topk, ("ids_bit_identical", "scores_match"))):
        for kf in keys:
            if not rep[kf]:
                failures.append(f"{name}.{kf} is False")

    return {
        "bench": "kernels",
        "smoke": bool(args.smoke),
        "backend": jax.default_backend(),
        "interpret_mode": jax.default_backend() != "tpu",
        "pq_adc": adc,
        "sq8_dot": sq8,
        "assign_topk": topk,
        "check_failures": failures,
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="CI-sized shapes")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero if any kernel disagrees with its "
                         "oracle")
    ap.add_argument("--out", default=None, help="also write JSON here")
    args = ap.parse_args(argv)

    report = run(args)
    text = json.dumps(report, indent=1, sort_keys=True)
    print(text)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            f.write(text + "\n")
    if args.check and report["check_failures"]:
        sys.exit("kernel parity violated: "
                 + "; ".join(report["check_failures"]))


if __name__ == "__main__":
    main()
