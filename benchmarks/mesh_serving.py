"""2-D mesh serving benchmark (DESIGN.md §12): query throughput across
(data, model) serving-mesh geometries, plus the shard-loss drill.

    PYTHONPATH=src python benchmarks/mesh_serving.py --smoke --check \\
        --out results/BENCH_mesh.json                                # CI
    PYTHONPATH=src python benchmarks/mesh_serving.py                 # full

Each geometry runs in its own subprocess (device emulation must precede
the jax import; a cold jit cache keeps the compile ledger exact) and
reports:

  · wall time per full query batch through the layout's server, and the
    sha256 fingerprint of a fixed probe batch's doc_ids — identical
    across EVERY geometry (the data axis partitions queries, the model
    axis re-merges to the §6 order, so geometry is invisible in
    results);
  · ``qps_emulated = qps_wall · data``: this container emulates all
    mesh devices on one CPU core, so data-axis slices that would run
    concurrently on real hardware run serially here and wall-clock
    throughput CANNOT scale.  Emulated QPS is the honest proxy — wall
    time stays the denominator, so any real per-replica overhead
    (dispatch, collectives, padding) still drags the number down, which
    is what the ≥ 1.6× (2,1)-vs-(1,1) gate below actually measures;
  · the serving runtime over the mesh: one compile per bucket per mesh
    (NOT per replica), burst + open-loop Poisson latency percentiles,
    and round-robin dispatch reaching every data-axis replica;
  · the shard-loss drill at (2,2): checkpoint → eject one model-axis
    shard → results keep serving from the survivors' document ranges
    flagged ``partial=True`` (nothing from the lost range) → rejoin
    from the checkpoint → bit-identical to pre-failure results.

Quality/structural fields are deterministic and gated bit-exactly by
``benchmarks/check_regression.py``; wall-clock fields (``qps_*``,
``*_ms``, ``us_per*``, ``speedup*``) are compared within the timing
ratio.
"""
from __future__ import annotations

import argparse
import hashlib
import json
import os
import subprocess
import sys
import time

import numpy as np

#: (data, model) sweep: data-axis scaling, model-axis scaling, and the
#: full 2-D product.  4 emulated host devices cover every point.
GEOMETRIES = ((1, 1), (2, 1), (4, 1), (1, 2), (2, 2))
DRILL_GEOMETRY = (2, 2)
N_DEVICES = 4


def _gname(d: int, m: int) -> str:
    return f"{d}x{m}"


def _build(args):
    import jax
    import jax.numpy as jnp
    from repro.core import hybrid_index as hi
    from repro.data import synthetic
    from repro.launch import serve

    corpus = synthetic.generate(seed=0, n_docs=args.docs,
                                n_queries=args.queries,
                                hidden=args.hidden, vocab_size=args.vocab,
                                n_topics=32)
    index = hi.build(jax.random.key(0), jnp.asarray(corpus.doc_emb),
                     jnp.asarray(corpus.doc_tokens), corpus.vocab_size,
                     n_clusters=args.clusters, k1_terms=8, codec=args.codec,
                     pq_m=4, pq_k=64, cluster_capacity=192,
                     term_capacity=96, kmeans_iters=5)
    cfg = serve.ServeConfig(max_batch=args.max_batch, n_shards=args.model,
                            data_parallel=args.data)
    return corpus, serve.make_server(index, cfg)


def _fingerprint(res) -> str:
    return hashlib.sha256(np.asarray(res.doc_ids).tobytes()).hexdigest()


def _percentiles(lat_s: list) -> dict:
    ms = np.asarray(lat_s) * 1e3
    return {"p50_ms": round(float(np.percentile(ms, 50)), 2),
            "p95_ms": round(float(np.percentile(ms, 95)), 2),
            "p99_ms": round(float(np.percentile(ms, 99)), 2)}


def run_geometry(args) -> dict:
    from repro.launch import runtime as rt_mod

    corpus, server = _build(args)
    b = args.max_batch
    qe, qt = corpus.query_emb[:b], corpus.query_tokens[:b]
    server.warmup(args.hidden, qt.shape[1])

    # --- direct batched throughput (wall) + probe fingerprint ------------
    probe = server.query(qe, qt)
    t0 = time.perf_counter()
    for _ in range(args.reps):
        np.asarray(server.query(qe, qt).doc_ids)   # block on host transfer
    wall = (time.perf_counter() - t0) / args.reps
    qps_wall = b / wall

    # --- serving runtime over the mesh -----------------------------------
    n_req = args.requests
    req = [(corpus.query_emb[i % corpus.query_emb.shape[0]],
            corpus.query_tokens[i % corpus.query_tokens.shape[0]])
           for i in range(n_req)]
    rt = rt_mod.ServingRuntime(
        server, rt_mod.RuntimeConfig(linger_ms=args.linger_ms,
                                     queue_depth=max(n_req, 64),
                                     cache_size=0))
    rt.warmup(args.hidden, qt.shape[1])
    via_rt = rt.query(qe, qt)
    runtime_bit_identical = np.array_equal(np.asarray(probe.doc_ids),
                                           np.asarray(via_rt.doc_ids))

    t0 = time.perf_counter()
    futures = [rt.submit(e, t) for e, t in req]
    for f in futures:
        f.result()
    qps_runtime = n_req / (time.perf_counter() - t0)

    rate = max(qps_runtime / 4.0, 1.0)
    rng = np.random.default_rng(0)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n_req))
    done_at = [None] * n_req

    def _mark(i):
        def cb(_):
            done_at[i] = time.perf_counter()
        return cb

    t0 = time.perf_counter()
    for i, (e, t) in enumerate(req):
        lead = t0 + arrivals[i] - time.perf_counter()
        if lead > 0:
            time.sleep(lead)
        rt.submit(e, t).add_done_callback(_mark(i))
    while any(d is None for d in done_at):
        time.sleep(0.001)
    span = max(done_at) - t0
    latencies = [done_at[i] - (t0 + arrivals[i]) for i in range(n_req)]

    rt.close(drain=True)
    stats = rt.stats()

    return {
        "data": args.data,
        "model": args.model,
        "buckets": stats["buckets"],
        "warm_compiles": {str(k): v for k, v in
                          sorted(stats["warm_traces"].items())},
        "post_warmup_compiles": stats["post_warmup_traces"],
        # the probe fingerprint is compared ACROSS geometries by the
        # parent and reported there as one boolean — raw hashes don't
        # belong in the gated report (floating-point results need only
        # be identical within a run, not across machines)
        "_fingerprint": _fingerprint(probe),
        "runtime_bit_identical": bool(runtime_bit_identical),
        # dispatch counts depend on arrival timing (not deterministic) —
        # report only the balance property the placement guarantees
        "dispatch_all_replicas": bool(
            all(n > 0 for n in stats["replica_dispatch"].values())),
        "us_per_batch": round(wall * 1e6, 1),
        "qps_wall": round(qps_wall, 1),
        "qps_emulated": round(qps_wall * args.data, 1),
        "qps_runtime": round(qps_runtime, 1),
        "poisson": {"qps_offered": round(rate, 1),
                    "qps_sustained": round(n_req / span, 1),
                    **_percentiles(latencies)},
    }


def run_drill(args) -> dict:
    """Shard-loss drill at (2, 2): checkpoint → eject → degraded-but-
    served (``partial=True``, survivors only) → rejoin → bit-identical."""
    import tempfile

    from repro.launch import runtime as rt_mod

    corpus, server = _build(args)
    b = args.max_batch
    qe, qt = corpus.query_emb[:b], corpus.query_tokens[:b]
    server.warmup(args.hidden, qt.shape[1])
    full = server.query(qe, qt)
    epoch0 = server.epoch

    with tempfile.TemporaryDirectory() as td:
        path = server.checkpoint(td)
        server.eject_shard(1)
        degraded = server.query(qe, qt)
        ids = np.asarray(degraded.doc_ids)
        live = ids[ids >= 0]
        excluded = all(
            not ((live >= lo) & (live < hi)).any()
            for lo, hi in server.lost_doc_ranges())

        # the runtime keeps serving the degraded mesh and must carry the
        # partial flag through to every client row
        rt = rt_mod.ServingRuntime(server, rt_mod.RuntimeConfig(
            linger_ms=args.linger_ms, queue_depth=64, cache_size=0))
        rt.warmup(args.hidden, qt.shape[1])
        via_rt = rt.query(qe, qt)
        rt.close(drain=True)

        server.rejoin(path)
        restored = server.query(qe, qt)

    return {
        "data": args.data,
        "model": args.model,
        "ejected_shard": 1,
        "partial_flagged": bool(degraded.partial),
        "runtime_partial_flagged": bool(via_rt.partial),
        "lost_range_excluded": bool(excluded),
        "degraded_differs": _fingerprint(degraded) != _fingerprint(full),
        "restored_not_partial": not bool(restored.partial),
        "rejoin_bit_identical": _fingerprint(restored) == _fingerprint(full),
        "epoch_bumps": int(server.epoch - epoch0),
    }


def _check(report: dict) -> list:
    fails = []
    geos = report["geometries"]
    if not report["doc_ids_identical_across_geometries"]:
        fails.append("doc_ids differ across geometries")
    for g, r in geos.items():
        # the direct-serving probe precedes runtime warmup and shares
        # the max_batch signature, so that bucket warms from the jit
        # cache (0 traces); the invariant is at MOST one per bucket
        bad = {b: n for b, n in r["warm_compiles"].items() if n > 1}
        if bad:
            fails.append(f"{g}: warmup compiles per bucket > 1: {bad}")
        if r["post_warmup_compiles"]:
            fails.append(f"{g}: {r['post_warmup_compiles']} compiles "
                         "caused by serving after warmup")
        if not r["runtime_bit_identical"]:
            fails.append(f"{g}: runtime rows != direct Server.query")
        if not r["dispatch_all_replicas"]:
            fails.append(f"{g}: some data-axis replica never dispatched")
    speedup = report["speedup_emulated_2x1"]
    if speedup < 1.6:
        fails.append(f"emulated (2,1) throughput only {speedup:.2f}x the "
                     "(1,1) baseline (< 1.6x)")
    drill = report["failover"]
    for key in ("partial_flagged", "runtime_partial_flagged",
                "lost_range_excluded", "degraded_differs",
                "restored_not_partial", "rejoin_bit_identical"):
        if not drill[key]:
            fails.append(f"failover drill: {key} is False")
    return fails


def _spawn(role_argv: list, argv: list) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = f"src:{env.get('PYTHONPATH', '')}".rstrip(":")
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "") +
        f" --xla_force_host_platform_device_count={N_DEVICES}").strip()
    r = subprocess.run(
        [sys.executable, os.path.abspath(__file__), *role_argv, *argv],
        capture_output=True, text=True, env=env)
    if r.returncode != 0:
        sys.exit(f"mesh_serving {' '.join(role_argv)} failed:\n"
                 f"{r.stdout}\n{r.stderr}")
    return json.loads(r.stdout[r.stdout.index("{"):])


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small corpus (CI scale)")
    ap.add_argument("--geometry", default=None, metavar="DxM",
                    help="run ONE (data, model) geometry in-process "
                         "(internal: the default orchestrates the sweep "
                         "in subprocesses)")
    ap.add_argument("--drill", action="store_true",
                    help="run the shard-loss drill in-process (internal)")
    ap.add_argument("--codec", default="sq8")
    ap.add_argument("--max-batch", type=int, default=None)
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--reps", type=int, default=None)
    ap.add_argument("--linger-ms", type=float, default=2.0)
    ap.add_argument("--out", default=None,
                    help="write BENCH_mesh.json here")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero unless doc_ids are bit-identical "
                         "across geometries, emulated (2,1) QPS is >= "
                         "1.6x the (1,1) baseline, and the shard-loss "
                         "drill upholds the partial-result contract")
    args = ap.parse_args(argv)
    if args.smoke:
        args.docs, args.queries = 4000, 64
        args.hidden, args.vocab, args.clusters = 32, 2048, 64
        args.max_batch = args.max_batch or 32
        args.requests = args.requests or 96
        args.reps = args.reps or 10
    else:
        args.docs, args.queries = 20_000, 128
        args.hidden, args.vocab, args.clusters = 64, 8192, 256
        args.max_batch = args.max_batch or 64
        args.requests = args.requests or 512
        args.reps = args.reps or 20

    if args.geometry or args.drill:
        d, m = ((2, 2) if args.drill
                else (int(x) for x in args.geometry.split("x")))
        args.data, args.model = int(d), int(m)
        report = run_drill(args) if args.drill else run_geometry(args)
    else:
        sub_argv = ["--codec", args.codec,
                    "--max-batch", str(args.max_batch),
                    "--requests", str(args.requests),
                    "--reps", str(args.reps),
                    "--linger-ms", str(args.linger_ms)]
        if args.smoke:
            sub_argv.append("--smoke")
        geos = {_gname(d, m): _spawn(["--geometry", _gname(d, m)], sub_argv)
                for d, m in GEOMETRIES}
        fps = {g: r.pop("_fingerprint") for g, r in geos.items()}
        base = geos[_gname(1, 1)]["qps_wall"]
        dp2 = geos[_gname(2, 1)]["qps_emulated"]
        report = {
            "bench": "mesh_serving",
            "smoke": bool(args.smoke),
            "codec": args.codec,
            "n_docs": args.docs,
            "max_batch": args.max_batch,
            "n_requests": args.requests,
            "n_devices": N_DEVICES,
            "geometries": geos,
            "doc_ids_identical_across_geometries":
                len(set(fps.values())) == 1,
            # emulated speedup: all devices share one CPU core, so the
            # data axis cannot shrink wall time here — see the module
            # docstring for why qps_emulated/qps_wall is still a real
            # overhead gate (wall time stays the denominator)
            "speedup_emulated_2x1": round(dp2 / base, 2),
            "failover": _spawn(["--drill"], sub_argv),
        }

    text = json.dumps(report, indent=2)
    print(text)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            f.write(text + "\n")
    if args.check and not (args.geometry or args.drill):
        failures = _check(report)
        if failures:
            sys.exit("; ".join(failures))


if __name__ == "__main__":
    main()
