"""Benchmark driver — one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import jax


def main() -> None:
    from benchmarks import (common, fig3_tradeoff, fig4_ablation,
                            table1_main, table2_robustness, table3_codec)
    from repro.core import hybrid_index as hi

    print("name,us_per_call,derived")
    qe, qt = common.queries()

    # timed core search call (jit-compiled, the paper's QL analogue)
    idx = common.unsup_index()
    us = common.time_call(
        lambda: hi.search(idx, qe, qt, kc=common.KC, k2=common.K2,
                          top_r=common.TOP_R))
    per_query = us / qe.shape[0]
    print(f"hi2_search_batch,{us:.0f},per_query_us={per_query:.1f}",
          flush=True)

    us64 = common.time_call(
        lambda: hi.search(idx, qe[:64], qt[:64], kc=common.KC, k2=common.K2,
                          top_r=common.TOP_R))
    print(f"hi2_search_64q,{us64:.0f},oracle_path", flush=True)

    # Table 1
    for row in table1_main.run():
        print(f"table1/{row['method']},0,"
              f"R@100={row['R@100']:.4f};MRR@10={row['MRR@10']:.4f};"
              f"cands={row['candidates']:.0f};"
              f"index_mb={row['index_bytes']/2**20:.1f}", flush=True)

    # Figure 3
    for name, pts in fig3_tradeoff.run().items():
        pts_s = ";".join(f"({c:.0f}:{r:.4f})" for c, r in pts)
        print(f"fig3/{name},0,{pts_s}", flush=True)

    # Figure 4
    for name, pts in fig4_ablation.run().items():
        pts_s = ";".join(f"({c:.0f}:{r:.4f})" for c, r in pts)
        print(f"fig4/{name},0,{pts_s}", flush=True)

    # Table 2
    for row in table2_robustness.run():
        print(f"table2/{row['model']}/{row['method']},0,"
              f"R@100={row['R100']:.4f}", flush=True)

    # Table 3
    for row in table3_codec.run():
        print(f"table3/{row['codec']},0,"
              f"R@100={row['R@100']:.4f};"
              f"index_mb={row['index_bytes']/2**20:.1f}", flush=True)

    # kernel microbenchmarks (oracle path timings; the Pallas bodies are
    # TPU-targeted and validated in interpret mode by the tests)
    from repro.kernels.pq_adc import ref as adc_ref
    lut = jax.random.normal(jax.random.key(0), (64, 8, 256))
    codes = jax.random.randint(jax.random.key(1), (64, 2048, 8), 0, 256)
    f = jax.jit(adc_ref.pq_adc)
    us = common.time_call(f, lut, codes)
    scored = 64 * 2048
    print(f"kernel/pq_adc_oracle,{us:.0f},cands_per_s={scored/us*1e6:.3g}",
          flush=True)


if __name__ == "__main__":
    main()
