"""Benchmark driver — enumerates and dispatches EVERY ``benchmarks/*.py``
entry point, so one command reproduces the full bench suite.  Prints
``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run             # everything
    PYTHONPATH=src python -m benchmarks.run --list      # what would run
    PYTHONPATH=src python -m benchmarks.run --only table3_codec

Every non-helper module in ``benchmarks/`` must have an entry in
``DISPATCH`` below; the driver exits nonzero if a benchmark file exists
without one, so new benchmarks cannot be silently dropped from the
suite (the mistake that previously left ``table3_codec`` and the
streaming bench out of this driver).
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import subprocess
import sys

import jax

#: benchmarks/ modules that are infrastructure, not benchmarks
HELPER_MODULES = {"__init__", "common", "run", "check_regression"}

_DIR = pathlib.Path(__file__).resolve().parent


def discovered() -> list[str]:
    """Module names of every benchmark entry point on disk."""
    return sorted(p.stem for p in _DIR.glob("*.py")
                  if p.stem not in HELPER_MODULES)


def _run_core_search() -> None:
    from benchmarks import common
    from repro.core import hybrid_index as hi

    qe, qt = common.queries()
    idx = common.unsup_index()
    us = common.time_call(
        lambda: hi.search(idx, qe, qt, kc=common.KC, k2=common.K2,
                          top_r=common.TOP_R))
    per_query = us / qe.shape[0]
    print(f"hi2_search_batch,{us:.0f},per_query_us={per_query:.1f}",
          flush=True)
    us64 = common.time_call(
        lambda: hi.search(idx, qe[:64], qt[:64], kc=common.KC, k2=common.K2,
                          top_r=common.TOP_R))
    print(f"hi2_search_64q,{us64:.0f},oracle_path", flush=True)


def _run_kernels() -> None:
    # oracle-path timing only; the fused/unfused Pallas comparison is
    # benchmarks/kernel_bench.py (gated via results/BENCH_kernels.json)
    from benchmarks import common
    from repro.kernels.pq_adc import ref as adc_ref

    lut = jax.random.normal(jax.random.key(0), (64, 8, 256))
    codes = jax.random.randint(jax.random.key(1), (64, 2048, 8), 0, 256)
    f = jax.jit(adc_ref.pq_adc)
    us = common.time_call(f, lut, codes)
    scored = 64 * 2048
    print(f"kernel/pq_adc_oracle,{us:.0f},cands_per_s={scored/us*1e6:.3g}",
          flush=True)


def _table1() -> None:
    from benchmarks import table1_main
    for row in table1_main.run():
        print(f"table1/{row['method']},0,"
              f"R@100={row['R@100']:.4f};MRR@10={row['MRR@10']:.4f};"
              f"cands={row['candidates']:.0f};"
              f"index_mb={row['index_bytes']/2**20:.1f}", flush=True)


def _table2() -> None:
    from benchmarks import table2_robustness
    for row in table2_robustness.run():
        print(f"table2/{row['model']}/{row['method']},0,"
              f"R@100={row['R100']:.4f}", flush=True)


def _table3() -> None:
    from benchmarks import table3_codec
    for row in table3_codec.run():
        print(f"table3/{row['codec']},0,"
              f"R@100={row['R@100']:.4f};"
              f"index_mb={row['index_bytes']/2**20:.1f}", flush=True)


def _fig3() -> None:
    from benchmarks import fig3_tradeoff
    for name, pts in fig3_tradeoff.run().items():
        pts_s = ";".join(f"({c:.0f}:{r:.4f})" for c, r in pts)
        print(f"fig3/{name},0,{pts_s}", flush=True)


def _fig4() -> None:
    from benchmarks import fig4_ablation
    for name, pts in fig4_ablation.run().items():
        pts_s = ";".join(f"({c:.0f}:{r:.4f})" for c, r in pts)
        print(f"fig4/{name},0,{pts_s}", flush=True)


def _subprocess_json(module: str, extra_args: list[str]) -> dict:
    """Run a benchmark that must own its process (device emulation via
    XLA_FLAGS must precede jax import) and parse its JSON stdout."""
    env = dict(os.environ)
    env["PYTHONPATH"] = f"src:{env.get('PYTHONPATH', '')}".rstrip(":")
    r = subprocess.run(
        [sys.executable, str(_DIR / f"{module}.py"), *extra_args],
        capture_output=True, text=True, cwd=str(_DIR.parent), env=env)
    if r.returncode != 0:
        sys.exit(f"{module} failed:\n{r.stdout}\n{r.stderr}")
    return json.loads(r.stdout[r.stdout.index("{"):])


def _sharded_search() -> None:
    rep = _subprocess_json("sharded_search",
                           ["--devices", "2", "--docs", "4000",
                            "--queries", "64"])
    base = rep["baseline"]
    print(f"sharded/baseline,{base['us_per_batch']:.0f},"
          f"qps={base['qps']:.0f}", flush=True)
    for e in rep["sharded"]:
        print(f"sharded/{e['shards']}shards,{e['us_per_batch']:.0f},"
              f"identical={e['doc_ids_identical']};"
              f"speedup={e['speedup_vs_baseline']}", flush=True)


def _filtered_search() -> None:
    rep = _subprocess_json("filtered_search", ["--smoke", "--check"])
    for pt in rep["points"]:
        print(f"filtered/pass{pt['pass_rate']:.2f},"
              f"{pt['search_us_per_batch']:.0f},"
              f"R@R={pt['R@R_vs_filtered_oracle']:.4f};"
              f"cands={pt['mean_candidates']:.0f};"
              f"isolated={pt['tenant_isolated']}", flush=True)
    print(f"filtered/allow_all,0,"
          f"equals_unfiltered={rep['allow_all_equals_unfiltered']}",
          flush=True)


def _streaming_updates() -> None:
    rep = _subprocess_json("streaming_updates", ["--smoke", "--check"])
    for p in rep["points"]:
        print(f"streaming/fill{p['fill_fraction']:.2f},"
              f"{p['search_us_per_batch']:.0f},R@100={p['R@100']:.4f}",
              flush=True)
    c = rep["compaction"]
    print(f"streaming/compaction,{c['seconds']*1e6:.0f},"
          f"equal_to_rebuild={c['equal_to_rebuild']};"
          f"tombstones_absent={rep['deletes']['tombstones_absent']}",
          flush=True)


def _serving_load() -> None:
    rep = _subprocess_json("serving_load", ["--smoke", "--check"])
    for name in ("plain", "sharded", "mutable", "sharded_mutable"):
        r = rep["layouts"][name]
        print(f"serving/{name},{1e6 / r['qps_runtime']:.0f},"
              f"speedup={r['qps_runtime'] / r['qps_serial']:.1f};"
              f"identical={r['bit_identical']};"
              f"p99_ms={r['poisson']['p99_ms']};"
              f"cache_hits={r['cache']['hits']}", flush=True)


def _mesh_serving() -> None:
    rep = _subprocess_json("mesh_serving", ["--smoke", "--check"])
    for name, r in rep["geometries"].items():
        print(f"mesh/{name},{r['us_per_batch']:.0f},"
              f"qps_emulated={r['qps_emulated']};"
              f"identical={r['runtime_bit_identical']};"
              f"p99_ms={r['poisson']['p99_ms']}", flush=True)
    d = rep["failover"]
    print(f"mesh/failover,0,"
          f"partial={d['partial_flagged']};"
          f"rejoin_identical={d['rejoin_bit_identical']}", flush=True)


def _hybrid_fusion() -> None:
    rep = _subprocess_json("hybrid_fusion", ["--smoke", "--check"])
    r = rep["top_r"]
    d = rep["dense_only"]
    print(f"hybrid/dense_only,{d['search_us_per_batch']:.0f},"
          f"R@{r}={d[f'R@{r}']:.4f}", flush=True)
    for pt in rep["points"]:
        print(f"hybrid/w{pt['fusion_weight']:.2f},"
              f"{pt['search_us_per_batch']:.0f},"
              f"R@{r}={pt[f'R@{r}']:.4f}", flush=True)
    print(f"hybrid/best,0,weight={rep['best_weight']};"
          f"fused_ge_dense={rep['fused_ge_dense']};"
          f"fallback={rep['fallback_equals_dense']}", flush=True)


def _autotune() -> None:
    rep = _subprocess_json("autotune", ["--smoke", "--check"])
    t, d, a = rep["tuned"], rep["default"], rep["adaptive"]
    print(f"autotune/default,0,cost={d['cost']};R@100={d['recall']:.4f}",
          flush=True)
    print(f"autotune/tuned,0,kc={t['kc']};k2={t['k2']};"
          f"mult={t['refine_mult']};cost={t['cost']};"
          f"R@100={t['recall']:.4f}", flush=True)
    print(f"autotune/adaptive,0,rungs={a['n_rungs']};"
          f"mean_cost={a['mean_cost']};R@100={a['recall']:.4f}",
          flush=True)
    rt = rep["runtime"]
    print(f"autotune/runtime,0,"
          f"programs={len(rt['warm_compiles'])};"
          f"post_warmup={rt['post_warmup_compiles']};"
          f"identical={rt['per_rung_bit_identical']}", flush=True)


def _sup_distill() -> None:
    rep = _subprocess_json("sup_distill", ["--smoke", "--check"])
    t = rep["trajectory"]
    print(f"sup_distill/train,0,steps={t['n_steps']};"
          f"loss={t['loss_first']:.4f}->{t['loss_last']:.4f};"
          f"improving={t['frac_improving_windows']:.2f}", flush=True)
    r = rep["top_r"]
    for p in rep["operating_points"]:
        print(f"sup_distill/kc{p['kc']}k2{p['k2']},0,"
              f"cost={p['cost_sup']};R@{r}_unsup={p['recall_unsup']:.4f};"
              f"R@{r}_sup={p['recall_sup']:.4f}", flush=True)
    life = rep["variants"]["mutable_lifecycle"]
    print(f"sup_distill/variants,0,"
          f"wins={rep['sup_wins']}/{rep['n_operating_points']};"
          f"roundtrip={rep['roundtrip']['planes_bit_identical']};"
          f"compact={life['compact_equals_scratch']}", flush=True)


def _kernel_bench() -> None:
    rep = _subprocess_json("kernel_bench", ["--smoke", "--check"])
    for name in ("pq_adc", "sq8_dot", "assign_topk"):
        e = rep[name]
        derived = ";".join(f"{k}={v}" for k, v in sorted(e.items())
                           if isinstance(v, bool)
                           or k.startswith("qps"))
        print(f"kernel_bench/{name},{e['fused_us_per_call']:.0f},"
              f"{derived}", flush=True)


#: every benchmark entry point; the driver refuses to run if a
#: benchmarks/*.py exists without a row here
DISPATCH = {
    "autotune": _autotune,
    "kernel_bench": _kernel_bench,
    "table1_main": _table1,
    "table2_robustness": _table2,
    "table3_codec": _table3,
    "fig3_tradeoff": _fig3,
    "fig4_ablation": _fig4,
    "sharded_search": _sharded_search,
    "sup_distill": _sup_distill,
    "streaming_updates": _streaming_updates,
    "filtered_search": _filtered_search,
    "hybrid_fusion": _hybrid_fusion,
    "serving_load": _serving_load,
    "mesh_serving": _mesh_serving,
}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", nargs="*", default=None,
                    help="run just these benchmarks")
    ap.add_argument("--list", action="store_true",
                    help="print the dispatch table and exit")
    args = ap.parse_args(argv)

    names = discovered()
    # collect EVERY dispatch-table problem before exiting, so one run
    # surfaces the full repair list instead of one entry at a time
    problems = []
    missing = sorted(set(names) - set(DISPATCH))
    if missing:
        problems.append(
            f"benchmarks without a DISPATCH entry in benchmarks/run.py:"
            f" {', '.join(missing)} — add one so `python -m "
            "benchmarks.run` reproduces the full suite")
    stale = sorted(set(DISPATCH) - set(names))
    if stale:
        problems.append(f"DISPATCH entries without a benchmarks/*.py "
                        f"file: {', '.join(stale)}")
    if problems:
        sys.exit("; ".join(problems))
    if args.list:
        for n in names:
            print(n)
        return
    selected = args.only if args.only else names
    unknown = sorted(set(selected) - set(DISPATCH))
    if unknown:
        sys.exit(f"unknown benchmark(s): {', '.join(unknown)}; "
                 f"known: {', '.join(names)}")

    print("name,us_per_call,derived")
    if not args.only:           # driver-level extras only on full runs
        _run_core_search()
    for name in selected:
        DISPATCH[name]()
    if not args.only:
        _run_kernels()


if __name__ == "__main__":
    main()
