"""Serving-load benchmark (DESIGN.md §10): the micro-batching runtime
under one-request-at-a-time traffic, on every serving layout.

    PYTHONPATH=src python benchmarks/serving_load.py --smoke --check \\
        --out results/BENCH_serving.json                             # CI
    PYTHONPATH=src python benchmarks/serving_load.py                 # full

Each layout (plain / sharded / mutable / sharded-mutable) runs in its
own subprocess — sharded layouts need device emulation before jax
imports, and a cold jit cache is what makes the one-compile-per-bucket
accounting exact.  Per layout the bench reports:

  · the warmup compile ledger (exactly one program per bucket, zero
    compiles caused by serving afterwards);
  · bit-identity of runtime results vs direct ``Server.query`` —
    unfiltered and under per-query namespace filters;
  · ``qps_serial`` (the status quo: one synchronous ``Server.query``
    per request, padded to ``max_batch``) vs ``qps_runtime`` (the same
    requests through ``submit``, coalesced into buckets) — with
    ``--check`` the speedup must be ≥ 2×;
  · open-loop Poisson arrivals at a quarter of the measured burst
    capacity: sustained throughput and p50/p95/p99 latency;
  · the LRU cache replay: every repeat hits, bit-identical rows.

Quality/structural fields are deterministic and gated bit-exactly by
``benchmarks/check_regression.py``; wall-clock fields (``qps_*``,
``*_ms``, ``speedup*``) are compared within the timing ratio.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

import numpy as np

LAYOUTS = ("plain", "sharded", "mutable", "sharded_mutable")
N_NAMESPACES = 8


def _build_server(layout: str, args):
    import jax
    import jax.numpy as jnp
    from repro.core import hybrid_index as hi
    from repro.core import segments as seg
    from repro.data import synthetic
    from repro.launch import serve

    corpus = synthetic.generate(seed=0, n_docs=args.docs,
                                n_queries=args.queries,
                                hidden=args.hidden, vocab_size=args.vocab,
                                n_topics=32)
    build_kwargs = dict(n_clusters=args.clusters, k1_terms=8,
                        codec=args.codec, pq_m=4, pq_k=64,
                        cluster_capacity=192, term_capacity=96,
                        kmeans_iters=5)
    sharded = layout in ("sharded", "sharded_mutable")
    cfg = serve.ServeConfig(max_batch=args.max_batch,
                            n_shards=args.shards if sharded else 1,
                            mutable=layout in ("mutable", "sharded_mutable"),
                            delta_capacity=256,
                            n_namespaces=N_NAMESPACES)
    doc_ns = np.arange(args.docs) % N_NAMESPACES
    if cfg.mutable:
        mut = seg.MutableHybridIndex.create(
            jax.random.key(0), corpus.doc_emb, corpus.doc_tokens,
            corpus.vocab_size, delta_capacity=256,
            doc_namespaces=doc_ns, **build_kwargs)
        server = serve.make_mutable_server(mut, cfg)
    else:
        index = hi.build(jax.random.key(0), jnp.asarray(corpus.doc_emb),
                         jnp.asarray(corpus.doc_tokens), corpus.vocab_size,
                         doc_namespaces=doc_ns, **build_kwargs)
        server = serve.make_server(index, cfg)
    return corpus, server


def _equal(a, b) -> bool:
    return (np.array_equal(np.asarray(a.doc_ids), np.asarray(b.doc_ids))
            and np.array_equal(np.asarray(a.scores), np.asarray(b.scores))
            and np.array_equal(np.asarray(a.n_candidates),
                               np.asarray(b.n_candidates)))


def _percentiles(lat_s: list) -> dict:
    ms = np.asarray(lat_s) * 1e3
    return {"p50_ms": round(float(np.percentile(ms, 50)), 2),
            "p95_ms": round(float(np.percentile(ms, 95)), 2),
            "p99_ms": round(float(np.percentile(ms, 99)), 2)}


def run_layout(layout: str, args) -> dict:
    from repro.launch import runtime as rt_mod

    corpus, server = _build_server(layout, args)
    qe, qt = corpus.query_emb, corpus.query_tokens
    n_req = args.requests
    # request stream: cycle the distinct query pool
    req = [(qe[i % qe.shape[0]], qt[i % qt.shape[0]]) for i in range(n_req)]

    rt = rt_mod.ServingRuntime(
        server, rt_mod.RuntimeConfig(linger_ms=args.linger_ms,
                                     queue_depth=max(n_req, 64),
                                     cache_size=0))
    rt.warmup(args.hidden, qt.shape[1])

    # --- bit-identity: runtime rows == direct Server.query rows ---------
    b = min(args.max_batch, qe.shape[0])
    direct = server.query(qe[:b], qt[:b])
    via_rt = rt.query(qe[:b], qt[:b])
    bit_identical = _equal(direct, via_rt)
    want = [i % N_NAMESPACES for i in range(b)]
    direct_f = server.query(qe[:b], qt[:b], namespaces=want)
    via_rt_f = rt.query(qe[:b], qt[:b], namespaces=want)
    bit_identical_filtered = _equal(direct_f, via_rt_f)

    # --- serial baseline: one synchronous Server.query per request ------
    t0 = time.perf_counter()
    for e, t in req:
        server.query(e[None], t[None])
    serial_s = time.perf_counter() - t0
    qps_serial = n_req / serial_s

    # --- burst through the runtime: micro-batching capacity -------------
    t0 = time.perf_counter()
    futures = [rt.submit(e, t) for e, t in req]
    for f in futures:
        f.result()
    burst_s = time.perf_counter() - t0
    qps_runtime = n_req / burst_s

    # --- open-loop Poisson at a quarter of the measured burst capacity
    # (burst rides max_batch buckets; sparse arrivals ride small ones,
    # whose per-query cost is higher — 1/4 keeps the queue stable so the
    # percentiles measure service + linger, not runaway backlog) --------
    rate = max(qps_runtime / 4.0, 1.0)
    rng = np.random.default_rng(0)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n_req))
    done_at = [None] * n_req

    def _mark(i):
        def cb(_):
            done_at[i] = time.perf_counter()
        return cb

    t0 = time.perf_counter()
    for i, (e, t) in enumerate(req):
        lead = t0 + arrivals[i] - time.perf_counter()
        if lead > 0:
            time.sleep(lead)
        rt.submit(e, t).add_done_callback(_mark(i))
    while any(d is None for d in done_at):
        time.sleep(0.001)
    span = max(done_at) - t0
    latencies = [done_at[i] - (t0 + arrivals[i]) for i in range(n_req)]

    rt.close(drain=True)
    stats = rt.stats()

    # --- LRU cache replay: second pass all hits, bit-identical ----------
    cached = rt_mod.ServingRuntime(
        server, rt_mod.RuntimeConfig(linger_ms=args.linger_ms,
                                     queue_depth=max(n_req, 64),
                                     cache_size=2 * b))
    cached.warmup(args.hidden, qt.shape[1])
    first = cached.query(qe[:b], qt[:b])
    again = cached.query(qe[:b], qt[:b])
    cached.close(drain=True)
    cstats = cached.stats()["cache"]
    cache_report = {
        "queries": b,
        "hits": cstats["hits"],
        "bit_identical": _equal(first, again) and _equal(direct, again),
    }

    return {
        "layout": layout,
        "shards": server.cfg.n_shards,
        "mutable": server.cfg.mutable,
        "n_requests": n_req,
        "buckets": stats["buckets"],
        "warm_compiles": {str(k): v for k, v in
                          sorted(stats["warm_traces"].items())},
        "post_warmup_compiles": stats["post_warmup_traces"],
        "bit_identical": bool(bit_identical),
        "bit_identical_filtered": bool(bit_identical_filtered),
        # NOTE: the serial→runtime speedup is deliberately NOT a report
        # field: a ratio of two same-machine timings does not rescale
        # with runner speed, so the regression gate's timing tolerance
        # would mis-gate it.  The >= 2x contract is enforced by --check
        # (below) from the two absolute qps numbers, which the gate
        # compares the normal wall-clock way.
        "qps_serial": round(qps_serial, 1),
        "qps_runtime": round(qps_runtime, 1),
        "poisson": {"qps_offered": round(rate, 1),
                    "qps_sustained": round(n_req / span, 1),
                    **_percentiles(latencies)},
        "cache": cache_report,
    }


def _check_layout(rep: dict) -> list:
    fails = []
    name = rep["layout"]
    if not rep["bit_identical"]:
        fails.append(f"{name}: runtime results != direct Server.query")
    if not rep["bit_identical_filtered"]:
        fails.append(f"{name}: filtered runtime results != direct")
    bad = {b: n for b, n in rep["warm_compiles"].items() if n != 1}
    if bad:
        fails.append(f"{name}: warmup compiles per bucket != 1: {bad}")
    if rep["post_warmup_compiles"]:
        fails.append(f"{name}: {rep['post_warmup_compiles']} compiles "
                     "caused by serving after warmup")
    speedup = rep["qps_runtime"] / rep["qps_serial"]
    if speedup < 2.0:
        fails.append(f"{name}: micro-batched throughput only "
                     f"{speedup:.2f}x the serial baseline (< 2x)")
    if rep["cache"]["hits"] != rep["cache"]["queries"]:
        fails.append(f"{name}: cache replay hit {rep['cache']['hits']}"
                     f"/{rep['cache']['queries']}")
    if not rep["cache"]["bit_identical"]:
        fails.append(f"{name}: cached rows != uncached rows")
    return fails


def _spawn_layout(layout: str, argv: list) -> dict:
    """Run one layout in a fresh interpreter: sharded layouts need the
    device-emulation flag before jax imports, and every layout needs a
    cold jit cache for exact compile accounting."""
    env = dict(os.environ)
    env["PYTHONPATH"] = f"src:{env.get('PYTHONPATH', '')}".rstrip(":")
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=2").strip()
    r = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--layout", layout,
         *argv], capture_output=True, text=True, env=env)
    if r.returncode != 0:
        sys.exit(f"serving_load --layout {layout} failed:\n"
                 f"{r.stdout}\n{r.stderr}")
    return json.loads(r.stdout[r.stdout.index("{"):])


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small corpus (CI scale)")
    ap.add_argument("--layout", default=None, choices=LAYOUTS,
                    help="run ONE layout in-process (internal: the "
                         "default orchestrates all four in subprocesses)")
    ap.add_argument("--codec", default="pq")
    ap.add_argument("--shards", type=int, default=2)
    ap.add_argument("--max-batch", type=int, default=None)
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--linger-ms", type=float, default=2.0)
    ap.add_argument("--out", default=None,
                    help="write BENCH_serving.json here")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero unless results are bit-identical "
                         "to direct serving, each bucket compiled once, "
                         "and micro-batching is >= 2x the serial baseline")
    args = ap.parse_args(argv)
    if args.smoke:
        args.docs, args.queries = 4000, 128
        args.hidden, args.vocab, args.clusters = 32, 2048, 64
        args.max_batch = args.max_batch or 32
        args.requests = args.requests or 192
    else:
        args.docs, args.queries = 20_000, 512
        args.hidden, args.vocab, args.clusters = 64, 8192, 256
        args.max_batch = args.max_batch or 64
        args.requests = args.requests or 1024

    if args.layout:
        report = run_layout(args.layout, args)
    else:
        sub_argv = ["--codec", args.codec, "--shards", str(args.shards),
                    "--max-batch", str(args.max_batch),
                    "--requests", str(args.requests),
                    "--linger-ms", str(args.linger_ms)]
        if args.smoke:
            sub_argv.append("--smoke")
        report = {
            "bench": "serving",
            "smoke": bool(args.smoke),
            "codec": args.codec,
            "n_docs": args.docs,
            "max_batch": args.max_batch,
            "n_requests": args.requests,
            "linger_ms": args.linger_ms,
            "n_namespaces": N_NAMESPACES,
            "layouts": {name: _spawn_layout(name, sub_argv)
                        for name in LAYOUTS},
        }

    text = json.dumps(report, indent=2)
    print(text)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            f.write(text + "\n")
    if args.check:
        reps = ([report] if args.layout
                else [report["layouts"][n] for n in LAYOUTS])
        failures = [msg for rep in reps for msg in _check_layout(rep)]
        if failures:
            sys.exit("; ".join(failures))


if __name__ == "__main__":
    main()
