"""Sharded-search benchmark: throughput vs shard count against the
single-device baseline (DESIGN.md §6).

Must own the process before jax initializes so it can emulate devices:

    PYTHONPATH=src python benchmarks/sharded_search.py --devices 4
    PYTHONPATH=src python benchmarks/sharded_search.py --devices 4 \\
        --out results/sharded_search.json

Emits a JSON document: the single-device baseline, one entry per shard
count in {2, 4, ..., --devices}, equality of the returned top-R against
the baseline, and per-device doc-plane bytes (the HBM win).  On
emulated CPU devices collective overhead dominates, so the interesting
number at laptop scale is the *identical doc_ids* column and the bytes
column — the throughput column becomes meaningful on real multi-chip
meshes where the per-shard gather+ADC actually runs in parallel.
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def _parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=4,
                    help="emulated host devices (= max shard count)")
    ap.add_argument("--docs", type=int, default=20_000)
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--top-r", type=int, default=100)
    # validated against the codec registry inside run() — the registry
    # (and jax) must not be imported before XLA_FLAGS is set in main()
    ap.add_argument("--codec", default=None,
                    help="codec spec to serve (default: the registry "
                         "default; any repro.core.codecs name works)")
    ap.add_argument("--out", default=None, help="also write JSON here")
    return ap.parse_args(argv)


def run(args) -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import codecs, hybrid_index as hi, sharded_index as shi
    from repro.data import synthetic

    codec = args.codec or codecs.DEFAULT
    codecs.get(codec)   # fail fast (with the registered names) on typos

    def time_call(fn, *a, warmup=2, iters=5):
        import time
        for _ in range(warmup):
            jax.block_until_ready(fn(*a))
        t0 = time.perf_counter()
        for _ in range(iters):
            jax.block_until_ready(fn(*a))
        return (time.perf_counter() - t0) / iters * 1e6  # µs per call

    if jax.device_count() < 2:
        sys.exit(f"only {jax.device_count()} device(s) visible — nothing "
                 "to shard (check XLA_FLAGS / --devices)")

    corpus = synthetic.generate(seed=0, n_docs=args.docs,
                                n_queries=args.queries, hidden=64,
                                vocab_size=8192, n_topics=128)
    index = hi.build(jax.random.key(0), jnp.asarray(corpus.doc_emb),
                     jnp.asarray(corpus.doc_tokens), corpus.vocab_size,
                     n_clusters=256, k1_terms=12, codec=codec, pq_m=8,
                     pq_k=256, cluster_capacity=256, term_capacity=128,
                     kmeans_iters=10)
    qe = jnp.asarray(corpus.query_emb)
    qt = jnp.asarray(corpus.query_tokens)
    kc, k2, top_r = 6, 8, args.top_r

    def doc_plane_bytes(doc_planes, entries_c, entries_t):
        planes = sum(np.asarray(leaf).nbytes
                     for leaf in jax.tree.leaves(doc_planes))
        return (planes + np.asarray(entries_c).nbytes
                + np.asarray(entries_t).nbytes)

    us = time_call(lambda: hi.search(index, qe, qt, kc=kc, k2=k2,
                                     top_r=top_r))
    ref = hi.search(index, qe, qt, kc=kc, k2=k2, top_r=top_r)
    report = {
        "n_docs": args.docs,
        "n_queries": args.queries,
        "top_r": top_r,
        "codec": codec,
        "candidate_budget": hi.candidate_budget(index, kc, k2),
        "candidate_cost": hi.candidate_cost(index, kc, k2, top_r),
        "devices": jax.device_count(),
        "baseline": {
            "us_per_batch": round(us, 1),
            "qps": round(args.queries / us * 1e6, 1),
            "doc_plane_bytes_per_device": doc_plane_bytes(
                index.doc_planes, index.cluster_lists.entries,
                index.term_lists.entries),
        },
        "sharded": [],
    }

    n = 2
    while n <= min(args.devices, jax.device_count()):
        sidx = shi.partition(index, n)
        mesh = shi.make_shard_mesh(n)
        sidx = shi.device_put(sidx, mesh)
        us_n = time_call(lambda: shi.search(
            sidx, qe, qt, kc=kc, k2=k2, top_r=top_r, mesh=mesh))
        out = shi.search(sidx, qe, qt, kc=kc, k2=k2, top_r=top_r, mesh=mesh)
        report["sharded"].append({
            "shards": n,
            "us_per_batch": round(us_n, 1),
            "qps": round(args.queries / us_n * 1e6, 1),
            "speedup_vs_baseline": round(us / us_n, 3),
            "doc_ids_identical": bool(
                (np.asarray(out.doc_ids) == np.asarray(ref.doc_ids)).all()),
            "doc_plane_bytes_per_device": doc_plane_bytes(
                jax.tree.map(lambda x: x[0], sidx.doc_planes),
                sidx.cluster_entries[0], sidx.term_entries[0]),
        })
        n *= 2
    return report


def main(argv=None) -> None:
    args = _parse_args(argv)
    # must precede the first jax import anywhere in the process; append
    # to (not replace, not defer to) any existing XLA_FLAGS — otherwise
    # an inherited value leaves 1 device and the benchmark becomes a
    # vacuous green no-op
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count"
            f"={args.devices}").strip()
    report = run(args)
    text = json.dumps(report, indent=2)
    print(text)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            f.write(text + "\n")
    if not all(e["doc_ids_identical"] for e in report["sharded"]):
        sys.exit(1)


if __name__ == "__main__":
    main()
