"""Streaming-updates benchmark (DESIGN.md §8): search throughput vs
delta fill-fraction, tombstone honesty, and compaction cost.

    PYTHONPATH=src python benchmarks/streaming_updates.py --smoke \\
        --out results/BENCH_streaming.json                          # CI
    PYTHONPATH=src python benchmarks/streaming_updates.py           # full

Builds a base index over most of the corpus, streams the held-out tail
through ``add_docs`` in fill-fraction steps, deletes a slice, compacts,
and reports per-step recall (exact, deterministic — the regression-gate
fields) plus wall-clock timings (compared within tolerance by
``benchmarks/check_regression.py``).  With ``--check`` it exits nonzero
if a tombstoned doc surfaces or the compacted index is not bit-identical
to a from-scratch rebuild over the survivors — the §8 contract.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import codecs, hybrid_index as hi, metrics
from repro.core import segments as seg
from repro.data import synthetic

FILL_STEPS = (0.25, 0.5, 1.0)


def _time_call(fn, *a, warmup=2, iters=5) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*a))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*a))
    return (time.perf_counter() - t0) / iters * 1e6  # µs per call


def run(args) -> dict:
    codec = args.codec or codecs.DEFAULT
    codecs.get(codec)    # fail fast on typos, listing registered names

    if args.smoke:
        n_docs, stream, n_queries = 4000, 512, 64
        build_kwargs = dict(n_clusters=64, k1_terms=8, codec=codec,
                            pq_m=4, pq_k=64, cluster_capacity=192,
                            term_capacity=96, kmeans_iters=5)
        vocab, hidden, topics = 2048, 32, 32
    else:
        n_docs, stream, n_queries = 20_000, 2048, 256
        build_kwargs = dict(n_clusters=256, k1_terms=12, codec=codec,
                            pq_m=8, pq_k=256, cluster_capacity=256,
                            term_capacity=128, kmeans_iters=10)
        vocab, hidden, topics = 8192, 64, 128

    corpus = synthetic.generate(seed=0, n_docs=n_docs, n_queries=n_queries,
                                hidden=hidden, vocab_size=vocab,
                                n_topics=topics)
    qe = jnp.asarray(corpus.query_emb)
    qt = jnp.asarray(corpus.query_tokens)
    kc, k2, top_r = 6, 8, args.top_r

    t0 = time.perf_counter()
    mut = seg.MutableHybridIndex.create(
        jax.random.key(0), corpus.doc_emb[:-stream],
        corpus.doc_tokens[:-stream], corpus.vocab_size,
        delta_capacity=stream, **build_kwargs)
    build_s = time.perf_counter() - t0

    def point(fill_fraction: float) -> dict:
        r = mut.search(qe, qt, kc=kc, k2=k2, top_r=top_r)
        us = _time_call(lambda: mut.search(qe, qt, kc=kc, k2=k2,
                                           top_r=top_r))
        return {
            "fill_fraction": fill_fraction,
            "delta_docs": mut.delta_count,
            "R@100": metrics.recall_at_k(r.doc_ids, corpus.qrels, 100),
            "mean_candidates": float(np.asarray(r.n_candidates).mean()),
            "search_us_per_batch": round(us, 1),
        }

    report = {
        "bench": "streaming",
        "smoke": bool(args.smoke),
        "codec": codec,
        "n_docs": n_docs,
        "streamed_docs": stream,
        "n_queries": n_queries,
        "top_r": top_r,
        "candidate_budget_base": hi.candidate_budget(mut.base, kc, k2),
        "candidate_budget_mutable": mut.candidate_budget(kc, k2),
        "candidate_cost_mutable": mut.candidate_cost(kc, k2, top_r),
        "base_build_seconds": round(build_s, 2),
        "points": [point(0.0)],
    }

    # --- stream the held-out tail in fill-fraction steps -----------------
    tail_emb = corpus.doc_emb[-stream:]
    tail_tok = corpus.doc_tokens[-stream:]
    added_ids, done = [], 0
    add_s = 0.0
    for frac in FILL_STEPS:
        upto = int(round(frac * stream))
        t0 = time.perf_counter()
        ids = mut.add_docs(tail_emb[done:upto], tail_tok[done:upto])
        add_s += time.perf_counter() - t0
        added_ids.append(ids)
        done = upto
        report["points"].append(point(frac))
    added = np.concatenate(added_ids)
    report["add_seconds_total"] = round(add_s, 2)
    report["dropped_postings"] = mut.dropped_postings

    # --- deletes: a slice of the streamed docs must vanish ---------------
    doomed = added[:stream // 4]
    mut.delete_docs(doomed)
    r = mut.search(qe, qt, kc=kc, k2=k2, top_r=top_r)
    surfaced = bool(np.isin(np.asarray(r.doc_ids), doomed).any())
    report["deletes"] = {
        "n_deleted": int(doomed.size),
        "tombstones_absent": not surfaced,
        "R@100": metrics.recall_at_k(r.doc_ids, corpus.qrels, 100),
        "search_us_per_batch": round(
            _time_call(lambda: mut.search(qe, qt, kc=kc, k2=k2,
                                          top_r=top_r)), 1),
    }

    # --- compaction: cost + bit-identity vs a from-scratch rebuild -------
    t0 = time.perf_counter()
    compacted = mut.compact()
    compact_s = time.perf_counter() - t0
    emb, tok = mut.surviving_corpus()
    rebuilt = hi.build(jax.random.key(0), jnp.asarray(emb),
                       jnp.asarray(tok), corpus.vocab_size, **build_kwargs)
    rc = compacted.search(qe, qt, kc=kc, k2=k2, top_r=top_r)
    rr = hi.search(rebuilt, qe, qt, kc=kc, k2=k2, top_r=top_r)
    equal = (np.array_equal(np.asarray(rc.doc_ids), np.asarray(rr.doc_ids))
             and np.array_equal(np.asarray(rc.scores),
                                np.asarray(rr.scores)))
    # compaction renumbers survivors contiguously — map the qrels
    # through the old->new correspondence before scoring recall
    # (deleted positives keep -2: never retrievable, an honest miss)
    old_to_new = np.full(mut.n_docs, -2, np.int64)
    old_to_new[mut.survivors()] = np.arange(compacted.n_base)
    qrels_new = old_to_new[corpus.qrels]
    report["compaction"] = {
        "seconds": round(compact_s, 2),
        "equal_to_rebuild": bool(equal),
        "n_live": compacted.n_base,
        "R@100": metrics.recall_at_k(rc.doc_ids, qrels_new, 100),
        "search_us_per_batch": round(
            _time_call(lambda: compacted.search(qe, qt, kc=kc, k2=k2,
                                                top_r=top_r)), 1),
    }
    return report


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small corpus (CI scale)")
    ap.add_argument("--codec", default=None,
                    help="codec spec (default: the registry default)")
    ap.add_argument("--top-r", type=int, default=100)
    ap.add_argument("--out", default=None,
                    help="write BENCH_streaming.json here")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero if a tombstoned doc surfaces or "
                         "compact() diverges from a from-scratch rebuild")
    args = ap.parse_args(argv)

    report = run(args)
    text = json.dumps(report, indent=2)
    print(text)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            f.write(text + "\n")
    if args.check:
        failures = []
        if not report["deletes"]["tombstones_absent"]:
            failures.append("a tombstoned doc surfaced in the top-R")
        if not report["compaction"]["equal_to_rebuild"]:
            failures.append("compact() != from-scratch rebuild")
        if failures:
            sys.exit("; ".join(failures))


if __name__ == "__main__":
    main()
