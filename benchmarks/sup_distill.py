"""Supervised HI² distillation benchmark (paper §4.3, DESIGN.md §15):
the selector-quality evidence chain for HI²_sup.

    PYTHONPATH=src python benchmarks/sup_distill.py --smoke --check \\
        --out results/BENCH_sup.json                              # CI
    PYTHONPATH=src python benchmarks/sup_distill.py               # full

Two stages:

  · **train + sweep** (in-process): build the HI²_unsup baseline, mine
    its top-scoring non-relevant docs as hard negatives (union with the
    topic-matched pool), train the supervised selectors with in-batch
    negatives and the refine-stage KL (§15 recipe), assemble HI²_sup at
    the frozen training-time φ, and sweep recall@R against the unsup
    index over the shared ``frontier.WIDTH_GRID`` operating points —
    matched capacities make ``candidate_cost`` *identical* at every
    (kc, k2), so any recall delta is pure selector quality.  The sup
    index is also round-tripped through ``save_index``/
    ``restore_index`` and compared plane-by-plane.
  · **variants** (subprocess, 2 emulated devices): the trained
    ``SupSelectors`` bundle drives all four serving layouts (plain /
    sharded / mutable / sharded-mutable) to bit-identical doc ids, and
    a supervised *mutable* index survives add → delete → compact with
    the compaction bit-identical to a from-scratch supervised build
    over the survivors.

``--check`` enforces the §15 acceptance contracts: (a) sup recall >=
unsup at matched cost on at least one operating point (costs asserted
equal), (b) the loss trajectory is monotone-ish (windowed means
decrease), (c) the index round-trip is bit-identical, (d) all four
layouts agree and the mutable lifecycle holds.  Every report field is
deterministic (losses rounded to 4dp, no wall-clock), so the
regression gate compares bit-exactly.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import subprocess
import sys
import tempfile

import numpy as np

LAYOUTS = ("plain", "sharded", "mutable", "sharded_mutable")
CODEC = "opq"

#: oracle width (see benchmarks/autotune.py): recall@top_r of the exact
#: top-10 neighbors — the teacher's own objective (Eq. 10), so the
#: sweep measures exactly what distillation optimizes
ORACLE_WIDTH = 10

#: fraction of consecutive loss windows whose mean must improve on the
#: previous window for the trajectory to count as monotone-ish
MONOTONE_FRAC = 0.7


def _scale(args) -> None:
    if args.smoke:
        args.docs, args.queries = 2500, 192
        args.hidden, args.vocab, args.clusters = 32, 2048, 32
        args.pq_m, args.pq_k, args.kmeans_iters = 4, 64, 6
        args.steps = args.steps or 160
    else:
        args.docs, args.queries = 4000, 256
        args.hidden, args.vocab, args.clusters = 32, 2048, 32
        args.pq_m, args.pq_k, args.kmeans_iters = 8, 64, 8
        args.steps = args.steps or 300


def _common(args) -> dict:
    return dict(k1_terms=8, codec=CODEC, pq_m=args.pq_m, pq_k=args.pq_k,
                cluster_capacity=512, term_capacity=96)


def _corpus(args):
    from repro.data import synthetic
    return synthetic.generate(seed=0, n_docs=args.docs,
                              n_queries=args.queries, hidden=args.hidden,
                              vocab_size=args.vocab,
                              n_topics=args.clusters)


def _cfg(args, n_steps=None):
    from repro.launch import train as tr
    return tr.SupTrainConfig(
        n_clusters=args.clusters, encoder_layers=1,
        encoder_dim=args.hidden, encoder_heads=2,
        n_steps=args.steps if n_steps is None else n_steps,
        batch_queries=32, n_negatives=7, n_inbatch=4, refine_weight=0.5,
        lr=2e-3, kmeans_iters=args.kmeans_iters, seed=0)


def _tree_equal(a, b) -> bool:
    import jax
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(la, lb))


# --------------------------------------------------------------------------
# stage: train + sweep (in-process)
# --------------------------------------------------------------------------

def run_train_sweep(args, ckpt_dir: str) -> dict:
    import jax
    import jax.numpy as jnp
    from repro import checkpoint as ckpt
    from repro.core import distill, hybrid_index as hi, metrics
    from repro.core.exec import frontier
    from repro.data import synthetic
    from repro.launch import train as tr, tune

    corpus = _corpus(args)
    common = _common(args)
    qe, qt = jnp.asarray(corpus.query_emb), jnp.asarray(corpus.query_tokens)
    oracle = tune.exact_oracle(corpus.doc_emb, corpus.query_emb,
                               ORACLE_WIDTH)

    unsup = hi.build(jax.random.key(0), jnp.asarray(corpus.doc_emb),
                     jnp.asarray(corpus.doc_tokens), corpus.vocab_size,
                     n_clusters=args.clusters,
                     kmeans_iters=args.kmeans_iters, **common)

    # §15 negative pool: topic-matched ∪ mined-from-the-unsup-index
    topic = synthetic.hard_negatives(corpus, 7, seed=0)
    mined = distill.mine_hard_negatives(unsup, corpus.query_emb,
                                        corpus.query_tokens, corpus.qrels,
                                        7)
    pool = np.concatenate([topic, mined], axis=1)

    cfg = _cfg(args)
    params, enc_cfg, assign, losses = tr.train_hi2_sup(
        corpus, cfg, log_every=0, negatives=pool)
    ckpt.save(ckpt_dir, cfg.n_steps, {"params": params})

    sup = tr.build_sup_index(corpus, params, enc_cfg, assign, **common)

    points, wins = [], 0
    for kc, k2 in frontier.WIDTH_GRID:
        ru = hi.search(unsup, qe, qt, kc=kc, k2=k2, top_r=args.top_r)
        rs = hi.search(sup, qe, qt, kc=kc, k2=k2, top_r=args.top_r)
        cost_u = hi.candidate_cost(unsup, kc, k2, args.top_r)
        cost_s = hi.candidate_cost(sup, kc, k2, args.top_r)
        r_u = round(float(tune.per_query_recall(
            ru.doc_ids, oracle, args.top_r).mean()), 4)
        r_s = round(float(tune.per_query_recall(
            rs.doc_ids, oracle, args.top_r).mean()), 4)
        wins += r_s >= r_u
        points.append({
            "kc": kc, "k2": k2,
            "cost_unsup": int(cost_u), "cost_sup": int(cost_s),
            "recall_unsup": r_u, "recall_sup": r_s,
            "qrels_recall_unsup": round(metrics.recall_at_k(
                ru.doc_ids, corpus.qrels, args.top_r), 4),
            "qrels_recall_sup": round(metrics.recall_at_k(
                rs.doc_ids, corpus.qrels, args.top_r), 4),
        })

    # loss trajectory: windowed means over 10 equal slices
    n = len(losses)
    w = max(1, n // 10)
    windows = [round(float(np.mean(losses[i:i + w])), 4)
               for i in range(0, n - w + 1, w)]
    improving = sum(b < a for a, b in zip(windows, windows[1:]))
    trajectory = {
        "n_steps": n,
        "loss_first": round(losses[0], 4),
        "loss_last": round(losses[-1], 4),
        "window_means": windows,
        "frac_improving_windows": round(improving / max(
            1, len(windows) - 1), 4),
    }

    # (c) assembly bit-round-trips through the index checkpoint
    with tempfile.TemporaryDirectory() as tmp:
        path = ckpt.save_index(tmp, 0, sup)
        restored = ckpt.restore_index(path, sup)
    rt = hi.search(restored, qe, qt, kc=6, k2=8, top_r=args.top_r)
    rd = hi.search(sup, qe, qt, kc=6, k2=8, top_r=args.top_r)
    roundtrip = {
        "planes_bit_identical": _tree_equal(sup, restored),
        "search_bit_identical": bool(
            np.array_equal(np.asarray(rt.doc_ids), np.asarray(rd.doc_ids))
            and np.array_equal(np.asarray(rt.scores),
                               np.asarray(rd.scores))),
    }

    return {
        "codec": CODEC,
        "top_r": args.top_r,
        "oracle_width": ORACLE_WIDTH,
        "negative_pool": {"topic": int(topic.shape[1]),
                          "mined": int(mined.shape[1]),
                          "in_batch": cfg.n_inbatch},
        "refine_weight": cfg.refine_weight,
        "operating_points": points,
        "sup_wins": int(wins),
        "n_operating_points": len(points),
        "trajectory": trajectory,
        "roundtrip": roundtrip,
    }


# --------------------------------------------------------------------------
# stage: variants (subprocess, 2 emulated devices)
# --------------------------------------------------------------------------

def run_variants(args) -> dict:
    import jax
    import jax.numpy as jnp
    from repro import checkpoint as ckpt
    from repro.core import hybrid_index as hi
    from repro.core import segments as seg
    from repro.launch import serve
    from repro.launch import train as tr

    corpus = _corpus(args)
    common = _common(args)
    b = 64
    qe, qt = (jnp.asarray(corpus.query_emb[:b]),
              jnp.asarray(corpus.query_tokens[:b]))
    kc, k2 = 6, 8

    # n_steps=0 reruns only the (deterministic) KMeans init — the
    # checkpoint written by the train stage supplies the trained values
    params0, enc_cfg, _, _ = tr.train_hi2_sup(corpus, _cfg(args, 0),
                                              log_every=0)
    params = ckpt.restore(args.params_ckpt, {"params": params0})["params"]
    sel = tr.SupSelectors(params=params, enc_cfg=enc_cfg)

    # all four layouts share one base: hi.build under the selector
    # bundle (argmax φ — the corpus-independent recipe compaction needs)
    sel_kwargs = sel.build_inputs(jnp.asarray(corpus.doc_emb),
                                  jnp.asarray(corpus.doc_tokens),
                                  corpus.vocab_size)
    base = hi.build(jax.random.key(0), jnp.asarray(corpus.doc_emb),
                    jnp.asarray(corpus.doc_tokens), corpus.vocab_size,
                    n_clusters=args.clusters, **sel_kwargs, **common)
    ref = hi.search(base, qe, qt, kc=kc, k2=k2, top_r=args.top_r)
    ref_ids = np.asarray(ref.doc_ids)

    def build_mut():
        return seg.MutableHybridIndex.create(
            jax.random.key(0), corpus.doc_emb, corpus.doc_tokens,
            corpus.vocab_size, delta_capacity=128, selectors=sel,
            **common)

    report = {}
    kw = dict(top_r=args.top_r, max_batch=b)
    report["plain"] = {"ids_identical": True}        # the reference
    sh = serve.make_server(base, serve.ServeConfig(
        kc=kc, k2=k2, n_shards=2, **kw))
    report["sharded"] = {"ids_identical": bool(np.array_equal(
        np.asarray(sh.query(corpus.query_emb[:b],
                            corpus.query_tokens[:b]).doc_ids), ref_ids))}
    mut = build_mut()
    report["mutable"] = {"ids_identical": bool(np.array_equal(
        np.asarray(mut.search(qe, qt, kc=kc, k2=k2,
                              top_r=args.top_r).doc_ids), ref_ids))}
    smut = serve.make_mutable_server(build_mut(), serve.ServeConfig(
        kc=kc, k2=k2, n_shards=2, mutable=True, delta_capacity=128, **kw))
    report["sharded_mutable"] = {"ids_identical": bool(np.array_equal(
        np.asarray(smut.query(corpus.query_emb[:b],
                              corpus.query_tokens[:b]).doc_ids), ref_ids))}

    # supervised mutable lifecycle: add → delete → compact, with the
    # compaction bit-identical to a from-scratch supervised build over
    # the survivors (the §10 contract, now under learned selectors)
    n0 = args.docs
    ids = mut.add_docs(corpus.query_emb[:16], corpus.query_tokens[:16])
    mut.delete_docs(ids[:4])
    mut.delete_docs(np.arange(8))
    comp = mut.compact()
    emb_s, tok_s = mut.surviving_corpus()
    scratch = seg.MutableHybridIndex.create(
        jax.random.key(0), emb_s, tok_s, corpus.vocab_size,
        delta_capacity=128, selectors=sel, **common)
    c_res = comp.search(qe, qt, kc=kc, k2=k2, top_r=args.top_r)
    s_res = scratch.search(qe, qt, kc=kc, k2=k2, top_r=args.top_r)
    report["mutable_lifecycle"] = {
        "n_live_after": int(comp.n_docs),
        "expected_live": int(n0 + 16 - 12),
        "compact_equals_scratch": bool(
            _tree_equal(comp.base, scratch.base)
            and np.array_equal(np.asarray(c_res.doc_ids),
                               np.asarray(s_res.doc_ids))
            and np.array_equal(np.asarray(c_res.scores),
                               np.asarray(s_res.scores))),
    }
    return report


# --------------------------------------------------------------------------
# orchestration + checks
# --------------------------------------------------------------------------

def _spawn(stage: str, argv: list, devices: int = 1) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = f"src:{env.get('PYTHONPATH', '')}".rstrip(":")
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={devices}").strip()
    r = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--stage", stage,
         *argv], capture_output=True, text=True, env=env)
    if r.returncode != 0:
        sys.exit(f"sup_distill --stage {stage} failed:\n"
                 f"{r.stdout}\n{r.stderr}")
    return json.loads(r.stdout[r.stdout.index("{"):])


def _check(report: dict) -> list:
    fails = []
    # (a) matched cost, and sup must win somewhere
    for p in report["operating_points"]:
        if p["cost_sup"] != p["cost_unsup"]:
            fails.append(f"kc={p['kc']} k2={p['k2']}: costs not matched "
                         f"({p['cost_sup']} vs {p['cost_unsup']})")
    if report["sup_wins"] < 1:
        fails.append("sup recall < unsup at every matched operating "
                     "point — distillation buys nothing")
    # (b) loss trajectory monotone-ish
    t = report["trajectory"]
    if t["loss_last"] >= t["loss_first"]:
        fails.append(f"loss did not decrease ({t['loss_first']} -> "
                     f"{t['loss_last']})")
    if t["frac_improving_windows"] < MONOTONE_FRAC:
        fails.append(f"loss trajectory not monotone-ish: only "
                     f"{t['frac_improving_windows']} of windows improve "
                     f"(need >= {MONOTONE_FRAC})")
    # (c) checkpoint round-trip
    for k, v in report["roundtrip"].items():
        if not v:
            fails.append(f"index round-trip failed: {k}")
    # (d) four layouts + mutable lifecycle
    for layout in LAYOUTS:
        if not report["variants"][layout]["ids_identical"]:
            fails.append(f"{layout}: doc ids differ from the plain "
                         "supervised search")
    life = report["variants"]["mutable_lifecycle"]
    if life["n_live_after"] != life["expected_live"]:
        fails.append(f"mutable lifecycle lost docs: {life['n_live_after']}"
                     f" live, expected {life['expected_live']}")
    if not life["compact_equals_scratch"]:
        fails.append("supervised compact() != from-scratch supervised "
                     "build over the survivors")
    return fails


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small corpus (CI scale)")
    ap.add_argument("--stage", default=None, choices=("variants",),
                    help="run ONE stage in-process (internal)")
    ap.add_argument("--top-r", type=int, default=100)
    ap.add_argument("--steps", type=int, default=None,
                    help="override the training step count")
    ap.add_argument("--params-ckpt", default=None,
                    help="trained-params checkpoint for --stage variants")
    ap.add_argument("--out", default=None,
                    help="write BENCH_sup.json here")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero unless the §15 acceptance "
                         "contracts (a)-(d) hold")
    args = ap.parse_args(argv)
    _scale(args)

    if args.stage == "variants":
        if not args.params_ckpt:
            sys.exit("--stage variants needs --params-ckpt")
        report = run_variants(args)
    else:
        with tempfile.TemporaryDirectory() as ckpt_dir:
            sweep = run_train_sweep(args, ckpt_dir)
            step_dir = os.path.join(
                ckpt_dir, sorted(os.listdir(ckpt_dir))[-1])
            sub = ["--top-r", str(args.top_r), "--steps", str(args.steps),
                   "--params-ckpt", step_dir]
            if args.smoke:
                sub.append("--smoke")
            report = {
                "bench": "sup_distill",
                "smoke": bool(args.smoke),
                "n_docs": args.docs,
                "n_queries": args.queries,
                **sweep,
                "variants": _spawn("variants", sub, devices=2),
            }

    text = json.dumps(report, indent=2)
    print(text)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            f.write(text + "\n")
    if args.check and args.stage is None:
        failures = _check(report)
        if failures:
            sys.exit("; ".join(failures))


if __name__ == "__main__":
    main()
