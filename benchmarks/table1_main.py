"""Paper Table 1 — overall evaluation: quality + efficiency of every
index family on the synthetic benchmark corpus.

Rows: Flat (brute force), IVF-OPQ, Distill-VQ (learned clusters, no
terms), term-only, HI²_unsup, HI²_sup.  Columns: MRR@10, R@10, R@100,
candidate budget (the latency proxy — §5.1: same candidates ⇒ same
latency), index size.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import cluster_selector as cs_mod, hybrid_index as hi
from repro.core.codecs import flat


def run() -> list[dict]:
    c = common.corpus()
    qe, qt = common.queries()
    rows = []

    # Flat upper bound
    _, fids = flat.search(qe, jnp.asarray(c.doc_emb), k=common.TOP_R)
    r = hi.SearchResult(doc_ids=fids, scores=jnp.zeros_like(fids, jnp.float32),
                        n_candidates=jnp.full((qe.shape[0],), c.doc_emb.shape[0],
                                              jnp.int32))
    rows.append(dict(method="Flat(brute force)", **common.evaluate(r),
                     index_bytes=c.doc_emb.nbytes))

    idx = common.unsup_index()
    # IVF-OPQ — cluster-only at a LARGER budget than HI² (paper setting)
    r = hi.search_ivf(idx, qe, qt, kc=10, top_r=common.TOP_R)
    rows.append(dict(method="IVF-OPQ", **common.evaluate(r),
                     index_bytes=common.index_size_bytes(idx)))

    # Distill-VQ: learned cluster embeddings, no term lists
    params, enc_cfg, assign = common.sup_artifacts()
    dv = hi.build(jax.random.key(3), jnp.asarray(c.doc_emb),
                  jnp.asarray(c.doc_tokens), c.vocab_size,
                  n_clusters=common.N_CLUSTERS,
                  cluster_sel=cs_mod.ClusterSelector(
                      embeddings=params.cluster_embeddings),
                  doc_assign=assign, use_terms=False,
                  **common.COMMON_INDEX)
    r = hi.search_ivf(dv, qe, qt, kc=10, top_r=common.TOP_R)
    rows.append(dict(method="Distill-VQ", **common.evaluate(r),
                     index_bytes=common.index_size_bytes(dv)))

    # term-only (w.o. Clus)
    r = hi.search_term_only(idx, qe, qt, k2=common.K2, top_r=common.TOP_R)
    rows.append(dict(method="TermOnly(w.o.Clus)", **common.evaluate(r),
                     index_bytes=common.index_size_bytes(idx)))

    # HI² unsup / sup
    r = hi.search(idx, qe, qt, kc=common.KC, k2=common.K2, top_r=common.TOP_R)
    rows.append(dict(method="HI2_unsup", **common.evaluate(r),
                     index_bytes=common.index_size_bytes(idx)))
    sup = common.sup_index()
    r = hi.search(sup, qe, qt, kc=common.KC, k2=common.K2, top_r=common.TOP_R)
    rows.append(dict(method="HI2_sup", **common.evaluate(r),
                     index_bytes=common.index_size_bytes(sup)))
    return rows


def main():
    for row in run():
        print(row)


if __name__ == "__main__":
    main()
