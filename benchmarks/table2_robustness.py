"""Paper Table 2 — robustness across embedding models (RQ3).

The same corpus under two encoders: model A (the generator's encoder)
and model B (rotated + noisier — a weaker but consistent encoder).
HI² must track brute-force quality under both; IVF must not.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import hybrid_index as hi, metrics
from repro.core.codecs import flat


def _eval_model(doc_emb, query_emb, tag: str) -> list[dict]:
    c = common.corpus()
    qt = jnp.asarray(c.query_tokens)
    qe = jnp.asarray(query_emb)
    rows = []

    _, fids = flat.search(qe, jnp.asarray(doc_emb), k=common.TOP_R)
    rows.append(dict(model=tag, method="Flat",
                     R100=metrics.recall_at_k(fids, c.qrels, 100)))

    idx = hi.build(jax.random.key(0), jnp.asarray(doc_emb),
                   jnp.asarray(c.doc_tokens), c.vocab_size,
                   n_clusters=common.N_CLUSTERS, kmeans_iters=10,
                   **common.COMMON_INDEX)
    r = hi.search_ivf(idx, qe, qt, kc=10, top_r=common.TOP_R)
    rows.append(dict(model=tag, method="IVF-OPQ",
                     R100=metrics.recall_at_k(r.doc_ids, c.qrels, 100)))
    r = hi.search(idx, qe, qt, kc=common.KC, k2=common.K2,
                  top_r=common.TOP_R)
    rows.append(dict(model=tag, method="HI2_unsup",
                     R100=metrics.recall_at_k(r.doc_ids, c.qrels, 100)))
    return rows


def run() -> list[dict]:
    c = common.corpus()
    rows = _eval_model(c.doc_emb, c.query_emb, "encA")
    rows += _eval_model(c.doc_emb_b, c.query_emb_b, "encB(weaker)")
    return rows


def main():
    for row in run():
        print(row)


if __name__ == "__main__":
    main()
