"""Paper Table 3 (appendix C) — codec analysis: the same HI² lists
evaluated with the PQ/OPQ codec vs the Flat codec (quality/size trade)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import hybrid_index as hi


def run() -> list[dict]:
    c = common.corpus()
    qe, qt = common.queries()
    rows = []
    for codec in ("opq", "pq", "flat"):
        kwargs = dict(common.COMMON_INDEX)
        kwargs["codec"] = codec
        idx = hi.build(jax.random.key(0), jnp.asarray(c.doc_emb),
                       jnp.asarray(c.doc_tokens), c.vocab_size,
                       n_clusters=common.N_CLUSTERS, kmeans_iters=10,
                       **kwargs)
        r = hi.search(idx, qe, qt, kc=common.KC, k2=common.K2,
                      top_r=common.TOP_R)
        rows.append(dict(codec=codec, **common.evaluate(r),
                         index_bytes=common.index_size_bytes(idx)))
    return rows


def main():
    for row in run():
        print(row)


if __name__ == "__main__":
    main()
