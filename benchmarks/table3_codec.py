"""Paper Table 3 (appendix C), generalized — the same HI² lists
evaluated under every codec in the registry (DESIGN.md §7): the
quality / bytes-per-doc / candidate-cost trade across index settings.

    PYTHONPATH=src python benchmarks/table3_codec.py                # full
    PYTHONPATH=src python benchmarks/table3_codec.py --smoke \\
        --out results/BENCH_codec.json                              # CI

Emits ``BENCH_codec.json`` and (with ``--check``) exits nonzero if the
refine codec fails its contract: recall@R within 0.001 of the flat
codec at ≤ 1.25× the pq candidate-cost proxy ("lossless at PQ cost").
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import codecs, hybrid_index as hi, metrics
from repro.data import synthetic

#: tolerance/cost bounds of the refine contract (also enforced in CI)
RECALL_SLACK = 0.001
COST_RATIO = 1.25


def _rows(corpus, *, n_clusters, kmeans_iters, index_kwargs,
          kc, k2, top_r, specs) -> list[dict]:
    de, dt = jnp.asarray(corpus.doc_emb), jnp.asarray(corpus.doc_tokens)
    qe, qt = jnp.asarray(corpus.query_emb), jnp.asarray(corpus.query_tokens)
    rows = []
    sel = {}     # cluster selector/assignment reused after the first build
    for spec in specs:
        kwargs = dict(index_kwargs)
        kwargs["codec"] = spec
        idx = hi.build(jax.random.key(0), de, dt, corpus.vocab_size,
                       n_clusters=n_clusters, kmeans_iters=kmeans_iters,
                       **kwargs, **sel)
        # identical key+data ⇒ identical lists; skip KMeans (the
        # dominant build cost) on the remaining codecs
        sel = {"cluster_sel": idx.cluster_sel, "doc_assign": idx.doc_assign}
        r = hi.search(idx, qe, qt, kc=kc, k2=k2, top_r=top_r)
        rows.append(dict(
            codec=spec,
            resolved=codecs.get(spec).name,
            **{"R@10": metrics.recall_at_k(r.doc_ids, corpus.qrels, 10),
               "R@100": metrics.recall_at_k(r.doc_ids, corpus.qrels, 100),
               "MRR@10": metrics.mrr_at_k(r.doc_ids, corpus.qrels, 10),
               "candidates": float(r.n_candidates.mean())},
            bytes_per_doc=codecs.get(spec).bytes_per_doc(idx.doc_planes),
            index_bytes=common.index_size_bytes(idx),
            candidate_budget=hi.candidate_budget(idx, kc, k2),
            candidate_cost=hi.candidate_cost(idx, kc, k2, top_r)))
    return rows


def run(smoke: bool = False, specs=None) -> list[dict]:
    """Sweep the registered codecs; ``smoke`` shrinks the corpus for CI."""
    specs = list(specs) if specs else codecs.registered()
    if smoke:
        corpus = synthetic.generate(seed=0, n_docs=4000, n_queries=128,
                                    hidden=32, vocab_size=2048, n_topics=32)
        return _rows(corpus, n_clusters=64, kmeans_iters=5,
                     index_kwargs=dict(k1_terms=8, pq_m=4, pq_k=64,
                                       cluster_capacity=192,
                                       term_capacity=96),
                     kc=common.KC, k2=common.K2, top_r=common.TOP_R,
                     specs=specs)
    kwargs = dict(common.COMMON_INDEX)
    kwargs.pop("codec")
    return _rows(common.corpus(), n_clusters=common.N_CLUSTERS,
                 kmeans_iters=10, index_kwargs=kwargs,
                 kc=common.KC, k2=common.K2, top_r=common.TOP_R, specs=specs)


def check(rows: list[dict]) -> tuple[str, list[str]]:
    """The refine-over-pq contract: recall within ``RECALL_SLACK`` of
    flat at ≤ ``COST_RATIO``× the pq cost proxy.

    Rows are matched by *resolved* codec name, so parameterized sweeps
    (``--codecs flat pq refine:pq:8``) still check.  Returns
    ``(status, failures)``: status is ``"skipped"`` when the sweep
    lacks a flat/pq/refine-over-pq triple (a partial ``--codecs`` run,
    not a contract violation), else ``"checked"``.
    """
    def find(pred):
        return next((r for r in rows if pred(r["resolved"])), None)

    flat = find(lambda n: n == "flat")
    pq = find(lambda n: n == "pq")
    refine = find(lambda n: n.startswith("refine:pq"))
    if not (flat and pq and refine):
        return "skipped", []
    failures = []
    if refine["R@100"] < flat["R@100"] - RECALL_SLACK:
        failures.append(
            f"refine R@100 {refine['R@100']:.4f} < flat "
            f"{flat['R@100']:.4f} - {RECALL_SLACK}")
    if refine["candidate_cost"] > COST_RATIO * pq["candidate_cost"]:
        failures.append(
            f"refine cost {refine['candidate_cost']} > {COST_RATIO}x pq "
            f"cost {pq['candidate_cost']}")
    return "checked", failures


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small corpus (CI scale)")
    ap.add_argument("--out", default=None,
                    help="write BENCH_codec.json here")
    ap.add_argument("--codecs", nargs="*", default=None,
                    help="codec specs to sweep (default: the registry)")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero if the refine contract fails")
    args = ap.parse_args(argv)

    rows = run(smoke=args.smoke, specs=args.codecs)
    status, failures = check(rows)
    report = {"bench": "codec", "smoke": args.smoke, "rows": rows,
              "refine_contract": {"recall_slack": RECALL_SLACK,
                                  "cost_ratio": COST_RATIO,
                                  "status": status,
                                  "failures": failures}}
    text = json.dumps(report, indent=2)
    print(text)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            f.write(text + "\n")
    if args.check:
        if status == "skipped":
            sys.exit("--check needs flat, pq and a refine:pq codec "
                     "in the sweep")
        if failures:
            sys.exit("; ".join(failures))


if __name__ == "__main__":
    main()
