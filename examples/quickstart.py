"""Quickstart: build a Hybrid Inverted Index over a synthetic corpus and
search it, comparing against IVF and brute force — then sweep every
registered codec over the same lists (the paper's Table 3 axis).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import codecs, hybrid_index as hi, metrics
from repro.core.codecs import flat
from repro.data import synthetic


def main():
    print("generating corpus (12k docs)...")
    corpus = synthetic.generate(seed=0, n_docs=12_000, n_queries=500,
                                hidden=64, vocab_size=8192)
    de, dt = jnp.asarray(corpus.doc_emb), jnp.asarray(corpus.doc_tokens)
    qe, qt = jnp.asarray(corpus.query_emb), jnp.asarray(corpus.query_tokens)

    print("building HI²_unsup (KMeans clusters + BM25 terms + OPQ codec)...")
    index = hi.build(jax.random.key(0), de, dt, corpus.vocab_size,
                     n_clusters=192, k1_terms=12, codec="opq",
                     pq_m=8, pq_k=256, cluster_capacity=256,
                     term_capacity=128, kmeans_iters=10)

    print("searching...")
    _, fids = flat.search(qe, de, k=100)
    r_hi2 = hi.search(index, qe, qt, kc=6, k2=8, top_r=100)
    r_ivf = hi.search_ivf(index, qe, qt, kc=10, top_r=100)

    print(f"\n{'method':<22}{'R@100':>8}{'MRR@10':>9}{'candidates':>12}")
    print(f"{'Flat (brute force)':<22}"
          f"{metrics.recall_at_k(fids, corpus.qrels, 100):>8.3f}"
          f"{'':>9}{corpus.doc_emb.shape[0]:>12}")
    for name, r in (("IVF-OPQ", r_ivf), ("HI2_unsup", r_hi2)):
        print(f"{name:<22}"
              f"{metrics.recall_at_k(r.doc_ids, corpus.qrels, 100):>8.3f}"
              f"{metrics.mrr_at_k(r.doc_ids, corpus.qrels, 10):>9.3f}"
              f"{float(r.n_candidates.mean()):>12.0f}")
    print("\nHI² reaches higher recall than IVF while evaluating fewer "
          "candidates — the paper's headline claim.")

    # the same candidate geometry under every registered codec (the
    # trained cluster selector/assignment are reused, skipping KMeans —
    # the dominant build cost; BM25 term fitting reruns per build)
    print(f"\ncodec sweep ({', '.join(codecs.registered())}):")
    print(f"{'codec':<10}{'R@100':>8}{'bytes/doc':>11}{'cost':>7}")
    for spec in codecs.registered():
        cidx = hi.build(jax.random.key(0), de, dt, corpus.vocab_size,
                        n_clusters=192, k1_terms=12, codec=spec,
                        pq_m=8, pq_k=256, cluster_capacity=256,
                        term_capacity=128,
                        cluster_sel=index.cluster_sel,
                        doc_assign=index.doc_assign)
        r = hi.search(cidx, qe, qt, kc=6, k2=8, top_r=100)
        print(f"{spec:<10}"
              f"{metrics.recall_at_k(r.doc_ids, corpus.qrels, 100):>8.3f}"
              f"{codecs.get(spec).bytes_per_doc(cidx.doc_planes):>11}"
              f"{hi.candidate_cost(cidx, 6, 8, 100):>7}")


if __name__ == "__main__":
    main()
