"""Assigned-architecture integration: accelerate SASRec item retrieval
with HI² (the ``retrieval_cand`` scenario — DESIGN.md §4).

The item-embedding table is the corpus; item "tokens" are synthetic
attribute ids (category/brand-style salient terms); user embeddings are
the queries. HI² retrieves top items without scoring all candidates.

    PYTHONPATH=src python examples/recsys_retrieval.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hybrid_index as hi, metrics
from repro.core.codecs import flat
from repro.data import recsys as rdata
from repro.models import recsys


def main():
    n_items, d = 20_000, 32
    cfg = recsys.SASRecConfig(n_items=n_items, embed_dim=d, seq_len=20)
    params = recsys.sasrec_init(jax.random.key(0), cfg)

    # item corpus = embedding table; attribute tokens = category ids the
    # item shares with co-consumed items (the lexical side for HI²)
    rng = np.random.default_rng(0)
    table = np.asarray(params["item_embed"]["table"])
    vocab = 2048
    cats = (np.arange(n_items) // 37) % (vocab // 2)       # category term
    brand = vocab // 2 + (np.arange(n_items) // 411) % (vocab // 2)
    item_tokens = np.stack([cats, brand,
                            rng.integers(0, vocab, n_items)], 1).astype(np.int32)

    index = hi.build(jax.random.key(1), jnp.asarray(table),
                     jnp.asarray(item_tokens), vocab,
                     n_clusters=128, k1_terms=3, codec="flat",
                     cluster_capacity=512, term_capacity=128,
                     kmeans_iters=8)

    batch = rdata.sasrec_batch(0, 64, seq_len=20, n_items=n_items)
    users = recsys.sasrec_user_embedding(params, cfg, batch.items)
    # query "tokens": categories of recently consumed items
    recent = np.asarray(batch.items)[:, -3:]
    q_tokens = np.stack([cats[recent[:, 0]], cats[recent[:, 1]],
                         brand[recent[:, 2]]], 1).astype(np.int32)

    # ground truth = exact top-1 item by embedding score
    _, exact = flat.search(users, jnp.asarray(table), k=10)
    res = hi.search(index, users, jnp.asarray(q_tokens), kc=6, k2=3,
                    top_r=10)
    overlap = metrics.recall_at_k(res.doc_ids, np.asarray(exact)[:, 0], 10)
    print(f"HI² top-10 contains the exact top-1 item for "
          f"{overlap*100:.1f}% of users, evaluating "
          f"{float(res.n_candidates.mean()):.0f}/{n_items} candidates")


if __name__ == "__main__":
    main()
