"""End-to-end serving driver: batched retrieval requests against a
persisted HI² index — build once, checkpoint, restore (the crash-safe
path), then serve query batches through the jitted search step.

    PYTHONPATH=src python examples/serve_retrieval.py
"""
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import checkpoint as ckpt
from repro.core import hybrid_index as hi, metrics
from repro.data import synthetic


def main():
    corpus = synthetic.generate(seed=0, n_docs=12_000, n_queries=512,
                                hidden=64, vocab_size=8192)
    index = hi.build(jax.random.key(0), jnp.asarray(corpus.doc_emb),
                     jnp.asarray(corpus.doc_tokens), corpus.vocab_size,
                     n_clusters=192, k1_terms=12, codec="opq", pq_m=8,
                     pq_k=256, cluster_capacity=256, term_capacity=128,
                     kmeans_iters=10)

    # persist + restore the index (the serving fleet's startup path);
    # save_index records the codec spec so a restore against an index
    # built with a different setting fails loudly
    with tempfile.TemporaryDirectory() as d:
        path = ckpt.save_index(d, 0, index)
        index = ckpt.restore_index(path, index)
        print(f"index persisted+restored from {path}")

    # serve batched requests
    batch = 64
    qe = jnp.asarray(corpus.query_emb)
    qt = jnp.asarray(corpus.query_tokens)
    hits, n = 0.0, 0
    t0 = time.perf_counter()
    for i in range(0, qe.shape[0], batch):
        res = hi.search(index, qe[i:i + batch], qt[i:i + batch],
                        kc=6, k2=8, top_r=100)
        hits += metrics.recall_at_k(res.doc_ids,
                                    corpus.qrels[i:i + batch], 100) * batch
        n += batch
    dt = time.perf_counter() - t0
    print(f"served {n} queries in {dt:.2f}s "
          f"({n/dt:.0f} q/s on CPU; R@100={hits/n:.3f})")


if __name__ == "__main__":
    main()
