"""End-to-end training driver: HI²_sup joint optimization (paper §4.3).

Trains the cluster embeddings + term-scorer encoder by KL distillation
from a teacher embedding model for a few hundred steps (with checkpoint/
resume), builds the supervised index, and evaluates against HI²_unsup.

    PYTHONPATH=src python examples/train_hi2_distill.py
"""

import jax
import jax.numpy as jnp

from repro.core import hybrid_index as hi, metrics
from repro.data import synthetic
from repro.launch import train as tr


def main():
    corpus = synthetic.generate(seed=0, n_docs=12_000, n_queries=500,
                                hidden=64, vocab_size=8192)
    qe, qt = jnp.asarray(corpus.query_emb), jnp.asarray(corpus.query_tokens)
    common = dict(k1_terms=12, codec="opq", pq_m=8, pq_k=256,
                  cluster_capacity=256, term_capacity=128)

    print("training HI²_sup by knowledge distillation (Eq. 9-13)...")
    cfg = tr.SupTrainConfig(n_clusters=192, n_steps=300, batch_queries=32,
                            lr=2e-3)
    params, enc_cfg, assign, losses = tr.train_hi2_sup(corpus, cfg,
                                                       log_every=50)
    print(f"distillation loss {losses[0]:.4f} -> {losses[-1]:.4f}")

    print("building both indexes...")
    sup = tr.build_sup_index(corpus, params, enc_cfg, assign,
                             prune_gamma=0.996, **common)
    unsup = hi.build(jax.random.key(0), jnp.asarray(corpus.doc_emb),
                     jnp.asarray(corpus.doc_tokens), corpus.vocab_size,
                     n_clusters=192, kmeans_iters=10, **common)

    for name, idx in (("HI2_unsup", unsup), ("HI2_sup", sup)):
        r = hi.search(idx, qe, qt, kc=6, k2=8, top_r=100)
        print(f"{name:<12} R@100="
              f"{metrics.recall_at_k(r.doc_ids, corpus.qrels, 100):.4f} "
              f"MRR@10={metrics.mrr_at_k(r.doc_ids, corpus.qrels, 10):.4f} "
              f"candidates={float(r.n_candidates.mean()):.0f}")


if __name__ == "__main__":
    main()
