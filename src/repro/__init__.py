"""repro — a TPU-native JAX framework reproducing and extending
"Hybrid Inverted Index Is a Robust Accelerator for Dense Retrieval" (HI²).

Layout:
    repro.core         — the paper's contribution (selectors, hybrid
                         index, codecs, distillation)
    repro.kernels      — Pallas TPU kernels for the compute hot spots (+ jnp oracles)
    repro.models       — model zoo: dense/MoE transformer LMs, GatedGCN, recsys archs
    repro.data         — synthetic corpus/graph/recsys data pipelines
    repro.optim        — optimizers, schedules, gradient tooling
    repro.checkpoint   — fault-tolerant checkpointing
    repro.distributed  — sharding rules, collectives, fault handling
    repro.configs      — assigned architecture configs + shape sets
    repro.launch       — mesh construction, multi-pod dry-run, roofline,
                         train/serve drivers, serving runtime
"""

__version__ = "1.0.0"
