from repro.checkpoint.checkpoint import (save, save_index, save_mutable,
                                         restore, restore_index,
                                         restore_mutable, restore_resharded)
from repro.checkpoint.manager import CheckpointManager

__all__ = ["save", "save_index", "save_mutable", "restore", "restore_index",
           "restore_mutable", "restore_resharded", "CheckpointManager"]
