from repro.checkpoint.checkpoint import (save, save_index, restore,
                                         restore_index, restore_resharded)
from repro.checkpoint.manager import CheckpointManager
