from repro.checkpoint.checkpoint import save, restore, restore_resharded
from repro.checkpoint.manager import CheckpointManager
