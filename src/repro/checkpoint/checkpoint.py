"""Fault-tolerant checkpointing: atomic writes, manifest-described pytrees,
elastic resharding on restore.

Layout of one checkpoint:

    <dir>/step_00000042/
        manifest.json       tree structure, leaf paths, shapes, dtypes, step
        arrays.npz          host-gathered leaf arrays (keyed by leaf index)

Atomicity: everything is written into ``<dir>/.tmp_step_X`` and
``os.replace``d into place — a crash mid-write never corrupts the latest
valid checkpoint (restart drill in tests/test_fault_tolerance.py).

Elastic restore: leaves are saved *unsharded* (host-gathered) and
re-placed under the restoring job's mesh/sharding — a 512-chip run can
restore a 256-chip checkpoint and vice versa (``restore_resharded``).
At >100B-parameter scale you would swap the npz body for per-shard
files + the same manifest; the manifest format already records shapes
per leaf to support that (see DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.exec import frontier

PyTree = Any


def _leaf_paths(tree: PyTree) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]


def save(directory: str, step: int, tree: PyTree,
         extra: Optional[dict] = None) -> str:
    """Atomically write one checkpoint; returns its final path."""
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = os.path.join(directory, f".tmp_step_{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    leaves = _leaf_paths(tree)
    arrays = {f"leaf_{i}": np.asarray(leaf) for i, (_, leaf) in
              enumerate(leaves)}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)

    treedef = jax.tree_util.tree_structure(tree)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "leaves": [{"path": p, "index": i,
                    "shape": list(np.shape(l)),
                    "dtype": str(np.asarray(l).dtype)}
                   for i, (p, l) in enumerate(leaves)],
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)

    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def load_manifest(path: str) -> dict:
    with open(os.path.join(path, "manifest.json")) as f:
        return json.load(f)


def restore(path: str, like: PyTree) -> PyTree:
    """Restore into the structure of ``like`` (shapes validated)."""
    manifest = load_manifest(path)
    with np.load(os.path.join(path, "arrays.npz")) as z:
        arrays = [z[f"leaf_{i}"] for i in range(len(manifest["leaves"]))]
    flat, treedef = jax.tree_util.tree_flatten(like)
    if len(flat) != len(arrays):
        raise ValueError(f"leaf count mismatch: checkpoint has "
                         f"{len(arrays)}, target has {len(flat)}")
    for a, l in zip(arrays, flat):
        if tuple(a.shape) != tuple(np.shape(l)):
            raise ValueError(f"shape mismatch {a.shape} vs {np.shape(l)}")
    return jax.tree_util.tree_unflatten(
        treedef, [jnp.asarray(a) for a in arrays])


def save_index(directory: str, step: int, index: Any,
               extra: Optional[dict] = None) -> str:
    """Checkpoint a built HI² index, recording its codec spec in the
    manifest so a restore against the wrong index setting fails loudly
    instead of mis-deserializing planes (DESIGN.md §7).

    The codec spec is *static* pytree metadata — it never changes the
    leaf layout of two indexes built with the same codec — so this is
    the only extra bookkeeping persistence needs.  The optional
    namespace plane (``doc_ns``, filtered search — DESIGN.md §9) is an
    ordinary leaf and round-trips like every other plane; restoring a
    filtered checkpoint into an unfiltered ``like`` (or vice versa)
    fails the leaf-count check loudly.
    """
    extra = dict(extra or {})
    extra["codec"] = index.codec
    extra["filtered"] = getattr(index, "doc_ns", None) is not None
    tuned = getattr(index, "tuned", None)
    if tuned is not None:
        # autotuned widths (DESIGN.md §14) are static metadata like the
        # codec spec: they ride the manifest, not the leaf arrays
        extra["tuned"] = frontier.to_json(tuned)
    return save(directory, step, index, extra=extra)


def restore_index(path: str, like: Any) -> Any:
    """Restore an index checkpoint into the structure of ``like``,
    validating the recorded codec spec when one was saved
    (:func:`save_index`); plain :func:`save` checkpoints restore
    unvalidated."""
    extra = load_manifest(path).get("extra", {})
    saved = extra.get("codec")
    if saved is not None and saved != like.codec:
        raise ValueError(
            f"checkpoint at {path} was built with codec {saved!r} but "
            f"the restore target uses {like.codec!r}; rebuild the "
            f"target index with codec={saved!r}")
    restored = restore(path, like)
    tuned = extra.get("tuned")
    if tuned is not None and dataclasses.is_dataclass(restored) and any(
            f.name == "tuned" for f in dataclasses.fields(restored)):
        # re-attach the tuned-width record (the restore target's meta
        # fields came from ``like``, which typically has none); sharded
        # restore targets without the field keep their own metadata
        restored = dataclasses.replace(restored,
                                       tuned=frontier.from_json(tuned))
    return restored


def save_mutable(directory: str, step: int, mut: Any,
                 extra: Optional[dict] = None) -> str:
    """Checkpoint a :class:`repro.core.segments.MutableHybridIndex`:
    base index + delta segment + tombstones + the retained corpus (the
    compaction source of truth), with the codec spec and the mutation
    counters recorded in the manifest (DESIGN.md §8).

    Works for any object exposing the ``state_tree()`` /
    ``state_extra()`` protocol; for a sharded mutable index pass its
    host-side ``.mut`` — the sharded placement is reconstructed on
    restore, exactly like the elastic resharding path of §5.
    """
    extra = dict(extra or {})
    extra["codec"] = mut.base.codec
    extra["mutable"] = mut.state_extra()
    return save(directory, step, mut.state_tree(), extra=extra)


def restore_mutable(path: str, like: Any) -> Any:
    """Restore a mutable-index checkpoint into a fresh instance shaped
    like ``like`` (same corpus/delta shapes), validating the recorded
    codec spec.  The restored index mutates identically to the saved
    one: list planes, eviction score planes, tombstones and counters
    all round-trip."""
    extra = load_manifest(path).get("extra", {})
    saved = extra.get("codec")
    if saved is not None and saved != like.base.codec:
        raise ValueError(
            f"checkpoint at {path} was built with codec {saved!r} but "
            f"the restore target uses {like.base.codec!r}")
    if "mutable" not in extra:
        raise ValueError(
            f"checkpoint at {path} is not a mutable-index checkpoint "
            "(no 'mutable' manifest entry); use restore_index")
    tree = restore(path, like.state_tree())
    return type(like).from_state(tree, extra,
                                 selectors=getattr(like, "selectors", None))


def restore_resharded(path: str, like: PyTree, shardings: PyTree) -> PyTree:
    """Restore and place each leaf under the given shardings — the elastic
    path used when the device count changed between save and restore."""
    tree = restore(path, like)
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s) if s is not None else x,
        tree, shardings,
        is_leaf=lambda x: x is None or isinstance(x, (jax.Array, np.ndarray)))
