"""Checkpoint rotation + auto-resume — the training loop's crash armor."""
from __future__ import annotations

import os
import re
import shutil
from typing import Any, Optional

from repro.checkpoint import checkpoint as ckpt

_STEP_RE = re.compile(r"^step_(\d{8})$")


class CheckpointManager:
    def __init__(self, directory: str, keep_n: int = 3,
                 save_every: int = 100):
        self.directory = directory
        self.keep_n = keep_n
        self.save_every = save_every
        os.makedirs(directory, exist_ok=True)

    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.directory):
            m = _STEP_RE.match(name)
            if m and os.path.exists(os.path.join(self.directory, name,
                                                 "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def path(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:08d}")

    def should_save(self, step: int) -> bool:
        return step > 0 and step % self.save_every == 0

    def save(self, step: int, tree: Any, extra: Optional[dict] = None) -> str:
        path = ckpt.save(self.directory, step, tree, extra)
        self._rotate()
        return path

    def restore_latest(self, like: Any) -> tuple[Optional[int], Any]:
        """(step, tree) of the newest valid checkpoint, or (None, like)."""
        for step in reversed(self.steps()):
            try:
                return step, ckpt.restore(self.path(step), like)
            except Exception:
                continue   # half-written/corrupt → fall back to older
        return None, like

    def _rotate(self) -> None:
        steps = self.steps()
        for s in steps[:-self.keep_n]:
            shutil.rmtree(self.path(s), ignore_errors=True)
