"""dien — embed_dim=18 seq_len=100 gru_dim=108 mlp=200-80
interaction=augru.  [arXiv:1809.03672; unverified]"""
from __future__ import annotations

from repro.configs import registry, shapes
from repro.models.recsys import DIENConfig


def make_config(shape=None) -> DIENConfig:
    return DIENConfig(n_items=1_000_000, embed_dim=18, seq_len=100,
                      gru_dim=108, mlp_hidden=(200, 80))


def make_reduced() -> DIENConfig:
    return DIENConfig(n_items=1_000, embed_dim=8, seq_len=12, gru_dim=24,
                      mlp_hidden=(32, 16))


ARCH = registry.register(registry.ArchDef(
    arch_id="dien", family="recsys", source="arXiv:1809.03672",
    make_config=make_config, make_reduced=make_reduced,
    shapes=dict(shapes.REC_SHAPES)))
