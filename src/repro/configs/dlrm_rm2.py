"""dlrm-rm2 — n_dense=13 n_sparse=26 embed_dim=64 bot_mlp=13-512-256-64
top_mlp=512-512-256-1 interaction=dot.  [arXiv:1906.00091; paper]"""
from __future__ import annotations

from repro.configs import registry, shapes
from repro.models.recsys import DLRMConfig


def make_config(shape=None) -> DLRMConfig:
    return DLRMConfig(n_dense=13, n_sparse=26, embed_dim=64,
                      n_rows=1_000_000,
                      bot_mlp=(13, 512, 256, 64),
                      top_mlp_hidden=(512, 512, 256, 1))


def make_reduced() -> DLRMConfig:
    return DLRMConfig(n_dense=13, n_sparse=4, embed_dim=16, n_rows=1_000,
                      bot_mlp=(13, 32, 16), top_mlp_hidden=(32, 16, 1))


ARCH = registry.register(registry.ArchDef(
    arch_id="dlrm-rm2", family="recsys", source="arXiv:1906.00091",
    make_config=make_config, make_reduced=make_reduced,
    shapes=dict(shapes.REC_SHAPES)))
