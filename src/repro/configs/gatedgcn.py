"""gatedgcn — n_layers=16 d_hidden=70 aggregator=gated.
[arXiv:2003.00982; paper]

d_feat / readout vary per shape cell (Cora-like 1433, Reddit-like 602,
ogbn-products 100, molecule 16 with graph-level readout).
"""
from __future__ import annotations

from repro.configs import registry, shapes
from repro.models.gnn import GatedGCNConfig


def make_config(shape: shapes.GNNShape | None = None) -> GatedGCNConfig:
    if shape is None:
        shape = shapes.GNN_SHAPES["full_graph_sm"]
    return GatedGCNConfig(
        n_layers=16, d_hidden=70, d_feat=shape.d_feat,
        n_classes=47 if shape.name == "ogb_products" else
        (41 if shape.name == "minibatch_lg" else
         (10 if shape.name == "molecule" else 7)),
        graph_level=(shape.kind == "molecule"))


def make_reduced() -> GatedGCNConfig:
    return GatedGCNConfig(n_layers=3, d_hidden=16, d_feat=24, n_classes=4,
                          remat=False)


ARCH = registry.register(registry.ArchDef(
    arch_id="gatedgcn", family="gnn", source="arXiv:2003.00982",
    make_config=make_config, make_reduced=make_reduced,
    shapes=dict(shapes.GNN_SHAPES)))
