"""hi2-synth — the paper's OWN system at production scale, as a dry-run
cell (extra, beyond the 10 assigned archs): HI²_sup serving over an
MS MARCO-scale corpus.

    corpus   8,841,823 docs × h=768        (paper §5.1)
    clusters L=10,000  (capacity 1024 ≈ paper avg 884 + headroom)
    terms    V=30,522 (BERT vocab), K₁ᵀ=3 ⇒ capacity 1024
    codec    OPQ m=96, k=256
    search   K^C=30, K₂ᵀ=32, R=100 (the HI²_sup operating point)
    queries  batch 256 × 32 tokens
"""
from __future__ import annotations

import dataclasses

from repro.configs import registry


@dataclasses.dataclass(frozen=True)
class HI2ServeShape:
    name: str
    kind: str = "hi2_serve"
    n_docs: int = 8_841_984     # 8,841,823 padded to a multiple of 512
    hidden: int = 768
    n_clusters: int = 10_000
    vocab: int = 30_528         # 30,522 padded to a multiple of 16
    cluster_capacity: int = 1_024
    term_capacity: int = 1_024
    pq_m: int = 96
    pq_k: int = 256
    codec: str = "opq"          # any repro.core.codecs registry spec
    kc: int = 30
    k2: int = 32
    top_r: int = 100
    query_batch: int = 256
    query_len: int = 32


@dataclasses.dataclass(frozen=True)
class HI2ShardedServeShape(HI2ServeShape):
    """Document-sharded serving (DESIGN.md §6): doc planes split over
    the mesh model axis (16-way on the single-pod mesh → ~553k docs ×
    96 uint8 codes ≈ 53 MB per device), queries over the data axis."""
    kind: str = "hi2_serve_sharded"


@dataclasses.dataclass(frozen=True)
class HI2FilteredServeShape(HI2ServeShape):
    """Filtered serving (DESIGN.md §9): the same serving step plus a
    per-doc namespace plane ((n_docs,) i32, doc-sharded like every
    other doc plane) and a per-query namespace bitmap
    ((batch, ⌈N/32⌉) u32, batch-sharded like the queries) — multi-tenant
    isolation at the paper's operating point with zero extra budget."""
    kind: str = "hi2_serve_filtered"
    n_namespaces: int = 64      # tenants; bitmap width = 2 u32 words


@dataclasses.dataclass(frozen=True)
class HI2BucketServeShape(HI2ServeShape):
    """One serving-runtime micro-batch bucket (DESIGN.md §10): the same
    §2 serving step at a small power-of-two query batch.  The runtime
    pre-compiles one program per bucket; this cell lowers the smallest
    interesting rung at MS MARCO scale to keep the bucket ladder's
    compile story visible in the dry-run grid (the ``serve_msmarco``
    cell is the ``max_batch`` rung)."""
    kind: str = "hi2_serve_bucket"
    query_batch: int = 8


@dataclasses.dataclass(frozen=True)
class HI2Config:
    pass


ARCH = registry.register(registry.ArchDef(
    arch_id="hi2-synth", family="hi2", source="this paper (HI², §5.1)",
    make_config=lambda shape=None: HI2Config(),
    make_reduced=lambda: HI2Config(),
    shapes={"serve_msmarco": HI2ServeShape("serve_msmarco"),
            "serve_msmarco_sharded":
                HI2ShardedServeShape("serve_msmarco_sharded"),
            # the refine index setting (DESIGN.md §7): sq8 stage-1 codes
            # (768 B/doc, still 1/4 of flat) + fp16 refine plane, exact
            # re-rank of the merged top-R′ frontier after the shard merge
            "serve_msmarco_refine_sq8":
                HI2ShardedServeShape("serve_msmarco_refine_sq8",
                                     codec="refine:sq8:4"),
            # filtered search (DESIGN.md §9): 64-tenant namespace bitmaps
            # through the exec layer's filter stage
            "serve_msmarco_filtered":
                HI2FilteredServeShape("serve_msmarco_filtered"),
            # the serving runtime's smallest micro-batch bucket (§10)
            "serve_msmarco_bucket8":
                HI2BucketServeShape("serve_msmarco_bucket8")},
    extra=True))
