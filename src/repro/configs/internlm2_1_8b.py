"""internlm2-1.8b — 24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92544.
[arXiv:2403.17297; hf]"""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs import registry, shapes
from repro.models.transformer import TransformerConfig


def make_config(shape=None) -> TransformerConfig:
    return TransformerConfig(
        n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8,
        d_ff=8192, vocab_size=92544,
        rope_theta=1_000_000.0,
        param_dtype=jnp.float32, compute_dtype=jnp.bfloat16)


def make_reduced() -> TransformerConfig:
    return TransformerConfig(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=512,
        param_dtype=jnp.float32, compute_dtype=jnp.float32, remat=False)


ARCH = registry.register(registry.ArchDef(
    arch_id="internlm2-1.8b", family="lm", source="arXiv:2403.17297",
    make_config=make_config, make_reduced=make_reduced,
    shapes=dict(shapes.LM_SHAPES),
    skip_shapes={"long_500k": "pure full attention (no sub-quadratic "
                              "path) — skipped per brief, DESIGN.md §4"}))
