"""llama3-8b — 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256.
[arXiv:2407.21783; unverified]"""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs import registry, shapes
from repro.models.transformer import TransformerConfig


def make_config(shape=None) -> TransformerConfig:
    return TransformerConfig(
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab_size=128256,
        rope_theta=500_000.0,
        param_dtype=jnp.float32, compute_dtype=jnp.bfloat16)


def make_reduced() -> TransformerConfig:
    return TransformerConfig(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=512,
        param_dtype=jnp.float32, compute_dtype=jnp.float32, remat=False)


ARCH = registry.register(registry.ArchDef(
    arch_id="llama3-8b", family="lm", source="arXiv:2407.21783",
    make_config=make_config, make_reduced=make_reduced,
    shapes=dict(shapes.LM_SHAPES),
    skip_shapes={"long_500k": "pure full attention (no sub-quadratic "
                              "path) — skipped per brief, DESIGN.md §4"}))
