"""mind — embed_dim=64 n_interests=4 capsule_iters=3
interaction=multi-interest.  [arXiv:1904.08030; unverified]"""
from __future__ import annotations

from repro.configs import registry, shapes
from repro.models.recsys import MINDConfig


def make_config(shape=None) -> MINDConfig:
    return MINDConfig(n_items=1_000_000, embed_dim=64, n_interests=4,
                      capsule_iters=3, seq_len=50)


def make_reduced() -> MINDConfig:
    return MINDConfig(n_items=1_000, embed_dim=16, n_interests=2,
                      capsule_iters=2, seq_len=12)


ARCH = registry.register(registry.ArchDef(
    arch_id="mind", family="recsys", source="arXiv:1904.08030",
    make_config=make_config, make_reduced=make_reduced,
    shapes=dict(shapes.REC_SHAPES)))
