"""mixtral-8x22b — 56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768,
MoE 8 experts top-2, SWA.  [arXiv:2401.04088; hf]

SWA window = 4096 (the Mistral-lineage window) — this is the one LM arch
whose ``long_500k`` cell runs: sliding-window attention is O(S·W) and the
decode cache rolls at ``window`` capacity (models/attention.py).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs import registry, shapes
from repro.models.transformer import TransformerConfig


def make_config(shape=None) -> TransformerConfig:
    return TransformerConfig(
        n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8,
        d_ff=16384, vocab_size=32768,
        n_experts=8, moe_top_k=2, window=4096,
        rope_theta=1_000_000.0,
        param_dtype=jnp.float32, compute_dtype=jnp.bfloat16)


def make_reduced() -> TransformerConfig:
    return TransformerConfig(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=96,
        vocab_size=512, n_experts=4, moe_top_k=2, window=8,
        param_dtype=jnp.float32, compute_dtype=jnp.float32, remat=False)


ARCH = registry.register(registry.ArchDef(
    arch_id="mixtral-8x22b", family="lm", source="arXiv:2401.04088",
    make_config=make_config, make_reduced=make_reduced,
    shapes=dict(shapes.LM_SHAPES)))
