"""olmoe-1b-7b — 16L d_model=2048 16H (GQA kv=16) d_ff=1024 (expert ffn)
vocab=50304, MoE 64 experts top-8.  [arXiv:2409.02060; hf]"""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs import registry, shapes
from repro.models.transformer import TransformerConfig


def make_config(shape=None) -> TransformerConfig:
    return TransformerConfig(
        n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=1024, vocab_size=50304,
        n_experts=64, moe_top_k=8,
        rope_theta=10000.0,
        param_dtype=jnp.float32, compute_dtype=jnp.bfloat16)


def make_reduced() -> TransformerConfig:
    return TransformerConfig(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=32,
        vocab_size=512, n_experts=8, moe_top_k=2,
        param_dtype=jnp.float32, compute_dtype=jnp.float32, remat=False)


ARCH = registry.register(registry.ArchDef(
    arch_id="olmoe-1b-7b", family="lm", source="arXiv:2409.02060",
    make_config=make_config, make_reduced=make_reduced,
    shapes=dict(shapes.LM_SHAPES),
    skip_shapes={"long_500k": "pure full attention (no sub-quadratic "
                              "path) — skipped per brief, DESIGN.md §4"}))
