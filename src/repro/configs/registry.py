"""Architecture registry: ``--arch <id>`` resolution for every launcher.

Each entry carries the exact published config, a reduced smoke-test
config (same family, small dims), its shape set, and per-shape skips
with reasons (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable



@dataclasses.dataclass(frozen=True)
class ArchDef:
    arch_id: str
    family: str                            # "lm" | "gnn" | "recsys" | "hi2"
    source: str
    make_config: Callable[..., Any]              # (shape=None) -> config
    make_reduced: Callable[[], Any]              # smoke config
    shapes: dict[str, Any]
    skip_shapes: dict[str, str] = dataclasses.field(default_factory=dict)
    extra: bool = False                    # beyond the 10 assigned archs


_REGISTRY: dict[str, ArchDef] = {}


def register(arch: ArchDef) -> ArchDef:
    _REGISTRY[arch.arch_id] = arch
    return arch


def get(arch_id: str) -> ArchDef:
    if arch_id not in _REGISTRY:
        _load_all()
    return _REGISTRY[arch_id]


def all_archs() -> dict[str, ArchDef]:
    _load_all()
    return dict(_REGISTRY)


def _load_all() -> None:
    # import side effects register each arch
    from repro.configs import (dien, dlrm_rm2, gatedgcn,  # noqa: F401
                               hi2_synth, internlm2_1_8b, llama3_8b, mind,
                               mixtral_8x22b, olmoe_1b_7b, sasrec,
                               stablelm_3b)


def cells(include_skipped: bool = False,
          include_extra: bool = False) -> list[tuple[str, str]]:
    """Every (arch_id, shape_name) pair — the dry-run grid.

    The 40 assigned cells by default; ``include_extra`` adds the paper's
    own hi2-synth serving cell.
    """
    out = []
    for aid, arch in sorted(all_archs().items()):
        if arch.extra and not include_extra:
            continue
        for shape_name in arch.shapes:
            if shape_name in arch.skip_shapes and not include_skipped:
                continue
            out.append((aid, shape_name))
    return out
