"""sasrec — embed_dim=50 n_blocks=2 n_heads=1 seq_len=50
interaction=self-attn-seq.  [arXiv:1808.09781; paper]"""
from __future__ import annotations

from repro.configs import registry, shapes
from repro.models.recsys import SASRecConfig


def make_config(shape=None) -> SASRecConfig:
    return SASRecConfig(n_items=1_000_000, embed_dim=50, n_blocks=2,
                        n_heads=1, seq_len=50)


def make_reduced() -> SASRecConfig:
    return SASRecConfig(n_items=1_000, embed_dim=16, n_blocks=2, n_heads=1,
                        seq_len=12)


ARCH = registry.register(registry.ArchDef(
    arch_id="sasrec", family="recsys", source="arXiv:1808.09781",
    make_config=make_config, make_reduced=make_reduced,
    shapes=dict(shapes.REC_SHAPES)))
