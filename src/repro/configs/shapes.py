"""Assigned input-shape sets (one set per architecture family).

Every (arch × shape) pair is a dry-run *cell*: the launch layer lowers
``train_step`` for training shapes and ``serve_step``/retrieval for
inference shapes (decode/long lower serve, never train — per the brief).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class LMShape:
    name: str
    kind: str              # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


LM_SHAPES = {
    "train_4k": LMShape("train_4k", "train", 4_096, 256),
    "prefill_32k": LMShape("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": LMShape("decode_32k", "decode", 32_768, 128),
    "long_500k": LMShape("long_500k", "decode", 524_288, 1),
}


@dataclasses.dataclass(frozen=True)
class GNNShape:
    name: str
    kind: str              # "full" | "minibatch" | "molecule"
    n_nodes: int
    n_edges: int
    d_feat: int
    batch_nodes: int = 0   # minibatch seeds
    fanout: tuple = ()
    batch_graphs: int = 0  # molecule graphs per batch


GNN_SHAPES = {
    "full_graph_sm": GNNShape("full_graph_sm", "full", 2_708, 10_556, 1_433),
    "minibatch_lg": GNNShape("minibatch_lg", "minibatch", 232_965,
                             114_615_892, 602, batch_nodes=1_024,
                             fanout=(15, 10)),
    "ogb_products": GNNShape("ogb_products", "full", 2_449_029,
                             61_859_140, 100),
    "molecule": GNNShape("molecule", "molecule", 30, 64, 16,
                         batch_graphs=128),
}


@dataclasses.dataclass(frozen=True)
class RecShape:
    name: str
    kind: str              # "train" | "serve" | "retrieval"
    batch: int
    n_candidates: int = 0


REC_SHAPES = {
    "train_batch": RecShape("train_batch", "train", 65_536),
    "serve_p99": RecShape("serve_p99", "serve", 512),
    "serve_bulk": RecShape("serve_bulk", "serve", 262_144),
    "retrieval_cand": RecShape("retrieval_cand", "retrieval", 1,
                               n_candidates=1_000_000),
}


def pad_to_multiple(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m
