"""stablelm-3b — 32L d_model=2560 32H (GQA kv=32) d_ff=6912 vocab=50304.
[hf:stabilityai/stablelm-2-1_6b; unverified]"""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs import registry, shapes
from repro.models.transformer import TransformerConfig


def make_config(shape=None) -> TransformerConfig:
    return TransformerConfig(
        n_layers=32, d_model=2560, n_heads=32, n_kv_heads=32,
        d_ff=6912, vocab_size=50304,
        rope_theta=10000.0,
        param_dtype=jnp.float32, compute_dtype=jnp.bfloat16)


def make_reduced() -> TransformerConfig:
    return TransformerConfig(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=512,
        param_dtype=jnp.float32, compute_dtype=jnp.float32, remat=False)


ARCH = registry.register(registry.ArchDef(
    arch_id="stablelm-3b", family="lm",
    source="hf:stabilityai/stablelm-2-1_6b",
    make_config=make_config, make_reduced=make_reduced,
    shapes=dict(shapes.LM_SHAPES),
    skip_shapes={"long_500k": "pure full attention (no sub-quadratic "
                              "path) — skipped per brief, DESIGN.md §4"}))
