"""BM25 term scoring (paper Eq. 7, HI²_unsup branch).

The paper scores every unique term v of a document D with

    s_v = (α+1) · IDF(v) · TF(v,D) / (TF(v,D) + α · (1 − β + β·|D|/avgdl))

with α=0.82, β=0.68 (paper §5.1 / Appendix B — note the paper reuses the
classical k1/b slots under the names α/β).

Documents arrive as fixed-shape padded token-id matrices ``(n, L)`` with
``PAD_ID`` (= -1) padding, so everything below is fixed-shape jnp:
TF via an O(L²) within-doc equality count (L ≤ 256 — 64k lane ops, cheap
on the VPU), document frequency via first-occurrence masking + bincount.

The same scores serve both sides of the hybrid index:

  indexing side — ``fit`` → ``score_positions`` → ``top_terms`` picks
  each document's K₁ salient terms, and
  :func:`repro.core.inverted_lists.build_scored` materializes the
  resulting (doc, term, score) triples as impact-ordered postings plus
  an aligned impact plane;

  query side (DESIGN.md §13) — a query probes its ≤K₂ᵀ terms (dedup'd
  through :func:`first_occurrence_mask` inside
  ``repro.core.term_selector.query_terms``) and
  ``repro.core.exec.sparse_topk`` sums the *stored* impacts of each
  candidate over the probed lists — a document's sparse score is the
  sum of its indexed s_v over query∩doc terms, never a recomputation
  against fresh statistics.  Example::

      stats = bm25.fit(doc_tokens, vocab_size)          # indexing
      pos = bm25.score_positions(doc_tokens, stats)
      terms, scores = bm25.top_terms(doc_tokens, pos, k1)
      # … build_scored(...) stores `scores` as the impact plane; at
      # query time execute(fusion=FusionSpec(...)) reads it back.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array

PAD_ID = -1


class BM25Stats(NamedTuple):
    idf: Array      # (V,) f32
    avgdl: Array    # () f32
    n_docs: Array   # () i32


def _valid(tokens: Array) -> Array:
    return tokens != PAD_ID


def first_occurrence_mask(tokens: Array) -> Array:
    """(n, L) -> (n, L) bool: True at the first position of each unique term."""
    eq = tokens[:, :, None] == tokens[:, None, :]              # (n, L, L)
    before = jnp.tril(jnp.ones(eq.shape[-2:], bool), k=-1)     # j < i
    seen_before = jnp.any(eq & before[None], axis=-1)
    return _valid(tokens) & ~seen_before


def term_frequency(tokens: Array) -> Array:
    """(n, L) -> (n, L) f32: TF of the term at each position within its doc."""
    eq = (tokens[:, :, None] == tokens[:, None, :]) & _valid(tokens)[:, None, :]
    return jnp.sum(eq, axis=-1).astype(jnp.float32) * _valid(tokens)


@functools.partial(jax.jit, static_argnames=("vocab_size",))
def fit(tokens: Array, vocab_size: int) -> BM25Stats:
    """Corpus statistics: IDF per vocab term + average doc length."""
    valid = _valid(tokens)
    doc_len = jnp.sum(valid, axis=-1).astype(jnp.float32)      # (n,)
    first = first_occurrence_mask(tokens)
    flat = jnp.where(first, tokens, vocab_size).reshape(-1)    # sentinel bin
    df = jnp.bincount(flat, length=vocab_size + 1)[:vocab_size].astype(jnp.float32)
    n = tokens.shape[0]
    # BM25+-style IDF, floored at 0 to avoid negative saliency
    idf = jnp.maximum(jnp.log((n - df + 0.5) / (df + 0.5) + 1.0), 0.0)
    return BM25Stats(idf=idf, avgdl=jnp.mean(doc_len), n_docs=jnp.int32(n))


@functools.partial(jax.jit, static_argnames=())
def score_positions(tokens: Array, stats: BM25Stats,
                    alpha: float = 0.82, beta: float = 0.68) -> Array:
    """Eq. 7 BM25 branch, evaluated at every token position.

    Positions holding a repeated term get that term's (identical) score;
    callers mask with :func:`first_occurrence_mask` when unique terms are
    needed. Returns (n, L) f32, 0 at pads.
    """
    tf = term_frequency(tokens)                                # (n, L)
    doc_len = jnp.sum(_valid(tokens), axis=-1, keepdims=True).astype(jnp.float32)
    idf = stats.idf[jnp.clip(tokens, 0, None)]                 # (n, L)
    denom = tf + alpha * (1.0 - beta + beta * doc_len / stats.avgdl)
    s = (alpha + 1.0) * idf * tf / jnp.maximum(denom, 1e-6)
    return s * _valid(tokens)


@functools.partial(jax.jit, static_argnames=("k",))
def top_terms(tokens: Array, scores: Array, k: int) -> tuple[Array, Array]:
    """Top-k unique terms per doc by score.

    Returns (term_ids (n,k) i32 with PAD_ID fill, term_scores (n,k) f32).
    """
    uniq = first_occurrence_mask(tokens)
    masked = jnp.where(uniq, scores, -jnp.inf)
    top_scores, top_idx = jax.lax.top_k(masked, k)
    term_ids = jnp.take_along_axis(tokens, top_idx, axis=-1)
    ok = jnp.isfinite(top_scores)
    return (jnp.where(ok, term_ids, PAD_ID).astype(jnp.int32),
            jnp.where(ok, top_scores, 0.0))


@functools.partial(jax.jit, static_argnames=("vocab_size",))
def average_term_scores(tokens: Array, scores: Array, vocab_size: int
                        ) -> Array:
    """s̄_v (Eq. 8): mean score of term v across documents containing it.

    Used at query time to pick K₂ᵀ terms of long queries with zero model
    cost — the paper's "very little overhead" requirement (§5.1).
    """
    first = first_occurrence_mask(tokens)
    flat_ids = jnp.where(first, tokens, vocab_size).reshape(-1)
    flat_scores = jnp.where(first, scores, 0.0).reshape(-1)
    sums = jax.ops.segment_sum(flat_scores, flat_ids, num_segments=vocab_size + 1)
    counts = jax.ops.segment_sum(jnp.ones_like(flat_scores), flat_ids,
                                 num_segments=vocab_size + 1)
    return (sums / jnp.maximum(counts, 1.0))[:vocab_size]


@functools.partial(jax.jit, static_argnames=("vocab_size",))
def score_vector(tokens: Array, scores: Array, vocab_size: int) -> Array:
    """Dense (n, V) score vectors s_D (Eq. 12) from per-position scores.

    Repeated terms collapse by max (Eq. 7's max over d_i = v).
    """
    n, L = tokens.shape
    doc_idx = jnp.broadcast_to(jnp.arange(n)[:, None], (n, L))
    valid = _valid(tokens)
    seg = jnp.where(valid, doc_idx * vocab_size + jnp.clip(tokens, 0, None),
                    n * vocab_size)
    out = jax.ops.segment_max(
        jnp.where(valid, scores, -jnp.inf).reshape(-1),
        seg.reshape(-1), num_segments=n * vocab_size + 1)[:n * vocab_size]
    out = out.reshape(n, vocab_size)
    return jnp.where(jnp.isfinite(out), out, 0.0)
