"""Cluster selector (paper §4.1).

Associates each of the L clusters with an embedding e_C ∈ R^h:
  · documents are indexed to their argmax cluster (1 list per doc),
  · queries are dispatched to the top-K^C clusters (Eq. 6).

HI²_unsup: the embeddings come from KMeans and stay fixed.
HI²_sup:   the same tensor is a *learnable parameter* optimized by the
           distillation objective (Eq. 9/11) with the doc→cluster
           assignment φ(D) frozen after initialization (§4.3).

Scoring is a single (B, h) × (h, L) matmul + top-k — the Pallas kernel
``repro.kernels.assign_topk.ops.topk_scores`` implements the fused
version (running top-k across centroid tiles, the (B, L) score plane
never reaching HBM); the jnp path here is the oracle and the autodiff
path used in training.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import kmeans

Array = jax.Array


class ClusterSelector(NamedTuple):
    embeddings: Array   # (L, h) f32 — learnable in HI²_sup

    @property
    def n_clusters(self) -> int:
        return self.embeddings.shape[0]


def init_kmeans(key: Array, doc_embeddings: Array, n_clusters: int,
                n_iters: int = 20) -> tuple[ClusterSelector, Array]:
    """KMeans init (both variants). Returns (selector, φ(D) assignments).

    φ(D) is the INNER-PRODUCT argmax over the KMeans centroids (paper
    §4.1: "indexed to the cluster with the highest score" ⟨e_D, e_C⟩) —
    not the L2 assignment KMeans itself used.
    """
    centroids, _ = kmeans.kmeans_fit(key, doc_embeddings,
                                     n_clusters=n_clusters, n_iters=n_iters)
    selector = ClusterSelector(embeddings=centroids)
    return selector, select_for_doc(selector, doc_embeddings)


@jax.jit
def scores(selector: ClusterSelector, x: Array) -> Array:
    """⟨e_x, e_C⟩ for a batch: (B, h) -> (B, L)."""
    return x.astype(jnp.float32) @ selector.embeddings.T


@jax.jit
def select_for_doc(selector: ClusterSelector, doc_embeddings: Array) -> Array:
    """Indexing side: each document goes to exactly one cluster."""
    return jnp.argmax(scores(selector, doc_embeddings), axis=-1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("k", "use_kernel"))
def select_for_query(selector: ClusterSelector, query_embeddings: Array,
                     k: int, *, use_kernel: bool = False
                     ) -> tuple[Array, Array]:
    """Search side (Eq. 6): top-K^C clusters per query.

    ``use_kernel`` routes through the fused running-top-k kernel —
    bit-identical list ids (same ``lax.top_k`` tie-break, asserted by
    tests/test_kernels.py)."""
    if use_kernel:
        from repro.kernels.assign_topk import ops as at_ops
        top_s, top_i = at_ops.topk_scores(
            query_embeddings.astype(jnp.float32), selector.embeddings, k)
        return top_i, top_s
    s = scores(selector, query_embeddings)
    top_s, top_i = jax.lax.top_k(s, k)
    return top_i.astype(jnp.int32), top_s
