"""Codec registry — the string-keyed seam every layer resolves codecs
through (DESIGN.md §7).

``HybridIndex.codec`` stays a plain string (the static pytree field
that keeps checkpoints and jit caches stable); this module turns it
into a :class:`~repro.core.codecs.base.Codec`:

    >>> codecs.get("opq")           # a registered base codec
    >>> codecs.get("refine:pq:4")   # parameterized spec (factory args
    ...                             #   after the first ':')
    >>> codecs.registered()         # ['flat', 'opq', 'pq', 'refine', 'sq8']

``registered()`` is what benchmarks/serve flags enumerate; an unknown
name raises with the known names listed.  Register out-of-tree codecs
with :func:`register` before building an index.
"""
from __future__ import annotations

import functools
from typing import Callable

from repro.core.codecs import base as base
from repro.core.codecs import flat as _flat
from repro.core.codecs import pq as _pq
from repro.core.codecs import refine as _refine
from repro.core.codecs import sq8 as _sq8
from repro.core.codecs.base import (Codec, RefineCtx, gather_rows,
                                    plane_bytes_per_doc, single_device_ctx)

#: the default index setting (the paper's evaluation codec, §5.1)
DEFAULT = "opq"

_FACTORIES: dict[str, Callable[..., Codec]] = {}


def register(name: str, factory: Callable[..., Codec]) -> None:
    """Register a codec factory under ``name``.  The factory receives
    the ``:``-separated option strings of the spec (none for plain
    names) and returns a :class:`Codec`."""
    if name in _FACTORIES:
        raise ValueError(f"codec {name!r} already registered")
    _FACTORIES[name] = factory


def registered() -> list[str]:
    """Sorted registered codec names (each a valid ``get()`` spec)."""
    return sorted(_FACTORIES)


@functools.lru_cache(maxsize=None)
def get(spec: str) -> Codec:
    """Resolve a codec spec string (``name[:opt[:opt...]]``).

    Cached per spec, so repeated lookups inside jitted search return
    the same instance.
    """
    name, *opts = str(spec).split(":")
    if name not in _FACTORIES:
        raise ValueError(
            f"unknown codec {spec!r}; registered codecs: "
            f"{', '.join(registered())}")
    return _FACTORIES[name](*opts)


def _make_refine(base_name: str = _refine.DEFAULT_BASE,
                 mult: str = str(_refine.DEFAULT_MULT)) -> Codec:
    try:
        mult = int(mult)
    except ValueError:
        raise ValueError(
            f"bad refine option {mult!r}: the spec grammar is "
            f"refine[:base[:mult]] with integer mult >= 1") from None
    return _refine.RefineCodec(get(base_name), mult)


register("flat", _flat.FlatCodec)
register("pq", _pq.PQCodec)
register("opq", _pq.OPQCodec)
register("sq8", _sq8.SQ8Codec)
register("refine", _make_refine)
