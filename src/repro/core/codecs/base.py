"""The Codec protocol — the pluggable scoring seam of HI² (DESIGN.md §7).

A codec owns everything document-representation-specific on the search
path, split into two pytrees that the index layers treat opaquely:

    params      replicated per device: codebooks, rotations, per-dim
                quantizer ranges.  May be ``None`` (flat).
    doc_planes  dict of per-document arrays, every leaf (n_docs, ...):
                codes, kept embeddings, refine embeddings.  This is the
                part :func:`repro.core.sharded_index.partition` splits
                over the shard axis.

Search integration (``hybrid_index.search`` / ``sharded_index``):

    scorer = codec.make_scorer(params, doc_planes, queries, use_kernel)
    scores = scorer(candidate_rows, live)    # stage 1, all candidates
    top    = topk_by_score(..., codec.refine_width(top_r))
    top    = codec.refine(..., top_r, ctx)   # stage 2 (identity unless
                                             # the codec re-ranks)

``refine`` runs after top-k selection — and, on the sharded path, after
the cross-shard merge — so a refining codec re-ranks the *same*
(B, R′) frontier on both paths and the sharded result stays
bit-identical to single-device search (DESIGN.md §7).  :class:`RefineCtx`
abstracts the two environments: on one device ``gather`` is a plain row
gather and ``psum`` the identity; under ``shard_map`` ``gather`` maps
global doc ids to local rows, ``owned`` masks docs of other shards, and
``psum`` sums the one owner's contribution across shards.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any


def gather_rows(plane: Array, ids: Array) -> Array:
    """Row-gather a doc plane at candidate ids, tolerating PAD (-1).

    The shared "safe candidate" pattern: clip ids to a valid row, gather,
    and let the caller mask the garbage rows (PAD slots always carry
    ``-inf`` scores downstream).  ``ids`` may be any shape; the result is
    ``ids.shape + plane.shape[1:]``.
    """
    return plane[jnp.clip(ids, 0, None)]


def plane_bytes_per_doc(doc_planes: PyTree) -> int:
    """Per-document bytes of the doc planes (HBM accounting for the
    README matrix and ``BENCH_codec.json``)."""
    total = 0
    for leaf in jax.tree.leaves(doc_planes):
        row = 1
        for d in leaf.shape[1:]:
            row *= d
        total += row * leaf.dtype.itemsize
    return total


class RefineCtx(NamedTuple):
    """Environment hooks for the refine stage (single-device vs shard)."""
    gather: Callable[[Array, Array], Array]   # (plane, (B,R) ids) -> rows
    owned: Callable[[Array], Array]           # (B,R) ids -> bool mask
    psum: Callable[[Array], Array]            # cross-shard sum (or id)


def single_device_ctx() -> RefineCtx:
    return RefineCtx(gather=gather_rows,
                     owned=lambda ids: ids >= 0,
                     psum=lambda x: x)


class Codec:
    """Base codec: train/encode/score plus the sharding + refine hooks.

    Subclasses set ``name`` and implement ``train``/``encode``/
    ``make_scorer``/``decode``/``abstract``; the defaults below give
    non-refining codecs identity refine semantics and generic
    partition/replicate/bytes accounting.
    """

    name: str = "?"

    # --- build-time ------------------------------------------------------
    def train(self, key: Array, embeddings: Array, *,
              pq_m: int = 8, pq_k: int = 256) -> PyTree:
        """Fit codec parameters on the corpus; returns the replicated
        ``params`` pytree (``None`` when the codec is parameter-free)."""
        return None

    def encode(self, params: PyTree, embeddings: Array) -> dict:
        """(n_docs, h) -> the per-document ``doc_planes`` dict."""
        raise NotImplementedError

    def decode(self, params: PyTree, doc_planes: dict) -> Array:
        """Reconstruct (n_docs, h) f32 embeddings — the numerics oracle
        used by the round-trip tests; not on the search path."""
        raise NotImplementedError

    def abstract(self, n_docs: int, hidden: int, *, pq_m: int = 8,
                 pq_k: int = 256) -> tuple[PyTree, dict]:
        """(params, doc_planes) as ShapeDtypeStructs — what
        ``launch/cells.py`` lowers at MS MARCO scale without building."""
        raise NotImplementedError

    # --- search-time -----------------------------------------------------
    def make_scorer(self, params: PyTree, doc_planes: dict, queries: Array,
                    use_kernel: bool = False) -> Callable[..., Array]:
        """Returns ``score(ids, live=None) -> (B, C) f32`` over candidate
        rows, with ``-inf`` on non-live lanes.

        ``ids`` index rows of ``doc_planes`` (already shard-local on the
        sharded path) and may contain PAD (-1): implementations gather
        via :func:`gather_rows` (or clip in-kernel) and never branch on
        validity.  ``live`` is the caller's dedup ∧ ¬tombstone ∧
        namespace mask for this source's slice of the candidate plane;
        the scorer owns the mask-to-``-inf`` so fused kernels can apply
        it in-kernel (DESIGN.md §11).  ``live=None`` means all-live
        (scores returned unmasked — the codec-numerics test path).
        """
        raise NotImplementedError

    def refine_width(self, top_r: int) -> int:
        """Stage-1 selection width R′ ≥ top_r (static).  Non-refining
        codecs keep R′ = R, making :meth:`refine` the identity."""
        return top_r

    def refine(self, params: PyTree, doc_planes: dict, queries: Array,
               scores: Array, ids: Array, top_r: int,
               ctx: RefineCtx) -> tuple[Array, Array]:
        """Re-rank the selected (B, R′) frontier down to (B, top_r).

        Called with the total-order top-R′ (already merged across shards
        on the sharded path).  The default is the identity — valid only
        because ``refine_width`` is ``top_r`` for non-refining codecs.
        """
        return scores, ids

    # --- sharding hooks --------------------------------------------------
    def partition(self, doc_planes: dict,
                  split: Callable[[Array], Array]) -> dict:
        """Apply the document split ((n_docs, ...) -> (S, P, ...)) to
        every doc plane; override to exclude or re-derive planes."""
        return jax.tree.map(split, doc_planes)

    def replicate(self, params: PyTree) -> PyTree:
        """Params placement under sharding — replicated by default."""
        return params

    # --- accounting ------------------------------------------------------
    def bytes_per_doc(self, doc_planes: dict) -> int:
        return plane_bytes_per_doc(doc_planes)

    def candidate_cost(self, budget: int, top_r: int) -> int:
        """The latency proxy for one query: the stage-1 candidate budget
        plus any refine work (each refined doc ≈ one exact candidate)."""
        return budget

    def __repr__(self) -> str:
        return f"{type(self).__name__}({self.name!r})"
