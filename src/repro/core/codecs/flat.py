"""Flat codec — full-precision embeddings, exact inner product
(DESIGN.md §7).  The quality upper bound every other codec is measured
against (paper Table 3); doc-plane cost is 4·h bytes/doc.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.codecs import base

Array = jax.Array


class FlatCodec(base.Codec):
    name = "flat"

    def encode(self, params, embeddings: Array) -> dict:
        return {"emb": jnp.asarray(embeddings, jnp.float32)}

    def decode(self, params, doc_planes: dict) -> Array:
        return doc_planes["emb"]

    def abstract(self, n_docs: int, hidden: int, *, pq_m: int = 8,
                 pq_k: int = 256):
        return None, {"emb": jax.ShapeDtypeStruct((n_docs, hidden),
                                                  jnp.float32)}

    def make_scorer(self, params, doc_planes: dict, queries: Array,
                    use_kernel: bool = False):
        q = queries.astype(jnp.float32)
        emb = doc_planes["emb"]

        def score(ids: Array) -> Array:
            rows = base.gather_rows(emb, ids)            # (B, C, h)
            return jnp.einsum("bh,bch->bc", q, rows)

        return score
