"""Flat codec — full-precision embeddings, exact inner product
(DESIGN.md §7).  The quality upper bound every other codec is measured
against (paper Table 3); doc-plane cost is 4·h bytes/doc.

Also home of :func:`search`, the brute-force top-k over a whole corpus
(folded in from the retired standalone flat-search module in PR 4):
the exact-retrieval oracle benchmarks and
tests measure every index against, blocked so the (B, n_docs) score
plane never materializes for large corpora.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.codecs import base

Array = jax.Array


@functools.partial(jax.jit, static_argnames=("k", "block"))
def search(query_embeddings: Array, doc_embeddings: Array, k: int,
           block: int = 65536) -> tuple[Array, Array]:
    """Exact top-k by inner product. Returns (scores (B,k), ids (B,k))."""
    b = query_embeddings.shape[0]
    n, h = doc_embeddings.shape
    q = query_embeddings.astype(jnp.float32)

    n_blocks = -(-n // block)
    pad = n_blocks * block - n
    docs = jnp.pad(doc_embeddings.astype(jnp.float32), ((0, pad), (0, 0)))
    docs = docs.reshape(n_blocks, block, h)

    def body(carry, xs):
        best_s, best_i = carry
        blk, blk_idx = xs
        s = q @ blk.T                                            # (B, block)
        ids = blk_idx * block + jnp.arange(block)
        valid = ids < n
        s = jnp.where(valid[None], s, -jnp.inf)
        cat_s = jnp.concatenate([best_s, s], axis=-1)
        cat_i = jnp.concatenate(
            [best_i, jnp.broadcast_to(ids, (b, block))], axis=-1)
        top_s, top_pos = jax.lax.top_k(cat_s, k)
        top_i = jnp.take_along_axis(cat_i, top_pos, axis=-1)
        return (top_s, top_i), None

    init = (jnp.full((b, k), -jnp.inf), jnp.full((b, k), -1, jnp.int32))
    (scores, ids), _ = jax.lax.scan(
        body, init, (docs, jnp.arange(n_blocks)))
    return scores, ids.astype(jnp.int32)


class FlatCodec(base.Codec):
    name = "flat"

    def encode(self, params, embeddings: Array) -> dict:
        return {"emb": jnp.asarray(embeddings, jnp.float32)}

    def decode(self, params, doc_planes: dict) -> Array:
        return doc_planes["emb"]

    def abstract(self, n_docs: int, hidden: int, *, pq_m: int = 8,
                 pq_k: int = 256):
        return None, {"emb": jax.ShapeDtypeStruct((n_docs, hidden),
                                                  jnp.float32)}

    def make_scorer(self, params, doc_planes: dict, queries: Array,
                    use_kernel: bool = False):
        # no fused kernel for flat: the fp32 plane's gather IS the score
        # input (h floats/doc, no decode step), so a fused op would save
        # nothing — ``use_kernel`` is accepted and ignored (the
        # documented fallback, DESIGN.md §11)
        q = queries.astype(jnp.float32)
        emb = doc_planes["emb"]

        def score(ids: Array, live: Array = None) -> Array:
            rows = base.gather_rows(emb, ids)            # (B, C, h)
            s = jnp.einsum("bh,bch->bc", q, rows)
            return s if live is None else jnp.where(live, s, -jnp.inf)

        return score
