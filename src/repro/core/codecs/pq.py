"""PQ and OPQ codecs (paper §3.2, Eq. 3–4; DESIGN.md §7).

``PQCodec`` quantizes each embedding to ``m`` sub-codeword ids and
scores candidates by ADC (per-query LUT + gather-sum; the Pallas kernel
``repro.kernels.pq_adc`` on TPU, the jnp oracle otherwise).  ``OPQCodec``
*composes* PQ with a learned orthogonal rotation — its params are an
:class:`repro.core.opq.OPQCodebook` wrapping the same
:class:`repro.core.pq.PQCodebook`, and scoring reduces to plain PQ once
the query is rotated.  Codes are stored uint8 when ``k ≤ 256`` (Faiss's
layout: 4× less HBM and gather traffic than i32 — §Perf, asserted
equivalent by ``tests/test_perf_impls.py``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import opq as opq_mod
from repro.core import pq as pq_mod
from repro.core.codecs import base

Array = jax.Array


def _pack_codes(codes: Array, k: int) -> Array:
    return codes.astype(jnp.uint8) if k <= 256 else codes


def _code_dtype(k: int):
    return jnp.uint8 if k <= 256 else jnp.int32


def _adc_scorer(lut: Array, codes_plane: Array, use_kernel: bool):
    def score(ids: Array) -> Array:
        codes = base.gather_rows(codes_plane, ids)       # (B, C, m)
        if use_kernel:
            from repro.kernels.pq_adc import ops as adc_ops
            return adc_ops.pq_adc(lut, codes)
        return pq_mod.adc_score(lut, codes)

    return score


class PQCodec(base.Codec):
    name = "pq"

    def train(self, key: Array, embeddings: Array, *, pq_m: int = 8,
              pq_k: int = 256) -> pq_mod.PQCodebook:
        return pq_mod.train_pq(key, embeddings.astype(jnp.float32),
                               m=pq_m, k=pq_k)

    def encode(self, params: pq_mod.PQCodebook, embeddings: Array) -> dict:
        return {"codes": _pack_codes(pq_mod.encode(params, embeddings),
                                     params.k)}

    def decode(self, params: pq_mod.PQCodebook, doc_planes: dict) -> Array:
        return pq_mod.decode(params, doc_planes["codes"].astype(jnp.int32))

    def abstract(self, n_docs: int, hidden: int, *, pq_m: int = 8,
                 pq_k: int = 256):
        sds = jax.ShapeDtypeStruct
        params = pq_mod.PQCodebook(
            codewords=sds((pq_m, pq_k, hidden // pq_m), jnp.float32))
        return params, {"codes": sds((n_docs, pq_m), _code_dtype(pq_k))}

    def make_scorer(self, params: pq_mod.PQCodebook, doc_planes: dict,
                    queries: Array, use_kernel: bool = False):
        lut = pq_mod.adc_lut(params, queries)            # (B, m, k)
        return _adc_scorer(lut, doc_planes["codes"], use_kernel)


class OPQCodec(PQCodec):
    name = "opq"

    def train(self, key: Array, embeddings: Array, *, pq_m: int = 8,
              pq_k: int = 256) -> opq_mod.OPQCodebook:
        return opq_mod.train_opq(key, embeddings, m=pq_m, k=pq_k)

    def encode(self, params: opq_mod.OPQCodebook, embeddings: Array) -> dict:
        return {"codes": _pack_codes(opq_mod.encode(params, embeddings),
                                     params.codebook.k)}

    def decode(self, params: opq_mod.OPQCodebook, doc_planes: dict) -> Array:
        # decode in rotated space, rotate back (R orthogonal: R⁻¹ = Rᵀ)
        xr = pq_mod.decode(params.codebook,
                           doc_planes["codes"].astype(jnp.int32))
        return xr @ params.rotation.T

    def abstract(self, n_docs: int, hidden: int, *, pq_m: int = 8,
                 pq_k: int = 256):
        sds = jax.ShapeDtypeStruct
        cb, planes = PQCodec.abstract(self, n_docs, hidden,
                                      pq_m=pq_m, pq_k=pq_k)
        params = opq_mod.OPQCodebook(
            rotation=sds((hidden, hidden), jnp.float32), codebook=cb)
        return params, planes

    def make_scorer(self, params: opq_mod.OPQCodebook, doc_planes: dict,
                    queries: Array, use_kernel: bool = False):
        # <xR, c> = <x, cRᵀ>: rotating the query reduces OPQ to PQ (Eq. 4)
        lut = opq_mod.adc_lut(params, queries)
        return _adc_scorer(lut, doc_planes["codes"], use_kernel)
