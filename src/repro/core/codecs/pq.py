"""PQ and OPQ — the quantization math (paper §3.2, Eq. 3–4) and its
codecs (DESIGN.md §7), in one place.

Product Quantization splits an h-dim embedding into ``m`` fragments,
quantizing each fragment to one of ``k`` codewords.  Storage per
document is ``m`` uint8 codes (k ≤ 256) — 32× smaller than fp32 at the
paper's (m=96, k=256, h=768).  Search uses ADC (asymmetric distance
computation): for a query we build a (m, k) inner-product lookup table
once, then score any candidate with an ``m``-gather + sum (Eq. 4).  On
TPU the LUT build is an MXU matmul and the gather-sum is the Pallas
kernel ``repro.kernels.pq_adc``; :func:`adc_score` is the pure-jnp
oracle path.  OPQ (Ge et al. 2014) composes PQ with a learned
orthogonal rotation R so that ``x @ R`` is easier to product-quantize;
scoring reduces to plain PQ once the query is rotated
(``<xR, c> = <x, cRᵀ>``).

``PQCodec`` / ``OPQCodec`` wrap this math behind the codec protocol:
codes are stored uint8 when ``k ≤ 256`` (Faiss's layout: 4× less HBM
and gather traffic than i32 — §Perf, asserted equivalent by
``tests/test_perf_impls.py``).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import kmeans
from repro.core.codecs import base

Array = jax.Array


# --------------------------------------------------------------------------
# PQ math (folded in from the retired standalone PQ module, PR 4)
# --------------------------------------------------------------------------

class PQCodebook(NamedTuple):
    """codewords: (m, k, dsub) f32 — ``m`` independent sub-codebooks."""
    codewords: Array

    @property
    def m(self) -> int:
        return self.codewords.shape[0]

    @property
    def k(self) -> int:
        return self.codewords.shape[1]

    @property
    def dsub(self) -> int:
        return self.codewords.shape[2]


def split_fragments(x: Array, m: int) -> Array:
    """(n, h) -> (n, m, h/m)."""
    n, h = x.shape
    assert h % m == 0, f"dim {h} not divisible by m={m}"
    return x.reshape(n, m, h // m)


@functools.partial(jax.jit, static_argnames=("m", "k", "n_iters"))
def train_pq(key: Array, x: Array, m: int, k: int = 256,
             n_iters: int = 15) -> PQCodebook:
    """One KMeans per fragment, vmapped over the m independent subspaces."""
    frags = split_fragments(x, m).transpose(1, 0, 2)  # (m, n, dsub)
    keys = jax.random.split(key, m)

    def fit_one(kk, xf):
        c, _ = kmeans.kmeans_fit(kk, xf, n_clusters=k, n_iters=n_iters)
        return c

    codewords = jax.vmap(fit_one)(keys, frags)  # (m, k, dsub)
    return PQCodebook(codewords=codewords)


@jax.jit
def pq_encode(codebook: PQCodebook, x: Array) -> Array:
    """Quantize embeddings to codes. (n, h) -> (n, m) int32 (values < k)."""
    frags = split_fragments(x, codebook.m)  # (n, m, dsub)
    # distance argmin per subspace: argmax(<x, c> - ||c||²/2)
    c = codebook.codewords.astype(jnp.float32)  # (m, k, dsub)
    c_norm = 0.5 * jnp.sum(c * c, axis=-1)  # (m, k)
    scores = jnp.einsum("nmd,mkd->nmk", frags.astype(jnp.float32), c) - c_norm
    return jnp.argmax(scores, axis=-1).astype(jnp.int32)


@jax.jit
def pq_decode(codebook: PQCodebook, codes: Array) -> Array:
    """Reconstruct embeddings from codes. (n, m) -> (n, h)."""
    gathered = jnp.take_along_axis(
        codebook.codewords[None],            # (1, m, k, dsub)
        codes[:, :, None, None],             # (n, m, 1, 1)
        axis=2,
    )[:, :, 0]                               # (n, m, dsub)
    return gathered.reshape(codes.shape[0], -1)


@jax.jit
def adc_lut(codebook: PQCodebook, queries: Array) -> Array:
    """Inner-product lookup tables for a batch of queries.

    (B, h) -> (B, m, k): lut[b, j, i] = <e_Q^j, v_{j,i}>  (Eq. 4 terms).
    """
    qf = split_fragments(queries, codebook.m)  # (B, m, dsub)
    return jnp.einsum("bmd,mkd->bmk", qf.astype(jnp.float32),
                      codebook.codewords.astype(jnp.float32))


@jax.jit
def adc_score(lut: Array, codes: Array) -> Array:
    """Score candidates against per-query LUTs (pure-jnp oracle path).

    lut: (B, m, k); codes: (B, C, m) int -> scores (B, C) f32.

    Implemented as ONE flat 1-D gather: the take_along_axis formulation
    materializes five (B, C, m, 3) s32 index planes (~18 GB/device at
    the MS MARCO serving point — EXPERIMENTS.md §Perf); flat indexing
    needs a single (B, C, m) i32 plane. (The Pallas kernel sidesteps
    both on TPU; this is the XLA fallback path.)
    """
    b, m, k = lut.shape
    c = codes.shape[1]
    # flatten only (m, k): the batch axis stays leading so its sharding
    # survives (a full flatten forces GSPMD to reshard the LUT)
    lut2 = lut.reshape(b, m * k)
    idx = (jnp.arange(m, dtype=jnp.int32)[None, None, :] * k
           + codes.astype(jnp.int32)).reshape(b, c * m)
    gathered = jnp.take_along_axis(lut2, idx, axis=1)
    return gathered.reshape(b, c, m).sum(axis=-1)


@jax.jit
def pq_full_scores(codebook: PQCodebook, queries: Array, codes: Array) -> Array:
    """Exhaustive PQ scoring of a whole corpus: (B, h) × (n, m) -> (B, n)."""
    lut = adc_lut(codebook, queries)                       # (B, m, k)
    onehot_free = jnp.take_along_axis(
        lut[:, None], codes[None, :, :, None], axis=-1)[..., 0]  # (B, n, m)
    return jnp.sum(onehot_free, axis=-1)


def reconstruction_mse(codebook: PQCodebook, x: Array) -> Array:
    codes = pq_encode(codebook, x)
    return jnp.mean(jnp.sum((pq_decode(codebook, codes) - x) ** 2, axis=-1))


# --------------------------------------------------------------------------
# OPQ math (folded in from the retired standalone OPQ module, PR 4)
# --------------------------------------------------------------------------

class OPQCodebook(NamedTuple):
    rotation: Array        # (h, h) orthogonal
    codebook: PQCodebook

    @property
    def m(self) -> int:
        return self.codebook.m


def train_opq(key: Array, x: Array, m: int, k: int = 256,
              n_outer: int = 4, n_kmeans_iters: int = 10) -> OPQCodebook:
    """Standard alternating scheme: PQ-train on rotated data (fix R, fit
    codebooks), then Procrustes-solve for R (fix codebooks: R = U Vᵀ
    from SVD of XᵀX̂, X̂ = decode(encode(XR))).  ``jnp.linalg.svd`` keeps
    everything in JAX; the rotation is h×h (≤ 1024²) so this is cheap
    relative to the KMeans passes."""
    h = x.shape[-1]
    r = jnp.eye(h, dtype=jnp.float32)
    x = x.astype(jnp.float32)
    cb = None
    for it in range(n_outer):
        key, sub = jax.random.split(key)
        xr = x @ r
        cb = train_pq(sub, xr, m=m, k=k, n_iters=n_kmeans_iters)
        # Procrustes: min_R ||X R - X̂||_F  s.t. RᵀR = I
        xhat = pq_decode(cb, pq_encode(cb, xr))
        u, _, vt = jnp.linalg.svd(x.T @ xhat, full_matrices=False)
        r = u @ vt
    # final codebook on the final rotation
    key, sub = jax.random.split(key)
    cb = train_pq(sub, x @ r, m=m, k=k, n_iters=n_kmeans_iters)
    return OPQCodebook(rotation=r, codebook=cb)


@jax.jit
def opq_encode(opq: OPQCodebook, x: Array) -> Array:
    return pq_encode(opq.codebook, x.astype(jnp.float32) @ opq.rotation)


@jax.jit
def opq_adc_lut(opq: OPQCodebook, queries: Array) -> Array:
    """Rotate the query into codebook space, then the LUT is plain PQ.

    <x R, c> = <x, c Rᵀ> — rotating the query preserves Eq. 4 exactly.
    """
    return adc_lut(opq.codebook, queries.astype(jnp.float32) @ opq.rotation)


def opq_reconstruction_mse(opq: OPQCodebook, x: Array) -> Array:
    xr = x.astype(jnp.float32) @ opq.rotation
    return reconstruction_mse(opq.codebook, xr)


# --------------------------------------------------------------------------
# codecs
# --------------------------------------------------------------------------

def _pack_codes(codes: Array, k: int) -> Array:
    return codes.astype(jnp.uint8) if k <= 256 else codes


def _code_dtype(k: int):
    return jnp.uint8 if k <= 256 else jnp.int32


def _adc_scorer(lut: Array, codes_plane: Array, use_kernel: bool):
    def score(ids: Array, live: Array = None) -> Array:
        if use_kernel:
            # fused path: the (N, m) plane is gathered INSIDE the kernel
            # and the live mask applied in-kernel — no (B, C, m) in HBM
            from repro.kernels.pq_adc import ops as adc_ops
            if live is None:
                live = jnp.ones(ids.shape, jnp.int32)
            return adc_ops.pq_adc_fused(
                lut, codes_plane, jnp.clip(ids, 0, None), live)
        codes = base.gather_rows(codes_plane, ids)       # (B, C, m)
        s = adc_score(lut, codes)
        return s if live is None else jnp.where(live, s, -jnp.inf)

    return score


class PQCodec(base.Codec):
    name = "pq"

    def train(self, key: Array, embeddings: Array, *, pq_m: int = 8,
              pq_k: int = 256) -> PQCodebook:
        return train_pq(key, embeddings.astype(jnp.float32),
                        m=pq_m, k=pq_k)

    def encode(self, params: PQCodebook, embeddings: Array) -> dict:
        return {"codes": _pack_codes(pq_encode(params, embeddings),
                                     params.k)}

    def decode(self, params: PQCodebook, doc_planes: dict) -> Array:
        return pq_decode(params, doc_planes["codes"].astype(jnp.int32))

    def abstract(self, n_docs: int, hidden: int, *, pq_m: int = 8,
                 pq_k: int = 256):
        sds = jax.ShapeDtypeStruct
        params = PQCodebook(
            codewords=sds((pq_m, pq_k, hidden // pq_m), jnp.float32))
        return params, {"codes": sds((n_docs, pq_m), _code_dtype(pq_k))}

    def make_scorer(self, params: PQCodebook, doc_planes: dict,
                    queries: Array, use_kernel: bool = False):
        lut = adc_lut(params, queries)                   # (B, m, k)
        return _adc_scorer(lut, doc_planes["codes"], use_kernel)


class OPQCodec(PQCodec):
    name = "opq"

    def train(self, key: Array, embeddings: Array, *, pq_m: int = 8,
              pq_k: int = 256) -> OPQCodebook:
        return train_opq(key, embeddings, m=pq_m, k=pq_k)

    def encode(self, params: OPQCodebook, embeddings: Array) -> dict:
        return {"codes": _pack_codes(opq_encode(params, embeddings),
                                     params.codebook.k)}

    def decode(self, params: OPQCodebook, doc_planes: dict) -> Array:
        # decode in rotated space, rotate back (R orthogonal: R⁻¹ = Rᵀ)
        xr = pq_decode(params.codebook,
                       doc_planes["codes"].astype(jnp.int32))
        return xr @ params.rotation.T

    def abstract(self, n_docs: int, hidden: int, *, pq_m: int = 8,
                 pq_k: int = 256):
        sds = jax.ShapeDtypeStruct
        cb, planes = PQCodec.abstract(self, n_docs, hidden,
                                      pq_m=pq_m, pq_k=pq_k)
        params = OPQCodebook(
            rotation=sds((hidden, hidden), jnp.float32), codebook=cb)
        return params, planes

    def make_scorer(self, params: OPQCodebook, doc_planes: dict,
                    queries: Array, use_kernel: bool = False):
        # <xR, c> = <x, cRᵀ>: rotating the query reduces OPQ to PQ (Eq. 4)
        lut = opq_adc_lut(params, queries)
        return _adc_scorer(lut, doc_planes["codes"], use_kernel)
