"""Two-stage refine codec (DESIGN.md §7) — wrap any base codec with an
exact re-rank of the top-R′ frontier against fp16 embeddings.

Stage 1 scores every candidate with the base codec (cheap, lossy) and
selects the total-order top-R′, R′ = mult·R.  Stage 2 gathers the fp16
embeddings of just those R′ docs, rescores them with an exact f32 inner
product, and takes the final total-order top-R.  The refine budget is
R′ extra exact-scored docs per query — tiny next to the stage-1
candidate budget — and buys back the base codec's quantization loss:
"lossless at PQ cost" up to fp16 rounding of the refine plane (with
R′ ≥ the whole candidate budget the ranking is the flat codec's over
fp16-rounded embeddings — bitwise equal to flat when the embeddings
are fp16-representable, as tests/test_codecs.py constructs; in general
within fp16 epsilon, which the BENCH_codec.json recall contract
bounds at ≤ 0.001 recall@100).

Shard story: refine runs strictly AFTER the cross-shard merge, so both
paths re-rank the identical (B, R′) frontier.  Each shard scores only
the frontier docs it owns (``ctx.owned``), contributes 0 for the rest,
and a psum assembles per-doc scores computed exactly once — summing one
owner's f32 value with zeros is exact, so the sharded result stays
bit-identical to single-device search (the §6 contract, asserted for
every registered codec by tests/test_sharded.py).

Spec grammar: ``refine[:base[:mult]]`` — e.g. ``refine`` (over pq, R′=4R),
``refine:opq``, ``refine:sq8:2``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.codecs import base

Array = jax.Array

DEFAULT_BASE = "pq"
DEFAULT_MULT = 4


class RefineCodec(base.Codec):
    def __init__(self, base_codec: base.Codec, mult: int = DEFAULT_MULT):
        if mult < 1:
            raise ValueError(f"refine mult must be >= 1, got {mult}")
        self.base = base_codec
        self.mult = int(mult)
        self.name = f"refine:{base_codec.name}:{self.mult}"

    # --- build-time: base planes + the fp16 refine plane -----------------
    def train(self, key, embeddings, *, pq_m=8, pq_k=256):
        return self.base.train(key, embeddings, pq_m=pq_m, pq_k=pq_k)

    def encode(self, params, embeddings: Array) -> dict:
        planes = dict(self.base.encode(params, embeddings))
        planes["refine_emb"] = embeddings.astype(jnp.float16)
        return planes

    def decode(self, params, doc_planes: dict) -> Array:
        # stage-2 representation — what the final ranking is computed on
        return doc_planes["refine_emb"].astype(jnp.float32)

    def abstract(self, n_docs, hidden, *, pq_m=8, pq_k=256):
        params, planes = self.base.abstract(n_docs, hidden,
                                            pq_m=pq_m, pq_k=pq_k)
        planes = dict(planes)
        planes["refine_emb"] = jax.ShapeDtypeStruct((n_docs, hidden),
                                                    jnp.float16)
        return params, planes

    # --- search-time -----------------------------------------------------
    def make_scorer(self, params, doc_planes, queries, use_kernel=False):
        # stage 1 is the base codec; the refine plane is never gathered
        # at candidate width
        return self.base.make_scorer(params, doc_planes, queries,
                                     use_kernel)

    def refine_width(self, top_r: int) -> int:
        return self.mult * top_r

    def refine(self, params, doc_planes, queries, scores, ids, top_r,
               ctx: base.RefineCtx):
        from repro.core.exec import stages
        emb = ctx.gather(doc_planes["refine_emb"], ids)   # (B, R', h)
        exact = jnp.einsum("bh,brh->br", queries.astype(jnp.float32),
                           emb.astype(jnp.float32))
        exact = ctx.psum(jnp.where(ctx.owned(ids), exact, 0.0))
        # slots beyond the valid frontier stay -inf and sort last
        exact = jnp.where(jnp.isfinite(scores), exact, -jnp.inf)
        return stages.topk_by_score(exact, ids, top_r)

    # --- accounting ------------------------------------------------------
    def candidate_cost(self, budget: int, top_r: int) -> int:
        # each refined doc ≈ one exact (flat) candidate of gather+dot work
        return budget + self.refine_width(top_r)
