"""8-bit scalar quantization codec (DESIGN.md §7) — Faiss's
``SQ8``/``QT_8bit``: a per-dimension min/max affine map onto one byte,

    code_d = round((x_d − lo_d) / scale_d),   scale_d = (hi_d − lo_d)/255

so a document costs h bytes — 4× less doc-plane HBM and gather traffic
than the flat codec — while scoring stays a (dequantized) exact dot
product:

    <q, x̂> = Σ_d q_d·(code_d·scale_d + lo_d)
           = <q·scale, code> + <q, lo>

i.e. one pre-scaled einsum over the gathered byte rows plus a per-query
bias, no lookup tables.  Reconstruction error is bounded by scale/2 per
dimension (asserted by ``tests/test_codecs.py``), which at typical
embedding ranges sits between PQ and flat on the quality–size trade —
the paper's "robust across index settings" axis (Table 3).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.codecs import base

Array = jax.Array


class SQ8Codec(base.Codec):
    name = "sq8"

    def train(self, key: Array, embeddings: Array, *, pq_m: int = 8,
              pq_k: int = 256) -> dict:
        x = embeddings.astype(jnp.float32)
        lo, hi = x.min(axis=0), x.max(axis=0)
        span = hi - lo
        # constant dims quantize to code 0 and decode to lo exactly
        scale = jnp.where(span > 0, span / 255.0, 1.0)
        return {"lo": lo, "scale": scale}

    def encode(self, params: dict, embeddings: Array) -> dict:
        x = embeddings.astype(jnp.float32)
        q = jnp.round((x - params["lo"]) / params["scale"])
        return {"codes": jnp.clip(q, 0, 255).astype(jnp.uint8)}

    def decode(self, params: dict, doc_planes: dict) -> Array:
        codes = doc_planes["codes"].astype(jnp.float32)
        return codes * params["scale"] + params["lo"]

    def abstract(self, n_docs: int, hidden: int, *, pq_m: int = 8,
                 pq_k: int = 256):
        sds = jax.ShapeDtypeStruct
        params = {"lo": sds((hidden,), jnp.float32),
                  "scale": sds((hidden,), jnp.float32)}
        return params, {"codes": sds((n_docs, hidden), jnp.uint8)}

    def make_scorer(self, params: dict, doc_planes: dict, queries: Array,
                    use_kernel: bool = False):
        q = queries.astype(jnp.float32)
        q_scaled = q * params["scale"]                   # (B, h)
        bias = q @ params["lo"]                          # (B,)
        codes_plane = doc_planes["codes"]

        def score(ids: Array, live: Array = None) -> Array:
            if use_kernel:
                # fused gather+dot; the bias is added AFTER the in-kernel
                # mask (-inf + bias = -inf, so masked lanes stay masked)
                from repro.kernels.sq8_dot import ops as sq8_ops
                lv = (jnp.ones(ids.shape, jnp.int32) if live is None
                      else live)
                return sq8_ops.sq8_dot_fused(
                    q_scaled, codes_plane, jnp.clip(ids, 0, None), lv
                ) + bias[:, None]
            rows = base.gather_rows(codes_plane, ids)    # (B, C, h) u8
            s = (jnp.einsum("bh,bch->bc", q_scaled,
                            rows.astype(jnp.float32))
                 + bias[:, None])
            return s if live is None else jnp.where(live, s, -jnp.inf)

        return score
