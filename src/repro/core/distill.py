"""Joint optimization of HI²_sup (paper §4.3, Eq. 9–13).

Trainable parameters
    · cluster embeddings  e_C                       (cluster selector)
    · term-scorer encoder + 2-layer MLP f(·)        (term selector)

Objective, per query Q with candidate docs D (positive + hard negatives
+ in-batch negatives):

    L = KL(Θ ∥ CS) + KL(Θ ∥ TS) + L_commit
    Θ  = softmax(⟨e_Q, e_D⟩)                         Eq. 10 (teacher)
    CS = softmax(⟨e_Q, e_{C_φ(D)}⟩)                  Eq. 11
    TS = softmax(⟨s_Q, s_D⟩)                         Eq. 12
    L_commit = −Σ_D log softmax(⟨e_D, e_C⟩)[φ(D)]    Eq. 13 (sign: the
      paper writes the log-softmax; we minimize its negative, the usual
      VQ-VAE commitment form it cites)

φ(D) is frozen after KMeans init (§4.3). Teacher embeddings are
off-the-shelf (Eq. 10) — any embedding model; our experiments use the
synthetic corpus's generating encoder.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import term_selector as ts_mod

Array = jax.Array


class DistillParams(NamedTuple):
    cluster_embeddings: Array   # (L, h)
    term_mlp: ts_mod.TermMLP
    encoder: Any                # pytree of the term-scorer encoder


class DistillBatch(NamedTuple):
    """One training step's inputs. B queries × D candidate docs each."""
    query_emb: Array        # (B, h)   teacher/query embeddings (frozen)
    query_tokens: Array     # (B, Lq)  padded token ids
    doc_emb: Array          # (B, D, h) frozen doc embeddings
    doc_tokens: Array       # (B, D, Ld)
    doc_assign: Array       # (B, D) i32 — φ(D), frozen


def kl(p_logits: Array, q_logits: Array) -> Array:
    """KL(softmax(p) ∥ softmax(q)), batched over leading dims."""
    logp = jax.nn.log_softmax(p_logits, axis=-1)
    logq = jax.nn.log_softmax(q_logits, axis=-1)
    return jnp.sum(jnp.exp(logp) * (logp - logq), axis=-1)


@functools.partial(jax.jit, static_argnames=("encoder_apply", "vocab_size"))
def loss_fn(params: DistillParams, batch: DistillBatch,
            encoder_apply: Callable[..., Array], vocab_size: int
            ) -> tuple[Array, dict[str, Array]]:
    """Eq. 9 + Eq. 13. ``encoder_apply(params.encoder, tokens) -> (B,L,h)``."""
    b, d, ld = batch.doc_tokens.shape

    # --- teacher (Eq. 10) -------------------------------------------------
    teacher = jnp.einsum("bh,bdh->bd", batch.query_emb.astype(jnp.float32),
                         batch.doc_emb.astype(jnp.float32))

    # --- cluster-selector student (Eq. 11) --------------------------------
    c_emb = params.cluster_embeddings[batch.doc_assign]        # (B, D, h)
    cs_logits = jnp.einsum("bh,bdh->bd",
                           batch.query_emb.astype(jnp.float32), c_emb)

    # --- term-selector student (Eq. 12) -----------------------------------
    # queries and documents are processed the same way here (paper note)
    q_hidden = encoder_apply(params.encoder, batch.query_tokens)
    q_pos = ts_mod.mlp_token_scores(params.term_mlp, q_hidden,
                                    batch.query_tokens)
    s_q = ts_mod.score_vectors(batch.query_tokens, q_pos, vocab_size)

    flat_docs = batch.doc_tokens.reshape(b * d, ld)
    d_hidden = encoder_apply(params.encoder, flat_docs)
    d_pos = ts_mod.mlp_token_scores(params.term_mlp, d_hidden, flat_docs)
    s_d = ts_mod.score_vectors(flat_docs, d_pos, vocab_size)
    s_d = s_d.reshape(b, d, vocab_size)
    ts_logits = jnp.einsum("bv,bdv->bd", s_q, s_d)

    # --- losses ------------------------------------------------------------
    l_cs = kl(teacher, cs_logits).mean()
    l_ts = kl(teacher, ts_logits).mean()

    # commitment (Eq. 13): keep e_D close to its frozen cluster
    commit_logits = jnp.einsum(
        "bdh,lh->bdl", batch.doc_emb.astype(jnp.float32),
        params.cluster_embeddings)                              # (B, D, L)
    logp = jax.nn.log_softmax(commit_logits, axis=-1)
    l_commit = -jnp.take_along_axis(
        logp, batch.doc_assign[..., None], axis=-1).mean()

    total = l_cs + l_ts + l_commit
    aux = {"loss": total, "kl_cluster": l_cs, "kl_term": l_ts,
           "commit": l_commit}
    return total, aux


def sample_candidates(key: Array, positives: Array, n_docs: int,
                      n_negatives: int) -> Array:
    """positive + uniform negatives → (B, 1+n_negatives) doc ids.

    The paper uses BM25 top-200 hard negatives; the data pipeline
    (repro/data/synthetic.py) supplies BM25-ranked hard negatives and
    falls back to uniform sampling here for unit tests.
    """
    b = positives.shape[0]
    negs = jax.random.randint(key, (b, n_negatives), 0, n_docs)
    return jnp.concatenate([positives[:, None], negs], axis=-1)
