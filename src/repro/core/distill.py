"""Joint optimization of HI²_sup (paper §4.3, Eq. 9–13).

Trainable parameters
    · cluster embeddings  e_C                       (cluster selector)
    · term-scorer encoder + 2-layer MLP f(·)        (term selector)

Objective, per query Q with candidate docs D (positive + hard negatives
+ in-batch negatives):

    L = KL(Θ ∥ CS) + KL(Θ ∥ TS) + L_commit [+ λ·KL(Θ ∥ CS+TS)]
    Θ  = softmax(⟨e_Q, e_D⟩)                         Eq. 10 (teacher)
    CS = softmax(⟨e_Q, e_{C_φ(D)}⟩)                  Eq. 11
    TS = softmax(⟨s_Q, s_D⟩)                         Eq. 12
    L_commit = −Σ_D log softmax(⟨e_D, e_C⟩)[φ(D)]    Eq. 13 (sign: the
      paper writes the log-softmax; we minimize its negative, the usual
      VQ-VAE commitment form it cites)

The optional λ term distills through the *refine stage* (DESIGN.md §15):
``CS + TS`` is the log-domain posterior of a document reaching the
refine frontier through either channel, so matching it to the teacher
trains the two selectors *jointly* on the candidates that the codec's
refine stage will actually re-rank — not just their marginal posteriors.

Θ is always treated as a constant (``stop_gradient``): the teacher is an
off-the-shelf frozen embedding model (Eq. 10), so no gradient may leak
into the loss through it — asserted by tests/test_distill.py via the
``teacher`` override seam of :func:`loss_fn`.

φ(D) is frozen after KMeans init (§4.3). Teacher embeddings are
off-the-shelf (Eq. 10) — any embedding model; our experiments use the
synthetic corpus's generating encoder.

Negative candidates (the ``D`` axis of a batch) come from three mines of
increasing hardness (DESIGN.md §15):

  · uniform (:func:`sample_candidates`) — unit-test fallback;
  · topic-matched (:func:`repro.data.synthetic.hard_negatives`) — the
    synthetic analogue of the paper's BM25 top-200;
  · index-mined (:func:`mine_hard_negatives`) — the top-scoring
    non-relevant docs of an already-built (unsupervised) index: exactly
    the candidates the selectors currently confuse with the positive;

plus per-batch **in-batch negatives** (:func:`add_in_batch_negatives`):
positives of the other queries in the same batch row-sampled into each
row's candidate set.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import term_selector as ts_mod

Array = jax.Array


class DistillParams(NamedTuple):
    cluster_embeddings: Array   # (L, h)
    term_mlp: ts_mod.TermMLP
    encoder: Any                # pytree of the term-scorer encoder


class DistillBatch(NamedTuple):
    """One training step's inputs. B queries × D candidate docs each."""
    query_emb: Array        # (B, h)   teacher/query embeddings (frozen)
    query_tokens: Array     # (B, Lq)  padded token ids
    doc_emb: Array          # (B, D, h) frozen doc embeddings
    doc_tokens: Array       # (B, D, Ld)
    doc_assign: Array       # (B, D) i32 — φ(D), frozen


def kl(p_logits: Array, q_logits: Array) -> Array:
    """KL(softmax(p) ∥ softmax(q)), batched over leading dims."""
    logp = jax.nn.log_softmax(p_logits, axis=-1)
    logq = jax.nn.log_softmax(q_logits, axis=-1)
    return jnp.sum(jnp.exp(logp) * (logp - logq), axis=-1)


def teacher_scores(batch: DistillBatch) -> Array:
    """Θ's logits (Eq. 10): exact inner products of the frozen teacher
    embeddings over the candidate axis, (B, D) f32."""
    return jnp.einsum("bh,bdh->bd", batch.query_emb.astype(jnp.float32),
                      batch.doc_emb.astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("encoder_apply", "vocab_size",
                                             "refine_weight"))
def loss_fn(params: DistillParams, batch: DistillBatch,
            encoder_apply: Callable[..., Array], vocab_size: int,
            refine_weight: float = 0.0,
            teacher: Optional[Array] = None
            ) -> tuple[Array, dict[str, Array]]:
    """Eq. 9 + Eq. 13 (+ the §15 refine-stage KL when ``refine_weight``
    > 0). ``encoder_apply(params.encoder, tokens) -> (B,L,h)``.

    ``teacher`` optionally overrides the Eq. 10 logits — the seam for
    distilling from scores computed outside this function (e.g. codec
    refine scores over a wider frontier).  Either way the teacher is
    wrapped in ``stop_gradient``: it is frozen by definition.
    """
    b, d, ld = batch.doc_tokens.shape

    # --- teacher (Eq. 10) -------------------------------------------------
    if teacher is None:
        teacher = teacher_scores(batch)
    teacher = jax.lax.stop_gradient(teacher)

    # --- cluster-selector student (Eq. 11) --------------------------------
    c_emb = params.cluster_embeddings[batch.doc_assign]        # (B, D, h)
    cs_logits = jnp.einsum("bh,bdh->bd",
                           batch.query_emb.astype(jnp.float32), c_emb)

    # --- term-selector student (Eq. 12) -----------------------------------
    # queries and documents are processed the same way here (paper note)
    q_hidden = encoder_apply(params.encoder, batch.query_tokens)
    q_pos = ts_mod.mlp_token_scores(params.term_mlp, q_hidden,
                                    batch.query_tokens)
    s_q = ts_mod.score_vectors(batch.query_tokens, q_pos, vocab_size)

    flat_docs = batch.doc_tokens.reshape(b * d, ld)
    d_hidden = encoder_apply(params.encoder, flat_docs)
    d_pos = ts_mod.mlp_token_scores(params.term_mlp, d_hidden, flat_docs)
    s_d = ts_mod.score_vectors(flat_docs, d_pos, vocab_size)
    s_d = s_d.reshape(b, d, vocab_size)
    ts_logits = jnp.einsum("bv,bdv->bd", s_q, s_d)

    # --- losses ------------------------------------------------------------
    l_cs = kl(teacher, cs_logits).mean()
    l_ts = kl(teacher, ts_logits).mean()

    # commitment (Eq. 13): keep e_D close to its frozen cluster
    commit_logits = jnp.einsum(
        "bdh,lh->bdl", batch.doc_emb.astype(jnp.float32),
        params.cluster_embeddings)                              # (B, D, L)
    logp = jax.nn.log_softmax(commit_logits, axis=-1)
    l_commit = -jnp.take_along_axis(
        logp, batch.doc_assign[..., None], axis=-1).mean()

    # refine-stage distillation (DESIGN.md §15): the union frontier's
    # routing posterior is the two channels' combined log-evidence
    l_refine = kl(teacher, cs_logits + ts_logits).mean()

    total = l_cs + l_ts + l_commit + refine_weight * l_refine
    aux = {"loss": total, "kl_cluster": l_cs, "kl_term": l_ts,
           "commit": l_commit, "kl_refine": l_refine}
    return total, aux


# --------------------------------------------------------------------------
# negative mining
# --------------------------------------------------------------------------

def sample_candidates(key: Array, positives: Array, n_docs: int,
                      n_negatives: int) -> Array:
    """positive + uniform negatives → (B, 1+n_negatives) doc ids.

    The paper uses BM25 top-200 hard negatives; the data pipeline
    (repro/data/synthetic.py) supplies BM25-ranked hard negatives and
    falls back to uniform sampling here for unit tests.
    """
    b = positives.shape[0]
    negs = jax.random.randint(key, (b, n_negatives), 0, n_docs)
    return jnp.concatenate([positives[:, None], negs], axis=-1)


def mine_hard_negatives(index, query_emb, query_tokens, positives,
                        n_neg: int, *, kc: int = 6, k2: int = 8,
                        seed: int = 0) -> np.ndarray:
    """Top-scoring non-relevant docs per query, mined from a built index
    (the HI²_unsup baseline in practice) — (n_queries, n_neg) i32.

    These are the hardest negatives available without a model: documents
    the current retrieval stack *already ranks above or near the
    positive*, so the KL pushes the selectors apart exactly where they
    are wrong (DESIGN.md §15).  Rows whose search frontier is too
    shallow (pads, or all candidates relevant) are topped up with
    uniform draws so the shape stays fixed.
    """
    from repro.core import hybrid_index as hi

    positives = np.asarray(positives).reshape(-1)
    res = hi.search(index, jnp.asarray(query_emb), jnp.asarray(query_tokens),
                    kc=kc, k2=k2, top_r=n_neg + 8)
    ids = np.asarray(res.doc_ids)
    rng = np.random.default_rng(seed)
    out = np.empty((ids.shape[0], n_neg), np.int32)
    for i in range(ids.shape[0]):
        row = ids[i]
        row = row[(row >= 0) & (row != positives[i])][:n_neg]
        if row.shape[0] < n_neg:
            fill = rng.integers(0, index.n_docs, n_neg - row.shape[0])
            row = np.concatenate([row, fill])
        out[i] = row
    return out


def add_in_batch_negatives(rng: np.random.Generator, candidates: np.ndarray,
                           positives: np.ndarray,
                           n_inbatch: int) -> np.ndarray:
    """Append ``n_inbatch`` in-batch negatives to each row's candidates.

    Row b gets positives of *other* rows in the same batch — free hard
    negatives under the teacher (they score high for their own query,
    so the softmax must learn to separate them).  ``candidates`` is
    (B, D) with column 0 the row's own positive; returns
    (B, D + n_inbatch).
    """
    b = candidates.shape[0]
    if n_inbatch <= 0:
        return candidates
    if b < 2:
        raise ValueError("in-batch negatives need a batch of >= 2 queries")
    positives = np.asarray(positives).reshape(-1)
    # sample other-row indices: draw from [0, b-1) and skip self by shift
    draw = rng.integers(0, b - 1, size=(b, n_inbatch))
    rows = np.arange(b)[:, None]
    other = np.where(draw >= rows, draw + 1, draw)
    return np.concatenate([candidates, positives[other]], axis=1)
