"""repro.core.exec — the composable query-execution layer (DESIGN.md §9).

One staged pipeline (dispatch → gather → dedup → filter → score → topk
→ refine) behind every search variant; see :mod:`repro.core.exec.stages`
for the engine, :mod:`repro.core.exec.filters` for per-query namespace
bitmaps, and :mod:`repro.core.exec.cost` for the shared latency proxy.

Hybrid dense∥sparse search (DESIGN.md §13) rides the same engine: when
a :class:`Source` carries a ``sparse_weights`` impact plane (the BM25
scores aligned with its term-list entries,
:func:`repro.core.inverted_lists.build_scored`) and the caller passes
``execute(fusion=FusionSpec(...))``, a sparse BM25 top-R over the
dispatched term lists runs next to the dense path and the two rankings
combine by reciprocal-rank fusion *after* the shard merge —
:mod:`repro.core.exec.fusion` holds the spec and the pure aggregation
helpers, :func:`~repro.core.exec.stages.sparse_topk` /
:func:`~repro.core.exec.stages.fuse` the stages.  Indexes without the
plane fall back to the dense-only result, bit-identically.
"""
from repro.core.exec import filters
from repro.core.exec import frontier
from repro.core.exec.cost import candidate_budget, candidate_cost
from repro.core.exec.frontier import TunedWidths
from repro.core.exec.fusion import FusionSpec
from repro.core.exec.stages import (Frontier, SearchResult, ShardEnv,
                                    Source, dedup, dispatch, execute,
                                    filter_stage, fuse, gather,
                                    make_refine_ctx, refine_planes, score,
                                    sparse_topk, topk, topk_by_score,
                                    trace_count)

__all__ = [
    "Frontier", "FusionSpec", "SearchResult", "ShardEnv", "Source",
    "TunedWidths", "candidate_budget", "candidate_cost", "dedup",
    "dispatch", "execute", "filter_stage", "filters", "frontier", "fuse",
    "gather", "make_refine_ctx", "refine_planes", "score", "sparse_topk",
    "topk", "topk_by_score", "trace_count",
]
