"""repro.core.exec — the composable query-execution layer (DESIGN.md §9).

One staged pipeline (dispatch → gather → dedup → filter → score → topk
→ refine) behind every search variant; see :mod:`repro.core.exec.stages`
for the engine, :mod:`repro.core.exec.filters` for per-query namespace
bitmaps, and :mod:`repro.core.exec.cost` for the shared latency proxy.
"""
from repro.core.exec import filters
from repro.core.exec.cost import candidate_budget, candidate_cost
from repro.core.exec.stages import (Frontier, SearchResult, ShardEnv,
                                    Source, dedup, dispatch, execute,
                                    filter_stage, gather, make_refine_ctx,
                                    refine_planes, score, topk,
                                    topk_by_score, trace_count)

__all__ = [
    "Frontier", "SearchResult", "ShardEnv", "Source",
    "candidate_budget", "candidate_cost", "dedup", "dispatch", "execute",
    "filter_stage", "filters", "gather", "make_refine_ctx",
    "refine_planes", "score", "topk", "topk_by_score", "trace_count",
]
