"""The one cost model for the DESIGN.md §2 latency proxy.

Search cost on the fixed-shape contract is dominated by gather + codec
scoring over the static per-query candidate slots, so the compiled
program's wall time is monotone in :func:`candidate_budget`; a refining
codec adds R′ exact-scored docs on top (:func:`candidate_cost`).  Every
index variant delegates here — one family per gather source (base,
delta) — so the proxy reported by ``benchmarks/`` cannot drift between
variants (it used to be re-implemented in ``hybrid_index``,
``sharded_index`` AND ``segments``).

``candidate_budget`` upper-bounds the paper's measured QL (queried
length = unique candidates, reported per query as
``SearchResult.n_candidates``); dedup and filtering only mask slots,
they never shrink the compute.
"""
from __future__ import annotations

from typing import Iterable, Tuple

Family = Tuple[int, int]     # (cluster list capacity, term list capacity)


def candidate_budget(kc: int, k2: int, families: Iterable[Family]) -> int:
    """Static per-query candidate slots over every gather source."""
    return sum(kc * c_cap + k2 * t_cap for c_cap, t_cap in families)


def candidate_cost(codec_spec: str, kc: int, k2: int, top_r: int,
                   families: Iterable[Family]) -> int:
    """:func:`candidate_budget` plus the codec's refine work — the full
    per-query latency proxy (DESIGN.md §7)."""
    from repro.core import codecs
    return codecs.get(codec_spec).candidate_cost(
        candidate_budget(kc, k2, families), top_r)
