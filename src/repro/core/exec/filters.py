"""Per-query namespace filters (DESIGN.md §9) — fixed-shape predicates
over candidate ids, without ever materializing a (B, n_docs) plane.

Each document carries one namespace id (tenant, collection, language,
shard-of-business — any partition of the corpus) in a per-doc ``doc_ns``
plane that lives next to the codec planes: (n_docs,) i32, split over
shards and delta segments exactly like every other doc plane.  A query's
predicate is a bitmap over namespace ids:

    ns_filter : (B, W) uint32,  W = ceil(n_namespaces / 32)
    doc d passes query b  ⇔  bit (doc_ns[d]) of ns_filter[b] is set

so the filter stage is one row gather + one word gather + a shift-mask —
O(B·C) with C the candidate budget, independent of corpus size.  The
tombstone mask of the mutation layer (DESIGN.md §8) is the degenerate
per-doc, all-queries case of the same mechanism.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

#: bits per bitmap word
WORD = 32


def n_words(n_namespaces: int) -> int:
    """Bitmap words per query for ``n_namespaces`` namespaces."""
    if n_namespaces < 1:
        raise ValueError(f"n_namespaces must be >= 1, got {n_namespaces}")
    return -(-n_namespaces // WORD)


def make_filter(allowed: Sequence, n_namespaces: int) -> Array:
    """Build the (B, W) uint32 per-query bitmap.

    ``allowed`` is one entry per query: an iterable of namespace ids the
    query may see (an int is shorthand for a single namespace).  Ids
    outside ``[0, n_namespaces)`` raise — a silently-ignored tenant id
    is a correctness bug, not a convenience.
    """
    w = n_words(n_namespaces)
    out = np.zeros((len(allowed), w), np.uint32)
    for b, spec in enumerate(allowed):
        ids = [spec] if np.isscalar(spec) else list(spec)
        for ns in ids:
            ns = int(ns)
            if not 0 <= ns < n_namespaces:
                raise ValueError(
                    f"namespace id {ns} out of range [0, {n_namespaces}) "
                    f"in filter row {b}")
            out[b, ns // WORD] |= np.uint32(1) << np.uint32(ns % WORD)
    return jnp.asarray(out)


def allow_all(batch: int, n_namespaces: int) -> Array:
    """The pass-everything bitmap — search with it is bit-identical to
    searching with no filter (asserted by tests/test_exec.py)."""
    return make_filter([range(n_namespaces)] * batch, n_namespaces)


def allowed_mask(ns_filter: Array, ns_ids: Array) -> Array:
    """(B, W) bitmap × (B, C) namespace ids → (B, C) bool.

    ``ns_ids`` are the gathered per-candidate namespaces; garbage rows
    from PAD candidates are fine — the caller ANDs with the dedup mask.
    Ids outside the bitmap's range ``[0, W·32)`` match NOTHING: the
    word gather must clip to stay fixed-shape, and letting a clipped id
    alias onto a valid bit would leak one tenant's doc into another's
    results — out-of-range docs fail closed instead.
    """
    w = ns_filter.shape[-1]
    ids = ns_ids.astype(jnp.int32)
    word = jnp.clip(ids // WORD, 0, w - 1)
    bit = (ns_ids.astype(jnp.uint32)) % WORD
    words = jnp.take_along_axis(ns_filter, word, axis=-1)
    hit = ((words >> bit) & jnp.uint32(1)).astype(bool)
    return hit & (ids >= 0) & (ids < w * WORD)


def pad_filter(ns_filter: Optional[Array], batch: int) -> Optional[Array]:
    """Zero-pad a bitmap to the serving ``max_batch`` (padded query rows
    match nothing, mirroring the PAD query tokens)."""
    if ns_filter is None:
        return None
    ns_filter = jnp.asarray(ns_filter, jnp.uint32)
    pad = batch - ns_filter.shape[0]
    if pad < 0:
        raise ValueError(
            f"filter batch {ns_filter.shape[0]} exceeds max_batch {batch}")
    if pad:
        ns_filter = jnp.pad(ns_filter, ((0, pad), (0, 0)))
    return ns_filter


def namespace_histogram(doc_ns: Array, n_namespaces: int) -> np.ndarray:
    """Docs per namespace — selectivity accounting for benchmarks."""
    return np.bincount(np.asarray(doc_ns).reshape(-1),
                       minlength=n_namespaces)
