"""Recall/cost frontier tooling for width autotuning (DESIGN.md §14).

The dispatch widths (kc, k2) — and, for a refining codec, the refine
multiplier — trade recall against the §2 latency proxy
(:func:`repro.core.exec.cost.candidate_cost`).  This module owns the
pure machinery that both the offline tuner (:mod:`repro.launch.tune`)
and the fig3 sweep share, so the figure and the tuner can never
disagree on the grid:

  · :data:`WIDTH_GRID` / :data:`IVF_KC_GRID` — the one (kc, k2) sweep
    grid (previously hardcoded three times in
    ``benchmarks/fig3_tradeoff.py``);
  · :func:`sweep` / :func:`pareto_frontier` / :func:`select` — evaluate
    a grid, trace the Pareto frontier, pick the cheapest config meeting
    a recall target;
  · :class:`TunedWidths` — the hashable record the tuner persists into
    ``HybridIndex.tuned`` (a static pytree field, carried through
    ``checkpoint.save_index/restore_index`` and honored as the default
    by ``launch/serve.py``);
  · :func:`margins` / :func:`resolve_rung` — the per-query difficulty
    signal (top-1 vs top-2 cluster-score margin of the dispatch stage,
    computed on the L2-NORMALIZED embedding so it is a pure function of
    the serving runtime's scale-invariant cache-key embedding) and the
    margin→rung routing used by adaptive serving.

Rung convention: ``TunedWidths.rungs`` is ordered narrow → wide; a
query with a LARGE margin (its best cluster clearly wins — an easy
query) takes a low rung, and ``margin_cuts`` (one fewer than the
rungs, descending) are the thresholds: rung = #{cut : margin < cut}.
An empty ladder (one rung, no cuts) is the degenerate non-adaptive
case — adaptive serving over it is exactly tuned-static serving.
"""
from __future__ import annotations

from typing import Callable, Iterable, NamedTuple, Optional, Sequence

import numpy as np

#: the one (kc, k2) sweep grid shared by the tuner and fig3
WIDTH_GRID = ((1, 2), (2, 4), (4, 6), (6, 8), (8, 12), (12, 16))

#: cluster-only sweep for the IVF baselines (k2 pinned to 1)
IVF_KC_GRID = (1, 2, 4, 8, 12, 16)


class SweepPoint(NamedTuple):
    """One evaluated grid config: recall vs the candidate-cost proxy."""
    kc: int
    k2: int
    recall: float
    cost: float
    refine_mult: Optional[int] = None


class TunedWidths(NamedTuple):
    """The persisted outcome of one offline tune (DESIGN.md §14).

    Hashable and immutable on purpose: it rides ``HybridIndex.tuned``
    as static pytree metadata (like the codec spec), so jit caches and
    checkpoints stay stable.  ``rungs`` / ``margin_cuts`` describe the
    adaptive ladder (narrow → wide; the LAST rung is always the tuned
    static config (kc, k2)); a single-rung ladder means adaptivity was
    calibrated away on the held-out sample.
    """
    kc: int
    k2: int
    refine_mult: Optional[int]   # None unless the codec is refine[:...]
    recall_target: float
    recall: float                # measured on the held-out sample
    cost: int                    # candidate_cost at (kc, k2, refine_mult)
    rungs: tuple = ()            # ((kc, k2), ...) narrow → wide
    margin_cuts: tuple = ()      # len(rungs) - 1 thresholds, descending


def to_json(tuned: TunedWidths) -> dict:
    """JSON-serializable form (checkpoint manifest ``extra['tuned']``)."""
    return {
        "kc": tuned.kc, "k2": tuned.k2, "refine_mult": tuned.refine_mult,
        "recall_target": tuned.recall_target, "recall": tuned.recall,
        "cost": tuned.cost, "rungs": [list(r) for r in tuned.rungs],
        "margin_cuts": list(tuned.margin_cuts),
    }


def from_json(d: dict) -> TunedWidths:
    mult = d.get("refine_mult")
    return TunedWidths(
        kc=int(d["kc"]), k2=int(d["k2"]),
        refine_mult=None if mult is None else int(mult),
        recall_target=float(d["recall_target"]), recall=float(d["recall"]),
        cost=int(d["cost"]),
        rungs=tuple((int(kc), int(k2)) for kc, k2 in d.get("rungs", [])),
        margin_cuts=tuple(float(c) for c in d.get("margin_cuts", [])))


# --------------------------------------------------------------------------
# sweep / frontier / selection
# --------------------------------------------------------------------------

def sweep(run_fn: Callable[[int, int], tuple],
          grid: Sequence = WIDTH_GRID,
          refine_mult: Optional[int] = None) -> list:
    """Evaluate ``run_fn(kc, k2) -> (recall, cost)`` over a grid.

    The tuner passes the static :func:`candidate_cost` proxy as the
    cost; fig3 passes the measured mean candidate count — the grid and
    the point schema are what the two must share.
    """
    return [SweepPoint(kc, k2, *map(float, run_fn(kc, k2)),
                       refine_mult=refine_mult)
            for kc, k2 in grid]


def pareto_frontier(points: Iterable[SweepPoint]) -> list:
    """The non-dominated subset, sorted by cost: each kept point has
    strictly higher recall than every cheaper one."""
    front, best = [], -np.inf
    for p in sorted(points, key=lambda p: (p.cost, -p.recall)):
        if p.recall > best:
            front.append(p)
            best = p.recall
    return front


def select(points: Iterable[SweepPoint],
           recall_target: float) -> SweepPoint:
    """The frontier selection rule (DESIGN.md §14): the CHEAPEST config
    meeting the recall target; if no config meets it, the highest-recall
    config (cheapest among ties) — never silently under-target."""
    pts = list(points)
    if not pts:
        raise ValueError("select() needs at least one sweep point")
    meeting = [p for p in pts if p.recall >= recall_target]
    if meeting:
        return min(meeting, key=lambda p: (p.cost, -p.recall))
    return max(pts, key=lambda p: (p.recall, -p.cost))


# --------------------------------------------------------------------------
# per-query difficulty signal + rung routing
# --------------------------------------------------------------------------

def margins(cluster_embeddings, query_embeddings) -> np.ndarray:
    """Top-1 vs top-2 cluster-score margin per query, (B,) float64.

    Computed host-side on the L2-NORMALIZED query embedding (float64,
    matching the runtime cache key's canonicalization) so the margin —
    and therefore the resolved rung — is invariant under positive
    rescaling of the query, exactly like the cache-key embedding
    component.  A raw-score margin would scale with ‖q‖ and let a
    rescaled query resolve a different rung than its cache
    representative.  Zero vectors get margin 0 (maximally "hard").
    """
    emb = np.asarray(cluster_embeddings, np.float64)
    q = np.atleast_2d(np.asarray(query_embeddings, np.float64))
    norms = np.linalg.norm(q, axis=1, keepdims=True)
    q = np.where(norms > 0.0, q / np.maximum(norms, 1e-30), q)
    s = q @ emb.T
    if s.shape[1] < 2:
        return np.zeros(s.shape[0], np.float64)
    top2 = np.partition(s, s.shape[1] - 2, axis=1)[:, -2:]
    return top2[:, 1] - top2[:, 0]


def resolve_rung(margin, cuts: Sequence[float]) -> np.ndarray:
    """Margin(s) → rung index: rung = #{cut : margin < cut}.

    With ``cuts`` descending, a confident (large-margin) query clears
    every cut and lands on rung 0 (narrowest widths); a hard query
    falls below all of them onto the last (widest, tuned) rung.  An
    empty ``cuts`` maps everything to rung 0.
    """
    m = np.atleast_1d(np.asarray(margin, np.float64))
    if not cuts:
        return np.zeros(m.shape[0], np.int64)
    return (m[:, None] < np.asarray(cuts, np.float64)[None, :]).sum(axis=1)
