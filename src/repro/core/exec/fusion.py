"""Reciprocal-rank fusion of the dense and sparse result lists
(DESIGN.md §13).

The sparse (BM25) query path produces a second ranked list next to the
dense codec ranking; this module holds the *pure* pieces of combining
them — the :class:`FusionSpec` knob and the fixed-shape per-document
aggregation both the sparse scorer and the fusion stage share.  The
stage orchestration (where sparse scoring and fusion sit in the
dispatch→…→refine pipeline) lives in :mod:`repro.core.exec.stages`;
nothing here imports the stages module, so the helpers stay reusable
from kernels and benchmarks without cycles.

RRF (Cormack et al.): a document at 0-based rank r of list ℓ with list
weight w_ℓ contributes

    w_ℓ / (rrf_k + 1 + r)

and a document's fused score is the sum of its contributions over the
lists that ranked it.  ``fusion_weight`` splits the mass between the
two lists: dense gets ``weight``, sparse ``1 − weight`` — so 1.0 is
pure dense (sparse contributions are exactly 0.0, which is what makes
the fused doc ids *bit-identical* to the dense-only search, asserted by
``tests/test_fusion.py``) and 0.0 is pure sparse.  Ties in fused score
break by ascending doc id — the same total order as every other
selection in the engine (:func:`repro.core.exec.topk_by_score`).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.inverted_lists import PAD_DOC

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class FusionSpec:
    """The hybrid-search knob: how to weigh dense vs sparse (DESIGN.md
    §13).

    Frozen + hashable on purpose: the spec is a *static* argument of
    every jitted search variant (a different weight is a different
    compiled constant) and a component of the serving runtime's cache
    key (a fusion change must miss, never replay stale rankings).

    ``weight`` ∈ [0, 1]: 1.0 = pure dense (doc ids bit-identical to
    ``fusion=None``), 0.0 = pure sparse BM25.  ``rrf_k`` is the
    standard RRF rank damping constant (60 everywhere in the
    literature); larger values flatten the rank discount.
    """
    weight: float = 0.5
    rrf_k: int = 60

    def __post_init__(self):
        if not 0.0 <= self.weight <= 1.0:
            raise ValueError(
                f"fusion weight must be in [0, 1], got {self.weight}")
        if self.rrf_k < 0:
            raise ValueError(f"rrf_k must be >= 0, got {self.rrf_k}")


def sum_by_doc(ids: Array, vals: Array) -> tuple[Array, Array, Array]:
    """Per-row, per-unique-id sums — the fixed-shape "group by doc id"
    both the sparse scorer (sum of BM25 impacts over probed term lists)
    and the fusion stage (sum of RRF contributions over lists) need.

    ``ids``/``vals``: (B, C) with ``PAD_DOC`` marking dead slots (their
    vals must already be 0).  Returns ``(sorted_ids, totals, first)``,
    all (B, C): ids stably sorted ascending per row, each slot's total
    over its id's run, and the first-occurrence mask — so
    ``where(first & live, totals, -inf)`` is ready for
    :func:`~repro.core.exec.stages.topk_by_score`.

    Bit-identity across partitionings (DESIGN.md §6 discipline): the
    sort is stable, so slots of one id keep their relative input order,
    and ``segment_sum`` adds each run in that order — a shard holding
    all of one document's postings in the same relative order as the
    single-device plane produces the identical float sum.
    """
    b, c = ids.shape
    order = jnp.argsort(ids, axis=-1, stable=True)
    sid = jnp.take_along_axis(ids, order, axis=-1)
    sval = jnp.take_along_axis(vals, order, axis=-1)
    first = jnp.concatenate(
        [jnp.ones((b, 1), bool), sid[:, 1:] != sid[:, :-1]], axis=-1)
    run = jnp.cumsum(first, axis=-1) - 1             # run index within row
    seg = (jnp.arange(b)[:, None] * c + run).reshape(-1)
    run_sums = jax.ops.segment_sum(sval.reshape(-1), seg,
                                   num_segments=b * c).reshape(b, c)
    totals = jnp.take_along_axis(run_sums, run, axis=-1)
    return sid, totals, first


def rrf_contributions(scores: Array, weight: float, rrf_k: int) -> Array:
    """Per-slot RRF mass of one ranked (B, R) list: ``weight /
    (rrf_k + 1 + rank)`` where the slot holds a real result
    (finite score), exactly 0.0 where it is padding — a padded slot
    must not leak rank mass to ``PAD_DOC``."""
    ranks = jnp.arange(scores.shape[-1], dtype=jnp.float32)
    return jnp.where(jnp.isfinite(scores),
                     weight / (rrf_k + 1.0 + ranks), 0.0)
