"""The staged query-execution engine (DESIGN.md §9).

Every HI² search variant — single-device, mutable (base + delta),
document-sharded, and sharded-mutable — is the SAME fixed-shape pipeline

    dispatch → gather → dedup → filter → score → topk → refine

over a different *configuration* of :class:`Source`s (where candidates
come from and which doc planes score them) and an optional
:class:`ShardEnv` (whether a cross-shard merge collective sits between
selection and refine).  This module owns the one implementation of each
stage; the index modules shrink to building the source list and calling
:func:`execute` inside their jitted/shard_map'd bodies.

Bit-identity across variants falls out of three invariants the stages
enforce (DESIGN.md §6/§9):

  · candidate order is source-major, [cluster | term] within a source,
    so any partitioning of the same lists concatenates to a permutation
    of the same (score, id) multiset;
  · top-R selection always goes through :func:`topk_by_score`'s total
    order (score desc, id asc) — a pure function of that multiset;
  · the filter stage (tombstones + per-query namespace bitmaps) masks
    to ``-inf`` BEFORE selection, so no masked doc can reach the
    refine frontier on any variant.

The engine is called *inside* jit / shard_map: sources may carry traced
offsets (``axis_index * per``) and the structures here are plain Python
containers built during tracing, never pytrees.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Sequence, Union

import jax
import jax.numpy as jnp

from repro.core import cluster_selector as cs_mod
from repro.core import inverted_lists as il
from repro.core import term_selector as ts_mod
from repro.core.codecs import base as codecs_base
from repro.core.exec import filters
from repro.core.exec import fusion as fusion_mod
from repro.core.exec.fusion import FusionSpec
from repro.core.inverted_lists import PAD_DOC, PaddedLists

Array = jax.Array


class SearchResult(NamedTuple):
    doc_ids: Array        # (B, R) i32, PAD_DOC when fewer candidates
    scores: Array         # (B, R) f32
    n_candidates: Array   # (B,) i32 — unique live docs evaluated (∝ QL)
    #: False on every full-index search.  The degraded serving path
    #: (DESIGN.md §12) sets it True host-side when one or more index
    #: shards are ejected, so results cover the surviving document
    #: ranges only — a contract flag, never a traced value.
    partial: Any = False


@dataclasses.dataclass(frozen=True)
class Source:
    """One gather+score source: a (cluster, term) inverted-list family
    over one set of codec doc planes, plus the global→local id mapping.

    ``offset`` is the global doc id stored at local row 0 (0 on the
    single-device base; ``axis_index * per`` under shard_map; shifted by
    ``n_base`` for delta segments) — it may be a traced scalar.
    ``family_lo``/``family_hi`` bound the *global* id range of the whole
    family this source is a slice of (base docs vs delta slots), which
    is what routes refine-stage gathers when several families coexist.
    ``tombstones``/``doc_ns`` are optional per-row planes consumed by
    the filter stage.  ``sparse_weights`` is the BM25 impact plane
    aligned with ``term_lists.entries`` (DESIGN.md §13,
    :func:`repro.core.inverted_lists.build_scored`); when every source
    carries one, ``execute(fusion=...)`` can run the sparse query path.
    """
    cluster_lists: PaddedLists
    term_lists: PaddedLists
    doc_planes: dict
    size: int                                # local rows in each plane
    offset: Union[int, Array] = 0
    family_lo: int = 0
    family_hi: Optional[int] = None          # default: family_lo + size
    tombstones: Optional[Array] = None       # (size,) bool
    doc_ns: Optional[Array] = None           # (size,) i32 namespace ids
    sparse_weights: Optional[Array] = None   # (V, Ct) f32 BM25 impacts

    @property
    def hi_bound(self):
        """Upper bound on global ids this source may own (``family_hi``
        when the family is larger than this source's slice)."""
        return (self.offset + self.size if self.family_hi is None
                else self.family_hi)


@dataclasses.dataclass(frozen=True)
class ShardEnv:
    """Marks execution inside shard_map: sources hold one shard's rows
    and the frontier must merge across ``axis_name`` before refine."""
    axis_name: str


@dataclasses.dataclass
class Frontier:
    """The per-stage state threaded through the pipeline: the candidate
    id plane plus each source's local-row view of its block of it
    (block s is ``local[s]``'s contiguous slice of the cand axis, in
    source order)."""
    cands: Array                   # (B, C) global ids, PAD_DOC invalid
    local: tuple                   # per-source (B, C_s) local rows
    live: Optional[Array] = None   # (B, C) bool after dedup+filter
    scores: Optional[Array] = None  # (B, C) f32, -inf where masked


# --------------------------------------------------------------------------
# selection primitive (shared by topk + every merge)
# --------------------------------------------------------------------------

def topk_by_score(scores: Array, ids: Array, r: int) -> tuple[Array, Array]:
    """Top-r rows under the total order (score desc, doc id asc).

    ``jax.lax.top_k`` breaks score ties by *position* in the candidate
    array, which differs between candidate orderings (single-device
    concat vs per-shard merge).  Sorting on the composite key makes the
    selection a pure function of the (score, id) *set*, so any
    partitioning of the candidates merges back bit-identically
    (DESIGN.md §6).  Invalid slots must carry ``-inf`` scores; they sort
    last and keep their raw ids — callers mask them (``isfinite``).
    Returns ``(scores, ids)`` of shape (B, r), ``-inf``/``PAD_DOC``
    filled when fewer than r slots exist.
    """
    k_eff = min(r, scores.shape[-1])
    neg_s, sorted_ids = jax.lax.sort(
        (-scores, ids), dimension=-1, num_keys=2)
    top_s, top_ids = -neg_s[..., :k_eff], sorted_ids[..., :k_eff]
    if k_eff < r:
        pad = ((0, 0), (0, r - k_eff))
        top_s = jnp.pad(top_s, pad, constant_values=-jnp.inf)
        top_ids = jnp.pad(top_ids, pad, constant_values=PAD_DOC)
    return top_s, top_ids


# --------------------------------------------------------------------------
# stages
# --------------------------------------------------------------------------

def dispatch(cluster_sel: cs_mod.ClusterSelector,
             term_sel: ts_mod.TermSelector,
             query_embeddings: Array, query_tokens: Array,
             kc: int, k2: int, use_kernel: bool = False
             ) -> tuple[Array, Array]:
    """Query → K^C cluster list ids + ≤K₂ᵀ term list ids (Eq. 5 LHS).

    Under ``use_kernel`` the cluster top-k runs through the
    ``kernels/assign_topk`` running-top-k kernel (bit-identical ids to
    the ``lax.top_k`` path — same tie-break, asserted by
    tests/test_kernels.py)."""
    cluster_ids, _ = cs_mod.select_for_query(cluster_sel,
                                             query_embeddings, kc,
                                             use_kernel=use_kernel)
    term_ids = ts_mod.query_terms(term_sel, query_tokens, k2)
    return cluster_ids, term_ids


def gather(sources: Sequence[Source], cluster_ids: Array,
           term_ids: Array) -> Frontier:
    """Fetch every source's dispatched list rows into one candidate
    plane (source-major, [cluster | term] within a source) and record
    each source's local-row view of its block."""
    pieces, local = [], []
    for s in sources:
        c = jnp.concatenate(
            [il.gather_candidates(s.cluster_lists, cluster_ids),
             il.gather_candidates(s.term_lists, term_ids)], axis=-1)
        pieces.append(c)
        local.append(jnp.clip(c - s.offset, 0, s.size - 1))
    cands = pieces[0] if len(pieces) == 1 else jnp.concatenate(pieces, -1)
    return Frontier(cands=cands, local=tuple(local))


def dedup(frontier: Frontier) -> Array:
    """First-occurrence mask over the whole candidate plane.  Sources
    own disjoint global id ranges, so this is global set semantics no
    matter how the corpus is partitioned."""
    return il.dedup_mask(frontier.cands)


def filter_stage(frontier: Frontier, sources: Sequence[Source],
                 keep: Array, ns_filter: Optional[Array]) -> Array:
    """keep ∧ ¬tombstoned ∧ namespace-allowed, per candidate slot.

    Runs between dedup and score (DESIGN.md §9): a filtered doc carries
    ``-inf`` into selection, so it can never consume a top-R′ slot or
    resurface through the refine stage — tombstones (per-doc, from the
    mutation layer) and per-query namespace bitmaps (``ns_filter``,
    built by :mod:`repro.core.exec.filters`) are the same mechanism at
    different granularities.
    """
    live = keep
    if any(s.tombstones is not None for s in sources):
        dead = [
            (s.tombstones[loc] if s.tombstones is not None
             else jnp.zeros(loc.shape, bool))
            for s, loc in zip(sources, frontier.local)]
        dead = dead[0] if len(dead) == 1 else jnp.concatenate(dead, -1)
        live = live & ~dead
    if ns_filter is not None:
        missing = [i for i, s in enumerate(sources) if s.doc_ns is None]
        if missing:
            raise ValueError(
                "search(filter=...) needs namespace planes on every "
                f"source, but source(s) {missing} have none — build the "
                "index with doc_namespaces= (hybrid_index.build) / pass "
                "namespaces= to add_docs")
        ns = [s.doc_ns[loc] for s, loc in zip(sources, frontier.local)]
        ns = ns[0] if len(ns) == 1 else jnp.concatenate(ns, -1)
        live = live & filters.allowed_mask(ns_filter, ns)
    return live


def score(codec_impl: codecs_base.Codec, codec_params: Any,
          sources: Sequence[Source], frontier: Frontier, live: Array,
          query_embeddings: Array, use_kernel: bool) -> Array:
    """Codec-score each source's block against its own doc planes;
    masked slots carry ``-inf`` into selection.

    Each scorer receives its source's static-width slice of ``live``
    and owns the mask-to-``-inf`` (fused kernels apply it in-kernel —
    DESIGN.md §11).  Slicing + per-part masking + concat is elementwise-
    identical to masking the concatenated plane, so this refactor is
    bitwise-neutral for the unfused path."""
    parts, off = [], 0
    for s, loc in zip(sources, frontier.local):
        w = loc.shape[-1]
        parts.append(
            codec_impl.make_scorer(codec_params, s.doc_planes,
                                   query_embeddings, use_kernel)
            (loc, live[..., off:off + w]))
        off += w
    return parts[0] if len(parts) == 1 else jnp.concatenate(parts, -1)


def topk(frontier: Frontier, r_prime: int,
         shard: Optional[ShardEnv]) -> tuple[Array, Array]:
    """Total-order top-R′ selection; under a :class:`ShardEnv` the
    per-shard frontiers all-gather and re-select, which the total order
    makes bit-identical to selecting over the concatenated candidates
    (DESIGN.md §6)."""
    top_s, top_ids = topk_by_score(frontier.scores, frontier.cands, r_prime)
    if shard is not None:
        from repro.distributed import collectives
        all_s, all_ids = collectives.gather_topk(top_s, top_ids,
                                                 shard.axis_name)
        top_s, top_ids = topk_by_score(all_s, all_ids, r_prime)
    return top_s, top_ids


def sparse_topk(sources: Sequence[Source], term_ids: Array, r: int,
                ns_filter: Optional[Array], shard: Optional[ShardEnv]
                ) -> tuple[Array, Array, Array]:
    """The sparse (BM25) query path (DESIGN.md §13): top-r documents by
    summed term impact over the ≤K₂ᵀ *dispatched* term lists.

    Reuses the dense dispatch's ``term_ids`` — sparse and dense probe
    the same lists — and each source's impact plane
    (``Source.sparse_weights``, aligned with ``term_lists.entries``).
    Per source: gather the probed postings + impacts, mask tombstoned /
    namespace-filtered docs to (PAD_DOC, 0) — the same fail-closed
    semantics as the dense filter stage — then sum impacts per unique
    document (:func:`repro.core.exec.fusion.sum_by_doc`) and select
    through the same total order as every other stage.  Zero-total
    documents (only zero-impact postings matched) rank as non-matches.

    Under a :class:`ShardEnv` each shard owns all of a document's
    postings, so per-shard sums equal single-device sums bit-exactly
    and the §6 gather + re-select merge applies unchanged.  Returns
    ``(scores, ids, n_sparse)`` — (B, r) planes (``-inf``/PAD_DOC
    padded) plus the unique matched-doc count per query.
    """
    ids_parts, w_parts = [], []
    for s in sources:
        safe = jnp.clip(term_ids, 0, None)
        rows = s.term_lists.entries[safe]             # (B, K2, Ct)
        w = s.sparse_weights[safe]
        probed = (term_ids >= 0)[:, :, None]
        ids = jnp.where(probed, rows, PAD_DOC).reshape(rows.shape[0], -1)
        w = jnp.where(probed, w, 0.0).reshape(ids.shape)
        live = ids != PAD_DOC
        loc = jnp.clip(ids - s.offset, 0, s.size - 1)
        if s.tombstones is not None:
            live = live & ~s.tombstones[loc]
        if ns_filter is not None:
            live = live & filters.allowed_mask(ns_filter, s.doc_ns[loc])
        ids_parts.append(jnp.where(live, ids, PAD_DOC))
        w_parts.append(jnp.where(live, w, 0.0))
    ids = (ids_parts[0] if len(ids_parts) == 1
           else jnp.concatenate(ids_parts, -1))
    w = w_parts[0] if len(w_parts) == 1 else jnp.concatenate(w_parts, -1)
    sid, totals, first = fusion_mod.sum_by_doc(ids, w)
    rep = first & (sid != PAD_DOC) & (totals > 0.0)
    scores = jnp.where(rep, totals, -jnp.inf)
    n_sparse = rep.sum(axis=-1).astype(jnp.int32)
    top_s, top_ids = topk_by_score(scores, sid, r)
    if shard is not None:
        from repro.distributed import collectives
        n_sparse = jax.lax.psum(n_sparse, shard.axis_name)
        all_s, all_ids = collectives.gather_topk(top_s, top_ids,
                                                 shard.axis_name)
        top_s, top_ids = topk_by_score(all_s, all_ids, r)
    return top_s, top_ids, n_sparse


def fuse(dense_scores: Array, dense_ids: Array, sparse_scores: Array,
         sparse_ids: Array, fusion: FusionSpec, top_r: int
         ) -> tuple[Array, Array]:
    """Reciprocal-rank fusion of the final dense and sparse rankings
    (DESIGN.md §13): contribution ``weight/(rrf_k+1+rank)`` from the
    dense list, ``(1−weight)/(rrf_k+1+rank)`` from the sparse one,
    summed per document, ties broken by ascending doc id via
    :func:`topk_by_score`.

    Runs strictly AFTER the shard merge (both inputs are the already
    replicated (B, R) planes), mirroring the §7 refine argument: ranks
    are positions in the merged total order, so every shard fuses the
    identical lists and the fused result needs no further collective.
    At ``weight=1.0`` sparse contributions are exactly 0.0 and
    sparse-only docs mask out, so fused doc ids are bit-identical to
    the dense-only search; ``weight=0.0`` is symmetric for sparse.
    """
    d = fusion_mod.rrf_contributions(dense_scores, fusion.weight,
                                     fusion.rrf_k)
    sp = fusion_mod.rrf_contributions(sparse_scores, 1.0 - fusion.weight,
                                      fusion.rrf_k)
    ids = jnp.concatenate(
        [jnp.where(jnp.isfinite(dense_scores), dense_ids, PAD_DOC),
         jnp.where(jnp.isfinite(sparse_scores), sparse_ids, PAD_DOC)], -1)
    vals = jnp.concatenate([d, sp], -1)
    sid, totals, first = fusion_mod.sum_by_doc(ids, vals)
    live = first & (sid != PAD_DOC) & (totals > 0.0)
    return topk_by_score(jnp.where(live, totals, -jnp.inf), sid, top_r)


# --------------------------------------------------------------------------
# refine plumbing: route frontier ids back to the owning source
# --------------------------------------------------------------------------

def _route_gather(sources: Sequence[Source], plane_group, ids: Array
                  ) -> Array:
    """Gather rows for global ``ids`` from per-source planes, routing
    each id to the source family that stores it (ids below the second
    family's ``family_lo`` hit the first, and so on).  Out-of-source
    rows are clipped garbage — callers mask via ``owned`` /
    finite-score checks."""
    if len(sources) == 1:
        s = sources[0]
        return plane_group[jnp.clip(ids - s.offset, 0, s.size - 1)]
    rows = None
    for s, plane in zip(sources, plane_group):
        mine = plane[jnp.clip(ids - s.offset, 0, s.size - 1)]
        if rows is None:
            rows = mine
            continue
        is_here = ids >= s.family_lo
        is_here = is_here.reshape(
            is_here.shape + (1,) * (mine.ndim - is_here.ndim))
        rows = jnp.where(is_here, mine, rows)
    return rows


def refine_planes(sources: Sequence[Source]) -> dict:
    """The doc-plane pytree handed to ``codec.refine``: the planes
    themselves for one source, per-key tuples of per-source planes
    otherwise (opaque to the codec — ``ctx.gather`` routes them)."""
    if len(sources) == 1:
        return sources[0].doc_planes
    return {k: tuple(s.doc_planes[k] for s in sources)
            for k in sources[0].doc_planes}


def make_refine_ctx(sources: Sequence[Source],
                    shard: Optional[ShardEnv]) -> codecs_base.RefineCtx:
    """RefineCtx over any source list: gathers route by family range,
    ``owned`` is the union of each source's local id range (so each doc
    is scored by exactly one shard), psum assembles across shards."""
    def gather_fn(plane_group, ids):
        return _route_gather(sources, plane_group, ids)

    def owned(ids):
        mask = None
        for s in sources:
            m = ((ids >= s.offset) & (ids < s.offset + s.size)
                 & (ids < s.hi_bound))
            mask = m if mask is None else (mask | m)
        return mask

    if shard is None:
        psum = lambda x: x                                    # noqa: E731
    else:
        axis = shard.axis_name
        psum = lambda x: jax.lax.psum(x, axis)                # noqa: E731
    return codecs_base.RefineCtx(gather=gather_fn, owned=owned, psum=psum)


# --------------------------------------------------------------------------
# compile accounting
# --------------------------------------------------------------------------

# :func:`execute` runs only while jax traces a search program (every
# variant's jitted/shard_map'd body funnels through it, and one compile
# traces it exactly once — asserted by tests/test_runtime.py), so the
# number of calls IS the number of search programs compiled in this
# process: +1 per new (variant, batch shape, static config) signature,
# +0 on jit-cache hits.  The serving runtime (repro.launch.runtime)
# reads deltas of this counter to enforce its one-compile-per-bucket
# warmup contract (DESIGN.md §10).
_TRACES = 0


def trace_count() -> int:
    """Search programs traced (≈ compiled) so far in this process."""
    return _TRACES


# --------------------------------------------------------------------------
# the engine
# --------------------------------------------------------------------------

def execute(codec_impl: codecs_base.Codec, codec_params: Any,
            cluster_sel: cs_mod.ClusterSelector,
            term_sel: ts_mod.TermSelector,
            sources: Sequence[Source],
            query_embeddings: Array, query_tokens: Array, *,
            kc: int, k2: int, top_r: int, use_kernel: bool = False,
            ns_filter: Optional[Array] = None,
            shard: Optional[ShardEnv] = None,
            fusion: Optional[FusionSpec] = None) -> SearchResult:
    """Run the full stage chain over ``sources`` (Eq. 5 + DESIGN.md §9).

    One body for all four variants: the single-device immutable path is
    one Source and no ShardEnv; mutable adds a delta Source; the sharded
    paths run this same function inside shard_map with per-shard sources
    and ``shard`` set.  ``ns_filter`` is the per-query namespace bitmap
    of :func:`repro.core.exec.filters.make_filter` (None ⇒ unfiltered).

    ``fusion`` (a :class:`~repro.core.exec.fusion.FusionSpec`, static)
    adds the sparse BM25 path + RRF fusion of DESIGN.md §13 after the
    dense refine; it is honored only when every source carries a
    ``sparse_weights`` impact plane — otherwise the search falls back
    to the dense-only result, unchanged to the bit (the documented
    contract for indexes built without ``sparse=True``).  Under fusion,
    ``scores`` are RRF mass (not codec scores) and ``n_candidates``
    additionally counts the unique sparse-matched docs (a doc seen by
    both paths is counted in each).
    """
    global _TRACES
    _TRACES += 1
    cluster_ids, term_ids = dispatch(cluster_sel, term_sel,
                                     query_embeddings, query_tokens, kc, k2,
                                     use_kernel)
    frontier = gather(sources, cluster_ids, term_ids)
    keep = dedup(frontier)
    frontier.live = filter_stage(frontier, sources, keep, ns_filter)
    frontier.scores = score(codec_impl, codec_params, sources, frontier,
                            frontier.live, query_embeddings, use_kernel)
    top_s, top_ids = topk(frontier, codec_impl.refine_width(top_r), shard)
    top_s, top_ids = codec_impl.refine(
        codec_params, refine_planes(sources), query_embeddings,
        top_s, top_ids, top_r, make_refine_ctx(sources, shard))

    fused = (fusion is not None
             and all(s.sparse_weights is not None for s in sources))
    if fused:
        sp_s, sp_ids, n_sparse = sparse_topk(sources, term_ids, top_r,
                                             ns_filter, shard)
        top_s, top_ids = fuse(top_s, top_ids, sp_s, sp_ids, fusion, top_r)

    n_cand = frontier.live.sum(axis=-1).astype(jnp.int32)
    if shard is not None:
        n_cand = jax.lax.psum(n_cand, shard.axis_name)
    if fused:
        n_cand = n_cand + n_sparse
    valid = jnp.isfinite(top_s)
    return SearchResult(
        doc_ids=jnp.where(valid, top_ids, PAD_DOC).astype(jnp.int32),
        scores=jnp.where(valid, top_s, 0.0),
        n_candidates=n_cand)
