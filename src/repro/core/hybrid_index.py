"""HI² — the Hybrid Inverted Index (paper §4, Eq. 5).

Each document is referenced from the inverted lists of exactly **1
embedding cluster** and **K₁ᵀ salient terms**.  A query is dispatched to
**K^C clusters** and **≤ K₂ᵀ terms**; candidates from both list families
are merged, deduplicated, optionally filtered, scored by the codec and
the top-R returned.

The codec — how documents are stored and scored — is pluggable
(:mod:`repro.core.codecs`, DESIGN.md §7): ``HybridIndex.codec`` is a
spec string (static pytree field, so checkpoints and jit caches stay
stable) resolved through the codec registry; the codec's replicated
parameters and per-document planes live in ``codec_params`` /
``doc_planes`` and are treated opaquely here.

Search-time compute is the staged query-execution engine of
:mod:`repro.core.exec` (DESIGN.md §9):

    dispatch → gather → dedup → filter → score → topk → refine

configured with ONE :class:`~repro.core.exec.Source` (this index's two
list families over its codec planes).  The mutable variant
(:mod:`repro.core.segments`) adds a delta Source; the document-sharded
variants (:mod:`repro.core.sharded_index`) run the same engine inside
``shard_map`` — all four produce bit-identical results because selection
always goes through the total order of :func:`topk_by_score`.

``search(..., filter=)`` takes a per-query namespace bitmap
(:mod:`repro.core.exec.filters`) over the optional ``doc_ns`` plane —
first-class filtered search (tenants, collections) with the same fixed
shapes.  The index build runs once on host+device; searching never
reshapes.  The static per-query candidate count
(:func:`candidate_budget`, one cost model in ``repro.core.exec.cost``)
is the latency proxy used throughout ``benchmarks/``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cluster_selector as cs_mod
from repro.core import codecs
from repro.core import exec as qexec
from repro.core import inverted_lists as il
from repro.core import term_selector as ts_mod
from repro.core.inverted_lists import PAD_DOC, PaddedLists

Array = jax.Array

# the search-result contract and total-order selection primitive live in
# the exec layer now; re-exported here because every consumer of an
# index naturally imports them from the index module
SearchResult = qexec.SearchResult
topk_by_score = qexec.topk_by_score


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["cluster_sel", "term_sel", "cluster_lists", "term_lists",
                 "codec_params", "doc_planes", "doc_assign", "doc_ns",
                 "sparse_weights"],
    meta_fields=["codec", "tuned"])
@dataclasses.dataclass(frozen=True)
class HybridIndex:
    cluster_sel: cs_mod.ClusterSelector
    term_sel: ts_mod.TermSelector
    cluster_lists: PaddedLists
    term_lists: PaddedLists
    codec_params: Any               # replicated codec state (may be None)
    doc_planes: dict                # per-doc planes, every leaf (n_docs, ...)
    doc_assign: Array               # φ(D), (n_docs,) i32
    doc_ns: Optional[Array] = None  # (n_docs,) i32 namespace ids (filtered
    #                                 search; None ⇒ index is unfiltered)
    sparse_weights: Optional[Array] = None  # (V, Ct) f32 BM25 impact plane
    #                                 aligned with term_lists.entries
    #                                 (build(sparse=True), DESIGN.md §13)
    codec: str = codecs.DEFAULT     # registry spec (static)
    tuned: Optional[qexec.TunedWidths] = None  # autotuned widths (static
    #                                 metadata like codec; DESIGN.md §14)

    @property
    def n_docs(self) -> int:
        return int(self.doc_assign.shape[0])

    # convenience views of the codec planes (None when absent)
    @property
    def doc_codes(self) -> Optional[Array]:
        return self.doc_planes.get("codes")

    @property
    def doc_embeddings(self) -> Optional[Array]:
        return self.doc_planes.get("emb")


# --------------------------------------------------------------------------
# build
# --------------------------------------------------------------------------

def build(key: Array,
          doc_embeddings: Array,
          doc_tokens: Array,
          vocab_size: int,
          *,
          n_clusters: int,
          k1_terms: int,
          codec: str = codecs.DEFAULT,
          pq_m: int = 8,
          pq_k: int = 256,
          cluster_capacity: Optional[int] = None,
          term_capacity: Optional[int] = None,
          cluster_sel: Optional[cs_mod.ClusterSelector] = None,
          doc_assign: Optional[Array] = None,
          term_pos_scores: Optional[Array] = None,
          term_sel: Optional[ts_mod.TermSelector] = None,
          kmeans_iters: int = 15,
          use_clusters: bool = True,
          use_terms: bool = True,
          doc_namespaces: Optional[Array] = None,
          sparse: bool = False,
          ) -> HybridIndex:
    """Build HI² over a corpus.

    The unsupervised path computes everything here (KMeans + BM25 +
    codec training).  The supervised path passes pre-trained
    ``cluster_sel`` / ``term_pos_scores`` / ``term_sel`` from the
    distillation trainer and reuses the same list construction.
    ``use_clusters`` / ``use_terms`` expose the paper's ablations
    (w.o. Clus / w.o. Term, §5.3).  ``codec`` is any
    :func:`repro.core.codecs.get` spec (unknown names raise with the
    registered list).  ``doc_namespaces`` ((n_docs,) int ids) enables
    per-query filtered search (DESIGN.md §9).  ``sparse=True``
    additionally materializes the BM25 impact plane next to the term
    lists, enabling hybrid search via ``search(fusion=...)``
    (DESIGN.md §13); without it, fusion requests fall back to the
    dense-only result.
    """
    codec_impl = codecs.get(codec)    # fail fast on unknown specs
    if sparse and not use_terms:
        raise ValueError("sparse=True needs the term lists "
                         "(use_terms=True): the sparse path scores over "
                         "the term postings")
    n_docs, _ = doc_embeddings.shape
    if doc_namespaces is not None:    # fail fast BEFORE kmeans/codec train
        doc_namespaces = jnp.asarray(doc_namespaces, jnp.int32)
        if doc_namespaces.shape != (n_docs,):
            raise ValueError(
                f"doc_namespaces must be ({n_docs},), got "
                f"{doc_namespaces.shape}")
        if int(doc_namespaces.min()) < 0:
            raise ValueError("doc_namespaces must be non-negative ids")
    k_cl, k_codec, k_ts = jax.random.split(key, 3)

    # --- cluster side -----------------------------------------------------
    if cluster_sel is None:
        cluster_sel, doc_assign = cs_mod.init_kmeans(
            k_cl, doc_embeddings, n_clusters, n_iters=kmeans_iters)
    elif doc_assign is None:
        doc_assign = cs_mod.select_for_doc(cluster_sel, doc_embeddings)

    if use_clusters:
        assign_scores = np.asarray(
            cs_mod.scores(cluster_sel, doc_embeddings)
        )[np.arange(n_docs), np.asarray(doc_assign)]
        cluster_lists = il.build(np.arange(n_docs), np.asarray(doc_assign),
                                 assign_scores, n_lists=n_clusters,
                                 capacity=cluster_capacity)
    else:
        cluster_lists = il.PaddedLists(
            entries=jnp.full((n_clusters, 1), PAD_DOC, jnp.int32),
            lengths=jnp.zeros((n_clusters,), jnp.int32))

    # --- term side --------------------------------------------------------
    if term_sel is None or term_pos_scores is None:
        term_sel, term_pos_scores, _ = ts_mod.fit_unsup(doc_tokens, vocab_size)

    sparse_weights = None
    if use_terms:
        term_ids, term_scores = ts_mod.doc_terms(doc_tokens, term_pos_scores,
                                                 k1_terms)
        doc_rep = np.repeat(np.arange(n_docs), k1_terms)
        if sparse:
            term_lists, sparse_weights = il.build_scored(
                doc_rep, np.asarray(term_ids).reshape(-1),
                np.asarray(term_scores).reshape(-1),
                n_lists=vocab_size, capacity=term_capacity)
        else:
            term_lists = il.build(doc_rep, np.asarray(term_ids).reshape(-1),
                                  np.asarray(term_scores).reshape(-1),
                                  n_lists=vocab_size, capacity=term_capacity)
    else:
        term_lists = il.PaddedLists(
            entries=jnp.full((vocab_size, 1), PAD_DOC, jnp.int32),
            lengths=jnp.zeros((vocab_size,), jnp.int32))

    # --- codec ------------------------------------------------------------
    codec_params = codec_impl.train(k_codec, doc_embeddings,
                                    pq_m=pq_m, pq_k=pq_k)
    doc_planes = codec_impl.encode(codec_params, doc_embeddings)

    return HybridIndex(cluster_sel=cluster_sel, term_sel=term_sel,
                       cluster_lists=cluster_lists, term_lists=term_lists,
                       codec_params=codec_params, doc_planes=doc_planes,
                       doc_assign=jnp.asarray(doc_assign, jnp.int32),
                       doc_ns=doc_namespaces,
                       sparse_weights=sparse_weights,
                       codec=codec)


# --------------------------------------------------------------------------
# search — one exec.Source over this index
# --------------------------------------------------------------------------

def base_source(index: HybridIndex) -> qexec.Source:
    """The index as a single query-execution gather source."""
    return qexec.Source(cluster_lists=index.cluster_lists,
                        term_lists=index.term_lists,
                        doc_planes=index.doc_planes,
                        size=index.n_docs,
                        doc_ns=index.doc_ns,
                        sparse_weights=index.sparse_weights)


@functools.partial(jax.jit,
                   static_argnames=("kc", "k2", "top_r", "use_kernel",
                                    "fusion"))
def search(index: HybridIndex, query_embeddings: Array, query_tokens: Array,
           *, kc: int, k2: int, top_r: int, use_kernel: bool = False,
           filter: Optional[Array] = None,
           fusion: Optional[qexec.FusionSpec] = None) -> SearchResult:
    """Eq. 5: A(Q) = A^C(Q) ∪ A^T(Q), then codec scoring + top-R —
    executed as the §9 stage chain over one Source.

    ``filter`` is an optional (B, W) uint32 per-query namespace bitmap
    (:func:`repro.core.exec.filters.make_filter`); it needs an index
    built with ``doc_namespaces=``.  ``fusion`` (a static
    :class:`~repro.core.exec.FusionSpec`) enables hybrid dense∥sparse
    search over an index built with ``sparse=True`` (DESIGN.md §13);
    on an index without the impact plane it falls back to the dense
    result, bit-identically.
    """
    return qexec.execute(
        codecs.get(index.codec), index.codec_params,
        index.cluster_sel, index.term_sel, [base_source(index)],
        query_embeddings, query_tokens,
        kc=kc, k2=k2, top_r=top_r, use_kernel=use_kernel,
        ns_filter=filter, fusion=fusion)


def candidate_budget(index: HybridIndex, kc: int, k2: int) -> int:
    """Static per-query candidate slots — the latency proxy used by
    ``benchmarks/`` (DESIGN.md §2; one cost model for every variant in
    :mod:`repro.core.exec.cost`)."""
    return qexec.candidate_budget(
        kc, k2, [(index.cluster_lists.capacity, index.term_lists.capacity)])


def candidate_cost(index: HybridIndex, kc: int, k2: int, top_r: int) -> int:
    """:func:`candidate_budget` plus the codec's refine work — the full
    per-query latency proxy (DESIGN.md §7)."""
    return qexec.candidate_cost(
        index.codec, kc, k2, top_r,
        [(index.cluster_lists.capacity, index.term_lists.capacity)])


def with_tuned(index: HybridIndex,
               tuned: Optional[qexec.TunedWidths]) -> HybridIndex:
    """The index with ``tuned`` width metadata attached (DESIGN.md §14).
    Pure metadata: the doc planes are shared, only the static pytree
    field changes (so the first search re-traces, like a codec swap)."""
    return dataclasses.replace(index, tuned=tuned)


# --------------------------------------------------------------------------
# paper baselines — degenerate configurations of the same machinery
# (folded in from the retired standalone IVF wrappers in PR 4; §5.1
# baselines and §5.3 ablations)
# --------------------------------------------------------------------------

def build_ivf(key: Array, doc_embeddings: Array, doc_tokens: Array,
              vocab_size: int, *, n_clusters: int, codec: str = "opq",
              pq_m: int = 8, pq_k: int = 256,
              cluster_capacity: Optional[int] = None,
              cluster_sel=None, doc_assign=None,
              kmeans_iters: int = 15) -> HybridIndex:
    """Cluster-only index (IVF-Flat / IVF-PQ / IVF-OPQ / Distill-VQ
    body).  Same code path as HI² with the term lists disabled, which
    keeps the comparison honest: identical gather/dedup/top-k machinery,
    only the dispatched lists differ (§5.1)."""
    return build(key, doc_embeddings, doc_tokens, vocab_size,
                 n_clusters=n_clusters, k1_terms=1, codec=codec,
                 pq_m=pq_m, pq_k=pq_k, cluster_capacity=cluster_capacity,
                 cluster_sel=cluster_sel, doc_assign=doc_assign,
                 kmeans_iters=kmeans_iters,
                 use_clusters=True, use_terms=False)


def build_term_only(key: Array, doc_embeddings: Array, doc_tokens: Array,
                    vocab_size: int, *, k1_terms: int, codec: str = "opq",
                    pq_m: int = 8, pq_k: int = 256,
                    term_capacity: Optional[int] = None,
                    term_pos_scores=None, term_sel=None) -> HybridIndex:
    """Term-only index (the paper's w.o. Clus ablation)."""
    return build(key, doc_embeddings, doc_tokens, vocab_size,
                 n_clusters=1, k1_terms=k1_terms, codec=codec,
                 pq_m=pq_m, pq_k=pq_k, term_capacity=term_capacity,
                 term_pos_scores=term_pos_scores, term_sel=term_sel,
                 use_clusters=False, use_terms=True)


def search_ivf(index: HybridIndex, query_embeddings: Array,
               query_tokens: Array, *, kc: int, top_r: int,
               use_kernel: bool = False) -> SearchResult:
    """Search with the term side off (k2=1 dispatches only PAD lists)."""
    return search(index, query_embeddings, query_tokens,
                  kc=kc, k2=1, top_r=top_r, use_kernel=use_kernel)


def search_term_only(index: HybridIndex, query_embeddings: Array,
                     query_tokens: Array, *, k2: int, top_r: int,
                     use_kernel: bool = False) -> SearchResult:
    return search(index, query_embeddings, query_tokens,
                  kc=1, k2=k2, top_r=top_r, use_kernel=use_kernel)
