"""HI² — the Hybrid Inverted Index (paper §4, Eq. 5).

Each document is referenced from the inverted lists of exactly **1
embedding cluster** and **K₁ᵀ salient terms**.  A query is dispatched to
**K^C clusters** and **≤ K₂ᵀ terms**; candidates from both list families
are merged, deduplicated, scored by the codec and the top-R returned.

The codec — how documents are stored and scored — is pluggable
(:mod:`repro.core.codecs`, DESIGN.md §7): ``HybridIndex.codec`` is a
spec string (static pytree field, so checkpoints and jit caches stay
stable) resolved through the codec registry; the codec's replicated
parameters and per-document planes live in ``codec_params`` /
``doc_planes`` and are treated opaquely here.

All search-time compute is fixed-shape jitted JAX (the search contract,
DESIGN.md §2):

    dispatch  : two matmul+top-k (cluster) / table-lookup+top-k (term)
    gather    : rows of the padded list planes → (B, budget) candidates
    dedup     : sort-based first-occurrence mask
    scoring   : codec scorer over the candidate rows (e.g. PQ ADC —
                LUT matmul + code gather-sum; Pallas kernel
                ``repro.kernels.pq_adc`` on TPU, jnp oracle otherwise)
    top-R′    : total-order sort by (score desc, doc id asc) — see
                :func:`topk_by_score` and DESIGN.md §6 (the deterministic
                tie-break is what makes the document-sharded merge in
                :mod:`repro.core.sharded_index` bit-identical to this
                single-device path)
    refine    : the codec's optional second stage (exact re-rank of the
                R′ frontier down to R; identity for plain codecs)

The index build runs once on host+device; searching never reshapes.
The static per-query candidate count (:func:`candidate_budget`) is the
latency proxy used throughout ``benchmarks/`` — it upper-bounds the
paper's QL (queried length) and is what the fixed shapes pin down;
:func:`candidate_cost` adds the codec's refine work on top.

Scaling beyond one device's HBM is document sharding (DESIGN.md §6):
:func:`repro.core.sharded_index.partition` splits the doc planes and
list entries over a mesh and reuses this module's dispatch/score ops
per shard under ``shard_map``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cluster_selector as cs_mod
from repro.core import codecs
from repro.core import inverted_lists as il
from repro.core import term_selector as ts_mod
from repro.core.inverted_lists import PAD_DOC, PaddedLists

Array = jax.Array


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["cluster_sel", "term_sel", "cluster_lists", "term_lists",
                 "codec_params", "doc_planes", "doc_assign"],
    meta_fields=["codec"])
@dataclasses.dataclass(frozen=True)
class HybridIndex:
    cluster_sel: cs_mod.ClusterSelector
    term_sel: ts_mod.TermSelector
    cluster_lists: PaddedLists
    term_lists: PaddedLists
    codec_params: Any               # replicated codec state (may be None)
    doc_planes: dict                # per-doc planes, every leaf (n_docs, ...)
    doc_assign: Array               # φ(D), (n_docs,) i32
    codec: str = codecs.DEFAULT     # registry spec (static)

    @property
    def n_docs(self) -> int:
        return int(self.doc_assign.shape[0])

    # convenience views of the codec planes (None when absent)
    @property
    def doc_codes(self) -> Optional[Array]:
        return self.doc_planes.get("codes")

    @property
    def doc_embeddings(self) -> Optional[Array]:
        return self.doc_planes.get("emb")


# --------------------------------------------------------------------------
# build
# --------------------------------------------------------------------------

def build(key: Array,
          doc_embeddings: Array,
          doc_tokens: Array,
          vocab_size: int,
          *,
          n_clusters: int,
          k1_terms: int,
          codec: str = codecs.DEFAULT,
          pq_m: int = 8,
          pq_k: int = 256,
          cluster_capacity: Optional[int] = None,
          term_capacity: Optional[int] = None,
          cluster_sel: Optional[cs_mod.ClusterSelector] = None,
          doc_assign: Optional[Array] = None,
          term_pos_scores: Optional[Array] = None,
          term_sel: Optional[ts_mod.TermSelector] = None,
          kmeans_iters: int = 15,
          use_clusters: bool = True,
          use_terms: bool = True,
          ) -> HybridIndex:
    """Build HI² over a corpus.

    The unsupervised path computes everything here (KMeans + BM25 +
    codec training).  The supervised path passes pre-trained
    ``cluster_sel`` / ``term_pos_scores`` / ``term_sel`` from the
    distillation trainer and reuses the same list construction.
    ``use_clusters`` / ``use_terms`` expose the paper's ablations
    (w.o. Clus / w.o. Term, §5.3).  ``codec`` is any
    :func:`repro.core.codecs.get` spec (unknown names raise with the
    registered list).
    """
    codec_impl = codecs.get(codec)    # fail fast on unknown specs
    n_docs, _ = doc_embeddings.shape
    k_cl, k_codec, k_ts = jax.random.split(key, 3)

    # --- cluster side -----------------------------------------------------
    if cluster_sel is None:
        cluster_sel, doc_assign = cs_mod.init_kmeans(
            k_cl, doc_embeddings, n_clusters, n_iters=kmeans_iters)
    elif doc_assign is None:
        doc_assign = cs_mod.select_for_doc(cluster_sel, doc_embeddings)

    if use_clusters:
        assign_scores = np.asarray(
            cs_mod.scores(cluster_sel, doc_embeddings)
        )[np.arange(n_docs), np.asarray(doc_assign)]
        cluster_lists = il.build(np.arange(n_docs), np.asarray(doc_assign),
                                 assign_scores, n_lists=n_clusters,
                                 capacity=cluster_capacity)
    else:
        cluster_lists = il.PaddedLists(
            entries=jnp.full((n_clusters, 1), PAD_DOC, jnp.int32),
            lengths=jnp.zeros((n_clusters,), jnp.int32))

    # --- term side --------------------------------------------------------
    if term_sel is None or term_pos_scores is None:
        term_sel, term_pos_scores, _ = ts_mod.fit_unsup(doc_tokens, vocab_size)

    if use_terms:
        term_ids, term_scores = ts_mod.doc_terms(doc_tokens, term_pos_scores,
                                                 k1_terms)
        doc_rep = np.repeat(np.arange(n_docs), k1_terms)
        term_lists = il.build(doc_rep, np.asarray(term_ids).reshape(-1),
                              np.asarray(term_scores).reshape(-1),
                              n_lists=vocab_size, capacity=term_capacity)
    else:
        term_lists = il.PaddedLists(
            entries=jnp.full((vocab_size, 1), PAD_DOC, jnp.int32),
            lengths=jnp.zeros((vocab_size,), jnp.int32))

    # --- codec ------------------------------------------------------------
    codec_params = codec_impl.train(k_codec, doc_embeddings,
                                    pq_m=pq_m, pq_k=pq_k)
    doc_planes = codec_impl.encode(codec_params, doc_embeddings)

    return HybridIndex(cluster_sel=cluster_sel, term_sel=term_sel,
                       cluster_lists=cluster_lists, term_lists=term_lists,
                       codec_params=codec_params, doc_planes=doc_planes,
                       doc_assign=jnp.asarray(doc_assign, jnp.int32),
                       codec=codec)


# --------------------------------------------------------------------------
# search
# --------------------------------------------------------------------------

class SearchResult(NamedTuple):
    doc_ids: Array        # (B, R) i32, PAD_DOC when fewer candidates
    scores: Array         # (B, R) f32
    n_candidates: Array   # (B,) i32 — unique docs evaluated (∝ paper's QL)


def topk_by_score(scores: Array, ids: Array, r: int) -> tuple[Array, Array]:
    """Top-r rows under the total order (score desc, doc id asc).

    ``jax.lax.top_k`` breaks score ties by *position* in the candidate
    array, which differs between candidate orderings (single-device
    concat vs per-shard merge).  Sorting on the composite key makes the
    selection a pure function of the (score, id) *set*, so any
    partitioning of the candidates merges back bit-identically
    (DESIGN.md §6).  Invalid slots must carry ``-inf`` scores; they sort
    last and keep their raw ids — callers mask them (``isfinite``).
    Returns ``(scores, ids)`` of shape (B, r), ``-inf``/``PAD_DOC``
    filled when fewer than r slots exist.
    """
    k_eff = min(r, scores.shape[-1])
    neg_s, sorted_ids = jax.lax.sort(
        (-scores, ids), dimension=-1, num_keys=2)
    top_s, top_ids = -neg_s[..., :k_eff], sorted_ids[..., :k_eff]
    if k_eff < r:
        pad = ((0, 0), (0, r - k_eff))
        top_s = jnp.pad(top_s, pad, constant_values=-jnp.inf)
        top_ids = jnp.pad(top_ids, pad, constant_values=PAD_DOC)
    return top_s, top_ids


@functools.partial(jax.jit,
                   static_argnames=("kc", "k2", "top_r", "use_kernel"))
def search(index: HybridIndex, query_embeddings: Array, query_tokens: Array,
           *, kc: int, k2: int, top_r: int,
           use_kernel: bool = False) -> SearchResult:
    """Eq. 5: A(Q) = A^C(Q) ∪ A^T(Q), then codec scoring + top-R."""
    codec_impl = codecs.get(index.codec)

    # dispatch
    cluster_ids, _ = cs_mod.select_for_query(index.cluster_sel,
                                             query_embeddings, kc)
    term_ids = ts_mod.query_terms(index.term_sel, query_tokens, k2)

    # gather + merge
    cand_c = il.gather_candidates(index.cluster_lists, cluster_ids)
    cand_t = il.gather_candidates(index.term_lists, term_ids)
    cands = jnp.concatenate([cand_c, cand_t], axis=-1)       # (B, budget)

    keep = il.dedup_mask(cands)
    scorer = codec_impl.make_scorer(index.codec_params, index.doc_planes,
                                    query_embeddings, use_kernel)
    scores = jnp.where(keep, scorer(cands), -jnp.inf)

    # total-order top-R′ (handles budgets smaller than R′ by PAD-fill),
    # then the codec's refine stage (identity unless it re-ranks)
    top_s, top_ids = topk_by_score(scores, cands,
                                   codec_impl.refine_width(top_r))
    top_s, top_ids = codec_impl.refine(
        index.codec_params, index.doc_planes, query_embeddings,
        top_s, top_ids, top_r, codecs.single_device_ctx())

    valid = jnp.isfinite(top_s)
    return SearchResult(
        doc_ids=jnp.where(valid, top_ids, PAD_DOC).astype(jnp.int32),
        scores=jnp.where(valid, top_s, 0.0),
        n_candidates=keep.sum(axis=-1).astype(jnp.int32),
    )


def candidate_budget(index: HybridIndex, kc: int, k2: int) -> int:
    """Static per-query candidate slots — the latency proxy used by
    ``benchmarks/`` (DESIGN.md §2).

    Search cost is dominated by gather + codec scoring over this many
    slots, and because the search step is fixed-shape the compiled
    program's wall time is monotone in it.  It upper-bounds the paper's
    measured QL (queried length = unique candidates, reported per query
    as ``SearchResult.n_candidates``); dedup only masks slots, it never
    shrinks the compute.
    """
    return kc * index.cluster_lists.capacity + k2 * index.term_lists.capacity


def candidate_cost(index: HybridIndex, kc: int, k2: int, top_r: int) -> int:
    """:func:`candidate_budget` plus the codec's refine work — the full
    per-query latency proxy (a refining codec exact-scores another R′
    docs after selection; DESIGN.md §7)."""
    return codecs.get(index.codec).candidate_cost(
        candidate_budget(index, kc, k2), top_r)
