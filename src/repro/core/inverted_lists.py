"""Fixed-capacity padded inverted lists — the TPU-native replacement for
Faiss's variable-length postings (DESIGN.md §2).

An inverted file is stored as two dense planes:

    entries: (n_lists, capacity) int32 doc ids, PAD (-1) beyond length
    lengths: (n_lists,)          int32

Construction happens once, host-side (numpy) — exactly like Faiss's CPU
index build — but every *search-time* operation (dispatch, gather, merge,
dedup) is fixed-shape jitted JAX.  Overflowing lists are truncated by
per-document score, which is the same operation as the paper's static
index pruning (Appendix B) applied at build time; :mod:`repro.core.pruning`
implements the percentile-threshold variant on an already-built index.

Postings are **impact-ordered**: :func:`build` sorts each list by
descending per-document score before the capacity cut, so ``entries``
row v holds term v's highest-impact documents first.  The sparse query
path (DESIGN.md §13) rides on that layout: :func:`build_scored`
additionally materializes the scores as an aligned ``(n_lists,
capacity)`` f32 *impact plane* (0 at pads), which makes BM25 search a
fixed-shape gather + per-document sum over the ≤K₂ᵀ probed term lists
— never an exhaustive (B, V) matmul — using the same list planes the
dense path dispatches over.

At scale the ``entries`` plane is sharded over the mesh ``model`` axis
(row-sharding over lists); see ``repro/distributed/sharding.py``.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

PAD_DOC = -1


class PaddedLists(NamedTuple):
    entries: Array   # (n_lists, capacity) i32, PAD_DOC padded
    lengths: Array   # (n_lists,) i32

    @property
    def n_lists(self) -> int:
        return self.entries.shape[0]

    @property
    def capacity(self) -> int:
        return self.entries.shape[1]


def _bucket(doc_ids: np.ndarray, list_ids: np.ndarray,
            scores: Optional[np.ndarray], n_lists: int,
            capacity: Optional[int]
            ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Shared bucketing body of :func:`build` / :func:`build_scored`:
    returns (entries, lengths, weights) numpy planes, weights holding
    each surviving posting's score (0 at pads), aligned with entries."""
    doc_ids = np.asarray(doc_ids).reshape(-1)
    list_ids = np.asarray(list_ids).reshape(-1)
    keep = list_ids >= 0
    doc_ids, list_ids = doc_ids[keep], list_ids[keep]
    if scores is None:
        scores = -np.arange(len(doc_ids), dtype=np.float64)  # FIFO
    else:
        scores = np.asarray(scores, np.float64).reshape(-1)[keep]

    # sort by (list, -score) then cut each list at capacity
    order = np.lexsort((-scores, list_ids))
    doc_ids, list_ids, scores = doc_ids[order], list_ids[order], scores[order]
    counts = np.bincount(list_ids, minlength=n_lists)
    if capacity is None:
        capacity = max(int(counts.max(initial=1)), 1)

    starts = np.zeros(n_lists + 1, np.int64)
    np.cumsum(counts, out=starts[1:])
    rank_in_list = np.arange(len(doc_ids)) - starts[list_ids]
    keep2 = rank_in_list < capacity

    entries = np.full((n_lists, capacity), PAD_DOC, np.int32)
    entries[list_ids[keep2], rank_in_list[keep2]] = doc_ids[keep2]
    weights = np.zeros((n_lists, capacity), np.float32)
    weights[list_ids[keep2], rank_in_list[keep2]] = scores[keep2]
    lengths = np.minimum(counts, capacity).astype(np.int32)
    return entries, lengths, weights


def build(doc_ids: np.ndarray, list_ids: np.ndarray, scores: Optional[np.ndarray],
          n_lists: int, capacity: Optional[int] = None) -> PaddedLists:
    """Bucket (doc, list[, score]) assignment triples into padded lists.

    ``doc_ids``/``list_ids``: (n_assignments,). Assignments with negative
    list id (PAD terms) are dropped. If a list overflows ``capacity`` the
    lowest-scoring documents are dropped (score defaults to insertion
    order → FIFO truncation).
    """
    entries, lengths, _ = _bucket(doc_ids, list_ids, scores, n_lists,
                                  capacity)
    return PaddedLists(entries=jnp.asarray(entries), lengths=jnp.asarray(lengths))


def build_scored(doc_ids: np.ndarray, list_ids: np.ndarray,
                 scores: np.ndarray, n_lists: int,
                 capacity: Optional[int] = None
                 ) -> tuple[PaddedLists, Array]:
    """:func:`build` plus the aligned impact plane for sparse search
    (DESIGN.md §13): ``weights[v, j]`` is the per-document score of
    posting ``entries[v, j]`` (0.0 at pads), so a sparse query scores
    candidates by gathering the same rows the dense path gathers and
    summing impacts per document — no second postings structure.

    ``scores`` is required: an impact plane built from the FIFO
    fallback's synthetic insertion-order scores would rank documents by
    arrival, not relevance, silently.
    """
    if scores is None:
        raise ValueError(
            "build_scored needs real per-posting scores; the FIFO "
            "fallback of build() has no meaningful impacts")
    entries, lengths, weights = _bucket(doc_ids, list_ids, scores, n_lists,
                                        capacity)
    return (PaddedLists(entries=jnp.asarray(entries),
                        lengths=jnp.asarray(lengths)),
            jnp.asarray(weights))


@jax.jit
def gather_candidates(lists: PaddedLists, dispatched: Array) -> Array:
    """Fetch the contents of the dispatched lists for a query batch.

    dispatched: (B, K) list ids (PAD=-1 allowed) →
    candidates: (B, K·capacity) doc ids with PAD_DOC where invalid.
    """
    safe = jnp.clip(dispatched, 0, None)
    rows = lists.entries[safe]                                   # (B, K, cap)
    rows = jnp.where((dispatched >= 0)[:, :, None], rows, PAD_DOC)
    return rows.reshape(dispatched.shape[0], -1)


@jax.jit
def dedup_mask(candidates: Array) -> Array:
    """First-occurrence mask over each row — TPU-friendly set semantics.

    Duplicates arise when a document sits in several dispatched lists
    (cluster ∩ term hits). We sort ids, mark repeats, and scatter the
    mask back — O(B·C log C), fixed shape, no hashing.
    """
    b, c = candidates.shape
    order = jnp.argsort(candidates, axis=-1)
    sorted_ids = jnp.take_along_axis(candidates, order, axis=-1)
    is_dup = jnp.concatenate(
        [jnp.zeros((b, 1), bool), sorted_ids[:, 1:] == sorted_ids[:, :-1]], axis=-1)
    keep_sorted = (~is_dup) & (sorted_ids != PAD_DOC)
    # scatter back to original positions via the inverse permutation
    inv = jnp.argsort(order, axis=-1)
    return jnp.take_along_axis(keep_sorted, inv, axis=-1)


def list_size_histogram(lists: PaddedLists) -> np.ndarray:
    return np.asarray(lists.lengths)
