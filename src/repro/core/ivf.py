"""IVF baselines (paper §5.1) and the HI² ablations (§5.3).

All of these are degenerate configurations of the hybrid machinery:

    IVF-Flat    — clusters only, Flat codec
    IVF-PQ      — clusters only, PQ codec        (Jégou et al. 2011)
    IVF-OPQ     — clusters only, OPQ codec       (Ge et al. 2014)
    Distill-VQ  — clusters only, *learned* cluster embeddings + OPQ
                  (Xiao et al. 2022a; our trainer in core/distill.py)
    w.o. Term   — HI² with the term lists disabled  (≡ IVF-*)
    w.o. Clus   — HI² with the cluster lists disabled (term-only)

Implementing the baselines through the same code path keeps the
comparison honest: identical gather/dedup/top-k machinery, only the
dispatched lists differ — exactly the paper's "same candidates ⇒ same
latency" argument (§5.1).
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.core import hybrid_index as hi

Array = jax.Array


def build_ivf(key: Array, doc_embeddings: Array, doc_tokens: Array,
              vocab_size: int, *, n_clusters: int, codec: str = "opq",
              pq_m: int = 8, pq_k: int = 256,
              cluster_capacity: Optional[int] = None,
              cluster_sel=None, doc_assign=None,
              kmeans_iters: int = 15) -> hi.HybridIndex:
    """Cluster-only index (IVF-Flat / IVF-PQ / IVF-OPQ / Distill-VQ body)."""
    return hi.build(key, doc_embeddings, doc_tokens, vocab_size,
                    n_clusters=n_clusters, k1_terms=1, codec=codec,
                    pq_m=pq_m, pq_k=pq_k, cluster_capacity=cluster_capacity,
                    cluster_sel=cluster_sel, doc_assign=doc_assign,
                    kmeans_iters=kmeans_iters,
                    use_clusters=True, use_terms=False)


def build_term_only(key: Array, doc_embeddings: Array, doc_tokens: Array,
                    vocab_size: int, *, k1_terms: int, codec: str = "opq",
                    pq_m: int = 8, pq_k: int = 256,
                    term_capacity: Optional[int] = None,
                    term_pos_scores=None, term_sel=None) -> hi.HybridIndex:
    """Term-only index (the paper's w.o. Clus ablation)."""
    return hi.build(key, doc_embeddings, doc_tokens, vocab_size,
                    n_clusters=1, k1_terms=k1_terms, codec=codec,
                    pq_m=pq_m, pq_k=pq_k, term_capacity=term_capacity,
                    term_pos_scores=term_pos_scores, term_sel=term_sel,
                    use_clusters=False, use_terms=True)


def search_ivf(index: hi.HybridIndex, query_embeddings: Array,
               query_tokens: Array, *, kc: int, top_r: int,
               use_kernel: bool = False) -> hi.SearchResult:
    """Search with the term side off (k2=1 dispatches only PAD lists)."""
    return hi.search(index, query_embeddings, query_tokens,
                     kc=kc, k2=1, top_r=top_r, use_kernel=use_kernel)


def search_term_only(index: hi.HybridIndex, query_embeddings: Array,
                     query_tokens: Array, *, k2: int, top_r: int,
                     use_kernel: bool = False) -> hi.SearchResult:
    return hi.search(index, query_embeddings, query_tokens,
                     kc=1, k2=k2, top_r=top_r, use_kernel=use_kernel)
