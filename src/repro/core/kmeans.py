"""Distributed KMeans in pure JAX.

This is the substrate for (a) IVF / HI² cluster-selector training
(paper §4.1: cluster embeddings initialized by KMeans over all document
embeddings) and (b) PQ sub-codebook training (paper §3.2, one KMeans per
embedding fragment).

TPU adaptation: assignment is a blocked matmul (``x @ c.T`` on the MXU,
argmax over clusters) instead of Faiss's CPU heap scan; centroid updates
are ``segment_sum`` scatters. The distributed variant shards points over
the mesh's data axes and completes the update with ``psum`` — the only
cross-device traffic is the (L, h) partial-sum planes, never the points.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

Array = jax.Array


def _pad_to_multiple(x: Array, block: int, axis: int = 0,
                     value=0.0) -> tuple[Array, int]:
    n = x.shape[axis]
    rem = (-n) % block
    if rem == 0:
        return x, n
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad, constant_values=value), n


def assign_blocked(x: Array, centroids: Array, block: int = 4096) -> Array:
    """argmin_j ||x_i - c_j||² for every point, computed in MXU-friendly blocks.

    ||x - c||² = ||x||² - 2<x,c> + ||c||²; the ||x||² term is constant per
    point so the argmin reduces to argmax(<x,c> - ||c||²/2).
    """
    c_norm = 0.5 * jnp.sum(centroids.astype(jnp.float32) ** 2, axis=-1)  # (L,)
    xp, n = _pad_to_multiple(x, block)
    xb = xp.reshape(-1, block, x.shape[-1])

    def one_block(xi):
        scores = xi.astype(jnp.float32) @ centroids.T.astype(jnp.float32) - c_norm
        return jnp.argmax(scores, axis=-1).astype(jnp.int32)

    out = jax.lax.map(one_block, xb).reshape(-1)
    return out[:n]


def _update(x: Array, assign: Array, n_clusters: int) -> tuple[Array, Array]:
    """Per-shard partial centroid sums + counts."""
    sums = jax.ops.segment_sum(x.astype(jnp.float32), assign, num_segments=n_clusters)
    counts = jax.ops.segment_sum(jnp.ones_like(assign, jnp.float32), assign,
                                 num_segments=n_clusters)
    return sums, counts


def _reseed_empty(key: Array, centroids: Array, counts: Array, x: Array) -> Array:
    """Empty clusters are re-seeded to random points (standard Lloyd fix).

    Fixed-shape: we draw one candidate point per cluster and use it only
    where the cluster is empty.
    """
    idx = jax.random.randint(key, (centroids.shape[0],), 0, x.shape[0])
    cand = x[idx].astype(jnp.float32)
    empty = (counts < 0.5)[:, None]
    return jnp.where(empty, cand, centroids)


@functools.partial(jax.jit, static_argnames=("n_clusters", "n_iters", "block"))
def kmeans_fit(key: Array, x: Array, n_clusters: int, n_iters: int = 20,
               block: int = 4096) -> tuple[Array, Array]:
    """Lloyd's algorithm. Returns (centroids (L,h) f32, assignments (n,) i32)."""
    n = x.shape[0]
    key, sub = jax.random.split(key)
    init_idx = jax.random.choice(sub, n, (n_clusters,), replace=n < n_clusters)
    init = x[init_idx].astype(jnp.float32)

    def body(carry, k):
        centroids = carry
        a = assign_blocked(x, centroids, block=block)
        sums, counts = _update(x, a, n_clusters)
        new = sums / jnp.maximum(counts, 1.0)[:, None]
        new = _reseed_empty(k, new, counts, x)
        return new, None

    keys = jax.random.split(key, n_iters)
    centroids, _ = jax.lax.scan(body, init, keys)
    return centroids, assign_blocked(x, centroids, block=block)


def kmeans_fit_sharded(key: Array, x_local: Array, n_clusters: int,
                       n_iters: int = 20, axis_names: tuple[str, ...] = ("data",),
                       block: int = 4096) -> Array:
    """SPMD KMeans body — call inside ``shard_map`` with points sharded over
    ``axis_names``. Centroids are replicated; each step does a local
    assign + partial update and a psum of the (L,h)+(L,) planes.
    """
    n_local = x_local.shape[0]
    key = jax.random.fold_in(key, 0)
    init_idx = jax.random.randint(key, (n_clusters,), 0, n_local)
    # every shard proposes local points; pmean so all shards agree on init
    init = jax.lax.pmean(x_local[init_idx].astype(jnp.float32), axis_names)

    def body(centroids, k):
        a = assign_blocked(x_local, centroids, block=block)
        sums, counts = _update(x_local, a, n_clusters)
        sums = jax.lax.psum(sums, axis_names)
        counts = jax.lax.psum(counts, axis_names)
        new = sums / jnp.maximum(counts, 1.0)[:, None]
        new = _reseed_empty(k, new, counts, x_local)
        new = jax.lax.pmean(new, axis_names)  # keep shards identical after reseed
        return new, None

    keys = jax.random.split(jax.random.fold_in(key, 1), n_iters)
    centroids, _ = jax.lax.scan(body, init, keys)
    return centroids


def kmeans_cost(x: Array, centroids: Array, assign: Array) -> Array:
    """Mean squared distance of points to their assigned centroid."""
    d = x.astype(jnp.float32) - centroids[assign]
    return jnp.mean(jnp.sum(d * d, axis=-1))
