"""Retrieval quality metrics (paper §5.1): Recall@K and MRR@K.

qrels are (n_queries,) int32 — one relevant doc per query (our synthetic
benchmark generates single-positive qrels, matching MS MARCO dev's
dominant single-judgement structure). Multi-positive variants accept a
(n_queries, n_pos) padded matrix.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def recall_at_k(retrieved: Array, qrels: Array, k: int) -> float:
    """retrieved: (B, R) ranked doc ids; qrels: (B,) or (B, P) with -1 pads."""
    retrieved = jnp.asarray(retrieved)[:, :k]
    qrels = jnp.asarray(qrels)
    if qrels.ndim == 1:
        qrels = qrels[:, None]
    hit = (retrieved[:, :, None] == qrels[:, None, :]) & (qrels[:, None, :] >= 0)
    per_q = hit.any(axis=1).sum(axis=-1) / jnp.maximum((qrels >= 0).sum(axis=-1), 1)
    return float(jnp.mean(per_q))


def mrr_at_k(retrieved: Array, qrels: Array, k: int) -> float:
    retrieved = jnp.asarray(retrieved)[:, :k]
    qrels = jnp.asarray(qrels)
    if qrels.ndim == 1:
        qrels = qrels[:, None]
    hit = (retrieved[:, :, None] == qrels[:, None, :]) & (qrels[:, None, :] >= 0)
    hit_any = hit.any(axis=-1)                                  # (B, k)
    ranks = jnp.argmax(hit_any, axis=-1)                        # first hit
    found = hit_any.any(axis=-1)
    rr = jnp.where(found, 1.0 / (ranks + 1.0), 0.0)
    return float(jnp.mean(rr))
