"""Optimized Product Quantization (Ge et al. 2014) — the paper's
HI²_unsup evaluation codec (§5.1: "OPQ as the evaluation codec").

OPQ learns an orthogonal rotation R so that ``x @ R`` is easier to
product-quantize.  We use the standard alternating scheme:

    repeat:
        PQ-train on rotated data          (fix R, fit codebooks)
        Procrustes solve for R            (fix codebooks: R = U V^T from
                                           SVD of  X^T X̂,  X̂ = decode(encode(XR)))

``jnp.linalg.svd`` keeps everything in JAX; the rotation is h×h (≤ 1024²)
so this is cheap relative to the KMeans passes.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import pq

Array = jax.Array


class OPQCodebook(NamedTuple):
    rotation: Array        # (h, h) orthogonal
    codebook: pq.PQCodebook

    @property
    def m(self) -> int:
        return self.codebook.m


def train_opq(key: Array, x: Array, m: int, k: int = 256,
              n_outer: int = 4, n_kmeans_iters: int = 10) -> OPQCodebook:
    h = x.shape[-1]
    r = jnp.eye(h, dtype=jnp.float32)
    x = x.astype(jnp.float32)
    cb = None
    for it in range(n_outer):
        key, sub = jax.random.split(key)
        xr = x @ r
        cb = pq.train_pq(sub, xr, m=m, k=k, n_iters=n_kmeans_iters)
        # Procrustes: min_R ||X R - X̂||_F  s.t. R^T R = I
        xhat = pq.decode(cb, pq.encode(cb, xr))
        u, _, vt = jnp.linalg.svd(x.T @ xhat, full_matrices=False)
        r = u @ vt
    # final codebook on the final rotation
    key, sub = jax.random.split(key)
    cb = pq.train_pq(sub, x @ r, m=m, k=k, n_iters=n_kmeans_iters)
    return OPQCodebook(rotation=r, codebook=cb)


@jax.jit
def encode(opq: OPQCodebook, x: Array) -> Array:
    return pq.encode(opq.codebook, x.astype(jnp.float32) @ opq.rotation)


@jax.jit
def adc_lut(opq: OPQCodebook, queries: Array) -> Array:
    """Rotate the query into codebook space, then the LUT is plain PQ.

    <x R, c> = <x, c R^T> — rotating the query preserves Eq. 4 exactly.
    """
    return pq.adc_lut(opq.codebook, queries.astype(jnp.float32) @ opq.rotation)


adc_score = pq.adc_score  # identical once the LUT is built


def reconstruction_mse(opq: OPQCodebook, x: Array) -> Array:
    xr = x.astype(jnp.float32) @ opq.rotation
    return pq.reconstruction_mse(opq.codebook, xr)
