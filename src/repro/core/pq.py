"""Product Quantization codec (paper §3.2, Eq. 3–4).

PQ splits an h-dim embedding into ``m`` fragments, quantizing each
fragment to one of ``k`` codewords.  Storage per document is ``m`` uint8
codes (k ≤ 256) — 32× smaller than fp32 at the paper's (m=96, k=256, h=768).

Search uses ADC (asymmetric distance computation): for a query we build a
(m, k) inner-product lookup table once, then score any candidate with an
``m``-gather + sum (Eq. 4).  On TPU the LUT build is an MXU matmul and
the gather-sum is the Pallas kernel ``repro.kernels.pq_adc``; this module
holds the codec logic and a pure-jnp scoring path used as the oracle.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import kmeans

Array = jax.Array


class PQCodebook(NamedTuple):
    """codewords: (m, k, dsub) f32 — ``m`` independent sub-codebooks."""
    codewords: Array

    @property
    def m(self) -> int:
        return self.codewords.shape[0]

    @property
    def k(self) -> int:
        return self.codewords.shape[1]

    @property
    def dsub(self) -> int:
        return self.codewords.shape[2]


def split_fragments(x: Array, m: int) -> Array:
    """(n, h) -> (n, m, h/m)."""
    n, h = x.shape
    assert h % m == 0, f"dim {h} not divisible by m={m}"
    return x.reshape(n, m, h // m)


@functools.partial(jax.jit, static_argnames=("m", "k", "n_iters"))
def train_pq(key: Array, x: Array, m: int, k: int = 256,
             n_iters: int = 15) -> PQCodebook:
    """One KMeans per fragment, vmapped over the m independent subspaces."""
    frags = split_fragments(x, m).transpose(1, 0, 2)  # (m, n, dsub)
    keys = jax.random.split(key, m)

    def fit_one(kk, xf):
        c, _ = kmeans.kmeans_fit(kk, xf, n_clusters=k, n_iters=n_iters)
        return c

    codewords = jax.vmap(fit_one)(keys, frags)  # (m, k, dsub)
    return PQCodebook(codewords=codewords)


@jax.jit
def encode(codebook: PQCodebook, x: Array) -> Array:
    """Quantize embeddings to codes. (n, h) -> (n, m) int32 (values < k)."""
    frags = split_fragments(x, codebook.m)  # (n, m, dsub)
    # distance argmin per subspace: argmax(<x, c> - ||c||²/2)
    c = codebook.codewords.astype(jnp.float32)  # (m, k, dsub)
    c_norm = 0.5 * jnp.sum(c * c, axis=-1)  # (m, k)
    scores = jnp.einsum("nmd,mkd->nmk", frags.astype(jnp.float32), c) - c_norm
    return jnp.argmax(scores, axis=-1).astype(jnp.int32)


@jax.jit
def decode(codebook: PQCodebook, codes: Array) -> Array:
    """Reconstruct embeddings from codes. (n, m) -> (n, h)."""
    m = codebook.m
    gathered = jnp.take_along_axis(
        codebook.codewords[None],            # (1, m, k, dsub)
        codes[:, :, None, None],             # (n, m, 1, 1)
        axis=2,
    )[:, :, 0]                               # (n, m, dsub)
    return gathered.reshape(codes.shape[0], -1)


@jax.jit
def adc_lut(codebook: PQCodebook, queries: Array) -> Array:
    """Inner-product lookup tables for a batch of queries.

    (B, h) -> (B, m, k): lut[b, j, i] = <e_Q^j, v_{j,i}>  (Eq. 4 terms).
    """
    qf = split_fragments(queries, codebook.m)  # (B, m, dsub)
    return jnp.einsum("bmd,mkd->bmk", qf.astype(jnp.float32),
                      codebook.codewords.astype(jnp.float32))


@jax.jit
def adc_score(lut: Array, codes: Array) -> Array:
    """Score candidates against per-query LUTs (pure-jnp oracle path).

    lut: (B, m, k); codes: (B, C, m) int -> scores (B, C) f32.

    Implemented as ONE flat 1-D gather: the take_along_axis formulation
    materializes five (B, C, m, 3) s32 index planes (~18 GB/device at
    the MS MARCO serving point — EXPERIMENTS.md §Perf); flat indexing
    needs a single (B, C, m) i32 plane. (The Pallas kernel sidesteps
    both on TPU; this is the XLA fallback path.)
    """
    b, m, k = lut.shape
    c = codes.shape[1]
    # flatten only (m, k): the batch axis stays leading so its sharding
    # survives (a full flatten forces GSPMD to reshard the LUT)
    lut2 = lut.reshape(b, m * k)
    idx = (jnp.arange(m, dtype=jnp.int32)[None, None, :] * k
           + codes.astype(jnp.int32)).reshape(b, c * m)
    gathered = jnp.take_along_axis(lut2, idx, axis=1)
    return gathered.reshape(b, c, m).sum(axis=-1)


@jax.jit
def pq_full_scores(codebook: PQCodebook, queries: Array, codes: Array) -> Array:
    """Exhaustive PQ scoring of a whole corpus: (B, h) × (n, m) -> (B, n)."""
    lut = adc_lut(codebook, queries)                       # (B, m, k)
    onehot_free = jnp.take_along_axis(
        lut[:, None], codes[None, :, :, None], axis=-1)[..., 0]  # (B, n, m)
    return jnp.sum(onehot_free, axis=-1)


def reconstruction_mse(codebook: PQCodebook, x: Array) -> Array:
    codes = encode(codebook, x)
    return jnp.mean(jnp.sum((decode(codebook, codes) - x) ** 2, axis=-1))
