"""Static index pruning (paper Appendix B).

After indexing, term-side inverted lists can become "super big" —
especially under the learned term selector.  The paper prunes them:

    threshold = size of the list at the γ-th percentile (γ = 0.996)
    lists above the threshold drop their lowest-scoring references
    until they equal the threshold.

Our padded lists are stored score-descending (inverted_lists.build sorts
by score), so pruning is a pure truncation of the trailing columns —
no re-sort needed at prune time.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.inverted_lists import PAD_DOC, PaddedLists


def prune_percentile(lists: PaddedLists, gamma: float = 0.996) -> PaddedLists:
    lengths = np.asarray(lists.lengths)
    threshold = int(np.quantile(lengths, gamma, method="lower"))
    threshold = max(threshold, 1)
    return prune_to_threshold(lists, threshold)


def prune_to_threshold(lists: PaddedLists, threshold: int) -> PaddedLists:
    entries = np.asarray(lists.entries).copy()
    lengths = np.asarray(lists.lengths).copy()
    cap = entries.shape[1]
    if threshold < cap:
        entries[:, threshold:] = PAD_DOC   # score-descending ⇒ tail = lowest
        lengths = np.minimum(lengths, threshold)
        entries = entries[:, :threshold]
    return PaddedLists(entries=jnp.asarray(entries),
                       lengths=jnp.asarray(lengths))
