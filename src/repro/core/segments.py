"""Streaming index mutations — delta segments, tombstones, compaction
(DESIGN.md §8).

The base :class:`~repro.core.hybrid_index.HybridIndex` is build-once:
its planes are immutable and its shapes are baked into the compiled
search program.  Live corpora churn, so this module adds the classic
segment model on top of it without giving up the fixed-shape search
contract of DESIGN.md §2:

    MutableHybridIndex = immutable base + one delta segment + tombstones

    add_docs()     assign through the *frozen* base selectors (cluster
                   argmax, BM25 terms under the base corpus statistics),
                   encode through the base codec params, append into
                   fixed-capacity delta planes.  New docs get global ids
                   ``n_base + slot``.
    delete_docs()  set a tombstone bit; the exec layer's filter stage
                   applies the mask before the total-order top-R
                   selection, so a deleted doc can never surface — not
                   even as a refine-stage candidate.
    compact()      fold the delta into a fresh base.  Implemented as a
                   from-scratch :func:`repro.core.hybrid_index.build`
                   over the surviving corpus with the original key, so
                   the result is bit-identical to rebuilding — the
                   correctness anchor (the §6 sharded-equals-single
                   contract's streaming analogue), enforced for every
                   registered codec by ``tests/test_segments.py``.

Search is the staged query-execution engine of :mod:`repro.core.exec`
(DESIGN.md §9) over TWO gather sources — the base planes and the
fixed-capacity delta planes — merged through the same total-order
selection as every other variant, so every registered codec
(flat/pq/opq/sq8/refine) works unmodified and per-query namespace
filters (``search(..., filter=)``) apply to streamed docs exactly like
indexed ones.  Mutations are host-side numpy (like the base build);
they change plane *values*, never shapes, so serving never recompiles
between compactions.

:class:`ShardedMutableIndex` runs the same semantics over the
document-sharded layout of DESIGN.md §6: each shard owns a contiguous
slice of the delta slots next to its base doc range, adds are routed to
the owning shard by the slot's global id, and the per-shard frontiers
merge through the same total-order collective — bit-identical to the
single-device mutable search.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bm25
from repro.core import cluster_selector as cs_mod
from repro.core import codecs
from repro.core import exec as qexec
from repro.core import hybrid_index as hi
from repro.core import sharded_index as shi
from repro.core import term_selector as ts_mod
from repro.core.inverted_lists import PAD_DOC, PaddedLists
from repro.distributed import compat

Array = jax.Array


class DeltaFull(RuntimeError):
    """Raised by ``add_docs`` when the delta segment has no free slots;
    call ``compact()`` to fold the delta into a fresh base first."""


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["cluster_lists", "term_lists", "doc_planes", "doc_assign",
                 "doc_ns", "sparse_weights"],
    meta_fields=[])
@dataclasses.dataclass(frozen=True)
class DeltaSegment:
    """The device-side view of the delta: fixed-capacity list planes over
    the same list ids as the base, codec doc planes with ``capacity``
    rows, entries holding *global* doc ids (``n_base + slot``)."""
    cluster_lists: PaddedLists        # (L, Cc') i32
    term_lists: PaddedLists           # (V, Ct') i32
    doc_planes: dict                  # codec planes, leaves (capacity, ...)
    doc_assign: Array                 # (capacity,) i32
    doc_ns: Optional[Array] = None    # (capacity,) i32 namespace ids
    sparse_weights: Optional[Array] = None  # (V, Ct') f32 BM25 impacts,
    #                                   derived from the delta's eviction
    #                                   score plane (DESIGN.md §13)

    @property
    def capacity(self) -> int:
        return int(self.doc_assign.shape[0])


def _pair_sources(base: hi.HybridIndex, delta: DeltaSegment,
                  tombstones: Array) -> list:
    """The (base, delta) source pair for the single-device mutable path:
    same global-capacity base source as the immutable index, plus the
    delta planes owning global ids [n_base, n_base + capacity)."""
    n_base = base.doc_assign.shape[0]
    cap = delta.capacity
    return [
        qexec.Source(cluster_lists=base.cluster_lists,
                     term_lists=base.term_lists,
                     doc_planes=base.doc_planes,
                     size=n_base,
                     tombstones=tombstones[:n_base],
                     doc_ns=base.doc_ns,
                     sparse_weights=base.sparse_weights),
        qexec.Source(cluster_lists=delta.cluster_lists,
                     term_lists=delta.term_lists,
                     doc_planes=delta.doc_planes,
                     size=cap,
                     offset=n_base,
                     family_lo=n_base,
                     family_hi=n_base + cap,
                     tombstones=tombstones[n_base:],
                     doc_ns=delta.doc_ns,
                     sparse_weights=delta.sparse_weights),
    ]


@functools.partial(jax.jit,
                   static_argnames=("kc", "k2", "top_r", "use_kernel",
                                    "fusion"))
def search(base: hi.HybridIndex, delta: DeltaSegment, tombstones: Array,
           query_embeddings: Array, query_tokens: Array, *, kc: int,
           k2: int, top_r: int, use_kernel: bool = False,
           filter: Optional[Array] = None,
           fusion: Optional[qexec.FusionSpec] = None) -> hi.SearchResult:
    """Eq. 5 over base ∪ delta minus tombstones — one fixed-shape jitted
    program (DESIGN.md §8): the §9 stage chain over the (base, delta)
    source pair.

    Dispatch runs once on the shared selectors; base and delta
    candidates are gathered from their own list planes, deduped,
    tombstone- and namespace-masked together, scored by the codec
    against their own doc planes, and the merged frontier goes through
    the total-order selection *before* the codec's refine stage — so
    refine can never resurrect a tombstoned or filtered doc (masked
    slots carry ``-inf`` and stay ``-inf`` through re-ranking).
    ``n_candidates`` counts unique *live* docs evaluated.
    """
    return qexec.execute(
        codecs.get(base.codec), base.codec_params,
        base.cluster_sel, base.term_sel,
        _pair_sources(base, delta, tombstones),
        query_embeddings, query_tokens,
        kc=kc, k2=k2, top_r=top_r, use_kernel=use_kernel,
        ns_filter=filter, fusion=fusion)


# --------------------------------------------------------------------------
# host-side mutable state
# --------------------------------------------------------------------------

def _insert_posting(entries: np.ndarray, scores: np.ndarray,
                    lengths: np.ndarray, list_id: int, doc_id: int,
                    score: float) -> bool:
    """Append one (doc, score) posting to a fixed-capacity delta list.

    Overflow evicts the lowest-scoring posting iff the newcomer beats it
    — the same per-document-score truncation the base build applies
    (DESIGN.md §2), done incrementally.  Returns False when the posting
    was dropped instead.
    """
    cap = entries.shape[1]
    n = int(lengths[list_id])
    if n < cap:
        entries[list_id, n] = doc_id
        scores[list_id, n] = score
        lengths[list_id] = n + 1
        return True
    j = int(np.argmin(scores[list_id]))
    if score <= scores[list_id, j]:
        return False
    entries[list_id, j] = doc_id
    scores[list_id, j] = score
    return True


class MutableHybridIndex:
    """Base HI² + one fixed-capacity delta segment + a tombstone set.

    Construct with :meth:`create` (which also runs the base build), then
    ``add_docs`` / ``delete_docs`` / ``search`` / ``compact``.  Mutation
    is host-side numpy; search operands are rebuilt lazily and cached,
    so repeated searches between mutations transfer nothing.

    The raw corpus (embeddings + tokens + namespaces when filtered) is
    retained host-side: it is the source of truth ``compact()`` rebuilds
    from and what makes the rebuild bit-identical to a from-scratch
    build over the survivors.
    """

    def __init__(self, base: hi.HybridIndex, *, vocab_size: int, key: Array,
                 build_kwargs: dict, delta_capacity: int,
                 delta_cluster_capacity: int, delta_term_capacity: int,
                 corpus_emb: np.ndarray, corpus_tokens: np.ndarray,
                 corpus_ns: Optional[np.ndarray] = None, selectors=None):
        if delta_capacity < 1:
            raise ValueError("delta_capacity must be >= 1")
        self.base = base
        self.vocab_size = int(vocab_size)
        self.key = key
        self.build_kwargs = dict(build_kwargs)
        self.selectors = selectors
        self.delta_capacity = int(delta_capacity)
        self.delta_cluster_capacity = int(delta_cluster_capacity)
        self.delta_term_capacity = int(delta_term_capacity)
        self._corpus_emb = np.array(corpus_emb, np.float32)
        self._corpus_tokens = np.array(corpus_tokens, np.int32)
        if (corpus_ns is None) != (base.doc_ns is None):
            raise ValueError("corpus_ns must accompany a namespaced base")
        self._corpus_ns = (None if corpus_ns is None
                           else np.array(corpus_ns, np.int32))
        self._stats = bm25.fit(jnp.asarray(self._corpus_tokens), vocab_size)

        n_clusters = base.cluster_lists.n_lists
        hidden = self._corpus_emb.shape[1]
        cap = self.delta_capacity
        self._dc_entries = np.full((n_clusters, delta_cluster_capacity),
                                   PAD_DOC, np.int32)
        self._dc_scores = np.full((n_clusters, delta_cluster_capacity),
                                  -np.inf, np.float32)
        self._dc_lengths = np.zeros((n_clusters,), np.int32)
        self._dt_entries = np.full((vocab_size, delta_term_capacity),
                                   PAD_DOC, np.int32)
        self._dt_scores = np.full((vocab_size, delta_term_capacity),
                                  -np.inf, np.float32)
        self._dt_lengths = np.zeros((vocab_size,), np.int32)
        # preallocate codec planes by encoding a zero block — exact
        # shapes/dtypes for any registered codec, no per-codec branches
        codec_impl = codecs.get(base.codec)
        zero = codec_impl.encode(base.codec_params,
                                 jnp.zeros((cap, hidden), jnp.float32))
        self._delta_planes = {k: np.array(v) for k, v in zero.items()}
        self._delta_assign = np.zeros((cap,), np.int32)
        self._delta_ns = (None if self._corpus_ns is None
                          else np.zeros((cap,), np.int32))
        self._delta_emb = np.zeros((cap, hidden), np.float32)
        self._delta_tokens = np.full((cap, self._corpus_tokens.shape[1]),
                                     bm25.PAD_ID, np.int32)
        self._tomb = np.zeros((self.n_base + cap,), bool)
        self._count = 0
        self.dropped_postings = 0
        self._cache: Optional[tuple[DeltaSegment, Array]] = None
        self._epoch = 0

    # --- construction ----------------------------------------------------
    @classmethod
    def create(cls, key: Array, doc_emb, doc_tokens, vocab_size: int, *,
               delta_capacity: int = 1024,
               delta_cluster_capacity: Optional[int] = None,
               delta_term_capacity: Optional[int] = None,
               doc_namespaces=None, selectors=None,
               **build_kwargs) -> "MutableHybridIndex":
        """Build the base index and wrap it with an empty delta segment.

        ``build_kwargs`` are forwarded verbatim to
        :func:`repro.core.hybrid_index.build` — and replayed by
        ``compact()``, so they must be plain JSON-able values
        (ints/strings/bools), not pre-trained selector overrides.
        ``doc_namespaces`` enables filtered search; streamed docs carry
        the ``namespaces=`` argument of :meth:`add_docs`.

        ``selectors`` optionally supplies *supervised* selectors (a
        :class:`repro.launch.train.SupSelectors`): an object with
        ``build_inputs(doc_emb, doc_tokens, vocab_size)`` returning the
        selector overrides for :func:`hi.build` and
        ``position_scores(doc_tokens)`` scoring streamed docs.  Because
        the object is corpus-independent, ``compact()`` can replay the
        build over the survivor set — unlike raw selector arrays, which
        stay rejected below.
        """
        for k in ("cluster_sel", "doc_assign", "term_sel",
                  "term_pos_scores"):
            if k in build_kwargs:
                raise ValueError(
                    f"build_kwargs[{k!r}] is not supported: compact() "
                    "replays the build from scratch and cannot persist "
                    "raw selector arrays — pass a corpus-independent "
                    "``selectors=`` object instead")
        doc_emb = np.asarray(doc_emb, np.float32)
        doc_tokens = np.asarray(doc_tokens, np.int32)
        if doc_namespaces is not None:
            doc_namespaces = np.asarray(doc_namespaces, np.int32)
        sel_kwargs = {}
        if selectors is not None:
            sel_kwargs = selectors.build_inputs(
                jnp.asarray(doc_emb), jnp.asarray(doc_tokens), vocab_size)
            # list count is fixed by the trained selector, not the caller
            n_sel = int(sel_kwargs["cluster_sel"].embeddings.shape[0])
            if build_kwargs.setdefault("n_clusters", n_sel) != n_sel:
                raise ValueError(
                    f"n_clusters={build_kwargs['n_clusters']} conflicts "
                    f"with the supervised selectors' {n_sel} clusters; "
                    "omit n_clusters to derive it")
        base = hi.build(key, jnp.asarray(doc_emb), jnp.asarray(doc_tokens),
                        vocab_size, doc_namespaces=doc_namespaces,
                        **sel_kwargs, **build_kwargs)
        n_clusters = base.cluster_lists.n_lists
        k1 = int(build_kwargs["k1_terms"])
        if delta_cluster_capacity is None:
            delta_cluster_capacity = min(
                delta_capacity,
                max(8, 4 * -(-delta_capacity // n_clusters)))
        if delta_term_capacity is None:
            delta_term_capacity = min(
                delta_capacity,
                max(8, 4 * -(-delta_capacity * k1 // vocab_size)))
        return cls(base, vocab_size=vocab_size, key=key,
                   build_kwargs=build_kwargs, delta_capacity=delta_capacity,
                   delta_cluster_capacity=delta_cluster_capacity,
                   delta_term_capacity=delta_term_capacity,
                   corpus_emb=doc_emb, corpus_tokens=doc_tokens,
                   corpus_ns=doc_namespaces, selectors=selectors)

    # --- views -----------------------------------------------------------
    @property
    def n_base(self) -> int:
        return self.base.n_docs

    @property
    def n_docs(self) -> int:
        """Allocated doc ids (base + filled delta slots), incl. deleted."""
        return self.n_base + self._count

    @property
    def delta_count(self) -> int:
        return self._count

    @property
    def delta_fill(self) -> float:
        return self._count / self.delta_capacity

    @property
    def n_deleted(self) -> int:
        return int(self._tomb[:self.n_docs].sum())

    @property
    def n_live(self) -> int:
        return self.n_docs - self.n_deleted

    @property
    def tombstone_ratio(self) -> float:
        """Deleted fraction of the allocated corpus — with
        :attr:`delta_fill`, one of the two auto-compaction watermarks
        (DESIGN.md §8)."""
        return self.n_deleted / self.n_docs if self.n_docs else 0.0

    def needs_compact(self, fill_watermark: float = 0.0,
                      tombstone_watermark: float = 0.0) -> bool:
        """True when either watermark is crossed: delta fill >=
        ``fill_watermark`` or tombstone ratio >= ``tombstone_watermark``.
        A watermark of 0 disables that trigger (the default — compaction
        stays manual unless serving opts in)."""
        if fill_watermark > 0 and self.delta_fill >= fill_watermark:
            return True
        return (tombstone_watermark > 0
                and self.tombstone_ratio >= tombstone_watermark)

    @property
    def tombstones(self) -> np.ndarray:
        return self._tomb.copy()

    @property
    def filtered(self) -> bool:
        """True when the index carries namespace planes (DESIGN.md §9)."""
        return self._corpus_ns is not None

    @property
    def epoch(self) -> int:
        """Monotonic mutation counter: +1 per ``add_docs`` /
        ``delete_docs`` call and across ``compact()`` (which renumbers
        doc ids, so it must invalidate too).  Serving caches key results
        on it — two searches at the same epoch see the same corpus
        (DESIGN.md §10)."""
        return self._epoch

    def is_deleted(self, ids) -> np.ndarray:
        return self._tomb[np.asarray(ids)]

    def namespaces_of(self, ids) -> np.ndarray:
        """Namespace id of each global doc id (filtered indexes only)."""
        if not self.filtered:
            raise ValueError("index has no namespace planes")
        ids = np.asarray(ids)
        all_ns = np.concatenate([self._corpus_ns, self._delta_ns])
        return all_ns[ids]

    # --- mutation --------------------------------------------------------
    def add_docs(self, doc_emb, doc_tokens, namespaces=None) -> np.ndarray:
        """Append documents to the delta segment; returns their global ids.

        Assignment uses the *frozen* base state: cluster = argmax against
        the base selector, salient terms = BM25 under the base corpus
        statistics (df/avgdl/s̄ refresh only at ``compact()``) — or, on a
        supervised index, the frozen ``selectors`` term scorer.
        ``namespaces`` ((n_new,) int ids or a scalar) is required on a
        filtered index and rejected on an unfiltered one.  Raises
        :class:`DeltaFull` when the segment has no free slots.
        """
        emb = np.atleast_2d(np.asarray(doc_emb, np.float32))
        tokens = np.atleast_2d(np.asarray(doc_tokens, np.int32))
        n_new = emb.shape[0]
        if tokens.shape[0] != n_new:
            raise ValueError(f"emb/tokens row mismatch: {n_new} vs "
                             f"{tokens.shape[0]}")
        if namespaces is not None and not self.filtered:
            raise ValueError(
                "namespaces= on an unfiltered index; build with "
                "doc_namespaces= to enable filtered search")
        if self.filtered:
            if namespaces is None:
                raise ValueError(
                    "filtered index: add_docs needs namespaces= for the "
                    "new docs")
            ns = np.broadcast_to(np.asarray(namespaces, np.int32),
                                 (n_new,)).copy()
            if ns.min() < 0:
                raise ValueError("namespaces must be non-negative ids")
        width = self._corpus_tokens.shape[1]
        if tokens.shape[1] > width:
            raise ValueError(f"doc_tokens wider than the corpus "
                             f"({tokens.shape[1]} > {width})")
        if tokens.shape[1] < width:
            tokens = np.pad(tokens, ((0, 0), (0, width - tokens.shape[1])),
                            constant_values=bm25.PAD_ID)
        if self._count + n_new > self.delta_capacity:
            raise DeltaFull(
                f"delta segment full: {self._count}/{self.delta_capacity} "
                f"slots used, {n_new} more requested — compact() first")

        assign = np.asarray(cs_mod.select_for_doc(self.base.cluster_sel,
                                                  jnp.asarray(emb)))
        a_scores = np.asarray(cs_mod.scores(self.base.cluster_sel,
                                            jnp.asarray(emb)))
        a_scores = a_scores[np.arange(n_new), assign]
        if self.selectors is not None:
            pos = self.selectors.position_scores(jnp.asarray(tokens))
        else:
            pos = bm25.score_positions(jnp.asarray(tokens), self._stats)
        k1 = int(self.build_kwargs["k1_terms"])
        t_ids, t_scores = bm25.top_terms(jnp.asarray(tokens), pos, k1)
        t_ids, t_scores = np.asarray(t_ids), np.asarray(t_scores)

        codec_impl = codecs.get(self.base.codec)
        enc = codec_impl.encode(self.base.codec_params, jnp.asarray(emb))
        lo = self._count
        for k, v in enc.items():
            self._delta_planes[k][lo:lo + n_new] = np.asarray(v)
        self._delta_emb[lo:lo + n_new] = emb
        self._delta_tokens[lo:lo + n_new] = tokens
        self._delta_assign[lo:lo + n_new] = assign
        if self.filtered:
            self._delta_ns[lo:lo + n_new] = ns

        ids = self.n_base + lo + np.arange(n_new)
        for i in range(n_new):
            gid = int(ids[i])
            if not _insert_posting(self._dc_entries, self._dc_scores,
                                   self._dc_lengths, int(assign[i]), gid,
                                   float(a_scores[i])):
                self.dropped_postings += 1
            for j in range(k1):
                term = int(t_ids[i, j])
                if term < 0:
                    continue
                if not _insert_posting(self._dt_entries, self._dt_scores,
                                       self._dt_lengths, term, gid,
                                       float(t_scores[i, j])):
                    self.dropped_postings += 1
        self._count += n_new
        self._cache = None
        self._epoch += 1
        return ids

    def delete_docs(self, doc_ids) -> None:
        """Tombstone documents by global id (base or delta; idempotent).
        Slots are reclaimed only by ``compact()``."""
        ids = np.asarray(doc_ids).reshape(-1)
        if ids.size and (ids.min() < 0 or ids.max() >= self.n_docs):
            raise ValueError(
                f"doc id out of range [0, {self.n_docs}): "
                f"{ids[(ids < 0) | (ids >= self.n_docs)][:8]}")
        self._tomb[ids] = True
        self._cache = None
        self._epoch += 1

    # --- search ----------------------------------------------------------
    def delta_segment(self) -> DeltaSegment:
        self._materialize()
        return self._cache[0]

    def _materialize(self) -> None:
        if self._cache is None:
            delta = DeltaSegment(
                cluster_lists=PaddedLists(jnp.asarray(self._dc_entries),
                                          jnp.asarray(self._dc_lengths)),
                term_lists=PaddedLists(jnp.asarray(self._dt_entries),
                                       jnp.asarray(self._dt_lengths)),
                doc_planes={k: jnp.asarray(v)
                            for k, v in self._delta_planes.items()},
                doc_assign=jnp.asarray(self._delta_assign),
                doc_ns=(None if self._delta_ns is None
                        else jnp.asarray(self._delta_ns)),
                # the eviction score plane IS the impact plane: -inf at
                # empty slots → 0.0, matching build_scored's pad fill
                sparse_weights=(
                    None if self.base.sparse_weights is None
                    else jnp.where(
                        jnp.asarray(self._dt_entries) == PAD_DOC, 0.0,
                        jnp.asarray(self._dt_scores))))
            self._cache = (delta, jnp.asarray(self._tomb))

    def search(self, query_embeddings, query_tokens, *, kc: int, k2: int,
               top_r: int, use_kernel: bool = False,
               filter=None,
               fusion: Optional[qexec.FusionSpec] = None
               ) -> hi.SearchResult:
        self._materialize()
        delta, tomb = self._cache
        return search(self.base, delta, tomb,
                      jnp.asarray(query_embeddings),
                      jnp.asarray(query_tokens),
                      kc=kc, k2=k2, top_r=top_r, use_kernel=use_kernel,
                      filter=filter, fusion=fusion)

    # --- compaction ------------------------------------------------------
    def survivors(self) -> np.ndarray:
        """Old global ids of the live docs, in the (arrival) order the
        compacted index renumbers them: new id i ↔ old id survivors[i]."""
        return np.flatnonzero(~self._tomb[:self.n_docs])

    def surviving_corpus(self) -> tuple[np.ndarray, np.ndarray]:
        emb = np.concatenate([self._corpus_emb,
                              self._delta_emb[:self._count]])
        tokens = np.concatenate([self._corpus_tokens,
                                 self._delta_tokens[:self._count]])
        live = self.survivors()
        return emb[live], tokens[live]

    def surviving_namespaces(self) -> Optional[np.ndarray]:
        """Namespace ids of the survivors (None on unfiltered indexes)
        — what ``compact()`` re-indexes them under."""
        if not self.filtered:
            return None
        ns = np.concatenate([self._corpus_ns,
                             self._delta_ns[:self._count]])
        return ns[self.survivors()]

    def compact(self, key: Optional[Array] = None) -> "MutableHybridIndex":
        """Fold delta + tombstones into a fresh base with an empty delta.

        Deliberately *is* a from-scratch build over the surviving corpus
        (KMeans, BM25 statistics, codec training and all), with the
        original build key unless overridden — which is what makes the
        equivalence contract exact rather than approximate: the
        compacted index is bit-identical to ``hi.build`` on the
        survivors.  Surviving docs are renumbered contiguously (their
        namespaces travel with them); use :meth:`survivors` for the
        old→new id correspondence.
        """
        emb, tokens = self.surviving_corpus()
        if emb.shape[0] == 0:
            raise ValueError("cannot compact an index with zero live docs")
        out = type(self).create(
            self.key if key is None else key, emb, tokens, self.vocab_size,
            delta_capacity=self.delta_capacity,
            delta_cluster_capacity=self.delta_cluster_capacity,
            delta_term_capacity=self.delta_term_capacity,
            doc_namespaces=self.surviving_namespaces(),
            selectors=self.selectors,
            **self.build_kwargs)
        # compaction renumbers survivors, so epoch-keyed caches must not
        # serve pre-compaction entries against the new index
        out._epoch = self._epoch + 1
        return out

    # --- cost accounting (DESIGN.md §2 latency proxy) --------------------
    def families(self) -> list:
        """(cluster, term) list capacities per gather source — the input
        to the shared cost model (repro.core.exec.cost)."""
        return [(self.base.cluster_lists.capacity,
                 self.base.term_lists.capacity),
                (self.delta_cluster_capacity, self.delta_term_capacity)]

    def candidate_budget(self, kc: int, k2: int) -> int:
        return qexec.candidate_budget(kc, k2, self.families())

    def candidate_cost(self, kc: int, k2: int, top_r: int) -> int:
        return qexec.candidate_cost(self.base.codec, kc, k2, top_r,
                                    self.families())

    # --- persistence (driven by repro.checkpoint) ------------------------
    def state_tree(self) -> dict:
        """The checkpointable pytree: base index + every piece of delta
        and tombstone state (including the retained corpus, the
        namespace planes when filtered, and the list score planes that
        drive overflow eviction, so restored indexes mutate identically
        to never-saved ones)."""
        delta = {
            "cluster_entries": self._dc_entries,
            "cluster_scores": self._dc_scores,
            "cluster_lengths": self._dc_lengths,
            "term_entries": self._dt_entries,
            "term_scores": self._dt_scores,
            "term_lengths": self._dt_lengths,
            "planes": self._delta_planes,
            "assign": self._delta_assign,
            "emb": self._delta_emb,
            "tokens": self._delta_tokens,
        }
        corpus = {"emb": self._corpus_emb, "tokens": self._corpus_tokens}
        if self.filtered:
            delta["ns"] = self._delta_ns
            corpus["ns"] = self._corpus_ns
        return {
            "base": self.base,
            "delta": delta,
            "tombstones": self._tomb,
            "corpus": corpus,
            "key": jax.random.key_data(self.key),
        }

    def state_extra(self) -> dict:
        """JSON-able metadata stored next to :meth:`state_tree`."""
        return {"epoch": self._epoch,
                "delta_count": self._count,
                "delta_capacity": self.delta_capacity,
                "delta_cluster_capacity": self.delta_cluster_capacity,
                "delta_term_capacity": self.delta_term_capacity,
                "vocab_size": self.vocab_size,
                "build_kwargs": self.build_kwargs,
                "filtered": self.filtered,
                "sup_selectors": self.selectors is not None,
                "dropped_postings": self.dropped_postings}

    @classmethod
    def from_state(cls, tree: dict, extra: dict,
                   selectors=None) -> "MutableHybridIndex":
        """Rebuild a mutable index from a restored :meth:`state_tree`
        (leaves may be jnp arrays) + its :meth:`state_extra`.

        Supervised selector *parameters* are not part of the state tree
        (they belong to the training checkpoint, not the index): a
        checkpoint written from a supervised index must be restored with
        the same ``selectors=`` object, or add/compact semantics would
        silently fall back to BM25.
        """
        m = extra["mutable"] if "mutable" in extra else extra
        if m.get("sup_selectors") and selectors is None:
            raise ValueError(
                "checkpoint was written from a supervised index; restore "
                "needs the matching selectors= (e.g. a `like` index that "
                "carries .selectors)")
        corpus_ns = tree["corpus"].get("ns")
        out = cls(tree["base"], vocab_size=int(m["vocab_size"]),
                  key=jax.random.wrap_key_data(jnp.asarray(tree["key"])),
                  build_kwargs=dict(m["build_kwargs"]),
                  delta_capacity=int(m["delta_capacity"]),
                  delta_cluster_capacity=int(m["delta_cluster_capacity"]),
                  delta_term_capacity=int(m["delta_term_capacity"]),
                  corpus_emb=np.asarray(tree["corpus"]["emb"]),
                  corpus_tokens=np.asarray(tree["corpus"]["tokens"]),
                  corpus_ns=(None if corpus_ns is None
                             else np.asarray(corpus_ns)),
                  selectors=selectors)
        d = tree["delta"]
        # np.array (not asarray): restored leaves may be jnp arrays whose
        # numpy views are read-only, and all of this state is mutated
        out._dc_entries = np.array(d["cluster_entries"], np.int32)
        out._dc_scores = np.array(d["cluster_scores"], np.float32)
        out._dc_lengths = np.array(d["cluster_lengths"], np.int32)
        out._dt_entries = np.array(d["term_entries"], np.int32)
        out._dt_scores = np.array(d["term_scores"], np.float32)
        out._dt_lengths = np.array(d["term_lengths"], np.int32)
        out._delta_planes = {k: np.array(v) for k, v in d["planes"].items()}
        out._delta_assign = np.array(d["assign"], np.int32)
        if "ns" in d:
            out._delta_ns = np.array(d["ns"], np.int32)
        out._delta_emb = np.array(d["emb"], np.float32)
        out._delta_tokens = np.array(d["tokens"], np.int32)
        out._tomb = np.array(tree["tombstones"], bool)
        out._count = int(m["delta_count"])
        out.dropped_postings = int(m.get("dropped_postings", 0))
        # epoch travels with the state: a restored index must keep
        # invalidating epoch-keyed caches where the saved one left off
        out._epoch = int(m.get("epoch", 0))
        out._cache = None
        return out


# --------------------------------------------------------------------------
# document-sharded mutable search (DESIGN.md §6 + §8 + §9)
# --------------------------------------------------------------------------

def make_mutable_search_step(mesh, axis_name: str, codec: str, n_base: int,
                             per: int, dper: int, kc: int, k2: int,
                             top_r: int, use_kernel: bool = False,
                             batch_axis: Optional[str] = None,
                             filtered: bool = False,
                             fusion: Optional[qexec.FusionSpec] = None):
    """shard_map'd base∪delta search + merge for one static config.

    Shard ``s`` owns base docs [s·per, (s+1)·per) *and* delta slots
    [s·dper, (s+1)·dper) (global ids ``n_base + slot``).  The body is
    the §9 stage chain over the per-shard (base, delta) source pair
    under a :class:`~repro.core.exec.ShardEnv` — the same engine as
    every other variant, so results stay bit-identical.  With
    ``filtered=True`` the step takes a fifth argument, the replicated
    (B, W) namespace bitmap, and ``planes`` must carry ``base_ns`` /
    ``delta_ns``.  ``batch_axis`` optionally partitions the query batch
    (and the bitmap) over a second mesh axis — the 2-D (data, model)
    serving layout of DESIGN.md §12, same semantics as
    :func:`repro.core.sharded_index.make_search_step`.
    """
    from jax.sharding import PartitionSpec as P

    codec_impl = codecs.get(codec)
    n_shards = mesh.shape[axis_name]

    def body(shard, rep, qe, qt, ns_filter=None):
        shard = jax.tree.map(lambda x: x[0], shard)
        s = jax.lax.axis_index(axis_name)
        b_lo, d_lo = s * per, s * dper
        sources = [
            qexec.Source(
                cluster_lists=PaddedLists(shard["base_cluster_entries"],
                                          shard["base_cluster_lengths"]),
                term_lists=PaddedLists(shard["base_term_entries"],
                                       shard["base_term_lengths"]),
                doc_planes=shard["base_codec"],
                size=per,
                offset=b_lo,
                family_hi=n_base,
                tombstones=shard["tomb_base"],
                doc_ns=shard.get("base_ns"),
                sparse_weights=shard.get("base_sparse_weights")),
            qexec.Source(
                cluster_lists=PaddedLists(shard["delta_cluster_entries"],
                                          shard["delta_cluster_lengths"]),
                term_lists=PaddedLists(shard["delta_term_entries"],
                                       shard["delta_term_lengths"]),
                doc_planes=shard["delta_codec"],
                size=dper,
                offset=n_base + d_lo,
                family_lo=n_base,
                family_hi=n_base + n_shards * dper,
                tombstones=shard["tomb_delta"],
                doc_ns=shard.get("delta_ns"),
                sparse_weights=shard.get("delta_sparse_weights")),
        ]
        res = qexec.execute(
            codec_impl, rep["codec"],
            cs_mod.ClusterSelector(embeddings=rep["cluster_emb"]),
            ts_mod.TermSelector(avg_scores=rep["term_avg"]),
            sources, qe, qt,
            kc=kc, k2=k2, top_r=top_r, use_kernel=use_kernel,
            ns_filter=ns_filter, shard=qexec.ShardEnv(axis_name),
            fusion=fusion)
        return res.doc_ids, res.scores, res.n_candidates

    def specs_like(tree, leading):
        return jax.tree.map(
            lambda x: P(leading, *(None,) * (x.ndim - 1)) if leading
            else P(*(None,) * x.ndim), tree)

    qspec = P(batch_axis, None)

    def run(planes, rep, qe, qt, ns_filter=None):
        in_specs = [specs_like(planes, axis_name), specs_like(rep, None),
                    qspec, qspec]
        args = [planes, rep, qe, qt]
        if filtered:
            in_specs.append(qspec)
            args.append(ns_filter)
        mapped = compat.shard_map(
            body, mesh=mesh,
            in_specs=tuple(in_specs),
            out_specs=(qspec, qspec, P(batch_axis)),
            check=False)  # outputs replicated by construction (§6 merge)
        return mapped(*args)

    return run


@functools.lru_cache(maxsize=32)
def _compiled_mutable_search(mesh, axis_name, codec, n_base, per, dper,
                             kc, k2, top_r, use_kernel, filtered,
                             batch_axis=None, fusion=None):
    return jax.jit(make_mutable_search_step(
        mesh, axis_name, codec, n_base, per, dper, kc, k2, top_r,
        use_kernel, batch_axis=batch_axis, filtered=filtered,
        fusion=fusion))


class ShardedMutableIndex:
    """Mutable HI² over the document-sharded layout of DESIGN.md §6.

    Wraps a :class:`MutableHybridIndex` (the host-side source of truth)
    and keeps a device-placed sharded view: the immutable base is
    partitioned once at construction; delta planes, namespace planes and
    tombstones are re-split after each mutation, which routes every
    added doc's postings and codec rows to the shard owning its global
    id.  Search is bit-identical to the single-device mutable search
    (asserted for every registered codec by ``tests/test_segments.py``
    and, with filters, ``tests/test_exec.py``).
    """

    def __init__(self, mut: MutableHybridIndex, n_shards: int, mesh=None,
                 axis_name: str = shi.SHARD_AXIS,
                 data_axis: Optional[str] = None):
        self.mut = mut
        self.n_shards = int(n_shards)
        self.axis_name = axis_name
        self.data_axis = data_axis
        if data_axis is not None and mesh is None:
            raise ValueError("data_axis= needs the 2-D mesh passed in "
                             "(launch.mesh.make_serving_mesh)")
        self.mesh = mesh if mesh is not None else shi.make_shard_mesh(
            n_shards, axis_name)
        sbase = shi.partition(mut.base, n_shards)
        self._sbase = shi.device_put(sbase, self.mesh, axis_name)
        self.per = sbase.docs_per_shard
        self.dper = -(-mut.delta_capacity // n_shards)
        self._delta_state: Optional[dict] = None

    # --- mutation: delegate to the host index, re-split the delta --------
    def add_docs(self, doc_emb, doc_tokens, namespaces=None) -> np.ndarray:
        ids = self.mut.add_docs(doc_emb, doc_tokens, namespaces=namespaces)
        self._delta_state = None
        return ids

    def delete_docs(self, doc_ids) -> None:
        self.mut.delete_docs(doc_ids)
        self._delta_state = None

    def compact(self, key: Optional[Array] = None) -> "ShardedMutableIndex":
        return type(self)(self.mut.compact(key), self.n_shards,
                          mesh=self.mesh, axis_name=self.axis_name,
                          data_axis=self.data_axis)

    @property
    def epoch(self) -> int:
        """The wrapped host index's mutation counter (DESIGN.md §10)."""
        return self.mut.epoch

    def owning_shard(self, doc_ids) -> np.ndarray:
        """Which shard serves each global doc id (base range split by
        ``per``, delta slots split by ``dper``)."""
        ids = np.asarray(doc_ids)
        n_base = self.mut.n_base
        return np.where(ids < n_base, ids // self.per,
                        (ids - n_base) // self.dper)

    # --- device state ----------------------------------------------------
    def _split_delta(self) -> dict:
        mut, n_base = self.mut, self.mut.n_base
        s, dper = self.n_shards, self.dper
        dc_e, dc_l = shi._split_lists(mut._dc_entries, s, dper, base=n_base)
        dt_w = None
        if mut.base.sparse_weights is None:
            dt_e, dt_l = shi._split_lists(mut._dt_entries, s, dper,
                                          base=n_base)
        else:
            dw = np.where(mut._dt_entries == PAD_DOC, 0.0,
                          mut._dt_scores).astype(np.float32)
            dt_e, dt_l, dt_w = shi._split_lists(mut._dt_entries, s, dper,
                                                base=n_base, weights=dw)
        tomb = mut._tomb
        state = {
            "delta_cluster_entries": jnp.asarray(dc_e),
            "delta_cluster_lengths": jnp.asarray(dc_l),
            "delta_term_entries": jnp.asarray(dt_e),
            "delta_term_lengths": jnp.asarray(dt_l),
            "delta_codec": {
                k: jnp.asarray(shi._split_docs(v, s, dper))
                for k, v in mut._delta_planes.items()},
            "tomb_base": jnp.asarray(
                shi._split_docs(tomb[:n_base], s, self.per)),
            "tomb_delta": jnp.asarray(
                shi._split_docs(tomb[n_base:], s, dper)),
        }
        if dt_w is not None:
            state["delta_sparse_weights"] = jnp.asarray(dt_w)
        if mut.filtered:
            state["delta_ns"] = jnp.asarray(
                shi._split_docs(mut._delta_ns, s, dper))
        return state

    def _planes(self) -> dict:
        if self._delta_state is None:
            from jax.sharding import NamedSharding, PartitionSpec as P

            def put(x):
                return jax.device_put(x, NamedSharding(
                    self.mesh,
                    P(self.axis_name, *(None,) * (x.ndim - 1))))

            self._delta_state = jax.tree.map(put, self._split_delta())
        sb = self._sbase
        planes = {
            "base_cluster_entries": sb.cluster_entries,
            "base_cluster_lengths": sb.cluster_lengths,
            "base_term_entries": sb.term_entries,
            "base_term_lengths": sb.term_lengths,
            "base_codec": sb.doc_planes,
            **self._delta_state,
        }
        if sb.doc_ns is not None:
            planes["base_ns"] = sb.doc_ns
        if sb.sparse_weights is not None:
            planes["base_sparse_weights"] = sb.sparse_weights
        return planes

    def search(self, query_embeddings, query_tokens, *, kc: int, k2: int,
               top_r: int, use_kernel: bool = False,
               filter=None,
               fusion: Optional[qexec.FusionSpec] = None
               ) -> hi.SearchResult:
        if filter is not None and not self.mut.filtered:
            raise ValueError(
                "search(filter=...) needs an index built with "
                "doc_namespaces=")
        rep = {"cluster_emb": self._sbase.cluster_sel.embeddings,
               "term_avg": self._sbase.term_sel.avg_scores,
               "codec": self._sbase.codec_params}
        if self.data_axis is not None:
            d = self.mesh.shape[self.data_axis]
            if np.shape(query_embeddings)[0] % d:
                raise ValueError(
                    f"batch {np.shape(query_embeddings)[0]} does not "
                    f"divide over {d} data-axis slices")
        fn = _compiled_mutable_search(
            self.mesh, self.axis_name, self.mut.base.codec, self.mut.n_base,
            self.per, self.dper, kc, k2, top_r, use_kernel,
            filter is not None, self.data_axis, fusion)
        args = [self._planes(), rep, jnp.asarray(query_embeddings),
                jnp.asarray(query_tokens)]
        if filter is not None:
            args.append(jnp.asarray(filter, jnp.uint32))
        ids, scores, n_cand = fn(*args)
        return hi.SearchResult(doc_ids=ids, scores=scores,
                               n_candidates=n_cand)
