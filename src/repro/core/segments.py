"""Streaming index mutations — delta segments, tombstones, compaction
(DESIGN.md §8).

The base :class:`~repro.core.hybrid_index.HybridIndex` is build-once:
its planes are immutable and its shapes are baked into the compiled
search program.  Live corpora churn, so this module adds the classic
segment model on top of it without giving up the fixed-shape search
contract of DESIGN.md §2:

    MutableHybridIndex = immutable base + one delta segment + tombstones

    add_docs()     assign through the *frozen* base selectors (cluster
                   argmax, BM25 terms under the base corpus statistics),
                   encode through the base codec params, append into
                   fixed-capacity delta planes.  New docs get global ids
                   ``n_base + slot``.
    delete_docs()  set a tombstone bit; the mask is applied before the
                   total-order top-R selection, so a deleted doc can
                   never surface — not even as a refine-stage candidate.
    compact()      fold the delta into a fresh base.  Implemented as a
                   from-scratch :func:`repro.core.hybrid_index.build`
                   over the surviving corpus with the original key, so
                   the result is bit-identical to rebuilding — the
                   correctness anchor (the §6 sharded-equals-single
                   contract's streaming analogue), enforced for every
                   registered codec by ``tests/test_segments.py``.

Search stays one fixed-shape jitted program: the delta segment has
static capacity, base and delta candidates are gathered and scored by
the *same* dispatch/gather/codec ops as the base-only path, and the two
frontiers merge through :func:`~repro.core.hybrid_index.topk_by_score`
before the codec's refine stage — so every registered codec
(flat/pq/opq/sq8/refine) works unmodified.  Mutations are host-side
numpy (like the base build); they change plane *values*, never shapes,
so serving never recompiles between compactions.

:class:`ShardedMutableIndex` runs the same semantics over the
document-sharded layout of DESIGN.md §6: each shard owns a contiguous
slice of the delta slots next to its base doc range, adds are routed to
the owning shard by the slot's global id, and the per-shard frontiers
merge through the same total-order collective — bit-identical to the
single-device mutable search.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bm25
from repro.core import cluster_selector as cs_mod
from repro.core import codecs
from repro.core import hybrid_index as hi
from repro.core import inverted_lists as il
from repro.core import sharded_index as shi
from repro.core import term_selector as ts_mod
from repro.core.inverted_lists import PAD_DOC, PaddedLists
from repro.distributed import collectives, compat

Array = jax.Array


class DeltaFull(RuntimeError):
    """Raised by ``add_docs`` when the delta segment has no free slots;
    call ``compact()`` to fold the delta into a fresh base first."""


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["cluster_lists", "term_lists", "doc_planes", "doc_assign"],
    meta_fields=[])
@dataclasses.dataclass(frozen=True)
class DeltaSegment:
    """The device-side view of the delta: fixed-capacity list planes over
    the same list ids as the base, codec doc planes with ``capacity``
    rows, entries holding *global* doc ids (``n_base + slot``)."""
    cluster_lists: PaddedLists        # (L, Cc') i32
    term_lists: PaddedLists           # (V, Ct') i32
    doc_planes: dict                  # codec planes, leaves (capacity, ...)
    doc_assign: Array                 # (capacity,) i32

    @property
    def capacity(self) -> int:
        return int(self.doc_assign.shape[0])


def _pair_gather(plane_pair, ids: Array, *, n_base: int, b_lo: int,
                 b_size: int, d_lo: int, d_size: int) -> Array:
    """RefineCtx gather over a (base_plane, delta_plane) pair.

    Routes each global id to the segment that stores it: ids below
    ``n_base`` hit the base plane at row ``id - b_lo``, ids at or above
    hit the delta plane at row ``id - n_base - d_lo`` (``b_lo``/``d_lo``
    are 0 on the single-device path and the shard offsets under
    shard_map).  Out-of-segment rows are clipped garbage — callers mask
    them via ``ctx.owned`` / finite-score checks.
    """
    plane_b, plane_d = plane_pair
    rows_b = plane_b[jnp.clip(ids - b_lo, 0, b_size - 1)]
    rows_d = plane_d[jnp.clip(ids - n_base - d_lo, 0, d_size - 1)]
    is_delta = ids >= n_base
    is_delta = is_delta.reshape(is_delta.shape
                                + (1,) * (rows_b.ndim - is_delta.ndim))
    return jnp.where(is_delta, rows_d, rows_b)


@functools.partial(jax.jit,
                   static_argnames=("kc", "k2", "top_r", "use_kernel"))
def search(base: hi.HybridIndex, delta: DeltaSegment, tombstones: Array,
           query_embeddings: Array, query_tokens: Array, *, kc: int,
           k2: int, top_r: int, use_kernel: bool = False) -> hi.SearchResult:
    """Eq. 5 over base ∪ delta minus tombstones — one fixed-shape jitted
    program (DESIGN.md §8).

    Dispatch runs once on the shared selectors; base and delta
    candidates are gathered from their own list planes, deduped and
    tombstone-masked together, scored by the codec against their own doc
    planes, and the merged frontier goes through the total-order
    ``topk_by_score`` *before* the codec's refine stage — so refine can
    never resurrect a tombstoned doc (masked slots carry ``-inf`` and
    stay ``-inf`` through re-ranking).  ``n_candidates`` counts unique
    *live* docs evaluated.
    """
    codec_impl = codecs.get(base.codec)
    n_base = base.doc_assign.shape[0]
    cap = delta.capacity

    cluster_ids, _ = cs_mod.select_for_query(base.cluster_sel,
                                             query_embeddings, kc)
    term_ids = ts_mod.query_terms(base.term_sel, query_tokens, k2)

    cand_b = jnp.concatenate(
        [il.gather_candidates(base.cluster_lists, cluster_ids),
         il.gather_candidates(base.term_lists, term_ids)], axis=-1)
    cand_d = jnp.concatenate(
        [il.gather_candidates(delta.cluster_lists, cluster_ids),
         il.gather_candidates(delta.term_lists, term_ids)], axis=-1)
    cands = jnp.concatenate([cand_b, cand_d], axis=-1)

    keep = il.dedup_mask(cands)
    dead = tombstones[jnp.clip(cands, 0, n_base + cap - 1)]
    live = keep & ~dead

    scorer_b = codec_impl.make_scorer(base.codec_params, base.doc_planes,
                                      query_embeddings, use_kernel)
    scorer_d = codec_impl.make_scorer(base.codec_params, delta.doc_planes,
                                      query_embeddings, use_kernel)
    local_d = jnp.clip(cand_d - n_base, 0, cap - 1)
    scores = jnp.concatenate([scorer_b(cand_b), scorer_d(local_d)], axis=-1)
    scores = jnp.where(live, scores, -jnp.inf)

    top_s, top_ids = hi.topk_by_score(scores, cands,
                                      codec_impl.refine_width(top_r))
    pair_planes = {k: (base.doc_planes[k], delta.doc_planes[k])
                   for k in base.doc_planes}
    ctx = codecs.RefineCtx(
        gather=functools.partial(_pair_gather, n_base=n_base, b_lo=0,
                                 b_size=n_base, d_lo=0, d_size=cap),
        owned=lambda ids: ids >= 0,
        psum=lambda x: x)
    top_s, top_ids = codec_impl.refine(base.codec_params, pair_planes,
                                       query_embeddings, top_s, top_ids,
                                       top_r, ctx)

    valid = jnp.isfinite(top_s)
    return hi.SearchResult(
        doc_ids=jnp.where(valid, top_ids, PAD_DOC).astype(jnp.int32),
        scores=jnp.where(valid, top_s, 0.0),
        n_candidates=live.sum(axis=-1).astype(jnp.int32))


# --------------------------------------------------------------------------
# host-side mutable state
# --------------------------------------------------------------------------

def _insert_posting(entries: np.ndarray, scores: np.ndarray,
                    lengths: np.ndarray, list_id: int, doc_id: int,
                    score: float) -> bool:
    """Append one (doc, score) posting to a fixed-capacity delta list.

    Overflow evicts the lowest-scoring posting iff the newcomer beats it
    — the same per-document-score truncation the base build applies
    (DESIGN.md §2), done incrementally.  Returns False when the posting
    was dropped instead.
    """
    cap = entries.shape[1]
    n = int(lengths[list_id])
    if n < cap:
        entries[list_id, n] = doc_id
        scores[list_id, n] = score
        lengths[list_id] = n + 1
        return True
    j = int(np.argmin(scores[list_id]))
    if score <= scores[list_id, j]:
        return False
    entries[list_id, j] = doc_id
    scores[list_id, j] = score
    return True


class MutableHybridIndex:
    """Base HI² + one fixed-capacity delta segment + a tombstone set.

    Construct with :meth:`create` (which also runs the base build), then
    ``add_docs`` / ``delete_docs`` / ``search`` / ``compact``.  Mutation
    is host-side numpy; search operands are rebuilt lazily and cached,
    so repeated searches between mutations transfer nothing.

    The raw corpus (embeddings + tokens) is retained host-side: it is
    the source of truth ``compact()`` rebuilds from and what makes the
    rebuild bit-identical to a from-scratch build over the survivors.
    """

    def __init__(self, base: hi.HybridIndex, *, vocab_size: int, key: Array,
                 build_kwargs: dict, delta_capacity: int,
                 delta_cluster_capacity: int, delta_term_capacity: int,
                 corpus_emb: np.ndarray, corpus_tokens: np.ndarray):
        if delta_capacity < 1:
            raise ValueError("delta_capacity must be >= 1")
        self.base = base
        self.vocab_size = int(vocab_size)
        self.key = key
        self.build_kwargs = dict(build_kwargs)
        self.delta_capacity = int(delta_capacity)
        self.delta_cluster_capacity = int(delta_cluster_capacity)
        self.delta_term_capacity = int(delta_term_capacity)
        self._corpus_emb = np.array(corpus_emb, np.float32)
        self._corpus_tokens = np.array(corpus_tokens, np.int32)
        self._stats = bm25.fit(jnp.asarray(self._corpus_tokens), vocab_size)

        n_clusters = base.cluster_lists.n_lists
        hidden = self._corpus_emb.shape[1]
        cap = self.delta_capacity
        self._dc_entries = np.full((n_clusters, delta_cluster_capacity),
                                   PAD_DOC, np.int32)
        self._dc_scores = np.full((n_clusters, delta_cluster_capacity),
                                  -np.inf, np.float32)
        self._dc_lengths = np.zeros((n_clusters,), np.int32)
        self._dt_entries = np.full((vocab_size, delta_term_capacity),
                                   PAD_DOC, np.int32)
        self._dt_scores = np.full((vocab_size, delta_term_capacity),
                                  -np.inf, np.float32)
        self._dt_lengths = np.zeros((vocab_size,), np.int32)
        # preallocate codec planes by encoding a zero block — exact
        # shapes/dtypes for any registered codec, no per-codec branches
        codec_impl = codecs.get(base.codec)
        zero = codec_impl.encode(base.codec_params,
                                 jnp.zeros((cap, hidden), jnp.float32))
        self._delta_planes = {k: np.array(v) for k, v in zero.items()}
        self._delta_assign = np.zeros((cap,), np.int32)
        self._delta_emb = np.zeros((cap, hidden), np.float32)
        self._delta_tokens = np.full((cap, self._corpus_tokens.shape[1]),
                                     bm25.PAD_ID, np.int32)
        self._tomb = np.zeros((self.n_base + cap,), bool)
        self._count = 0
        self.dropped_postings = 0
        self._cache: Optional[tuple[DeltaSegment, Array]] = None

    # --- construction ----------------------------------------------------
    @classmethod
    def create(cls, key: Array, doc_emb, doc_tokens, vocab_size: int, *,
               delta_capacity: int = 1024,
               delta_cluster_capacity: Optional[int] = None,
               delta_term_capacity: Optional[int] = None,
               **build_kwargs) -> "MutableHybridIndex":
        """Build the base index and wrap it with an empty delta segment.

        ``build_kwargs`` are forwarded verbatim to
        :func:`repro.core.hybrid_index.build` — and replayed by
        ``compact()``, so they must be plain JSON-able values
        (ints/strings/bools), not pre-trained selector overrides.
        """
        for k in ("cluster_sel", "doc_assign", "term_sel",
                  "term_pos_scores"):
            if k in build_kwargs:
                raise ValueError(
                    f"build_kwargs[{k!r}] is not supported: compact() "
                    "replays the build from scratch and cannot persist "
                    "pre-trained selector state")
        doc_emb = np.asarray(doc_emb, np.float32)
        doc_tokens = np.asarray(doc_tokens, np.int32)
        base = hi.build(key, jnp.asarray(doc_emb), jnp.asarray(doc_tokens),
                        vocab_size, **build_kwargs)
        n_clusters = base.cluster_lists.n_lists
        k1 = int(build_kwargs["k1_terms"])
        if delta_cluster_capacity is None:
            delta_cluster_capacity = min(
                delta_capacity,
                max(8, 4 * -(-delta_capacity // n_clusters)))
        if delta_term_capacity is None:
            delta_term_capacity = min(
                delta_capacity,
                max(8, 4 * -(-delta_capacity * k1 // vocab_size)))
        return cls(base, vocab_size=vocab_size, key=key,
                   build_kwargs=build_kwargs, delta_capacity=delta_capacity,
                   delta_cluster_capacity=delta_cluster_capacity,
                   delta_term_capacity=delta_term_capacity,
                   corpus_emb=doc_emb, corpus_tokens=doc_tokens)

    # --- views -----------------------------------------------------------
    @property
    def n_base(self) -> int:
        return self.base.n_docs

    @property
    def n_docs(self) -> int:
        """Allocated doc ids (base + filled delta slots), incl. deleted."""
        return self.n_base + self._count

    @property
    def delta_count(self) -> int:
        return self._count

    @property
    def delta_fill(self) -> float:
        return self._count / self.delta_capacity

    @property
    def n_deleted(self) -> int:
        return int(self._tomb[:self.n_docs].sum())

    @property
    def n_live(self) -> int:
        return self.n_docs - self.n_deleted

    @property
    def tombstones(self) -> np.ndarray:
        return self._tomb.copy()

    def is_deleted(self, ids) -> np.ndarray:
        return self._tomb[np.asarray(ids)]

    # --- mutation --------------------------------------------------------
    def add_docs(self, doc_emb, doc_tokens) -> np.ndarray:
        """Append documents to the delta segment; returns their global ids.

        Assignment uses the *frozen* base state: cluster = argmax against
        the base selector, salient terms = BM25 under the base corpus
        statistics (df/avgdl/s̄ refresh only at ``compact()``).  Raises
        :class:`DeltaFull` when the segment has no free slots.
        """
        emb = np.atleast_2d(np.asarray(doc_emb, np.float32))
        tokens = np.atleast_2d(np.asarray(doc_tokens, np.int32))
        n_new = emb.shape[0]
        if tokens.shape[0] != n_new:
            raise ValueError(f"emb/tokens row mismatch: {n_new} vs "
                             f"{tokens.shape[0]}")
        width = self._corpus_tokens.shape[1]
        if tokens.shape[1] > width:
            raise ValueError(f"doc_tokens wider than the corpus "
                             f"({tokens.shape[1]} > {width})")
        if tokens.shape[1] < width:
            tokens = np.pad(tokens, ((0, 0), (0, width - tokens.shape[1])),
                            constant_values=bm25.PAD_ID)
        if self._count + n_new > self.delta_capacity:
            raise DeltaFull(
                f"delta segment full: {self._count}/{self.delta_capacity} "
                f"slots used, {n_new} more requested — compact() first")

        assign = np.asarray(cs_mod.select_for_doc(self.base.cluster_sel,
                                                  jnp.asarray(emb)))
        a_scores = np.asarray(cs_mod.scores(self.base.cluster_sel,
                                            jnp.asarray(emb)))
        a_scores = a_scores[np.arange(n_new), assign]
        pos = bm25.score_positions(jnp.asarray(tokens), self._stats)
        k1 = int(self.build_kwargs["k1_terms"])
        t_ids, t_scores = bm25.top_terms(jnp.asarray(tokens), pos, k1)
        t_ids, t_scores = np.asarray(t_ids), np.asarray(t_scores)

        codec_impl = codecs.get(self.base.codec)
        enc = codec_impl.encode(self.base.codec_params, jnp.asarray(emb))
        lo = self._count
        for k, v in enc.items():
            self._delta_planes[k][lo:lo + n_new] = np.asarray(v)
        self._delta_emb[lo:lo + n_new] = emb
        self._delta_tokens[lo:lo + n_new] = tokens
        self._delta_assign[lo:lo + n_new] = assign

        ids = self.n_base + lo + np.arange(n_new)
        for i in range(n_new):
            gid = int(ids[i])
            if not _insert_posting(self._dc_entries, self._dc_scores,
                                   self._dc_lengths, int(assign[i]), gid,
                                   float(a_scores[i])):
                self.dropped_postings += 1
            for j in range(k1):
                term = int(t_ids[i, j])
                if term < 0:
                    continue
                if not _insert_posting(self._dt_entries, self._dt_scores,
                                       self._dt_lengths, term, gid,
                                       float(t_scores[i, j])):
                    self.dropped_postings += 1
        self._count += n_new
        self._cache = None
        return ids

    def delete_docs(self, doc_ids) -> None:
        """Tombstone documents by global id (base or delta; idempotent).
        Slots are reclaimed only by ``compact()``."""
        ids = np.asarray(doc_ids).reshape(-1)
        if ids.size and (ids.min() < 0 or ids.max() >= self.n_docs):
            raise ValueError(
                f"doc id out of range [0, {self.n_docs}): "
                f"{ids[(ids < 0) | (ids >= self.n_docs)][:8]}")
        self._tomb[ids] = True
        self._cache = None

    # --- search ----------------------------------------------------------
    def delta_segment(self) -> DeltaSegment:
        self._materialize()
        return self._cache[0]

    def _materialize(self) -> None:
        if self._cache is None:
            delta = DeltaSegment(
                cluster_lists=PaddedLists(jnp.asarray(self._dc_entries),
                                          jnp.asarray(self._dc_lengths)),
                term_lists=PaddedLists(jnp.asarray(self._dt_entries),
                                       jnp.asarray(self._dt_lengths)),
                doc_planes={k: jnp.asarray(v)
                            for k, v in self._delta_planes.items()},
                doc_assign=jnp.asarray(self._delta_assign))
            self._cache = (delta, jnp.asarray(self._tomb))

    def search(self, query_embeddings, query_tokens, *, kc: int, k2: int,
               top_r: int, use_kernel: bool = False) -> hi.SearchResult:
        self._materialize()
        delta, tomb = self._cache
        return search(self.base, delta, tomb,
                      jnp.asarray(query_embeddings),
                      jnp.asarray(query_tokens),
                      kc=kc, k2=k2, top_r=top_r, use_kernel=use_kernel)

    # --- compaction ------------------------------------------------------
    def survivors(self) -> np.ndarray:
        """Old global ids of the live docs, in the (arrival) order the
        compacted index renumbers them: new id i ↔ old id survivors[i]."""
        return np.flatnonzero(~self._tomb[:self.n_docs])

    def surviving_corpus(self) -> tuple[np.ndarray, np.ndarray]:
        emb = np.concatenate([self._corpus_emb,
                              self._delta_emb[:self._count]])
        tokens = np.concatenate([self._corpus_tokens,
                                 self._delta_tokens[:self._count]])
        live = self.survivors()
        return emb[live], tokens[live]

    def compact(self, key: Optional[Array] = None) -> "MutableHybridIndex":
        """Fold delta + tombstones into a fresh base with an empty delta.

        Deliberately *is* a from-scratch build over the surviving corpus
        (KMeans, BM25 statistics, codec training and all), with the
        original build key unless overridden — which is what makes the
        equivalence contract exact rather than approximate: the
        compacted index is bit-identical to ``hi.build`` on the
        survivors.  Surviving docs are renumbered contiguously; use
        :meth:`survivors` for the old→new id correspondence.
        """
        emb, tokens = self.surviving_corpus()
        if emb.shape[0] == 0:
            raise ValueError("cannot compact an index with zero live docs")
        return type(self).create(
            self.key if key is None else key, emb, tokens, self.vocab_size,
            delta_capacity=self.delta_capacity,
            delta_cluster_capacity=self.delta_cluster_capacity,
            delta_term_capacity=self.delta_term_capacity,
            **self.build_kwargs)

    # --- cost accounting (DESIGN.md §2 latency proxy) --------------------
    def candidate_budget(self, kc: int, k2: int) -> int:
        return (hi.candidate_budget(self.base, kc, k2)
                + kc * self.delta_cluster_capacity
                + k2 * self.delta_term_capacity)

    def candidate_cost(self, kc: int, k2: int, top_r: int) -> int:
        return codecs.get(self.base.codec).candidate_cost(
            self.candidate_budget(kc, k2), top_r)

    # --- persistence (driven by repro.checkpoint) ------------------------
    def state_tree(self) -> dict:
        """The checkpointable pytree: base index + every piece of delta
        and tombstone state (including the retained corpus and the list
        score planes that drive overflow eviction, so restored indexes
        mutate identically to never-saved ones)."""
        return {
            "base": self.base,
            "delta": {
                "cluster_entries": self._dc_entries,
                "cluster_scores": self._dc_scores,
                "cluster_lengths": self._dc_lengths,
                "term_entries": self._dt_entries,
                "term_scores": self._dt_scores,
                "term_lengths": self._dt_lengths,
                "planes": self._delta_planes,
                "assign": self._delta_assign,
                "emb": self._delta_emb,
                "tokens": self._delta_tokens,
            },
            "tombstones": self._tomb,
            "corpus": {"emb": self._corpus_emb,
                       "tokens": self._corpus_tokens},
            "key": jax.random.key_data(self.key),
        }

    def state_extra(self) -> dict:
        """JSON-able metadata stored next to :meth:`state_tree`."""
        return {"delta_count": self._count,
                "delta_capacity": self.delta_capacity,
                "delta_cluster_capacity": self.delta_cluster_capacity,
                "delta_term_capacity": self.delta_term_capacity,
                "vocab_size": self.vocab_size,
                "build_kwargs": self.build_kwargs,
                "dropped_postings": self.dropped_postings}

    @classmethod
    def from_state(cls, tree: dict, extra: dict) -> "MutableHybridIndex":
        """Rebuild a mutable index from a restored :meth:`state_tree`
        (leaves may be jnp arrays) + its :meth:`state_extra`."""
        m = extra["mutable"] if "mutable" in extra else extra
        out = cls(tree["base"], vocab_size=int(m["vocab_size"]),
                  key=jax.random.wrap_key_data(jnp.asarray(tree["key"])),
                  build_kwargs=dict(m["build_kwargs"]),
                  delta_capacity=int(m["delta_capacity"]),
                  delta_cluster_capacity=int(m["delta_cluster_capacity"]),
                  delta_term_capacity=int(m["delta_term_capacity"]),
                  corpus_emb=np.asarray(tree["corpus"]["emb"]),
                  corpus_tokens=np.asarray(tree["corpus"]["tokens"]))
        d = tree["delta"]
        # np.array (not asarray): restored leaves may be jnp arrays whose
        # numpy views are read-only, and all of this state is mutated
        out._dc_entries = np.array(d["cluster_entries"], np.int32)
        out._dc_scores = np.array(d["cluster_scores"], np.float32)
        out._dc_lengths = np.array(d["cluster_lengths"], np.int32)
        out._dt_entries = np.array(d["term_entries"], np.int32)
        out._dt_scores = np.array(d["term_scores"], np.float32)
        out._dt_lengths = np.array(d["term_lengths"], np.int32)
        out._delta_planes = {k: np.array(v) for k, v in d["planes"].items()}
        out._delta_assign = np.array(d["assign"], np.int32)
        out._delta_emb = np.array(d["emb"], np.float32)
        out._delta_tokens = np.array(d["tokens"], np.int32)
        out._tomb = np.array(tree["tombstones"], bool)
        out._count = int(m["delta_count"])
        out.dropped_postings = int(m.get("dropped_postings", 0))
        out._cache = None
        return out


# --------------------------------------------------------------------------
# document-sharded mutable search (DESIGN.md §6 + §8)
# --------------------------------------------------------------------------

def make_mutable_search_step(mesh, axis_name: str, codec: str, n_base: int,
                             per: int, dper: int, kc: int, k2: int,
                             top_r: int, use_kernel: bool = False):
    """shard_map'd base∪delta search + merge for one static config.

    Shard ``s`` owns base docs [s·per, (s+1)·per) *and* delta slots
    [s·dper, (s+1)·dper) (global ids ``n_base + slot``).  The body is
    the sharded §6 pipeline with a second (delta) candidate family and
    the tombstone mask applied before the local top-R′; the refine ctx
    routes the merged frontier through per-segment plane pairs exactly
    like the single-device mutable path, so results stay bit-identical.
    """
    codec_impl = codecs.get(codec)
    r_prime = codec_impl.refine_width(top_r)

    def body(shard, rep, qe, qt):
        shard = jax.tree.map(lambda x: x[0], shard)
        cluster_ids, _ = cs_mod.select_for_query(
            cs_mod.ClusterSelector(embeddings=rep["cluster_emb"]), qe, kc)
        term_ids = ts_mod.query_terms(
            ts_mod.TermSelector(avg_scores=rep["term_avg"]), qt, k2)

        def family(prefix):
            return jnp.concatenate(
                [il.gather_candidates(
                    PaddedLists(shard[f"{prefix}_cluster_entries"],
                                shard[f"{prefix}_cluster_lengths"]),
                    cluster_ids),
                 il.gather_candidates(
                     PaddedLists(shard[f"{prefix}_term_entries"],
                                 shard[f"{prefix}_term_lengths"]),
                     term_ids)], axis=-1)

        cand_b, cand_d = family("base"), family("delta")
        cands = jnp.concatenate([cand_b, cand_d], axis=-1)
        keep = il.dedup_mask(cands)

        s = jax.lax.axis_index(axis_name)
        b_lo, d_lo = s * per, s * dper
        local_b = jnp.clip(cand_b - b_lo, 0, per - 1)
        local_d = jnp.clip(cand_d - n_base - d_lo, 0, dper - 1)
        dead = jnp.concatenate(
            [shard["tomb_base"][local_b], shard["tomb_delta"][local_d]],
            axis=-1)
        live = keep & ~dead

        scorer_b = codec_impl.make_scorer(rep["codec"], shard["base_codec"],
                                          qe, use_kernel)
        scorer_d = codec_impl.make_scorer(rep["codec"], shard["delta_codec"],
                                          qe, use_kernel)
        scores = jnp.concatenate([scorer_b(local_b), scorer_d(local_d)],
                                 axis=-1)
        scores = jnp.where(live, scores, -jnp.inf)

        top_s, top_ids = hi.topk_by_score(scores, cands, r_prime)
        all_s, all_ids = collectives.gather_topk(top_s, top_ids, axis_name)
        fin_s, fin_ids = hi.topk_by_score(all_s, all_ids, r_prime)

        pair_planes = {k: (shard["base_codec"][k], shard["delta_codec"][k])
                       for k in shard["base_codec"]}

        def owned(ids):
            base_owned = ((ids >= b_lo) & (ids < b_lo + per)
                          & (ids < n_base))
            delta_owned = ((ids >= n_base + d_lo)
                           & (ids < n_base + d_lo + dper))
            return base_owned | delta_owned

        ctx = codecs.RefineCtx(
            gather=functools.partial(_pair_gather, n_base=n_base, b_lo=b_lo,
                                     b_size=per, d_lo=d_lo, d_size=dper),
            owned=owned,
            psum=lambda x: jax.lax.psum(x, axis_name))
        fin_s, fin_ids = codec_impl.refine(rep["codec"], pair_planes, qe,
                                           fin_s, fin_ids, top_r, ctx)
        n_cand = jax.lax.psum(live.sum(axis=-1).astype(jnp.int32), axis_name)
        valid = jnp.isfinite(fin_s)
        return (jnp.where(valid, fin_ids, PAD_DOC).astype(jnp.int32),
                jnp.where(valid, fin_s, 0.0),
                n_cand)

    from jax.sharding import PartitionSpec as P

    def specs_like(tree, leading):
        return jax.tree.map(
            lambda x: P(leading, *(None,) * (x.ndim - 1)) if leading
            else P(*(None,) * x.ndim), tree)

    qspec = P(None, None)

    def run(planes, rep, qe, qt):
        mapped = compat.shard_map(
            body, mesh=mesh,
            in_specs=(specs_like(planes, axis_name),
                      specs_like(rep, None), qspec, qspec),
            out_specs=(qspec, qspec, P(None)),
            check=False)  # outputs replicated by construction (§6 merge)
        return mapped(planes, rep, qe, qt)

    return run


@functools.lru_cache(maxsize=32)
def _compiled_mutable_search(mesh, axis_name, codec, n_base, per, dper,
                             kc, k2, top_r, use_kernel):
    return jax.jit(make_mutable_search_step(
        mesh, axis_name, codec, n_base, per, dper, kc, k2, top_r,
        use_kernel))


class ShardedMutableIndex:
    """Mutable HI² over the document-sharded layout of DESIGN.md §6.

    Wraps a :class:`MutableHybridIndex` (the host-side source of truth)
    and keeps a device-placed sharded view: the immutable base is
    partitioned once at construction; delta planes and tombstones are
    re-split after each mutation, which routes every added doc's
    postings and codec rows to the shard owning its global id.  Search
    is bit-identical to the single-device mutable search (asserted for
    every registered codec by ``tests/test_segments.py``).
    """

    def __init__(self, mut: MutableHybridIndex, n_shards: int, mesh=None,
                 axis_name: str = shi.SHARD_AXIS):
        self.mut = mut
        self.n_shards = int(n_shards)
        self.axis_name = axis_name
        self.mesh = mesh if mesh is not None else shi.make_shard_mesh(
            n_shards, axis_name)
        sbase = shi.partition(mut.base, n_shards)
        self._sbase = shi.device_put(sbase, self.mesh, axis_name)
        self.per = sbase.docs_per_shard
        self.dper = -(-mut.delta_capacity // n_shards)
        self._delta_state: Optional[dict] = None

    # --- mutation: delegate to the host index, re-split the delta --------
    def add_docs(self, doc_emb, doc_tokens) -> np.ndarray:
        ids = self.mut.add_docs(doc_emb, doc_tokens)
        self._delta_state = None
        return ids

    def delete_docs(self, doc_ids) -> None:
        self.mut.delete_docs(doc_ids)
        self._delta_state = None

    def compact(self, key: Optional[Array] = None) -> "ShardedMutableIndex":
        return type(self)(self.mut.compact(key), self.n_shards,
                          mesh=self.mesh, axis_name=self.axis_name)

    def owning_shard(self, doc_ids) -> np.ndarray:
        """Which shard serves each global doc id (base range split by
        ``per``, delta slots split by ``dper``)."""
        ids = np.asarray(doc_ids)
        n_base = self.mut.n_base
        return np.where(ids < n_base, ids // self.per,
                        (ids - n_base) // self.dper)

    # --- device state ----------------------------------------------------
    def _split_delta(self) -> dict:
        mut, n_base = self.mut, self.mut.n_base
        s, dper = self.n_shards, self.dper
        dc_e, dc_l = shi._split_lists(mut._dc_entries, s, dper, base=n_base)
        dt_e, dt_l = shi._split_lists(mut._dt_entries, s, dper, base=n_base)
        tomb = mut._tomb
        return {
            "delta_cluster_entries": jnp.asarray(dc_e),
            "delta_cluster_lengths": jnp.asarray(dc_l),
            "delta_term_entries": jnp.asarray(dt_e),
            "delta_term_lengths": jnp.asarray(dt_l),
            "delta_codec": {
                k: jnp.asarray(shi._split_docs(v, s, dper))
                for k, v in mut._delta_planes.items()},
            "tomb_base": jnp.asarray(
                shi._split_docs(tomb[:n_base], s, self.per)),
            "tomb_delta": jnp.asarray(
                shi._split_docs(tomb[n_base:], s, dper)),
        }

    def _planes(self) -> dict:
        if self._delta_state is None:
            from jax.sharding import NamedSharding, PartitionSpec as P

            def put(x):
                return jax.device_put(x, NamedSharding(
                    self.mesh,
                    P(self.axis_name, *(None,) * (x.ndim - 1))))

            self._delta_state = jax.tree.map(put, self._split_delta())
        sb = self._sbase
        return {
            "base_cluster_entries": sb.cluster_entries,
            "base_cluster_lengths": sb.cluster_lengths,
            "base_term_entries": sb.term_entries,
            "base_term_lengths": sb.term_lengths,
            "base_codec": sb.doc_planes,
            **self._delta_state,
        }

    def search(self, query_embeddings, query_tokens, *, kc: int, k2: int,
               top_r: int, use_kernel: bool = False) -> hi.SearchResult:
        rep = {"cluster_emb": self._sbase.cluster_sel.embeddings,
               "term_avg": self._sbase.term_sel.avg_scores,
               "codec": self._sbase.codec_params}
        fn = _compiled_mutable_search(
            self.mesh, self.axis_name, self.mut.base.codec, self.mut.n_base,
            self.per, self.dper, kc, k2, top_r, use_kernel)
        ids, scores, n_cand = fn(self._planes(), rep,
                                 jnp.asarray(query_embeddings),
                                 jnp.asarray(query_tokens))
        return hi.SearchResult(doc_ids=ids, scores=scores,
                               n_candidates=n_cand)
