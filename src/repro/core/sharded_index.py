"""Document-sharded HI² — the index-parallel serving path (DESIGN.md §6).

A single-device :class:`~repro.core.hybrid_index.HybridIndex` caps the
corpus at one device's HBM.  This module splits the *documents* (and
with them the codec doc planes, the namespace plane and the
inverted-list entries) over a device mesh and runs the SAME staged
query-execution engine as every other variant
(:mod:`repro.core.exec`, DESIGN.md §9) per shard under ``shard_map``:

    shard s owns the contiguous doc range [s·P, (s+1)·P)

    replicated per device : cluster/term selectors, codec params, queries
    sharded (leading axis) : every codec doc plane, ``doc_ns``, the
                             list entry planes filtered to the shard's
                             docs, and (for sparse-built indexes) the
                             BM25 impact plane split by the same
                             permutation

    per shard : dispatch → gather → dedup → filter → score → local top-R′
    merge     : all-gather of the (B, R′) planes along the shard axis +
                one more total-order top-R′ (inside ``exec.topk``)
    refine    : the codec's second stage on the merged frontier — each
                shard exact-scores the frontier docs it owns, a psum
                assembles them (identity for non-refining codecs)

The codec is resolved through :mod:`repro.core.codecs` (DESIGN.md §7):
this module never inspects codec names — the codec's ``partition`` hook
splits its doc planes and the exec layer routes scoring/refine through
the per-shard :class:`~repro.core.exec.Source`.

The partition happens AFTER global list construction (including
capacity truncation), so the union of the per-shard lists is exactly
the single-device lists — no doc is scored on the sharded path that the
single-device path would have truncated away, and vice versa.  Because
each doc lives in exactly one shard, per-shard dedup is global dedup,
and because top-R selection uses the total order of
:func:`~repro.core.exec.topk_by_score` (score desc, id asc) — and any
refine stage re-ranks the already-merged frontier — the merged result
is **bit-identical** to single-device ``search()`` for every registered
codec, with and without a namespace filter (asserted by
``tests/test_exec.py``).

Per-shard planes keep the *global* list capacity, so the per-shard
candidate budget equals the single-device budget; the win is HBM (each
device holds 1/S of the codec planes) and throughput (S devices
gather+score concurrently), not per-shard budget.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import cluster_selector as cs_mod
from repro.core import codecs
from repro.core import exec as qexec
from repro.core import hybrid_index as hi
from repro.core import term_selector as ts_mod
from repro.core.inverted_lists import PAD_DOC, PaddedLists
from repro.distributed import compat

Array = jax.Array

SHARD_AXIS = "shards"


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["cluster_sel", "term_sel", "cluster_entries",
                 "cluster_lengths", "term_entries", "term_lengths",
                 "codec_params", "doc_planes", "doc_assign", "doc_ns",
                 "sparse_weights"],
    meta_fields=["codec", "n_docs"])
@dataclasses.dataclass(frozen=True)
class ShardedHybridIndex:
    """HI² with every document-indexed plane carrying a leading shard
    axis (S, ...).  Selector/codec-param state is replicated."""
    cluster_sel: cs_mod.ClusterSelector     # replicated
    term_sel: ts_mod.TermSelector           # replicated
    cluster_entries: Array                  # (S, L, Cc) i32, global doc ids
    cluster_lengths: Array                  # (S, L) i32
    term_entries: Array                     # (S, V, Ct) i32
    term_lengths: Array                     # (S, V) i32
    codec_params: Any                       # replicated codec state
    doc_planes: dict                        # codec planes, leaves (S, P, ...)
    doc_assign: Array                       # (S, P) i32, φ(D) per shard
    doc_ns: Optional[Array] = None          # (S, P) i32 namespace ids
    sparse_weights: Optional[Array] = None  # (S, V, Ct) f32 BM25 impacts
    #                                         aligned with term_entries
    codec: str = codecs.DEFAULT
    n_docs: int = 0                         # true corpus size (pre-padding)

    @property
    def n_shards(self) -> int:
        return self.cluster_entries.shape[0]

    @property
    def docs_per_shard(self) -> int:
        return self.doc_assign.shape[1]

    # convenience views matching HybridIndex (None when absent)
    @property
    def doc_codes(self) -> Optional[Array]:
        return self.doc_planes.get("codes")

    @property
    def doc_embeddings(self) -> Optional[Array]:
        return self.doc_planes.get("emb")


# --------------------------------------------------------------------------
# partition (host-side, build-time)
# --------------------------------------------------------------------------

def _split_lists(entries: Array, n_shards: int, per: int, base: int = 0,
                 weights: Optional[Array] = None):
    """Filter a global (L, C) entries plane into per-shard planes.

    Keeps the global capacity C per shard and left-packs each row, so
    the union over shards is exactly the global plane (order within a
    list is preserved — which the sparse path relies on: impact order
    survives the split, so per-shard BM25 sums are the same in-order
    float additions as single-device).  Shard ``s`` owns ids in
    [base + s·per, base + (s+1)·per) — ``base`` is 0 for the doc planes
    and ``n_base`` when splitting a delta segment's global ids over its
    slot ranges (repro.core.segments).

    With ``weights`` (an aligned (L, C) impact plane,
    :func:`repro.core.inverted_lists.build_scored`) the same
    permutation splits it too (0.0 beyond each shard's count) and a
    third plane is returned.
    """
    e = np.asarray(entries)
    n_lists, cap = e.shape
    out = np.full((n_shards, n_lists, cap), PAD_DOC, np.int32)
    lengths = np.zeros((n_shards, n_lists), np.int32)
    w = None if weights is None else np.asarray(weights)
    w_out = (None if w is None else
             np.zeros((n_shards, n_lists, cap), np.float32))
    cols = np.arange(cap)[None, :]
    for s in range(n_shards):
        mine = (e >= base + s * per) & (e < base + (s + 1) * per)
        order = np.argsort(~mine, axis=1, kind="stable")   # left-pack
        packed = np.take_along_axis(e, order, axis=1)
        count = mine.sum(axis=1)
        out[s] = np.where(cols < count[:, None], packed, PAD_DOC)
        lengths[s] = count
        if w is not None:
            packed_w = np.take_along_axis(w, order, axis=1)
            w_out[s] = np.where(cols < count[:, None], packed_w, 0.0)
    if w is None:
        return out, lengths
    return out, lengths, w_out


def _split_docs(plane: Array, n_shards: int, per: int) -> np.ndarray:
    """(n_docs, ...) -> (S, P, ...) with zero-padded tail rows (padded
    rows are unreachable: no list entry ever points at them)."""
    x = np.asarray(plane)
    pad = n_shards * per - x.shape[0]
    x = np.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1))
    return x.reshape((n_shards, per) + x.shape[1:])


def partition(index: hi.HybridIndex, n_shards: int) -> ShardedHybridIndex:
    """Split a built single-device index into ``n_shards`` contiguous
    document ranges.  Pure host-side numpy; run once at build time."""
    assert n_shards >= 1
    codec_impl = codecs.get(index.codec)
    n_docs = index.n_docs
    per = -(-n_docs // n_shards)    # ceil
    c_entries, c_lengths = _split_lists(index.cluster_lists.entries,
                                        n_shards, per)
    s_weights = None
    if index.sparse_weights is None:
        t_entries, t_lengths = _split_lists(index.term_lists.entries,
                                            n_shards, per)
    else:
        t_entries, t_lengths, s_weights = _split_lists(
            index.term_lists.entries, n_shards, per,
            weights=index.sparse_weights)
    return ShardedHybridIndex(
        cluster_sel=index.cluster_sel,
        term_sel=index.term_sel,
        cluster_entries=jnp.asarray(c_entries),
        cluster_lengths=jnp.asarray(c_lengths),
        term_entries=jnp.asarray(t_entries),
        term_lengths=jnp.asarray(t_lengths),
        codec_params=codec_impl.replicate(index.codec_params),
        doc_planes=codec_impl.partition(
            index.doc_planes,
            lambda x: jnp.asarray(_split_docs(x, n_shards, per))),
        doc_assign=jnp.asarray(_split_docs(index.doc_assign, n_shards, per)),
        doc_ns=(None if index.doc_ns is None else
                jnp.asarray(_split_docs(index.doc_ns, n_shards, per))),
        sparse_weights=(None if s_weights is None else
                        jnp.asarray(s_weights)),
        codec=index.codec,
        n_docs=n_docs)


# --------------------------------------------------------------------------
# placement
# --------------------------------------------------------------------------

def make_shard_mesh(n_shards: int, axis_name: str = SHARD_AXIS) -> Mesh:
    """1-D serving mesh over the first ``n_shards`` local devices.

    On CPU, emulate devices with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.
    """
    devs = jax.devices()
    if len(devs) < n_shards:
        raise RuntimeError(
            f"need {n_shards} devices for {n_shards} shards, have "
            f"{len(devs)}; on CPU set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n_shards}")
    return compat.make_mesh((n_shards,), (axis_name,),
                            devices=devs[:n_shards])


def device_put(sindex: ShardedHybridIndex, mesh: Mesh,
               axis_name: str = SHARD_AXIS) -> ShardedHybridIndex:
    """Place each shard's planes on its device (1/S of the doc-plane
    bytes per device — the HBM win), selectors/codec params replicated."""
    def put_sharded(x):
        return (None if x is None else jax.device_put(
            x, NamedSharding(mesh, P(axis_name, *(None,) * (x.ndim - 1)))))

    def put_rep(t):
        return jax.tree.map(
            lambda x: jax.device_put(x, NamedSharding(mesh, P())), t)

    return dataclasses.replace(
        sindex,
        cluster_sel=put_rep(sindex.cluster_sel),
        term_sel=put_rep(sindex.term_sel),
        codec_params=put_rep(sindex.codec_params),
        cluster_entries=put_sharded(sindex.cluster_entries),
        cluster_lengths=put_sharded(sindex.cluster_lengths),
        term_entries=put_sharded(sindex.term_entries),
        term_lengths=put_sharded(sindex.term_lengths),
        doc_planes=jax.tree.map(put_sharded, sindex.doc_planes),
        doc_assign=put_sharded(sindex.doc_assign),
        doc_ns=put_sharded(sindex.doc_ns),
        sparse_weights=put_sharded(sindex.sparse_weights))


# --------------------------------------------------------------------------
# search
# --------------------------------------------------------------------------

def _shard_planes(sindex: ShardedHybridIndex) -> dict:
    planes = {"cluster_entries": sindex.cluster_entries,
              "cluster_lengths": sindex.cluster_lengths,
              "term_entries": sindex.term_entries,
              "term_lengths": sindex.term_lengths,
              "codec": sindex.doc_planes}
    if sindex.doc_ns is not None:
        planes["doc_ns"] = sindex.doc_ns
    if sindex.sparse_weights is not None:
        planes["sparse_weights"] = sindex.sparse_weights
    return planes


def make_search_step(mesh: Mesh, axis_name: str, codec: str, per: int,
                     kc: int, k2: int, top_r: int,
                     use_kernel: bool = False,
                     batch_axis: Optional[str] = None,
                     filtered: bool = False,
                     fusion: Optional[qexec.FusionSpec] = None):
    """shard_map'd per-shard search + merge for one static config.

    Returns ``step(planes, rep, qe, qt) -> (doc_ids, scores, n_cands)``
    — or, with ``filtered=True``, ``step(planes, rep, qe, qt,
    ns_filter)`` where ``ns_filter`` is the replicated (B, W) uint32
    per-query namespace bitmap and ``planes`` must carry ``doc_ns``.
    The step is un-jitted, so ``launch/cells.py`` can lower it with
    explicit in_shardings.  ``planes`` carries the shard-leading arrays
    with the codec doc planes nested under ``"codec"``; ``rep`` the
    replicated selector state with the codec params under ``"codec"``.
    ``batch_axis`` optionally data-shards the query batch over a second
    mesh axis (the production (data, model) layout: queries over data,
    index shards over model); None replicates queries, which is the 1-D
    serving-mesh case.

    The body is nothing but the §9 stage chain over one per-shard
    :class:`~repro.core.exec.Source` with a
    :class:`~repro.core.exec.ShardEnv` — the same engine as the
    single-device path, so results are bit-identical by construction.
    """
    codec_impl = codecs.get(codec)

    def body(shard, rep, qe, qt, ns_filter=None):
        # shard_map hands this device's block with a leading length-1
        # shard axis; drop it to get the local planes
        shard = jax.tree.map(lambda x: x[0], shard)
        # an explicit per-shard "offsets" plane overrides the contiguous
        # axis_index * per layout — the survivor-set serving path
        # (DESIGN.md §12) keeps global doc ids stable when shard m is
        # ejected and position i no longer owns range [i·per, (i+1)·per)
        offset = shard.get("offsets")
        if offset is None:
            offset = jax.lax.axis_index(axis_name) * per
        source = qexec.Source(
            cluster_lists=PaddedLists(shard["cluster_entries"],
                                      shard["cluster_lengths"]),
            term_lists=PaddedLists(shard["term_entries"],
                                   shard["term_lengths"]),
            doc_planes=shard["codec"],
            size=per,
            offset=offset,
            doc_ns=shard.get("doc_ns"),
            sparse_weights=shard.get("sparse_weights"))
        res = qexec.execute(
            codec_impl, rep["codec"],
            cs_mod.ClusterSelector(embeddings=rep["cluster_emb"]),
            ts_mod.TermSelector(avg_scores=rep["term_avg"]),
            [source], qe, qt,
            kc=kc, k2=k2, top_r=top_r, use_kernel=use_kernel,
            ns_filter=ns_filter, shard=qexec.ShardEnv(axis_name),
            fusion=fusion)
        return res.doc_ids, res.scores, res.n_candidates

    def specs_like(tree, leading):
        return jax.tree.map(
            lambda x: P(leading, *(None,) * (x.ndim - 1)) if leading
            else P(*(None,) * x.ndim), tree)

    qspec = P(batch_axis, None)

    def run(planes, rep, qe, qt, ns_filter=None):
        in_specs = [specs_like(planes, axis_name), specs_like(rep, None),
                    qspec, qspec]
        args = [planes, rep, qe, qt]
        if filtered:
            in_specs.append(qspec)       # bitmap rides with the queries
            args.append(ns_filter)
        mapped = compat.shard_map(
            body, mesh=mesh,
            in_specs=tuple(in_specs),
            out_specs=(qspec, qspec, P(batch_axis)),
            check=False)  # outputs are replicated over the shard axis by
        #                   construction (merge ends in identical
        #                   all-gathered data on every shard)
        return mapped(*args)

    return run


@functools.lru_cache(maxsize=32)
def _compiled_search(mesh: Mesh, axis_name: str, codec: str, per: int,
                     kc: int, k2: int, top_r: int, use_kernel: bool,
                     filtered: bool, batch_axis: Optional[str] = None,
                     fusion: Optional[qexec.FusionSpec] = None):
    return jax.jit(make_search_step(mesh, axis_name, codec, per,
                                    kc, k2, top_r, use_kernel,
                                    batch_axis=batch_axis,
                                    filtered=filtered, fusion=fusion))


def take_shards(sindex: ShardedHybridIndex,
                shard_ids) -> ShardedHybridIndex:
    """The survivor view: a smaller sharded index holding only the
    given shards' planes (DESIGN.md §12).

    Global doc ids are preserved — list entries still name the original
    corpus positions — but the surviving shards no longer sit at their
    original mesh positions, so searches over the view must pass
    :func:`search` the matching ``shard_offsets`` (``shard_ids · per``);
    without it, shard position i would be misattributed range
    [i·per, (i+1)·per).
    """
    sel = np.asarray(sorted(int(s) for s in shard_ids))
    if sel.size == 0:
        raise ValueError("take_shards needs at least one surviving shard")
    if sel.min() < 0 or sel.max() >= sindex.n_shards:
        raise ValueError(f"shard ids {sel.tolist()} out of range "
                         f"[0, {sindex.n_shards})")
    take = lambda x: None if x is None else x[jnp.asarray(sel)]  # noqa: E731
    return dataclasses.replace(
        sindex,
        cluster_entries=take(sindex.cluster_entries),
        cluster_lengths=take(sindex.cluster_lengths),
        term_entries=take(sindex.term_entries),
        term_lengths=take(sindex.term_lengths),
        doc_planes=jax.tree.map(take, sindex.doc_planes),
        doc_assign=take(sindex.doc_assign),
        doc_ns=take(sindex.doc_ns),
        sparse_weights=take(sindex.sparse_weights))


def shard_offsets_for(shard_ids, per: int) -> np.ndarray:
    """The explicit offsets plane matching :func:`take_shards`."""
    return np.asarray(sorted(int(s) for s in shard_ids),
                      np.int32) * np.int32(per)


def search(sindex: ShardedHybridIndex, query_embeddings: Array,
           query_tokens: Array, *, kc: int, k2: int, top_r: int,
           mesh: Optional[Mesh] = None, axis_name: str = SHARD_AXIS,
           use_kernel: bool = False,
           filter: Optional[Array] = None,
           data_axis: Optional[str] = None,
           shard_offsets: Optional[Array] = None,
           fusion: Optional[qexec.FusionSpec] = None) -> hi.SearchResult:
    """Sharded Eq. 5 — same contract and bit-identical results as
    :func:`repro.core.hybrid_index.search` (DESIGN.md §6), including
    under a per-query namespace ``filter`` (DESIGN.md §9) and under
    hybrid ``fusion`` (DESIGN.md §13; needs an index partitioned from
    one built with ``sparse=True`` — otherwise the dense-only fallback
    applies, exactly as single-device).

    ``mesh`` defaults to a fresh 1-D mesh over the first ``n_shards``
    devices; pass the mesh from :func:`make_shard_mesh` (after
    :func:`device_put`) to reuse placement across calls.

    ``data_axis`` names a second mesh axis to partition the query batch
    over — the 2-D (data, model) serving layout of DESIGN.md §12: the
    index planes replicate along it, each data slice searches its rows
    independently, and the batch size must divide by its length.
    ``shard_offsets`` ((S,) i32) overrides the contiguous s·per doc-id
    layout for survivor views (:func:`take_shards`).
    """
    if mesh is None:
        mesh = make_shard_mesh(sindex.n_shards, axis_name)
    if mesh.shape[axis_name] != sindex.n_shards:
        # a smaller axis would silently drop shards (each device keeps
        # only block [0] of its slice) — corrupt results, so hard-fail
        raise ValueError(
            f"mesh axis {axis_name!r} has size {mesh.shape[axis_name]} "
            f"but the index has {sindex.n_shards} shards")
    if data_axis is not None:
        if data_axis not in mesh.shape:
            raise ValueError(f"mesh has no axis {data_axis!r} "
                             f"(axes: {tuple(mesh.shape)})")
        d = mesh.shape[data_axis]
        if query_embeddings.shape[0] % d:
            raise ValueError(
                f"batch {query_embeddings.shape[0]} does not divide over "
                f"{d} data-axis slices; pad to a multiple of {d}")
    if filter is not None and sindex.doc_ns is None:
        raise ValueError(
            "search(filter=...) needs an index partitioned from one "
            "built with doc_namespaces=")
    rep = {"cluster_emb": sindex.cluster_sel.embeddings,
           "term_avg": sindex.term_sel.avg_scores,
           "codec": sindex.codec_params}
    fn = _compiled_search(mesh, axis_name, sindex.codec,
                          sindex.docs_per_shard, kc, k2, top_r, use_kernel,
                          filter is not None, data_axis, fusion)
    planes = _shard_planes(sindex)
    if shard_offsets is not None:
        off = jnp.asarray(shard_offsets, jnp.int32)
        if off.shape != (sindex.n_shards,):
            raise ValueError(f"shard_offsets shape {off.shape} != "
                             f"({sindex.n_shards},)")
        planes["offsets"] = off
    args = (planes, rep, query_embeddings, query_tokens)
    if filter is not None:
        args += (jnp.asarray(filter, jnp.uint32),)
    ids, scores, n_cand = fn(*args)
    return hi.SearchResult(doc_ids=ids, scores=scores, n_candidates=n_cand)


def candidate_budget(sindex: ShardedHybridIndex, kc: int, k2: int) -> int:
    """Per-shard candidate slots per query (the latency proxy; equals
    the single-device budget because shards keep the global capacity)."""
    return qexec.candidate_budget(
        kc, k2, [(sindex.cluster_entries.shape[2],
                  sindex.term_entries.shape[2])])
