"""Term selector (paper §4.2, Eq. 7–8).

Indexing side: pick the top-K₁ᵀ salient terms of each document.
Search side:   dispatch the query to ≤ K₂ᵀ of its own terms using only the
               stored corpus-average term scores s̄ — no model runs on the
               query path (the paper's efficiency requirement).

Two scoring backends share every function below through a per-position
score tensor:

  · HI²_unsup — BM25 position scores (:mod:`repro.core.bm25`);
  · HI²_sup   — a two-layer ReLU MLP f: R^h → R over encoder token states
                (Eq. 7 middle branch), with max-pooling over repeated
                terms handled by the shared score_vector/top_terms paths.

The encoder itself lives in :mod:`repro.models.transformer`; training
wires ``encoder → hidden states → mlp_token_scores`` (see
``repro/core/distill.py`` and ``examples/train_hi2_distill.py``).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import bm25
from repro.core.bm25 import PAD_ID

Array = jax.Array


class TermMLP(NamedTuple):
    """f(·) in Eq. 7: two-layer MLP with ReLU, R^h → R."""
    w1: Array  # (h, h)
    b1: Array  # (h,)
    w2: Array  # (h, 1)
    b2: Array  # (1,)


def init_mlp(key: Array, hidden: int) -> TermMLP:
    k1, k2 = jax.random.split(key)
    s = 1.0 / jnp.sqrt(hidden)
    return TermMLP(
        w1=jax.random.normal(k1, (hidden, hidden), jnp.float32) * s,
        b1=jnp.zeros((hidden,), jnp.float32),
        w2=jax.random.normal(k2, (hidden, 1), jnp.float32) * s,
        b2=jnp.zeros((1,), jnp.float32),
    )


def mlp_token_scores(mlp: TermMLP, hidden_states: Array, tokens: Array) -> Array:
    """Per-position saliency from encoder states: (B, L, h) -> (B, L).

    Softplus keeps scores positive (BM25-comparable saliency scale);
    pads score 0.
    """
    x = jax.nn.relu(hidden_states @ mlp.w1 + mlp.b1)
    s = (x @ mlp.w2 + mlp.b2)[..., 0]
    s = jax.nn.softplus(s)
    return s * (tokens != PAD_ID)


class TermSelector(NamedTuple):
    """Search-time state shared by both variants (model-free query path)."""
    avg_scores: Array  # s̄_v, (V,) f32


@functools.partial(jax.jit, static_argnames=("k1",))
def doc_terms(tokens: Array, position_scores: Array, k1: int
              ) -> tuple[Array, Array]:
    """Indexing side: top-K₁ᵀ unique terms per document (+ their scores)."""
    return bm25.top_terms(tokens, position_scores, k1)


@functools.partial(jax.jit, static_argnames=("k2",))
def query_terms(selector: TermSelector, query_tokens: Array, k2: int) -> Array:
    """Search side (Eq. 8), fixed-shape for both branches.

    Unique query terms ranked by stored s̄; top-k of ≤ k2 valid terms
    *is* "select all terms" for short queries, so one path covers both.
    Returns (B, k2) term ids with PAD_ID fill.
    """
    first = bm25.first_occurrence_mask(query_tokens)
    sbar = selector.avg_scores[jnp.clip(query_tokens, 0, None)]
    masked = jnp.where(first, sbar, -jnp.inf)
    k_eff = min(k2, query_tokens.shape[-1])   # queries shorter than K₂ᵀ
    top_s, top_i = jax.lax.top_k(masked, k_eff)
    ids = jnp.take_along_axis(query_tokens, top_i, axis=-1)
    ids = jnp.where(jnp.isfinite(top_s), ids, PAD_ID).astype(jnp.int32)
    if k_eff < k2:
        ids = jnp.pad(ids, ((0, 0), (0, k2 - k_eff)),
                      constant_values=PAD_ID)
    return ids


@functools.partial(jax.jit, static_argnames=("vocab_size",))
def score_vectors(tokens: Array, position_scores: Array, vocab_size: int
                  ) -> Array:
    """s_D / s_Q over the vocabulary (Eq. 12), max-pooled over repeats."""
    return bm25.score_vector(tokens, position_scores, vocab_size)


def fit_unsup(tokens: Array, vocab_size: int, alpha: float = 0.82,
              beta: float = 0.68) -> tuple[TermSelector, Array, bm25.BM25Stats]:
    """HI²_unsup: BM25 stats + s̄ from the corpus.

    Returns (selector, per-position corpus scores (n, L), stats).
    """
    stats = bm25.fit(tokens, vocab_size)
    pos_scores = bm25.score_positions(tokens, stats, alpha=alpha, beta=beta)
    sbar = bm25.average_term_scores(tokens, pos_scores, vocab_size)
    return TermSelector(avg_scores=sbar), pos_scores, stats
