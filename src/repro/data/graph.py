"""Graph data: synthetic generators + the fanout neighbor sampler
(required substrate for the ``minibatch_lg`` cell).

All outputs are fixed-shape padded ``GraphBatch``es (PAD edges point at a
sink node with edge_mask=0) so every downstream step is jit-stable.
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.models.gnn import GraphBatch


def random_graph(seed: int, n_nodes: int, n_edges: int, d_feat: int,
                 n_classes: int, n_communities: int = 16) -> GraphBatch:
    """Community-structured random graph (labels correlate with features)."""
    rng = np.random.default_rng(seed)
    comm = rng.integers(0, n_communities, n_nodes)
    # 70% intra-community edges, 30% random
    n_intra = int(n_edges * 0.7)
    src = rng.integers(0, n_nodes, n_edges).astype(np.int32)
    dst = np.empty(n_edges, np.int32)
    # intra: rewire dst to a node of the same community (approx via sort buckets)
    order = np.argsort(comm, kind="stable")
    starts = np.searchsorted(comm[order], np.arange(n_communities + 1))
    for i in range(n_intra):
        c = comm[src[i]]
        lo, hi = starts[c], starts[c + 1]
        dst[i] = order[rng.integers(lo, hi)] if hi > lo else src[i]
    dst[n_intra:] = rng.integers(0, n_nodes, n_edges - n_intra)

    centers = rng.normal(size=(n_communities, d_feat)).astype(np.float32)
    feat = centers[comm] + rng.normal(size=(n_nodes, d_feat)).astype(np.float32)
    labels = (comm % n_classes).astype(np.int32)
    return GraphBatch(
        node_feat=jnp.asarray(feat), edge_src=jnp.asarray(src),
        edge_dst=jnp.asarray(dst),
        edge_mask=jnp.ones((n_edges,), jnp.float32),
        node_mask=jnp.ones((n_nodes,), jnp.float32),
        labels=jnp.asarray(labels), graph_id=jnp.zeros((n_nodes,), jnp.int32),
        n_graphs=1)


class NeighborSampler:
    """GraphSAGE-style fanout sampling over a CSR adjacency (host-side)."""

    def __init__(self, n_nodes: int, edge_src: np.ndarray,
                 edge_dst: np.ndarray):
        self.n_nodes = n_nodes
        order = np.argsort(edge_dst, kind="stable")
        self.sorted_src = np.asarray(edge_src)[order]
        self.indptr = np.searchsorted(np.asarray(edge_dst)[order],
                                      np.arange(n_nodes + 1))

    def sample(self, seed: int, seeds: np.ndarray, fanouts: tuple[int, ...],
               node_feat: np.ndarray, labels: np.ndarray) -> GraphBatch:
        """Returns the padded union subgraph of ``seeds`` + sampled hops.

        Fixed shapes: n_sub = Σ_l seeds·Π fanouts[:l];
        edges point child→parent (messages flow to the seeds).
        """
        rng = np.random.default_rng(seed)
        frontier = np.asarray(seeds, np.int64)
        all_nodes = [frontier]
        src_list, dst_list, mask_list = [], [], []
        offset = 0
        for f in fanouts:
            deg = self.indptr[frontier + 1] - self.indptr[frontier]
            picks = rng.integers(0, np.maximum(deg, 1)[:, None],
                                 size=(len(frontier), f))
            nbr = self.sorted_src[self.indptr[frontier][:, None] + picks]
            valid = (deg > 0)[:, None] & np.ones_like(picks, bool)
            parent_pos = offset + np.arange(len(frontier))
            child_pos = offset + len(frontier) + np.arange(nbr.size)
            src_list.append(child_pos.astype(np.int32))
            dst_list.append(np.repeat(parent_pos, f).astype(np.int32))
            mask_list.append(valid.reshape(-1).astype(np.float32))
            offset += len(frontier)
            frontier = nbr.reshape(-1)
            all_nodes.append(frontier)

        nodes = np.concatenate(all_nodes)
        src = np.concatenate(src_list)
        dst = np.concatenate(dst_list)
        mask = np.concatenate(mask_list)
        labels_out = np.full(len(nodes), -1, np.int32)
        labels_out[:len(seeds)] = np.asarray(labels)[seeds]
        node_mask = np.zeros(len(nodes), np.float32)
        node_mask[:len(seeds)] = 1.0          # loss only on the seed nodes
        return GraphBatch(
            node_feat=jnp.asarray(node_feat[nodes].astype(np.float32)),
            edge_src=jnp.asarray(src), edge_dst=jnp.asarray(dst),
            edge_mask=jnp.asarray(mask),
            node_mask=jnp.asarray(node_mask),
            labels=jnp.asarray(labels_out),
            graph_id=jnp.zeros(len(nodes), jnp.int32), n_graphs=1)


def molecule_batch(seed: int, batch: int, n_nodes: int, n_edges: int,
                   d_feat: int, n_classes: int) -> GraphBatch:
    """Disjoint union of ``batch`` small graphs (the ``molecule`` cell)."""
    rng = np.random.default_rng(seed)
    total_n = batch * n_nodes
    total_e = batch * n_edges
    offs = np.repeat(np.arange(batch) * n_nodes, n_edges)
    src = rng.integers(0, n_nodes, total_e) + offs
    dst = rng.integers(0, n_nodes, total_e) + offs
    feat = rng.normal(size=(total_n, d_feat)).astype(np.float32)
    labels = rng.integers(0, n_classes, batch).astype(np.int32)
    return GraphBatch(
        node_feat=jnp.asarray(feat),
        edge_src=jnp.asarray(src.astype(np.int32)),
        edge_dst=jnp.asarray(dst.astype(np.int32)),
        edge_mask=jnp.ones((total_e,), jnp.float32),
        node_mask=jnp.ones((total_n,), jnp.float32),
        labels=jnp.asarray(labels),
        graph_id=jnp.asarray(np.repeat(np.arange(batch), n_nodes)
                             .astype(np.int32)),
        n_graphs=batch)


def partition_by_dst(batch: GraphBatch, n_shards: int) -> GraphBatch:
    """Owner-computes range partitioning (gnn.forward_partitioned input
    contract): nodes padded to a multiple of n_shards; edges reordered so
    shard s holds exactly E/n_shards edges whose dst ∈ s's node range
    (PAD edges fill the slack; real edges never drop)."""
    import numpy as np
    src = np.asarray(batch.edge_src)
    dst = np.asarray(batch.edge_dst)
    mask = np.asarray(batch.edge_mask)
    feat = np.asarray(batch.node_feat)
    nmask = np.asarray(batch.node_mask)
    labels = np.asarray(batch.labels)

    n_nodes = feat.shape[0]
    n_pad_nodes = -n_nodes % n_shards
    if n_pad_nodes:
        feat = np.pad(feat, ((0, n_pad_nodes), (0, 0)))
        nmask = np.pad(nmask, (0, n_pad_nodes))
        labels = np.pad(labels, (0, n_pad_nodes), constant_values=-1)
    n_total = n_nodes + n_pad_nodes
    n_local = n_total // n_shards

    owner = dst // n_local
    counts = np.bincount(owner[mask > 0], minlength=n_shards)
    e_local = int(counts.max(initial=1))
    src_out = np.zeros((n_shards, e_local), np.int32)
    dst_out = np.tile((np.arange(n_shards) * n_local)[:, None],
                      (1, e_local)).astype(np.int32)   # PAD → own range
    mask_out = np.zeros((n_shards, e_local), np.float32)
    for s in range(n_shards):
        sel = (owner == s) & (mask > 0)
        k = sel.sum()
        src_out[s, :k] = src[sel]
        dst_out[s, :k] = dst[sel]
        mask_out[s, :k] = 1.0
    return GraphBatch(
        node_feat=jnp.asarray(feat),
        edge_src=jnp.asarray(src_out.reshape(-1)),
        edge_dst=jnp.asarray(dst_out.reshape(-1)),
        edge_mask=jnp.asarray(mask_out.reshape(-1)),
        node_mask=jnp.asarray(nmask),
        labels=jnp.asarray(labels),
        graph_id=jnp.zeros(n_total, jnp.int32),
        n_graphs=batch.n_graphs)
