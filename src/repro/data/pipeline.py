"""Sharded host→device data pipeline.

Batches are numpy pytrees; ``shard_batch`` places them under the active
mesh with the batch axis split over ("pod","data") — the producer side of
the data-parallel axes.  ``Dataloader`` adds deterministic seeding,
epoch iteration, and host-subset resharding (the fault-tolerance hook:
after a host ejection the loader recomputes its shard bounds from the
surviving host list — see distributed/fault.reshard_bounds)."""
from __future__ import annotations

from typing import Any, Callable, Iterator, Optional

import jax
import numpy as np

from repro.distributed import fault
from repro.distributed.sharding import named_sharding

PyTree = Any


def shard_batch(batch: PyTree, batch_axis: str = "batch") -> PyTree:
    """device_put a host batch with the leading axis data-sharded."""
    def one(x):
        sh = named_sharding(batch_axis, *([None] * (np.ndim(x) - 1)))
        return jax.device_put(x, sh) if sh is not None else jax.numpy.asarray(x)
    return jax.tree.map(one, batch)


class Dataloader:
    """Deterministic, reshardable loader over a synthetic batch factory.

    ``factory(seed, batch_size) -> pytree``; every global step consumes
    one seed so runs are reproducible across restarts (the crash/restart
    drill relies on this).
    """

    def __init__(self, factory: Callable[[int, int], PyTree],
                 global_batch: int, seed: int = 0,
                 host_id: int = 0, healthy_hosts: Optional[list[int]] = None):
        self.factory = factory
        self.global_batch = global_batch
        self.seed = seed
        self.host_id = host_id
        self.healthy_hosts = healthy_hosts or [0]

    def local_batch_size(self) -> int:
        bounds = fault.reshard_bounds(self.global_batch, self.healthy_hosts)
        lo, hi = bounds[self.host_id]
        return hi - lo

    def reshard(self, healthy_hosts: list[int]) -> None:
        """Fault-tolerance hook: drop ejected hosts, recompute bounds."""
        self.healthy_hosts = healthy_hosts

    def batch_at(self, step: int) -> PyTree:
        return self.factory(self.seed * 1_000_003 + step,
                            self.local_batch_size())

    def __iter__(self) -> Iterator[PyTree]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
