"""Synthetic recsys click/behaviour batches (DLRM / SASRec / DIEN / MIND).

Clicks follow a latent-factor model so training actually reduces loss:
user/item factors are drawn once per seed; labels = σ(⟨u, v⟩ + noise).
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.models.recsys import (DIENBatch, DLRMBatch, MINDBatch,
                                 SASRecBatch)


def dlrm_batch(seed: int, batch: int, n_dense: int = 13, n_sparse: int = 26,
               n_rows: int = 1_000_000) -> DLRMBatch:
    rng = np.random.default_rng(seed)
    dense = rng.normal(size=(batch, n_dense)).astype(np.float32)
    sparse = rng.integers(0, n_rows, size=(batch, n_sparse)).astype(np.int32)
    # clickiness correlates with the dense features → learnable signal
    w = np.linspace(-1, 1, n_dense)
    p = 1 / (1 + np.exp(-(dense @ w + rng.normal(size=batch) * 0.5)))
    labels = (rng.random(batch) < p).astype(np.float32)
    return DLRMBatch(dense=jnp.asarray(dense), sparse=jnp.asarray(sparse),
                     labels=jnp.asarray(labels))


def sasrec_batch(seed: int, batch: int, seq_len: int = 50,
                 n_items: int = 1_000_000) -> SASRecBatch:
    rng = np.random.default_rng(seed)
    # random-walk sequences in item space → local transition structure
    start = rng.integers(0, n_items, batch)
    steps = rng.integers(-50, 51, size=(batch, seq_len)).cumsum(axis=1)
    items = ((start[:, None] + steps) % n_items).astype(np.int32)
    targets = np.roll(items, -1, axis=1)
    targets[:, -1] = rng.integers(0, n_items, batch)
    negs = rng.integers(0, n_items, size=(batch, seq_len)).astype(np.int32)
    return SASRecBatch(items=jnp.asarray(items), targets=jnp.asarray(targets),
                       negatives=jnp.asarray(negs))


def dien_batch(seed: int, batch: int, seq_len: int = 100,
               n_items: int = 1_000_000) -> DIENBatch:
    rng = np.random.default_rng(seed)
    hist = rng.integers(0, n_items, size=(batch, seq_len)).astype(np.int32)
    target = rng.integers(0, n_items, batch).astype(np.int32)
    # positive iff the target's category (id % C) appears in the history
    c = max(n_items // 100, 16)
    labels = (np.isin(target % c, hist % c, assume_unique=False) &
              (rng.random(batch) < 0.9)).astype(np.float32)
    return DIENBatch(history=jnp.asarray(hist), target=jnp.asarray(target),
                     labels=jnp.asarray(labels))


def mind_batch(seed: int, batch: int, seq_len: int = 50, n_neg: int = 10,
               n_items: int = 1_000_000) -> MINDBatch:
    rng = np.random.default_rng(seed)
    hist = rng.integers(0, n_items, size=(batch, seq_len)).astype(np.int32)
    target = hist[np.arange(batch), rng.integers(0, seq_len, batch)]
    negs = rng.integers(0, n_items, size=(batch, n_neg)).astype(np.int32)
    return MINDBatch(history=jnp.asarray(hist),
                     target=jnp.asarray(target.astype(np.int32)),
                     negatives=jnp.asarray(negs))
