"""Synthetic retrieval corpus with controlled semantic/lexical structure.

MS MARCO / NQ text and the paper's BERT checkpoints are unavailable
offline, so we generate a corpus that preserves the *property the paper
exploits* (DESIGN.md §2): a tunable fraction of relevant (query, doc)
pairs are **semantically hard** — the query embedding lands far from the
document's cluster — while still **sharing rare salient terms** with the
document.  IVF alone must miss these pairs at small K^C; term-side lists
recover them; the hybrid wins (paper RQ2).

Generative model
    topics   t = 1..T        : unit centers c_t ∈ R^h, topical term sets
    document d (topic t)     : e_D = normalize(c_t + σ_doc·ε + idio)
                               tokens ~ mix(Zipf background, topical terms,
                                            doc-salient rare terms)
    query    q → positive d  : tokens share d's salient terms;
        easy  (1−p_hard)     : e_Q = normalize(e_D + σ_easy·ε)
        hard  (p_hard)       : e_Q = normalize(mix(e_D, c_{t'}) + σ_hard·ε)
                               (pulled toward a *different* topic)

Two embedding "models" (A and B) of different quality are derived per
corpus for the paper's RQ3 robustness study: B applies a fixed random
orthogonal rotation plus extra noise to both sides — a weaker but
consistent encoder.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

PAD_ID = -1


@dataclasses.dataclass
class Corpus:
    doc_emb: np.ndarray        # (n_docs, h) f32 — embedding model A
    doc_tokens: np.ndarray     # (n_docs, doc_len) i32, PAD_ID padded
    query_emb: np.ndarray      # (n_queries, h)
    query_tokens: np.ndarray   # (n_queries, query_len) i32
    qrels: np.ndarray          # (n_queries,) i32 positive doc id
    doc_topic: np.ndarray      # (n_docs,) i32
    is_hard: np.ndarray        # (n_queries,) bool — semantically-hard flag
    vocab_size: int
    # embedding model B (same corpus, weaker encoder) for RQ3
    doc_emb_b: Optional[np.ndarray] = None
    query_emb_b: Optional[np.ndarray] = None


def _normalize(x: np.ndarray) -> np.ndarray:
    return x / np.maximum(np.linalg.norm(x, axis=-1, keepdims=True), 1e-9)


def _zipf_probs(v: int, s: float = 1.07) -> np.ndarray:
    p = 1.0 / np.arange(1, v + 1) ** s
    return p / p.sum()


def generate(seed: int = 0, *, n_docs: int = 20000, n_queries: int = 1000,
             hidden: int = 64, vocab_size: int = 8192, n_topics: int = 128,
             doc_len: int = 64, query_len: int = 8,
             p_hard: float = 0.35, sigma_doc: float = 0.35,
             sigma_easy: float = 0.12, sigma_hard: float = 0.22,
             hard_topic_mix: float = 0.18, p_lexical: float = 0.75,
             topical_terms: int = 40, salient_per_doc: int = 3,
             make_model_b: bool = True) -> Corpus:
    rng = np.random.default_rng(seed)

    # --- topics -------------------------------------------------------------
    centers = _normalize(rng.normal(size=(n_topics, hidden)))
    # topical terms drawn from the mid-frequency band; doc-salient terms from
    # the rare tail (high ids under the Zipf order) so they get high IDF.
    mid_lo, mid_hi = vocab_size // 16, vocab_size // 2
    topic_terms = rng.integers(mid_lo, mid_hi, size=(n_topics, topical_terms))
    rare_lo = vocab_size // 2

    # --- documents ----------------------------------------------------------
    doc_topic = rng.integers(0, n_topics, size=n_docs)
    idio = rng.normal(size=(n_docs, hidden)) * 0.15
    doc_emb = _normalize(centers[doc_topic]
                         + rng.normal(size=(n_docs, hidden)) * sigma_doc
                         + idio).astype(np.float32)

    zipf = _zipf_probs(vocab_size)
    n_bg = doc_len - doc_len // 3 - salient_per_doc
    n_top = doc_len // 3
    bg = rng.choice(vocab_size, size=(n_docs, n_bg), p=zipf)
    tt = topic_terms[doc_topic][
        np.arange(n_docs)[:, None],
        rng.integers(0, topical_terms, size=(n_docs, n_top))]
    salient = rng.integers(rare_lo, vocab_size, size=(n_docs, salient_per_doc))
    doc_tokens = np.concatenate([bg, tt, salient], axis=1).astype(np.int32)
    perm = rng.random(doc_tokens.shape).argsort(axis=1)
    doc_tokens = np.take_along_axis(doc_tokens, perm, axis=1)

    # --- queries ------------------------------------------------------------
    qrels = rng.integers(0, n_docs, size=n_queries).astype(np.int32)
    is_hard = rng.random(n_queries) < p_hard

    pos_emb = doc_emb[qrels]
    other_topic = rng.integers(0, n_topics, size=n_queries)
    hard_emb = _normalize((1 - hard_topic_mix) * pos_emb
                          + hard_topic_mix * centers[other_topic]
                          + rng.normal(size=(n_queries, hidden)) * sigma_hard)
    easy_emb = _normalize(pos_emb
                          + rng.normal(size=(n_queries, hidden)) * sigma_easy)
    query_emb = np.where(is_hard[:, None], hard_emb, easy_emb).astype(np.float32)

    # query tokens: the positive doc's salient terms + topical + background.
    # Only a p_lexical fraction of queries carries the salient terms — term
    # matching must be strong-but-imperfect (paper Fig. 4: w.o. Clus beats
    # w.o. Term but both lose to the hybrid).
    n_sal_q = min(2, salient_per_doc)
    q_sal = salient[qrels][:, :n_sal_q]
    has_lex = rng.random(n_queries) < p_lexical
    lex_fallback = rng.choice(vocab_size, size=q_sal.shape, p=zipf)
    q_sal = np.where(has_lex[:, None], q_sal, lex_fallback)
    n_top_q = (query_len - n_sal_q) // 2
    q_top = topic_terms[doc_topic[qrels]][
        np.arange(n_queries)[:, None],
        rng.integers(0, topical_terms, size=(n_queries, n_top_q))]
    n_bg_q = query_len - n_sal_q - n_top_q
    q_bg = rng.choice(vocab_size, size=(n_queries, n_bg_q), p=zipf)
    query_tokens = np.concatenate([q_sal, q_top, q_bg], axis=1).astype(np.int32)

    corpus = Corpus(doc_emb=doc_emb, doc_tokens=doc_tokens,
                    query_emb=query_emb, query_tokens=query_tokens,
                    qrels=qrels, doc_topic=doc_topic.astype(np.int32),
                    is_hard=is_hard, vocab_size=vocab_size)

    if make_model_b:
        # model B: fixed orthogonal rotation + extra isotropic noise on both
        # towers — a weaker encoder with consistent query/doc geometry.
        # nb=0.1/dim ⇒ noise norm ≈ 0.8 vs unit signal: Flat recall drops
        # to the paper's "weaker encoder" band rather than collapsing.
        q_rot, _ = np.linalg.qr(rng.normal(size=(hidden, hidden)))
        nb = 0.10
        corpus.doc_emb_b = _normalize(
            doc_emb @ q_rot + rng.normal(size=doc_emb.shape) * nb
        ).astype(np.float32)
        corpus.query_emb_b = _normalize(
            query_emb @ q_rot + rng.normal(size=query_emb.shape) * nb
        ).astype(np.float32)
    return corpus


def hard_negatives(corpus: Corpus, n_neg: int, seed: int = 0) -> np.ndarray:
    """Topic-matched hard negatives for distillation training.

    (The paper samples BM25 top-200; same-topic docs are the synthetic
    equivalent — lexically & semantically confusable non-positives.)
    """
    rng = np.random.default_rng(seed)
    n_queries = corpus.qrels.shape[0]
    pos_topics = corpus.doc_topic[corpus.qrels]
    # docs grouped by topic for O(1) sampling
    order = np.argsort(corpus.doc_topic, kind="stable")
    sorted_topics = corpus.doc_topic[order]
    starts = np.searchsorted(sorted_topics, np.arange(sorted_topics.max() + 2))
    negs = np.empty((n_queries, n_neg), np.int32)
    for i in range(n_queries):
        t = pos_topics[i]
        lo, hi = starts[t], starts[t + 1]
        pool = order[lo:hi]
        if len(pool) == 0:
            pool = np.arange(corpus.doc_emb.shape[0])
        negs[i] = rng.choice(pool, size=n_neg, replace=len(pool) < n_neg)
    return negs
