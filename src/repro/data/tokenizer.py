"""Hash tokenizer — deterministic text → fixed-vocab ids with no external
vocabulary files (none are available offline).  Used by the end-to-end
examples when indexing real text snippets; the synthetic benchmark
corpus generates ids directly."""
from __future__ import annotations

import hashlib
import re

import numpy as np

PAD_ID = -1
_WORD_RE = re.compile(r"[a-z0-9]+")


def tokenize(text: str, vocab_size: int, max_len: int) -> np.ndarray:
    """Lowercase word split, each word hashed into [0, vocab_size)."""
    words = _WORD_RE.findall(text.lower())[:max_len]
    ids = [int.from_bytes(hashlib.blake2b(w.encode(), digest_size=4).digest(),
                          "little") % vocab_size
           for w in words]
    out = np.full(max_len, PAD_ID, np.int32)
    out[:len(ids)] = ids
    return out


def tokenize_batch(texts: list[str], vocab_size: int,
                   max_len: int) -> np.ndarray:
    return np.stack([tokenize(t, vocab_size, max_len) for t in texts])
