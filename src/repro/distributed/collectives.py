"""Collective helpers for the (pod, data, model) production mesh.

The interesting one is the **hierarchical gradient all-reduce**: at 512+
chips a flat all-reduce over (pod × data) serializes on the slow
cross-pod (DCI) links.  The bandwidth-optimal schedule is

    reduce_scatter(data)  →  all_reduce(pod)  →  all_gather(data)

which moves 1/|data| of the gradient bytes across pods.  These helpers
are `shard_map`-body functions; `launch/train.py` applies them when the
mesh has a pod axis, and `tests/test_distributed.py` proves numerical
equality with the flat psum on the 8-device host mesh.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


def flat_allreduce(grads: PyTree, axis_names: tuple[str, ...]) -> PyTree:
    return jax.tree.map(lambda g: jax.lax.psum(g, axis_names), grads)


def hierarchical_allreduce(grads: PyTree, data_axis: str = "data",
                           pod_axis: str = "pod") -> PyTree:
    """reduce_scatter(data) → psum(pod) → all_gather(data), leafwise.

    Falls back to a flat psum for leaves too small to scatter.
    """
    data_size = jax.lax.axis_size(data_axis)

    def one(g):
        if g.ndim == 0 or g.shape[0] % data_size != 0:
            return jax.lax.psum(g, (data_axis, pod_axis))
        scattered = jax.lax.psum_scatter(g, data_axis,
                                         scatter_dimension=0, tiled=True)
        scattered = jax.lax.psum(scattered, pod_axis)
        return jax.lax.all_gather(scattered, data_axis, axis=0, tiled=True)

    return jax.tree.map(one, grads)


def pmean_metrics(metrics: PyTree, axis_names: tuple[str, ...]) -> PyTree:
    return jax.tree.map(lambda m: jax.lax.pmean(m, axis_names), metrics)
