"""Collective helpers for the (pod, data, model) production mesh.

The interesting one is the **hierarchical gradient all-reduce**: at 512+
chips a flat all-reduce over (pod × data) serializes on the slow
cross-pod (DCI) links.  The bandwidth-optimal schedule is

    reduce_scatter(data)  →  all_reduce(pod)  →  all_gather(data)

which moves 1/|data| of the gradient bytes across pods.  These helpers
are `shard_map`-body functions; `launch/train.py` applies them when the
mesh has a pod axis, and `tests/test_distributed.py` proves numerical
equality with the flat psum on the 8-device host mesh.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed import compat

PyTree = Any


def flat_allreduce(grads: PyTree, axis_names: tuple[str, ...]) -> PyTree:
    return jax.tree.map(lambda g: jax.lax.psum(g, axis_names), grads)


def hierarchical_allreduce(grads: PyTree, data_axis: str = "data",
                           pod_axis: str = "pod") -> PyTree:
    """reduce_scatter(data) → psum(pod) → all_gather(data), leafwise.

    Falls back to a flat psum for leaves too small to scatter.
    """
    data_size = compat.axis_size(data_axis)

    def one(g):
        if g.ndim == 0 or g.shape[0] % data_size != 0:
            return jax.lax.psum(g, (data_axis, pod_axis))
        scattered = jax.lax.psum_scatter(g, data_axis,
                                         scatter_dimension=0, tiled=True)
        scattered = jax.lax.psum(scattered, pod_axis)
        return jax.lax.all_gather(scattered, data_axis, axis=0, tiled=True)

    return jax.tree.map(one, grads)


def pmean_metrics(metrics: PyTree, axis_names: tuple[str, ...]) -> PyTree:
    return jax.tree.map(lambda m: jax.lax.pmean(m, axis_names), metrics)


def gather_topk(scores: jax.Array, ids: jax.Array, axis_name: str
                ) -> tuple[jax.Array, jax.Array]:
    """Merge per-shard top-R planes for the sharded HI² search
    (DESIGN.md §6): all-gather each shard's (B, R) scores/ids along the
    shard axis and lay them out as one (B, S·R) candidate plane per
    query, ready for a final total-order top-R.

    Communication is 2·S·B·R values (f32 + i32) — independent of corpus
    size and list capacities, which is the point: only the tiny merged
    frontier crosses the interconnect, never candidates or codes.  Runs
    inside a ``shard_map`` body; every shard returns the identical
    merged plane (the caller's final top-R is replicated work).
    """
    s = jax.lax.all_gather(scores, axis_name)            # (S, B, R)
    i = jax.lax.all_gather(ids, axis_name)
    n_shards, b, r = s.shape
    return (jnp.moveaxis(s, 0, 1).reshape(b, n_shards * r),
            jnp.moveaxis(i, 0, 1).reshape(b, n_shards * r))
