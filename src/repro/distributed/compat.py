"""Version tolerance for the JAX SPMD API surface (DESIGN.md §6).

The distributed code targets the modern spelling (``jax.shard_map``,
``jax.sharding.AxisType``, ``check_vma=``) but must also run on the
pinned 0.4.x jaxlib baked into the accelerator image, where the same
functionality lives under ``jax.experimental.shard_map`` with
``check_rep=`` and meshes have no axis types.  Every call site goes
through these two wrappers instead of importing jax directly, so a
toolchain bump is a one-file change.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
from jax.sharding import Mesh

try:  # jax >= 0.6: top-level export, replication check renamed to vma
    from jax import shard_map as _shard_map
    _CHECK_KW = "check_vma"
except ImportError:  # 0.4.x fallback
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = "check_rep"

try:  # explicit-sharding era meshes carry per-axis types
    from jax.sharding import AxisType as _AxisType
except ImportError:
    _AxisType = None


def shard_map(f, mesh: Mesh, in_specs, out_specs, check: bool = True):
    """``jax.shard_map`` with the replication-check kwarg spelled for
    whichever jax is installed (``check_vma`` / ``check_rep``)."""
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **{_CHECK_KW: check})


def axis_size(axis_name) -> jax.Array:
    """Size of a shard_map/pmap axis from inside the mapped body
    (``jax.lax.axis_size`` where available, psum-of-ones otherwise)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str],
              *, devices: Optional[Sequence] = None) -> Mesh:
    """``jax.make_mesh`` pinned to Auto axis types where the installed
    jax distinguishes them (shard_map + GSPMD code here assumes Auto)."""
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    if _AxisType is not None:
        kwargs["axis_types"] = (_AxisType.Auto,) * len(axis_shapes)
    try:
        return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)
    except TypeError:  # installed jax.make_mesh predates axis_types kwarg
        kwargs.pop("axis_types", None)
        return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)
