"""Fault tolerance & straggler machinery.

This container has one host, so real preemption can't be exercised —
what CAN be proven here (and is, in tests/test_fault_tolerance.py):

  · crash/restart drill: a training loop is killed mid-run (simulated
    exception at a chosen step) and resumed from the CheckpointManager —
    the resumed trajectory is bit-identical to an uninterrupted run;
  · elastic reshard: a checkpoint saved under one device count restores
    under another (restore_resharded) and training continues;
  · straggler detection: an online per-step-latency monitor flags
    outliers against a rolling median deadline — at scale the flagged
    host is drained and its data shard redistributed (skip-and-reshard,
    documented below), which tests simulate by dropping a shard.

Production notes (1000+ nodes), encoded as policy constants here:
  · STRAGGLER_FACTOR: a step slower than median × factor marks the host.
  · After MAX_STRIKES strikes the host is ejected; the data pipeline
    reshards (every host owns `global_batch / n_healthy` examples —
    our pipeline computes shard bounds from the *current* host set).
  · Checkpoint cadence bounds lost work; with save_every=100 steps and
    ~1 step/s, a failure costs ≤ 100 s of compute + restore time.
"""
from __future__ import annotations

import collections
import time
from typing import Optional

STRAGGLER_FACTOR = 2.5
MAX_STRIKES = 3


class StragglerMonitor:
    """Rolling-median step-latency watchdog."""

    def __init__(self, window: int = 50, factor: float = STRAGGLER_FACTOR):
        self.durations: collections.deque = collections.deque(maxlen=window)
        self.factor = factor
        self.strikes: collections.Counter = collections.Counter()
        self._t0: Optional[float] = None

    def step_start(self) -> None:
        self._t0 = time.monotonic()

    def step_end(self, host_id: int = 0) -> bool:
        """Record a step; True if this host just exceeded the deadline."""
        assert self._t0 is not None, "step_start not called"
        dt = time.monotonic() - self._t0
        self._t0 = None
        flagged = False
        if len(self.durations) >= 8:
            med = sorted(self.durations)[len(self.durations) // 2]
            if dt > med * self.factor:
                self.strikes[host_id] += 1
                flagged = True
        self.durations.append(dt)
        return flagged

    def should_eject(self, host_id: int = 0) -> bool:
        return self.strikes[host_id] >= MAX_STRIKES


def reshard_bounds(n_examples: int, healthy_hosts: list[int]
                   ) -> dict[int, tuple[int, int]]:
    """Contiguous per-host example ranges over the *current* host set —
    the skip-and-reshard primitive used after an ejection."""
    n = len(healthy_hosts)
    per = n_examples // n
    rem = n_examples % n
    out, start = {}, 0
    for i, h in enumerate(sorted(healthy_hosts)):
        size = per + (1 if i < rem else 0)
        out[h] = (start, start + size)
        start += size
    return out


class SimulatedFailure(RuntimeError):
    """Raised by the test drill to kill a run at a chosen step."""
