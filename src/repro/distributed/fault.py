"""Fault tolerance & straggler machinery.

This container has one host, so real preemption can't be exercised —
what CAN be proven here (and is, in tests/test_fault_tolerance.py):

  · crash/restart drill: a training loop is killed mid-run (simulated
    exception at a chosen step) and resumed from the CheckpointManager —
    the resumed trajectory is bit-identical to an uninterrupted run;
  · elastic reshard: a checkpoint saved under one device count restores
    under another (restore_resharded) and training continues;
  · straggler detection: an online per-step-latency monitor flags
    outliers against a rolling median deadline — at scale the flagged
    host is drained and its data shard redistributed (skip-and-reshard,
    documented below), which tests simulate by dropping a shard.

Production notes (1000+ nodes), encoded as policy constants here:
  · STRAGGLER_FACTOR: a step slower than median × factor marks the host.
  · After MAX_STRIKES strikes the host is ejected; the data pipeline
    reshards (every host owns `global_batch / n_healthy` examples —
    our pipeline computes shard bounds from the *current* host set).
  · Checkpoint cadence bounds lost work; with save_every=100 steps and
    ~1 step/s, a failure costs ≤ 100 s of compute + restore time.
"""
from __future__ import annotations

import collections
import time
from typing import Optional

STRAGGLER_FACTOR = 2.5
MAX_STRIKES = 3


class StragglerMonitor:
    """Rolling-median step-latency watchdog."""

    def __init__(self, window: int = 50, factor: float = STRAGGLER_FACTOR,
                 max_strikes: int = MAX_STRIKES):
        self.durations: collections.deque = collections.deque(maxlen=window)
        self.factor = factor
        self.max_strikes = max_strikes
        self.strikes: collections.Counter = collections.Counter()
        self._t0: Optional[float] = None

    def step_start(self) -> None:
        self._t0 = time.monotonic()

    def step_end(self, host_id: int = 0) -> bool:
        """Record a timed step; True if this host just exceeded the
        deadline.  Convenience over :meth:`observe` for loops that let
        the monitor do its own timing."""
        assert self._t0 is not None, "step_start not called"
        dt = time.monotonic() - self._t0
        self._t0 = None
        return self.observe(dt, host_id)

    def observe(self, dt: float, host_id: int = 0) -> bool:
        """Record one externally-measured duration for ``host_id``;
        True when it exceeded the rolling-median deadline.  This is the
        seam the serving path feeds (per-shard latencies measured by the
        caller, DESIGN.md §12) — the median window is shared across
        hosts, strikes are per host."""
        flagged = False
        if len(self.durations) >= 8:
            med = sorted(self.durations)[len(self.durations) // 2]
            if dt > med * self.factor:
                self.strikes[host_id] += 1
                flagged = True
        self.durations.append(dt)
        return flagged

    def should_eject(self, host_id: int = 0) -> bool:
        return self.strikes[host_id] >= self.max_strikes


class ShardHealth:
    """Serving-side shard membership driven by the straggler policy
    (DESIGN.md §12): feed per-shard latencies through :meth:`observe`;
    after ``max_strikes`` deadline misses a shard should be ejected from
    the serving set.  Ejection and rejoin themselves are explicit calls
    — the index layer owns the actual survivor-set rebuild."""

    def __init__(self, n_shards: int, window: int = 50,
                 factor: float = STRAGGLER_FACTOR,
                 max_strikes: int = MAX_STRIKES):
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        self.n_shards = int(n_shards)
        self.monitor = StragglerMonitor(window=window, factor=factor,
                                        max_strikes=max_strikes)
        self._lost: set[int] = set()

    def observe(self, shard: int, dt: float) -> bool:
        """Record one measured shard latency; True when the shard has
        now accumulated enough strikes that it should be ejected."""
        self._check(shard)
        self.monitor.observe(dt, shard)
        return shard not in self._lost and self.monitor.should_eject(shard)

    def eject(self, shard: int) -> None:
        self._check(shard)
        if len(self.healthy) <= 1 and shard in self.healthy:
            raise ValueError("cannot eject the last healthy shard")
        self._lost.add(shard)

    def rejoin(self, shard: Optional[int] = None) -> None:
        """Return one shard (or, with None, every lost shard) to the
        healthy set and clear its strikes."""
        back = list(self._lost) if shard is None else [shard]
        for s in back:
            self._check(s)
            self._lost.discard(s)
            self.monitor.strikes[s] = 0

    @property
    def healthy(self) -> list[int]:
        return [s for s in range(self.n_shards) if s not in self._lost]

    @property
    def lost(self) -> list[int]:
        return sorted(self._lost)

    @property
    def degraded(self) -> bool:
        return bool(self._lost)

    def _check(self, shard: int) -> None:
        if not 0 <= shard < self.n_shards:
            raise ValueError(
                f"shard {shard} out of range [0, {self.n_shards})")


def reshard_bounds(n_examples: int, healthy_hosts: list[int]
                   ) -> dict[int, tuple[int, int]]:
    """Contiguous per-host example ranges over the *current* host set —
    the skip-and-reshard primitive used after an ejection."""
    n = len(healthy_hosts)
    per = n_examples // n
    rem = n_examples % n
    out, start = {}, 0
    for i, h in enumerate(sorted(healthy_hosts)):
        size = per + (1 if i < rem else 0)
        out[h] = (start, start + size)
        start += size
    return out


class SimulatedFailure(RuntimeError):
    """Raised by the test drill to kill a run at a chosen step."""
