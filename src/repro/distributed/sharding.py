"""Logical-axis sharding rules (MaxText-style).

Model code annotates tensors with *logical* axis names
(``shard(x, "batch", "seq", "embed")``); a process-wide rule table maps
logical names to mesh axes.  Outside a mesh context (CPU unit tests) the
annotation is a no-op, so the same model code runs everywhere.

Default rules target the production (pod, data, model) mesh:

    batch    → ("pod", "data")   pure DP over pods + data axis
    seq      → "model"           sequence-sharded residual stream between
                                  blocks (Megatron sequence parallelism —
                                  XLA inserts the all-gather/reduce-scatter
                                  pair around attention/FFN)
    heads    → "model"           tensor parallelism over (kv-)heads
    ff       → "model"           tensor parallelism over the FFN hidden dim
    expert_ff→ "model"           MoE experts: TP inside each expert
    vocab    → "model"           sharded unembedding / embedding rows
    table    → "model"           recsys embedding-table row sharding
    edges    → ("data", "model") GNN edge planes over the whole pod
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisName = Union[str, tuple, None]

DEFAULT_RULES: dict[str, AxisName] = {
    "batch": ("pod", "data"),
    # ZeRO-3 weight sharding: spans pods on the multi-pod mesh (cross-pod
    # all-gather of weights is the price of fitting 141B×16B of state)
    "fsdp": ("pod", "data"),
    "seq": "model",
    "seq_kv": None,
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "ff": "model",
    "experts": None,
    "expert_ff": "model",
    "moe_capacity": "data",
    "moe_flat": "data",   # flattened (token, slot) assignment axis
    "vocab": "model",
    "table": "model",
    "rows": None,
    "edges": ("data", "model"),
    "nodes": None,
    "clusters": None,
    "candidates": "model",
    # document-sharded HI² (DESIGN.md §6): the leading shard axis of
    # every ShardedHybridIndex doc/list plane. On the production mesh it
    # rides the model axis; serve.py uses a dedicated 1-D "shards" mesh.
    "shards": "model",
}

_state = threading.local()


def _rules() -> Optional[dict[str, AxisName]]:
    return getattr(_state, "rules", None)


def _mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def use_mesh(mesh: Mesh, rules: Optional[dict[str, AxisName]] = None):
    """Activate sharding annotations for model code built under ``mesh``."""
    merged = dict(DEFAULT_RULES)
    if rules:
        merged.update(rules)
    # drop rules that reference axes the mesh doesn't have
    def filter_axis(ax):
        if ax is None:
            return None
        if isinstance(ax, tuple):
            kept = tuple(a for a in ax if a in mesh.axis_names)
            return kept if kept else None
        return ax if ax in mesh.axis_names else None

    merged = {k: filter_axis(v) for k, v in merged.items()}
    prev_rules, prev_mesh = _rules(), _mesh()
    _state.rules, _state.mesh = merged, mesh
    try:
        with mesh:
            yield
    finally:
        _state.rules, _state.mesh = prev_rules, prev_mesh


def spec(*logical_axes: Optional[str]) -> P:
    """PartitionSpec for a tuple of logical axis names (None = replicated)."""
    rules = _rules() or {}
    return P(*[rules.get(a) if a is not None else None
               for a in logical_axes])


def shard(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical names; no-op outside use_mesh."""
    mesh = _mesh()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec(*logical_axes)))


def named_sharding(*logical_axes: Optional[str]) -> Optional[NamedSharding]:
    mesh = _mesh()
    if mesh is None:
        return None
    return NamedSharding(mesh, spec(*logical_axes))
