"""Pallas TPU kernels for the framework's compute hot spots.

    pq_adc          — PQ asymmetric-distance scoring (paper Eq. 4), the
                      per-query candidate-evaluation hot path of HI²;
                      includes the fused gather+ADC+mask search path
                      (DESIGN.md §11).
    sq8_dot         — fused gather+dequantized-dot scoring for the sq8
                      codec (DESIGN.md §11).
    assign_topk     — fused embedding×centroid scoring with running
                      argmax: KMeans assignment + cluster dispatch
                      (paper Eq. 6) over large L; ``topk_scores`` is
                      the lax.top_k-exact dispatch top-k (§11).
    flash_attention — SWA/GQA-capable flash attention for the LM-family
                      architecture backbones (beyond-paper optimization).

Every kernel ships ``kernel.py`` (pl.pallas_call + explicit BlockSpec
VMEM tiling), ``ops.py`` (jit'd public wrapper with an ``interpret``
switch so CPU CI exercises the kernel body), and ``ref.py`` (pure-jnp
oracle used by the tests' assert_allclose sweeps).
"""
