from repro.kernels.assign_topk import kernel, ops, ref
