"""Fused embedding×centroid scoring with running argmax — the KMeans
assignment / cluster-dispatch hot spot (paper Eq. 6, DESIGN.md §2).

Faiss scans centroids with a CPU heap; on TPU the score plane is an MXU
matmul tiled so the (N_blk, L_blk) tile lives in VMEM, with a *running*
max/argmax folded across centroid tiles — the full (N, L) plane never
reaches HBM.  The centroid ``-½‖c‖²`` bias (inner-product ↔ L2 argmin
equivalence) is computed in-kernel per tile.

Grid: (N/N_blk, L/L_blk), centroid axis innermost; the output blocks are
indexed by the N tile only, so they are *revisited* across centroid
tiles — the legal sequential-reduction pattern on TPU grids.

VMEM per step (N_blk=256, L_blk=512, h=128):
    x 128 KiB + c 256 KiB + tile 512 KiB + outs 2 KiB ≈ 0.9 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _assign_kernel(x_ref, c_ref, best_s_ref, best_i_ref, *, l_blk: int):
    j = pl.program_id(1)
    x = x_ref[...].astype(jnp.float32)            # (n_blk, h)
    c = c_ref[...].astype(jnp.float32)            # (l_blk, h)
    c_norm = 0.5 * jnp.sum(c * c, axis=-1)        # (l_blk,)
    s = jnp.dot(x, c.T, preferred_element_type=jnp.float32) - c_norm[None, :]
    local_s = jnp.max(s, axis=-1)
    local_i = jnp.argmax(s, axis=-1).astype(jnp.int32) + j * l_blk

    @pl.when(j == 0)
    def _init():
        best_s_ref[...] = local_s
        best_i_ref[...] = local_i

    @pl.when(j > 0)
    def _merge():
        prev_s = best_s_ref[...]
        take = local_s > prev_s
        best_s_ref[...] = jnp.where(take, local_s, prev_s)
        best_i_ref[...] = jnp.where(take, local_i, best_i_ref[...])


# --------------------------------------------------------------------------
# running top-k (PR 6) — the dispatch stage's cluster selection
# --------------------------------------------------------------------------
#
# Same tiling as the argmax kernel, but the per-query state carried
# across centroid tiles is a (k,) best-list instead of a scalar.  Each
# tile concatenates [previous best ‖ tile scores] and re-selects top-k
# with *first-position* tie-break: previous winners come from earlier
# tiles (smaller global indices) and sit first in the concat, and
# within a tile the column iota ascends — so the selection reproduces
# ``lax.top_k``'s lowest-index-first tie-break exactly, by induction.
# Padded centroid columns are masked to -inf via the static ``l_true``
# (duplicate-row padding is safe for argmax but NOT for top-k: a
# duplicate would enter the best list as a second distinct id).
#
# Unlike dispatch scoring via assign_argmax, this op uses the *plain*
# inner product — no -½‖c‖² bias — matching cluster_selector's routing
# score (the bias is a KMeans-assignment L2 equivalence, not a routing
# quantity).


def _select_topk(s, ids, k: int):
    """Static-k selection of (n, w) rows; first position wins ties."""
    pos = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    out_s, out_i = [], []
    for _ in range(k):
        best = jnp.max(s, axis=-1)
        p = jnp.argmax(s, axis=-1)              # first max position
        sel = pos == p[:, None]
        out_s.append(best)
        out_i.append(jnp.sum(jnp.where(sel, ids, 0), axis=-1))
        s = jnp.where(sel, -jnp.inf, s)
    return jnp.stack(out_s, axis=-1), jnp.stack(out_i, axis=-1)


def _topk_kernel(x_ref, e_ref, best_s_ref, best_i_ref, *, k: int,
                 l_blk: int, l_true: int):
    j = pl.program_id(1)
    x = x_ref[...].astype(jnp.float32)            # (n_blk, h)
    e = e_ref[...].astype(jnp.float32)            # (l_blk, h)
    s = jnp.dot(x, e.T, preferred_element_type=jnp.float32)
    col = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1) + j * l_blk
    s = jnp.where(col < l_true, s, -jnp.inf)      # mask padded columns

    @pl.when(j == 0)
    def _init():
        ts, ti = _select_topk(s, col, k)
        best_s_ref[...] = ts
        best_i_ref[...] = ti

    @pl.when(j > 0)
    def _merge():
        cs = jnp.concatenate([best_s_ref[...], s], axis=-1)
        ci = jnp.concatenate([best_i_ref[...], col], axis=-1)
        ts, ti = _select_topk(cs, ci, k)
        best_s_ref[...] = ts
        best_i_ref[...] = ti


@functools.partial(jax.jit,
                   static_argnames=("k", "n_blk", "l_blk", "l_true",
                                    "interpret"))
def topk_scores(x: jax.Array, emb: jax.Array, *, k: int, n_blk: int = 256,
                l_blk: int = 512, l_true: int, interpret: bool = False
                ) -> tuple[jax.Array, jax.Array]:
    """x: (N, h); emb: (L, h) → (scores (N, k), idx (N, k)) — the top-k
    plain inner products per row, ``lax.top_k`` tie-break semantics.

    N % n_blk == 0 and L % l_blk == 0 (ops.py pads); columns ≥
    ``l_true`` are padding and are masked to -inf in-kernel.
    """
    n, h = x.shape
    l, _ = emb.shape
    assert n % n_blk == 0 and l % l_blk == 0, (n, n_blk, l, l_blk)
    assert k <= l_true <= l, (k, l_true, l)
    grid = (n // n_blk, l // l_blk)
    return pl.pallas_call(
        functools.partial(_topk_kernel, k=k, l_blk=l_blk, l_true=l_true),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n_blk, h), lambda i, j: (i, 0)),
            pl.BlockSpec((l_blk, h), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((n_blk, k), lambda i, j: (i, 0)),
            pl.BlockSpec((n_blk, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, k), jnp.float32),
            jax.ShapeDtypeStruct((n, k), jnp.int32),
        ],
        interpret=interpret,
    )(x, emb)


@functools.partial(jax.jit, static_argnames=("n_blk", "l_blk", "interpret"))
def assign_argmax(x: jax.Array, centroids: jax.Array, *, n_blk: int = 256,
                  l_blk: int = 512, interpret: bool = False
                  ) -> tuple[jax.Array, jax.Array]:
    """x: (N, h); centroids: (L, h) → (best_score (N,), best_idx (N,)).

    argmax_j ⟨x, c_j⟩ − ½‖c_j‖²  ==  argmin_j ‖x − c_j‖².
    N % n_blk == 0 and L % l_blk == 0 (ops.py pads).
    """
    n, h = x.shape
    l, _ = centroids.shape
    assert n % n_blk == 0 and l % l_blk == 0, (n, n_blk, l, l_blk)
    grid = (n // n_blk, l // l_blk)
    return pl.pallas_call(
        functools.partial(_assign_kernel, l_blk=l_blk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n_blk, h), lambda i, j: (i, 0)),
            pl.BlockSpec((l_blk, h), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((n_blk,), lambda i, j: (i,)),
            pl.BlockSpec((n_blk,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
        ],
        interpret=interpret,
    )(x, centroids)
