"""Fused embedding×centroid scoring with running argmax — the KMeans
assignment / cluster-dispatch hot spot (paper Eq. 6, DESIGN.md §2).

Faiss scans centroids with a CPU heap; on TPU the score plane is an MXU
matmul tiled so the (N_blk, L_blk) tile lives in VMEM, with a *running*
max/argmax folded across centroid tiles — the full (N, L) plane never
reaches HBM.  The centroid ``-½‖c‖²`` bias (inner-product ↔ L2 argmin
equivalence) is computed in-kernel per tile.

Grid: (N/N_blk, L/L_blk), centroid axis innermost; the output blocks are
indexed by the N tile only, so they are *revisited* across centroid
tiles — the legal sequential-reduction pattern on TPU grids.

VMEM per step (N_blk=256, L_blk=512, h=128):
    x 128 KiB + c 256 KiB + tile 512 KiB + outs 2 KiB ≈ 0.9 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _assign_kernel(x_ref, c_ref, best_s_ref, best_i_ref, *, l_blk: int):
    j = pl.program_id(1)
    x = x_ref[...].astype(jnp.float32)            # (n_blk, h)
    c = c_ref[...].astype(jnp.float32)            # (l_blk, h)
    c_norm = 0.5 * jnp.sum(c * c, axis=-1)        # (l_blk,)
    s = jnp.dot(x, c.T, preferred_element_type=jnp.float32) - c_norm[None, :]
    local_s = jnp.max(s, axis=-1)
    local_i = jnp.argmax(s, axis=-1).astype(jnp.int32) + j * l_blk

    @pl.when(j == 0)
    def _init():
        best_s_ref[...] = local_s
        best_i_ref[...] = local_i

    @pl.when(j > 0)
    def _merge():
        prev_s = best_s_ref[...]
        take = local_s > prev_s
        best_s_ref[...] = jnp.where(take, local_s, prev_s)
        best_i_ref[...] = jnp.where(take, local_i, best_i_ref[...])


@functools.partial(jax.jit, static_argnames=("n_blk", "l_blk", "interpret"))
def assign_argmax(x: jax.Array, centroids: jax.Array, *, n_blk: int = 256,
                  l_blk: int = 512, interpret: bool = False
                  ) -> tuple[jax.Array, jax.Array]:
    """x: (N, h); centroids: (L, h) → (best_score (N,), best_idx (N,)).

    argmax_j ⟨x, c_j⟩ − ½‖c_j‖²  ==  argmin_j ‖x − c_j‖².
    N % n_blk == 0 and L % l_blk == 0 (ops.py pads).
    """
    n, h = x.shape
    l, _ = centroids.shape
    assert n % n_blk == 0 and l % l_blk == 0, (n, n_blk, l, l_blk)
    grid = (n // n_blk, l // l_blk)
    return pl.pallas_call(
        functools.partial(_assign_kernel, l_blk=l_blk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n_blk, h), lambda i, j: (i, 0)),
            pl.BlockSpec((l_blk, h), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((n_blk,), lambda i, j: (i,)),
            pl.BlockSpec((n_blk,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
        ],
        interpret=interpret,
    )(x, centroids)
