"""Public wrapper: pads N/L to tile multiples, strips the padding, and
switches to interpret mode off-TPU."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.assign_topk import kernel, ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("n_blk", "l_blk", "use_kernel"))
def assign_argmax(x: jax.Array, centroids: jax.Array, *, n_blk: int = 256,
                  l_blk: int = 512, use_kernel: bool = True
                  ) -> tuple[jax.Array, jax.Array]:
    if not use_kernel:
        return ref.assign_argmax(x, centroids)
    n, h = x.shape
    l = centroids.shape[0]
    n_blk = min(n_blk, max(8, n))
    l_blk = min(l_blk, max(8, l))
    pad_n = (-n) % n_blk
    pad_l = (-l) % l_blk
    xp = jnp.pad(x, ((0, pad_n), (0, 0)))
    # pad centroids with COPIES of centroid 0: duplicates can only tie,
    # and the running-max merge breaks ties toward the earlier tile, so
    # the original index always wins. (A huge-norm sentinel was tried
    # first and refuted by hypothesis: x·c − ‖c‖²/2 = inf − inf = NaN.)
    cp = (jnp.concatenate(
        [centroids, jnp.broadcast_to(centroids[:1], (pad_l, h))])
        if pad_l else centroids)
    s, i = kernel.assign_argmax(xp, cp, n_blk=n_blk, l_blk=l_blk,
                                interpret=not _on_tpu())
    return s[:n], i[:n]


@functools.partial(jax.jit,
                   static_argnames=("k", "n_blk", "l_blk", "use_kernel"))
def topk_scores(x: jax.Array, emb: jax.Array, k: int, *, n_blk: int = 256,
                l_blk: int = 512, use_kernel: bool = True
                ) -> tuple[jax.Array, jax.Array]:
    """Top-k plain inner products per row of ``x`` against ``emb``,
    ``lax.top_k`` semantics (score desc, lowest index first on ties).

    Padding uses zero rows masked to -inf in-kernel via the static
    ``l_true`` — NOT the duplicate-row trick from assign_argmax, which
    is only safe for argmax (a duplicated centroid would enter a top-k
    list twice under a second id).
    """
    if not use_kernel:
        return ref.topk_scores(x, emb, k)
    n, h = x.shape
    l = emb.shape[0]
    assert k <= l, (k, l)
    n_blk = min(n_blk, max(8, n))
    l_blk = min(l_blk, max(8, l))
    pad_n = (-n) % n_blk
    pad_l = (-l) % l_blk
    xp = jnp.pad(x, ((0, pad_n), (0, 0)))
    ep = jnp.pad(emb, ((0, pad_l), (0, 0)))
    s, i = kernel.topk_scores(xp, ep, k=k, n_blk=n_blk, l_blk=l_blk,
                              l_true=l, interpret=not _on_tpu())
    return s[:n], i[:n]
