"""Pure-jnp oracle for the assignment kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def assign_argmax(x: jax.Array, centroids: jax.Array
                  ) -> tuple[jax.Array, jax.Array]:
    c = centroids.astype(jnp.float32)
    s = x.astype(jnp.float32) @ c.T - 0.5 * jnp.sum(c * c, axis=-1)[None, :]
    return jnp.max(s, axis=-1), jnp.argmax(s, axis=-1).astype(jnp.int32)


def topk_scores(x: jax.Array, emb: jax.Array, k: int
                ) -> tuple[jax.Array, jax.Array]:
    """Plain inner-product top-k — the dispatch-stage routing score
    (no -½‖c‖² bias; that is KMeans-assignment-only)."""
    s = x.astype(jnp.float32) @ emb.astype(jnp.float32).T
    vals, idx = jax.lax.top_k(s, k)
    return vals, idx.astype(jnp.int32)
