"""Flash attention (fwd) with causal, sliding-window (SWA) and GQA
support — the compute hot spot of every LM-family assigned architecture.

TPU adaptation of the FlashAttention recurrence: the score tile
(blk_q, blk_k) is an MXU matmul held in VMEM; the online-softmax running
(max, sum, acc) statistics live in VMEM scratch and persist across the
kv-tile grid dimension (innermost, sequential on TPU).  The O(S²) score
plane never exists in HBM; with a window W the kv loop only contributes
O(S·W) work (fully-masked tiles short-circuit via ``pl.when``).

GQA is handled by BlockSpec index mapping — query head ``h`` reads kv
head ``h // group`` — so grouped KV is never materialized to Hq heads.

Grid: (B, Hq, Sq/blk_q, Sk/blk_k).
VMEM per step (blk_q=blk_k=128, d=128):
    q/k/v tiles 3·64 KiB + scores 64 KiB + acc 64 KiB + stats 1 KiB ≈ 0.3 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref,
                  acc_ref, m_ref, l_ref, *,
                  blk_q: int, blk_k: int, causal: bool, window: int,
                  scale: float, sq: int, sk: int):
    iq = pl.program_id(2)
    jk = pl.program_id(3)
    nk = pl.num_programs(3)

    q_pos = iq * blk_q + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 0)
    k_pos = jk * blk_k + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 1)

    # tile-level skip: under causal/SWA masks, whole kv tiles are dead —
    # with a window W only O(S·W) tiles do work
    first_q = iq * blk_q
    last_q = first_q + blk_q - 1
    first_k = jk * blk_k
    last_k = first_k + blk_k - 1
    live = jnp.bool_(True)
    if causal:
        live &= first_k <= last_q
    if window > 0:
        live &= last_k > first_q - window           # kv not too far behind
        if not causal:
            live &= first_k < last_q + window       # kv not too far ahead

    @pl.when(jk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale     # (blk_q, d)
        k = k_ref[0, 0].astype(jnp.float32)             # (blk_k, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)

        mask = (q_pos < sq) & (k_pos < sk)
        if causal:
            mask &= q_pos >= k_pos
        if window > 0:
            mask &= (q_pos - k_pos) < window
            if not causal:
                mask &= (k_pos - q_pos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(jk == nk - 1)
    def _finish():
        l = l_ref[...]
        safe_l = jnp.where(l > 0.0, l, 1.0)
        o_ref[0, 0] = (acc_ref[...] / safe_l[:, None]).astype(o_ref.dtype)
        lse_ref[0, 0] = jnp.where(l > 0.0, m_ref[...] + jnp.log(safe_l),
                                  NEG_INF)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "scale", "blk_q", "blk_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    scale: float | None = None,
                    blk_q: int = 128, blk_k: int = 128,
                    interpret: bool = False
                    ) -> tuple[jax.Array, jax.Array]:
    """q: (B, Hq, Sq, d); k/v: (B, Hkv, Sk, d), Hq % Hkv == 0.

    Sq % blk_q == 0 and Sk % blk_k == 0 (ops.py pads; the kernel masks
    padded positions via the true ``sq``/``sk`` carried statically).
    Returns (out (B, Hq, Sq, d), lse (B, Hq, Sq)).
    """
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    grid = (b, hq, sq // blk_q, sk // blk_k)
    return pl.pallas_call(
        functools.partial(_flash_kernel, blk_q=blk_q, blk_k=blk_k,
                          causal=causal, window=window, scale=scale,
                          sq=sq, sk=sk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, blk_q, d), lambda b_, h, i, j: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, blk_k, d),
                         lambda b_, h, i, j: (b_, h // group, j, 0)),
            pl.BlockSpec((1, 1, blk_k, d),
                         lambda b_, h, i, j: (b_, h // group, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, blk_q, d), lambda b_, h, i, j: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, blk_q), lambda b_, h, i, j: (b_, h, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(q.shape, q.dtype),
            jax.ShapeDtypeStruct((b, hq, sq), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((blk_q, d), jnp.float32),
            pltpu.VMEM((blk_q,), jnp.float32),
            pltpu.VMEM((blk_q,), jnp.float32),
        ],
        grid_spec=None,
        interpret=interpret,
    )(q, k, v)
