"""Public flash-attention wrapper: pads sequence dims to tile multiples,
switches to interpret mode off-TPU, and exposes a differentiable op —
the forward is the Pallas kernel; the backward is the XLA-native
recompute gradient of the oracle (the paper's serving regime never
backprops through attention; training falls back to a fused-by-XLA path,
recorded in DESIGN.md §2)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import kernel, ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(q, k, v, causal: bool = True, window: int = 0,
                    scale: float | None = None):
    return _forward(q, k, v, causal, window, scale)


def _forward(q, k, v, causal, window, scale):
    b, hq, sq, d = q.shape
    sk = k.shape[2]
    blk_q = min(128, max(8, sq))
    blk_k = min(128, max(8, sk))
    pad_q = (-sq) % blk_q
    pad_k = (-sk) % blk_k
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    # true lengths are carried via sq/sk inside the kernel mask
    out, _ = kernel.flash_attention(
        qp, kp, vp, causal=causal, window=window, scale=scale,
        blk_q=blk_q, blk_k=blk_k, interpret=not _on_tpu())
    # kernel masks by absolute position, but padded q rows still emit
    out = out[:, :, :sq]
    return out


def _fwd(q, k, v, causal, window, scale):
    return _forward(q, k, v, causal, window, scale), (q, k, v)


def _bwd(causal, window, scale, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: ref.attention(q_, k_, v_, causal=causal,
                                         window=window, scale=scale),
        q, k, v)
    return vjp(g)


flash_attention.defvjp(_fwd, _bwd)
