"""Pure-jnp dense-attention oracle (causal / sliding-window / GQA)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, window: int = 0,
              scale: float | None = None) -> jax.Array:
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    group = hq // hkv
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    q_pos = jnp.arange(sq)[:, None]
    k_pos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
        if not causal:
            mask &= (k_pos - q_pos) < window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    # fully-masked rows (possible under non-causal windows with sq != sk)
    # emit zeros — the flash-kernel convention — not a uniform artifact
    p = jnp.where(mask.any(axis=-1)[None, None, :, None], p, 0.0)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def attention_chunked(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True, window: int = 0,
                      scale: float | None = None,
                      q_chunk: int = 512) -> jax.Array:
    """XLA-native flash-memory attention: lax.map over query chunks keeps
    the live score plane at (B, H, q_chunk, S) instead of (B, H, S, S).

    This is the lowering used off-TPU (and by the dry-run): it mirrors the
    Pallas kernel's O(S·chunk) memory so ``memory_analysis`` reflects the
    TPU deployment, where the real kernel runs.
    """
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    group = hq // hkv
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    if sq % q_chunk != 0:
        return attention(q, k, v, causal=causal, window=window, scale=scale)
    kg = jnp.repeat(k, group, axis=1).astype(jnp.float32)
    vg = jnp.repeat(v, group, axis=1).astype(jnp.float32)
    k_pos = jnp.arange(sk)[None, :]

    def one_chunk(i):
        qi = jax.lax.dynamic_slice_in_dim(q, i * q_chunk, q_chunk, axis=2)
        s = jnp.einsum("bhqd,bhkd->bhqk", qi.astype(jnp.float32) * scale, kg)
        q_pos = i * q_chunk + jnp.arange(q_chunk)[:, None]
        mask = jnp.ones((q_chunk, sk), bool)
        if causal:
            mask &= q_pos >= k_pos
        if window > 0:
            mask &= (q_pos - k_pos) < window
            if not causal:
                mask &= (k_pos - q_pos) < window
        s = jnp.where(mask[None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        p = jnp.where(mask.any(axis=-1)[None, None, :, None], p, 0.0)
        return jnp.einsum("bhqk,bhkd->bhqd", p, vg).astype(q.dtype)

    chunks = jax.lax.map(one_chunk, jnp.arange(sq // q_chunk))
    return jnp.moveaxis(chunks, 0, 2).reshape(b, hq, sq, d)
