from repro.kernels.pq_adc import kernel, ops, ref
