"""PQ ADC scoring kernel (paper Eq. 4) — TPU-native design.

Problem: per query, a (m, k) inner-product LUT is known; each candidate
document is m uint8/int32 codes; its score is Σ_j lut[j, code_j].

GPU/Faiss does this with SIMD gathers through L1.  TPUs have no fast
per-lane gather from VMEM, so we *reformulate the gather as a one-hot
contraction* that runs on the MXU/VPU:

    score(c) = Σ_j  onehot(code_cj) · lut[j]        (k-wide dot)

Layout: codes arrive **fragment-major** ``(B, m, C)`` (the transpose is
done once at index-build; Faiss uses the same interleaved layout for its
SIMD path).  Candidate tiles of 128 keep every intermediate 128-lane
aligned; the one-hot plane per fragment is (C_blk, k) f32 = 128 KiB for
k=256 — far under VMEM even with double buffering.

Grid: (B, C / C_blk); the LUT block (1, m, k) is revisited across the
candidate dimension so it stays resident in VMEM for the whole query.

VMEM budget per grid step (m=96, k=256, C_blk=512):
    lut 96·256·4 = 98 KiB, codes 96·512·4 = 196 KiB,
    onehot 512·256·4 = 512 KiB, out 2 KiB   → ≈ 0.8 MiB ≪ 16 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _adc_kernel(lut_ref, codes_ref, out_ref, *, m: int, k: int, c_blk: int):
    lut = lut_ref[0]          # (m, k) f32
    codes = codes_ref[0]      # (m, c_blk) i32
    acc = jnp.zeros((c_blk,), jnp.float32)
    iota = jax.lax.broadcasted_iota(jnp.int32, (c_blk, k), 1)
    for j in range(m):        # static unroll — m ≤ 96
        onehot = (codes[j][:, None] == iota).astype(jnp.float32)  # (c_blk, k)
        acc = acc + jnp.dot(onehot, lut[j],
                            preferred_element_type=jnp.float32)
    out_ref[0] = acc


@functools.partial(jax.jit, static_argnames=("c_blk", "interpret"))
def pq_adc_fragmajor(lut: jax.Array, codes_fm: jax.Array, *,
                     c_blk: int = 512, interpret: bool = False) -> jax.Array:
    """lut: (B, m, k) f32; codes_fm: (B, m, C) i32 → scores (B, C) f32.

    C must be a multiple of ``c_blk`` (ops.py pads); k a multiple of 128.
    """
    b, m, k = lut.shape
    _, _, c = codes_fm.shape
    assert c % c_blk == 0, (c, c_blk)
    grid = (b, c // c_blk)
    return pl.pallas_call(
        functools.partial(_adc_kernel, m=m, k=k, c_blk=c_blk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, m, k), lambda bi, ci: (bi, 0, 0)),
            pl.BlockSpec((1, m, c_blk), lambda bi, ci: (bi, 0, ci)),
        ],
        out_specs=pl.BlockSpec((1, c_blk), lambda bi, ci: (bi, ci)),
        out_shape=jax.ShapeDtypeStruct((b, c), jnp.float32),
        interpret=interpret,
    )(lut, codes_fm)


# --------------------------------------------------------------------------
# fused gather + ADC (PR 6) — the whole within-list evaluation in one kernel
# --------------------------------------------------------------------------
#
# The unfused path above needs the caller to materialize the (B, C, m)
# candidate-code gather in HBM first (an XLA gather over the resident
# (N, m) plane), then streams that plane back through the ADC kernel —
# 2× the HBM traffic of the codes actually scored, plus the intermediate
# itself.  The fused kernel takes the *resident* plane and the (B, C)
# candidate ids and performs the row gather inside the kernel body:
#
#   · ids are scalar-prefetched (SMEM), so each row's HBM address is
#     known before the compute step runs;
#   · the codes plane stays in HBM (memory_space=ANY) and candidate
#     rows are DMA'd into a (c_blk, m) VMEM scratch, double-buffered so
#     row i+1 is in flight while row i lands;
#   · the live mask (dedup ∧ ¬tombstone ∧ namespace) is applied
#     in-kernel: masked lanes leave as -inf, so the (B, C) score plane
#     that reaches HBM is already selection-ready.
#
# Nothing of shape (B, C, m) ever exists — asserted over the jaxpr by
# tests/test_kernels.py.  Per-candidate accumulation order (fragment
# j = 0..m-1, one-hot dot per fragment) is identical to `_adc_kernel`,
# so fused and unfused *kernel* scores agree bitwise; only the pure-jnp
# oracle's m-reduction order differs (DESIGN.md §11 bounds it).


def _adc_fused_kernel(ids_ref, lut_ref, live_ref, plane_ref, out_ref,
                      codes_sc, sems, *, m: int, k: int, c_blk: int):
    b, ci = pl.program_id(0), pl.program_id(1)
    base = ci * c_blk

    def row_copy(i, slot):
        idx = ids_ref[b, base + i]
        return pltpu.make_async_copy(plane_ref.at[pl.ds(idx, 1)],
                                     codes_sc.at[pl.ds(i, 1)],
                                     sems.at[slot])

    row_copy(0, 0).start()

    def gather_body(i, _):
        @pl.when(i + 1 < c_blk)
        def _prefetch():
            row_copy(i + 1, (i + 1) % 2).start()

        row_copy(i, i % 2).wait()
        return 0

    jax.lax.fori_loop(0, c_blk, gather_body, 0)

    lut = lut_ref[0]                                   # (m, k) f32
    codes = codes_sc[...].astype(jnp.int32)            # (c_blk, m)
    acc = jnp.zeros((c_blk,), jnp.float32)
    iota = jax.lax.broadcasted_iota(jnp.int32, (c_blk, k), 1)
    for j in range(m):        # static unroll — same order as _adc_kernel
        onehot = (codes[:, j][:, None] == iota).astype(jnp.float32)
        acc = acc + jnp.dot(onehot, lut[j],
                            preferred_element_type=jnp.float32)
    out_ref[0] = jnp.where(live_ref[0] != 0, acc, -jnp.inf)


@functools.partial(jax.jit, static_argnames=("c_blk", "interpret"))
def pq_adc_fused(lut: jax.Array, codes_plane: jax.Array, ids: jax.Array,
                 live: jax.Array, *, c_blk: int = 256,
                 interpret: bool = False) -> jax.Array:
    """lut: (B, m, k) f32; codes_plane: (N, m) int; ids: (B, C) i32 in
    [0, N); live: (B, C) i32 (0 = masked) → scores (B, C) f32, ``-inf``
    on masked lanes.

    C must be a multiple of ``c_blk`` and k of 128 (ops.py pads both).
    The codes plane keeps its storage dtype (uint8 when k ≤ 256) all
    the way into VMEM; widening to i32 happens on-chip.
    """
    b, m, k = lut.shape
    n = codes_plane.shape[0]
    _, c = ids.shape
    assert c % c_blk == 0, (c, c_blk)
    del n
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, c // c_blk),
        in_specs=[
            pl.BlockSpec((1, m, k), lambda bi, ci, ids_ref: (bi, 0, 0)),
            pl.BlockSpec((1, c_blk), lambda bi, ci, ids_ref: (bi, ci)),
            pl.BlockSpec(memory_space=pltpu.ANY),      # resident plane
        ],
        out_specs=pl.BlockSpec((1, c_blk), lambda bi, ci, ids_ref: (bi, ci)),
        scratch_shapes=[
            pltpu.VMEM((c_blk, m), codes_plane.dtype),
            pltpu.SemaphoreType.DMA((2,)),
        ],
    )
    return pl.pallas_call(
        functools.partial(_adc_fused_kernel, m=m, k=k, c_blk=c_blk),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, c), jnp.float32),
        interpret=interpret,
    )(ids, lut, live, codes_plane)
