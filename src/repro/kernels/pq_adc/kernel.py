"""PQ ADC scoring kernel (paper Eq. 4) — TPU-native design.

Problem: per query, a (m, k) inner-product LUT is known; each candidate
document is m uint8/int32 codes; its score is Σ_j lut[j, code_j].

GPU/Faiss does this with SIMD gathers through L1.  TPUs have no fast
per-lane gather from VMEM, so we *reformulate the gather as a one-hot
contraction* that runs on the MXU/VPU:

    score(c) = Σ_j  onehot(code_cj) · lut[j]        (k-wide dot)

Layout: codes arrive **fragment-major** ``(B, m, C)`` (the transpose is
done once at index-build; Faiss uses the same interleaved layout for its
SIMD path).  Candidate tiles of 128 keep every intermediate 128-lane
aligned; the one-hot plane per fragment is (C_blk, k) f32 = 128 KiB for
k=256 — far under VMEM even with double buffering.

Grid: (B, C / C_blk); the LUT block (1, m, k) is revisited across the
candidate dimension so it stays resident in VMEM for the whole query.

VMEM budget per grid step (m=96, k=256, C_blk=512):
    lut 96·256·4 = 98 KiB, codes 96·512·4 = 196 KiB,
    onehot 512·256·4 = 512 KiB, out 2 KiB   → ≈ 0.8 MiB ≪ 16 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _adc_kernel(lut_ref, codes_ref, out_ref, *, m: int, k: int, c_blk: int):
    lut = lut_ref[0]          # (m, k) f32
    codes = codes_ref[0]      # (m, c_blk) i32
    acc = jnp.zeros((c_blk,), jnp.float32)
    iota = jax.lax.broadcasted_iota(jnp.int32, (c_blk, k), 1)
    for j in range(m):        # static unroll — m ≤ 96
        onehot = (codes[j][:, None] == iota).astype(jnp.float32)  # (c_blk, k)
        acc = acc + jnp.dot(onehot, lut[j],
                            preferred_element_type=jnp.float32)
    out_ref[0] = acc


@functools.partial(jax.jit, static_argnames=("c_blk", "interpret"))
def pq_adc_fragmajor(lut: jax.Array, codes_fm: jax.Array, *,
                     c_blk: int = 512, interpret: bool = False) -> jax.Array:
    """lut: (B, m, k) f32; codes_fm: (B, m, C) i32 → scores (B, C) f32.

    C must be a multiple of ``c_blk`` (ops.py pads); k a multiple of 128.
    """
    b, m, k = lut.shape
    _, _, c = codes_fm.shape
    assert c % c_blk == 0, (c, c_blk)
    grid = (b, c // c_blk)
    return pl.pallas_call(
        functools.partial(_adc_kernel, m=m, k=k, c_blk=c_blk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, m, k), lambda bi, ci: (bi, 0, 0)),
            pl.BlockSpec((1, m, c_blk), lambda bi, ci: (bi, 0, ci)),
        ],
        out_specs=pl.BlockSpec((1, c_blk), lambda bi, ci: (bi, ci)),
        out_shape=jax.ShapeDtypeStruct((b, c), jnp.float32),
        interpret=interpret,
    )(lut, codes_fm)
