"""Public jit'd wrapper for the PQ ADC kernel.

Handles layout (candidate-major → fragment-major), padding C to the tile
size, and the CPU/TPU switch: on non-TPU backends the pallas_call runs in
``interpret=True`` mode (the kernel body executed by XLA:CPU) so the same
code path is exercised everywhere.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.pq_adc import kernel, ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("c_blk", "use_kernel"))
def pq_adc(lut: jax.Array, codes: jax.Array, *, c_blk: int = 512,
           use_kernel: bool = True) -> jax.Array:
    """lut: (B, m, k) f32; codes: (B, C, m) i32 → scores (B, C) f32."""
    if not use_kernel:
        return ref.pq_adc(lut, codes)
    b, c, m = codes.shape
    pad = (-c) % c_blk
    codes_fm = jnp.swapaxes(codes, 1, 2)                     # (B, m, C)
    if pad:
        codes_fm = jnp.pad(codes_fm, ((0, 0), (0, 0), (0, pad)))
    out = kernel.pq_adc_fragmajor(lut, codes_fm, c_blk=c_blk,
                                  interpret=not _on_tpu())
    return out[:, :c]


@functools.partial(jax.jit, static_argnames=("c_blk", "use_kernel"))
def pq_adc_fused(lut: jax.Array, codes_plane: jax.Array, ids: jax.Array,
                 live: jax.Array, *, c_blk: int = 256,
                 use_kernel: bool = True) -> jax.Array:
    """Fused gather + ADC + mask over the *resident* codes plane.

    lut: (B, m, k) f32; codes_plane: (N, m) uint8/i32; ids: (B, C) i32
    in [0, N); live: (B, C) bool/i32 (falsy = masked) → (B, C) f32
    scores with ``-inf`` on masked lanes.  The candidate rows are
    gathered inside the kernel (DMA from the HBM-resident plane) — no
    (B, C, m) intermediate is ever allocated.

    Padding done here so the kernel sees aligned shapes only:
      · C → multiple of ``c_blk`` with ids=0 / live=0 (rows stripped
        after the call; id 0 keeps the in-kernel DMA in bounds);
      · k → multiple of 128 with zero LUT columns (codes < k never
        select them).
    """
    if not use_kernel:
        return ref.pq_adc_fused(lut, codes_plane, ids, live)
    b, m, k = lut.shape
    _, c = ids.shape
    k_pad = (-k) % 128
    if k_pad:
        lut = jnp.pad(lut, ((0, 0), (0, 0), (0, k_pad)))
    c_pad = (-c) % c_blk
    ids = jnp.clip(ids.astype(jnp.int32), 0, codes_plane.shape[0] - 1)
    live = live.astype(jnp.int32)
    if c_pad:
        ids = jnp.pad(ids, ((0, 0), (0, c_pad)))
        live = jnp.pad(live, ((0, 0), (0, c_pad)))
    out = kernel.pq_adc_fused(lut, codes_plane, ids, live, c_blk=c_blk,
                              interpret=not _on_tpu())
    return out[:, :c]
