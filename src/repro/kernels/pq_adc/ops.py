"""Public jit'd wrapper for the PQ ADC kernel.

Handles layout (candidate-major → fragment-major), padding C to the tile
size, and the CPU/TPU switch: on non-TPU backends the pallas_call runs in
``interpret=True`` mode (the kernel body executed by XLA:CPU) so the same
code path is exercised everywhere.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.pq_adc import kernel, ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("c_blk", "use_kernel"))
def pq_adc(lut: jax.Array, codes: jax.Array, *, c_blk: int = 512,
           use_kernel: bool = True) -> jax.Array:
    """lut: (B, m, k) f32; codes: (B, C, m) i32 → scores (B, C) f32."""
    if not use_kernel:
        return ref.pq_adc(lut, codes)
    b, c, m = codes.shape
    pad = (-c) % c_blk
    codes_fm = jnp.swapaxes(codes, 1, 2)                     # (B, m, C)
    if pad:
        codes_fm = jnp.pad(codes_fm, ((0, 0), (0, 0), (0, pad)))
    out = kernel.pq_adc_fragmajor(lut, codes_fm, c_blk=c_blk,
                                  interpret=not _on_tpu())
    return out[:, :c]
