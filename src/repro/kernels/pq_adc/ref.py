"""Pure-jnp oracle for the PQ ADC kernel (same math as core/codecs/pq.adc_score)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pq_adc(lut: jax.Array, codes: jax.Array) -> jax.Array:
    """lut: (B, m, k); codes: (B, C, m) i32 → (B, C) f32."""
    gathered = jnp.take_along_axis(
        lut[:, None],            # (B, 1, m, k)
        codes[..., None],        # (B, C, m, 1)
        axis=-1,
    )[..., 0]
    return jnp.sum(gathered, axis=-1).astype(jnp.float32)
