"""Pure-jnp oracle for the PQ ADC kernel (same math as core/codecs/pq.adc_score)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pq_adc(lut: jax.Array, codes: jax.Array) -> jax.Array:
    """lut: (B, m, k); codes: (B, C, m) i32 → (B, C) f32."""
    gathered = jnp.take_along_axis(
        lut[:, None],            # (B, 1, m, k)
        codes[..., None],        # (B, C, m, 1)
        axis=-1,
    )[..., 0]
    return jnp.sum(gathered, axis=-1).astype(jnp.float32)


def pq_adc_fused(lut: jax.Array, codes_plane: jax.Array, ids: jax.Array,
                 live: jax.Array) -> jax.Array:
    """Oracle for the fused op: gather rows, score, mask to ``-inf``.

    lut: (B, m, k); codes_plane: (N, m) int; ids: (B, C) i32;
    live: (B, C) bool/i32 → (B, C) f32.  This is the semantic spec —
    the kernel must agree up to m-reduction order (DESIGN.md §11).
    """
    ids = jnp.clip(ids.astype(jnp.int32), 0, codes_plane.shape[0] - 1)
    codes = codes_plane[ids].astype(jnp.int32)        # (B, C, m)
    scores = pq_adc(lut, codes)
    return jnp.where(live.astype(bool), scores, -jnp.inf)
