from repro.kernels.sq8_dot import kernel, ops, ref

__all__ = ["kernel", "ops", "ref"]
