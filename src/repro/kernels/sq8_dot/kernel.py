"""Fused gather + dequantized dot for the SQ8 codec (DESIGN.md §7, §11).

SQ8 scoring is ⟨q·scale, code⟩ + ⟨q, lo⟩: a pre-scaled dot over the
gathered byte rows plus a per-query bias.  The unfused path gathers the
(B, C, h) byte rows in HBM first; this kernel keeps the (N, h) codes
plane resident in HBM and DMAs candidate rows straight into VMEM —
the same scalar-prefetch + double-buffered-copy structure as
``pq_adc/kernel._adc_fused_kernel``, with the one-hot ADC loop replaced
by a single (c_blk, h)·(h,) MXU dot.

The live mask is applied in-kernel (-inf); the per-query bias is added
*outside* by the caller after masking (-inf + bias = -inf, so masked
lanes stay -inf) — keeping the kernel bias-free means the mask needs no
special-casing.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _sq8_fused_kernel(ids_ref, q_ref, live_ref, plane_ref, out_ref,
                      rows_sc, sems, *, h: int, c_blk: int):
    b, ci = pl.program_id(0), pl.program_id(1)
    base = ci * c_blk

    def row_copy(i, slot):
        idx = ids_ref[b, base + i]
        return pltpu.make_async_copy(plane_ref.at[pl.ds(idx, 1)],
                                     rows_sc.at[pl.ds(i, 1)],
                                     sems.at[slot])

    row_copy(0, 0).start()

    def gather_body(i, _):
        @pl.when(i + 1 < c_blk)
        def _prefetch():
            row_copy(i + 1, (i + 1) % 2).start()

        row_copy(i, i % 2).wait()
        return 0

    jax.lax.fori_loop(0, c_blk, gather_body, 0)

    q = q_ref[0]                                       # (h,) f32, pre-scaled
    rows = rows_sc[...].astype(jnp.float32)            # (c_blk, h)
    acc = jnp.dot(rows, q, preferred_element_type=jnp.float32)
    out_ref[0] = jnp.where(live_ref[0] != 0, acc, -jnp.inf)


@functools.partial(jax.jit, static_argnames=("c_blk", "interpret"))
def sq8_dot_fused(q_scaled: jax.Array, codes_plane: jax.Array,
                  ids: jax.Array, live: jax.Array, *, c_blk: int = 256,
                  interpret: bool = False) -> jax.Array:
    """q_scaled: (B, h) f32; codes_plane: (N, h) u8; ids: (B, C) i32 in
    [0, N); live: (B, C) i32 → (B, C) f32 bias-free scores, ``-inf`` on
    masked lanes.  C must be a multiple of ``c_blk`` (ops.py pads)."""
    b, h = q_scaled.shape
    _, c = ids.shape
    assert c % c_blk == 0, (c, c_blk)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, c // c_blk),
        in_specs=[
            pl.BlockSpec((1, h), lambda bi, ci, ids_ref: (bi, 0)),
            pl.BlockSpec((1, c_blk), lambda bi, ci, ids_ref: (bi, ci)),
            pl.BlockSpec(memory_space=pltpu.ANY),      # resident plane
        ],
        out_specs=pl.BlockSpec((1, c_blk), lambda bi, ci, ids_ref: (bi, ci)),
        scratch_shapes=[
            pltpu.VMEM((c_blk, h), codes_plane.dtype),
            pltpu.SemaphoreType.DMA((2,)),
        ],
    )
    return pl.pallas_call(
        functools.partial(_sq8_fused_kernel, h=h, c_blk=c_blk),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, c), jnp.float32),
        interpret=interpret,
    )(ids, q_scaled, live, codes_plane)
