"""Public jit'd wrapper for the fused SQ8 gather+dot kernel: pads C to
the tile size, clips ids defensively, and switches to interpret mode
off-TPU so CPU CI runs the same kernel body."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.sq8_dot import kernel, ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("c_blk", "use_kernel"))
def sq8_dot_fused(q_scaled: jax.Array, codes_plane: jax.Array,
                  ids: jax.Array, live: jax.Array, *, c_blk: int = 256,
                  use_kernel: bool = True) -> jax.Array:
    """Fused gather + dequantized dot + mask over the resident plane.

    q_scaled: (B, h) f32 (queries already multiplied by the per-dim
    scale); codes_plane: (N, h) u8; ids: (B, C); live: (B, C) → (B, C)
    f32 *bias-free* scores, ``-inf`` on masked lanes.  The caller adds
    the per-query ⟨q, lo⟩ bias afterwards (-inf survives the add).
    """
    if not use_kernel:
        return ref.sq8_dot_fused(q_scaled, codes_plane, ids, live)
    _, c = ids.shape
    c_pad = (-c) % c_blk
    ids = jnp.clip(ids.astype(jnp.int32), 0, codes_plane.shape[0] - 1)
    live = live.astype(jnp.int32)
    if c_pad:
        ids = jnp.pad(ids, ((0, 0), (0, c_pad)))
        live = jnp.pad(live, ((0, 0), (0, c_pad)))
    out = kernel.sq8_dot_fused(q_scaled, codes_plane, ids, live,
                               c_blk=c_blk, interpret=not _on_tpu())
    return out[:, :c]
