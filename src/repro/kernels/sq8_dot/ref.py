"""Pure-jnp oracle for the fused SQ8 gather+dot kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sq8_dot_fused(q_scaled: jax.Array, codes_plane: jax.Array,
                  ids: jax.Array, live: jax.Array) -> jax.Array:
    """q_scaled: (B, h); codes_plane: (N, h) u8; ids/live: (B, C) →
    (B, C) f32 bias-free scores, ``-inf`` where not live."""
    ids = jnp.clip(ids.astype(jnp.int32), 0, codes_plane.shape[0] - 1)
    rows = codes_plane[ids].astype(jnp.float32)        # (B, C, h)
    scores = jnp.einsum("bh,bch->bc", q_scaled.astype(jnp.float32), rows)
    return jnp.where(live.astype(bool), scores, -jnp.inf)
