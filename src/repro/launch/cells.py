"""Dry-run cell construction: for every (architecture × input shape)
pair, the concrete step function, abstract inputs (ShapeDtypeStruct —
never allocated), and in_shardings for the production mesh.

Cell kinds
    lm/train      train_step  = value_and_grad(loss) + clip + AdamW
    lm/prefill    prefill_step (full forward emitting KV caches)
    lm/decode     serve_step   (1 token vs a seq_len KV cache)
    gnn/*         train_step over padded GraphBatch
    recsys/train  train_step over click batches
    recsys/serve  forward scoring
    recsys/retrieval   1 query × 10⁶ candidates top-R

Padding policy: GNN node/edge counts are padded up to multiples of 512
(PAD entries are masked in the model); all other assigned dims divide
the mesh axes exactly.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import registry, shapes as sh
from repro.distributed import sharding as shd
from repro.models import attention, gnn, recsys, transformer as tfm
from repro.models.gnn import GraphBatch
from repro.models.recsys import DIENBatch, DLRMBatch, MINDBatch, SASRecBatch
from repro.optim import AdamConfig, adam_init, adam_update, clip_by_global_norm

Array = jax.Array

ADAM = AdamConfig(lr=1e-4, weight_decay=0.0)


@dataclasses.dataclass
class Cell:
    arch_id: str
    shape_name: str
    kind: str
    fn: Callable
    args: tuple                 # abstract args (ShapeDtypeStruct pytrees)
    in_shardings: tuple
    donate_argnums: tuple
    rules: dict                 # sharding-rule overrides used for this cell

    @property
    def name(self) -> str:
        return f"{self.arch_id}/{self.shape_name}"


def _sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _abstract(fn, *args):
    return jax.eval_shape(fn, *args)


def _shardings_by_path(tree, rule_fn):
    """NamedSharding pytree from a (path_str, ndim) -> logical-axes fn."""
    def one(path, leaf):
        axes = rule_fn(jax.tree_util.keystr(path), len(leaf.shape))
        return shd.named_sharding(*axes)
    return jax.tree_util.tree_map_with_path(one, tree)


def _replicated(tree):
    return jax.tree.map(lambda l: shd.named_sharding(*([None] * len(l.shape))),
                        tree)


def _batch_sharded(tree, axis: str = "batch"):
    return jax.tree.map(
        lambda l: shd.named_sharding(axis, *([None] * (len(l.shape) - 1))),
        tree)


def _adam_shardings(param_sh):
    from repro.optim.adam import AdamState
    return AdamState(step=shd.named_sharding(),
                     mu=param_sh, nu=jax.tree.map(lambda x: x, param_sh))


# --------------------------------------------------------------------------
# LM family
# --------------------------------------------------------------------------

def _lm_param_axes(path: str, ndim: int) -> tuple:
    """TP over heads/ff/vocab (model axis) × FSDP over d_model (data axis).

    The FSDP ("fsdp" → data) factor is what lets Mixtral-8x22B's 141B
    parameters + Adam state fit a v5e pod: TP alone leaves 140+ GB per
    device; ZeRO-3 sharding brings it to ~9 GB (weights are all-gathered
    at use inside the layer scan — the standard FSDP exchange).
    """
    if "embed" in path and "unembed" not in path:
        return ("vocab", "fsdp")
    if "unembed" in path:
        return ("fsdp", "vocab")
    if "['moe']" in path:
        if "router" in path:
            return (None, "fsdp", None)
        if "w_down" in path:
            return (None, "experts", "expert_ff", "fsdp")
        return (None, "experts", "fsdp", "expert_ff")    # w_gate / w_up
    if "['attn']" in path:
        if "wo" in path:
            return (None, "heads", "fsdp")
        if "wq" in path:
            return (None, "fsdp", "heads")
        return (None, "fsdp", "kv_joint")                # wk / wv columns
    if "['mlp']" in path:
        if "w_down" in path:
            return (None, "ff", "fsdp")
        return (None, "fsdp", "ff")                      # w_gate / w_up
    return tuple([None] * ndim)                          # norms etc.


def _lm_rules(cfg: tfm.TransformerConfig, mesh: Mesh, kind: str) -> dict:
    model_size = mesh.shape.get("model", 1)
    kv_sharded = cfg.n_kv_heads % model_size == 0
    rules: dict[str, Any] = {
        "kv_joint": ("model" if (cfg.n_kv_heads * cfg.head_dim)
                     % model_size == 0 else None),
        "kv_heads": "model" if kv_sharded else None,
    }
    if kind == "decode":
        rules["seq"] = None
        # decode cache capacity comes from kv_heads OR head_dim on the
        # model axis (never seq: dynamic-update-slice along a sharded dim
        # forces full rematerialization in GSPMD)
        if not kv_sharded and cfg.head_dim % model_size == 0:
            rules["head_dim"] = "model"
    return rules


def _lm_train_cell(arch, shape: sh.LMShape, cfg) -> Cell:
    def train_step(params, opt_state, batch):
        def loss(p):
            return tfm.loss_fn(p, cfg, batch["tokens"], batch["labels"])
        (l, metrics), grads = jax.value_and_grad(loss, has_aux=True)(params)
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        params, opt_state = adam_update(grads, opt_state, params, ADAM)
        metrics = dict(metrics, grad_norm=gnorm)
        return params, opt_state, metrics

    params_a = _abstract(functools.partial(tfm.init, cfg=cfg),
                         jax.random.key(0))
    opt_a = _abstract(adam_init, params_a)
    batch_a = {"tokens": _sds((shape.global_batch, shape.seq_len), jnp.int32),
               "labels": _sds((shape.global_batch, shape.seq_len), jnp.int32)}
    p_sh = _shardings_by_path(params_a, _lm_param_axes)
    return Cell(arch.arch_id, shape.name, "lm/train", train_step,
                (params_a, opt_a, batch_a),
                (p_sh, _adam_shardings(p_sh), _batch_sharded(batch_a)),
                donate_argnums=(0, 1), rules={})


def _lm_prefill_cell(arch, shape: sh.LMShape, cfg) -> Cell:
    def prefill(params, tokens):
        return tfm.prefill_step(params, cfg, tokens)

    params_a = _abstract(functools.partial(tfm.init, cfg=cfg),
                         jax.random.key(0))
    tokens_a = _sds((shape.global_batch, shape.seq_len), jnp.int32)
    p_sh = _shardings_by_path(params_a, _lm_param_axes)
    return Cell(arch.arch_id, shape.name, "lm/prefill", prefill,
                (params_a, tokens_a),
                (p_sh, shd.named_sharding("batch", None)),
                donate_argnums=(), rules={})


def _lm_decode_cell(arch, shape: sh.LMShape, cfg, rules: dict) -> Cell:
    def decode(params, caches, tokens_new, pos):
        return tfm.serve_step(params, cfg, caches, tokens_new, pos)

    params_a = _abstract(functools.partial(tfm.init, cfg=cfg),
                         jax.random.key(0))
    caches_a = _abstract(
        functools.partial(tfm.init_decode_caches, cfg, shape.global_batch,
                          shape.seq_len))
    tokens_a = _sds((shape.global_batch, 1), jnp.int32)
    pos_a = _sds((), jnp.int32)
    p_sh = _shardings_by_path(params_a, _lm_param_axes)
    cache_sh = attention.KVCache(
        k=shd.named_sharding(None, "batch", "kv_heads", None, "head_dim"),
        v=shd.named_sharding(None, "batch", "kv_heads", None, "head_dim"),
        cache_pos=shd.named_sharding(None, None))
    return Cell(arch.arch_id, shape.name, "lm/decode", decode,
                (params_a, caches_a, tokens_a, pos_a),
                (p_sh, cache_sh,
                 shd.named_sharding("batch", None), shd.named_sharding()),
                donate_argnums=(1,), rules=rules)


# --------------------------------------------------------------------------
# GNN family
# --------------------------------------------------------------------------

def _gnn_abstract_batch(shape: sh.GNNShape, cfg) -> GraphBatch:
    if shape.kind == "minibatch":
        seeds = shape.batch_nodes
        n_nodes = seeds
        n_edges = 0
        frontier = seeds
        for f in shape.fanout:
            n_edges += frontier * f
            frontier *= f
            n_nodes += frontier
    elif shape.kind == "molecule":
        n_nodes = shape.batch_graphs * shape.n_nodes
        n_edges = shape.batch_graphs * shape.n_edges
    else:
        n_nodes, n_edges = shape.n_nodes, shape.n_edges
    n_nodes = sh.pad_to_multiple(n_nodes, 512)
    n_edges = sh.pad_to_multiple(n_edges, 512)
    n_graphs = shape.batch_graphs if shape.kind == "molecule" else 1
    labels_shape = (n_graphs,) if shape.kind == "molecule" else (n_nodes,)
    return GraphBatch(
        node_feat=_sds((n_nodes, shape.d_feat), jnp.float32),
        edge_src=_sds((n_edges,), jnp.int32),
        edge_dst=_sds((n_edges,), jnp.int32),
        edge_mask=_sds((n_edges,), jnp.float32),
        node_mask=_sds((n_nodes,), jnp.float32),
        labels=_sds(labels_shape, jnp.int32),
        graph_id=_sds((n_nodes,), jnp.int32),
        n_graphs=n_graphs)


def _gnn_cell(arch, shape: sh.GNNShape) -> Cell:
    cfg = arch.make_config(shape)
    loss = (gnn.loss_fn_partitioned if cfg.impl == "partitioned"
            and not cfg.graph_level else gnn.loss_fn)

    def train_step(params, opt_state, batch):
        (l, metrics), grads = jax.value_and_grad(
            lambda p: loss(p, cfg, batch), has_aux=True)(params)
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        params, opt_state = adam_update(grads, opt_state, params, ADAM)
        return params, opt_state, dict(metrics, grad_norm=gnorm)

    params_a = _abstract(functools.partial(gnn.init, cfg=cfg),
                         jax.random.key(0))
    opt_a = _abstract(adam_init, params_a)
    batch_a = _gnn_abstract_batch(shape, cfg)
    p_sh = _replicated(params_a)
    batch_sh = GraphBatch(
        node_feat=shd.named_sharding("nodes", None),
        edge_src=shd.named_sharding("edges"),
        edge_dst=shd.named_sharding("edges"),
        edge_mask=shd.named_sharding("edges"),
        node_mask=shd.named_sharding("nodes"),
        labels=shd.named_sharding(None if cfg.graph_level else "nodes"),
        graph_id=shd.named_sharding("nodes"),
        n_graphs=batch_a.n_graphs)
    rules = ({"nodes": ("data", "model")}
             if cfg.impl == "partitioned" else {"nodes": "model"})
    return Cell(arch.arch_id, shape.name, f"gnn/{shape.kind}", train_step,
                (params_a, opt_a, batch_a),
                (p_sh, _adam_shardings(p_sh), batch_sh),
                donate_argnums=(0, 1),
                rules=rules)


# --------------------------------------------------------------------------
# RecSys family
# --------------------------------------------------------------------------

_REC_LOSS = {
    "dlrm-rm2": (recsys.dlrm_loss, recsys.dlrm_init),
    "sasrec": (recsys.sasrec_loss, recsys.sasrec_init),
    "dien": (recsys.dien_loss, recsys.dien_init),
    "mind": (recsys.mind_loss, recsys.mind_init),
}


def _rec_abstract_batch(arch_id: str, cfg, batch: int):
    if arch_id == "dlrm-rm2":
        return DLRMBatch(dense=_sds((batch, cfg.n_dense), jnp.float32),
                         sparse=_sds((batch, cfg.n_sparse), jnp.int32),
                         labels=_sds((batch,), jnp.float32))
    if arch_id == "sasrec":
        s = (batch, cfg.seq_len)
        return SASRecBatch(items=_sds(s, jnp.int32),
                           targets=_sds(s, jnp.int32),
                           negatives=_sds(s, jnp.int32))
    if arch_id == "dien":
        return DIENBatch(history=_sds((batch, cfg.seq_len), jnp.int32),
                         target=_sds((batch,), jnp.int32),
                         labels=_sds((batch,), jnp.float32))
    if arch_id == "mind":
        return MINDBatch(history=_sds((batch, cfg.seq_len), jnp.int32),
                         target=_sds((batch,), jnp.int32),
                         negatives=_sds((batch, 10), jnp.int32))
    raise KeyError(arch_id)


def _rec_param_axes(path: str, ndim: int) -> tuple:
    if "tables" in path:                       # DLRM (F, R, D)
        return (None, "table", None)
    if "item_embed" in path:                   # (R, D)
        return ("table", None)
    return tuple([None] * ndim)


def _rec_train_cell(arch, shape: sh.RecShape, cfg) -> Cell:
    loss_fn, init_fn = _REC_LOSS[arch.arch_id]

    def train_step(params, opt_state, batch):
        (l, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch), has_aux=True)(params)
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        params, opt_state = adam_update(grads, opt_state, params, ADAM)
        return params, opt_state, dict(metrics, grad_norm=gnorm)

    params_a = _abstract(functools.partial(init_fn, cfg=cfg),
                         jax.random.key(0))
    opt_a = _abstract(adam_init, params_a)
    batch_a = _rec_abstract_batch(arch.arch_id, cfg, shape.batch)
    p_sh = _shardings_by_path(params_a, _rec_param_axes)
    return Cell(arch.arch_id, shape.name, "recsys/train", train_step,
                (params_a, opt_a, batch_a),
                (p_sh, _adam_shardings(p_sh), _batch_sharded(batch_a)),
                donate_argnums=(0, 1), rules={})


def _rec_serve_cell(arch, shape: sh.RecShape, cfg) -> Cell:
    loss_fn, init_fn = _REC_LOSS[arch.arch_id]
    fwd = {
        "dlrm-rm2": lambda p, b: recsys.dlrm_forward(p, cfg, b),
        "sasrec": lambda p, b: recsys.sasrec_user_embedding(p, cfg, b.items),
        "dien": lambda p, b: recsys.dien_forward(p, cfg, b),
        "mind": lambda p, b: recsys.mind_interests(p, cfg, b.history),
    }[arch.arch_id]

    params_a = _abstract(functools.partial(init_fn, cfg=cfg),
                         jax.random.key(0))
    batch_a = _rec_abstract_batch(arch.arch_id, cfg, shape.batch)
    p_sh = _shardings_by_path(params_a, _rec_param_axes)
    return Cell(arch.arch_id, shape.name, "recsys/serve", fwd,
                (params_a, batch_a),
                (p_sh, _batch_sharded(batch_a)),
                donate_argnums=(), rules={})


def _rec_retrieval_cell(arch, shape: sh.RecShape, cfg) -> Cell:
    n_cand = shape.n_candidates
    params_a = _abstract(
        functools.partial(_REC_LOSS[arch.arch_id][1], cfg=cfg),
        jax.random.key(0))
    p_sh = _shardings_by_path(params_a, _rec_param_axes)
    rep = shd.named_sharding
    if arch.arch_id == "sasrec":
        def fn(p, items):
            return recsys.sasrec_retrieval(p, cfg, items)
        args = (params_a, _sds((1, cfg.seq_len), jnp.int32))
        in_sh = (p_sh, rep(None, None))
    elif arch.arch_id == "mind":
        def fn(p, hist):
            return recsys.mind_retrieval(p, cfg, hist)
        args = (params_a, _sds((1, cfg.seq_len), jnp.int32))
        in_sh = (p_sh, rep(None, None))
    elif arch.arch_id == "dien":
        def fn(p, hist, cand):
            return recsys.dien_retrieval(p, cfg, hist, cand)
        args = (params_a, _sds((1, cfg.seq_len), jnp.int32),
                _sds((n_cand,), jnp.int32))
        in_sh = (p_sh, rep(None, None), rep("candidates"))
    else:  # dlrm
        def fn(p, dense, ctx, cand):
            return recsys.dlrm_retrieval(p, cfg, dense, ctx, cand)
        args = (params_a, _sds((1, cfg.n_dense), jnp.float32),
                _sds((1, cfg.n_sparse - 1), jnp.int32),
                _sds((n_cand,), jnp.int32))
        in_sh = (p_sh, rep(None, None), rep(None, None), rep("candidates"))
    return Cell(arch.arch_id, shape.name, "recsys/retrieval", fn, args,
                in_sh, donate_argnums=(), rules={})


# --------------------------------------------------------------------------
# hi2-synth: the paper's own serving step at MS MARCO scale (extra cell)
# --------------------------------------------------------------------------

def _hi2_abstract_index(shape, filtered: bool = False):
    from repro.core import cluster_selector as cs_mod
    from repro.core import codecs
    from repro.core import hybrid_index as hixm
    from repro.core import inverted_lists as il
    from repro.core import term_selector as ts_mod
    h, L, V = shape.hidden, shape.n_clusters, shape.vocab
    # codec state as ShapeDtypeStructs, via the registry (DESIGN.md §7)
    params_a, planes_a = codecs.get(shape.codec).abstract(
        shape.n_docs, h, pq_m=shape.pq_m, pq_k=shape.pq_k)
    return hixm.HybridIndex(
        cluster_sel=cs_mod.ClusterSelector(
            embeddings=_sds((L, h), jnp.float32)),
        term_sel=ts_mod.TermSelector(avg_scores=_sds((V,), jnp.float32)),
        cluster_lists=il.PaddedLists(
            entries=_sds((L, shape.cluster_capacity), jnp.int32),
            lengths=_sds((L,), jnp.int32)),
        term_lists=il.PaddedLists(
            entries=_sds((V, shape.term_capacity), jnp.int32),
            lengths=_sds((V,), jnp.int32)),
        codec_params=params_a,
        doc_planes=planes_a,
        doc_assign=_sds((shape.n_docs,), jnp.int32),
        doc_ns=_sds((shape.n_docs,), jnp.int32) if filtered else None,
        codec=shape.codec)


def _hi2_serve_cell(arch, shape) -> Cell:
    from repro.core import hybrid_index as hixm

    def serve(index, q_emb, q_tokens):
        return hixm.search(index, q_emb, q_tokens, kc=shape.kc, k2=shape.k2,
                           top_r=shape.top_r)

    index_a = _hi2_abstract_index(shape)
    qe_a = _sds((shape.query_batch, shape.hidden), jnp.float32)
    qt_a = _sds((shape.query_batch, shape.query_len), jnp.int32)
    # index planes doc/list-sharded over the model axis; queries over data
    rep = shd.named_sharding
    from repro.core import cluster_selector as cs_mod
    from repro.core import hybrid_index as hixm2
    from repro.core import inverted_lists as il
    from repro.core import term_selector as ts_mod
    index_sh = hixm2.HybridIndex(
        cluster_sel=cs_mod.ClusterSelector(embeddings=rep("clusters", None)),
        term_sel=ts_mod.TermSelector(avg_scores=rep(None)),
        cluster_lists=il.PaddedLists(entries=rep("clusters", None),
                                     lengths=rep("clusters")),
        term_lists=il.PaddedLists(entries=rep("vocab", None),
                                  lengths=rep("vocab")),
        # codec params replicated, every doc plane sharded on axis 0
        codec_params=jax.tree.map(
            lambda s: rep(*(None,) * s.ndim), index_a.codec_params),
        doc_planes=jax.tree.map(
            lambda s: rep("docs", *(None,) * (s.ndim - 1)),
            index_a.doc_planes),
        doc_assign=rep("docs"),
        codec=shape.codec)
    rules = {"clusters": "model", "docs": "model", "vocab": "model"}
    return Cell(arch.arch_id, shape.name, "hi2/serve", serve,
                (index_a, qe_a, qt_a),
                (index_sh, rep("batch", None), rep("batch", None)),
                donate_argnums=(), rules=rules)


def _hi2_filtered_serve_cell(arch, shape) -> Cell:
    """Filtered HI² serving (DESIGN.md §9): the §2 serving step with a
    per-query namespace bitmap flowing through the exec layer's filter
    stage.  The ``doc_ns`` plane rides the docs axis like every codec
    plane; the (batch, ⌈N/32⌉) u32 bitmap rides the batch axis like the
    queries — zero replicated state beyond what unfiltered serving has."""
    from repro.core import hybrid_index as hixm
    from repro.core.exec import filters as ns_filters

    def serve(index, q_emb, q_tokens, ns_filter):
        return hixm.search(index, q_emb, q_tokens, kc=shape.kc, k2=shape.k2,
                           top_r=shape.top_r, filter=ns_filter)

    base = _hi2_serve_cell(arch, shape)     # reuse the §2 cell's shardings
    index_a = _hi2_abstract_index(shape, filtered=True)
    index_sh = dataclasses.replace(base.in_shardings[0],
                                   doc_ns=shd.named_sharding("docs"))
    w = ns_filters.n_words(shape.n_namespaces)
    filt_a = _sds((shape.query_batch, w), jnp.uint32)
    rep = shd.named_sharding
    return Cell(arch.arch_id, shape.name, "hi2/serve_filtered", serve,
                (index_a, base.args[1], base.args[2], filt_a),
                (index_sh, base.in_shardings[1], base.in_shardings[2],
                 rep("batch", None)),
                donate_argnums=(), rules=base.rules)


def _hi2_sharded_serve_cell(arch, shape, mesh: Mesh) -> Cell:
    """Document-sharded HI² serving on the production mesh (DESIGN.md
    §6): index shards ride the model axis, the query batch the data
    axis.  Exercises the same shard_map step ``launch/serve.py`` runs
    at CPU scale, at MS MARCO shapes."""
    from repro.core import codecs
    from repro.core import sharded_index as shi

    n_shards = mesh.shape["model"]
    per = -(-shape.n_docs // n_shards)
    step = shi.make_search_step(mesh, "model", shape.codec, per, shape.kc,
                                shape.k2, shape.top_r, batch_axis="data")

    h, L, V = shape.hidden, shape.n_clusters, shape.vocab
    # per-shard codec planes/params from the registry's abstract shapes
    codec_params_a, codec_planes_a = codecs.get(shape.codec).abstract(
        per, h, pq_m=shape.pq_m, pq_k=shape.pq_k)
    planes_a = {
        "cluster_entries": _sds((n_shards, L, shape.cluster_capacity),
                                jnp.int32),
        "cluster_lengths": _sds((n_shards, L), jnp.int32),
        "term_entries": _sds((n_shards, V, shape.term_capacity), jnp.int32),
        "term_lengths": _sds((n_shards, V), jnp.int32),
        "codec": jax.tree.map(
            lambda s: _sds((n_shards,) + s.shape, s.dtype), codec_planes_a),
    }
    rep_a = {
        "cluster_emb": _sds((L, h), jnp.float32),
        "term_avg": _sds((V,), jnp.float32),
        "codec": codec_params_a,
    }
    qe_a = _sds((shape.query_batch, h), jnp.float32)
    qt_a = _sds((shape.query_batch, shape.query_len), jnp.int32)

    def ns(*axes):
        return NamedSharding(mesh, P(*axes))

    planes_sh = jax.tree.map(
        lambda s: ns("model", *(None,) * (s.ndim - 1)), planes_a)
    rep_sh = jax.tree.map(lambda s: ns(*(None,) * s.ndim), rep_a)
    return Cell(arch.arch_id, shape.name, "hi2/serve_sharded", step,
                (planes_a, rep_a, qe_a, qt_a),
                (planes_sh, rep_sh, ns("data", None), ns("data", None)),
                donate_argnums=(), rules={})


# --------------------------------------------------------------------------
# dispatch
# --------------------------------------------------------------------------

def build_cell(arch_id: str, shape_name: str, mesh: Mesh) -> Cell:
    """Build the cell *under* the mesh's sharding rules (two passes: the
    rule overrides are decided per cell, then shardings are materialized
    inside a use_mesh(rules) context)."""
    arch = registry.get(arch_id)
    shape = arch.shapes[shape_name]

    # decide rule overrides first
    rules: dict[str, Any] = {}
    if arch.family == "hi2":
        if shape.kind == "hi2_serve_sharded":
            # all shardings are explicit NamedShardings; no rule context
            return _hi2_sharded_serve_cell(arch, shape, mesh)
        rules = {"clusters": "model", "docs": "model", "vocab": "model"}
        if shape.kind == "hi2_serve_bucket":
            # runtime micro-batch buckets (DESIGN.md §10) are smaller
            # than the data axis — the query batch replicates
            rules["batch"] = None
        with shd.use_mesh(mesh, rules):
            if shape.kind == "hi2_serve_filtered":
                cell = _hi2_filtered_serve_cell(arch, shape)
            else:
                cell = _hi2_serve_cell(arch, shape)
        cell.rules = rules      # lower_cell re-enters use_mesh with these
        return cell
    if arch.family == "lm":
        cfg = arch.make_config(shape)
        rules = _lm_rules(cfg, mesh, shape.kind)
        if shape.name == "long_500k":
            rules["batch"] = None        # batch=1 cannot shard
    elif arch.family == "gnn":
        rules = {"nodes": "model"}
    elif arch.family == "recsys" and shape.kind == "retrieval":
        rules = {"batch": None}

    with shd.use_mesh(mesh, rules):
        if arch.family == "lm":
            if shape.kind == "train":
                cell = _lm_train_cell(arch, shape, cfg)
            elif shape.kind == "prefill":
                cell = _lm_prefill_cell(arch, shape, cfg)
            else:
                cell = _lm_decode_cell(arch, shape, cfg, rules)
        elif arch.family == "gnn":
            cell = _gnn_cell(arch, shape)
        else:
            cfg = arch.make_config(shape)
            if shape.kind == "train":
                cell = _rec_train_cell(arch, shape, cfg)
            elif shape.kind == "serve":
                cell = _rec_serve_cell(arch, shape, cfg)
            else:
                cell = _rec_retrieval_cell(arch, shape, cfg)
    cell.rules = rules
    return cell


def lower_cell(cell: Cell, mesh: Mesh):
    """jit → lower under the cell's mesh+rules. Returns the Lowered."""
    with shd.use_mesh(mesh, cell.rules):
        jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                         donate_argnums=cell.donate_argnums)
        return jitted.lower(*cell.args)
