import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import/init: jax locks the device count on first use.
"""Multi-pod dry-run: lower + compile every (architecture × input shape)
cell on the production meshes and record memory/cost/collective analysis.

    PYTHONPATH=src python -m repro.launch.dryrun                  # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --multi-pod      # 2×16×16

Success criterion (the brief): ``.lower().compile()`` must succeed for
every cell on the 16×16 single-pod mesh AND the (2,16,16) multi-pod
mesh; ``memory_analysis()`` proves the per-device footprint fits a v5e
(16 GB HBM); cost/collective numbers feed EXPERIMENTS.md §Roofline.
"""
import argparse
import json
import time
import traceback


def run_cell(arch_id: str, shape_name: str, multi_pod: bool,
             out_dir: str | None = None, save_hlo: bool = False) -> dict:
    import jax
    from repro.configs import registry
    from repro.launch import cells as cells_mod
    from repro.launch import mesh as mesh_mod
    from repro.launch import roofline

    arch = registry.get(arch_id)
    if shape_name in arch.skip_shapes:
        return {"cell": f"{arch_id}/{shape_name}", "status": "skipped",
                "reason": arch.skip_shapes[shape_name]}

    mesh = mesh_mod.make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    record: dict = {"cell": f"{arch_id}/{shape_name}",
                    "mesh": "x".join(str(s) for s in mesh.devices.shape),
                    "n_devices": mesh.devices.size}
    try:
        cell = cells_mod.build_cell(arch_id, shape_name, mesh)
        with mesh:
            lowered = cells_mod.lower_cell(cell, mesh)
            record["lower_s"] = round(time.time() - t0, 1)
            t1 = time.time()
            compiled = lowered.compile()
            record["compile_s"] = round(time.time() - t1, 1)

            # NOTE: memory_analysis() reports the *per-device* SPMD module
            # (verified empirically: argument bytes match the sharded
            # shapes) — no further division by device count.
            mem = compiled.memory_analysis()
            record["memory"] = {
                "argument_gb": mem.argument_size_in_bytes / 2**30,
                "output_gb": mem.output_size_in_bytes / 2**30,
                "temp_gb": mem.temp_size_in_bytes / 2**30,
                "alias_gb": mem.alias_size_in_bytes / 2**30,
                "per_device_gb": (mem.argument_size_in_bytes
                                  + mem.output_size_in_bytes
                                  + mem.temp_size_in_bytes
                                  - mem.alias_size_in_bytes) / 2**30,
            }
            hlo_txt = compiled.as_text()
            record["cost"] = roofline.cost_summary(compiled)
            # XLA:CPU cost_analysis counts while (scan) bodies once — the
            # weighted variant re-derives flops/bytes with trip counts
            record["weighted"] = roofline.weighted_cost(hlo_txt)
            record["collectives"] = roofline.collective_summary(hlo_txt)
            record["kind"] = cell.kind
            record["status"] = "ok"
            if save_hlo and out_dir:
                with open(os.path.join(
                        out_dir, f"{arch_id}_{shape_name}"
                        f"{'_mp' if multi_pod else ''}.hlo"), "w") as f:
                    f.write(compiled.as_text())
    except Exception as e:  # noqa: BLE001 — report, don't crash the sweep
        record["status"] = "FAILED"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-2000:]
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--extra", action="store_true",
                    help="include beyond-assignment cells (hi2-synth)")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    args = ap.parse_args()

    from repro.configs import registry

    os.makedirs(args.out, exist_ok=True)
    grid = registry.cells(include_skipped=True,
                          include_extra=args.extra or bool(args.arch))
    if args.arch:
        grid = [(a, s) for a, s in grid if a == args.arch]
    if args.shape:
        grid = [(a, s) for a, s in grid if s == args.shape]

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    n_fail = 0
    for multi_pod in meshes:
        for arch_id, shape_name in grid:
            rec = run_cell(arch_id, shape_name, multi_pod, args.out,
                           args.save_hlo)
            tag = "mp" if multi_pod else "sp"
            path = os.path.join(args.out,
                                f"{arch_id}_{shape_name}_{tag}.json")
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            status = rec["status"]
            extra = ""
            if status == "ok":
                extra = (f"mem/dev={rec['memory']['per_device_gb']:.2f}GB "
                         f"lower={rec['lower_s']}s "
                         f"compile={rec['compile_s']}s")
            elif status == "FAILED":
                n_fail += 1
                extra = rec["error"][:200]
            print(f"[{tag}] {arch_id}/{shape_name}: {status} {extra}",
                  flush=True)
    print(f"done, {n_fail} failures")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
