import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
"""Hillclimb harness: measure a cell variant (optionally with config
overrides) and print the three roofline terms + HBM — used to drive the
hypothesis → change → measure cycles recorded in EXPERIMENTS.md §Perf.

    PYTHONPATH=src python -m repro.launch.hillclimb mixtral-8x22b train_4k \
        --override moe_impl=shard_map
"""
import argparse
import dataclasses
import json


def measure(arch_id: str, shape_name: str, overrides: dict,
            multi_pod: bool = False) -> dict:
    from repro.configs import registry
    from repro.launch import cells as cm, mesh as mesh_mod, roofline

    mesh = mesh_mod.make_production_mesh(multi_pod=multi_pod)
    if overrides:
        arch = registry.get(arch_id)
        base_make = arch.make_config

        def patched_make(shape=None):
            return dataclasses.replace(base_make(shape), **overrides)

        registry.register(dataclasses.replace(arch,
                                              make_config=patched_make))
    cell = cm.build_cell(arch_id, shape_name, mesh)
    with mesh:
        compiled = cm.lower_cell(cell, mesh).compile()
    mem = compiled.memory_analysis()
    hbm = (mem.argument_size_in_bytes + mem.output_size_in_bytes
           + mem.temp_size_in_bytes - mem.alias_size_in_bytes) / 2**30
    txt = compiled.as_text()
    wc = roofline.weighted_cost(txt)
    col = roofline.collective_summary(txt)
    n = mesh.devices.size
    terms = roofline.roofline_terms(wc["flops"] * n, wc["bytes"] * n,
                                    col["total_bytes"] * n, n)
    return {
        "cell": f"{arch_id}/{shape_name}", "overrides": overrides,
        "hbm_gb": round(hbm, 2),
        "compute_s": terms["compute_s"], "memory_s": terms["memory_s"],
        "collective_s": terms["collective_s"],
        "dominant": terms["dominant"],
        "collectives_by_op_gb": {k: round(v / 2**30, 3)
                                 for k, v in col["by_op"].items()},
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("arch")
    ap.add_argument("shape")
    ap.add_argument("--override", action="append", default=[])
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()
    overrides = {}
    for ov in args.override:
        k, v = ov.split("=", 1)
        if v in ("True", "False"):
            v = v == "True"
        else:
            try:
                v = int(v)
            except ValueError:
                try:
                    v = float(v)
                except ValueError:
                    pass
        overrides[k] = v
    print(json.dumps(measure(args.arch, args.shape, overrides,
                             args.multi_pod), indent=1))


if __name__ == "__main__":
    main()
