"""Production mesh construction.

Target: TPU v5e pods — 16×16 = 256 chips per pod; the multi-pod mesh
stacks a leading "pod" axis (2 pods = 512 chips).  A FUNCTION, not a
module constant, so importing never touches jax device state (the
dry-run must set XLA_FLAGS before the first jax init).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

from repro.distributed import compat


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_host_mesh(data: int = 2, model: int = 4) -> Mesh:
    """Small mesh over host devices for tests (requires
    xla_force_host_platform_device_count ≥ data·model)."""
    return compat.make_mesh((data, model), ("data", "model"))


def make_serving_mesh(data: int, model: int,
                      data_axis: str = "data",
                      model_axis: str = "shards") -> Mesh:
    """The 2-D serving mesh (DESIGN.md §12): queries partition over
    ``data`` replica slices, document shards over ``model`` devices per
    replica — ``data · model`` devices total.  The model axis keeps the
    sharded-index default name ("shards") so the same search step runs
    on 1-D and 2-D meshes unchanged.
    """
    if data < 1 or model < 1:
        raise ValueError(f"mesh axes must be >= 1, got ({data}, {model})")
    devs = jax.devices()
    need = data * model
    if len(devs) < need:
        raise RuntimeError(
            f"need {need} devices for a ({data}, {model}) serving mesh, "
            f"have {len(devs)}; on CPU set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={need}")
    return compat.make_mesh((data, model), (data_axis, model_axis),
                            devices=devs[:need])
