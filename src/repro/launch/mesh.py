"""Production mesh construction.

Target: TPU v5e pods — 16×16 = 256 chips per pod; the multi-pod mesh
stacks a leading "pod" axis (2 pods = 512 chips).  A FUNCTION, not a
module constant, so importing never touches jax device state (the
dry-run must set XLA_FLAGS before the first jax init).
"""
from __future__ import annotations

from jax.sharding import Mesh

from repro.distributed import compat


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_host_mesh(data: int = 2, model: int = 4) -> Mesh:
    """Small mesh over host devices for tests (requires
    xla_force_host_platform_device_count ≥ data·model)."""
    return compat.make_mesh((data, model), ("data", "model"))
