"""Roofline report generator: reads the dry-run JSON records and emits
the EXPERIMENTS.md §Dry-run and §Roofline tables.

    PYTHONPATH=src python -m repro.launch.report --dir results/dryrun
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.launch import roofline


def _model_flops_for(cell: dict) -> float | None:
    """MODEL_FLOPS = 6·N_active·D (train) / 2·N_active·D (inference)."""
    from repro.configs import registry
    arch_id, shape_name = cell["cell"].split("/")
    arch = registry.get(arch_id)
    shape = arch.shapes[shape_name]
    if arch.family != "lm":
        return None
    cfg = arch.make_config(shape)
    n_active = cfg.n_active_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return roofline.model_flops(n_active, tokens, training=True)
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return roofline.model_flops(n_active, tokens, training=False)
    # decode: one token per sequence
    return roofline.model_flops(n_active, shape.global_batch,
                                training=False)


def load_records(directory: str, tag: str) -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(directory, f"*_{tag}.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def roofline_row(rec: dict) -> dict | None:
    if rec.get("status") != "ok":
        return None
    n = rec["n_devices"]
    # per-device SPMD module numbers, globalized for the brief's formula
    # (identical result). Prefer the trip-count-weighted re-derivation —
    # XLA:CPU cost_analysis counts scan bodies once.
    cost = rec.get("weighted", rec["cost"])
    flops = cost["flops"] * n
    mem_bytes = cost["bytes"] * n
    coll = rec["collectives"]["total_bytes"] * n
    terms = roofline.roofline_terms(flops, mem_bytes, coll, n)
    mf = _model_flops_for(rec)
    row = dict(cell=rec["cell"], n_devices=n, hbm_gb=rec["memory"]["per_device_gb"],
               **terms)
    row["useful_frac"] = (mf / flops) if (mf and flops) else None
    # roofline fraction: ideal (dominant-term) time / sum of all terms —
    # how close a perfectly-overlapped execution would run to the
    # dominant-resource bound
    tot = terms["compute_s"] + terms["memory_s"] + terms["collective_s"]
    dom = max(terms["compute_s"], terms["memory_s"], terms["collective_s"])
    row["roofline_frac"] = dom / tot if tot else None
    return row


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def emit_tables(directory: str) -> str:
    out = []
    for tag, title in (("sp", "single-pod 16×16 (256 chips)"),
                       ("mp", "multi-pod 2×16×16 (512 chips)")):
        recs = load_records(directory, tag)
        if not recs:
            continue
        out.append(f"\n### Mesh: {title}\n")
        out.append("| cell | status | HBM GB/dev | compute | memory | "
                   "collective | dominant | MODEL/HLO flops | roofline frac |")
        out.append("|---|---|---|---|---|---|---|---|---|")
        for rec in recs:
            if rec["status"] == "skipped":
                out.append(f"| {rec['cell']} | SKIP ({rec['reason'][:40]}…) "
                           f"| – | – | – | – | – | – | – |")
                continue
            if rec["status"] != "ok":
                out.append(f"| {rec['cell']} | **FAILED** | – | – | – | – "
                           f"| – | – | – |")
                continue
            row = roofline_row(rec)
            uf = f"{row['useful_frac']:.2f}" if row["useful_frac"] else "n/a"
            out.append(
                f"| {row['cell']} | ok | {row['hbm_gb']:.2f} "
                f"| {fmt_s(row['compute_s'])} | {fmt_s(row['memory_s'])} "
                f"| {fmt_s(row['collective_s'])} | {row['dominant']} "
                f"| {uf} | {row['roofline_frac']:.2f} |")
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    args = ap.parse_args()
    print(emit_tables(args.dir))


if __name__ == "__main__":
    main()
