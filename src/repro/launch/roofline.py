"""Roofline analysis from compiled dry-run artifacts (no TPU in this
container — the three terms are *derived*, not timed):

    compute term    = HLO_FLOPs      / (chips × peak_FLOP/s)
    memory term     = HLO_bytes      / (chips × HBM_bw)
    collective term = collective_bytes / (chips × link_bw)

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (brief §Roofline).

Sources: ``compiled.cost_analysis()`` provides flops / bytes accessed
(XLA aggregates while-loop bodies by trip count).  Collective bytes are
NOT in cost_analysis: we parse the compiled HLO text, summing operand
sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops, each multiplied by the estimated trip count of
its enclosing while loop (scan-over-layers executes its body collectives
n_layers times — ignoring that would undercount ~50×).
"""
from __future__ import annotations

import re
from typing import Optional

# --- TPU v5e constants -----------------------------------------------------
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per chip (ICI, per-link order)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\(?[^=]*?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_CALLED_RE = re.compile(r"(?:to_apply|body|condition|branch_computations|"
                        r"called_computations)=\{?%?([\w.\-]+)")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _parse_computations(hlo: str) -> dict[str, list[str]]:
    """computation name → its body lines.

    HLO pretty-print invariant: computation headers sit at column 0 and
    end with "{"; body ops are indented; the closing "}" is at column 0.
    """
    comps: dict[str, list[str]] = {}
    cur: Optional[str] = None
    for line in hlo.splitlines():
        if not line.startswith((" ", "\t")) and line.rstrip().endswith("{"):
            m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)", line)
            if m:
                cur = m.group(1)
                comps[cur] = []
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line)
    return comps


def _while_trip_count(cond_lines: list[str]) -> int:
    """Best-effort: the largest small-int constant in the condition."""
    best = 1
    for line in cond_lines:
        for m in re.finditer(r"constant\((\d+)\)", line):
            v = int(m.group(1))
            if 1 < v < 1_000_000:
                best = max(best, v)
    return best


def collective_summary(hlo: str) -> dict:
    """Total collective bytes (trip-count weighted) + per-op breakdown."""
    comps = _parse_computations(hlo)

    # map computation → multiplier from while loops that call it
    mult: dict[str, int] = {name: 1 for name in comps}
    for name, lines in comps.items():
        for line in lines:
            if " while(" in line or "= while(" in line:
                body_m = re.search(r"body=%?([\w.\-]+)", line)
                cond_m = re.search(r"condition=%?([\w.\-]+)", line)
                if body_m and cond_m and cond_m.group(1) in comps:
                    trips = _while_trip_count(comps[cond_m.group(1)])
                    if body_m.group(1) in mult:
                        mult[body_m.group(1)] = trips

    # propagate: computations called from a multiplied body inherit it
    # (one level is enough for scan bodies calling fusions)
    for name, lines in comps.items():
        if mult.get(name, 1) == 1:
            continue
        for line in lines:
            for cm in _CALLED_RE.finditer(line):
                callee = cm.group(1)
                if callee in mult and mult[callee] < mult[name]:
                    mult[callee] = mult[name]

    totals: dict[str, float] = {c: 0.0 for c in _COLLECTIVES}
    count = 0
    for name, lines in comps.items():
        m = mult.get(name, 1)
        for line in lines:
            om = _OP_RE.match(line)
            if not om:
                continue
            if "-done(" in line:          # start/done pairs: count start only
                continue
            type_str, op = om.group(1), om.group(2)
            b = _shape_bytes(type_str)
            totals[op] += b * m
            count += 1
    total = sum(totals.values())
    return {"total_bytes": total, "n_ops": count,
            "by_op": {k: v for k, v in totals.items() if v}}


def cost_summary(compiled) -> dict:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "transcendentals": float(ca.get("transcendentals", 0.0))}


def roofline_terms(flops: float, bytes_accessed: float,
                   collective_bytes: float, n_chips: int) -> dict:
    """The three terms in seconds (global work / aggregate capability)."""
    compute_s = flops / (n_chips * PEAK_FLOPS)
    memory_s = bytes_accessed / (n_chips * HBM_BW)
    collective_s = collective_bytes / (n_chips * LINK_BW)
    dominant = max((compute_s, "compute"), (memory_s, "memory"),
                   (collective_s, "collective"))[1]
    return {"compute_s": compute_s, "memory_s": memory_s,
            "collective_s": collective_s, "dominant": dominant}


def model_flops(n_params_active: int, n_tokens: int,
                training: bool = True) -> float:
    """6·N·D for a train step (2 fwd + 4 bwd per param·token);
    2·N·D for inference."""
    per = 6.0 if training else 2.0
    return per * n_params_active * n_tokens


# ---------------------------------------------------------------------------
# trip-count-weighted cost (XLA:CPU cost_analysis counts while bodies ONCE —
# a scan-over-56-layers step would be undercounted ~56×; we re-derive flops
# and bytes from the HLO text with the same per-computation multipliers used
# for collectives)
# ---------------------------------------------------------------------------

_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(\(?[^=]+?\)?)\s*"
                     r"([\w\-]+)\(")
_OPERAND_RE = re.compile(r"\((%[\w.\-]+(?:,\s*%[\w.\-]+)*)?\)")
_SKIP_BYTES_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                   "bitcast", "reshape", "copy", "broadcast", "iota",
                   "after-all", "custom-call", "while", "conditional",
                   "call"}


def _computation_multipliers(comps: dict[str, list[str]]) -> dict[str, int]:
    mult: dict[str, int] = {name: 1 for name in comps}
    for name, lines in comps.items():
        for line in lines:
            if " while(" in line or "= while(" in line:
                body_m = re.search(r"body=%?([\w.\-]+)", line)
                cond_m = re.search(r"condition=%?([\w.\-]+)", line)
                if body_m and cond_m and cond_m.group(1) in comps:
                    trips = _while_trip_count(comps[cond_m.group(1)])
                    if body_m.group(1) in mult:
                        mult[body_m.group(1)] = trips
    for name, lines in comps.items():
        if mult.get(name, 1) == 1:
            continue
        for line in lines:
            for cm in _CALLED_RE.finditer(line):
                callee = cm.group(1)
                if callee in mult and mult[callee] < mult[name]:
                    mult[callee] = mult[name]
    return mult


def _parse_shapes(lines: list[str]) -> dict[str, str]:
    """op name → its output type string, plus parameter declarations."""
    shapes: dict[str, str] = {}
    for line in lines:
        m = _DEF_RE.match(line)
        if m:
            shapes[m.group(1)] = m.group(2)
    return shapes


def _dot_flops(line: str, shapes: dict[str, str]) -> float:
    """2 · out_elems · K for a dot/dot-general line."""
    m = _DEF_RE.match(line)
    if not m:
        return 0.0
    out_type = m.group(2)
    out_elems = 0
    for dtype, dims in _SHAPE_RE.findall(out_type):
        if dtype in _DTYPE_BYTES:
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            out_elems += n
    # contraction size from the lhs operand's contracting dims
    ops = re.search(r"\((%[\w.\-]+)", line)
    cd = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
    if not ops or not cd or ops.group(1) not in shapes:
        return 2.0 * out_elems  # degenerate: treat as K=1
    lhs_type = shapes[ops.group(1)]
    sm = _SHAPE_RE.search(lhs_type)
    if not sm:
        return 2.0 * out_elems
    lhs_dims = [int(d) for d in sm.group(2).split(",") if d]
    k = 1
    for i in cd.group(1).split(","):
        if i and int(i) < len(lhs_dims):
            k *= lhs_dims[int(i)]
    return 2.0 * out_elems * k


def weighted_cost(hlo: str) -> dict:
    """Trip-count-weighted {flops, bytes} from the compiled HLO text.

    flops: dot/dot-general MACs ×2 (matmuls dominate every assigned arch).
    bytes: Σ (operands + output) of every materializing op — the same
    per-op convention XLA's bytes-accessed uses, fusions counted at their
    boundaries (internal temps stay in registers/VMEM).
    """
    comps = _parse_computations(hlo)
    mult = _computation_multipliers(comps)
    flops = 0.0
    bytes_ = 0.0
    for name, lines in comps.items():
        m = mult.get(name, 1)
        shapes = _parse_shapes(lines)
        for line in lines:
            dm = _DEF_RE.match(line)
            if not dm:
                continue
            op = dm.group(3)
            if op in ("dot",):
                flops += _dot_flops(line, shapes) * m
            if op in _SKIP_BYTES_OPS:
                continue
            b = _shape_bytes(dm.group(2))
            onames = re.search(r"\(((?:%[\w.\-]+(?:,\s*)?)+)\)", line)
            if onames:
                for oname in re.findall(r"%[\w.\-]+", onames.group(1)):
                    if oname in shapes:
                        b += _shape_bytes(shapes[oname])
            bytes_ += b * m
    return {"flops": flops, "bytes": bytes_}
