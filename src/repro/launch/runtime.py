"""Serving runtime (DESIGN.md §10): bucketed micro-batching, an
epoch-keyed LRU result cache, and admission control in front of every
:mod:`repro.launch.serve` server variant.

``Server.query`` is a synchronous, caller-batched API: whoever holds the
request decides the batch, and everything pads to one ``max_batch``
shape.  Real traffic is many independent clients submitting one query
each; this module is the layer between them and the compiled search
programs:

  · :class:`ServingRuntime.submit` enqueues one query and returns a
    future.  A scheduler thread drains the queue into *shape buckets* —
    powers of two from :data:`MIN_BUCKET` up to ``max_batch`` — so a
    request batch of n pads to the next bucket, not to ``max_batch``.
    Each bucket is one compiled program, pre-warmed by
    :meth:`ServingRuntime.warmup` (exactly one compile per bucket,
    enforced through :func:`repro.core.exec.trace_count`), and a lone
    request is never held hostage: the oldest request waits at most
    ``linger_ms`` for co-riders before its bucket executes.
  · An LRU cache keyed on (index epoch, namespace filter, fusion spec,
    query bytes) returns bit-identical
    :class:`~repro.core.hybrid_index.SearchResult` rows for repeated
    queries.  Mutations (``add``/``delete``/``compact``) bump the index
    epoch, and re-weighting hybrid fusion
    (:meth:`ServingRuntime.set_fusion_weight`, DESIGN.md §13) changes
    the key's fusion component, so no post-mutation or re-weighted
    query can see a stale result.
  · Admission control bounds the queue: past ``queue_depth`` pending
    requests, :meth:`submit` fails fast with
    :class:`RuntimeOverloaded` (carrying a retry-after hint) instead of
    letting latency grow without bound; :meth:`close` drains gracefully
    — every accepted request completes.

Bit-identity contract: a query's result rows are identical whether it
rides a bucket of 2 or the full ``max_batch`` pad of ``Server.query``
(all per-row stages of the §9 pipeline are batch-size invariant), so
the runtime is a pure scheduling layer — asserted per layout by
``benchmarks/serving_load.py --check`` and ``tests/test_runtime.py``.

Threading model: client threads only enqueue numpy rows and wait on
futures; ALL jax dispatch happens on the one scheduler thread (plus
whichever thread calls ``warmup``/mutations, serialized by the serve
lock), so device work is never issued concurrently.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from concurrent.futures import Future, InvalidStateError
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import exec as qexec
from repro.core import hybrid_index as hi
from repro.core.exec import filters as ns_filters
from repro.core.exec import frontier

#: Smallest micro-batch bucket.  B=1 would lower the query·centroid
#: matmul through XLA's vector path, whose reduction order differs from
#: the batched kernel by ~1 ulp — padding a lone request to 2 rows keeps
#: every bucket on the same kernel family, which is what makes runtime
#: results bit-identical to ``Server.query`` (DESIGN.md §10).
MIN_BUCKET = 2

#: Cache-key quantum for the L2-normalized query embedding: components
#: are rounded to multiples of this before hashing, so two embeddings
#: that are positive scalings of each other (ranking is scale-invariant
#: under cosine scoring) — or that differ by < CACHE_QUANT/2 per
#: normalized component — share one cache entry.  A hit returns the
#: representative's stored rows verbatim; exact repeats are still
#: deterministic, so cached replay stays bit-identical.  1e-4 sits ~4
#: orders of magnitude above float32 scaling noise on a unit vector
#: (so scale-variants land in the same grid cell) and ~3 below the
#: distance between genuinely different queries.
CACHE_QUANT = 1e-4


class RuntimeOverloaded(RuntimeError):
    """Admission control rejected the request: the queue is at
    ``queue_depth``.  ``retry_after_ms`` is the backoff hint."""

    def __init__(self, depth: int, retry_after_ms: float):
        super().__init__(
            f"request queue full ({depth} pending); retry in "
            f"{retry_after_ms:g} ms")
        self.retry_after_ms = retry_after_ms


class RuntimeClosed(RuntimeError):
    """The runtime is shutting down (or was never started)."""


@dataclasses.dataclass(frozen=True)
class RuntimeConfig:
    linger_ms: float = 2.0     # max wait of the OLDEST request for co-riders
    queue_depth: int = 256     # pending-request bound (admission control)
    cache_size: int = 0        # LRU result-cache entries; 0 disables
    retry_after_ms: float = 5.0  # backoff hint carried by RuntimeOverloaded
    min_bucket: int = MIN_BUCKET


def bucket_sizes(max_batch: int, min_bucket: int = MIN_BUCKET,
                 quantum: int = 1) -> tuple:
    """The bucket ladder: powers of two from ``min_bucket`` up, capped
    by a final ``max_batch`` rung (itself, even when not a power of 2).

    ``quantum`` is the batch granularity of the serving layout — the
    data-axis replica count of a 2-D mesh server (DESIGN.md §12), whose
    query batch must split into equal per-replica row blocks.  Every
    rung is a multiple of it (``max_batch`` itself must be)."""
    if max_batch < 1:
        raise ValueError(f"max_batch must be >= 1, got {max_batch}")
    q = max(1, int(quantum))
    if max_batch % q:
        raise ValueError(f"max_batch {max_batch} is not a multiple of "
                         f"the batch quantum {q}")
    sizes, b = [], max(1, min_bucket) * q
    while b < max_batch:
        sizes.append(b)
        b *= 2
    sizes.append(max_batch)
    return tuple(sizes)


class QueryCache:
    """Thread-safe LRU over normalized query keys.

    A key is (index epoch, canonical namespace spec, normalized query
    embedding bytes, query token bytes).  The embedding component is the
    L2-normalized vector quantized to :data:`CACHE_QUANT` — ranking is
    scale-invariant, so positive scalings of one query (and embeddings
    within the documented tolerance) share an entry; the token
    component stays byte-exact.  A hit returns the stored result rows
    verbatim, which is what makes cached and uncached responses
    bit-identical for exact repeats.  The epoch component is how
    mutations invalidate: ``add``/``delete``/``compact`` (and mesh
    membership changes, DESIGN.md §12) bump the server's epoch, so
    stale entries simply never match again (they age out of the LRU
    instead of being swept eagerly).
    """

    def __init__(self, capacity: int):
        if capacity < 1:
            raise ValueError(f"cache capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self._lru: collections.OrderedDict = collections.OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def get(self, key):
        """Lookup; counts hits only.  ``misses`` is incremented by the
        owner when a request is actually *computed* — a lookup can run
        twice per request (submit pre-check + scheduler re-check), so
        counting lookups would double-book and rejected requests would
        skew the hit rate."""
        with self._lock:
            if key in self._lru:
                self._lru.move_to_end(key)
                self.hits += 1
                return self._lru[key]
            return None

    def put(self, key, value) -> None:
        with self._lock:
            self._lru[key] = value
            self._lru.move_to_end(key)
            while len(self._lru) > self.capacity:
                self._lru.popitem(last=False)

    def __len__(self) -> int:
        with self._lock:
            return len(self._lru)


class _Request:
    __slots__ = ("qe", "qt", "ns", "rung", "future", "t_submit")

    def __init__(self, qe: np.ndarray, qt: np.ndarray, ns, rung: int,
                 future: Future):
        self.qe = qe
        self.qt = qt
        self.ns = ns
        self.rung = rung
        self.future = future
        self.t_submit = time.monotonic()


def _fail(future: Future, exc: BaseException) -> None:
    """``set_exception`` tolerating a client-side ``cancel()`` race —
    a future cancelled while pending needs no resolution."""
    try:
        future.set_exception(exc)
    except InvalidStateError:
        pass


def _canon_qe(qe: np.ndarray) -> bytes:
    """Cache-key bytes for one query embedding: L2-normalize (float64 —
    the quantization must not inherit float32 rounding), quantize to
    :data:`CACHE_QUANT`, hash the integer grid point.  Zero vectors pass
    through unnormalized (nothing meaningful to scale)."""
    v = qe.astype(np.float64)
    n = float(np.linalg.norm(v))
    if n > 0.0:
        v = v / n
    return np.round(v / CACHE_QUANT).astype(np.int64).tobytes()


def _canon_ns(namespaces) -> Optional[tuple]:
    """One request's namespace spec (an int or an iterable of ids) as a
    canonical hashable tuple — equal specs must produce equal cache keys."""
    if namespaces is None:
        return None
    if np.isscalar(namespaces):
        return (int(namespaces),)
    return tuple(sorted({int(n) for n in namespaces}))


class ServingRuntime:
    """Bucketed micro-batching + caching + admission control over one
    :class:`repro.launch.serve.Server` (any layout: plain, sharded,
    mutable, sharded-mutable; any codec; with or without namespaces).

    Lifecycle: construct → :meth:`warmup` (compiles every bucket, starts
    the scheduler) → :meth:`submit`/:meth:`query` → :meth:`close`.
    Usable as a context manager (``close(drain=True)`` on exit).
    """

    def __init__(self, server, cfg: RuntimeConfig = RuntimeConfig()):
        if cfg.linger_ms < 0:
            raise ValueError("linger_ms must be >= 0")
        if cfg.queue_depth < 1:
            raise ValueError("queue_depth must be >= 1")
        self.server = server
        self.cfg = cfg
        self.max_batch = int(server.cfg.max_batch)
        # batch quantum: a 2-D mesh server partitions each bucket over
        # its data-axis replicas (DESIGN.md §12), so every rung must
        # split into equal per-replica row blocks
        self.n_replicas = max(1, int(getattr(server, "n_replicas", 1)))
        self.buckets = bucket_sizes(self.max_batch, cfg.min_bucket,
                                    self.n_replicas)
        self.cache = (QueryCache(cfg.cache_size) if cfg.cache_size > 0
                      else None)
        self._hidden: Optional[int] = None
        self._query_len: Optional[int] = None
        # serve lock: serializes search execution, mutations, and the
        # epoch reads cache keys depend on
        self._serve_lock = threading.Lock()
        self._cond = threading.Condition()
        self._queue: collections.deque = collections.deque()
        self._thread: Optional[threading.Thread] = None
        self._closing = False
        self._drop_pending = False
        # telemetry
        self.n_served = 0
        self.n_rejected = 0
        self.n_batches = 0
        self.bucket_counts = {b: 0 for b in self.buckets}
        self.replica_dispatch = {r: 0 for r in range(self.n_replicas)}
        # width rungs (DESIGN.md §14): the server's static (kc, k2)
        # ladder.  Single-rung on every non-adaptive server — then the
        # runtime behaves (and keys warm_traces) exactly as before.
        self.rung_dispatch: dict = {}
        self._cluster_emb: Optional[np.ndarray] = None
        self._refresh_rungs()
        self.warm_traces: dict = {}
        # compiles triggered by runtime batches after warmup — 0 when
        # every request lands in a warmed bucket.  Deltas are taken
        # around the scheduler's own search calls; a direct Server.query
        # compiling a NEW signature concurrently with a runtime batch
        # would be misattributed (the process-global trace counter can't
        # tell threads apart), so keep external searches off the hot
        # serving window — the bench and tests interleave them only
        # while the runtime is idle.
        self.serve_traces = 0

    # --- lifecycle -------------------------------------------------------
    def warmup(self, hidden: int, query_len: int) -> None:
        """Compile every bucket's search program (one compile per bucket
        — the deltas land in :attr:`warm_traces`) and start the
        scheduler.  Must run before :meth:`submit`; running it again
        after :meth:`close` revives the runtime."""
        self._hidden, self._query_len = int(hidden), int(query_len)
        with self._serve_lock:
            self._warm_buckets()
        with self._cond:
            closing, t = self._closing, self._thread
        if (closing and t is not None
                and t is not threading.current_thread()):
            # close() initiated from a done-callback stops the scheduler
            # asynchronously; wait it out so the revive below is real
            t.join()
            with self._cond:
                if self._thread is t:
                    self._thread = None
        with self._cond:
            # check-and-start under the lock: two racing warmups must
            # not each start a scheduler (one scheduler thread is the
            # concurrency model)
            if self._thread is None:
                self._closing = False
                self._drop_pending = False
                self._thread = threading.Thread(target=self._loop,
                                                name="hi2-serving-runtime",
                                                daemon=True)
                self._thread.start()

    def _refresh_rungs(self) -> None:
        """Snapshot the server's width ladder (DESIGN.md §14).  On a
        multi-rung ladder the dispatch margin needs the cluster
        embeddings host-side; re-read on every (re)warm so compaction's
        fresh base swaps them in with the new compiled programs."""
        self.rungs = tuple(getattr(self.server, "rungs", None)
                           or ((getattr(self.server, "kc", None),
                                getattr(self.server, "k2", None)),))
        self.margin_cuts = tuple(getattr(self.server, "margin_cuts", ()))
        for r in range(len(self.rungs)):
            self.rung_dispatch.setdefault(r, 0)
        self._cluster_emb = (np.asarray(
            self.server.index.cluster_sel.embeddings, np.float32)
            if len(self.rungs) > 1 else None)

    def _warm_buckets(self) -> None:
        """Compile the ladder at the current index shapes (caller holds
        the serve lock; :meth:`warmup` has recorded the query dims).
        One compile per (bucket, rung); single-rung runtimes keep the
        plain per-bucket ledger keys (and jit signatures) of §10."""
        self._refresh_rungs()
        multi = len(self.rungs) > 1
        for b in self.buckets:
            qe = jnp.zeros((b, self._hidden), jnp.float32)
            qt = jnp.full((b, self._query_len), -1, jnp.int32)
            for r, widths in enumerate(self.rungs):
                before = qexec.trace_count()
                jax.block_until_ready(
                    self.server._search(self.server.index, qe, qt,
                                        filter=self._bitmap([], b),
                                        widths=widths if multi else None))
                key = (b, r) if multi else b
                self.warm_traces[key] = qexec.trace_count() - before

    def close(self, drain: bool = True) -> None:
        """Stop the runtime.  ``drain=True`` (the default) completes
        every accepted request first; ``drain=False`` fails pending
        futures with :class:`RuntimeClosed`.  Idempotent.  From a
        done-callback (which may run on the scheduler thread) the stop
        is asynchronous — the scheduler cannot join itself."""
        with self._cond:
            self._closing = True
            self._drop_pending = not drain
            self._cond.notify_all()
        t = self._thread
        if t is not None and t is not threading.current_thread():
            t.join()
            with self._cond:
                if self._thread is t:   # exiting schedulers self-clear
                    self._thread = None

    def __enter__(self) -> "ServingRuntime":
        return self

    def __exit__(self, *exc) -> None:
        self.close(drain=True)

    # --- request path ----------------------------------------------------
    def submit(self, query_emb, query_tokens, namespaces=None) -> Future:
        """Enqueue ONE query; returns a future resolving to its
        :class:`~repro.core.hybrid_index.SearchResult` rows —
        ``doc_ids``/``scores`` of shape (R,), scalar ``n_candidates`` —
        bit-identical to the same query through ``Server.query``.

        Raises :class:`RuntimeOverloaded` past ``queue_depth`` pending
        requests and :class:`RuntimeClosed` after :meth:`close` (or
        before :meth:`warmup`).
        """
        if self._thread is None or self._closing:
            raise RuntimeClosed(
                "runtime not serving; call warmup(hidden, query_len) "
                "first" if self._thread is None else "runtime closed")
        qe = np.asarray(query_emb, np.float32).reshape(-1)
        qt = np.asarray(query_tokens, np.int32).reshape(-1)
        if qe.shape[0] != self._hidden or qt.shape[0] != self._query_len:
            raise ValueError(
                f"query shapes ({qe.shape[0]},)/({qt.shape[0]},) do not "
                f"match the warmed ({self._hidden},)/({self._query_len},)")
        ns = _canon_ns(namespaces)
        if ns is not None:
            n_ns = self.server.cfg.n_namespaces
            if not n_ns:
                raise ValueError(
                    "this server was built without namespaces; construct "
                    "with ServeConfig(n_namespaces=N) / --namespaces N")
            # validate here, per request — a bad id surfacing later as a
            # make_filter error inside the scheduler would fail every
            # co-rider in the same micro-batch
            bad = [i for i in ns if not 0 <= i < n_ns]
            if bad:
                raise ValueError(
                    f"namespace id(s) {bad} out of range [0, {n_ns})")
        rung = self._rung_for(qe)
        future: Future = Future()
        if self.cache is not None:
            # lock-free pre-check: submit must never wait behind an
            # in-flight batch holding the serve lock.  A racing
            # mutation can at worst make this a spurious miss — the
            # scheduler re-checks under the lock before executing —
            # and a hit at the pre-read epoch is a result the request
            # could have legitimately observed (it raced the mutation).
            hit = self.cache.get(self._key(qe, qt, ns, rung))
            if hit is not None:
                future.set_result(hit)
                return future
        req = _Request(qe, qt, ns, rung, future)
        with self._cond:
            if self._closing:
                raise RuntimeClosed("runtime closed")
            if len(self._queue) >= self.cfg.queue_depth:
                self.n_rejected += 1
                raise RuntimeOverloaded(len(self._queue),
                                        self.cfg.retry_after_ms)
            self._queue.append(req)
            self._cond.notify_all()
        return future

    def query(self, query_emb, query_tokens,
              namespaces=None) -> hi.SearchResult:
        """Synchronous batch convenience with the ``Server.query``
        signature: splits the batch into per-query submissions, waits,
        and reassembles — so callers migrating from direct serving keep
        their call sites."""
        qe = np.atleast_2d(np.asarray(query_emb, np.float32))
        qt = np.atleast_2d(np.asarray(query_tokens, np.int32))
        n = qe.shape[0]
        if namespaces is not None and len(namespaces) != n:
            raise ValueError(f"{len(namespaces)} filter rows for {n} "
                             "queries")
        futures = [self.submit(qe[i], qt[i],
                               None if namespaces is None else namespaces[i])
                   for i in range(n)]
        rows = [f.result() for f in futures]
        return hi.SearchResult(
            doc_ids=np.stack([r.doc_ids for r in rows]),
            scores=np.stack([r.scores for r in rows]),
            n_candidates=np.stack([r.n_candidates for r in rows]),
            partial=any(bool(getattr(r, "partial", False)) for r in rows))

    # --- mutations (mutable servers): epoch-coherent forwarding ----------
    def add(self, doc_emb, doc_tokens, namespaces=None) -> np.ndarray:
        with self._serve_lock:
            base = self.server.index
            ids = self.server.add(doc_emb, doc_tokens,
                                  namespaces=namespaces)
            self._rewarm_if_compacted(base)
            return ids

    def delete(self, doc_ids) -> None:
        with self._serve_lock:
            base = self.server.index
            self.server.delete(doc_ids)
            self._rewarm_if_compacted(base)

    def _rewarm_if_compacted(self, base) -> None:
        """A watermark-triggered auto-compaction inside ``add``/``delete``
        (ServeConfig.compact_*_watermark, DESIGN.md §8) swaps the base
        index; re-warm here — under the serve lock, off the request
        path — exactly like an explicit :meth:`compact`."""
        if self.server.index is not base and self._hidden is not None:
            self._warm_buckets()

    def compact(self) -> None:
        with self._serve_lock:
            self.server.compact()
            # compaction rebuilds the base with new plane shapes, so
            # the §8 one-recompile-per-compaction happens here, off the
            # request path — re-warming keeps the compile ledger honest
            # instead of charging the next request of every bucket
            if self._hidden is not None:
                self._warm_buckets()

    def set_fusion_weight(self, weight: Optional[float]) -> None:
        """Re-weight hybrid fusion live (DESIGN.md §13).  Runs under the
        serve lock (a new FusionSpec is a new compiled program per
        bucket, so the ladder is re-warmed off the request path), and
        the spec's place in the cache key keeps previously fused
        results from replaying at the new weight."""
        with self._serve_lock:
            self.server.set_fusion(weight)
            if self._hidden is not None:
                self._warm_buckets()

    # --- observability ---------------------------------------------------
    def stats(self) -> dict:
        cache = None
        if self.cache is not None:
            h, m = self.cache.hits, self.cache.misses
            cache = {"hits": h, "misses": m, "entries": len(self.cache),
                     "hit_rate": (h / (h + m)) if h + m else 0.0}
        with self._cond:
            depth = len(self._queue)
        return {
            "buckets": list(self.buckets),
            "warm_traces": dict(self.warm_traces),
            "post_warmup_traces": self.serve_traces,
            "n_served": self.n_served,
            "n_rejected": self.n_rejected,
            "n_batches": self.n_batches,
            "queue_depth": depth,
            "bucket_counts": dict(self.bucket_counts),
            "n_replicas": self.n_replicas,
            "replica_dispatch": dict(self.replica_dispatch),
            "rungs": [list(r) for r in self.rungs],
            "rung_dispatch": dict(self.rung_dispatch),
            "widths": [getattr(self.server, "kc", None),
                       getattr(self.server, "k2", None)],
            "width_source": getattr(self.server, "width_source",
                                    "default"),
            "cache": cache,
        }

    def serve_metrics(self, port: int = 0) -> "MetricsServer":
        """Expose :meth:`stats` as plaintext (Prometheus exposition
        style) on ``http://127.0.0.1:port/metrics``; ``port=0`` binds an
        ephemeral port (read it from the returned server).  The caller
        owns the returned :class:`MetricsServer` (``close()`` it)."""
        return MetricsServer(self, port)

    def assert_one_compile_per_bucket(self) -> None:
        """The warmup contract (DESIGN.md §10): every bucket compiled at
        most once during warmup (exactly once on a cold jit cache) and
        nothing has compiled since."""
        bad = {b: n for b, n in self.warm_traces.items() if n > 1}
        if bad:
            raise AssertionError(
                f"buckets compiled more than once during warmup: {bad}")
        if self.serve_traces:
            raise AssertionError(
                f"{self.serve_traces} search program(s) compiled after "
                "warmup — a request escaped the warmed bucket shapes")

    # --- internals -------------------------------------------------------
    def _epoch(self) -> int:
        return getattr(self.server, "epoch", 0)

    def _rung_for(self, qe: np.ndarray) -> int:
        """Resolve one query's width rung from its dispatch margin
        (DESIGN.md §14).  Computed on the L2-normalized embedding —
        the same canonical form the cache key hashes — so positive
        scalings of one query always resolve the same rung.  Constant 0
        on a single-rung ladder (every non-adaptive server)."""
        if len(self.rungs) <= 1:
            return 0
        m = frontier.margins(self._cluster_emb, qe[None])
        return int(frontier.resolve_rung(m, self.margin_cuts)[0])

    def _key(self, qe: np.ndarray, qt: np.ndarray, ns, rung: int,
             epoch: Optional[int] = None) -> tuple:
        """The one cache-key schema; the scheduler passes its
        lock-pinned ``epoch``, the submit pre-check reads the live one.
        The fusion spec joins the key so re-weighting hybrid fusion
        (DESIGN.md §13) can never replay a result fused at another
        weight; the resolved width rung joins it so a row computed at
        one rung can never replay for a query resolved to another —
        even an ulp-level margin flip at a cut is a miss, never a
        cross-rung replay (DESIGN.md §14)."""
        e = self._epoch() if epoch is None else epoch
        return (e, ns, getattr(self.server, "fusion", None), rung,
                _canon_qe(qe), qt.tobytes())

    def _bucket_for(self, n: int) -> int:
        for b in self.buckets:
            if b >= n:
                return b
        return self.max_batch

    def _rows_idx(self, n: int, bucket: int) -> list:
        """Row placement for n requests in a bucket: identity on 1-D
        layouts; on a D-replica mesh, request i rides row
        ``(i % D) · bucket/D + i // D`` — round-robin over the
        contiguous per-replica row blocks the data axis partitions the
        bucket into, so a part-full bucket spreads live queries across
        every replica instead of stacking them on replica 0."""
        d = self.n_replicas
        if d == 1:
            return list(range(n))
        per = bucket // d
        return [(i % d) * per + (i // d) for i in range(n)]

    def _bitmap(self, specs: Sequence, bucket: int, rows_idx=None):
        """Per-bucket namespace bitmap, or None on an unfiltered server.
        A namespaced server ALWAYS gets a bitmap (allow-all rows for
        requests without a filter — a bitwise no-op) so each bucket has
        one jit signature; pad rows match nothing.  ``rows_idx`` scatters
        the specs to their mesh-placed rows (:meth:`_rows_idx`)."""
        n_ns = self.server.cfg.n_namespaces
        if not n_ns:
            return None
        if rows_idx is None:
            rows = [range(n_ns) if ns is None else ns for ns in specs]
            return ns_filters.pad_filter(ns_filters.make_filter(rows, n_ns),
                                         bucket)
        rows = [()] * bucket     # un-placed rows match nothing (pad rows)
        for i, ns in enumerate(specs):
            rows[rows_idx[i]] = range(n_ns) if ns is None else ns
        return ns_filters.make_filter(rows, n_ns)

    def _loop(self) -> None:
        try:
            self._run_scheduler()
        finally:
            # let close()-from-a-done-callback revive later: the
            # scheduler clears its own registration on exit so a
            # subsequent warmup() starts a fresh thread
            with self._cond:
                if self._thread is threading.current_thread():
                    self._thread = None

    def _run_scheduler(self) -> None:
        while True:
            with self._cond:
                while not self._queue and not self._closing:
                    self._cond.wait()
                if not self._queue:          # closing and drained
                    return
                # linger: wait for co-riders until the oldest request's
                # deadline, then take what arrived (never past max_batch)
                deadline = self._queue[0].t_submit + self.cfg.linger_ms / 1e3
                while (len(self._queue) < self.max_batch
                       and not self._closing):
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        break
                    self._cond.wait(remaining)
                if self._closing and self._drop_pending:
                    dropped = list(self._queue)
                    self._queue.clear()
                else:
                    dropped = None
                    # co-rung micro-batching (DESIGN.md §14): a batch
                    # runs ONE compiled program, so it can only carry
                    # requests resolved to one width rung.  Take the
                    # oldest request's rung, sweep the queue for
                    # co-rung riders (never past max_batch), and put
                    # the others back in arrival order — FIFO within
                    # each rung, and the oldest request always runs
                    # now.  Single-rung ladders sweep everything, which
                    # is exactly the pre-§14 batching.
                    rung = self._queue[0].rung
                    batch, keep = [], []
                    while self._queue and len(batch) < self.max_batch:
                        req = self._queue.popleft()
                        (batch if req.rung == rung else keep).append(req)
                    self._queue.extendleft(reversed(keep))
            if dropped is not None:
                # futures resolve outside the locks: a done-callback may
                # re-enter submit()/close() (both take them)
                for req in dropped:
                    _fail(req.future,
                          RuntimeClosed("runtime closed before execution"))
                return
            # claim each future: a client that cancel()ed while queued
            # drops out here, and a claimed (RUNNING) future can no
            # longer be cancelled out from under set_result
            batch = [r for r in batch
                     if r.future.set_running_or_notify_cancel()]
            if not batch:
                continue
            try:
                self._execute(batch)
            except BaseException as e:       # noqa: BLE001 — fail futures,
                for req in batch:            # never strand waiting clients
                    if not req.future.done():
                        _fail(req.future, e)

    def _execute(self, batch: list) -> None:
        rows = {}              # id(req) -> row; futures resolve OUTSIDE
        #                        the serve lock (a done-callback may
        #                        re-enter submit()/add()/close(), which
        #                        take it) and in batch order (FIFO even
        #                        when a scheduler-side cache hit lands
        #                        next to computed rows)
        err = None
        rung = batch[0].rung     # co-rung by construction (_run_scheduler)
        with self._serve_lock:
            epoch = self._epoch()
            misses = []
            for req in batch:
                hit = (None if self.cache is None else
                       self.cache.get(self._key(req.qe, req.qt, req.ns,
                                                req.rung, epoch)))
                if hit is not None:
                    rows[id(req)] = hit
                else:
                    misses.append(req)
            if misses:
                try:
                    bucket = self._bucket_for(len(misses))
                    place = self._rows_idx(len(misses), bucket)
                    qe = np.zeros((bucket, self._hidden), np.float32)
                    qt = np.full((bucket, self._query_len), -1, np.int32)
                    for i, req in enumerate(misses):
                        qe[place[i]], qt[place[i]] = req.qe, req.qt
                    before = qexec.trace_count()
                    res = self.server._search(
                        self.server.index, jnp.asarray(qe),
                        jnp.asarray(qt),
                        filter=self._bitmap(
                            [r.ns for r in misses], bucket,
                            None if self.n_replicas == 1 else place),
                        widths=(self.rungs[rung]
                                if len(self.rungs) > 1 else None))
                    self.serve_traces += qexec.trace_count() - before
                    ids = np.asarray(res.doc_ids)
                    scores = np.asarray(res.scores)
                    n_cand = np.asarray(res.n_candidates)
                    part = bool(np.asarray(getattr(res, "partial",
                                                   False)))
                    for i, req in enumerate(misses):
                        j = place[i]
                        row = hi.SearchResult(doc_ids=ids[j],
                                              scores=scores[j],
                                              n_candidates=n_cand[j],
                                              partial=part)
                        if self.cache is not None:
                            self.cache.put(self._key(req.qe, req.qt,
                                                     req.ns, req.rung,
                                                     epoch), row)
                        rows[id(req)] = row
                        self.replica_dispatch[i % self.n_replicas] += 1
                    if self.cache is not None:
                        self.cache.misses += len(misses)
                    self.n_served += len(misses)
                    self.n_batches += 1
                    self.bucket_counts[bucket] += 1
                    self.rung_dispatch[rung] += len(misses)
                    if hasattr(self.server, "n_served"):
                        self.server.n_served += len(misses)
                except BaseException as e:   # noqa: BLE001 — the cache
                    err = e                  # hits still resolve below
        for req in batch:
            row = rows.get(id(req))
            if row is not None:
                req.future.set_result(row)
            else:
                req.future.set_exception(err)


def render_metrics(stats: dict) -> str:
    """One :meth:`ServingRuntime.stats` dict as plaintext metrics
    (Prometheus exposition style: ``name{label="v"} value`` lines) —
    the scrape payload of :class:`MetricsServer`."""
    lines = [
        f"hi2_runtime_served_total {stats['n_served']}",
        f"hi2_runtime_rejected_total {stats['n_rejected']}",
        f"hi2_runtime_batches_total {stats['n_batches']}",
        f"hi2_runtime_queue_depth {stats['queue_depth']}",
        f"hi2_runtime_replicas {stats['n_replicas']}",
        f"hi2_runtime_post_warmup_compiles {stats['post_warmup_traces']}",
    ]
    for b in stats["buckets"]:
        lines.append(f'hi2_runtime_bucket_batches_total{{bucket="{b}"}} '
                     f"{stats['bucket_counts'][b]}")
    for b, n in sorted(stats["warm_traces"].items()):
        if isinstance(b, tuple):     # multi-rung ledger: (bucket, rung)
            lines.append(f'hi2_runtime_bucket_compiles{{bucket="{b[0]}",'
                         f'rung="{b[1]}"}} {n}')
        else:
            lines.append(f'hi2_runtime_bucket_compiles{{bucket="{b}"}} {n}')
    for r, n in sorted(stats["replica_dispatch"].items()):
        lines.append(f'hi2_runtime_replica_dispatch_total{{replica="{r}"}} '
                     f"{n}")
    # width-rung dispatch + tuned-config info (DESIGN.md §14)
    kc, k2 = stats["widths"]
    lines.append(f'hi2_runtime_width_info{{source="{stats["width_source"]}"'
                 f',kc="{kc}",k2="{k2}"}} 1')
    lines.append(f"hi2_runtime_rungs {len(stats['rungs'])}")
    for r, n in sorted(stats["rung_dispatch"].items()):
        rkc, rk2 = stats["rungs"][int(r)]
        lines.append(f'hi2_runtime_rung_dispatch_total{{rung="{r}",'
                     f'kc="{rkc}",k2="{rk2}"}} {n}')
    cache = stats["cache"]
    if cache is not None:
        lines += [
            f"hi2_runtime_cache_hits_total {cache['hits']}",
            f"hi2_runtime_cache_misses_total {cache['misses']}",
            f"hi2_runtime_cache_entries {cache['entries']}",
            f"hi2_runtime_cache_hit_rate {cache['hit_rate']:.6f}",
        ]
    return "\n".join(lines) + "\n"


class MetricsServer:
    """Plaintext metrics endpoint over one :class:`ServingRuntime`
    (DESIGN.md §10): ``GET /metrics`` on a loopback-only stdlib HTTP
    server returns :func:`render_metrics` of a live :meth:`stats`
    snapshot.  Daemon-threaded; ``close()`` (or process exit) stops it.
    """

    def __init__(self, runtime: ServingRuntime, port: int = 0):
        import http.server

        rt = runtime

        class _Handler(http.server.BaseHTTPRequestHandler):
            def do_GET(self):
                if self.path.split("?", 1)[0] != "/metrics":
                    self.send_error(404, "scrape /metrics")
                    return
                body = render_metrics(rt.stats()).encode()
                self.send_response(200)
                self.send_header("Content-Type",
                                 "text/plain; version=0.0.4")
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *args):     # scrapes are not stdout news
                pass

        self._httpd = http.server.ThreadingHTTPServer(("127.0.0.1", port),
                                                      _Handler)
        self.port = int(self._httpd.server_address[1])
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        name="hi2-metrics", daemon=True)
        self._thread.start()

    def close(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join()

    def __enter__(self) -> "MetricsServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
