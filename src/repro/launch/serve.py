"""Serving driver: a persisted HI² index behind a fixed-shape batched
search step (the production query path, DESIGN.md §2).

    PYTHONPATH=src python -m repro.launch.serve                 # 1 device
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \\
    PYTHONPATH=src python -m repro.launch.serve --shards 4      # sharded

Two serving layouts:

  · :class:`Server` — the whole index on one device; request batches
    padded to ``max_batch`` so one compiled program serves every
    request size (no recompiles on the hot path).
  · :class:`ShardedServer` — the document-sharded layout of
    DESIGN.md §6: doc planes partitioned over a 1-D device mesh
    (:mod:`repro.core.sharded_index`), per-shard search under
    shard_map, top-R merged by one all-gather.  Bit-identical results,
    1/S of the doc-plane HBM per device.

Latency is governed by the static per-query candidate budget
(:func:`repro.core.hybrid_index.candidate_budget` — the proxy all of
``benchmarks/`` reports); ``launch/cells.py::_hi2_serve_cell`` and
``_hi2_sharded_serve_cell`` lower these same steps at MS MARCO scale
for the dry-run.
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpoint as ckpt
from repro.core import codecs
from repro.core import hybrid_index as hi
from repro.core import sharded_index as shi


@dataclasses.dataclass
class ServeConfig:
    kc: int = 6
    k2: int = 8
    top_r: int = 100
    max_batch: int = 64
    use_kernel: bool = False     # Pallas ADC on TPU
    n_shards: int = 1            # >1 → document-sharded layout


class Server:
    """Pads request batches to max_batch so one compiled program serves
    every request size (no recompiles on the hot path)."""

    def __init__(self, index: hi.HybridIndex, cfg: ServeConfig = ServeConfig()):
        self.index = index
        self.cfg = cfg
        # hi.search is already jitted (static kc/k2/top_r/use_kernel) —
        # bind the statics with partial instead of wrapping in a second
        # jax.jit, which would pay nested-jit dispatch on every request
        self._search = functools.partial(
            hi.search, kc=cfg.kc, k2=cfg.k2, top_r=cfg.top_r,
            use_kernel=cfg.use_kernel)
        self.n_served = 0

    @classmethod
    def from_checkpoint(cls, path: str, like: hi.HybridIndex,
                        cfg: ServeConfig = ServeConfig()) -> "Server":
        return cls(ckpt.restore_index(path, like), cfg)

    def warmup(self, hidden: int, query_len: int) -> None:
        qe = jnp.zeros((self.cfg.max_batch, hidden), jnp.float32)
        qt = jnp.full((self.cfg.max_batch, query_len), -1, jnp.int32)
        jax.block_until_ready(self._search(self.index, qe, qt))

    def _pad(self, query_emb: np.ndarray, query_tokens: np.ndarray):
        n = query_emb.shape[0]
        pad = self.cfg.max_batch - n
        assert pad >= 0, f"batch {n} exceeds max_batch {self.cfg.max_batch}"
        qe = jnp.asarray(np.pad(query_emb, ((0, pad), (0, 0))))
        qt = jnp.asarray(np.pad(query_tokens, ((0, pad), (0, 0)),
                                constant_values=-1))
        return n, qe, qt

    def query(self, query_emb: np.ndarray, query_tokens: np.ndarray
              ) -> hi.SearchResult:
        n, qe, qt = self._pad(query_emb, query_tokens)
        res = self._search(self.index, qe, qt)
        self.n_served += n
        return hi.SearchResult(doc_ids=res.doc_ids[:n],
                               scores=res.scores[:n],
                               n_candidates=res.n_candidates[:n])


class ShardedServer(Server):
    """Document-sharded serving (DESIGN.md §6): same request contract
    and bit-identical results as :class:`Server`, index split over
    ``cfg.n_shards`` devices."""

    def __init__(self, index: hi.HybridIndex,
                 cfg: ServeConfig = ServeConfig(),
                 mesh=None):
        self.cfg = cfg
        self.mesh = mesh or shi.make_shard_mesh(cfg.n_shards)
        self.index = shi.device_put(shi.partition(index, cfg.n_shards),
                                    self.mesh)
        self._search = lambda idx, qe, qt: shi.search(
            idx, qe, qt, kc=cfg.kc, k2=cfg.k2, top_r=cfg.top_r,
            mesh=self.mesh, use_kernel=cfg.use_kernel)
        self.n_served = 0


def make_server(index: hi.HybridIndex, cfg: ServeConfig) -> Server:
    return ShardedServer(index, cfg) if cfg.n_shards > 1 else Server(index,
                                                                     cfg)


def main(argv: Optional[list] = None) -> None:
    ap = argparse.ArgumentParser(description="HI² serving demo loop")
    ap.add_argument("--shards", type=int, default=1,
                    help="document shards (devices); on CPU emulate with "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=N")
    ap.add_argument("--docs", type=int, default=8000)
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--codec", default=codecs.DEFAULT,
                    metavar="|".join(codecs.registered()),
                    help="any registered codec spec, e.g. sq8 or refine:pq:4")
    ap.add_argument("--batch", type=int, default=64)
    args = ap.parse_args(argv)
    codecs.get(args.codec)   # fail fast (with the registered names) on typos

    from repro.data import synthetic
    corpus = synthetic.generate(seed=0, n_docs=args.docs,
                                n_queries=args.queries,
                                hidden=64, vocab_size=4096)
    index = hi.build(jax.random.key(0), jnp.asarray(corpus.doc_emb),
                     jnp.asarray(corpus.doc_tokens), corpus.vocab_size,
                     n_clusters=128, k1_terms=10, codec=args.codec, pq_m=8,
                     pq_k=256, cluster_capacity=192, term_capacity=96,
                     kmeans_iters=8)
    cfg = ServeConfig(max_batch=args.batch, n_shards=args.shards)
    server = make_server(index, cfg)
    server.warmup(64, corpus.query_tokens.shape[1])
    t0 = time.perf_counter()
    for i in range(0, args.queries, args.batch):
        server.query(corpus.query_emb[i:i + args.batch],
                     corpus.query_tokens[i:i + args.batch])
    dt = time.perf_counter() - t0
    layout = f"{args.shards} shard(s)" if args.shards > 1 else "1 device"
    print(f"served {server.n_served} queries in {dt:.3f}s "
          f"({server.n_served / dt:.0f} q/s, {layout})")


if __name__ == "__main__":
    main()
