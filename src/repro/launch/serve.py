"""Serving driver: a persisted HI² index behind a fixed-shape batched
search step (the production query path, DESIGN.md §2).

    PYTHONPATH=src python -m repro.launch.serve                 # 1 device
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \\
    PYTHONPATH=src python -m repro.launch.serve --shards 4      # sharded
    PYTHONPATH=src python -m repro.launch.serve --mutable       # streaming
    PYTHONPATH=src python -m repro.launch.serve --runtime \\
        --linger-ms 2 --cache 1024                  # micro-batched (§10)

Serving layouts:

  · :class:`Server` — the whole index on one device; request batches
    padded to ``max_batch`` so one compiled program serves every
    request size (no recompiles on the hot path).
  · :class:`ShardedServer` — the document-sharded layout of
    DESIGN.md §6: doc planes partitioned over a 1-D device mesh
    (:mod:`repro.core.sharded_index`), per-shard search under
    shard_map, top-R merged by one all-gather.  Bit-identical results,
    1/S of the doc-plane HBM per device.
  · :class:`MeshServer` — the 2-D (data, model) serving mesh of
    DESIGN.md §12 (``--data-parallel D``): doc planes sharded along the
    model axis AND replicated along a data axis over which the query
    batch is partitioned — D× the query throughput of the sharded
    layout, bit-identical results.  Survives model-axis shard loss by
    serving from the survivors' document ranges (``partial=True``)
    until :meth:`MeshServer.rejoin` restores from checkpoint.
  · :class:`MutableServer` / :class:`ShardedMutableServer` — the
    streaming layout of DESIGN.md §8 (``--mutable``): base + delta
    segment + tombstones (:mod:`repro.core.segments`), live
    ``add``/``delete``/``compact`` with no recompiles between
    compactions; the sharded variant routes adds to the owning shard.

Every layout accepts per-query namespace filters (DESIGN.md §9):
build the index with ``--namespaces N`` and pass
``query(..., namespaces=...)`` — one namespace id (or an iterable of
ids) per query — and no document outside those namespaces can appear
in that query's results, on any layout, bit-identically.

Every layout also serves hybrid dense∥sparse fusion (DESIGN.md §13):
``--fusion-weight W`` builds the index with the BM25 impact plane
(``sparse=True``) and fuses the dense ranking with a sparse BM25
ranking by reciprocal-rank fusion; ``W=1.0`` is bit-identical to
dense-only, ``W=0.0`` is pure lexical.  :meth:`Server.set_fusion`
re-weights live (the serving runtime keys its cache on the fusion
spec, so stale fused results can never be replayed).

``--runtime`` puts the asynchronous serving runtime of
:mod:`repro.launch.runtime` (DESIGN.md §10) in front of the chosen
layout: clients submit single queries, a scheduler thread coalesces
them into power-of-two shape buckets (one pre-compiled program each),
an LRU cache short-circuits repeats (``--cache N`` entries, invalidated
by mutations through the index epoch), and a bounded queue
fails fast when overloaded instead of stretching tail latency.

Latency is governed by the static per-query candidate budget
(:func:`repro.core.hybrid_index.candidate_budget` — the proxy all of
``benchmarks/`` reports); ``launch/cells.py::_hi2_serve_cell`` and
``_hi2_sharded_serve_cell`` lower these same steps at MS MARCO scale
for the dry-run.
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpoint as ckpt
from repro.core import codecs
from repro.core import exec as qexec
from repro.core import hybrid_index as hi
from repro.core.exec import filters as ns_filters
from repro.core import segments as seg
from repro.core import sharded_index as shi
from repro.distributed import fault
from repro.launch import mesh as mesh_mod


#: the hand-picked width defaults serving falls back to when neither an
#: explicit ServeConfig override nor a tuned index record is present
DEFAULT_KC, DEFAULT_K2 = 6, 8


@dataclasses.dataclass
class ServeConfig:
    # dispatch widths (DESIGN.md §14): None = resolve at server
    # construction — the index's TunedWidths record when present, else
    # DEFAULT_KC/DEFAULT_K2; an explicit value always wins
    kc: Optional[int] = None
    k2: Optional[int] = None
    top_r: int = 100
    max_batch: int = 64
    use_kernel: bool = False     # fused Pallas scoring (--use-kernel, §11)
    n_shards: int = 1            # >1 → document-sharded layout
    mutable: bool = False        # serve a MutableHybridIndex (§8)
    delta_capacity: int = 1024   # delta slots between compactions
    n_namespaces: int = 0        # >0 → filtered search over N namespaces
    data_parallel: int = 1       # >1 → 2-D (data, model) serving mesh (§12)
    # hybrid dense∥sparse fusion (§13): None = dense-only; else the RRF
    # dense weight in [0, 1] (sparse gets 1-w).  Needs an index built
    # with sparse=True, otherwise the dense-only fallback applies.
    fusion_weight: Optional[float] = None
    # per-query adaptive widths (§14): route each query to a rung of
    # the tuned ladder by its dispatch-margin difficulty signal.  Only
    # takes effect when the index carries a multi-rung TunedWidths
    # record and no explicit kc/k2 override is set.
    adaptive: bool = False
    # auto-compaction watermarks (§8): compact when delta fill or
    # tombstone ratio crosses the threshold; 0 disables (the default —
    # serving never compacts behind the operator's back unless asked)
    compact_fill_watermark: float = 0.0
    compact_tombstone_watermark: float = 0.0


def resolve_widths(cfg: ServeConfig, index) -> tuple:
    """Resolve the serving dispatch widths (DESIGN.md §14).

    Resolution order, per field: an explicit ``ServeConfig`` value
    wins, else the index's :class:`repro.core.exec.TunedWidths` record,
    else :data:`DEFAULT_KC`/:data:`DEFAULT_K2`.  Returns
    ``(kc, k2, source)`` where ``source`` is ``"explicit"`` (any field
    overridden), ``"tuned"`` or ``"default"`` — adaptive serving only
    engages when the source is ``"tuned"`` (an operator pinning widths
    pins them for every query).
    """
    tuned = getattr(index, "tuned", None)
    fb_kc = tuned.kc if tuned is not None else DEFAULT_KC
    fb_k2 = tuned.k2 if tuned is not None else DEFAULT_K2
    if cfg.kc is not None or cfg.k2 is not None:
        return (int(cfg.kc if cfg.kc is not None else fb_kc),
                int(cfg.k2 if cfg.k2 is not None else fb_k2), "explicit")
    if tuned is not None:
        return int(tuned.kc), int(tuned.k2), "tuned"
    return DEFAULT_KC, DEFAULT_K2, "default"


class Server:
    """Pads request batches to max_batch so one compiled program serves
    every request size (no recompiles on the hot path)."""

    def __init__(self, index: hi.HybridIndex, cfg: ServeConfig = ServeConfig()):
        self.index = index
        self.cfg = cfg
        self._resolve_widths(index)
        # hi.search is already jitted (static kc/k2/top_r/use_kernel/
        # fusion) — dispatch through a bound method instead of wrapping
        # in a second jax.jit, which would pay nested-jit dispatch on
        # every request; reading cfg at call time lets set_fusion()
        # re-weight live (one compile per distinct FusionSpec)
        self._search = self._base_search
        self.n_served = 0

    def _resolve_widths(self, index) -> None:
        """Resolve (kc, k2) once at construction (DESIGN.md §14) —
        stable across mutations/compactions, like the codec spec."""
        self.tuned = getattr(index, "tuned", None)
        self.kc, self.k2, self.width_source = resolve_widths(self.cfg,
                                                             index)

    def _base_search(self, idx, qe, qt, filter=None,
                     widths=None) -> hi.SearchResult:
        kc, k2 = widths if widths is not None else (self.kc, self.k2)
        return hi.search(idx, qe, qt, kc=kc, k2=k2,
                         top_r=self.cfg.top_r,
                         use_kernel=self.cfg.use_kernel,
                         filter=filter, fusion=self.fusion)

    @classmethod
    def from_checkpoint(cls, path: str, like: hi.HybridIndex,
                        cfg: ServeConfig = ServeConfig()) -> "Server":
        return cls(ckpt.restore_index(path, like), cfg)

    @property
    def epoch(self) -> int:
        """Index mutation counter (DESIGN.md §10) — constant 0 here:
        an immutable index never invalidates cached results.  Mutable
        servers override with the live counter."""
        return 0

    @property
    def n_replicas(self) -> int:
        """Data-axis replica slices (DESIGN.md §12) — the runtime's
        batch quantum: every micro-batch bucket must divide into equal
        per-replica row blocks.  1 on every non-mesh layout."""
        return max(1, int(self.cfg.data_parallel))

    @property
    def _adaptive_ladder(self) -> bool:
        t = self.tuned
        return (self.cfg.adaptive and t is not None and len(t.rungs) > 1
                and self.width_source != "explicit")

    @property
    def rungs(self) -> tuple:
        """The static width ladder adaptive serving compiles, narrow →
        wide (DESIGN.md §14).  A single rung — the resolved (kc, k2) —
        unless adaptivity is on, the index carries a multi-rung tuned
        record, and no explicit override pinned the widths."""
        if self._adaptive_ladder:
            return tuple((int(kc), int(k2)) for kc, k2 in self.tuned.rungs)
        return ((self.kc, self.k2),)

    @property
    def margin_cuts(self) -> tuple:
        """Descending margin thresholds between the rungs (one fewer
        than :attr:`rungs`); empty in the single-rung case."""
        if self._adaptive_ladder:
            return tuple(float(c) for c in self.tuned.margin_cuts)
        return ()

    @property
    def fusion(self) -> Optional[qexec.FusionSpec]:
        """The active hybrid-fusion spec (DESIGN.md §13), derived from
        ``cfg.fusion_weight`` at call time so :meth:`set_fusion` takes
        effect without rebuilding the server.  None = dense-only."""
        w = self.cfg.fusion_weight
        return None if w is None else qexec.FusionSpec(weight=float(w))

    def set_fusion(self, weight: Optional[float]) -> None:
        """Re-weight (or disable, with None) hybrid fusion live.  Takes
        effect on the next query; each distinct weight compiles once
        (the spec is a static argument of the search program)."""
        if weight is not None:
            qexec.FusionSpec(weight=float(weight))  # validate eagerly
        self.cfg.fusion_weight = weight

    def warmup(self, hidden: int, query_len: int) -> None:
        qe = jnp.zeros((self.cfg.max_batch, hidden), jnp.float32)
        qt = jnp.full((self.cfg.max_batch, query_len), -1, jnp.int32)
        jax.block_until_ready(self._search(self.index, qe, qt))

    def _pad(self, query_emb: np.ndarray, query_tokens: np.ndarray):
        n = query_emb.shape[0]
        pad = self.cfg.max_batch - n
        assert pad >= 0, f"batch {n} exceeds max_batch {self.cfg.max_batch}"
        qe = jnp.asarray(np.pad(query_emb, ((0, pad), (0, 0))))
        qt = jnp.asarray(np.pad(query_tokens, ((0, pad), (0, 0)),
                                constant_values=-1))
        return n, qe, qt

    def _filter(self, namespaces, n: int):
        """Per-query ``namespaces`` (one id or iterable of ids per
        query, length n) → the padded (max_batch, W) bitmap; padded
        query rows match nothing (like the PAD query tokens)."""
        if namespaces is None:
            return None
        if not self.cfg.n_namespaces:
            raise ValueError(
                "this server was built without namespaces; construct "
                "with ServeConfig(n_namespaces=N) / --namespaces N")
        if len(namespaces) != n:
            raise ValueError(f"{len(namespaces)} filter rows for {n} "
                             "queries")
        bitmap = ns_filters.make_filter(namespaces, self.cfg.n_namespaces)
        return ns_filters.pad_filter(bitmap, self.cfg.max_batch)

    def query(self, query_emb: np.ndarray, query_tokens: np.ndarray,
              namespaces=None) -> hi.SearchResult:
        n, qe, qt = self._pad(query_emb, query_tokens)
        res = self._search(self.index, qe, qt,
                           filter=self._filter(namespaces, n))
        self.n_served += n
        return hi.SearchResult(
            doc_ids=res.doc_ids[:n],
            scores=res.scores[:n],
            n_candidates=res.n_candidates[:n],
            partial=bool(np.asarray(getattr(res, "partial", False))))

    # mutation API — live only on the mutable servers below
    def add(self, doc_emb: np.ndarray, doc_tokens: np.ndarray,
            namespaces=None) -> np.ndarray:
        raise RuntimeError("this server is immutable; construct with "
                           "ServeConfig(mutable=True) / --mutable to "
                           "enable add/delete/compact")

    def delete(self, doc_ids) -> None:
        self.add(None, None)     # same immutability error

    def compact(self) -> None:
        self.add(None, None)


class ShardedServer(Server):
    """Document-sharded serving (DESIGN.md §6): same request contract
    and bit-identical results as :class:`Server`, index split over
    ``cfg.n_shards`` devices."""

    def __init__(self, index: hi.HybridIndex,
                 cfg: ServeConfig = ServeConfig(),
                 mesh=None):
        self.cfg = cfg
        # widths resolve from the input index: the sharded form drops
        # the tuned record (it is per-index metadata, not per-shard)
        self._resolve_widths(index)
        self.mesh = mesh or shi.make_shard_mesh(cfg.n_shards)
        self.index = shi.device_put(shi.partition(index, cfg.n_shards),
                                    self.mesh)
        self._search = self._sharded_search
        self.n_served = 0

    def _sharded_search(self, idx, qe, qt, filter=None,
                        widths=None) -> hi.SearchResult:
        kc, k2 = widths if widths is not None else (self.kc, self.k2)
        return shi.search(idx, qe, qt, kc=kc, k2=k2,
                          top_r=self.cfg.top_r, mesh=self.mesh,
                          use_kernel=self.cfg.use_kernel, filter=filter,
                          fusion=self.fusion)


class MeshServer(Server):
    """2-D (data, model) mesh serving with shard-loss degradation
    (DESIGN.md §12).

    The index is partitioned into ``cfg.n_shards`` document shards along
    the model axis and replicated along ``cfg.data_parallel`` data-axis
    slices; each slice searches its block of the query batch
    independently, so throughput scales with the data axis while every
    result stays bit-identical to the single-device search (the §6 merge
    runs per-replica over the model axis only).

    Survivability: :meth:`eject_shard` drops one model-axis shard from
    the serving set — requests keep being served from the survivors'
    document ranges, flagged ``partial=True`` — and :meth:`rejoin`
    restores the full mesh from a :meth:`checkpoint`, bit-identical to
    the pre-failure results.  Both bump :attr:`epoch`, so runtime caches
    can never replay full results while degraded or vice versa.
    """

    def __init__(self, index: hi.HybridIndex,
                 cfg: ServeConfig = ServeConfig(), mesh=None):
        data, model = max(1, int(cfg.data_parallel)), int(cfg.n_shards)
        if cfg.max_batch % data:
            raise ValueError(
                f"max_batch {cfg.max_batch} must divide over "
                f"{data} data-axis slices")
        self.cfg = cfg
        self._resolve_widths(index)
        self.data, self.model = data, model
        self.data_axis = "data"
        self.mesh = mesh or mesh_mod.make_serving_mesh(data, model)
        self._full = shi.device_put(shi.partition(index, model), self.mesh)
        self.index = self._full
        # zero-memory restore template (shapes/dtypes, no plane bytes):
        # rejoin-from-checkpoint must not depend on live full-mesh state
        self._template = jax.tree.map(
            lambda x: np.broadcast_to(np.zeros((), x.dtype), x.shape),
            self._full)
        self.health = fault.ShardHealth(model)
        self._survivor = None    # (sub_index, sub_mesh, offsets) | None
        self._mesh_epoch = 0
        self._search = self._mesh_search
        self.n_served = 0

    @property
    def epoch(self) -> int:
        """Bumps on every membership change (eject/rejoin) — degraded
        and full results must never share a cache namespace."""
        return self._mesh_epoch

    @property
    def partial(self) -> bool:
        return self.health.degraded

    def _mesh_search(self, idx, qe, qt, filter=None,
                     widths=None) -> hi.SearchResult:
        kc, k2 = widths if widths is not None else (self.kc, self.k2)
        da = self.data_axis if self.data > 1 else None
        if self._survivor is None:
            return shi.search(self._full, qe, qt, kc=kc,
                              k2=k2, top_r=self.cfg.top_r,
                              mesh=self.mesh,
                              use_kernel=self.cfg.use_kernel,
                              filter=filter, data_axis=da,
                              fusion=self.fusion)
        sub, sub_mesh, offsets = self._survivor
        res = shi.search(sub, qe, qt, kc=kc, k2=k2,
                         top_r=self.cfg.top_r, mesh=sub_mesh,
                         use_kernel=self.cfg.use_kernel, filter=filter,
                         data_axis=da, shard_offsets=offsets,
                         fusion=self.fusion)
        return res._replace(partial=True)

    # --- shard-loss degradation + recovery -------------------------------
    def note_shard_latency(self, shard: int, dt: float) -> bool:
        """Feed one measured per-shard latency into the straggler policy
        (:class:`repro.distributed.fault.ShardHealth`); ejects the shard
        and returns True once it crosses ``MAX_STRIKES`` deadline
        misses."""
        if self.health.observe(shard, dt):
            self.eject_shard(shard)
            return True
        return False

    def eject_shard(self, shard: int) -> None:
        """Drop one model-axis shard from the serving set: subsequent
        queries are served from the survivors' document ranges and
        flagged ``partial=True``.  Idempotent per shard; the last
        healthy shard cannot be ejected."""
        if shard in self.health.lost:
            return
        self.health.eject(shard)
        survivors = self.health.healthy
        sub_mesh = mesh_mod.make_serving_mesh(self.data, len(survivors))
        sub = shi.device_put(shi.take_shards(self._full, survivors),
                             sub_mesh)
        offsets = shi.shard_offsets_for(survivors,
                                        self._full.docs_per_shard)
        self._survivor = (sub, sub_mesh, offsets)
        self._mesh_epoch += 1

    def lost_doc_ranges(self) -> list:
        """[lo, hi) global doc-id ranges currently missing from results
        — the degradation contract surface (DESIGN.md §12)."""
        per, n = self._full.docs_per_shard, self._full.n_docs
        return [(m * per, min((m + 1) * per, n)) for m in self.health.lost]

    def checkpoint(self, directory: str, step: int = 0) -> str:
        """Persist the full sharded index (codec spec recorded in the
        manifest); the path feeds :meth:`rejoin`."""
        return ckpt.save_index(directory, step, self._full)

    def rejoin(self, checkpoint_path: str) -> None:
        """Restore the full mesh from a checkpoint: every lost shard
        returns, results are bit-identical to pre-failure full-mesh
        serving (one more epoch bump keeps caches honest)."""
        restored = ckpt.restore_index(checkpoint_path, self._template)
        self._full = shi.device_put(restored, self.mesh)
        self.index = self._full
        self.health.rejoin()
        self._survivor = None
        self._mesh_epoch += 1


class MutableServer(Server):
    """Serving over a :class:`repro.core.segments.MutableHybridIndex`
    (DESIGN.md §8): the same padded-batch request contract as
    :class:`Server`, plus live ``add``/``delete``/``compact``.  Mutation
    changes plane values, never shapes, so the compiled search program
    is reused across mutations; ``compact()`` swaps in the fresh base
    (one recompile per compaction, never per request)."""

    def __init__(self, mut: seg.MutableHybridIndex,
                 cfg: ServeConfig = ServeConfig()):
        self.mut = mut
        self.cfg = cfg
        self._resolve_widths(mut.base)
        self.index = mut.base    # for the padded-query plumbing only
        self._search = self._mut_search
        self.n_served = 0

    def _mut_search(self, idx, qe, qt, filter=None,
                    widths=None) -> hi.SearchResult:
        kc, k2 = widths if widths is not None else (self.kc, self.k2)
        return self.mut.search(qe, qt, kc=kc, k2=k2,
                               top_r=self.cfg.top_r,
                               use_kernel=self.cfg.use_kernel,
                               filter=filter, fusion=self.fusion)

    @property
    def epoch(self) -> int:
        """The mutable index's mutation counter: bumps on every
        ``add``/``delete`` and across ``compact`` — the cache
        invalidation key of the serving runtime (DESIGN.md §10)."""
        return self.mut.epoch

    def add(self, doc_emb: np.ndarray, doc_tokens: np.ndarray,
            namespaces=None) -> np.ndarray:
        """Index new documents; returns their global doc ids.  On a
        namespaced server ``namespaces`` (scalar or (n,) ids) is
        required."""
        ids = self.mut.add_docs(doc_emb, doc_tokens,
                                namespaces=namespaces)
        self._auto_compact()
        return ids

    def delete(self, doc_ids) -> None:
        """Tombstone documents; they can never appear in results again."""
        self.mut.delete_docs(doc_ids)
        self._auto_compact()

    def _auto_compact(self) -> None:
        """Watermark-driven compaction (DESIGN.md §8): compact when the
        delta fill or tombstone ratio crosses its configured threshold.
        Both watermarks default to 0.0 = disabled — serving never
        compacts behind the operator's back unless asked."""
        fill = self.cfg.compact_fill_watermark
        tomb = self.cfg.compact_tombstone_watermark
        if fill <= 0.0 and tomb <= 0.0:
            return
        host = getattr(self.mut, "mut", self.mut)
        if host.needs_compact(fill_watermark=fill, tombstone_watermark=tomb):
            self.compact()

    def compact(self) -> None:
        """Fold delta + tombstones into a fresh base (bit-identical to a
        from-scratch rebuild over the surviving corpus)."""
        self.mut = self.mut.compact()
        self.index = self.mut.base


class ShardedMutableServer(MutableServer):
    """Mutable + document-sharded: adds are routed to the owning shard
    (``repro.core.segments.ShardedMutableIndex``), results stay
    bit-identical to the single-device :class:`MutableServer`."""

    def __init__(self, mut: seg.MutableHybridIndex,
                 cfg: ServeConfig = ServeConfig(), mesh=None):
        data = max(1, int(cfg.data_parallel))
        if data > 1:
            if cfg.max_batch % data:
                raise ValueError(
                    f"max_batch {cfg.max_batch} must divide over "
                    f"{data} data-axis slices")
            mesh = mesh or mesh_mod.make_serving_mesh(data, cfg.n_shards)
            smut = seg.ShardedMutableIndex(mut, cfg.n_shards, mesh,
                                           data_axis="data")
        else:
            smut = seg.ShardedMutableIndex(mut, cfg.n_shards, mesh)
        self.mut = smut
        self.cfg = cfg
        self._resolve_widths(mut.base)
        self.index = smut.mut.base
        self._search = self._mut_search
        self.n_served = 0

    def compact(self) -> None:
        self.mut = self.mut.compact()
        self.index = self.mut.mut.base


def make_server(index: hi.HybridIndex, cfg: ServeConfig) -> Server:
    if cfg.mutable:
        raise ValueError("make_server serves a built immutable index; "
                         "use make_mutable_server(mut, cfg) for "
                         "ServeConfig(mutable=True)")
    if cfg.data_parallel > 1:
        return MeshServer(index, cfg)
    return ShardedServer(index, cfg) if cfg.n_shards > 1 else Server(index,
                                                                     cfg)


def make_mutable_server(mut: seg.MutableHybridIndex,
                        cfg: ServeConfig) -> MutableServer:
    if cfg.n_shards > 1:
        return ShardedMutableServer(mut, cfg)
    return MutableServer(mut, cfg)


def main(argv: Optional[list] = None) -> None:
    ap = argparse.ArgumentParser(description="HI² serving demo loop")
    ap.add_argument("--shards", type=int, default=1,
                    help="document shards (devices); on CPU emulate with "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=N")
    ap.add_argument("--docs", type=int, default=8000)
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--codec", default=codecs.DEFAULT,
                    metavar="|".join(codecs.registered()),
                    help="any registered codec spec, e.g. sq8 or refine:pq:4")
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--kc", type=int, default=None,
                    help="clusters probed per query; default = the "
                         "index's tuned record if present, else "
                         f"{DEFAULT_KC} (DESIGN.md §14)")
    ap.add_argument("--k2", type=int, default=None,
                    help="term lists probed per query; default = the "
                         "index's tuned record if present, else "
                         f"{DEFAULT_K2}")
    ap.add_argument("--adaptive", action="store_true",
                    help="per-query adaptive widths over the tuned rung "
                         "ladder (needs an index tuned by "
                         "repro.launch.tune; DESIGN.md §14)")
    ap.add_argument("--mutable", action="store_true",
                    help="serve a mutable index and demo live "
                         "add/delete/compact (DESIGN.md §8)")
    ap.add_argument("--delta-capacity", type=int, default=1024,
                    help="delta slots between compactions (--mutable)")
    ap.add_argument("--namespaces", type=int, default=0,
                    help="partition the corpus into N namespaces and demo "
                         "per-query filtered search (DESIGN.md §9)")
    ap.add_argument("--use-kernel", action="store_true",
                    help="score candidates with the fused Pallas kernels "
                         "(DESIGN.md §11; interpret-mode on CPU)")
    ap.add_argument("--fusion-weight", type=float, default=None,
                    metavar="W",
                    help="hybrid dense∥sparse serving (DESIGN.md §13): "
                         "build the BM25 impact plane and fuse dense and "
                         "sparse rankings by RRF with dense weight W in "
                         "[0,1] (1.0 = dense-only, 0.0 = pure lexical)")
    ap.add_argument("--runtime", action="store_true",
                    help="serve through the micro-batching runtime "
                         "(DESIGN.md §10) instead of direct batched calls")
    ap.add_argument("--linger-ms", type=float, default=2.0,
                    help="max wait of the oldest queued request for "
                         "co-riders before its bucket executes (--runtime)")
    ap.add_argument("--cache", type=int, default=0,
                    help="LRU query-result cache entries, 0 = off "
                         "(--runtime)")
    ap.add_argument("--data-parallel", type=int, default=1,
                    help="data-axis replica slices for the 2-D serving "
                         "mesh (DESIGN.md §12); needs shards x replicas "
                         "devices")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="with --runtime: serve plaintext metrics on "
                         "http://127.0.0.1:PORT/metrics (0 = ephemeral)")
    args = ap.parse_args(argv)
    codecs.get(args.codec)   # fail fast (with the registered names) on typos

    from repro.data import synthetic
    corpus = synthetic.generate(seed=0, n_docs=args.docs,
                                n_queries=args.queries,
                                hidden=64, vocab_size=4096)
    build_kwargs = dict(n_clusters=128, k1_terms=10, codec=args.codec,
                        pq_m=8, pq_k=256, cluster_capacity=192,
                        term_capacity=96, kmeans_iters=8,
                        sparse=args.fusion_weight is not None)
    cfg = ServeConfig(kc=args.kc, k2=args.k2, adaptive=args.adaptive,
                      max_batch=args.batch, n_shards=args.shards,
                      use_kernel=args.use_kernel,
                      mutable=args.mutable,
                      delta_capacity=args.delta_capacity,
                      n_namespaces=args.namespaces,
                      data_parallel=args.data_parallel,
                      fusion_weight=args.fusion_weight)
    # round-robin tenant assignment for the demo corpus
    doc_ns = (np.arange(args.docs) % args.namespaces
              if args.namespaces else None)
    if args.mutable:
        if args.docs < 512:
            sys.exit("--mutable demo needs --docs >= 512 (the base build "
                     "must keep enough docs for KMeans after the held-out "
                     "stream is split off)")
        # stream the last ~1/8 of the corpus in live, then compact;
        # never more than the delta can hold or half the corpus
        held = max(args.batch, args.docs // 8)
        held = min(held, args.delta_capacity, args.docs // 2)
        mut = seg.MutableHybridIndex.create(
            jax.random.key(0), corpus.doc_emb[:-held],
            corpus.doc_tokens[:-held], corpus.vocab_size,
            delta_capacity=args.delta_capacity,
            doc_namespaces=None if doc_ns is None else doc_ns[:-held],
            **build_kwargs)
        server = make_mutable_server(mut, cfg)
    else:
        index = hi.build(jax.random.key(0), jnp.asarray(corpus.doc_emb),
                         jnp.asarray(corpus.doc_tokens), corpus.vocab_size,
                         doc_namespaces=doc_ns, **build_kwargs)
        server = make_server(index, cfg)
    metrics = None
    if args.runtime:
        from repro.launch import runtime as rt_mod
        front = rt_mod.ServingRuntime(
            server, rt_mod.RuntimeConfig(
                linger_ms=args.linger_ms, cache_size=args.cache,
                # the demo submits whole batches back-to-back; admission
                # control must not reject its own driver loop
                queue_depth=max(256, 2 * args.batch)))
        front.warmup(64, corpus.query_tokens.shape[1])
        if args.metrics_port is not None:
            metrics = front.serve_metrics(args.metrics_port)
            print(f"metrics: http://127.0.0.1:{metrics.port}/metrics")
    else:
        front = server
        server.warmup(64, corpus.query_tokens.shape[1])
    t0 = time.perf_counter()
    for i in range(0, args.queries, args.batch):
        front.query(corpus.query_emb[i:i + args.batch],
                    corpus.query_tokens[i:i + args.batch])
    dt = time.perf_counter() - t0
    if args.data_parallel > 1:
        layout = f"({args.data_parallel}, {args.shards}) mesh"
    elif args.shards > 1:
        layout = f"{args.shards} shard(s)"
    else:
        layout = "1 device"
    print(f"served {server.n_served} queries in {dt:.3f}s "
          f"({server.n_served / dt:.0f} q/s, {layout})")
    if args.namespaces:
        # each query restricted to one tenant; results must honor it
        b = min(args.batch, args.queries)
        want = [i % args.namespaces for i in range(b)]
        res = front.query(corpus.query_emb[:b], corpus.query_tokens[:b],
                          namespaces=want)
        ids = np.asarray(res.doc_ids)
        ok = all((ids[i][ids[i] >= 0] % args.namespaces == want[i]).all()
                 for i in range(b))
        print(f"filtered: {b} queries x 1/{args.namespaces} namespaces, "
              f"mean candidates "
              f"{float(np.asarray(res.n_candidates).mean()):.0f}, "
              f"tenant isolation {'OK' if ok else 'VIOLATED'}")
        if not ok:
            sys.exit("namespace filter violated tenant isolation")
    if args.mutable:
        ids = front.add(corpus.doc_emb[-held:], corpus.doc_tokens[-held:],
                        namespaces=(None if not args.namespaces else
                                    doc_ns[-held:]))
        front.query(corpus.query_emb[:args.batch],
                    corpus.query_tokens[:args.batch])
        front.delete(ids[: held // 4])
        t0 = time.perf_counter()
        front.compact()
        dt_c = time.perf_counter() - t0
        mut_idx = server.mut
        print(f"mutable: added {held}, deleted {held // 4}, "
              f"compacted to {getattr(mut_idx, 'mut', mut_idx).n_base} "
              f"docs in {dt_c:.2f}s")
    if args.runtime:
        if metrics is not None:
            metrics.close()
        front.close(drain=True)
        s = front.stats()
        cache = s["cache"]
        print(f"runtime: {s['n_batches']} batches over buckets "
              f"{s['buckets']} (counts {s['bucket_counts']}), "
              f"compiles/bucket {s['warm_traces']}, "
              f"{s['post_warmup_traces']} post-warmup compiles"
              + ("" if cache is None else
                 f", cache {cache['hits']} hits / {cache['misses']} "
                 f"misses"))


if __name__ == "__main__":
    main()
