"""Serving driver: a persisted HI² index behind a fixed-shape batched
search step (the production query path).

    PYTHONPATH=src python -m repro.launch.serve        # demo loop

At pod scale the index planes are sharded over the model axis and the
request batch over (pod, data) — `launch/cells.py::_hi2_serve_cell`
lowers exactly this step for the dry-run; here the same search runs for
real at CPU scale.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpoint as ckpt
from repro.core import hybrid_index as hi


@dataclasses.dataclass
class ServeConfig:
    kc: int = 6
    k2: int = 8
    top_r: int = 100
    max_batch: int = 64
    use_kernel: bool = False     # Pallas ADC on TPU


class Server:
    """Pads request batches to max_batch so one compiled program serves
    every request size (no recompiles on the hot path)."""

    def __init__(self, index: hi.HybridIndex, cfg: ServeConfig = ServeConfig()):
        self.index = index
        self.cfg = cfg
        self._search = jax.jit(
            lambda idx, qe, qt: hi.search(idx, qe, qt, kc=cfg.kc, k2=cfg.k2,
                                          top_r=cfg.top_r,
                                          use_kernel=cfg.use_kernel))
        self.n_served = 0

    @classmethod
    def from_checkpoint(cls, path: str, like: hi.HybridIndex,
                        cfg: ServeConfig = ServeConfig()) -> "Server":
        return cls(ckpt.restore(path, like), cfg)

    def warmup(self, hidden: int, query_len: int) -> None:
        qe = jnp.zeros((self.cfg.max_batch, hidden), jnp.float32)
        qt = jnp.full((self.cfg.max_batch, query_len), -1, jnp.int32)
        jax.block_until_ready(self._search(self.index, qe, qt))

    def query(self, query_emb: np.ndarray, query_tokens: np.ndarray
              ) -> hi.SearchResult:
        n = query_emb.shape[0]
        pad = self.cfg.max_batch - n
        assert pad >= 0, f"batch {n} exceeds max_batch {self.cfg.max_batch}"
        qe = jnp.asarray(np.pad(query_emb, ((0, pad), (0, 0))))
        qt = jnp.asarray(np.pad(query_tokens, ((0, pad), (0, 0)),
                                constant_values=-1))
        res = self._search(self.index, qe, qt)
        self.n_served += n
        return hi.SearchResult(doc_ids=res.doc_ids[:n],
                               scores=res.scores[:n],
                               n_candidates=res.n_candidates[:n])


def main() -> None:
    from repro.data import synthetic
    corpus = synthetic.generate(seed=0, n_docs=8000, n_queries=256,
                                hidden=64, vocab_size=4096)
    index = hi.build(jax.random.key(0), jnp.asarray(corpus.doc_emb),
                     jnp.asarray(corpus.doc_tokens), corpus.vocab_size,
                     n_clusters=128, k1_terms=10, codec="opq", pq_m=8,
                     pq_k=256, cluster_capacity=192, term_capacity=96,
                     kmeans_iters=8)
    server = Server(index)
    server.warmup(64, corpus.query_tokens.shape[1])
    t0 = time.perf_counter()
    for i in range(0, 256, 64):
        server.query(corpus.query_emb[i:i + 64],
                     corpus.query_tokens[i:i + 64])
    dt = time.perf_counter() - t0
    print(f"served {server.n_served} queries in {dt:.3f}s "
          f"({server.n_served / dt:.0f} q/s)")


if __name__ == "__main__":
    main()
