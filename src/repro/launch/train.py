"""Training drivers.

Two layers:
  · ``fit`` — the generic fault-tolerant loop every example uses
    (checkpoint manager + auto-resume + straggler monitor + optional
    gradient compression);
  · ``train_hi2_sup`` — the paper's joint optimization (§4.3): learns
    cluster embeddings + the term-scorer encoder/MLP by KL distillation
    from a teacher embedding model, then assembles the HI²_sup index
    (``build_sup_index`` for the immutable layouts, ``SupSelectors``
    for the mutable ones — DESIGN.md §15).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core import cluster_selector as cs_mod
from repro.core import distill, hybrid_index as hi
from repro.core import term_selector as ts_mod
from repro.data import synthetic
from repro.distributed.fault import StragglerMonitor
from repro.models import transformer as tfm
from repro.optim import (AdamConfig, adam_init, adam_update,
                        clip_by_global_norm, warmup_cosine)


# --------------------------------------------------------------------------
# generic loop
# --------------------------------------------------------------------------

def fit(loss_fn: Callable, params: Any, batches: Callable[[int], Any],
        n_steps: int, *, adam: AdamConfig = AdamConfig(lr=1e-3),
        clip_norm: float = 1.0, ckpt_dir: Optional[str] = None,
        save_every: int = 100, log_every: int = 20,
        schedule=None, monitor: Optional[StragglerMonitor] = None
        ) -> tuple[Any, list[float]]:
    """Generic train loop: value_and_grad + clip + AdamW (+ checkpointing,
    resume, straggler monitoring).

    The monitor is an *observer*: it times steps and counts strikes but
    sits entirely outside the numeric path, so running with any monitor
    (or none) leaves the optimizer trajectory bit-identical — asserted
    by tests/test_distill.py.
    """
    schedule = schedule or (lambda s: 1.0)
    state = adam_init(params)
    start = 0
    mgr = None
    if ckpt_dir:
        mgr = CheckpointManager(ckpt_dir, keep_n=2, save_every=save_every)
        step0, restored = mgr.restore_latest({"params": params, "opt": state})
        if step0 is not None:
            params, state, start = restored["params"], restored["opt"], step0

    @jax.jit
    def step_fn(p, s, batch, lr_scale):
        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            p, batch)
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        p, s = adam_update(grads, s, p, adam, lr_scale=lr_scale)
        return p, s, loss, gnorm

    monitor = monitor or StragglerMonitor()
    losses = []
    for i in range(start, n_steps):
        monitor.step_start()
        params, state, loss, gnorm = step_fn(params, state, batches(i),
                                             schedule(i))
        losses.append(float(loss))
        monitor.step_end()
        if mgr and mgr.should_save(i + 1):
            mgr.save(i + 1, {"params": params, "opt": state})
        if log_every and (i + 1) % log_every == 0:
            print(f"  step {i+1}/{n_steps} loss={float(loss):.4f} "
                  f"gnorm={float(gnorm):.3f}", flush=True)
    return params, losses


# --------------------------------------------------------------------------
# HI²_sup distillation (paper §4.3, DESIGN.md §15)
# --------------------------------------------------------------------------

@dataclasses.dataclass
class SupTrainConfig:
    n_clusters: int = 128
    encoder_layers: int = 2
    encoder_dim: int = 64
    encoder_heads: int = 4
    n_steps: int = 300
    batch_queries: int = 32
    n_negatives: int = 7
    n_inbatch: int = 0          # extra in-batch negatives per row (§15)
    refine_weight: float = 0.0  # λ of the refine-stage KL (§15)
    lr: float = 2e-3
    warmup_steps: int = 20      # linear warmup, then cosine to n_steps
    kmeans_iters: int = 10
    seed: int = 0


def train_hi2_sup(corpus: synthetic.Corpus, cfg: SupTrainConfig,
                  log_every: int = 50, *,
                  negatives: Optional[np.ndarray] = None,
                  ckpt_dir: Optional[str] = None):
    """Returns (DistillParams, encoder cfg, φ assignments, losses).

    ``negatives`` optionally overrides the per-query hard-negative pool
    ((n_queries, >=cfg.n_negatives) doc ids) — the §15 recipe mines it
    from the HI²_unsup index (:func:`repro.core.distill.
    mine_hard_negatives`); the default is the topic-matched pool of
    :func:`repro.data.synthetic.hard_negatives`.  ``cfg.n_inbatch``
    additionally appends in-batch negatives (other rows' positives) to
    every candidate row; ``cfg.refine_weight`` enables the refine-stage
    KL.  ``ckpt_dir`` threads through to :func:`fit` for checkpointed/
    resumable training.
    """
    key = jax.random.key(cfg.seed)
    doc_emb = jnp.asarray(corpus.doc_emb)

    # init cluster embeddings from KMeans; φ(D) frozen afterwards (§4.3)
    k1, k2, k3 = jax.random.split(key, 3)
    cluster_sel, doc_assign = cs_mod.init_kmeans(
        k1, doc_emb, cfg.n_clusters, n_iters=cfg.kmeans_iters)

    enc_cfg = tfm.TransformerConfig(
        n_layers=cfg.encoder_layers, d_model=cfg.encoder_dim,
        n_heads=cfg.encoder_heads, n_kv_heads=cfg.encoder_heads,
        d_ff=cfg.encoder_dim * 4, vocab_size=corpus.vocab_size,
        causal=False, compute_dtype=jnp.float32, remat=False)
    params = distill.DistillParams(
        cluster_embeddings=cluster_sel.embeddings,
        term_mlp=ts_mod.init_mlp(k2, cfg.encoder_dim),
        encoder=tfm.init(k3, enc_cfg),
    )

    def encoder_apply(enc_params, tokens):
        hidden, _ = tfm.encode(enc_params, enc_cfg, tokens)
        return hidden

    if negatives is None:
        negatives = synthetic.hard_negatives(corpus, cfg.n_negatives,
                                             seed=cfg.seed)
    negatives = np.asarray(negatives, np.int32)
    if negatives.shape[1] < cfg.n_negatives:
        raise ValueError(
            f"negatives pool has {negatives.shape[1]} per query, "
            f"cfg.n_negatives={cfg.n_negatives}")
    nq = corpus.qrels.shape[0]
    assign_np = np.asarray(doc_assign)

    def batches(step: int):
        rng = np.random.default_rng(cfg.seed * 7919 + step)
        qi = rng.integers(0, nq, cfg.batch_queries)
        # per-row: own positive first, then a draw from the hard pool
        cols = rng.permuted(
            np.broadcast_to(np.arange(negatives.shape[1]),
                            (cfg.batch_queries, negatives.shape[1])),
            axis=1)[:, :cfg.n_negatives]
        hard = negatives[qi[:, None], cols]
        cand = np.concatenate([corpus.qrels[qi][:, None], hard], axis=1)
        cand = distill.add_in_batch_negatives(rng, cand, corpus.qrels[qi],
                                              cfg.n_inbatch)
        return distill.DistillBatch(
            query_emb=jnp.asarray(corpus.query_emb[qi]),
            query_tokens=jnp.asarray(corpus.query_tokens[qi]),
            doc_emb=jnp.asarray(corpus.doc_emb[cand]),
            doc_tokens=jnp.asarray(corpus.doc_tokens[cand]),
            doc_assign=jnp.asarray(assign_np[cand]),
        )

    def loss_fn(p, batch):
        return distill.loss_fn(p, batch, encoder_apply=encoder_apply,
                               vocab_size=corpus.vocab_size,
                               refine_weight=cfg.refine_weight)

    params, losses = fit(loss_fn, params, batches, cfg.n_steps,
                         adam=AdamConfig(lr=cfg.lr),
                         schedule=warmup_cosine(cfg.warmup_steps,
                                                cfg.n_steps),
                         log_every=log_every, ckpt_dir=ckpt_dir)
    return params, enc_cfg, doc_assign, losses


@dataclasses.dataclass(frozen=True)
class SupSelectors:
    """The trained selector bundle as a corpus-independent build recipe.

    Wraps the distilled parameters so any corpus (the original one, a
    compaction's survivor set, streamed documents) can be indexed under
    the SAME frozen selectors: cluster side = argmax over the learned
    embeddings, term side = encoder+MLP saliency (Eq. 7).  This is the
    object :class:`repro.core.segments.MutableHybridIndex` stores and
    replays at ``compact()`` (DESIGN.md §15) — the supervised analogue
    of the unsup path's "recompute KMeans + BM25 from the survivors".
    """
    params: distill.DistillParams
    enc_cfg: Any                      # tfm.TransformerConfig
    encode_batch: int = 512

    def position_scores(self, doc_tokens) -> jnp.ndarray:
        """Per-position saliency of every document, (n, Ld) f32 —
        chunked so corpora of any size run at fixed memory."""
        tokens = jnp.asarray(doc_tokens)

        @jax.jit
        def score_chunk(chunk):
            hidden, _ = tfm.encode(self.params.encoder, self.enc_cfg,
                                   chunk)
            return ts_mod.mlp_token_scores(self.params.term_mlp, hidden,
                                           chunk)

        chunks = [score_chunk(tokens[i:i + self.encode_batch])
                  for i in range(0, tokens.shape[0], self.encode_batch)]
        return jnp.concatenate(chunks, axis=0)

    def build_inputs(self, doc_emb, doc_tokens, vocab_size: int) -> dict:
        """The selector overrides for :func:`repro.core.hybrid_index.
        build` on an arbitrary corpus.  φ here is the argmax under the
        learned embeddings — corpus-independent (required by
        compaction), and identical to the frozen training-time φ for
        every document whose commitment loss converged (Eq. 13)."""
        from repro.core import bm25

        cluster_sel = cs_mod.ClusterSelector(
            embeddings=self.params.cluster_embeddings)
        pos_scores = self.position_scores(doc_tokens)
        sbar = bm25.average_term_scores(jnp.asarray(doc_tokens),
                                        pos_scores, vocab_size)
        return dict(
            cluster_sel=cluster_sel,
            doc_assign=cs_mod.select_for_doc(cluster_sel,
                                             jnp.asarray(doc_emb)),
            term_pos_scores=pos_scores,
            term_sel=ts_mod.TermSelector(avg_scores=sbar))


def build_sup_index(corpus: synthetic.Corpus, params: distill.DistillParams,
                    enc_cfg, doc_assign, *, k1_terms: int, codec: str = "opq",
                    pq_m: int = 8, pq_k: int = 256,
                    cluster_capacity=None, term_capacity=None,
                    prune_gamma: Optional[float] = None,
                    encode_batch: int = 512, sparse: bool = False,
                    doc_namespaces=None) -> hi.HybridIndex:
    """Assemble HI²_sup: learned cluster embeddings + learned term scores
    drive the same list construction as the unsupervised path.

    Uses the *frozen training-time* φ(D) (``doc_assign``) — the paper's
    operating point.  ``sparse``/``doc_namespaces`` pass through to
    :func:`repro.core.hybrid_index.build`, so a supervised index serves
    every §9/§13 feature the unsupervised one does.
    """
    sel = SupSelectors(params=params, enc_cfg=enc_cfg,
                       encode_batch=encode_batch)
    doc_tokens = jnp.asarray(corpus.doc_tokens)
    pos_scores = sel.position_scores(doc_tokens)

    from repro.core import bm25
    sbar = bm25.average_term_scores(doc_tokens, pos_scores,
                                    corpus.vocab_size)
    term_sel = ts_mod.TermSelector(avg_scores=sbar)
    index = hi.build(
        jax.random.key(1), jnp.asarray(corpus.doc_emb), doc_tokens,
        corpus.vocab_size, n_clusters=params.cluster_embeddings.shape[0],
        k1_terms=k1_terms, codec=codec, pq_m=pq_m, pq_k=pq_k,
        cluster_capacity=cluster_capacity, term_capacity=term_capacity,
        cluster_sel=cs_mod.ClusterSelector(
            embeddings=params.cluster_embeddings),
        doc_assign=doc_assign, term_pos_scores=pos_scores,
        term_sel=term_sel, sparse=sparse, doc_namespaces=doc_namespaces)
    if prune_gamma is not None:
        from repro.core import pruning
        index = dataclasses.replace(
            index, term_lists=pruning.prune_percentile(index.term_lists,
                                                       prune_gamma))
    return index
