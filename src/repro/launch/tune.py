"""Offline width autotuner (DESIGN.md §14).

    PYTHONPATH=src python -m repro.launch.tune --docs 8000 \\
        --recall-target 0.95 --out /tmp/tuned_ckpt

HI²'s latency is monotone in the candidate budget ``kc·c_cap + k2·t_cap``
(§2), yet the widths have historically been hand-picked constants
(``serve.DEFAULT_KC/DEFAULT_K2``).  This module makes them a *tuned
index property*:

  1. sweep a (kc, k2[, refine-mult]) grid on a held-out query sample,
     scoring recall@R against the exact brute-force oracle and cost by
     the static :func:`repro.core.hybrid_index.candidate_cost` proxy
     (the shared machinery lives in :mod:`repro.core.exec.frontier`,
     which ``benchmarks/fig3_tradeoff.py`` also sweeps with — the
     figure and the tuner can never disagree on the grid);
  2. select the CHEAPEST config meeting the recall target
     (:func:`frontier.select`);
  3. calibrate an optional adaptive rung ladder: if routing the
     easiest fraction of queries (largest top-1 vs top-2 cluster-score
     margin) to a cheaper frontier config keeps the held-out recall
     while lowering the mean per-query cost, record the
     (narrow, tuned) ladder and its margin cut;
  4. persist the outcome as a :class:`frontier.TunedWidths` record on
     ``HybridIndex.tuned`` (:func:`apply_tuned`) — carried through
     ``checkpoint.save_index/restore_index`` and honored as the
     serving default by :mod:`repro.launch.serve`.

The refine multiplier tunes for free: ``refine[:base[:mult]]`` only
changes search-time refine width, never the encoded planes, so a spec
rewrite (``dataclasses.replace(index, codec=...)``) re-uses the built
index.
"""
from __future__ import annotations

import argparse
import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hybrid_index as hi
from repro.core.codecs import refine as refine_codec
from repro.core.exec import frontier

#: the easy-query fractions tried per candidate narrow rung when
#: calibrating the adaptive ladder (step 3 above)
EASY_FRACTIONS = (0.9, 0.75, 0.5, 0.25, 0.1)


def exact_oracle(doc_emb, query_emb, top_r: int) -> np.ndarray:
    """Brute-force top-R doc ids per query — the tuner's ground truth
    (an unordered id set; recall@R does not depend on rank order)."""
    s = np.asarray(query_emb, np.float32) @ np.asarray(doc_emb,
                                                       np.float32).T
    k = min(top_r, s.shape[1])
    return np.argpartition(-s, k - 1, axis=1)[:, :k].astype(np.int64)


def per_query_recall(retrieved, oracle_ids, k: int) -> np.ndarray:
    """(B,) recall@k against the oracle id sets (-1 pads ignored) —
    the per-query resolution :func:`repro.core.metrics.recall_at_k`
    averages away, needed here to compose rung ladders per query."""
    r = np.asarray(retrieved)[:, :k]
    o = np.asarray(oracle_ids)
    hit = (r[:, :, None] == o[:, None, :]) & (o[:, None, :] >= 0)
    return (hit.any(axis=1).sum(axis=-1)
            / np.maximum((o >= 0).sum(axis=-1), 1))


def _with_mult(spec: str, mult: int) -> str:
    """The refine spec with its multiplier replaced (base preserved)."""
    parts = spec.split(":")
    base = parts[1] if len(parts) > 1 and parts[1] else \
        refine_codec.DEFAULT_BASE
    return f"refine:{base}:{int(mult)}"


def _spec_for(codec: str, refine_mult: Optional[int]) -> str:
    return codec if refine_mult is None else _with_mult(codec, refine_mult)


def tune_index(index: hi.HybridIndex, query_emb, query_tokens,
               oracle_ids, *, recall_target: float = 0.95,
               top_r: int = 100, grid: Sequence = frontier.WIDTH_GRID,
               refine_mults: Sequence = (),
               use_kernel: bool = False) -> tuple:
    """Run the full tune on one built index + held-out query sample.

    Returns ``(tuned, points)``: the :class:`frontier.TunedWidths`
    outcome and every evaluated :class:`frontier.SweepPoint` (the raw
    material of the fig3-style frontier plot).  ``refine_mults`` only
    applies to a ``refine`` codec — each multiplier sweeps the grid on
    a spec-rewritten view of the same index.
    """
    qe, qt = jnp.asarray(query_emb), jnp.asarray(query_tokens)
    is_refine = index.codec.split(":")[0] == "refine"
    mults = tuple(refine_mults) if (refine_mults and is_refine) else \
        (None,)
    per_q: dict = {}     # (spec, kc, k2) -> per-query recall array
    points: list = []
    for mult in mults:
        spec = _spec_for(index.codec, mult)
        idx = (index if spec == index.codec
               else dataclasses.replace(index, codec=spec))

        def run(kc, k2, idx=idx, spec=spec):
            res = hi.search(idx, qe, qt, kc=kc, k2=k2, top_r=top_r,
                            use_kernel=use_kernel)
            pq = per_query_recall(res.doc_ids, oracle_ids, top_r)
            per_q[(spec, kc, k2)] = pq
            return pq.mean(), hi.candidate_cost(idx, kc, k2, top_r)

        points += frontier.sweep(run, grid, refine_mult=mult)
    best = frontier.select(points, recall_target)
    best_spec = _spec_for(index.codec, best.refine_mult)
    rungs, cuts = _calibrate_rungs(
        index, [p for p in points if p.refine_mult == best.refine_mult],
        best, per_q, best_spec, query_emb, top_r)
    tuned = frontier.TunedWidths(
        kc=int(best.kc), k2=int(best.k2), refine_mult=best.refine_mult,
        recall_target=float(recall_target), recall=float(best.recall),
        cost=int(best.cost), rungs=rungs, margin_cuts=cuts)
    return tuned, points


def _calibrate_rungs(index, points, best, per_q, spec, query_emb,
                     top_r) -> tuple:
    """Try a 2-rung (narrow, tuned) ladder per cheaper frontier config
    × easy fraction; keep the cheapest that holds the tuned recall on
    the held-out sample, else the degenerate single-rung ladder.  The
    ladder varies only (kc, k2) — the refine multiplier is a codec
    property, fixed at the selected value across rungs."""
    degenerate = (((best.kc, best.k2),), ())
    margins = frontier.margins(index.cluster_sel.embeddings, query_emb)
    best_pq = per_q[(spec, best.kc, best.k2)]
    cheaper = [p for p in frontier.pareto_frontier(points)
               if p.cost < best.cost and (p.kc, p.k2) != (best.kc,
                                                          best.k2)]
    choice = None        # (mean_cost, rungs, cuts)
    for p in cheaper:
        narrow_pq = per_q[(spec, p.kc, p.k2)]
        for frac in EASY_FRACTIONS:
            cut = float(np.quantile(margins, 1.0 - frac))
            easy = margins >= cut
            if not easy.any() or easy.all():
                continue
            composed = np.where(easy, narrow_pq, best_pq)
            f = float(easy.mean())
            mean_cost = f * p.cost + (1.0 - f) * best.cost
            if (composed.mean() >= best.recall - 1e-9
                    and mean_cost < best.cost
                    and (choice is None or mean_cost < choice[0])):
                choice = (mean_cost,
                          ((int(p.kc), int(p.k2)),
                           (int(best.kc), int(best.k2))),
                          (round(cut, 6),))
    return (choice[1], choice[2]) if choice is not None else degenerate


def apply_tuned(index: hi.HybridIndex,
                tuned: frontier.TunedWidths) -> hi.HybridIndex:
    """The index with the tune applied: codec spec rewritten to the
    selected refine multiplier (when one was tuned) and the record
    attached as static metadata (:func:`hi.with_tuned`)."""
    idx = index
    if tuned.refine_mult is not None:
        spec = _with_mult(index.codec, tuned.refine_mult)
        if spec != index.codec:
            idx = dataclasses.replace(idx, codec=spec)
    return hi.with_tuned(idx, tuned)


def main(argv: Optional[list] = None) -> None:
    ap = argparse.ArgumentParser(
        description="HI² offline width autotuner (DESIGN.md §14)")
    ap.add_argument("--docs", type=int, default=8000)
    ap.add_argument("--queries", type=int, default=256,
                    help="held-out tuning queries")
    ap.add_argument("--codec", default="refine:pq:4")
    ap.add_argument("--top-r", type=int, default=100)
    ap.add_argument("--recall-target", type=float, default=0.95)
    ap.add_argument("--refine-mults", type=int, nargs="*",
                    default=(2, 4, 8),
                    help="refine multipliers to sweep (refine codec "
                         "only)")
    ap.add_argument("--out", default=None, metavar="DIR",
                    help="save the tuned index as a checkpoint "
                         "(repro.checkpoint.save_index)")
    args = ap.parse_args(argv)

    from repro.data import synthetic
    corpus = synthetic.generate(seed=0, n_docs=args.docs,
                                n_queries=args.queries, hidden=64,
                                vocab_size=4096)
    index = hi.build(jax.random.key(0), jnp.asarray(corpus.doc_emb),
                     jnp.asarray(corpus.doc_tokens), corpus.vocab_size,
                     n_clusters=128, k1_terms=10, codec=args.codec,
                     pq_m=8, pq_k=256, cluster_capacity=192,
                     term_capacity=96, kmeans_iters=8)
    oracle = exact_oracle(corpus.doc_emb, corpus.query_emb, args.top_r)
    tuned, points = tune_index(
        index, corpus.query_emb, corpus.query_tokens, oracle,
        recall_target=args.recall_target, top_r=args.top_r,
        refine_mults=args.refine_mults)
    for p in frontier.pareto_frontier(points):
        mark = " <- selected" if (p.kc, p.k2, p.refine_mult) == (
            tuned.kc, tuned.k2, tuned.refine_mult) else ""
        print(f"frontier: kc={p.kc:3d} k2={p.k2:3d} "
              f"mult={p.refine_mult} cost={p.cost:7.0f} "
              f"recall@{args.top_r}={p.recall:.4f}{mark}")
    print(f"tuned: kc={tuned.kc} k2={tuned.k2} "
          f"refine_mult={tuned.refine_mult} cost={tuned.cost} "
          f"recall={tuned.recall:.4f} (target {tuned.recall_target}) "
          f"rungs={tuned.rungs} cuts={tuned.margin_cuts}")
    if args.out:
        from repro.checkpoint import checkpoint as ckpt
        path = ckpt.save_index(args.out, 0, apply_tuned(index, tuned))
        print(f"saved tuned index checkpoint: {path}")


if __name__ == "__main__":
    main()
