"""GQA attention: full-sequence forward (train / prefill) and KV-cache
decode, with sliding-window (SWA) support via a rolling cache.

Sharding (logical names → repro.distributed.sharding rules):
    projections   q: ("batch", None, "heads", None) — TP over query heads
    kv            replicated over TP when n_kv_heads < model-axis size,
                  sharded otherwise (rule set per arch at launch)
    decode cache  ("batch", None, "seq_kv", None) — flash-decode style
                  sequence-sharded cache; XLA completes the sharded
                  softmax with the lse-combining collectives.

SWA rolling cache: for window W the cache holds only the last W
positions (slot = pos mod W), so ``long_500k`` decode is O(W) memory and
compute — the sub-quadratic path the brief requires for 500k contexts.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models import layers

Array = jax.Array


def init(key: Array, d_model: int, n_heads: int, n_kv_heads: int,
         d_head: int, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 4)
    return {
        "wq": layers.dense_init(ks[0], d_model, n_heads * d_head, dtype),
        "wk": layers.dense_init(ks[1], d_model, n_kv_heads * d_head, dtype),
        "wv": layers.dense_init(ks[2], d_model, n_kv_heads * d_head, dtype),
        "wo": layers.dense_init(ks[3], n_heads * d_head, d_model, dtype),
    }


def _project_qkv(params: dict, x: Array, n_heads: int, n_kv_heads: int,
                 d_head: int, positions: Array, rope_theta: float):
    """rope_theta <= 0 disables RoPE (archs with learned positions)."""
    b, s, _ = x.shape
    q = layers.dense(params["wq"], x).reshape(b, s, n_heads, d_head)
    k = layers.dense(params["wk"], x).reshape(b, s, n_kv_heads, d_head)
    v = layers.dense(params["wv"], x).reshape(b, s, n_kv_heads, d_head)
    q = shard(q, "batch", None, "heads", None)
    k = shard(k, "batch", None, "kv_heads", None)
    v = shard(v, "batch", None, "kv_heads", None)
    q, k = q.swapaxes(1, 2), k.swapaxes(1, 2)
    if rope_theta > 0:
        q = layers.apply_rope(q, positions[:, None], rope_theta)
        k = layers.apply_rope(k, positions[:, None], rope_theta)
    return q, k, v.swapaxes(1, 2)   # (B, H, S, dh) each


def forward(params: dict, x: Array, *, n_heads: int, n_kv_heads: int,
            d_head: int, causal: bool = True, window: int = 0,
            rope_theta: float = 10000.0, use_flash: bool = False,
            positions: Optional[Array] = None, return_kv: bool = False):
    """Full-sequence attention. x: (B, S, D) -> (B, S, D)[, (k, v)]."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    q, k, v = _project_qkv(params, x, n_heads, n_kv_heads, d_head,
                           positions, rope_theta)
    if use_flash:
        from repro.kernels.flash_attention import ops as fa_ops
        out = fa_ops.flash_attention(q, k, v, causal, window, None)
    else:
        # XLA path with flash-like O(S·chunk) memory (the Pallas kernel's
        # behavior on TPU) — a dense (S, S) plane would dominate HBM at 4k+
        from repro.kernels.flash_attention import ref as fa_ref
        out = fa_ref.attention_chunked(q, k, v, causal=causal, window=window)
    out = shard(out, "batch", "heads", None, None)
    out = out.swapaxes(1, 2).reshape(b, s, n_heads * d_head)
    out = layers.dense(params["wo"], out)
    if return_kv:
        return out, (k, v)
    return out


# --------------------------------------------------------------------------
# decode
# --------------------------------------------------------------------------

class KVCache(NamedTuple):
    k: Array          # (B, n_kv_heads, C, d_head)
    v: Array          # (B, n_kv_heads, C, d_head)
    cache_pos: Array  # (C,) i32 — absolute position stored in each slot, -1 empty


def init_cache(batch: int, n_kv_heads: int, capacity: int, d_head: int,
               dtype=jnp.bfloat16) -> KVCache:
    return KVCache(
        k=jnp.zeros((batch, n_kv_heads, capacity, d_head), dtype),
        v=jnp.zeros((batch, n_kv_heads, capacity, d_head), dtype),
        cache_pos=jnp.full((capacity,), -1, jnp.int32),
    )


def cache_capacity(seq_len: int, window: int) -> int:
    """SWA models only ever need the last ``window`` positions."""
    return min(seq_len, window) if window > 0 else seq_len


def decode_step(params: dict, cache: KVCache, x_new: Array, pos: Array, *,
                n_heads: int, n_kv_heads: int, d_head: int, window: int = 0,
                rope_theta: float = 10000.0) -> tuple[Array, KVCache]:
    """One decode step. x_new: (B, 1, D); pos: () absolute position."""
    b, _, _ = x_new.shape
    group = n_heads // n_kv_heads
    capacity = cache.k.shape[2]
    positions = jnp.broadcast_to(pos[None], (b, 1))
    q, k_new, v_new = _project_qkv(params, x_new, n_heads, n_kv_heads,
                                   d_head, positions, rope_theta)

    slot = (pos % capacity).astype(jnp.int32)       # rolling for SWA
    # NOTE the cache seq axis is deliberately NOT sharded: a dynamic
    # update-slice along a sharded dim triggers GSPMD "involuntary full
    # rematerialization" (the whole cache replicates per step). Model-axis
    # capacity comes from kv_heads when divisible, else head_dim.
    k = jax.lax.dynamic_update_slice(cache.k, k_new.astype(cache.k.dtype),
                                     (0, 0, slot, 0))
    v = jax.lax.dynamic_update_slice(cache.v, v_new.astype(cache.v.dtype),
                                     (0, 0, slot, 0))
    cache_pos = jax.lax.dynamic_update_slice(cache.cache_pos,
                                             pos[None].astype(jnp.int32),
                                             (slot,))
    k = shard(k, "batch", "kv_heads", None, "head_dim")
    v = shard(v, "batch", "kv_heads", None, "head_dim")

    # grouped-query scoring without materializing repeated KV. The cache
    # stays in its storage dtype inside the dots (preferred_element_type
    # accumulates in f32) — an explicit .astype(f32) would materialize a
    # 2× copy of the whole per-device cache every step.
    qg = q.reshape(b, n_kv_heads, group, d_head).astype(k.dtype)
    s = jnp.einsum("bhgd,bhcd->bhgc", qg, k,
                   preferred_element_type=jnp.float32) * (d_head ** -0.5)
    valid = cache_pos >= 0
    valid &= cache_pos <= pos
    if window > 0:
        valid &= cache_pos > pos - window
    s = jnp.where(valid[None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgc,bhcd->bhgd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    out = out.reshape(b, 1, n_heads * d_head).astype(x_new.dtype)
    return layers.dense(params["wo"], out), KVCache(k=k, v=v,
                                                    cache_pos=cache_pos)
