"""GatedGCN (Bresson & Laurent 2017; benchmarked in arXiv:2003.00982).

JAX has no CSR SpMM — message passing is built on the edge-index →
``jax.ops.segment_sum`` scatter pattern (the brief's required substrate):

    e'_ij  = e_ij + ReLU(Norm(E1·h_i + E2·h_j + E3·e_ij))
    σ_ij   = sigmoid(e'_ij)
    agg_i  = Σ_j σ_ij ⊙ (B2·h_j)  /  (Σ_j σ_ij + ε)       (gated mean)
    h'_i   = h_i + ReLU(Norm(B1·h_i + agg_i))

Adaptation note (DESIGN.md): BatchNorm → LayerNorm (BN statistics don't
compose across edge-sharded devices; LN is the standard substitution in
distributed GNN training).

Scale-out: edge planes (src, dst, e) are sharded over the mesh
("edges" logical axis); node features stay replicated; each shard's
partial ``segment_sum`` is completed by XLA's scatter-add all-reduce.
Graphs are padded to fixed shapes (PAD edges point at a sink node).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models import layers

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class GatedGCNConfig:
    n_layers: int = 16
    d_hidden: int = 70
    d_feat: int = 1433
    d_edge_feat: int = 0       # 0 → learned constant edge init
    n_classes: int = 16
    graph_level: bool = False  # molecule cells: per-graph readout
    remat: bool = True
    impl: str = "gspmd"        # "gspmd" | "partitioned" (§Perf)
    bf16_gather: bool = False  # partitioned: gather node states in bf16


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["node_feat", "edge_src", "edge_dst", "edge_mask",
                 "node_mask", "labels", "graph_id"],
    meta_fields=["n_graphs"])
@dataclasses.dataclass(frozen=True)
class GraphBatch:
    """Fixed-shape padded (batch of) graph(s).

    Batched small graphs are flattened into one disjoint union; ``graph_id``
    maps nodes to their graph (for graph-level readout). PAD edges use
    src=dst=n_nodes-1 with edge_mask=0; PAD nodes have node_mask=0.
    ``n_graphs`` is static metadata (it feeds segment counts).
    """
    node_feat: Array            # (N, d_feat) f32
    edge_src: Array             # (E,) i32
    edge_dst: Array             # (E,) i32
    edge_mask: Array            # (E,) f32
    node_mask: Array            # (N,) f32
    labels: Array               # (N,) or (G,) i32
    graph_id: Array             # (N,) i32 (zeros for single-graph)
    n_graphs: int = 1


def init(key: Array, cfg: GatedGCNConfig) -> dict:
    ks = jax.random.split(key, 4 + cfg.n_layers)
    d = cfg.d_hidden

    def layer_init(k):
        kk = jax.random.split(k, 6)
        return {
            "E1": layers.dense_init(kk[0], d, d),
            "E2": layers.dense_init(kk[1], d, d),
            "E3": layers.dense_init(kk[2], d, d),
            "B1": layers.dense_init(kk[3], d, d),
            "B2": layers.dense_init(kk[4], d, d),
            "norm_h": layers.layernorm_init(d),
            "norm_e": layers.layernorm_init(d),
        }

    stacked = jax.vmap(layer_init)(jax.random.split(ks[0], cfg.n_layers))
    return {
        "embed_h": layers.dense_init(ks[1], cfg.d_feat, d),
        "embed_e": (layers.dense_init(ks[2], cfg.d_edge_feat, d)
                    if cfg.d_edge_feat > 0
                    else {"const": jnp.zeros((d,), jnp.float32)}),
        "layers": stacked,
        "head": layers.dense_init(ks[3], d, cfg.n_classes),
    }


def _layer(lp: dict, h: Array, e: Array, src: Array, dst: Array,
           edge_mask: Array, n_nodes: int) -> tuple[Array, Array]:
    h_src = jnp.take(h, src, axis=0)
    h_dst = jnp.take(h, dst, axis=0)
    h_src = shard(h_src, "edges", None)
    h_dst = shard(h_dst, "edges", None)

    e_new = (layers.dense(lp["E1"], h_dst) + layers.dense(lp["E2"], h_src)
             + layers.dense(lp["E3"], e))
    e = e + jax.nn.relu(layers.layernorm(lp["norm_e"], e_new))
    gate = jax.nn.sigmoid(e) * edge_mask[:, None]

    msg = gate * layers.dense(lp["B2"], h_src)
    msg = shard(msg, "edges", None)
    num = jax.ops.segment_sum(msg, dst, num_segments=n_nodes)
    den = jax.ops.segment_sum(gate, dst, num_segments=n_nodes)
    num = shard(num, "nodes", None)
    den = shard(den, "nodes", None)
    agg = num / (den + 1e-6)

    h_new = layers.dense(lp["B1"], h) + agg
    h = h + jax.nn.relu(layers.layernorm(lp["norm_h"], h_new))
    # node planes sharded between layers: at ogb_products scale a
    # replicated (N, d) carry × n_layers of saved activations would be
    # tens of GB per device
    return shard(h, "nodes", None), e


def forward(params: dict, cfg: GatedGCNConfig, batch: GraphBatch) -> Array:
    """Returns logits: (N, n_classes) node-level or (G, n_classes) graph-level."""
    n_nodes = batch.node_feat.shape[0]
    h = shard(layers.dense(params["embed_h"], batch.node_feat),
              "nodes", None)
    if cfg.d_edge_feat > 0:
        raise NotImplementedError("edge-featured inputs not used by the assigned cells")
    e = jnp.broadcast_to(params["embed_e"]["const"],
                         (batch.edge_src.shape[0], cfg.d_hidden))
    e = shard(e, "edges", None)

    def scan_body(carry, lp):
        h_c, e_c = carry
        def fn(hh, ee, p):
            return _layer(p, hh, ee, batch.edge_src, batch.edge_dst,
                          batch.edge_mask, n_nodes)
        if cfg.remat:
            fn = jax.checkpoint(fn)
        h_n, e_n = fn(h_c, e_c, lp)
        return (h_n, e_n), None

    (h, e), _ = jax.lax.scan(scan_body, (h, e), params["layers"])

    if cfg.graph_level:
        pooled = jax.ops.segment_sum(h * batch.node_mask[:, None],
                                     batch.graph_id,
                                     num_segments=batch.n_graphs)
        counts = jax.ops.segment_sum(batch.node_mask, batch.graph_id,
                                     num_segments=batch.n_graphs)
        pooled = pooled / jnp.maximum(counts, 1.0)[:, None]
        return layers.dense(params["head"], pooled)
    return layers.dense(params["head"], h)


def loss_fn(params: dict, cfg: GatedGCNConfig, batch: GraphBatch
            ) -> tuple[Array, dict]:
    logits = forward(params, cfg, batch)
    if cfg.graph_level:
        loss = layers.softmax_xent(logits, batch.labels)
    else:
        mask = batch.node_mask
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        ll = jnp.take_along_axis(logp, jnp.clip(batch.labels, 0, None)[:, None],
                                 axis=-1)[:, 0]
        loss = -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return loss, {"loss": loss}


# ---------------------------------------------------------------------------
# partitioned implementation (hillclimb: EXPERIMENTS.md §Perf, ogb cell)
#
# The GSPMD baseline psums full (N, d) node planes per layer (num + den,
# f32, fwd + bwd) because edge-sharded segment_sum cannot prove locality.
# Owner-computes partitioning makes aggregation LOCAL: each shard owns a
# contiguous node range and every edge whose dst lies in its range (the
# data pipeline's range partitioner, graph.partition_by_dst). Per layer
# the only collective is ONE all-gather of the node states (src gathers
# may touch any node); its transpose is one reduce-scatter.
# ---------------------------------------------------------------------------

def forward_partitioned(params: dict, cfg: GatedGCNConfig,
                        batch: GraphBatch) -> Array:
    """shard_map GatedGCN. Contract: edges are dst-range partitioned
    (edge i on shard s ⇒ dst[i] ∈ [s·n_local, (s+1)·n_local)); node
    planes are sharded by the same ranges. Falls back to :func:`forward`
    off-mesh."""
    from jax.sharding import PartitionSpec as P
    from repro.distributed import sharding as shd
    from repro.distributed.compat import shard_map as _shard_map

    mesh = shd._mesh()
    if mesh is None:
        return forward(params, cfg, batch)
    axes = tuple(a for a in ("data", "model") if a in mesh.axis_names)
    n_shards = 1
    for a in axes:
        n_shards *= mesh.shape[a]
    n_nodes = batch.node_feat.shape[0]
    n_local = n_nodes // n_shards

    def body(p, feat_l, src, dst, emask):
        offset = jax.lax.axis_index(axes) * n_local
        h = layers.dense(p["embed_h"], feat_l)               # (n_local, d)
        e = jnp.broadcast_to(p["embed_e"]["const"],
                             (src.shape[0], cfg.d_hidden))

        def scan_body(carry, lp):
            h_c, e_c = carry

            def one_layer(h_i, e_i, lpp):
                hg = (h_i.astype(jnp.bfloat16) if cfg.bf16_gather else h_i)
                h_full = jax.lax.all_gather(hg, axes, axis=0, tiled=True)
                h_full = h_full.astype(h_i.dtype)
                h_src = jnp.take(h_full, src, axis=0)
                h_dst = jnp.take(h_full, dst, axis=0)
                e_new = (layers.dense(lpp["E1"], h_dst)
                         + layers.dense(lpp["E2"], h_src)
                         + layers.dense(lpp["E3"], e_i))
                e_i = e_i + jax.nn.relu(layers.layernorm(lpp["norm_e"],
                                                         e_new))
                gate = jax.nn.sigmoid(e_i) * emask[:, None]
                msg = gate * layers.dense(lpp["B2"], h_src)
                dst_local = dst - offset                    # owned range
                num = jax.ops.segment_sum(msg, dst_local,
                                          num_segments=n_local)
                den = jax.ops.segment_sum(gate, dst_local,
                                          num_segments=n_local)
                agg = num / (den + 1e-6)
                h_new = layers.dense(lpp["B1"], h_i) + agg
                h_i = h_i + jax.nn.relu(layers.layernorm(lpp["norm_h"],
                                                         h_new))
                return h_i, e_i

            fn = one_layer
            if cfg.remat:
                fn = jax.checkpoint(fn)
            h_n, e_n = fn(h_c, e_c, lp)
            return (h_n, e_n), None

        (h, e), _ = jax.lax.scan(scan_body, (h, e), p["layers"])
        return layers.dense(p["head"], h)                    # (n_local, C)

    ax = axes if len(axes) > 1 else axes[0]
    logits = _shard_map(
        body, mesh=mesh,
        in_specs=(P(), P(ax, None), P(ax), P(ax), P(ax)),
        out_specs=P(ax, None),
        check=False,
    )(params, batch.node_feat, batch.edge_src, batch.edge_dst,
      batch.edge_mask)
    return logits


def loss_fn_partitioned(params: dict, cfg: GatedGCNConfig,
                        batch: GraphBatch) -> tuple[Array, dict]:
    logits = forward_partitioned(params, cfg, batch)
    mask = batch.node_mask
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, jnp.clip(batch.labels, 0, None)[:, None],
                             axis=-1)[:, 0]
    loss = -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return loss, {"loss": loss}
