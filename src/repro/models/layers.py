"""Common neural layers as pure functions over param dicts.

No framework (flax/haiku unavailable offline): parameters are nested
dicts of arrays; ``init_*`` builds them, ``apply``-style functions
consume them. All matmuls run in the param dtype with f32 accumulation
via ``preferred_element_type``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def dense_init(key: Array, d_in: int, d_out: int, dtype=jnp.float32,
               scale: float | None = None) -> dict:
    if scale is None:
        scale = d_in ** -0.5
    return {"w": (jax.random.normal(key, (d_in, d_out), jnp.float32)
                  * scale).astype(dtype)}


def dense(params: dict, x: Array) -> Array:
    # master weights may be f32 while activations are bf16: cast the
    # WEIGHT down (small) — mixed-dtype matmuls would promote the
    # activation tensor to f32 and double its HBM footprint
    w = params["w"]
    if jnp.issubdtype(x.dtype, jnp.floating) and w.dtype != x.dtype:
        w = w.astype(x.dtype)
    return jnp.matmul(x, w, preferred_element_type=jnp.float32
                      ).astype(x.dtype)


def embedding_init(key: Array, n_rows: int, dim: int, dtype=jnp.float32,
                   scale: float = 0.02) -> dict:
    return {"table": (jax.random.normal(key, (n_rows, dim), jnp.float32)
                      * scale).astype(dtype)}


def embedding_lookup(params: dict, ids: Array) -> Array:
    return jnp.take(params["table"], jnp.clip(ids, 0, None), axis=0)


def rmsnorm_init(dim: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm(params: dict, x: Array, eps: float = 1e-6) -> Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(dim: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def layernorm(params: dict, x: Array, eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)
            + params["bias"].astype(jnp.float32)).astype(x.dtype)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------

def rope_freqs(d_head: int, theta: float = 10000.0) -> Array:
    exponent = jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head
    return 1.0 / (theta ** exponent)                    # (d_head/2,)


def apply_rope(x: Array, positions: Array, theta: float = 10000.0) -> Array:
    """x: (..., S, d_head); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                        # (d/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, d/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# losses
# --------------------------------------------------------------------------

def softmax_xent(logits: Array, labels: Array, ignore_id: int = -1) -> Array:
    """Mean next-token cross entropy; positions with ``ignore_id`` skipped."""
    mask = labels != ignore_id
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, jnp.clip(labels, 0, None)[..., None],
                             axis=-1)[..., 0]
    return -(ll * mask).sum() / jnp.maximum(mask.sum(), 1)
