"""Mixture-of-Experts FFN with top-k routing and capacity-bounded,
sort-free dispatch (GShard/Switch lineage, MegaBlocks-style gathers).

TPU adaptation (DESIGN.md §5): the classic GShard one-hot dispatch einsum
(N·E·C·d FLOPs) is replaced by scatter/gather through per-expert
capacity buffers — FLOPs stay proportional to *active* parameters:

    router logits (N, E) → top-k ids/weights (N, k)
    position-in-expert  = masked running count (cumsum over assignments)
    expert buffer (E, C, d)  ← scatter of kept assignments
    expert FFN (E, C, d) × (E, d, f) batched matmuls (SwiGLU)
    token out ← gather back × routing weight, summed over the k slots

Experts are **TP-sharded** on the mesh model axis (each expert's ffn dim
split) — valid for any expert count (Mixtral's 8 < 16-wide model axis
included). Aux load-balancing loss follows Switch (§ loss = E·Σ f_e·P_e).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models import layers

Array = jax.Array


def init(key: Array, d_model: int, d_ff: int, n_experts: int,
         dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 4)
    s_in = d_model ** -0.5
    s_out = d_ff ** -0.5

    def ew(k, shape, scale):
        return (jax.random.normal(k, shape, jnp.float32) * scale).astype(dtype)

    return {
        "router": layers.dense_init(ks[0], d_model, n_experts, jnp.float32),
        "w_gate": ew(ks[1], (n_experts, d_model, d_ff), s_in),
        "w_up": ew(ks[2], (n_experts, d_model, d_ff), s_in),
        "w_down": ew(ks[3], (n_experts, d_ff, d_model), s_out),
    }


class MoEStats(NamedTuple):
    aux_loss: Array       # Switch load-balance loss
    dropped_frac: Array   # fraction of assignments dropped at capacity


def forward(params: dict, x: Array, *, n_experts: int, top_k: int,
            capacity_factor: float = 1.25) -> tuple[Array, MoEStats]:
    """x: (B, S, D) -> (B, S, D)."""
    b, s, d = x.shape
    n = b * s
    xt = x.reshape(n, d)

    logits = layers.dense(params["router"], xt).astype(jnp.float32)  # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, top_k)                       # (N, k)
    top_w = top_w / jnp.maximum(top_w.sum(axis=-1, keepdims=True), 1e-9)

    capacity = max(int(n * top_k * capacity_factor / n_experts), 1)

    # position of each assignment within its expert (running count over
    # the flattened (token, slot) order)
    flat_e = top_e.reshape(-1)                                       # (N·k,)
    onehot = jax.nn.one_hot(flat_e, n_experts, dtype=jnp.int32)      # (N·k, E)
    pos_in_e = (jnp.cumsum(onehot, axis=0) - 1)                      # inclusive
    pos = jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]
    flat_keep = pos < capacity
    keep = flat_keep.reshape(n, top_k)

    # dispatch: ONE 2-D scatter-add into the (E, C, d) buffer.
    # (Two alternatives were tried and refuted, see EXPERIMENTS.md §Perf:
    # a per-slot scatter chain keeps top_k cotangent copies of the buffer
    # live in backward (8×5.4 GB for OLMoE); a flat (E·C, d) segment_sum
    # loses the sharding relation and GSPMD replicates everything.)
    flat_tok = jnp.repeat(jnp.arange(n), top_k)                      # (N·k,)
    safe_pos = jnp.where(flat_keep, pos, 0)
    updates = jnp.take(xt, flat_tok, axis=0) * flat_keep[:, None
                                                         ].astype(xt.dtype)
    updates = shard(updates, "moe_flat", None)
    buf = jnp.zeros((n_experts, capacity, d), xt.dtype)
    buf = buf.at[flat_e, safe_pos].add(updates, mode="drop")
    # capacity axis sharded over data (E·C·d would replicate to tens of
    # GB otherwise)
    buf = shard(buf, "experts", "moe_capacity", None)

    # expert SwiGLU, TP-sharded on the ffn dim; weights cast to the
    # activation dtype (mixed-dtype einsums would upcast the E·C·d
    # dispatch buffers to f32 — gigabytes per device)
    w_gate = shard(params["w_gate"], "experts", None, "expert_ff"
                   ).astype(buf.dtype)
    w_up = shard(params["w_up"], "experts", None, "expert_ff"
                 ).astype(buf.dtype)
    w_down = shard(params["w_down"], "experts", "expert_ff", None
                   ).astype(buf.dtype)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w_gate,
                               preferred_element_type=jnp.float32)) * \
        jnp.einsum("ecd,edf->ecf", buf, w_up,
                   preferred_element_type=jnp.float32)
    h = h.astype(xt.dtype)
    out_buf = jnp.einsum("ecf,efd->ecd", h, w_down,
                         preferred_element_type=jnp.float32).astype(xt.dtype)

    # combine: 2-D gather of each assignment's expert output + ONE
    # segment_sum back to tokens (single-op both ways — no chains)
    flat_out = out_buf[flat_e, safe_pos]                             # (N·k, d)
    flat_out = shard(flat_out, "moe_flat", None)
    w = (flat_keep * top_w.reshape(-1)).astype(xt.dtype)
    out = jax.ops.segment_sum(flat_out * w[:, None], flat_tok,
                              num_segments=n).astype(xt.dtype)

    # Switch aux loss: E · Σ_e f_e · P_e
    f_e = jnp.mean(
        (jax.nn.one_hot(top_e, n_experts).sum(axis=1) > 0), axis=0)
    p_e = probs.mean(axis=0)
    aux = n_experts * jnp.sum(f_e * p_e)
    stats = MoEStats(aux_loss=aux,
                     dropped_frac=1.0 - keep.mean())
    return out.reshape(b, s, d), stats


# ---------------------------------------------------------------------------
# shard_map implementation (hillclimb: EXPERIMENTS.md §Perf, mixtral cell)
#
# The GSPMD path above leaves two structural costs on the table:
#   1. the position-in-expert cumsum runs over the GLOBAL (N·k, E) plane —
#      GSPMD cannot partition a prefix-sum, so it replicates it;
#   2. dispatch/combine scatters cross data shards, and FSDP weight
#      gathers are emitted in f32.
# Here each data shard dispatches its OWN tokens into its OWN capacity
# buffer (local cumsum — zero dispatch collectives, the standard
# "local capacity" semantics of data-parallel MoE), experts stay
# TP-sharded on the model axis (one psum after the down-projection), and
# the FSDP weight gather happens explicitly in bf16 (half the bytes of
# the f32 auto-gather).
# ---------------------------------------------------------------------------

def _local_moe_body(xt, router_w, w_gate, w_up, w_down, *,
                    n_experts: int, top_k: int, capacity: int,
                    model_axis):
    """Per-shard MoE: xt (n_local, d) with FULLY LOCAL dispatch."""
    n, d = xt.shape
    logits = jnp.matmul(xt, router_w.astype(xt.dtype),
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_w, top_e = jax.lax.top_k(probs, top_k)
    top_w = top_w / jnp.maximum(top_w.sum(axis=-1, keepdims=True), 1e-9)

    flat_e = top_e.reshape(-1)
    onehot = jax.nn.one_hot(flat_e, n_experts, dtype=jnp.int32)
    pos = jnp.take_along_axis(jnp.cumsum(onehot, axis=0) - 1,
                              flat_e[:, None], axis=1)[:, 0]
    flat_keep = pos < capacity
    safe_pos = jnp.where(flat_keep, pos, 0)
    flat_tok = jnp.repeat(jnp.arange(n), top_k)

    updates = jnp.take(xt, flat_tok, axis=0) * flat_keep[:, None
                                                         ].astype(xt.dtype)
    buf = jnp.zeros((n_experts, capacity, d), xt.dtype)
    buf = buf.at[flat_e, safe_pos].add(updates, mode="drop")

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w_gate,
                               preferred_element_type=jnp.float32)) * \
        jnp.einsum("ecd,edf->ecf", buf, w_up,
                   preferred_element_type=jnp.float32)
    h = h.astype(xt.dtype)
    out_buf = jnp.einsum("ecf,efd->ecd", h, w_down,
                         preferred_element_type=jnp.float32)
    out_buf = out_buf.astype(xt.dtype)

    flat_out = out_buf[flat_e, safe_pos]
    w = (flat_keep * top_w.reshape(-1)).astype(xt.dtype)
    out = jax.ops.segment_sum(flat_out * w[:, None], flat_tok,
                              num_segments=n).astype(xt.dtype)
    # TP partial sums: combine is linear in out_buf, so the psum commutes
    # past it — reducing the (N, d) token plane (1.5 GB) instead of the
    # (E, C=N·k·cf/E, d) buffer (3.75 GB) cuts the dominant collective
    # 2.5× (capacity expansion never crosses the wire)
    if model_axis is not None:
        out = jax.lax.psum(out, model_axis)

    f_e = jnp.mean((jax.nn.one_hot(top_e, n_experts).sum(axis=1) > 0),
                   axis=0)
    aux = n_experts * jnp.sum(f_e * probs.mean(axis=0))
    dropped = 1.0 - flat_keep.mean()
    return out, aux, dropped


def forward_shard_map(params: dict, x: Array, *, n_experts: int, top_k: int,
                      capacity_factor: float = 1.25
                      ) -> tuple[Array, MoEStats]:
    """shard_map MoE (see header). Falls back to :func:`forward` when no
    mesh is active (CPU unit tests)."""
    from jax.sharding import PartitionSpec as P
    from repro.distributed import sharding as shd
    from repro.distributed.compat import shard_map as _shard_map

    mesh = shd._mesh()
    if mesh is None:
        return forward(params, x, n_experts=n_experts, top_k=top_k,
                       capacity_factor=capacity_factor)
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    model_axis = "model" if "model" in mesh.axis_names else None
    n_data = 1
    for a in data_axes:
        n_data *= mesh.shape[a]
    n_model = mesh.shape.get("model", 1)

    b, s, d = x.shape
    n_local = (b * s) // n_data
    capacity = max(int(n_local * top_k * capacity_factor / n_experts), 1)

    def body(xl, rw, wg, wu, wd):
        bl, sl, _ = xl.shape
        # explicit FSDP gather of this layer's expert weights, in bf16
        # (the f32 auto-gather at the boundary would double the traffic)
        def regather(wp):                        # (E, D/|data|, F/|model|)
            wp = wp.astype(xl.dtype)
            return jax.lax.all_gather(wp, data_axes, axis=1, tiled=True)

        out, aux, dropped = _local_moe_body(
            xl.reshape(bl * sl, d), rw, regather(wg), regather(wu),
            jnp.swapaxes(jax.lax.all_gather(
                jnp.swapaxes(wd.astype(xl.dtype), 1, 2),
                data_axes, axis=1, tiled=True), 1, 2),
            n_experts=n_experts, top_k=top_k, capacity=capacity,
            model_axis=model_axis)
        aux = jax.lax.pmean(aux, data_axes)
        dropped = jax.lax.pmean(dropped, data_axes)
        if model_axis is not None:
            # shards along model computed identical stats; keep one copy
            aux = jax.lax.pmean(aux, model_axis)
            dropped = jax.lax.pmean(dropped, model_axis)
        return out.reshape(bl, sl, d), aux, dropped

    batch_spec = P(data_axes if len(data_axes) > 1 else data_axes[0],
                   None, None)
    out, aux, dropped = _shard_map(
        body, mesh=mesh,
        in_specs=(batch_spec,
                  P(None, None),                       # router (replicated)
                  P(None, data_axes, "model"),         # w_gate (E, D, F)
                  P(None, data_axes, "model"),         # w_up
                  P(None, "model", data_axes)),        # w_down (E, F, D)
        out_specs=(batch_spec, P(), P()),
        check=False,
    )(x, params["router"]["w"], params["w_gate"], params["w_up"],
      params["w_down"])
    return out, MoEStats(aux_loss=aux, dropped_frac=dropped)
