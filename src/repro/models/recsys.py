"""RecSys architecture family: DLRM, SASRec, DIEN, MIND.

Shared substrate — **EmbeddingBag in JAX** (the brief's required gap-fill:
no ``nn.EmbeddingBag`` / CSR in JAX): fixed-shape padded bags via
``jnp.take`` + masked reduction; the ragged-offset variant via
``jax.ops.segment_sum`` is provided for host-side pipelines.

Scale-out: the embedding tables are the memory giants (26 × 10⁶⁺ rows for
DLRM) — row-sharded over the mesh model axis ("table" logical axis);
dense MLPs replicated; batch over data.  ``retrieval_cand`` scores one
query against 10⁶ candidates with a single sharded matmul + top-k
(never a loop), reusing ``repro.core.codecs.flat``; HI² indexes the same item
tower in ``examples/recsys_retrieval.py``.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models import attention, layers

Array = jax.Array

PAD_ID = -1


# --------------------------------------------------------------------------
# EmbeddingBag
# --------------------------------------------------------------------------

def embedding_bag(table: Array, ids: Array, mode: str = "sum") -> Array:
    """Padded-bag lookup: table (R, D), ids (..., bag) with PAD_ID pads.

    Fixed-shape equivalent of torch's EmbeddingBag: gather + masked sum /
    mean over the bag axis.
    """
    table = shard(table, "table", None)
    mask = (ids != PAD_ID)[..., None]
    emb = jnp.take(table, jnp.clip(ids, 0, None), axis=0) * mask
    out = emb.sum(axis=-2)
    if mode == "mean":
        out = out / jnp.maximum(mask.sum(axis=-2), 1.0)
    return out


def embedding_bag_ragged(table: Array, flat_ids: Array, offsets: Array,
                         n_bags: int) -> Array:
    """Ragged-offset variant (torch-style CSR offsets) via segment_sum."""
    seg = jnp.searchsorted(offsets, jnp.arange(flat_ids.shape[0]),
                           side="right") - 1
    emb = jnp.take(table, jnp.clip(flat_ids, 0, None), axis=0)
    emb = emb * (flat_ids != PAD_ID)[:, None]
    return jax.ops.segment_sum(emb, seg, num_segments=n_bags)


def _mlp_init(key: Array, dims: list[int]) -> list[dict]:
    ks = jax.random.split(key, len(dims) - 1)
    return [{"w": layers.dense_init(ks[i], dims[i], dims[i + 1])["w"],
             "b": jnp.zeros((dims[i + 1],), jnp.float32)}
            for i in range(len(dims) - 1)]


def _mlp(params: list[dict], x: Array, final_act: bool = False) -> Array:
    for i, p in enumerate(params):
        x = jnp.matmul(x, p["w"], preferred_element_type=jnp.float32) + p["b"]
        if i < len(params) - 1 or final_act:
            x = jax.nn.relu(x)
    return x


def bce_loss(logits: Array, labels: Array) -> Array:
    logits = logits.astype(jnp.float32)
    return jnp.mean(jnp.maximum(logits, 0) - logits * labels
                    + jnp.log1p(jnp.exp(-jnp.abs(logits))))


# --------------------------------------------------------------------------
# DLRM  (Naumov et al., arXiv:1906.00091 — RM2 scale)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DLRMConfig:
    n_dense: int = 13
    n_sparse: int = 26
    embed_dim: int = 64
    n_rows: int = 1_000_000          # rows per sparse table
    bot_mlp: tuple = (13, 512, 256, 64)
    top_mlp_hidden: tuple = (512, 512, 256, 1)


class DLRMBatch(NamedTuple):
    dense: Array      # (B, n_dense) f32
    sparse: Array     # (B, n_sparse) i32 ids (single-hot; bags via pipeline)
    labels: Array     # (B,) f32 clicks


def dlrm_init(key: Array, cfg: DLRMConfig) -> dict:
    ks = jax.random.split(key, 3)
    tables = (jax.random.normal(ks[0],
                                (cfg.n_sparse, cfg.n_rows, cfg.embed_dim),
                                jnp.float32)
              * (cfg.embed_dim ** -0.5)).astype(jnp.float32)
    n_feat = cfg.n_sparse + 1
    n_inter = n_feat * (n_feat - 1) // 2
    top_in = n_inter + cfg.bot_mlp[-1]
    return {
        "tables": tables,
        "bot": _mlp_init(ks[1], list(cfg.bot_mlp)),
        "top": _mlp_init(ks[2], [top_in] + list(cfg.top_mlp_hidden)),
    }


def dlrm_forward(params: dict, cfg: DLRMConfig, batch: DLRMBatch) -> Array:
    b = batch.dense.shape[0]
    dense_v = _mlp(params["bot"], batch.dense, final_act=True)   # (B, D)
    tables = shard(params["tables"], None, "table", None)
    # per-feature single-id lookup (gather over row-sharded tables)
    emb = jnp.take_along_axis(
        tables[None],                                            # (1, F, R, D)
        jnp.clip(batch.sparse, 0, None).T[None, :, :, None],     # (1, F, B, 1)
        axis=2,
    )[0].transpose(1, 0, 2)                                      # (B, F, D)
    feats = jnp.concatenate([dense_v[:, None], emb], axis=1)     # (B, F+1, D)
    feats = shard(feats, "batch", None, None)
    inter = jnp.einsum("bfd,bgd->bfg", feats, feats,
                       preferred_element_type=jnp.float32)
    f = feats.shape[1]
    iu, ju = jnp.triu_indices(f, k=1)
    z = inter[:, iu, ju]                                         # (B, F(F-1)/2)
    top_in = jnp.concatenate([dense_v, z], axis=-1)
    return _mlp(params["top"], top_in)[:, 0]                     # logits (B,)


def dlrm_loss(params: dict, cfg: DLRMConfig, batch: DLRMBatch
              ) -> tuple[Array, dict]:
    logits = dlrm_forward(params, cfg, batch)
    loss = bce_loss(logits, batch.labels)
    return loss, {"loss": loss}


# --------------------------------------------------------------------------
# SASRec  (Kang & McAuley, arXiv:1808.09781)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SASRecConfig:
    n_items: int = 1_000_000
    embed_dim: int = 50
    n_blocks: int = 2
    n_heads: int = 1
    seq_len: int = 50


class SASRecBatch(NamedTuple):
    items: Array      # (B, S) i32 behaviour sequence, PAD_ID padded
    targets: Array    # (B, S) i32 next-item labels
    negatives: Array  # (B, S) i32 sampled negatives


def sasrec_init(key: Array, cfg: SASRecConfig) -> dict:
    ks = jax.random.split(key, 2 + cfg.n_blocks)
    d = cfg.embed_dim

    def block_init(k):
        kk = jax.random.split(k, 3)
        return {
            "attn_norm": layers.layernorm_init(d),
            "attn": attention.init(kk[0], d, cfg.n_heads, cfg.n_heads,
                                   d // cfg.n_heads),
            "ff_norm": layers.layernorm_init(d),
            "ff1": layers.dense_init(kk[1], d, d),
            "ff2": layers.dense_init(kk[2], d, d),
        }

    stacked = jax.vmap(block_init)(jax.random.split(ks[0], cfg.n_blocks))
    return {
        "item_embed": layers.embedding_init(ks[1], cfg.n_items, d),
        "pos_embed": layers.embedding_init(jax.random.fold_in(ks[1], 1),
                                           cfg.seq_len, d),
        "blocks": stacked,
        "final_norm": layers.layernorm_init(d),
    }


def sasrec_hidden(params: dict, cfg: SASRecConfig, items: Array) -> Array:
    b, s = items.shape
    table = shard(params["item_embed"]["table"], "table", None)
    x = jnp.take(table, jnp.clip(items, 0, None), axis=0)
    x = x * (cfg.embed_dim ** 0.5) + params["pos_embed"]["table"][None, :s]
    x = x * (items != PAD_ID)[..., None]
    x = shard(x, "batch", None, None)

    def body(carry, bp):
        h = layers.layernorm(bp["attn_norm"], carry)
        h = attention.forward(bp["attn"], h, n_heads=cfg.n_heads,
                              n_kv_heads=cfg.n_heads,
                              d_head=cfg.embed_dim // cfg.n_heads,
                              causal=True, rope_theta=0.0, use_flash=False)
        x1 = carry + h
        h = layers.layernorm(bp["ff_norm"], x1)
        h = layers.dense(bp["ff2"], jax.nn.relu(layers.dense(bp["ff1"], h)))
        return x1 + h, None

    x, _ = jax.lax.scan(body, x, params["blocks"])
    return layers.layernorm(params["final_norm"], x)


def sasrec_loss(params: dict, cfg: SASRecConfig, batch: SASRecBatch
                ) -> tuple[Array, dict]:
    """BPR-style binary loss with sampled negatives (paper's objective)."""
    h = sasrec_hidden(params, cfg, batch.items)                 # (B, S, D)
    table = shard(params["item_embed"]["table"], "table", None)
    pos_e = jnp.take(table, jnp.clip(batch.targets, 0, None), axis=0)
    neg_e = jnp.take(table, jnp.clip(batch.negatives, 0, None), axis=0)
    pos_s = jnp.einsum("bsd,bsd->bs", h, pos_e)
    neg_s = jnp.einsum("bsd,bsd->bs", h, neg_e)
    mask = (batch.targets != PAD_ID)
    loss = -(jax.nn.log_sigmoid(pos_s) + jax.nn.log_sigmoid(-neg_s))
    loss = (loss * mask).sum() / jnp.maximum(mask.sum(), 1)
    return loss, {"loss": loss}


def sasrec_user_embedding(params: dict, cfg: SASRecConfig, items: Array
                          ) -> Array:
    """Last hidden state = the retrieval query vector."""
    return sasrec_hidden(params, cfg, items)[:, -1]


# --------------------------------------------------------------------------
# DIEN  (Zhou et al., arXiv:1809.03672) — GRU + AUGRU interest evolution
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DIENConfig:
    n_items: int = 1_000_000
    embed_dim: int = 18
    seq_len: int = 100
    gru_dim: int = 108
    mlp_hidden: tuple = (200, 80)


class DIENBatch(NamedTuple):
    history: Array    # (B, S) i32
    target: Array     # (B,) i32
    labels: Array     # (B,) f32


def _gru_init(key: Array, d_in: int, d_h: int) -> dict:
    ks = jax.random.split(key, 3)
    s = (d_in + d_h) ** -0.5
    def w(k):
        return (jax.random.normal(k, (d_in + d_h, d_h), jnp.float32) * s)
    return {"wz": w(ks[0]), "wr": w(ks[1]), "wh": w(ks[2]),
            "bz": jnp.zeros((d_h,)), "br": jnp.zeros((d_h,)),
            "bh": jnp.zeros((d_h,))}


def _gru_cell(p: dict, h: Array, x: Array, att: Optional[Array] = None
              ) -> Array:
    xh = jnp.concatenate([x, h], axis=-1)
    z = jax.nn.sigmoid(xh @ p["wz"] + p["bz"])
    r = jax.nn.sigmoid(xh @ p["wr"] + p["br"])
    xrh = jnp.concatenate([x, r * h], axis=-1)
    hh = jnp.tanh(xrh @ p["wh"] + p["bh"])
    if att is not None:          # AUGRU: attention scales the update gate
        z = z * att[:, None]
    return (1 - z) * h + z * hh


def dien_init(key: Array, cfg: DIENConfig) -> dict:
    ks = jax.random.split(key, 5)
    d_in = cfg.embed_dim * 2     # item ⊕ category embedding (paper)
    top_in = cfg.gru_dim + d_in
    return {
        "item_embed": layers.embedding_init(ks[0], cfg.n_items, cfg.embed_dim),
        "cat_embed": layers.embedding_init(ks[1], max(cfg.n_items // 100, 16),
                                           cfg.embed_dim),
        "gru1": _gru_init(ks[2], d_in, cfg.gru_dim),
        "augru": _gru_init(ks[3], cfg.gru_dim, cfg.gru_dim),
        "top": _mlp_init(ks[4], [top_in] + list(cfg.mlp_hidden) + [1]),
    }


def _dien_embed(params: dict, cfg: DIENConfig, ids: Array) -> Array:
    item_t = shard(params["item_embed"]["table"], "table", None)
    cat_t = params["cat_embed"]["table"]
    cat_ids = jnp.clip(ids, 0, None) % cat_t.shape[0]
    return jnp.concatenate([
        jnp.take(item_t, jnp.clip(ids, 0, None), axis=0),
        jnp.take(cat_t, cat_ids, axis=0)], axis=-1)


def dien_forward(params: dict, cfg: DIENConfig, batch: DIENBatch) -> Array:
    b, s = batch.history.shape
    hist = _dien_embed(params, cfg, batch.history)              # (B, S, 2d)
    tgt = _dien_embed(params, cfg, batch.target[:, None])[:, 0]  # (B, 2d)
    mask = (batch.history != PAD_ID).astype(jnp.float32)

    # interest extraction GRU
    def step1(h, xs):
        x, m = xs
        h_new = _gru_cell(params["gru1"], h, x)
        h = jnp.where(m[:, None] > 0, h_new, h)
        return h, h

    h0 = jnp.zeros((b, cfg.gru_dim), jnp.float32)
    _, states = jax.lax.scan(step1, h0, (hist.swapaxes(0, 1),
                                         mask.swapaxes(0, 1)))
    states = states.swapaxes(0, 1)                              # (B, S, H)

    # attention of target on interest states → AUGRU
    att_proj = states[..., :tgt.shape[-1]]
    att = jnp.einsum("bsd,bd->bs", att_proj, tgt)
    att = jax.nn.softmax(jnp.where(mask > 0, att, -1e30), axis=-1)

    def step2(h, xs):
        x, a, m = xs
        h_new = _gru_cell(params["augru"], h, x, att=a)
        h = jnp.where(m[:, None] > 0, h_new, h)
        return h, None

    h_final, _ = jax.lax.scan(step2, h0, (states.swapaxes(0, 1),
                                          att.swapaxes(0, 1),
                                          mask.swapaxes(0, 1)))
    top_in = jnp.concatenate([h_final, tgt], axis=-1)
    return _mlp(params["top"], top_in)[:, 0]


def dien_loss(params: dict, cfg: DIENConfig, batch: DIENBatch
              ) -> tuple[Array, dict]:
    logits = dien_forward(params, cfg, batch)
    loss = bce_loss(logits, batch.labels)
    return loss, {"loss": loss}


# --------------------------------------------------------------------------
# MIND  (Li et al., arXiv:1904.08030) — multi-interest capsule routing
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MINDConfig:
    n_items: int = 1_000_000
    embed_dim: int = 64
    n_interests: int = 4
    capsule_iters: int = 3
    seq_len: int = 50


class MINDBatch(NamedTuple):
    history: Array    # (B, S) i32
    target: Array     # (B,) i32 positive item
    negatives: Array  # (B, N) i32 sampled negatives


def mind_init(key: Array, cfg: MINDConfig) -> dict:
    ks = jax.random.split(key, 2)
    return {
        "item_embed": layers.embedding_init(ks[0], cfg.n_items,
                                            cfg.embed_dim),
        "bilinear": layers.dense_init(ks[1], cfg.embed_dim, cfg.embed_dim),
    }


def mind_interests(params: dict, cfg: MINDConfig, history: Array) -> Array:
    """B2I dynamic routing → (B, n_interests, D) user interest capsules."""
    b, s = history.shape
    table = shard(params["item_embed"]["table"], "table", None)
    beh = jnp.take(table, jnp.clip(history, 0, None), axis=0)   # (B, S, D)
    mask = (history != PAD_ID).astype(jnp.float32)
    beh_hat = layers.dense(params["bilinear"], beh)             # shared S

    # routing logits fixed-init to 0 (deterministic variant; the paper's
    # random init is a no-op in expectation under squash)
    logits = jnp.zeros((b, cfg.n_interests, s), jnp.float32)
    caps = None
    for _ in range(cfg.capsule_iters):
        w = jax.nn.softmax(logits, axis=1)                      # over interests
        w = w * mask[:, None, :]
        caps = jnp.einsum("bks,bsd->bkd", w, beh_hat)
        norm2 = jnp.sum(caps * caps, axis=-1, keepdims=True)
        caps = caps * (norm2 / (1 + norm2)) / jnp.sqrt(norm2 + 1e-9)  # squash
        logits = logits + jnp.einsum("bkd,bsd->bks", caps, beh_hat)
    return caps


def mind_loss(params: dict, cfg: MINDConfig, batch: MINDBatch
              ) -> tuple[Array, dict]:
    """Sampled-softmax with label-aware attention (hard max over interests)."""
    caps = mind_interests(params, cfg, batch.history)           # (B, K, D)
    table = shard(params["item_embed"]["table"], "table", None)
    cand = jnp.concatenate([batch.target[:, None], batch.negatives], axis=1)
    cand_e = jnp.take(table, jnp.clip(cand, 0, None), axis=0)   # (B, 1+N, D)
    scores = jnp.einsum("bkd,bnd->bkn", caps, cand_e)
    scores = jnp.max(scores, axis=1)                            # label-aware max
    logp = jax.nn.log_softmax(scores, axis=-1)
    loss = -logp[:, 0].mean()
    return loss, {"loss": loss}


# --------------------------------------------------------------------------
# retrieval scoring (the ``retrieval_cand`` cells: 1 query × 10⁶ candidates,
# one batched pass — never a loop; HI² indexes the same item towers)
# --------------------------------------------------------------------------

def sasrec_retrieval(params: dict, cfg: SASRecConfig, items: Array,
                     top_r: int = 100) -> tuple[Array, Array]:
    """items: (1, S) history → (scores, ids) of the top_r of all n_items."""
    user = sasrec_user_embedding(params, cfg, items)            # (1, D)
    table = shard(params["item_embed"]["table"], "candidates", None)
    scores = jnp.matmul(user, table.T,
                        preferred_element_type=jnp.float32)     # (1, R)
    return jax.lax.top_k(scores, top_r)


def mind_retrieval(params: dict, cfg: MINDConfig, history: Array,
                   top_r: int = 100) -> tuple[Array, Array]:
    """Multi-interest retrieval: max over the K interest capsules."""
    caps = mind_interests(params, cfg, history)                 # (1, K, D)
    table = shard(params["item_embed"]["table"], "candidates", None)
    scores = jnp.einsum("bkd,rd->bkr", caps, table)
    return jax.lax.top_k(jnp.max(scores, axis=1), top_r)


def dien_retrieval(params: dict, cfg: DIENConfig, history: Array,
                   candidates: Array, top_r: int = 100
                   ) -> tuple[Array, Array]:
    """DIEN is target-conditioned (AUGRU depends on the candidate), so
    retrieval re-runs the evolution layer per candidate — batched over the
    sharded candidate axis, GRU-extracted interests computed once."""
    b, s = history.shape
    n = candidates.shape[0]
    hist = _dien_embed(params, cfg, history)                    # (1, S, 2d)
    mask = (history != PAD_ID).astype(jnp.float32)

    def step1(h, xs):
        x, m = xs
        h_new = _gru_cell(params["gru1"], h, x)
        return jnp.where(m[:, None] > 0, h_new, h), jnp.where(
            m[:, None] > 0, h_new, h)

    h0 = jnp.zeros((b, cfg.gru_dim), jnp.float32)
    _, states = jax.lax.scan(step1, h0, (hist.swapaxes(0, 1),
                                         mask.swapaxes(0, 1)))
    states = states[:, 0]                                       # (S, H)

    tgt = _dien_embed(params, cfg, candidates[:, None])[:, 0]   # (N, 2d)
    tgt = shard(tgt, "candidates", None)
    att = jnp.einsum("sh,nh->ns", states[:, :tgt.shape[-1]], tgt)
    att = jax.nn.softmax(jnp.where(mask[0][None] > 0, att, -1e30), axis=-1)
    att = shard(att, "candidates", None)

    def step2(h, xs):
        x, a = xs                                               # (H,), (N,)
        h_new = _gru_cell(params["augru"],
                          h, jnp.broadcast_to(x[None], (n, x.shape[0])),
                          att=a)
        return h_new, None

    hn0 = jnp.zeros((n, cfg.gru_dim), jnp.float32)
    h_final, _ = jax.lax.scan(step2, hn0, (states, att.T))
    top_in = jnp.concatenate([h_final, tgt], axis=-1)
    scores = _mlp(params["top"], top_in)[:, 0]                  # (N,)
    return jax.lax.top_k(scores[None], top_r)


def dlrm_retrieval(params: dict, cfg: DLRMConfig, dense: Array,
                   sparse_ctx: Array, candidates: Array, top_r: int = 100
                   ) -> tuple[Array, Array]:
    """Score 1 user context against N candidate items: the candidate id
    fills the last sparse slot; everything else broadcasts."""
    n = candidates.shape[0]
    sparse = jnp.broadcast_to(sparse_ctx, (n, cfg.n_sparse - 1))
    sparse = jnp.concatenate([sparse, candidates[:, None]], axis=-1)
    sparse = shard(sparse, "candidates", None)
    batch = DLRMBatch(dense=jnp.broadcast_to(dense, (n, cfg.n_dense)),
                      sparse=sparse, labels=jnp.zeros((n,), jnp.float32))
    scores = dlrm_forward(params, cfg, batch)
    return jax.lax.top_k(scores[None], top_r)
