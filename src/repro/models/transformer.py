"""Transformer LM family: dense + MoE decoder LMs (GQA, RoPE, SWA) and a
bidirectional encoder mode (the HI² term-selector / bi-encoder tower).

Production structure:
  · layers are stacked (leading L axis) and iterated with ``jax.lax.scan``
    so HLO size and compile time stay flat at 56 layers (Mixtral);
  · per-layer ``jax.checkpoint`` (full remat) bounds activation memory to
    one layer plus the scan-carried residuals;
  · residual stream is sequence-sharded between blocks (logical "seq" →
    model axis), attention/FFN internals are TP-sharded — XLA inserts the
    Megatron sequence-parallel all-gather/reduce-scatter pairs;
  · decode uses the rolling KV cache from models.attention, scanned over
    layers with stacked caches.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models import attention, layers, moe

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: Optional[int] = None           # default d_model // n_heads
    # MoE (n_experts=0 → dense)
    n_experts: int = 0
    moe_top_k: int = 2
    capacity_factor: float = 1.25
    # attention
    causal: bool = True
    window: int = 0                        # SWA window; 0 = full attention
    rope_theta: float = 10000.0
    # numerics / structure
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    use_flash: bool = False
    remat: bool = True
    moe_impl: str = "gspmd"                # "gspmd" | "shard_map" (§Perf)

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def n_params(self) -> int:
        """Total parameter count (embeddings + layers + unembed)."""
        d, f = self.d_model, self.d_ff
        attn = d * self.n_heads * self.head_dim * 2 \
            + d * self.n_kv_heads * self.head_dim * 2
        if self.is_moe:
            mlp = self.n_experts * 3 * d * f + d * self.n_experts
        else:
            mlp = 3 * d * f
        per_layer = attn + mlp + 2 * d
        return (self.vocab_size * d * 2 + self.n_layers * per_layer + d)

    def n_active_params(self) -> int:
        """Activated parameters per token (MoE: only routed experts)."""
        if not self.is_moe:
            return self.n_params()
        d, f = self.d_model, self.d_ff
        attn = d * self.n_heads * self.head_dim * 2 \
            + d * self.n_kv_heads * self.head_dim * 2
        mlp = self.moe_top_k * 3 * d * f + d * self.n_experts
        per_layer = attn + mlp + 2 * d
        return (self.vocab_size * d * 2 + self.n_layers * per_layer + d)


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def _init_layer(key: Array, cfg: TransformerConfig) -> dict:
    ks = jax.random.split(key, 4)
    p = {
        "attn_norm": layers.rmsnorm_init(cfg.d_model, cfg.param_dtype),
        "attn": attention.init(ks[0], cfg.d_model, cfg.n_heads,
                               cfg.n_kv_heads, cfg.head_dim, cfg.param_dtype),
        "mlp_norm": layers.rmsnorm_init(cfg.d_model, cfg.param_dtype),
    }
    if cfg.is_moe:
        p["moe"] = moe.init(ks[1], cfg.d_model, cfg.d_ff, cfg.n_experts,
                            cfg.param_dtype)
    else:
        s_in, s_out = cfg.d_model ** -0.5, cfg.d_ff ** -0.5
        p["mlp"] = {
            "w_gate": layers.dense_init(ks[1], cfg.d_model, cfg.d_ff,
                                        cfg.param_dtype, s_in),
            "w_up": layers.dense_init(ks[2], cfg.d_model, cfg.d_ff,
                                      cfg.param_dtype, s_in),
            "w_down": layers.dense_init(ks[3], cfg.d_ff, cfg.d_model,
                                        cfg.param_dtype, s_out),
        }
    return p


def init(key: Array, cfg: TransformerConfig) -> dict:
    k_embed, k_layers, k_out = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    stacked = jax.vmap(lambda k: _init_layer(k, cfg))(layer_keys)
    return {
        "embed": layers.embedding_init(k_embed, cfg.vocab_size, cfg.d_model,
                                       cfg.param_dtype),
        "layers": stacked,
        "final_norm": layers.rmsnorm_init(cfg.d_model, cfg.param_dtype),
        "unembed": layers.dense_init(k_out, cfg.d_model, cfg.vocab_size,
                                     cfg.param_dtype),
    }


# --------------------------------------------------------------------------
# forward (train / prefill / encode)
# --------------------------------------------------------------------------

def _mlp_forward(p: dict, x: Array) -> Array:
    w_gate = shard(p["w_gate"]["w"], "embed", "ff").astype(x.dtype)
    w_up = shard(p["w_up"]["w"], "embed", "ff").astype(x.dtype)
    w_down = shard(p["w_down"]["w"], "ff", "embed").astype(x.dtype)
    h = jax.nn.silu(jnp.matmul(x, w_gate, preferred_element_type=jnp.float32))
    h = (h * jnp.matmul(x, w_up, preferred_element_type=jnp.float32)
         ).astype(x.dtype)
    h = shard(h, "batch", None, "ff")
    return jnp.matmul(h, w_down,
                      preferred_element_type=jnp.float32).astype(x.dtype)


def _layer_forward(lp: dict, cfg: TransformerConfig, x: Array) -> tuple[Array, Array]:
    h = layers.rmsnorm(lp["attn_norm"], x)
    h = attention.forward(
        lp["attn"], h, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        d_head=cfg.head_dim, causal=cfg.causal, window=cfg.window,
        rope_theta=cfg.rope_theta, use_flash=cfg.use_flash)
    x = x + h
    x = shard(x, "batch", "seq", None)
    h = layers.rmsnorm(lp["mlp_norm"], x)
    if cfg.is_moe:
        moe_fn = (moe.forward_shard_map if cfg.moe_impl == "shard_map"
                  else moe.forward)
        h, stats = moe_fn(
            lp["moe"], h, n_experts=cfg.n_experts, top_k=cfg.moe_top_k,
            capacity_factor=cfg.capacity_factor)
        aux = stats.aux_loss
    else:
        h = _mlp_forward(lp["mlp"], h)
        aux = jnp.float32(0.0)
    x = x + h
    x = shard(x, "batch", "seq", None)
    return x, aux


def hidden_states(params: dict, cfg: TransformerConfig, tokens: Array
                  ) -> tuple[Array, Array]:
    """(B, S) -> ((B, S, D) final hidden states, scalar moe aux loss)."""
    table = shard(params["embed"]["table"], "vocab", None)
    x = jnp.take(table, jnp.clip(tokens, 0, None), axis=0)
    x = x.astype(cfg.compute_dtype)
    x = shard(x, "batch", "seq", None)

    body = functools.partial(_layer_forward, cfg=cfg)

    def scan_body(carry, lp):
        def fn(c, p):
            return body(p, x=c)
        if cfg.remat:
            fn = jax.checkpoint(fn,
                                policy=jax.checkpoint_policies.nothing_saveable)
        new_x, aux = fn(carry, lp)
        return new_x, aux

    x, auxes = jax.lax.scan(scan_body, x, params["layers"])
    x = layers.rmsnorm(params["final_norm"], x)
    return x, jnp.sum(auxes)


def logits_fn(params: dict, cfg: TransformerConfig, tokens: Array
              ) -> tuple[Array, Array]:
    x, aux = hidden_states(params, cfg, tokens)
    unembed = shard(params["unembed"]["w"], None, "vocab").astype(x.dtype)
    logits = jnp.matmul(x, unembed, preferred_element_type=jnp.float32)
    return shard(logits, "batch", None, "vocab"), aux


def loss_fn(params: dict, cfg: TransformerConfig, tokens: Array,
            labels: Array, aux_weight: float = 0.01) -> tuple[Array, dict]:
    logits, aux = logits_fn(params, cfg, tokens)
    xent = layers.softmax_xent(logits, labels)
    loss = xent + aux_weight * aux
    return loss, {"loss": loss, "xent": xent, "moe_aux": aux}


def encode(params: dict, cfg: TransformerConfig, tokens: Array,
           pad_id: int = -1) -> tuple[Array, Array]:
    """Encoder mode (causal=False configs): (hidden (B,S,D), pooled (B,D)).

    Pooled embedding is masked mean-pool — the bi-encoder tower for HI²
    and the term-selector backbone (paper Eq. 7 BERT slot).
    """
    hidden, _ = hidden_states(params, cfg, tokens)
    mask = (tokens != pad_id)[..., None].astype(hidden.dtype)
    pooled = (hidden * mask).sum(axis=1) / jnp.maximum(mask.sum(axis=1), 1.0)
    return hidden, pooled


# --------------------------------------------------------------------------
# decode (serve_step)
# --------------------------------------------------------------------------

def init_decode_caches(cfg: TransformerConfig, batch: int, seq_len: int
                       ) -> attention.KVCache:
    """Stacked per-layer caches (leading L axis) for the scan."""
    capacity = attention.cache_capacity(seq_len, cfg.window)

    def one(_):
        return attention.init_cache(batch, cfg.n_kv_heads, capacity,
                                    cfg.head_dim, cfg.compute_dtype)

    return jax.vmap(one)(jnp.arange(cfg.n_layers))


def serve_step(params: dict, cfg: TransformerConfig,
               caches: attention.KVCache, tokens_new: Array, pos: Array
               ) -> tuple[Array, attention.KVCache]:
    """One token for the whole batch against the KV caches.

    tokens_new: (B, 1); pos: () absolute position. Returns
    (logits (B, 1, V), updated caches).
    """
    table = shard(params["embed"]["table"], "vocab", None)
    x = jnp.take(table, jnp.clip(tokens_new, 0, None), axis=0)
    x = x.astype(cfg.compute_dtype)
    x = shard(x, "batch", None, None)

    def body(carry, xs):
        lp, cache = xs
        h = layers.rmsnorm(lp["attn_norm"], carry)
        h, new_cache = attention.decode_step(
            lp["attn"], cache, h, pos, n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads, d_head=cfg.head_dim,
            window=cfg.window, rope_theta=cfg.rope_theta)
        x1 = carry + h
        h = layers.rmsnorm(lp["mlp_norm"], x1)
        if cfg.is_moe:
            h, _ = moe.forward(lp["moe"], h, n_experts=cfg.n_experts,
                               top_k=cfg.moe_top_k,
                               capacity_factor=cfg.capacity_factor)
        else:
            h = _mlp_forward(lp["mlp"], h)
        return x1 + h, new_cache

    x, new_caches = jax.lax.scan(body, x, (params["layers"], caches))
    x = layers.rmsnorm(params["final_norm"], x)
    unembed = shard(params["unembed"]["w"], None, "vocab").astype(x.dtype)
    logits = jnp.matmul(x, unembed, preferred_element_type=jnp.float32)
    return shard(logits, "batch", None, "vocab"), new_caches


def prefill_step(params: dict, cfg: TransformerConfig, tokens: Array
                 ) -> tuple[Array, attention.KVCache]:
    """Production prefill: one full-sequence forward that also emits the
    stacked KV caches (scan ys) and the last-token logits — the graph the
    ``prefill_*`` dry-run cells lower."""
    b, s = tokens.shape
    table = shard(params["embed"]["table"], "vocab", None)
    x = jnp.take(table, jnp.clip(tokens, 0, None), axis=0)
    x = x.astype(cfg.compute_dtype)
    x = shard(x, "batch", "seq", None)

    def body(carry, lp):
        def fn(c, p):
            h = layers.rmsnorm(p["attn_norm"], c)
            h, (k, v) = attention.forward(
                p["attn"], h, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                d_head=cfg.head_dim, causal=cfg.causal, window=cfg.window,
                rope_theta=cfg.rope_theta, use_flash=cfg.use_flash,
                return_kv=True)
            x1 = c + h
            h2 = layers.rmsnorm(p["mlp_norm"], x1)
            if cfg.is_moe:
                h2, _ = moe.forward(p["moe"], h2, n_experts=cfg.n_experts,
                                    top_k=cfg.moe_top_k,
                                    capacity_factor=cfg.capacity_factor)
            else:
                h2 = _mlp_forward(p["mlp"], h2)
            return x1 + h2, (k, v)
        if cfg.remat:
            fn = jax.checkpoint(fn,
                                policy=jax.checkpoint_policies.nothing_saveable)
        new_x, kv = fn(carry, lp)
        kv = jax.tree.map(
            lambda t: shard(t, "batch", "kv_heads", "seq_kv", None), kv)
        return new_x, kv

    x, (ks, vs) = jax.lax.scan(body, x, params["layers"])
    x = layers.rmsnorm(params["final_norm"], x[:, -1:])
    unembed = shard(params["unembed"]["w"], None, "vocab").astype(x.dtype)
    logits = jnp.matmul(x, unembed, preferred_element_type=jnp.float32)
    capacity = attention.cache_capacity(s, cfg.window)
    # rolling-cache layout: position p must land in slot p % capacity so
    # that continued decode (slot = pos % capacity) evicts the *oldest*
    # position, never a live one
    p0 = s - capacity
    shift = p0 % capacity if capacity else 0
    caches = attention.KVCache(
        k=jnp.roll(ks[..., -capacity:, :], shift, axis=-2
                   ).astype(cfg.compute_dtype),
        v=jnp.roll(vs[..., -capacity:, :], shift, axis=-2
                   ).astype(cfg.compute_dtype),
        cache_pos=jnp.broadcast_to(
            jnp.roll(jnp.arange(p0, s, dtype=jnp.int32), shift),
            (cfg.n_layers, capacity)),
    )
    return logits, caches


def prefill(params: dict, cfg: TransformerConfig, tokens: Array
            ) -> tuple[Array, attention.KVCache]:
    """Sequential prefill via serve_step (example-scale oracle for tests;
    production prefill is :func:`prefill_step`)."""
    b, s = tokens.shape
    caches = init_decode_caches(cfg, b, s)
    logits = None
    for i in range(s):
        logits, caches = serve_step(params, cfg, caches, tokens[:, i:i + 1],
                                    jnp.int32(i))
    return logits, caches
