from repro.optim.adam import AdamConfig, AdamState, adam_init, adam_update
from repro.optim.schedule import (constant, cosine_decay, linear_warmup,
                                  warmup_cosine)
from repro.optim.grad import (accumulate_grads, clip_by_global_norm,
                              global_norm)
