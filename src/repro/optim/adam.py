"""AdamW from scratch (no optax in this environment) over arbitrary pytrees.

Used for HI²_sup distillation (cluster embeddings + term-scorer encoder,
paper §4.3) and by the LM/GNN/recsys training drivers.  State lives in
the same sharding as the parameters — on a (data, model) mesh the first
and second moments inherit the parameter PartitionSpecs, so the optimizer
adds zero extra collectives.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any


class AdamConfig(NamedTuple):
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0


class AdamState(NamedTuple):
    step: Array     # () i32
    mu: PyTree      # first moment
    nu: PyTree      # second moment


def adam_init(params: PyTree) -> AdamState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return AdamState(step=jnp.zeros((), jnp.int32), mu=zeros,
                     nu=jax.tree.map(jnp.copy, zeros))


def adam_update(grads: PyTree, state: AdamState, params: PyTree,
                config: AdamConfig, lr_scale: Array | float = 1.0
                ) -> tuple[PyTree, AdamState]:
    """One AdamW step. ``lr_scale`` multiplies the base lr (schedules)."""
    step = state.step + 1
    b1, b2 = config.b1, config.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    lr = config.lr * lr_scale

    def moment1(m, g):
        return b1 * m + (1 - b1) * g.astype(jnp.float32)

    def moment2(v, g):
        g = g.astype(jnp.float32)
        return b2 * v + (1 - b2) * g * g

    mu = jax.tree.map(moment1, state.mu, grads)
    nu = jax.tree.map(moment2, state.nu, grads)

    def upd(p, m, v):
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + config.eps)
        if config.weight_decay:
            delta = delta + config.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, AdamState(step=step, mu=mu, nu=nu)
