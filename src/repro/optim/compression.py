"""int8 gradient compression with error feedback (1-bit-Adam lineage).

Cross-pod gradient traffic is the scaling bottleneck of the pod axis
(DESIGN.md §5).  Per-tensor symmetric int8 quantization cuts it 4×
versus f32 (2× vs bf16); the quantization error is fed back into the
next step's gradient (error feedback), which keeps SGD/Adam convergence
unbiased in the long run (Karimireddy et al., 2019).

Usage (see launch/train.py): compress → all_reduce int8→f32 sums →
decompress; EF state lives next to the optimizer state and is
checkpointed with it.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


class CompressedLeaf(NamedTuple):
    q: jax.Array       # int8 payload
    scale: jax.Array   # () f32


def compress_leaf(g: jax.Array) -> CompressedLeaf:
    amax = jnp.max(jnp.abs(g.astype(jnp.float32)))
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return CompressedLeaf(q=q, scale=scale)


def decompress_leaf(c: CompressedLeaf) -> jax.Array:
    return c.q.astype(jnp.float32) * c.scale


def ef_init(params: PyTree) -> PyTree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_with_ef(grads: PyTree, ef: PyTree
                     ) -> tuple[PyTree, PyTree]:
    """Returns (compressed grads tree, new error-feedback state)."""
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        c = compress_leaf(corrected)
        return c, corrected - decompress_leaf(c)

    pairs = jax.tree.map(one, grads, ef)
    comp = jax.tree.map(lambda pr: pr[0], pairs,
                        is_leaf=lambda x: isinstance(x, tuple))
    new_ef = jax.tree.map(lambda pr: pr[1], pairs,
                          is_leaf=lambda x: isinstance(x, tuple))
    return comp, new_ef


def decompress(comp: PyTree) -> PyTree:
    return jax.tree.map(decompress_leaf, comp,
                        is_leaf=lambda x: isinstance(x, CompressedLeaf))
