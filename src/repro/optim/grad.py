"""Gradient tooling: global-norm clipping and microbatch accumulation."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


def global_norm(tree: PyTree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(tree: PyTree, max_norm: float) -> tuple[PyTree, jax.Array]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), tree), norm


def accumulate_grads(loss_fn, params: PyTree, microbatches,
                     *args) -> tuple[jax.Array, PyTree]:
    """Sequential gradient accumulation over a stacked microbatch pytree.

    ``microbatches`` leaves have a leading microbatch axis; the scan keeps
    activation memory at one microbatch.
    """
    grad_fn = jax.grad(loss_fn, has_aux=False)

    def body(carry, mb):
        acc, total = carry
        g = grad_fn(params, mb, *args)
        loss = loss_fn(params, mb, *args)
        acc = jax.tree.map(jnp.add, acc, g)
        return (acc, total + loss), None

    n = jax.tree.leaves(microbatches)[0].shape[0]
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (acc, total), _ = jax.lax.scan(body, (zeros, 0.0), microbatches)
    inv = 1.0 / n
    return total * inv, jax.tree.map(lambda g: g * inv, acc)
