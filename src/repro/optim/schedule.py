"""Learning-rate schedules as pure ``step -> scale`` functions."""
from __future__ import annotations

import jax.numpy as jnp


def constant():
    return lambda step: jnp.float32(1.0)


def linear_warmup(warmup_steps: int):
    def fn(step):
        return jnp.minimum(1.0, (step + 1) / max(warmup_steps, 1)).astype(jnp.float32)
    return fn


def cosine_decay(total_steps: int, final_scale: float = 0.1):
    def fn(step):
        t = jnp.clip(step / max(total_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return (final_scale + (1.0 - final_scale) * cos).astype(jnp.float32)
    return fn


def warmup_cosine(warmup_steps: int, total_steps: int, final_scale: float = 0.1):
    wu = linear_warmup(warmup_steps)
    cd = cosine_decay(max(total_steps - warmup_steps, 1), final_scale)
    def fn(step):
        return jnp.where(step < warmup_steps, wu(step),
                         cd(step - warmup_steps))
    return fn
