"""Deterministic stand-in for the slice of hypothesis this suite uses.

CI installs the real hypothesis from requirements.txt; the accelerator
image does not ship it and nothing may be pip-installed there.  Rather
than skip the property tests, this shim *runs* them: ``@given`` draws
``settings.max_examples`` examples from a fixed-seed RNG (first two
draws pinned to the strategy's min/max so boundaries are always hit)
and calls the test once per example.  No shrinking, no database — a
failing example's kwargs are attached to the assertion message instead.

Only the strategies the suite uses are implemented: ``integers``,
``sampled_from``, ``booleans``.
"""
from __future__ import annotations

import functools
import inspect
import random


class _Strategy:
    def __init__(self, draw, boundaries=()):
        self.draw = draw                  # rng -> value
        self.boundaries = tuple(boundaries)


class strategies:
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value),
                         boundaries=(min_value, max_value))

    @staticmethod
    def sampled_from(elements):
        xs = list(elements)
        return _Strategy(lambda rng: rng.choice(xs),
                         boundaries=(xs[0], xs[-1]))

    @staticmethod
    def booleans():
        return _Strategy(lambda rng: rng.random() < 0.5,
                         boundaries=(False, True))


class settings:
    max_examples = 10
    _profiles: dict = {}

    def __init__(self, **kwargs):  # @settings(...) decorator form (unused)
        self.kwargs = kwargs

    def __call__(self, fn):
        return fn

    @classmethod
    def register_profile(cls, name, max_examples=10, deadline=None, **_):
        cls._profiles[name] = max_examples

    @classmethod
    def load_profile(cls, name):
        cls.max_examples = cls._profiles.get(name, 10)


def given(**strats):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            rng = random.Random(0x412)    # fixed seed: reproducible draws
            names = sorted(strats)
            for i in range(settings.max_examples):
                if i < 2:                 # boundary examples first
                    drawn = {n: strats[n].boundaries[i] for n in names
                             if len(strats[n].boundaries) > i}
                    drawn.update({n: strats[n].draw(rng) for n in names
                                  if n not in drawn})
                else:
                    drawn = {n: strats[n].draw(rng) for n in names}
                try:
                    fn(*args, **kwargs, **drawn)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example ({fn.__name__}): {drawn}") from e

        # pytest must only see the non-strategy params (fixtures): expose
        # a reduced signature and hide __wrapped__ so nothing unwraps it
        fixture_params = [p for n, p in
                          inspect.signature(fn).parameters.items()
                          if n not in strats]
        wrapper.__signature__ = inspect.Signature(fixture_params)
        del wrapper.__wrapped__
        return wrapper
    return deco
