"""Per-architecture smoke tests: every assigned arch instantiates a
REDUCED config of its family and runs one forward/train step on CPU,
asserting output shapes and finiteness (the FULL configs are exercised
only through the compile-only dry-run)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.data import graph as gdata, recsys as rdata
from repro.models import gnn, recsys, transformer as tfm
from repro.optim import AdamConfig, adam_init, adam_update

LM_ARCHS = ["olmoe-1b-7b", "mixtral-8x22b", "stablelm-3b", "internlm2-1.8b",
            "llama3-8b"]
REC_ARCHS = ["dlrm-rm2", "sasrec", "dien", "mind"]


def _finite(tree):
    return all(np.isfinite(np.asarray(x)).all() for x in jax.tree.leaves(tree))


def test_registry_has_all_ten_archs():
    archs = {k: v for k, v in registry.all_archs().items() if not v.extra}
    assert set(archs) == set(LM_ARCHS) | set(REC_ARCHS) | {"gatedgcn"}
    # 40 assigned cells (incl. recorded skips)
    assert len(registry.cells(include_skipped=True)) == 40
    skips = sum(len(a.skip_shapes) for a in archs.values())
    assert skips == 4  # long_500k for the pure full-attention LMs


@pytest.mark.parametrize("arch_id", LM_ARCHS)
def test_lm_arch_smoke(arch_id):
    arch = registry.get(arch_id)
    cfg = arch.make_reduced()
    params = tfm.init(jax.random.key(0), cfg)
    tokens = jax.random.randint(jax.random.key(1), (2, 16), 0,
                                cfg.vocab_size)
    labels = jnp.roll(tokens, -1, axis=1)
    # one full train step (loss + grads + adam)
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: tfm.loss_fn(p, cfg, tokens, labels), has_aux=True)(params)
    state = adam_init(params)
    params2, state2 = adam_update(grads, state, params, AdamConfig(lr=1e-3))
    assert np.isfinite(float(loss))
    assert _finite(params2)
    # serve path: one decode step
    caches = tfm.init_decode_caches(cfg, 2, 16)
    logits, caches = tfm.serve_step(params, cfg, caches,
                                    tokens[:, :1], jnp.int32(0))
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert _finite(logits)


def test_lm_full_configs_match_assignment():
    """The exact published dims of the full configs (the dry-run inputs)."""
    expect = {
        "olmoe-1b-7b": (16, 2048, 16, 16, 1024, 50304, 64, 8),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768, 8, 2),
        "stablelm-3b": (32, 2560, 32, 32, 6912, 50304, 0, 2),
        "internlm2-1.8b": (24, 2048, 16, 8, 8192, 92544, 0, 2),
        "llama3-8b": (32, 4096, 32, 8, 14336, 128256, 0, 2),
    }
    for arch_id, (nl, dm, nh, nkv, dff, v, ne, tk) in expect.items():
        cfg = registry.get(arch_id).make_config()
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab_size) == (nl, dm, nh, nkv, dff, v)
        assert cfg.n_experts == ne
        if ne:
            assert cfg.moe_top_k == tk
    assert registry.get("mixtral-8x22b").make_config().window == 4096


def test_gatedgcn_smoke():
    arch = registry.get("gatedgcn")
    cfg = arch.make_reduced()
    params = gnn.init(jax.random.key(0), cfg)
    g = gdata.random_graph(0, n_nodes=120, n_edges=480, d_feat=cfg.d_feat,
                           n_classes=cfg.n_classes)
    (loss, _), grads = jax.value_and_grad(
        lambda p: gnn.loss_fn(p, cfg, g), has_aux=True)(params)
    assert np.isfinite(float(loss)) and _finite(grads)
    logits = gnn.forward(params, cfg, g)
    assert logits.shape == (120, cfg.n_classes)


def test_gatedgcn_minibatch_sampler_smoke():
    arch = registry.get("gatedgcn")
    cfg = arch.make_reduced()
    g = gdata.random_graph(1, n_nodes=500, n_edges=4000, d_feat=cfg.d_feat,
                           n_classes=cfg.n_classes)
    sampler = gdata.NeighborSampler(500, np.asarray(g.edge_src),
                                    np.asarray(g.edge_dst))
    sub = sampler.sample(0, np.arange(16), (5, 3),
                         np.asarray(g.node_feat), np.asarray(g.labels))
    params = gnn.init(jax.random.key(0), cfg)
    loss, _ = gnn.loss_fn(params, cfg, sub)
    assert np.isfinite(float(loss))
    # fixed shapes: 16·(1+5+15) nodes, 16·(5+5·3) edges
    assert sub.node_feat.shape[0] == 16 * 21
    assert sub.edge_src.shape[0] == 16 * 20


def test_gatedgcn_molecule_smoke():
    arch = registry.get("gatedgcn")
    cfg = gnn.GatedGCNConfig(n_layers=3, d_hidden=16, d_feat=16,
                             n_classes=10, graph_level=True, remat=False)
    params = gnn.init(jax.random.key(0), cfg)
    mb = gdata.molecule_batch(0, batch=8, n_nodes=30, n_edges=64, d_feat=16,
                              n_classes=10)
    logits = gnn.forward(params, cfg, mb)
    assert logits.shape == (8, 10)
    loss, _ = gnn.loss_fn(params, cfg, mb)
    assert np.isfinite(float(loss))


_REC_FACTORY = {
    "dlrm-rm2": lambda cfg, b: rdata.dlrm_batch(0, b, n_dense=cfg.n_dense,
                                                n_sparse=cfg.n_sparse,
                                                n_rows=cfg.n_rows),
    "sasrec": lambda cfg, b: rdata.sasrec_batch(0, b, seq_len=cfg.seq_len,
                                                n_items=cfg.n_items),
    "dien": lambda cfg, b: rdata.dien_batch(0, b, seq_len=cfg.seq_len,
                                            n_items=cfg.n_items),
    "mind": lambda cfg, b: rdata.mind_batch(0, b, seq_len=cfg.seq_len,
                                            n_items=cfg.n_items),
}

_REC_FNS = {
    "dlrm-rm2": (recsys.dlrm_init, recsys.dlrm_loss),
    "sasrec": (recsys.sasrec_init, recsys.sasrec_loss),
    "dien": (recsys.dien_init, recsys.dien_loss),
    "mind": (recsys.mind_init, recsys.mind_loss),
}


@pytest.mark.parametrize("arch_id", REC_ARCHS)
def test_recsys_arch_smoke(arch_id):
    arch = registry.get(arch_id)
    cfg = arch.make_reduced()
    init_fn, loss_fn = _REC_FNS[arch_id]
    params = init_fn(jax.random.key(0), cfg)
    batch = _REC_FACTORY[arch_id](cfg, 16)
    (loss, _), grads = jax.value_and_grad(
        lambda p: loss_fn(p, cfg, batch), has_aux=True)(params)
    assert np.isfinite(float(loss)) and _finite(grads)
    # one optimizer step actually reduces loss on the same batch
    state = adam_init(params)
    p2, _ = adam_update(grads, state, params, AdamConfig(lr=1e-2))
    loss2, _ = loss_fn(p2, cfg, batch)
    assert float(loss2) < float(loss)


@pytest.mark.parametrize("arch_id", REC_ARCHS)
def test_recsys_retrieval_smoke(arch_id):
    arch = registry.get(arch_id)
    cfg = arch.make_reduced()
    init_fn, _ = _REC_FNS[arch_id]
    params = init_fn(jax.random.key(0), cfg)
    if arch_id == "sasrec":
        s, ids = recsys.sasrec_retrieval(params, cfg,
                                         jnp.ones((1, cfg.seq_len),
                                                  jnp.int32), top_r=10)
    elif arch_id == "mind":
        s, ids = recsys.mind_retrieval(params, cfg,
                                       jnp.ones((1, cfg.seq_len), jnp.int32),
                                       top_r=10)
    elif arch_id == "dien":
        s, ids = recsys.dien_retrieval(params, cfg,
                                       jnp.ones((1, cfg.seq_len), jnp.int32),
                                       jnp.arange(200, dtype=jnp.int32),
                                       top_r=10)
    else:
        s, ids = recsys.dlrm_retrieval(params, cfg,
                                       jnp.zeros((1, cfg.n_dense)),
                                       jnp.zeros((1, cfg.n_sparse - 1),
                                                 jnp.int32),
                                       jnp.arange(200, dtype=jnp.int32),
                                       top_r=10)
    assert s.shape == (1, 10) and ids.shape == (1, 10)
    assert _finite(s)
    # scores actually sorted descending
    assert np.all(np.diff(np.asarray(s)[0]) <= 1e-6)
