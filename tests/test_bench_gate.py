"""The CI bench-regression gate and the benchmark driver's coverage
guarantee (benchmarks/check_regression.py, benchmarks/run.py).

The gate's semantics: quality/structural fields compare bit-exactly,
wall-clock fields (``*_us*``/``seconds``/``qps``/``speedup*``) only
directionally within a ratio; a missing baseline or a missing fresh file
is itself a failure (no silent green).  The driver must refuse to run if
a benchmarks/*.py exists without a dispatch entry, so new benchmarks
cannot silently drop out of `python -m benchmarks.run`.
"""
import json
import os
import pathlib
import sys

_ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(_ROOT))

from benchmarks import check_regression as cr            # noqa: E402
from benchmarks import run as bench_run                  # noqa: E402


def test_timing_direction_heuristic():
    assert cr.timing_direction("us_per_batch") == "lower"
    assert cr.timing_direction("add_seconds_total") == "lower"
    assert cr.timing_direction("base_build_seconds") == "lower"
    assert cr.timing_direction("search_us_per_batch") == "lower"
    assert cr.timing_direction("qps") == "higher"
    assert cr.timing_direction("speedup_vs_baseline") == "higher"
    for exact in ("R@100", "candidate_cost", "delta_docs",
                  "mean_candidates", "n_live", "fill_fraction"):
        assert cr.timing_direction(exact) is None, exact


def test_dispatch_covers_every_benchmark_on_disk():
    names = bench_run.discovered()
    assert set(names) == set(bench_run.DISPATCH), (
        "benchmarks/*.py and benchmarks/run.py DISPATCH diverged")
    for helper in bench_run.HELPER_MODULES - {"__init__"}:
        assert (_ROOT / "benchmarks" / f"{helper}.py").exists(), helper
    # the three gate files all come from dispatched benchmarks
    assert {"table3_codec", "sharded_search", "streaming_updates"} \
        <= set(names)


def _write(d, name, doc):
    os.makedirs(d, exist_ok=True)
    with open(os.path.join(d, name), "w") as f:
        json.dump(doc, f)


BASE = {"rows": [{"codec": "flat", "R@100": 0.9609375,
                  "candidate_cost": 1920}],
        "baseline": {"us_per_batch": 1000.0, "qps": 64.0},
        "flags": {"equal_to_rebuild": True}}


def test_gate_passes_on_identical_and_tolerable_timing(tmp_path):
    b, f = str(tmp_path / "base"), str(tmp_path / "fresh")
    fresh = json.loads(json.dumps(BASE))
    fresh["baseline"]["us_per_batch"] = 3500.0     # 3.5x slower < 4x
    fresh["baseline"]["qps"] = 20.0                # > 64/4
    _write(b, "x.json", BASE)
    _write(f, "x.json", fresh)
    assert cr.check_files(b, f, ["x.json"], timing_ratio=4.0,
                          float_tol=0.0) == []


def test_gate_fails_on_recall_drift():
    import tempfile
    with tempfile.TemporaryDirectory() as d:
        b, f = os.path.join(d, "base"), os.path.join(d, "fresh")
        fresh = json.loads(json.dumps(BASE))
        fresh["rows"][0]["R@100"] = 0.9609374      # one ulp of drift
        _write(b, "x.json", BASE)
        _write(f, "x.json", fresh)
        fails = cr.check_files(b, f, ["x.json"], timing_ratio=4.0,
                               float_tol=0.0)
        assert len(fails) == 1 and "R@100" in fails[0]


def test_gate_fails_on_slow_timing_but_not_fast(tmp_path):
    b, f = str(tmp_path / "base"), str(tmp_path / "fresh")
    fresh = json.loads(json.dumps(BASE))
    fresh["baseline"]["us_per_batch"] = 5000.0     # 5x slower > 4x
    fresh["baseline"]["qps"] = 1000.0              # faster: fine
    _write(b, "x.json", BASE)
    _write(f, "x.json", fresh)
    fails = cr.check_files(b, f, ["x.json"], timing_ratio=4.0,
                           float_tol=0.0)
    assert len(fails) == 1 and "us_per_batch" in fails[0]


def test_gate_fails_on_structure_change_and_flag_flip(tmp_path):
    b, f = str(tmp_path / "base"), str(tmp_path / "fresh")
    fresh = json.loads(json.dumps(BASE))
    fresh["flags"]["equal_to_rebuild"] = False
    del fresh["rows"][0]["candidate_cost"]
    fresh["rows"][0]["new_field"] = 1
    _write(b, "x.json", BASE)
    _write(f, "x.json", fresh)
    fails = cr.check_files(b, f, ["x.json"], timing_ratio=4.0,
                           float_tol=0.0)
    msgs = "\n".join(fails)
    assert "equal_to_rebuild" in msgs
    assert "candidate_cost" in msgs and "missing" in msgs
    assert "new_field" in msgs


def test_gate_fails_on_missing_files(tmp_path):
    b, f = str(tmp_path / "base"), str(tmp_path / "fresh")
    os.makedirs(b), os.makedirs(f)
    _write(f, "present.json", BASE)
    fails = cr.check_files(b, f, ["present.json", "absent.json"],
                           timing_ratio=4.0, float_tol=0.0)
    msgs = "\n".join(fails)
    assert "no committed baseline" in msgs       # present.json: no baseline
    assert "fresh run missing" in msgs or "no committed baseline" in msgs


def test_gate_covers_sup_bench_and_fails_on_regression(tmp_path):
    """BENCH_sup.json is a first-class gate file: absent fresh runs and
    drifted supervised recall both fail."""
    assert "BENCH_sup.json" in cr.DEFAULT_FILES
    base = {"sup_wins": 4,
            "operating_points": [{"kc": 4, "k2": 6, "cost_sup": 2624,
                                  "recall_sup": 0.6094}],
            "roundtrip": {"planes_bit_identical": True}}
    b, f = str(tmp_path / "base"), str(tmp_path / "fresh")
    _write(b, "BENCH_sup.json", base)
    os.makedirs(f, exist_ok=True)
    fails = cr.check_files(b, f, ["BENCH_sup.json"], timing_ratio=4.0,
                           float_tol=0.0)
    assert len(fails) == 1 and "fresh run missing" in fails[0]

    fresh = json.loads(json.dumps(base))
    fresh["operating_points"][0]["recall_sup"] = 0.55    # regressed
    fresh["sup_wins"] = 3
    _write(f, "BENCH_sup.json", fresh)
    fails = cr.check_files(b, f, ["BENCH_sup.json"], timing_ratio=4.0,
                           float_tol=0.0)
    msgs = "\n".join(fails)
    assert "recall_sup" in msgs and "sup_wins" in msgs


def test_run_driver_reports_all_dispatch_problems(monkeypatch):
    """One run surfaces EVERY dispatch-table problem — a missing entry
    and a stale entry together, not first-failure-only."""
    import pytest
    patched = dict(bench_run.DISPATCH)
    del patched["autotune"]                     # on disk, no entry
    patched["ghost_bench"] = lambda: None       # entry, no file
    monkeypatch.setattr(bench_run, "DISPATCH", patched)
    with pytest.raises(SystemExit) as e:
        bench_run.main(["--list"])
    msg = str(e.value)
    assert "autotune" in msg and "ghost_bench" in msg


def test_committed_baselines_exist_and_selfcompare():
    """The gate's default files are committed under results/ and compare
    clean against themselves (sanity of the comparator on real docs)."""
    res = _ROOT / "results"
    for name in cr.DEFAULT_FILES:
        assert (res / name).exists(), f"commit a baseline for {name}"
    assert cr.check_files(str(res), str(res), list(cr.DEFAULT_FILES),
                          timing_ratio=4.0, float_tol=0.0) == []
