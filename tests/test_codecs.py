"""Codec subsystem numerics and registry contracts (DESIGN.md §7).

Every registered codec goes through the same build → search path, so
these tests pin the seam itself: registry resolution errors, per-codec
encode/score round trips against the decode oracle, the sq8
quantization-error bound, uint8/i32 code equivalence, the refine
codec's "lossless when R′ covers the budget" guarantee, and
codec-validated checkpointing.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import codecs, hybrid_index as hi
from repro.core.codecs import base as codecs_base
from repro.data import synthetic

KEY = jax.random.key(0)


def _corpus(n_docs=2000, hidden=32, f16_exact=False):
    c = synthetic.generate(seed=0, n_docs=n_docs, n_queries=32,
                           hidden=hidden, vocab_size=1024, n_topics=16)
    doc_emb = np.asarray(c.doc_emb)
    if f16_exact:
        # embeddings exactly representable in fp16, so the refine
        # plane's cast is lossless and scores can be compared bitwise
        doc_emb = doc_emb.astype(np.float16).astype(np.float32)
    return dataclasses.replace(c, doc_emb=doc_emb)


def _build(corpus, codec, **overrides):
    kwargs = dict(n_clusters=32, k1_terms=6, codec=codec, pq_m=4, pq_k=64,
                  cluster_capacity=96, term_capacity=48, kmeans_iters=5)
    kwargs.update(overrides)
    return hi.build(KEY, jnp.asarray(corpus.doc_emb),
                    jnp.asarray(corpus.doc_tokens), corpus.vocab_size,
                    **kwargs)


# --------------------------------------------------------------------------
# registry
# --------------------------------------------------------------------------

def test_registry_unknown_codec_lists_known_names():
    with pytest.raises(ValueError) as exc:
        codecs.get("no_such_codec")
    msg = str(exc.value)
    assert "no_such_codec" in msg
    for name in codecs.registered():
        assert name in msg


def test_registry_covers_expected_codecs_and_caches():
    names = codecs.registered()
    for expected in ("flat", "pq", "opq", "sq8", "refine"):
        assert expected in names
    assert codecs.get("sq8") is codecs.get("sq8")          # cached per spec
    assert codecs.get("refine").name == "refine:pq:4"      # defaults
    assert codecs.get("refine:sq8:2").mult == 2


def test_build_rejects_unknown_codec():
    c = _corpus(n_docs=200)
    with pytest.raises(ValueError, match="registered codecs"):
        _build(c, "not_a_codec")


# --------------------------------------------------------------------------
# per-codec numerics: scorer == <q, decode(encode(x))>
# --------------------------------------------------------------------------

@pytest.mark.parametrize("spec", ["flat", "pq", "opq", "sq8", "refine"])
def test_scorer_matches_decode_oracle(spec):
    """Stage-1 scoring must equal the inner product against the codec's
    reconstruction — the property that makes ``decode`` an oracle."""
    impl = codecs.get(spec)
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (500, 32))
    q = jax.random.normal(jax.random.fold_in(KEY, 2), (8, 32))
    params = impl.train(jax.random.fold_in(KEY, 3), x, pq_m=4, pq_k=16)
    planes = impl.encode(params, x)
    ids = jnp.tile(jnp.arange(64, dtype=jnp.int32)[None], (8, 1))
    got = impl.make_scorer(params, planes, q)(ids)
    # a refining codec's stage-1 scorer is its base codec's scorer
    oracle = impl.base if isinstance(impl, codecs.refine.RefineCodec) else impl
    want = np.asarray(q) @ np.asarray(oracle.decode(params, planes)).T[:, :64]
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-3, atol=1e-3)


def test_sq8_reconstruction_error_bound():
    """Affine min/max quantization: per-dim error ≤ scale/2, and codes
    span the full byte range at the extremes."""
    impl = codecs.get("sq8")
    x = jax.random.normal(jax.random.fold_in(KEY, 4), (1000, 16)) * 3.0
    params = impl.train(KEY, x)
    planes = impl.encode(params, x)
    err = np.abs(np.asarray(impl.decode(params, planes)) - np.asarray(x))
    bound = np.asarray(params["scale"]) / 2 + 1e-6
    assert (err <= bound[None, :]).all()
    codes = np.asarray(planes["codes"])
    assert codes.min() == 0 and codes.max() == 255


def test_sq8_constant_dimension_is_exact():
    impl = codecs.get("sq8")
    x = jnp.concatenate([jnp.full((100, 1), 2.5),
                         jax.random.normal(KEY, (100, 3))], axis=-1)
    params = impl.train(KEY, x)
    rec = np.asarray(impl.decode(params, impl.encode(params, x)))
    np.testing.assert_allclose(rec[:, 0], 2.5, rtol=0, atol=0)


@pytest.mark.parametrize("spec", ["pq", "opq"])
def test_pq_codes_pack_to_uint8_iff_small_k(spec):
    impl = codecs.get(spec)
    x = jax.random.normal(KEY, (300, 16))
    for pq_k, dtype in ((16, jnp.uint8), (300, jnp.int32)):
        params = impl.train(jax.random.fold_in(KEY, pq_k), x,
                            pq_m=4, pq_k=pq_k)
        codes = impl.encode(params, x)["codes"]
        assert codes.dtype == dtype, (spec, pq_k)


# (uint8 vs i32 code *search* equivalence lives with the other §Perf
# claims in tests/test_perf_impls.py)


# --------------------------------------------------------------------------
# refine semantics
# --------------------------------------------------------------------------

def test_refine_equals_flat_when_width_covers_budget():
    """With R′ ≥ the candidate budget every candidate is exact-rescored,
    so refine-over-pq returns exactly the flat codec's results."""
    c = _corpus(f16_exact=True)
    flat_idx = _build(c, "flat")
    budget = hi.candidate_budget(flat_idx, 4, 4)
    top_r = 25
    mult = -(-budget // top_r)     # ceil: R' = mult*top_r >= budget
    ref_idx = _build(c, f"refine:pq:{mult}")
    qe, qt = jnp.asarray(c.query_emb), jnp.asarray(c.query_tokens)
    a = hi.search(flat_idx, qe, qt, kc=4, k2=4, top_r=top_r)
    b = hi.search(ref_idx, qe, qt, kc=4, k2=4, top_r=top_r)
    np.testing.assert_array_equal(np.asarray(a.doc_ids),
                                  np.asarray(b.doc_ids))
    np.testing.assert_array_equal(np.asarray(a.scores), np.asarray(b.scores))
    np.testing.assert_array_equal(np.asarray(a.n_candidates),
                                  np.asarray(b.n_candidates))


def test_refine_improves_base_codec_recall():
    from repro.core import metrics
    c = _corpus(n_docs=3000)
    qe, qt = jnp.asarray(c.query_emb), jnp.asarray(c.query_tokens)
    r_pq = hi.search(_build(c, "pq"), qe, qt, kc=4, k2=4, top_r=50)
    r_ref = hi.search(_build(c, "refine:pq:4"), qe, qt, kc=4, k2=4, top_r=50)
    assert (metrics.mrr_at_k(r_ref.doc_ids, c.qrels, 10)
            >= metrics.mrr_at_k(r_pq.doc_ids, c.qrels, 10))


def test_refine_candidate_cost_accounting():
    c = _corpus(n_docs=500)
    idx = _build(c, "refine:pq:4")
    budget = hi.candidate_budget(idx, 4, 4)
    assert hi.candidate_cost(idx, 4, 4, 10) == budget + 40
    plain = _build(c, "pq")
    assert hi.candidate_cost(plain, 4, 4, 10) == budget


# --------------------------------------------------------------------------
# plumbing: bytes accounting, checkpointing
# --------------------------------------------------------------------------

def test_bytes_per_doc_accounting():
    h = 32
    x = jax.random.normal(KEY, (100, h))
    for spec, expect in (("flat", 4 * h), ("sq8", h), ("pq", 4),
                         ("refine:pq:4", 4 + 2 * h)):
        impl = codecs.get(spec)
        params = impl.train(KEY, x, pq_m=4, pq_k=16)
        assert impl.bytes_per_doc(impl.encode(params, x)) == expect, spec


def test_gather_rows_tolerates_pad_ids():
    plane = jnp.arange(12, dtype=jnp.float32).reshape(4, 3)
    ids = jnp.asarray([[-1, 2], [3, -1]], dtype=jnp.int32)
    rows = codecs_base.gather_rows(plane, ids)
    assert rows.shape == (2, 2, 3)
    np.testing.assert_array_equal(np.asarray(rows[0, 0]),
                                  np.asarray(plane[0]))   # PAD clips to row 0


def test_checkpoint_records_and_validates_codec(tmp_path):
    from repro.checkpoint import checkpoint as ckpt
    c = _corpus(n_docs=400)
    idx = _build(c, "sq8")
    path = ckpt.save_index(str(tmp_path), 0, idx)
    assert ckpt.load_manifest(path)["extra"]["codec"] == "sq8"
    restored = ckpt.restore_index(path, idx)
    np.testing.assert_array_equal(np.asarray(restored.doc_planes["codes"]),
                                  np.asarray(idx.doc_planes["codes"]))
    wrong = _build(c, "flat")
    with pytest.raises(ValueError, match="codec"):
        ckpt.restore_index(path, wrong)
