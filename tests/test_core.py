"""Unit + property tests for the HI² core numerics."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # accelerator image: no pip installs; CI has the real one
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import (bm25, cluster_selector as cs, inverted_lists as il,
                        kmeans, pruning, term_selector as ts)
from repro.core.codecs import pq

settings.register_profile("core", max_examples=10, deadline=None)
settings.load_profile("core")


# --------------------------------------------------------------------------
# kmeans
# --------------------------------------------------------------------------

def test_kmeans_reduces_cost_and_assigns_all():
    key = jax.random.key(0)
    x = jax.random.normal(key, (2000, 16))
    c0 = x[jax.random.choice(jax.random.key(1), 2000, (32,), replace=False)]
    a0 = kmeans.assign_blocked(x, c0)
    cost0 = kmeans.kmeans_cost(x, c0, a0)
    c, a = kmeans.kmeans_fit(jax.random.key(1), x, n_clusters=32, n_iters=10)
    assert float(kmeans.kmeans_cost(x, c, a)) < float(cost0)
    assert int(a.min()) >= 0 and int(a.max()) < 32


def test_kmeans_assignment_is_nearest():
    key = jax.random.key(2)
    x = jax.random.normal(key, (500, 8))
    c, a = kmeans.kmeans_fit(jax.random.key(3), x, n_clusters=16, n_iters=5)
    d = np.linalg.norm(np.asarray(x)[:, None] - np.asarray(c)[None], axis=-1)
    np.testing.assert_array_equal(np.asarray(a), d.argmin(axis=1))


# --------------------------------------------------------------------------
# pq / opq
# --------------------------------------------------------------------------

@given(m=st.sampled_from([2, 4, 8]), n=st.integers(300, 800))
def test_pq_reconstruction_better_than_random(m, n):
    key = jax.random.key(m * n)
    x = jax.random.normal(key, (n, 32))
    cb = pq.train_pq(jax.random.fold_in(key, 1), x, m=m, k=16, n_iters=6)
    mse = float(pq.reconstruction_mse(cb, x))
    assert mse < float(jnp.mean(jnp.sum(x * x, axis=-1)))  # beats zero codes


def test_pq_adc_equals_decoded_inner_product():
    """Eq. 4: ADC score == ⟨q, decode(code)⟩ exactly."""
    key = jax.random.key(5)
    x = jax.random.normal(key, (400, 32))
    q = jax.random.normal(jax.random.fold_in(key, 1), (8, 32))
    cb = pq.train_pq(jax.random.fold_in(key, 2), x, m=4, k=16, n_iters=5)
    codes = pq.pq_encode(cb, x)
    lut = pq.adc_lut(cb, q)
    cand = jnp.broadcast_to(jnp.arange(50)[None], (8, 50))
    scores = pq.adc_score(lut, codes[cand])
    expect = q @ pq.pq_decode(cb, codes[:50]).T
    np.testing.assert_allclose(np.asarray(scores), np.asarray(expect),
                               rtol=1e-4, atol=1e-4)


def test_opq_rotation_is_orthogonal_and_helps():
    key = jax.random.key(6)
    # anisotropic data — the regime OPQ exists for
    scales = jnp.concatenate([jnp.ones(4) * 4.0, jnp.ones(28) * 0.3])
    x = jax.random.normal(key, (1500, 32)) * scales
    o = pq.train_opq(jax.random.fold_in(key, 1), x, m=4, k=16,
                      n_outer=3, n_kmeans_iters=5)
    r = np.asarray(o.rotation)
    np.testing.assert_allclose(r @ r.T, np.eye(32), atol=1e-4)
    cb = pq.train_pq(jax.random.fold_in(key, 2), x, m=4, k=16, n_iters=5)
    assert float(pq.opq_reconstruction_mse(o, x)) <= \
        float(pq.reconstruction_mse(cb, x)) * 1.05


# --------------------------------------------------------------------------
# bm25 / term selection
# --------------------------------------------------------------------------

def _toy_corpus():
    # doc0 repeats term 7; term 9 appears only in doc1 (high IDF)
    return jnp.array([[7, 7, 7, 1, 2, -1],
                      [9, 1, 2, 3, -1, -1],
                      [1, 2, 3, 4, 5, 6]], jnp.int32)


def test_bm25_idf_favors_rare_terms():
    toks = _toy_corpus()
    stats = bm25.fit(toks, vocab_size=16)
    idf = np.asarray(stats.idf)
    assert idf[9] > idf[1]          # term 9 in 1 doc, term 1 in 3 docs
    assert idf[9] > idf[2]


def test_bm25_tf_saturation():
    """Repeats help sub-linearly (the BM25 point)."""
    toks = _toy_corpus()
    stats = bm25.fit(toks, vocab_size=16)
    s = np.asarray(bm25.score_positions(toks, stats))
    one_seven = s[0][np.asarray(toks[0]) == 7][0]
    # score of tf=3 occurrence < 3× a hypothetical tf=1 score
    toks1 = toks.at[0, 1].set(10).at[0, 2].set(11)
    s1 = np.asarray(bm25.score_positions(toks1, bm25.fit(toks1, 16)))
    one_seven_tf1 = s1[0][np.asarray(toks1[0]) == 7][0]
    assert one_seven < 3 * one_seven_tf1


def test_first_occurrence_and_top_terms():
    toks = _toy_corpus()
    first = np.asarray(bm25.first_occurrence_mask(toks))
    assert first[0].tolist() == [True, False, False, True, True, False]
    stats = bm25.fit(toks, vocab_size=16)
    scores = bm25.score_positions(toks, stats)
    ids, sc = bm25.top_terms(toks, scores, k=2)
    assert ids.shape == (3, 2)
    # every selected term actually occurs in its doc
    for i in range(3):
        for t in np.asarray(ids[i]):
            if t != bm25.PAD_ID:
                assert t in np.asarray(toks[i])


def test_score_vector_max_pools_repeats():
    toks = jnp.array([[5, 5, -1]], jnp.int32)
    pos = jnp.array([[2.0, 3.0, 0.0]])
    v = bm25.score_vector(toks, pos, vocab_size=8)
    assert float(v[0, 5]) == 3.0
    assert float(v[0].sum()) == 3.0


def test_query_terms_short_query_selects_all():
    """Eq. 8: |Q| ≤ K₂ᵀ → all unique terms dispatched."""
    sel = ts.TermSelector(avg_scores=jnp.arange(16, dtype=jnp.float32))
    q = jnp.array([[3, 5, -1, -1]], jnp.int32)
    out = np.asarray(ts.query_terms(sel, q, k2=8))
    assert set(out[0]) - {-1} == {3, 5}


def test_query_terms_long_query_selects_top_sbar():
    sel = ts.TermSelector(avg_scores=jnp.arange(16, dtype=jnp.float32))
    q = jnp.array([[1, 9, 3, 14, 2, 7]], jnp.int32)
    out = np.asarray(ts.query_terms(sel, q, k2=3))
    assert set(out[0]) == {14, 9, 7}     # top-3 by s̄


# --------------------------------------------------------------------------
# inverted lists / pruning
# --------------------------------------------------------------------------

@given(n=st.integers(20, 300), n_lists=st.integers(2, 20),
       cap=st.integers(1, 16))
def test_build_respects_capacity_and_membership(n, n_lists, cap):
    rng = np.random.default_rng(n)
    docs = rng.integers(0, 10_000, n)
    lists = rng.integers(0, n_lists, n)
    scores = rng.normal(size=n)
    pl = il.build(docs, lists, scores, n_lists=n_lists, capacity=cap)
    assert pl.entries.shape == (n_lists, cap)
    e = np.asarray(pl.entries)
    lengths = np.asarray(pl.lengths)
    for li in range(n_lists):
        members = set(docs[lists == li].tolist())
        stored = [d for d in e[li] if d != il.PAD_DOC]
        assert len(stored) == min(len(docs[lists == li]), cap) == lengths[li]
        assert set(stored) <= members
        # kept entries are the top-scored ones
        if len(docs[lists == li]) > cap:
            kept_scores = sorted(scores[lists == li])[-cap:]
            got = sorted(scores[(lists == li) & np.isin(docs, stored)])[-cap:]
            np.testing.assert_allclose(got, kept_scores)


def test_dedup_mask_keeps_exactly_first_occurrences():
    cands = jnp.array([[3, 5, 3, -1, 5, 7]], jnp.int32)
    keep = np.asarray(il.dedup_mask(cands))[0]
    kept = np.asarray(cands)[0][keep]
    assert sorted(kept.tolist()) == [3, 5, 7]


def test_pruning_truncates_to_percentile():
    rng = np.random.default_rng(0)
    docs = np.arange(1000)
    lists = np.concatenate([np.zeros(500, int), rng.integers(1, 50, 500)])
    pl = il.build(docs, lists, rng.normal(size=1000), n_lists=50)
    pruned = pruning.prune_percentile(pl, gamma=0.9)
    assert pruned.capacity < pl.capacity
    assert int(np.asarray(pruned.lengths).max()) <= pruned.capacity


# --------------------------------------------------------------------------
# cluster selector
# --------------------------------------------------------------------------

def test_cluster_selector_doc_goes_to_argmax():
    key = jax.random.key(8)
    docs = jax.random.normal(key, (200, 16))
    sel, assign = cs.init_kmeans(jax.random.key(9), docs, n_clusters=8,
                                 n_iters=5)
    s = np.asarray(cs.scores(sel, docs))
    np.testing.assert_array_equal(np.asarray(assign), s.argmax(axis=1))
    top_i, top_s = cs.select_for_query(sel, docs[:10], k=3)
    assert top_i.shape == (10, 3)
    np.testing.assert_array_equal(np.asarray(top_i[:, 0]),
                                  s[:10].argmax(axis=1))
