"""Distillation mechanics (paper §4.3, DESIGN.md §15): loss structure,
gradient routing, negative mining, the fault-tolerant training loop,
and the supervised selectors' serving/lifecycle contracts — the full
quality run lives in benchmarks/sup_distill.py."""
import functools
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import distill, term_selector as ts_mod
from repro.data import synthetic
from repro.models import transformer as tfm
from repro.optim import AdamConfig, adam_init, adam_update

_ENV = dict(os.environ,
            XLA_FLAGS="--xla_force_host_platform_device_count=2",
            PYTHONPATH=os.environ.get("PYTHONPATH", "src"))


@functools.lru_cache(maxsize=1)
def _setup():
    corpus = synthetic.generate(seed=0, n_docs=800, n_queries=64,
                                hidden=32, vocab_size=512, n_topics=16,
                                make_model_b=False)
    enc_cfg = tfm.TransformerConfig(n_layers=1, d_model=32, n_heads=2,
                                    n_kv_heads=2, d_ff=64,
                                    vocab_size=corpus.vocab_size,
                                    causal=False,
                                    compute_dtype=jnp.float32, remat=False)
    k1, k2, k3 = jax.random.split(jax.random.key(0), 3)
    from repro.core import cluster_selector as cs_mod
    sel, assign = cs_mod.init_kmeans(k1, jnp.asarray(corpus.doc_emb), 16,
                                     n_iters=5)
    params = distill.DistillParams(
        cluster_embeddings=sel.embeddings,
        term_mlp=ts_mod.init_mlp(k2, 32),
        encoder=tfm.init(k3, enc_cfg))

    def encoder_apply(p, toks):
        hidden, _ = tfm.encode(p, enc_cfg, toks)
        return hidden

    rng = np.random.default_rng(0)
    qi = rng.integers(0, 64, 16)
    negs = rng.integers(0, 800, (16, 4))
    cand = np.concatenate([corpus.qrels[qi][:, None], negs], axis=1)
    batch = distill.DistillBatch(
        query_emb=jnp.asarray(corpus.query_emb[qi]),
        query_tokens=jnp.asarray(corpus.query_tokens[qi]),
        doc_emb=jnp.asarray(corpus.doc_emb[cand]),
        doc_tokens=jnp.asarray(corpus.doc_tokens[cand]),
        doc_assign=jnp.asarray(np.asarray(assign)[cand]))
    return corpus, params, batch, encoder_apply


# --------------------------------------------------------------------------
# loss structure (Eq. 9-13 + §15 refine term)
# --------------------------------------------------------------------------

def test_distill_loss_components_finite_and_positive():
    corpus, params, batch, enc = _setup()
    loss, aux = distill.loss_fn(params, batch, encoder_apply=enc,
                                vocab_size=corpus.vocab_size)
    assert np.isfinite(float(loss))
    for k in ("kl_cluster", "kl_term", "commit", "kl_refine"):
        assert np.isfinite(float(aux[k]))
        assert float(aux[k]) >= 0 or k == "commit"  # KL ≥ 0


def test_kl_nonnegative_and_exactly_zero_at_equal():
    k1, k2 = jax.random.split(jax.random.key(3))
    p = jax.random.normal(k1, (8, 12)) * 3.0
    q = jax.random.normal(k2, (8, 12)) * 3.0
    assert float(distill.kl(p, q).min()) >= 0.0
    # KL(p ∥ p) is identically zero — logp - logq cancels exactly, not
    # just to float tolerance
    np.testing.assert_array_equal(np.asarray(distill.kl(p, p)),
                                  np.zeros(8, np.float32))


def test_commit_loss_is_strictly_positive_nll():
    """Eq. 13 as minimized here is a negative log-softmax over L > 1
    clusters — strictly positive for any finite logits (the paper
    writes the raw log-softmax; sign convention is in the docstring)."""
    corpus, params, batch, enc = _setup()
    _, aux = distill.loss_fn(params, batch, encoder_apply=enc,
                             vocab_size=corpus.vocab_size)
    assert float(aux["commit"]) > 0.0


def test_teacher_is_fixed_point_of_perfect_student():
    """If the cluster embedding of every doc equals the doc embedding,
    KL(teacher ∥ CS) is exactly zero (sanity of Eq. 10/11)."""
    corpus, params, batch, enc = _setup()
    teacher = jnp.einsum("bh,bdh->bd", batch.query_emb, batch.doc_emb)
    cs = distill.kl(teacher, teacher)
    np.testing.assert_allclose(np.asarray(cs), 0.0, atol=1e-6)


def test_refine_term_composes_linearly():
    """refine_weight=0 reproduces the pre-§15 objective exactly, and
    the weighted total is base + λ·KL(Θ ∥ CS+TS)."""
    corpus, params, batch, enc = _setup()
    l0, aux0 = distill.loss_fn(params, batch, encoder_apply=enc,
                               vocab_size=corpus.vocab_size,
                               refine_weight=0.0)
    base = aux0["kl_cluster"] + aux0["kl_term"] + aux0["commit"]
    np.testing.assert_allclose(float(l0), float(base), rtol=1e-6)
    assert float(aux0["kl_refine"]) >= 0.0
    l5, aux5 = distill.loss_fn(params, batch, encoder_apply=enc,
                               vocab_size=corpus.vocab_size,
                               refine_weight=0.5)
    np.testing.assert_allclose(float(l5),
                               float(l0) + 0.5 * float(aux5["kl_refine"]),
                               rtol=1e-6)


def test_loss_invariant_under_batch_row_permutation():
    """Every loss component is a mean over query rows, so reordering
    the batch cannot change the objective (up to summation order)."""
    corpus, params, batch, enc = _setup()
    perm = np.random.default_rng(7).permutation(batch.query_emb.shape[0])
    shuffled = distill.DistillBatch(*[jnp.asarray(np.asarray(f)[perm])
                                      for f in batch])
    l0, _ = distill.loss_fn(params, batch, encoder_apply=enc,
                            vocab_size=corpus.vocab_size,
                            refine_weight=0.3)
    l1, _ = distill.loss_fn(params, shuffled, encoder_apply=enc,
                            vocab_size=corpus.vocab_size,
                            refine_weight=0.3)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-5)


# --------------------------------------------------------------------------
# gradient routing
# --------------------------------------------------------------------------

def _gnorm(tree) -> float:
    return float(jnp.sqrt(sum(jnp.sum(jnp.square(l)) for l in
                              jax.tree_util.tree_leaves(tree))))


def test_gradients_flow_to_all_three_param_groups():
    corpus, params, batch, enc = _setup()
    grads = jax.grad(lambda p: distill.loss_fn(
        p, batch, encoder_apply=enc, vocab_size=corpus.vocab_size,
        refine_weight=0.5)[0])(params)
    assert _gnorm(grads.cluster_embeddings) > 0
    assert _gnorm(grads.term_mlp) > 0
    assert _gnorm(grads.encoder) > 0


def test_zero_gradient_through_teacher_override():
    """Θ is frozen by definition (Eq. 10): the loss must carry no
    gradient into whatever computed the teacher scores."""
    corpus, params, batch, enc = _setup()
    teacher = distill.teacher_scores(batch)
    g = jax.grad(lambda t: distill.loss_fn(
        params, batch, encoder_apply=enc, vocab_size=corpus.vocab_size,
        refine_weight=0.5, teacher=t)[0])(teacher)
    np.testing.assert_array_equal(np.asarray(g),
                                  np.zeros_like(np.asarray(g)))


def test_distill_short_training_reduces_loss():
    corpus, params, batch, enc = _setup()

    def loss_fn(p, b):
        return distill.loss_fn(p, b, encoder_apply=enc,
                               vocab_size=corpus.vocab_size)

    state = adam_init(params)
    l0 = float(loss_fn(params, batch)[0])
    step = jax.jit(lambda p, s: _step(p, s, loss_fn, batch))
    for _ in range(15):
        params, state = step(params, state)
    l1 = float(loss_fn(params, batch)[0])
    assert l1 < l0, (l0, l1)


def _step(p, s, loss_fn, batch):
    (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(p, batch)
    return adam_update(g, s, p, AdamConfig(lr=1e-3))


# --------------------------------------------------------------------------
# negative mining (§15)
# --------------------------------------------------------------------------

def test_sample_candidates_puts_positive_first():
    pos = jnp.asarray(np.arange(6, dtype=np.int32) * 5)
    cand = distill.sample_candidates(jax.random.key(0), pos, 100, 4)
    assert cand.shape == (6, 5)
    np.testing.assert_array_equal(np.asarray(cand[:, 0]), np.asarray(pos))


def test_in_batch_negatives_are_other_rows_positives():
    rng = np.random.default_rng(0)
    pos = np.arange(8, dtype=np.int32) * 3       # distinct per row
    cand = np.concatenate([pos[:, None],
                           rng.integers(100, 200, (8, 4))], axis=1)
    out = distill.add_in_batch_negatives(rng, cand, pos, 3)
    assert out.shape == (8, 8)
    np.testing.assert_array_equal(out[:, :5], cand)
    for b in range(8):
        added = out[b, 5:]
        assert np.all(np.isin(added, pos)), added
        assert not np.any(added == pos[b]), "row sampled its own positive"
    # n_inbatch=0 is the identity
    np.testing.assert_array_equal(
        distill.add_in_batch_negatives(rng, cand, pos, 0), cand)


def test_in_batch_negatives_reject_singleton_batch():
    rng = np.random.default_rng(0)
    with pytest.raises(ValueError, match="batch of >= 2"):
        distill.add_in_batch_negatives(rng, np.zeros((1, 3), np.int32),
                                       np.zeros(1, np.int32), 2)


def test_mine_hard_negatives_excludes_positives_and_pads():
    from repro.core import hybrid_index as hi
    corpus, *_ = _setup()
    index = hi.build(jax.random.key(0), jnp.asarray(corpus.doc_emb),
                     jnp.asarray(corpus.doc_tokens), corpus.vocab_size,
                     n_clusters=16, k1_terms=6, pq_m=4, pq_k=32,
                     kmeans_iters=4)
    mined = distill.mine_hard_negatives(index, corpus.query_emb,
                                        corpus.query_tokens, corpus.qrels,
                                        6)
    assert mined.shape == (corpus.query_emb.shape[0], 6)
    assert mined.min() >= 0 and mined.max() < corpus.doc_emb.shape[0]
    for i in range(mined.shape[0]):
        assert corpus.qrels[i] not in mined[i], i
    # deterministic: same index + seed → same pool
    again = distill.mine_hard_negatives(index, corpus.query_emb,
                                        corpus.query_tokens, corpus.qrels,
                                        6)
    np.testing.assert_array_equal(mined, again)


# --------------------------------------------------------------------------
# the fit() loop: resume + observer-only monitoring
# --------------------------------------------------------------------------

def _quadratic_problem():
    from repro.launch import train as tr
    params = {"w": jnp.zeros(4, jnp.float32),
              "b": jnp.ones(2, jnp.float32)}

    def loss_fn(p, batch):
        target, scale = batch
        l = jnp.sum((p["w"] - target) ** 2) + scale * jnp.sum(p["b"] ** 2)
        return l, {"loss": l}

    def batches(i):
        rng = np.random.default_rng(i)
        return (jnp.asarray(rng.normal(size=4), jnp.float32),
                jnp.float32(1.0 + 0.1 * (i % 3)))

    return tr, loss_fn, params, batches


def test_fit_checkpoint_resume_bit_identical(tmp_path):
    """Kill at step k, resume from the checkpoint, land on exactly the
    params an uninterrupted run produces — resume restores params AND
    optimizer state, and the step-keyed batch stream replays."""
    tr, loss_fn, params, batches = _quadratic_problem()
    straight, _ = tr.fit(loss_fn, params, batches, 12, log_every=0)

    ckpt = str(tmp_path / "ckpt")
    tr.fit(loss_fn, params, batches, 5, ckpt_dir=ckpt, save_every=5,
           log_every=0)                                   # "killed" at 5
    resumed, losses = tr.fit(loss_fn, params, batches, 12, ckpt_dir=ckpt,
                             save_every=5, log_every=0)
    assert len(losses) == 12 - 5, "resume must continue, not restart"
    for k in params:
        np.testing.assert_array_equal(np.asarray(straight[k]),
                                      np.asarray(resumed[k]))


def test_straggler_monitor_does_not_perturb_training():
    """The monitor observes wall-clock only — any monitor (or none)
    leaves the numeric trajectory bit-identical."""
    from repro.distributed.fault import StragglerMonitor
    tr, loss_fn, params, batches = _quadratic_problem()
    p_none, l_none = tr.fit(loss_fn, params, batches, 8, log_every=0)
    p_mon, l_mon = tr.fit(loss_fn, params, batches, 8, log_every=0,
                          monitor=StragglerMonitor(window=4, factor=1.0,
                                                   max_strikes=1))
    assert l_none == l_mon
    for k in params:
        np.testing.assert_array_equal(np.asarray(p_none[k]),
                                      np.asarray(p_mon[k]))


# --------------------------------------------------------------------------
# supervised selectors: serving variants + mutable lifecycle (§15)
# --------------------------------------------------------------------------

def test_mutable_sup_selectors_survive_add_delete_compact():
    """A MutableHybridIndex built from SupSelectors accepts streamed
    docs and deletes, and compact() is bit-identical to a from-scratch
    supervised build over the survivors (the §10 contract under
    learned selectors)."""
    from repro.core import hybrid_index as hi, segments as seg
    from repro.launch import train as tr
    corpus, params, _, _ = _setup()
    enc_cfg = tfm.TransformerConfig(n_layers=1, d_model=32, n_heads=2,
                                    n_kv_heads=2, d_ff=64,
                                    vocab_size=corpus.vocab_size,
                                    causal=False,
                                    compute_dtype=jnp.float32, remat=False)
    sel = tr.SupSelectors(params=params, enc_cfg=enc_cfg)
    kw = dict(k1_terms=6, pq_m=4, pq_k=32, delta_capacity=32)
    mut = seg.MutableHybridIndex.create(
        jax.random.key(0), corpus.doc_emb[:600], corpus.doc_tokens[:600],
        corpus.vocab_size, selectors=sel, **kw)
    assert mut.base.cluster_lists.n_lists == \
        params.cluster_embeddings.shape[0]
    ids = mut.add_docs(corpus.doc_emb[600:616], corpus.doc_tokens[600:616])
    mut.delete_docs(ids[:4])
    mut.delete_docs(np.arange(8))
    qe = jnp.asarray(corpus.query_emb[:16])
    qt = jnp.asarray(corpus.query_tokens[:16])
    assert mut.search(qe, qt, kc=4, k2=6, top_r=20).doc_ids.shape == (16, 20)

    comp = mut.compact()
    assert comp.n_docs == 600 + 16 - 12
    emb_s, tok_s = mut.surviving_corpus()
    scratch = seg.MutableHybridIndex.create(
        jax.random.key(0), emb_s, tok_s, corpus.vocab_size,
        selectors=sel, **kw)
    a = comp.search(qe, qt, kc=4, k2=6, top_r=20)
    b = scratch.search(qe, qt, kc=4, k2=6, top_r=20)
    np.testing.assert_array_equal(np.asarray(a.doc_ids),
                                  np.asarray(b.doc_ids))
    np.testing.assert_array_equal(np.asarray(a.scores),
                                  np.asarray(b.scores))


def test_mutable_sup_rejects_mismatched_cluster_count():
    from repro.core import segments as seg
    from repro.launch import train as tr
    corpus, params, _, _ = _setup()
    enc_cfg = tfm.TransformerConfig(n_layers=1, d_model=32, n_heads=2,
                                    n_kv_heads=2, d_ff=64,
                                    vocab_size=corpus.vocab_size,
                                    causal=False,
                                    compute_dtype=jnp.float32, remat=False)
    sel = tr.SupSelectors(params=params, enc_cfg=enc_cfg)
    with pytest.raises(ValueError, match="conflicts with the supervised"):
        seg.MutableHybridIndex.create(
            jax.random.key(0), corpus.doc_emb[:200],
            corpus.doc_tokens[:200], corpus.vocab_size, selectors=sel,
            n_clusters=8, k1_terms=6, pq_m=4, pq_k=32)


def test_mutable_sup_checkpoint_needs_selectors_on_restore(tmp_path):
    """Selector params live in the training checkpoint, not the index
    state tree — restoring a supervised mutable checkpoint without a
    selectors-bearing ``like`` must fail loudly (silent BM25 fallback
    would corrupt add/compact semantics)."""
    from repro import checkpoint as ckpt
    from repro.core import segments as seg
    from repro.launch import train as tr
    corpus, params, _, _ = _setup()
    enc_cfg = tfm.TransformerConfig(n_layers=1, d_model=32, n_heads=2,
                                    n_kv_heads=2, d_ff=64,
                                    vocab_size=corpus.vocab_size,
                                    causal=False,
                                    compute_dtype=jnp.float32, remat=False)
    sel = tr.SupSelectors(params=params, enc_cfg=enc_cfg)
    kw = dict(k1_terms=6, pq_m=4, pq_k=32, delta_capacity=16)
    mut = seg.MutableHybridIndex.create(
        jax.random.key(0), corpus.doc_emb[:300], corpus.doc_tokens[:300],
        corpus.vocab_size, selectors=sel, **kw)
    path = ckpt.save_mutable(str(tmp_path), 1, mut)

    bare = seg.MutableHybridIndex.create(
        jax.random.key(0), corpus.doc_emb[:300], corpus.doc_tokens[:300],
        corpus.vocab_size, selectors=sel, **kw)
    bare.selectors = None
    with pytest.raises(ValueError, match="supervised index"):
        ckpt.restore_mutable(path, bare)

    setattr(bare, "selectors", sel)
    restored = ckpt.restore_mutable(path, bare)
    assert restored.selectors is sel
    ids = restored.add_docs(corpus.doc_emb[300:302],
                            corpus.doc_tokens[300:302])
    assert ids.shape == (2,)


def test_sup_index_bit_identical_across_all_four_variants():
    """The trained selector bundle serves identically through every
    layout: plain == sharded == mutable(empty delta) == sharded-mutable
    doc ids (2 emulated devices; the tests/test_exec.py pattern)."""
    script = """
import jax, jax.numpy as jnp, numpy as np
assert jax.device_count() == 2
from repro.core import hybrid_index as hi, segments as seg
from repro.data import synthetic
from repro.launch import serve, train as tr

corpus = synthetic.generate(seed=0, n_docs=600, n_queries=32, hidden=32,
                            vocab_size=512, n_topics=16,
                            make_model_b=False)
cfg = tr.SupTrainConfig(n_clusters=16, encoder_layers=1, encoder_dim=32,
                        encoder_heads=2, n_steps=10, batch_queries=8,
                        n_negatives=3, kmeans_iters=4, seed=0)
params, enc_cfg, assign, _ = tr.train_hi2_sup(corpus, cfg, log_every=0)
sel = tr.SupSelectors(params=params, enc_cfg=enc_cfg)
kw = dict(k1_terms=6, pq_m=4, pq_k=32, codec="pq")
sel_kwargs = sel.build_inputs(jnp.asarray(corpus.doc_emb),
                              jnp.asarray(corpus.doc_tokens),
                              corpus.vocab_size)
base = hi.build(jax.random.key(0), jnp.asarray(corpus.doc_emb),
                jnp.asarray(corpus.doc_tokens), corpus.vocab_size,
                n_clusters=16, **sel_kwargs, **kw)
qe, qt = jnp.asarray(corpus.query_emb), jnp.asarray(corpus.query_tokens)
ref = np.asarray(hi.search(base, qe, qt, kc=4, k2=6, top_r=20).doc_ids)

sh = serve.make_server(base, serve.ServeConfig(kc=4, k2=6, top_r=20,
                                               max_batch=32, n_shards=2))
assert np.array_equal(
    np.asarray(sh.query(corpus.query_emb, corpus.query_tokens).doc_ids),
    ref), "sharded != plain"

mut = seg.MutableHybridIndex.create(
    jax.random.key(0), corpus.doc_emb, corpus.doc_tokens,
    corpus.vocab_size, selectors=sel, delta_capacity=32, **kw)
assert np.array_equal(
    np.asarray(mut.search(qe, qt, kc=4, k2=6, top_r=20).doc_ids), ref), \
    "mutable != plain"

mut2 = seg.MutableHybridIndex.create(
    jax.random.key(0), corpus.doc_emb, corpus.doc_tokens,
    corpus.vocab_size, selectors=sel, delta_capacity=32, **kw)
sm = serve.make_mutable_server(mut2, serve.ServeConfig(
    kc=4, k2=6, top_r=20, max_batch=32, n_shards=2, mutable=True,
    delta_capacity=32))
assert np.array_equal(
    np.asarray(sm.query(corpus.query_emb, corpus.query_tokens).doc_ids),
    ref), "sharded-mutable != plain"
print("OK")
"""
    r = subprocess.run([sys.executable, "-c", script], env=_ENV,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "OK" in r.stdout
