"""Distillation mechanics (paper §4.3): loss structure, gradients, and
short-horizon improvement — the full quality run lives in benchmarks."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distill, term_selector as ts_mod
from repro.data import synthetic
from repro.models import transformer as tfm
from repro.optim import AdamConfig, adam_init, adam_update


def _setup():
    corpus = synthetic.generate(seed=0, n_docs=800, n_queries=64,
                                hidden=32, vocab_size=512, n_topics=16,
                                make_model_b=False)
    enc_cfg = tfm.TransformerConfig(n_layers=1, d_model=32, n_heads=2,
                                    n_kv_heads=2, d_ff=64,
                                    vocab_size=corpus.vocab_size,
                                    causal=False,
                                    compute_dtype=jnp.float32, remat=False)
    k1, k2, k3 = jax.random.split(jax.random.key(0), 3)
    from repro.core import cluster_selector as cs_mod
    sel, assign = cs_mod.init_kmeans(k1, jnp.asarray(corpus.doc_emb), 16,
                                     n_iters=5)
    params = distill.DistillParams(
        cluster_embeddings=sel.embeddings,
        term_mlp=ts_mod.init_mlp(k2, 32),
        encoder=tfm.init(k3, enc_cfg))

    def encoder_apply(p, toks):
        hidden, _ = tfm.encode(p, enc_cfg, toks)
        return hidden

    rng = np.random.default_rng(0)
    qi = rng.integers(0, 64, 16)
    negs = rng.integers(0, 800, (16, 4))
    cand = np.concatenate([corpus.qrels[qi][:, None], negs], axis=1)
    batch = distill.DistillBatch(
        query_emb=jnp.asarray(corpus.query_emb[qi]),
        query_tokens=jnp.asarray(corpus.query_tokens[qi]),
        doc_emb=jnp.asarray(corpus.doc_emb[cand]),
        doc_tokens=jnp.asarray(corpus.doc_tokens[cand]),
        doc_assign=jnp.asarray(np.asarray(assign)[cand]))
    return corpus, params, batch, encoder_apply


def test_distill_loss_components_finite_and_positive():
    corpus, params, batch, enc = _setup()
    loss, aux = distill.loss_fn(params, batch, encoder_apply=enc,
                                vocab_size=corpus.vocab_size)
    assert np.isfinite(float(loss))
    for k in ("kl_cluster", "kl_term", "commit"):
        assert np.isfinite(float(aux[k]))
        assert float(aux[k]) >= 0 or k == "commit"  # KL ≥ 0


def test_distill_short_training_reduces_loss():
    corpus, params, batch, enc = _setup()

    def loss_fn(p, b):
        return distill.loss_fn(p, b, encoder_apply=enc,
                               vocab_size=corpus.vocab_size)

    state = adam_init(params)
    l0 = float(loss_fn(params, batch)[0])
    step = jax.jit(lambda p, s: _step(p, s, loss_fn, batch))
    for _ in range(15):
        params, state = step(params, state)
    l1 = float(loss_fn(params, batch)[0])
    assert l1 < l0, (l0, l1)


def _step(p, s, loss_fn, batch):
    (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(p, batch)
    return adam_update(g, s, p, AdamConfig(lr=1e-3))


def test_teacher_is_fixed_point_of_perfect_student():
    """If the cluster embedding of every doc equals the doc embedding,
    KL(teacher ∥ CS) is exactly zero (sanity of Eq. 10/11)."""
    corpus, params, batch, enc = _setup()
    b, d, _ = batch.doc_emb.shape
    perfect = distill.DistillParams(
        cluster_embeddings=jnp.zeros_like(params.cluster_embeddings),
        term_mlp=params.term_mlp, encoder=params.encoder)
    teacher = jnp.einsum("bh,bdh->bd", batch.query_emb, batch.doc_emb)
    cs = distill.kl(teacher, teacher)
    np.testing.assert_allclose(np.asarray(cs), 0.0, atol=1e-6)
