"""Distributed-correctness tests. These need >1 device, so each spawns a
fresh interpreter with xla_force_host_platform_device_count set —
keeping the main pytest process at 1 device (per the brief, smoke tests
must see a single device)."""
import os
import subprocess
import sys

import pytest

_ENV = dict(os.environ,
            XLA_FLAGS="--xla_force_host_platform_device_count=8",
            PYTHONPATH=os.environ.get("PYTHONPATH", "src"))


def _run(script: str) -> None:
    r = subprocess.run([sys.executable, "-c", script], env=_ENV,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"


def test_sharded_kmeans_matches_psum_semantics():
    _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core import kmeans
from repro.distributed import compat
from repro.distributed.compat import shard_map
mesh = compat.make_mesh((8,), ("data",))
x = jax.random.normal(jax.random.key(0), (1024, 16))

fit = shard_map(
    lambda xl: kmeans.kmeans_fit_sharded(jax.random.key(1), xl, 8, n_iters=5),
    mesh=mesh, in_specs=P("data"), out_specs=P())
c_sharded = fit(x)
assert c_sharded.shape == (8, 16)
# cost must beat random init cost (learning happened across shards)
a = kmeans.assign_blocked(x, c_sharded)
cost = float(kmeans.kmeans_cost(x, c_sharded, a))
c0 = x[:8]
cost0 = float(kmeans.kmeans_cost(x, c0, kmeans.assign_blocked(x, c0)))
assert cost < cost0, (cost, cost0)
""")


def test_hierarchical_allreduce_equals_flat():
    _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.distributed import collectives, compat
from repro.distributed.compat import shard_map
mesh = compat.make_mesh((2, 4), ("pod", "data"))
g = {"w": jax.random.normal(jax.random.key(0), (16, 8)),
     "b": jax.random.normal(jax.random.key(1), (5,))}   # 5 not divisible by 4

flat = shard_map(
    lambda t: collectives.flat_allreduce(t, ("data", "pod")),
    mesh=mesh, in_specs=P(("pod", "data")), out_specs=P())
hier = shard_map(
    lambda t: collectives.hierarchical_allreduce(t),
    mesh=mesh, in_specs=P(("pod", "data")), out_specs=P(),
    check=False)  # RS->AR->AG reconstructs replication; not inferable

gs = {"w": jnp.tile(g["w"], (8, 1)), "b": jnp.tile(g["b"], 8)}
a = flat({"w": gs["w"], "b": gs["b"]})
b = hier({"w": gs["w"], "b": gs["b"]})
np.testing.assert_allclose(np.asarray(a["w"]), np.asarray(b["w"]), rtol=1e-5)
np.testing.assert_allclose(np.asarray(a["b"]), np.asarray(b["b"]), rtol=1e-5)
""")


def test_sharded_hi2_search_matches_single_device():
    """Index-parallel serving: query-sharded search over the mesh equals
    the single-device result (the paper's serving layout, DESIGN.md §2)."""
    _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core import hybrid_index as hi
from repro.data import synthetic
from repro.distributed import compat, sharding as shd

corpus = synthetic.generate(seed=0, n_docs=4000, n_queries=128,
                            hidden=32, vocab_size=2048, n_topics=32)
idx = hi.build(jax.random.key(0), jnp.asarray(corpus.doc_emb),
               jnp.asarray(corpus.doc_tokens), corpus.vocab_size,
               n_clusters=64, k1_terms=8, codec="opq", pq_m=4, pq_k=64,
               cluster_capacity=128, term_capacity=64, kmeans_iters=5)
qe, qt = jnp.asarray(corpus.query_emb), jnp.asarray(corpus.query_tokens)
ref = hi.search(idx, qe, qt, kc=4, k2=4, top_r=20)

mesh = compat.make_mesh((8,), ("data",))
with shd.use_mesh(mesh, {"batch": "data"}):
    qe_s = jax.device_put(qe, NamedSharding(mesh, P("data")))
    qt_s = jax.device_put(qt, NamedSharding(mesh, P("data")))
    out = hi.search(idx, qe_s, qt_s, kc=4, k2=4, top_r=20)
np.testing.assert_array_equal(np.asarray(ref.doc_ids), np.asarray(out.doc_ids))
""")


def test_dryrun_entrypoint_single_cell():
    """The actual dryrun module runs end-to-end for one cheap cell (with a
    reduced device count via env to keep CI fast)."""
    env = dict(os.environ, PYTHONPATH=os.environ.get("PYTHONPATH", "src"))
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "sasrec",
         "--shape", "serve_p99", "--out", "/tmp/dryrun_test"],
        env=env, capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "ok" in r.stdout
