"""Documentation integrity: the docs spine stays navigable as the
system grows.

  · every ``§N`` cross-reference anywhere in the repo resolves to a
    ``## §N`` heading in DESIGN.md (the ISSUE-5 re-anchor check);
  · no retired module path (the pre-codec ``core/pq`` / ``core/opq`` /
    ``core/ivf`` / ``core/flat`` files, folded into ``core/codecs`` and
    ``hybrid_index`` by PR 4) is referenced anywhere outside the
    CHANGES.md history log;
  · every path named in the README "Repository map" exists on disk.
"""
import pathlib
import re

_ROOT = pathlib.Path(__file__).resolve().parent.parent

#: files whose references are historical records or work orders, not
#: live pointers into the tree
_HISTORY = {"CHANGES.md", "ISSUE.md"}

#: module paths retired by PR 4 (their code lives in core/codecs and
#: hybrid_index now) — referencing them anywhere is a stale doc
_RETIRED = ("core/pq.py", "core/opq.py", "core/ivf.py", "core/flat.py",
            "core.pq", "core.opq", "core.ivf")


def _repo_files(*suffixes):
    for p in sorted(_ROOT.rglob("*")):
        if p.suffix not in suffixes or not p.is_file():
            continue
        rel = p.relative_to(_ROOT).as_posix()
        if any(part in ("__pycache__", ".git", "ci_results", ".venv",
                        "venv", "build", "dist", ".eggs", "node_modules")
               for part in p.parts):
            continue
        yield rel, p.read_text()


def test_every_section_reference_resolves():
    design = (_ROOT / "DESIGN.md").read_text()
    headings = {int(m) for m in re.findall(r"^## §(\d+)", design, re.M)}
    assert headings, "DESIGN.md lost its ## §N headings"
    dangling = []
    for rel, text in _repo_files(".py", ".md"):
        for n in {int(m) for m in re.findall(r"§(\d+)", text)}:
            if n not in headings:
                dangling.append((rel, f"§{n}"))
    assert not dangling, (
        f"cross-references to missing DESIGN.md sections: {dangling}")


def test_no_retired_module_referenced():
    offenders = []
    this = pathlib.Path(__file__).name
    for rel, text in _repo_files(".py", ".md"):
        if rel.rsplit("/", 1)[-1] in _HISTORY | {this}:
            continue
        for stale in _RETIRED:
            if stale in text:
                offenders.append((rel, stale))
    assert not offenders, (
        f"retired pre-codec modules referenced: {offenders}")


def test_readme_repository_map_paths_exist():
    readme = (_ROOT / "README.md").read_text()
    m = re.search(r"## Repository map\s+```(.*?)```", readme, re.S)
    assert m, "README.md lost its Repository map section"
    missing = []
    for line in m.group(1).splitlines():
        # the path column starts each entry; indented lines are
        # description continuations
        if not line or line[0].isspace():
            continue
        path = line.split()[0]
        if "/" not in path:
            continue
        if not (_ROOT / path.rstrip("/")).exists():
            missing.append(path)
    assert not missing, f"Repository map names missing paths: {missing}"
