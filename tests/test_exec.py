"""The query-execution layer (DESIGN.md §9): cross-variant equivalence,
namespace filters, and the one-pipeline acceptance criterion.

The headline suite replaces the per-variant bit-identity copies that
used to live in tests/test_sharded.py: ONE parametrized run asserts
that all four search variants — single-device, mutable (base + empty
delta), document-sharded (2 and 4 shards), and sharded-mutable — return
bit-identical ids/scores/candidate-counts on the same corpus for every
registered codec, WITH and WITHOUT a per-query namespace filter.

Multi-device cases spawn a fresh interpreter with
xla_force_host_platform_device_count (the tests/test_sharded.py
pattern); filter semantics and the exec-layer contract run in-process.
"""
import os
import pathlib
import re
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import exec as qexec, hybrid_index as hi
from repro.core import segments as seg
from repro.core.exec import filters as ns_filters
from repro.data import synthetic

_ENV = dict(os.environ,
            XLA_FLAGS="--xla_force_host_platform_device_count=4",
            PYTHONPATH=os.environ.get("PYTHONPATH", "src"))


def _run(script: str) -> None:
    r = subprocess.run([sys.executable, "-c", script], env=_ENV,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"


# --------------------------------------------------------------------------
# the cross-variant equivalence suite (tentpole acceptance)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("use_kernel", [False, True],
                         ids=["unfused", "kernel"])
def test_all_four_variants_bit_identical_every_codec_with_and_without_filter(
        use_kernel):
    """single == mutable(empty delta) == sharded(2,4) == sharded-mutable
    for every registered codec, unfiltered AND under a per-query
    namespace bitmap — the §9 'one engine' contract — on BOTH scoring
    paths.  Cross-variant equality is bitwise on each path (all four
    variants run the identical fused kernels, and per-candidate ADC
    accumulation order is blocking-independent).  The kernel path is
    then compared against the unfused path with tolerance: the fused
    kernels reduce the m fragments / h dims in a different order than
    the jnp oracle, so scores agree only to ~1e-4 (DESIGN.md §11
    documents the bound: |Δ| ≤ m·k·eps·Σ|lut| ≪ 1e-4 at test scale).
    Candidate counts stay bitwise equal across paths — dispatch ids and
    the live mask are reduction-order-free."""
    _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.core import codecs, hybrid_index as hi, segments as seg
from repro.core import sharded_index as shi
from repro.core.exec import filters as ns_filters
from repro.data import synthetic

UK = %r
assert jax.device_count() == 4
N_NS = 8
c = synthetic.generate(seed=0, n_docs=3001, n_queries=24, hidden=32,
                       vocab_size=1024, n_topics=16)
doc_ns = (np.arange(3001) * 7 %% N_NS).astype(np.int32)
kw = dict(n_clusters=32, k1_terms=6, pq_m=4, pq_k=64,
          cluster_capacity=96, term_capacity=48, kmeans_iters=5)
qe, qt = jnp.asarray(c.query_emb), jnp.asarray(c.query_tokens)
bitmap = ns_filters.make_filter(
    [[b %% N_NS, (b + 3) %% N_NS] for b in range(24)], N_NS)

def check(ref, out, err):
    np.testing.assert_array_equal(np.asarray(ref.doc_ids),
                                  np.asarray(out.doc_ids), err)
    np.testing.assert_array_equal(np.asarray(ref.scores),
                                  np.asarray(out.scores), err)
    np.testing.assert_array_equal(np.asarray(ref.n_candidates),
                                  np.asarray(out.n_candidates), err)

for codec in codecs.registered():
    idx = hi.build(jax.random.key(0), jnp.asarray(c.doc_emb),
                   jnp.asarray(c.doc_tokens), c.vocab_size, codec=codec,
                   doc_namespaces=doc_ns, **kw)
    mut = seg.MutableHybridIndex.create(
        jax.random.key(0), c.doc_emb, c.doc_tokens, c.vocab_size,
        delta_capacity=64, codec=codec, doc_namespaces=doc_ns, **kw)
    for filt in (None, bitmap):
        ref = hi.search(idx, qe, qt, kc=4, k2=4, top_r=20, filter=filt,
                        use_kernel=UK)
        err0 = (codec, filt is not None, UK)
        # variant 2: mutable, empty delta — the delta sources must be
        # bit-transparent
        check(ref, mut.search(qe, qt, kc=4, k2=4, top_r=20, filter=filt,
                              use_kernel=UK),
              ("mutable",) + err0)
        for n_shards in (2, 4):
            # variant 3: document-sharded
            mesh = shi.make_shard_mesh(n_shards)
            sidx = shi.device_put(shi.partition(idx, n_shards), mesh)
            check(ref, shi.search(sidx, qe, qt, kc=4, k2=4, top_r=20,
                                  mesh=mesh, filter=filt, use_kernel=UK),
                  ("sharded", n_shards) + err0)
            # variant 4: sharded-mutable
            smut = seg.ShardedMutableIndex(mut, n_shards)
            check(ref, smut.search(qe, qt, kc=4, k2=4, top_r=20,
                                   filter=filt, use_kernel=UK),
                  ("sharded-mutable", n_shards) + err0)
        if UK:
            # fused vs unfused: same dispatch/mask bitwise; selected
            # scores within the documented reduction-order bound
            ref0 = hi.search(idx, qe, qt, kc=4, k2=4, top_r=20,
                             filter=filt, use_kernel=False)
            np.testing.assert_array_equal(
                np.asarray(ref.n_candidates),
                np.asarray(ref0.n_candidates), err0)
            np.testing.assert_allclose(
                np.sort(np.asarray(ref.scores), axis=-1),
                np.sort(np.asarray(ref0.scores), axis=-1),
                rtol=1e-4, atol=1e-4, err_msg=str(err0))
        if filt is not None:
            ids = np.asarray(ref.doc_ids)
            for b in range(ids.shape[0]):
                row = ids[b][ids[b] >= 0]
                ok = np.isin(doc_ns[row], [b %% N_NS, (b + 3) %% N_NS])
                assert ok.all(), (codec, b, row[~ok])
""" % use_kernel)


def test_filtered_mutable_stream_bit_identical_sharded():
    """Filters over a *mutated* index (streamed adds with namespaces +
    tombstones): single-device mutable == 4-shard sharded-mutable, and
    isolation holds across base and delta docs."""
    _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.core import segments as seg
from repro.core.exec import filters as ns_filters
from repro.data import synthetic

N_NS = 4
c = synthetic.generate(seed=0, n_docs=1501, n_queries=16, hidden=32,
                       vocab_size=512, n_topics=8)
doc_ns = (np.arange(1501) % N_NS).astype(np.int32)
kw = dict(n_clusters=16, k1_terms=4, codec="refine:pq:2", pq_m=4,
          pq_k=64, cluster_capacity=64, term_capacity=32, kmeans_iters=3)
hold = 80
mut = seg.MutableHybridIndex.create(
    jax.random.key(0), c.doc_emb[:-hold], c.doc_tokens[:-hold],
    c.vocab_size, delta_capacity=100, doc_namespaces=doc_ns[:-hold], **kw)
ids = mut.add_docs(c.doc_emb[-hold:], c.doc_tokens[-hold:],
                   namespaces=doc_ns[-hold:])
mut.delete_docs(ids[:20]); mut.delete_docs([5, 6, 7])
qe, qt = jnp.asarray(c.query_emb), jnp.asarray(c.query_tokens)
bitmap = ns_filters.make_filter([b % N_NS for b in range(16)], N_NS)
ref = mut.search(qe, qt, kc=4, k2=4, top_r=15, filter=bitmap)
smut = seg.ShardedMutableIndex(mut, 4)
out = smut.search(qe, qt, kc=4, k2=4, top_r=15, filter=bitmap)
np.testing.assert_array_equal(np.asarray(ref.doc_ids),
                              np.asarray(out.doc_ids))
np.testing.assert_array_equal(np.asarray(ref.scores),
                              np.asarray(out.scores))
np.testing.assert_array_equal(np.asarray(ref.n_candidates),
                              np.asarray(out.n_candidates))
rids = np.asarray(ref.doc_ids)
for b in range(16):
    row = rids[b][rids[b] >= 0]
    assert (mut.namespaces_of(row) == b % N_NS).all(), (b, row)
    assert not np.isin(row, ids[:20]).any()     # tombstones still honored
""")


# --------------------------------------------------------------------------
# filter semantics (in-process, single device)
# --------------------------------------------------------------------------

def _small(codec="flat", n_ns=None):
    c = synthetic.generate(seed=0, n_docs=1200, n_queries=16, hidden=32,
                           vocab_size=512, n_topics=8)
    ns = None if n_ns is None else (np.arange(1200) % n_ns).astype(np.int32)
    idx = hi.build(jax.random.key(0), jnp.asarray(c.doc_emb),
                   jnp.asarray(c.doc_tokens), c.vocab_size,
                   n_clusters=16, k1_terms=4, codec=codec,
                   cluster_capacity=64, term_capacity=32, kmeans_iters=3,
                   doc_namespaces=ns)
    return c, idx, ns


def test_allow_all_filter_is_a_bitwise_noop():
    c, idx, _ = _small(n_ns=5)
    qe, qt = jnp.asarray(c.query_emb), jnp.asarray(c.query_tokens)
    ref = hi.search(idx, qe, qt, kc=4, k2=4, top_r=12)
    out = hi.search(idx, qe, qt, kc=4, k2=4, top_r=12,
                    filter=ns_filters.allow_all(16, 5))
    np.testing.assert_array_equal(np.asarray(ref.doc_ids),
                                  np.asarray(out.doc_ids))
    np.testing.assert_array_equal(np.asarray(ref.scores),
                                  np.asarray(out.scores))
    np.testing.assert_array_equal(np.asarray(ref.n_candidates),
                                  np.asarray(out.n_candidates))


def test_filtered_results_keep_unfiltered_scores_and_isolation():
    """Filtering masks candidates; it must not perturb the scores of
    the docs that survive, and every result obeys its query's bitmap."""
    c, idx, ns = _small(n_ns=3)
    qe, qt = jnp.asarray(c.query_emb), jnp.asarray(c.query_tokens)
    ref = hi.search(idx, qe, qt, kc=4, k2=4, top_r=64)
    out = hi.search(idx, qe, qt, kc=4, k2=4, top_r=64,
                    filter=ns_filters.make_filter([1] * 16, 3))
    rid, rsc = np.asarray(ref.doc_ids), np.asarray(ref.scores)
    oid, osc = np.asarray(out.doc_ids), np.asarray(out.scores)
    for b in range(16):
        keep = oid[b] >= 0
        assert (ns[oid[b][keep]] == 1).all()
        # surviving docs keep their exact unfiltered scores
        both = np.intersect1d(oid[b][keep], rid[b][rid[b] >= 0])
        r_lookup = dict(zip(rid[b], rsc[b]))
        o_lookup = dict(zip(oid[b], osc[b]))
        assert all(r_lookup[d] == o_lookup[d] for d in both)
        # and n_candidates shrank (a 1/3 filter must mask something)
    assert (np.asarray(out.n_candidates)
            < np.asarray(ref.n_candidates)).all()


def test_filter_without_namespace_planes_raises():
    c, idx, _ = _small(n_ns=None)
    qe, qt = jnp.asarray(c.query_emb), jnp.asarray(c.query_tokens)
    with pytest.raises(ValueError, match="doc_namespaces"):
        hi.search(idx, qe, qt, kc=4, k2=4, top_r=8,
                  filter=ns_filters.make_filter([0] * 16, 4))


def test_mutable_namespace_plumbing_validation():
    c = synthetic.generate(seed=0, n_docs=900, n_queries=4, hidden=32,
                           vocab_size=512, n_topics=8)
    kw = dict(n_clusters=16, k1_terms=4, codec="flat",
              cluster_capacity=64, term_capacity=32, kmeans_iters=3)
    plain = seg.MutableHybridIndex.create(
        jax.random.key(0), c.doc_emb[:-20], c.doc_tokens[:-20],
        c.vocab_size, delta_capacity=32, **kw)
    with pytest.raises(ValueError, match="unfiltered"):
        plain.add_docs(c.doc_emb[-2:], c.doc_tokens[-2:], namespaces=0)
    ns = np.zeros(880, np.int32)
    filt = seg.MutableHybridIndex.create(
        jax.random.key(0), c.doc_emb[:-20], c.doc_tokens[:-20],
        c.vocab_size, delta_capacity=32, doc_namespaces=ns, **kw)
    with pytest.raises(ValueError, match="namespaces"):
        filt.add_docs(c.doc_emb[-2:], c.doc_tokens[-2:])
    ids = filt.add_docs(c.doc_emb[-2:], c.doc_tokens[-2:], namespaces=3)
    assert (filt.namespaces_of(ids) == 3).all()
    # namespaces survive compaction with the survivors
    filt.delete_docs(ids[:1])
    comp = filt.compact()
    assert comp.namespaces_of([comp.n_base - 1]) == [3]


def test_filtered_checkpoint_roundtrip(tmp_path):
    """Namespace planes round-trip through the mutable checkpoint path
    (DESIGN.md §5/§9) and keep filtering identically after restore."""
    from repro.checkpoint import checkpoint as ckpt
    c = synthetic.generate(seed=0, n_docs=900, n_queries=8, hidden=32,
                           vocab_size=512, n_topics=8)
    kw = dict(n_clusters=16, k1_terms=4, codec="sq8",
              cluster_capacity=64, term_capacity=32, kmeans_iters=3)
    ns = (np.arange(880) % 4).astype(np.int32)
    mut = seg.MutableHybridIndex.create(
        jax.random.key(0), c.doc_emb[:-20], c.doc_tokens[:-20],
        c.vocab_size, delta_capacity=32, doc_namespaces=ns, **kw)
    mut.add_docs(c.doc_emb[-20:], c.doc_tokens[-20:],
                 namespaces=np.arange(20) % 4)
    qe, qt = jnp.asarray(c.query_emb), jnp.asarray(c.query_tokens)
    bitmap = ns_filters.make_filter([b % 4 for b in range(8)], 4)
    ref = mut.search(qe, qt, kc=4, k2=4, top_r=10, filter=bitmap)
    path = ckpt.save_mutable(str(tmp_path), 3, mut)
    like = seg.MutableHybridIndex.create(
        jax.random.key(1), c.doc_emb[:-20], c.doc_tokens[:-20],
        c.vocab_size, delta_capacity=32, doc_namespaces=ns, **kw)
    back = ckpt.restore_mutable(path, like)
    out = back.search(qe, qt, kc=4, k2=4, top_r=10, filter=bitmap)
    np.testing.assert_array_equal(np.asarray(ref.doc_ids),
                                  np.asarray(out.doc_ids))
    np.testing.assert_array_equal(np.asarray(ref.scores),
                                  np.asarray(out.scores))


def test_plain_index_checkpoint_roundtrips_doc_ns(tmp_path):
    from repro.checkpoint import checkpoint as ckpt
    c, idx, ns = _small(codec="sq8", n_ns=6)
    path = ckpt.save_index(str(tmp_path), 0, idx)
    like = hi.build(jax.random.key(1), jnp.asarray(c.doc_emb),
                    jnp.asarray(c.doc_tokens), c.vocab_size,
                    n_clusters=16, k1_terms=4, codec="sq8",
                    cluster_capacity=64, term_capacity=32, kmeans_iters=3,
                    doc_namespaces=np.zeros(1200, np.int32))
    back = ckpt.restore_index(path, like)
    np.testing.assert_array_equal(np.asarray(back.doc_ns), ns)


# --------------------------------------------------------------------------
# filter bitmap unit semantics
# --------------------------------------------------------------------------

def test_make_filter_bitmap_layout_and_bounds():
    assert ns_filters.n_words(1) == 1
    assert ns_filters.n_words(32) == 1
    assert ns_filters.n_words(33) == 2
    bm = np.asarray(ns_filters.make_filter([[0, 33], 5, []], 40))
    assert bm.shape == (3, 2) and bm.dtype == np.uint32
    assert bm[0, 0] == 1 and bm[0, 1] == 2          # bits 0 and 33
    assert bm[1, 0] == 1 << 5 and bm[1, 1] == 0
    assert bm[2].sum() == 0                          # match-nothing row
    with pytest.raises(ValueError, match="out of range"):
        ns_filters.make_filter([[40]], 40)
    with pytest.raises(ValueError, match="out of range"):
        ns_filters.make_filter([[-1]], 40)


def test_allowed_mask_matches_python_semantics():
    bm = ns_filters.make_filter([[0, 2, 37], [1]], 64)
    ids = jnp.asarray([[0, 1, 2, 37, 63], [0, 1, 2, 37, 63]])
    got = np.asarray(ns_filters.allowed_mask(bm, ids))
    np.testing.assert_array_equal(
        got, [[True, False, True, True, False],
              [False, True, False, False, False]])


def test_allowed_mask_fails_closed_on_out_of_range_ids():
    """A doc namespace id beyond the bitmap's W·32 range must match
    NOTHING: the fixed-shape word gather clips, and letting id 64 alias
    onto bit 32's word/bit slot would leak one tenant's doc into
    another's results.  Negative garbage ids likewise."""
    bm = ns_filters.make_filter([[32], list(range(64))], 64)   # W = 2
    ids = jnp.asarray([[32, 64, 96, -1], [32, 64, 96, -1]])
    got = np.asarray(ns_filters.allowed_mask(bm, ids))
    np.testing.assert_array_equal(
        got, [[True, False, False, False],
              [True, False, False, False]])
    # and the doc-side plumbing refuses negative ids outright
    with pytest.raises(ValueError, match="non-negative"):
        hi.build(jax.random.key(0), jnp.zeros((64, 8)),
                 jnp.zeros((64, 4), jnp.int32), 32, n_clusters=4,
                 k1_terms=2, codec="flat", kmeans_iters=1,
                 doc_namespaces=np.full(64, -1))


# --------------------------------------------------------------------------
# the shared cost model (satellite: no more per-variant drift)
# --------------------------------------------------------------------------

def test_one_cost_model_across_variants():
    from repro.core import sharded_index as shi
    c, idx, _ = _small()
    assert hi.candidate_budget(idx, 4, 6) == qexec.candidate_budget(
        4, 6, [(idx.cluster_lists.capacity, idx.term_lists.capacity)])
    sidx = shi.partition(idx, 1)
    assert shi.candidate_budget(sidx, 4, 6) == hi.candidate_budget(idx, 4, 6)
    mut = seg.MutableHybridIndex.create(
        jax.random.key(0), c.doc_emb, c.doc_tokens, c.vocab_size,
        delta_capacity=32, n_clusters=16, k1_terms=4, codec="flat",
        cluster_capacity=64, term_capacity=32, kmeans_iters=3)
    want = (hi.candidate_budget(mut.base, 4, 6)
            + 4 * mut.delta_cluster_capacity + 6 * mut.delta_term_capacity)
    assert mut.candidate_budget(4, 6) == want
    # refine codecs add R' to the cost through the same one model
    assert qexec.candidate_cost("refine:pq:4", 4, 6, 10,
                                [(64, 32)]) == 4 * 64 + 6 * 32 + 40


# --------------------------------------------------------------------------
# acceptance criterion: one pipeline, no duplicated stage bodies
# --------------------------------------------------------------------------

def test_dispatch_cluster_topk_kernel_parity_at_real_shapes():
    """The dispatch stage's cluster selection under ``use_kernel`` must
    return bit-identical list ids and scores to the ``lax.top_k`` path
    at the (kc, L) shapes the engine actually dispatches — including
    the running-merge tie-break (DESIGN.md §11)."""
    from repro.core import cluster_selector as cs
    key = jax.random.key(7)
    for n_clusters, kc, b in ((32, 4, 24), (128, 6, 64), (31, 8, 3)):
        sel = cs.ClusterSelector(
            embeddings=jax.random.normal(key, (n_clusters, 32)))
        q = jax.random.normal(jax.random.fold_in(key, n_clusters), (b, 32))
        i0, s0 = cs.select_for_query(sel, q, kc, use_kernel=False)
        i1, s1 = cs.select_for_query(sel, q, kc, use_kernel=True)
        np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1),
                                      (n_clusters, kc))
        np.testing.assert_allclose(np.asarray(s0), np.asarray(s1),
                                   rtol=1e-5, atol=1e-5)


def test_dispatch_calls_the_topk_kernel_only_under_use_kernel():
    """``topk_scores`` (the assign_topk dispatch kernel) may be called
    from exactly one place outside its own package — the
    ``use_kernel`` branch of ``cluster_selector.select_for_query`` —
    and the traced program must contain a pallas_call iff the flag is
    set (the grep-plus-jaxpr version of the stage-chain scan above)."""
    from repro.core import cluster_selector as cs
    root = pathlib.Path(hi.__file__).resolve().parents[1]   # src/repro
    offenders = []
    for p in root.rglob("*.py"):
        rel = p.relative_to(root).as_posix()
        if re.search(r"topk_scores\(", p.read_text()):
            if rel not in ("kernels/assign_topk/kernel.py",
                           "kernels/assign_topk/ops.py",
                           "kernels/assign_topk/ref.py",
                           "core/cluster_selector.py"):
                offenders.append(rel)
    assert not offenders, offenders
    # the call sits inside the use_kernel branch
    src = (root / "core/cluster_selector.py").read_text()
    body = src[src.index("def select_for_query"):]
    assert body.index("if use_kernel:") < body.index("topk_scores(")
    # behavioral: the kernel primitive appears in the trace iff flagged
    sel = cs.ClusterSelector(embeddings=jnp.zeros((16, 8)))
    q = jnp.zeros((4, 8))
    with_k = str(jax.make_jaxpr(
        lambda s, x: cs.select_for_query(s, x, 4, use_kernel=True))(sel, q))
    without = str(jax.make_jaxpr(
        lambda s, x: cs.select_for_query(s, x, 4, use_kernel=False))(sel, q))
    assert "pallas_call" in with_k
    assert "pallas_call" not in without and "top_k" in without


def test_dedup_and_stage_chain_live_only_in_the_exec_layer():
    """`dedup_mask(` may be *defined* in inverted_lists and *called*
    only from the exec layer — the grep the ISSUE pins the refactor to.
    Same for the merge primitive gather_topk (exec owns the shard
    merge)."""
    root = pathlib.Path(hi.__file__).resolve().parents[1]   # src/repro
    offenders = []
    for p in root.rglob("*.py"):
        rel = p.relative_to(root).as_posix()
        text = p.read_text()
        if re.search(r"dedup_mask\(", text):
            if rel not in ("core/inverted_lists.py", "core/exec/stages.py"):
                offenders.append((rel, "dedup_mask"))
        if re.search(r"gather_topk\(", text):
            if rel not in ("distributed/collectives.py",
                           "core/exec/stages.py"):
                offenders.append((rel, "gather_topk"))
    assert not offenders, offenders
