"""Fault-tolerance drills (DESIGN.md §5):
  · atomic checkpoint + rotation + resume-from-latest
  · crash/restart: a killed run resumed from checkpoint reproduces the
    uninterrupted trajectory bit-for-bit
  · elastic reshard: restore under a different device layout
  · straggler monitor flags outliers; loader reshards around ejections
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, checkpoint as ckpt
from repro.data.pipeline import Dataloader
from repro.distributed import fault
from repro.models import transformer as tfm
from repro.optim import AdamConfig, adam_init, adam_update


def _tiny_cfg():
    return tfm.TransformerConfig(n_layers=2, d_model=32, n_heads=2,
                                 n_kv_heads=2, d_ff=64, vocab_size=128,
                                 compute_dtype=jnp.float32, remat=False)


def _batch_factory(seed, batch):
    k = jax.random.key(seed)
    toks = jax.random.randint(k, (batch, 8), 0, 128)
    return {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}


def _train(cfg, params, state, loader, steps, start_step=0, manager=None,
           crash_at=None):
    @jax.jit
    def step_fn(p, s, b):
        (l, _), g = jax.value_and_grad(
            lambda pp: tfm.loss_fn(pp, cfg, b["tokens"], b["labels"]),
            has_aux=True)(p)
        p, s = adam_update(g, s, p, AdamConfig(lr=1e-3))
        return p, s, l

    losses = []
    for i in range(start_step, steps):
        if crash_at is not None and i == crash_at:
            raise fault.SimulatedFailure(f"killed at step {i}")
        params, state, loss = step_fn(params, state, loader.batch_at(i))
        losses.append(float(loss))
        if manager and manager.should_save(i + 1):
            manager.save(i + 1, {"params": params, "opt": state})
    return params, state, losses


def test_atomic_save_restore_roundtrip(tmp_path):
    cfg = _tiny_cfg()
    params = tfm.init(jax.random.key(0), cfg)
    path = ckpt.save(str(tmp_path), 7, {"params": params}, extra={"a": 1})
    restored = ckpt.restore(path, {"params": params})
    for a, b in zip(jax.tree.leaves(params),
                    jax.tree.leaves(restored["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert ckpt.load_manifest(path)["extra"] == {"a": 1}


def test_restore_rejects_shape_mismatch(tmp_path):
    params = {"w": jnp.zeros((4, 4))}
    path = ckpt.save(str(tmp_path), 1, params)
    with pytest.raises(ValueError):
        ckpt.restore(path, {"w": jnp.zeros((8, 4))})


def test_manager_rotation_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_n=2, save_every=1)
    for s in (1, 2, 3, 4):
        mgr.save(s, {"x": jnp.full((2,), s)})
    assert mgr.steps() == [3, 4]
    step, tree = mgr.restore_latest({"x": jnp.zeros((2,))})
    assert step == 4 and float(tree["x"][0]) == 4


def test_crash_restart_is_bit_identical(tmp_path):
    """The headline drill: kill at step 7, resume from the step-5
    checkpoint, final params must equal an uninterrupted run."""
    cfg = _tiny_cfg()
    loader = Dataloader(_batch_factory, global_batch=4, seed=42)

    # uninterrupted reference
    p0 = tfm.init(jax.random.key(1), cfg)
    s0 = adam_init(p0)
    ref_params, _, ref_losses = _train(cfg, p0, s0, loader, steps=10)

    # crashing run with checkpoints every 5 steps
    mgr = CheckpointManager(str(tmp_path), keep_n=2, save_every=5)
    p1 = tfm.init(jax.random.key(1), cfg)
    s1 = adam_init(p1)
    with pytest.raises(fault.SimulatedFailure):
        _train(cfg, p1, s1, loader, steps=10, manager=mgr, crash_at=7)

    # restart: restore latest (step 5) and continue 5..10
    step, tree = mgr.restore_latest({"params": p1, "opt": adam_init(p1)})
    assert step == 5
    p2, s2, _ = _train(cfg, tree["params"], tree["opt"], loader,
                       steps=10, start_step=5)
    for a, b in zip(jax.tree.leaves(ref_params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_elastic_reshard_restore(tmp_path):
    """Restore under a different sharding layout (device-count change)."""
    params = {"table": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    path = ckpt.save(str(tmp_path), 1, params)
    shardings = {"table": jax.sharding.SingleDeviceSharding(
        jax.devices()[0])}
    restored = ckpt.restore_resharded(path, params, shardings)
    np.testing.assert_array_equal(np.asarray(restored["table"]),
                                  np.asarray(params["table"]))


def test_straggler_monitor_flags_outlier():
    mon = fault.StragglerMonitor(window=16, factor=2.0)
    import time
    for _ in range(10):
        mon.step_start()
        mon.step_end(host_id=0)
    mon.step_start()
    time.sleep(0.05)         # ~100× the no-op step latency
    assert mon.step_end(host_id=0)
    assert mon.strikes[0] == 1


def test_straggler_observe_external_measurements():
    """The serving seam (DESIGN.md §12): per-shard latencies measured by
    the caller, median window shared across hosts, strikes per host."""
    mon = fault.StragglerMonitor(window=16, factor=2.0, max_strikes=3)
    # no deadline until the median window has >= 8 samples
    assert not mon.observe(10.0, host_id=1)
    for _ in range(8):
        assert not mon.observe(0.1, host_id=0)
    # shared median (~0.1s) flags host 1, not host 0
    assert mon.observe(1.0, host_id=1)
    assert not mon.observe(0.15, host_id=0)
    assert mon.strikes[1] == 1 and mon.strikes[0] == 0
    assert not mon.should_eject(1)
    for _ in range(2):
        assert mon.observe(1.0, host_id=1)
    assert mon.should_eject(1) and not mon.should_eject(0)


def test_shard_health_policy():
    """ShardHealth as used by MeshServer recovery: observe -> eject at
    max_strikes, refuse to eject the last survivor, rejoin clears
    strikes, out-of-range shards rejected."""
    h = fault.ShardHealth(2, window=16, factor=2.0, max_strikes=2)
    assert h.healthy == [0, 1] and h.lost == [] and not h.degraded
    for _ in range(10):
        assert not h.observe(0, 0.1)
        assert not h.observe(1, 0.1)
    assert not h.observe(1, 1.0)      # strike 1 of 2
    assert h.observe(1, 1.0)          # strike 2 -> eject signal
    h.eject(1)
    assert h.degraded and h.lost == [1] and h.healthy == [0]
    # an already-lost shard never re-signals ejection
    assert not h.observe(1, 1.0)
    with pytest.raises(ValueError, match="last healthy"):
        h.eject(0)
    with pytest.raises(ValueError, match="out of range"):
        h.observe(2, 0.1)
    with pytest.raises(ValueError, match="out of range"):
        h.eject(-1)
    h.rejoin(1)
    assert not h.degraded and h.healthy == [0, 1]
    assert h.monitor.strikes[1] == 0  # clean slate after rejoin


def test_shard_health_rejoin_all():
    h = fault.ShardHealth(4)
    h.eject(0)
    h.eject(2)
    assert h.lost == [0, 2] and h.healthy == [1, 3]
    h.rejoin()                        # None -> every lost shard returns
    assert h.healthy == [0, 1, 2, 3] and not h.degraded


def test_loader_reshards_after_ejection():
    loader = Dataloader(_batch_factory, global_batch=12, seed=0,
                        host_id=0, healthy_hosts=[0, 1, 2])
    assert loader.local_batch_size() == 4
    loader.reshard([0, 2])   # host 1 ejected
    assert loader.local_batch_size() == 6
    bounds = fault.reshard_bounds(12, [0, 2])
    assert bounds[0] == (0, 6) and bounds[2] == (6, 12)


def test_gradient_compression_error_feedback():
    from repro.optim import compression as comp
    g = {"w": jnp.array([1.0, -0.5, 0.25, 1e-4])}
    ef = comp.ef_init(g)
    total = jnp.zeros(4)
    # accumulated decompressed grads converge to accumulated true grads
    for _ in range(50):
        c, ef = comp.compress_with_ef(g, ef)
        total = total + comp.decompress(c)["w"]
    # error-feedback residual is bounded by half a quantization step,
    # amortized over the 50 steps (scale/2/50 ≈ 8e-5)
    np.testing.assert_allclose(np.asarray(total) / 50,
                               np.asarray(g["w"]), rtol=0.02, atol=2e-4)
