"""Hybrid dense∥sparse fusion (DESIGN.md §13): degenerate-weight
bit-identity, the pure-BM25 oracle, cross-variant equivalence,
namespace isolation of sparse candidates, cache keying, and the
checkpoint round-trip of the impact plane.

Multi-device cases spawn a fresh interpreter with
xla_force_host_platform_device_count (the tests/test_exec.py pattern);
everything else runs in-process.
"""
import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt
from repro.core import exec as qexec, hybrid_index as hi
from repro.core import segments as seg
from repro.core import term_selector as ts_mod
from repro.core.exec import filters as ns_filters
from repro.core.inverted_lists import PAD_DOC
from repro.data import synthetic
from repro.launch import runtime as rt_mod
from repro.launch import serve

_ENV = dict(os.environ,
            XLA_FLAGS="--xla_force_host_platform_device_count=4",
            PYTHONPATH=os.environ.get("PYTHONPATH", "src"))


def _run(script: str) -> None:
    r = subprocess.run([sys.executable, "-c", script], env=_ENV,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"


def _corpus():
    return synthetic.generate(seed=0, n_docs=1400, n_queries=24, hidden=32,
                              vocab_size=512, n_topics=8)


_KW = dict(n_clusters=16, k1_terms=4, codec="pq", pq_m=4, pq_k=64,
           cluster_capacity=64, term_capacity=32, kmeans_iters=3)


def _index(c, sparse=True, **over):
    kw = dict(_KW, **over)
    return hi.build(jax.random.key(0), jnp.asarray(c.doc_emb),
                    jnp.asarray(c.doc_tokens), c.vocab_size,
                    sparse=sparse, **kw)


# --------------------------------------------------------------------------
# the spec
# --------------------------------------------------------------------------

def test_fusion_spec_validates():
    qexec.FusionSpec(weight=0.0)
    qexec.FusionSpec(weight=1.0)
    with pytest.raises(ValueError):
        qexec.FusionSpec(weight=1.5)
    with pytest.raises(ValueError):
        qexec.FusionSpec(weight=-0.1)
    with pytest.raises(ValueError):
        qexec.FusionSpec(rrf_k=-1)
    # hashable + equality — the spec is a jit static arg and a cache key
    assert qexec.FusionSpec(weight=0.5) == qexec.FusionSpec(weight=0.5)
    assert hash(qexec.FusionSpec()) == hash(qexec.FusionSpec())


def test_build_sparse_requires_term_lists():
    c = _corpus()
    with pytest.raises(ValueError, match="use_terms"):
        hi.build(jax.random.key(0), jnp.asarray(c.doc_emb),
                 jnp.asarray(c.doc_tokens), c.vocab_size,
                 sparse=True, use_terms=False, **_KW)


# --------------------------------------------------------------------------
# degenerate weights and the fallback contract
# --------------------------------------------------------------------------

def test_weight_one_bit_identical_to_dense_only():
    """fusion_weight=1.0 zeroes every sparse contribution, so the fused
    ids must be bit-identical to dense-only search (§13 contract)."""
    c = _corpus()
    idx = _index(c)
    qe, qt = jnp.asarray(c.query_emb), jnp.asarray(c.query_tokens)
    dense = hi.search(idx, qe, qt, kc=4, k2=4, top_r=16)
    w1 = hi.search(idx, qe, qt, kc=4, k2=4, top_r=16,
                   fusion=qexec.FusionSpec(weight=1.0))
    np.testing.assert_array_equal(np.asarray(dense.doc_ids),
                                  np.asarray(w1.doc_ids))


def test_dense_fallback_without_impact_plane_is_exact():
    """A FusionSpec against an index with no sparse_weights plane must
    return the UNCHANGED dense result — ids and codec scores, not RRF
    scores (the fallback is the dense path, not a degenerate fusion)."""
    c = _corpus()
    idx = _index(c, sparse=False)
    assert idx.sparse_weights is None
    qe, qt = jnp.asarray(c.query_emb), jnp.asarray(c.query_tokens)
    dense = hi.search(idx, qe, qt, kc=4, k2=4, top_r=16)
    fb = hi.search(idx, qe, qt, kc=4, k2=4, top_r=16,
                   fusion=qexec.FusionSpec(weight=0.5))
    np.testing.assert_array_equal(np.asarray(dense.doc_ids),
                                  np.asarray(fb.doc_ids))
    np.testing.assert_array_equal(np.asarray(dense.scores),
                                  np.asarray(fb.scores))
    np.testing.assert_array_equal(np.asarray(dense.n_candidates),
                                  np.asarray(fb.n_candidates))


def test_mixed_weight_changes_ranking_and_counts_sparse():
    """A mid-sweep weight must actually fuse: the ranking differs from
    dense-only and n_candidates grows by the sparse uniques."""
    c = _corpus()
    idx = _index(c)
    qe, qt = jnp.asarray(c.query_emb), jnp.asarray(c.query_tokens)
    dense = hi.search(idx, qe, qt, kc=4, k2=4, top_r=16)
    fused = hi.search(idx, qe, qt, kc=4, k2=4, top_r=16,
                      fusion=qexec.FusionSpec(weight=0.5))
    assert not np.array_equal(np.asarray(dense.doc_ids),
                              np.asarray(fused.doc_ids))
    assert (np.asarray(fused.n_candidates)
            >= np.asarray(dense.n_candidates)).all()


# --------------------------------------------------------------------------
# weight=0.0 against a pure-BM25 numpy oracle
# --------------------------------------------------------------------------

def _bm25_oracle(index, query_tokens, k2, top_r):
    """Pure sparse top-R: for each query, sum the STORED impacts of
    every doc over its probed term lists (accumulated in probed-term
    order, float32 — the same addition order as the fixed-shape path),
    rank by (score desc, id asc), exclude zero-score docs."""
    t_ids = np.asarray(ts_mod.query_terms(index.term_sel,
                                          jnp.asarray(query_tokens), k2))
    entries = np.asarray(index.term_lists.entries)
    weights = np.asarray(index.sparse_weights)
    n_docs = index.n_docs
    out = np.full((t_ids.shape[0], top_r), PAD_DOC, np.int64)
    for b in range(t_ids.shape[0]):
        acc = np.zeros((n_docs,), np.float32)
        for t in t_ids[b]:
            if t < 0:
                continue
            for slot in range(entries.shape[1]):
                d = entries[t, slot]
                if d >= 0:
                    acc[d] = np.float32(acc[d] + weights[t, slot])
        live = np.flatnonzero(acc > 0.0)
        order = live[np.lexsort((live, -acc[live]))][:top_r]
        out[b, :order.size] = order
    return out


def test_weight_zero_matches_bm25_oracle():
    c = _corpus()
    # term_capacity=None → no truncation, so every posting the oracle
    # sums is present in the impact plane
    idx = _index(c, term_capacity=None)
    res = hi.search(idx, jnp.asarray(c.query_emb),
                    jnp.asarray(c.query_tokens), kc=4, k2=4, top_r=16,
                    fusion=qexec.FusionSpec(weight=0.0))
    oracle = _bm25_oracle(idx, c.query_tokens, k2=4, top_r=16)
    np.testing.assert_array_equal(np.asarray(res.doc_ids), oracle)


# --------------------------------------------------------------------------
# cross-variant equivalence (sharded paths in a 4-device subprocess)
# --------------------------------------------------------------------------

def test_fused_search_identical_across_all_four_variants():
    """single == mutable == sharded(2,4) == sharded-mutable under
    fusion, bitwise in ids/scores/candidate counts — and weight=1.0
    stays bit-identical to dense-only on every variant."""
    _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.core import exec as qexec, hybrid_index as hi
from repro.core import segments as seg, sharded_index as shi
from repro.data import synthetic

assert jax.device_count() == 4
c = synthetic.generate(seed=0, n_docs=1400, n_queries=24, hidden=32,
                       vocab_size=512, n_topics=8)
kw = dict(n_clusters=16, k1_terms=4, codec="pq", pq_m=4, pq_k=64,
          cluster_capacity=64, term_capacity=32, kmeans_iters=3,
          sparse=True)
qe, qt = jnp.asarray(c.query_emb), jnp.asarray(c.query_tokens)
idx = hi.build(jax.random.key(0), jnp.asarray(c.doc_emb),
               jnp.asarray(c.doc_tokens), c.vocab_size, **kw)
mut = seg.MutableHybridIndex.create(
    jax.random.key(0), c.doc_emb, c.doc_tokens, c.vocab_size,
    delta_capacity=64, **kw)

def check(ref, out, err):
    np.testing.assert_array_equal(np.asarray(ref.doc_ids),
                                  np.asarray(out.doc_ids), err)
    np.testing.assert_array_equal(np.asarray(ref.scores),
                                  np.asarray(out.scores), err)
    np.testing.assert_array_equal(np.asarray(ref.n_candidates),
                                  np.asarray(out.n_candidates), err)

for fus in (qexec.FusionSpec(weight=0.5), qexec.FusionSpec(weight=1.0)):
    ref = hi.search(idx, qe, qt, kc=4, k2=4, top_r=16, fusion=fus)
    check(ref, mut.search(qe, qt, kc=4, k2=4, top_r=16, fusion=fus),
          ("mutable", fus))
    for n_shards in (2, 4):
        mesh = shi.make_shard_mesh(n_shards)
        sidx = shi.device_put(shi.partition(idx, n_shards), mesh)
        check(ref, shi.search(sidx, qe, qt, kc=4, k2=4, top_r=16,
                              mesh=mesh, fusion=fus),
              ("sharded", n_shards, fus))
        smut = seg.ShardedMutableIndex(mut, n_shards)
        check(ref, smut.search(qe, qt, kc=4, k2=4, top_r=16, fusion=fus),
              ("sharded-mutable", n_shards, fus))

# weight=1.0 == dense-only, on the sharded path too
dense = hi.search(idx, qe, qt, kc=4, k2=4, top_r=16)
w1 = hi.search(idx, qe, qt, kc=4, k2=4, top_r=16,
               fusion=qexec.FusionSpec(weight=1.0))
np.testing.assert_array_equal(np.asarray(dense.doc_ids),
                              np.asarray(w1.doc_ids))
""")


def test_fused_search_with_live_delta_and_tombstones():
    """Streamed docs join the sparse channel (their postings carry the
    eviction-score impacts) and tombstoned docs can never surface in a
    fused result."""
    c = _corpus()
    kw = dict(_KW, sparse=True)
    mut = seg.MutableHybridIndex.create(
        jax.random.key(0), c.doc_emb[:1200], c.doc_tokens[:1200],
        c.vocab_size, delta_capacity=256, **kw)
    new_ids = mut.add_docs(c.doc_emb[1200:], c.doc_tokens[1200:])
    dead = np.arange(0, 60)
    mut.delete_docs(dead)
    fus = qexec.FusionSpec(weight=0.5)
    res = mut.search(c.query_emb, c.query_tokens, kc=4, k2=4, top_r=16,
                     fusion=fus)
    ids = np.asarray(res.doc_ids)
    assert not np.isin(ids, dead).any()
    # the delta is searchable through the sparse channel: pure-sparse
    # search can return streamed docs
    sp = mut.search(c.query_emb, c.query_tokens, kc=4, k2=4, top_r=64,
                    fusion=qexec.FusionSpec(weight=0.0))
    assert np.isin(np.asarray(sp.doc_ids), new_ids).any()
    # compact folds the impacts into a fresh base build and keeps fusing
    mut2 = mut.compact()
    assert mut2.base.sparse_weights is not None
    res2 = mut2.search(c.query_emb, c.query_tokens, kc=4, k2=4, top_r=16,
                       fusion=fus)
    assert np.asarray(res2.doc_ids).shape == ids.shape


# --------------------------------------------------------------------------
# namespace isolation of sparse candidates
# --------------------------------------------------------------------------

def test_namespace_filter_applies_to_sparse_candidates():
    """The sparse channel must fail closed exactly like the dense one:
    no fused (or pure-sparse) result may leave the query's allowed
    namespaces."""
    c = _corpus()
    n_ns = 4
    doc_ns = (np.arange(1400) * 7 % n_ns).astype(np.int32)
    idx = hi.build(jax.random.key(0), jnp.asarray(c.doc_emb),
                   jnp.asarray(c.doc_tokens), c.vocab_size,
                   doc_namespaces=doc_ns, sparse=True, **_KW)
    allowed = [[b % n_ns] for b in range(24)]
    bitmap = ns_filters.make_filter(allowed, n_ns)
    for w in (0.0, 0.5):
        res = hi.search(idx, jnp.asarray(c.query_emb),
                        jnp.asarray(c.query_tokens), kc=4, k2=4, top_r=16,
                        filter=bitmap, fusion=qexec.FusionSpec(weight=w))
        ids = np.asarray(res.doc_ids)
        for b, row in enumerate(ids):
            live = row[row >= 0]
            assert np.isin(doc_ns[live], allowed[b]).all(), (w, b)


# --------------------------------------------------------------------------
# serving: cache keying on the fusion spec
# --------------------------------------------------------------------------

def test_runtime_cache_fused_hit_and_weight_change_miss():
    c = _corpus()
    idx = _index(c)
    srv = serve.Server(idx, serve.ServeConfig(
        kc=4, k2=4, top_r=16, max_batch=8, fusion_weight=0.5))
    rt = rt_mod.ServingRuntime(srv, rt_mod.RuntimeConfig(cache_size=64))
    rt.warmup(c.query_emb.shape[1], c.query_tokens.shape[1])
    try:
        r1 = rt.query(c.query_emb[:4], c.query_tokens[:4])
        r2 = rt.query(c.query_emb[:4], c.query_tokens[:4])
        np.testing.assert_array_equal(np.asarray(r1.doc_ids),
                                      np.asarray(r2.doc_ids))
        np.testing.assert_array_equal(np.asarray(r1.scores),
                                      np.asarray(r2.scores))
        assert rt.cache.hits == 4 and rt.cache.misses == 4
        rt.set_fusion_weight(0.25)
        r3 = rt.query(c.query_emb[:4], c.query_tokens[:4])
        # a re-weighted query must recompute, never replay
        assert rt.cache.hits == 4 and rt.cache.misses == 8
        assert not np.array_equal(np.asarray(r3.doc_ids),
                                  np.asarray(r1.doc_ids))
        # and the runtime stays bit-identical to direct serving
        direct = srv.query(c.query_emb[:4], c.query_tokens[:4])
        np.testing.assert_array_equal(np.asarray(r3.doc_ids),
                                      np.asarray(direct.doc_ids))
    finally:
        rt.close()


def test_server_set_fusion_validates_weight():
    c = _corpus()
    srv = serve.Server(_index(c), serve.ServeConfig(kc=4, k2=4, top_r=8,
                                                    max_batch=8))
    assert srv.fusion is None
    with pytest.raises(ValueError):
        srv.set_fusion(2.0)
    srv.set_fusion(0.5)
    assert srv.fusion == qexec.FusionSpec(weight=0.5)
    srv.set_fusion(None)
    assert srv.fusion is None


# --------------------------------------------------------------------------
# persistence: the impact plane round-trips
# --------------------------------------------------------------------------

def test_checkpoint_roundtrip_preserves_fused_search(tmp_path):
    c = _corpus()
    idx = _index(c)
    path = ckpt.save_index(str(tmp_path), 0, idx)
    like = jax.tree.map(lambda x: np.zeros(x.shape, x.dtype), idx)
    restored = ckpt.restore_index(path, like)
    assert restored.sparse_weights is not None
    fus = qexec.FusionSpec(weight=0.5)
    qe, qt = jnp.asarray(c.query_emb), jnp.asarray(c.query_tokens)
    ref = hi.search(idx, qe, qt, kc=4, k2=4, top_r=16, fusion=fus)
    got = hi.search(restored, qe, qt, kc=4, k2=4, top_r=16, fusion=fus)
    np.testing.assert_array_equal(np.asarray(ref.doc_ids),
                                  np.asarray(got.doc_ids))
    np.testing.assert_array_equal(np.asarray(ref.scores),
                                  np.asarray(got.scores))


def test_mutable_state_roundtrip_preserves_fused_search(tmp_path):
    c = _corpus()
    kw = dict(_KW, sparse=True)
    mut = seg.MutableHybridIndex.create(
        jax.random.key(0), c.doc_emb[:1200], c.doc_tokens[:1200],
        c.vocab_size, delta_capacity=256, **kw)
    mut.add_docs(c.doc_emb[1200:], c.doc_tokens[1200:])
    restored = seg.MutableHybridIndex.from_state(mut.state_tree(),
                                                 mut.state_extra())
    fus = qexec.FusionSpec(weight=0.5)
    ref = mut.search(c.query_emb, c.query_tokens, kc=4, k2=4, top_r=16,
                     fusion=fus)
    got = restored.search(c.query_emb, c.query_tokens, kc=4, k2=4,
                          top_r=16, fusion=fus)
    np.testing.assert_array_equal(np.asarray(ref.doc_ids),
                                  np.asarray(got.doc_ids))
    np.testing.assert_array_equal(np.asarray(ref.scores),
                                  np.asarray(got.scores))


def test_sparse_weights_align_with_term_entries():
    """Structural invariant of build_scored: the impact plane is 0 at
    pads and > 0 exactly where a posting exists (BM25 impacts of stored
    salient terms are positive)."""
    c = _corpus()
    idx = _index(c)
    entries = np.asarray(idx.term_lists.entries)
    w = np.asarray(idx.sparse_weights)
    assert w.shape == entries.shape
    assert (w[entries == PAD_DOC] == 0.0).all()
    assert (w[entries != PAD_DOC] > 0.0).all()


# keep the helper referenced for linting tools that flag unused imports
_ = dataclasses
