"""Per-kernel validation: shape/dtype sweeps (hypothesis) asserting
allclose against the pure-jnp oracles, in interpret mode on CPU."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # accelerator image: no pip installs; CI has the real one
    from _hypothesis_fallback import given, settings, strategies as st

from repro.kernels.assign_topk import ops as at_ops, ref as at_ref
from repro.kernels.flash_attention import ops as fa_ops, ref as fa_ref
from repro.kernels.pq_adc import ops as adc_ops, ref as adc_ref
from repro.kernels.sq8_dot import ops as sq8_ops, ref as sq8_ref

settings.register_profile("kernels", max_examples=12, deadline=None)
settings.load_profile("kernels")


# --------------------------------------------------------------------------
# pq_adc
# --------------------------------------------------------------------------

@given(b=st.integers(1, 4), c=st.integers(1, 700), m=st.sampled_from([1, 3, 8, 16]),
       k=st.sampled_from([128, 256]))
def test_pq_adc_matches_oracle(b, c, m, k):
    key = jax.random.key(b * 1000 + c)
    lut = jax.random.normal(key, (b, m, k), jnp.float32)
    codes = jax.random.randint(jax.random.fold_in(key, 1), (b, c, m), 0, k)
    out = adc_ops.pq_adc(lut, codes, c_blk=128)
    expect = adc_ref.pq_adc(lut, codes)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-5)


def test_pq_adc_paper_scale():
    """The paper's production config: m=96, k=256."""
    key = jax.random.key(0)
    lut = jax.random.normal(key, (2, 96, 256), jnp.float32)
    codes = jax.random.randint(jax.random.fold_in(key, 1), (2, 2048, 96),
                               0, 256)
    out = adc_ops.pq_adc(lut, codes)
    expect = adc_ref.pq_adc(lut, codes)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------------------
# pq_adc_fused — gather + ADC + mask in one kernel (DESIGN.md §11)
# --------------------------------------------------------------------------

def _fused_case(seed, b, c, m, k, n, code_dtype, mask_row=None,
                dup_ids=False):
    """Random (lut, plane, ids, live) with the edge shapes under test."""
    key = jax.random.key(seed)
    lut = jax.random.normal(key, (b, m, k), jnp.float32)
    plane = jax.random.randint(jax.random.fold_in(key, 1), (n, m),
                               0, k).astype(code_dtype)
    ids = jax.random.randint(jax.random.fold_in(key, 2), (b, c), 0, n,
                             jnp.int32)
    if dup_ids:          # every id appears at least twice per row
        ids = jnp.concatenate([ids[:, : (c + 1) // 2]] * 2, -1)[:, :c]
    live = jax.random.bernoulli(jax.random.fold_in(key, 3), 0.8,
                                (b, c)).astype(jnp.int32)
    if mask_row is not None:
        live = live.at[mask_row % b].set(0)          # fully-masked row
    return lut, plane, ids, live


def _assert_fused_matches_ref(lut, plane, ids, live, c_blk):
    got = np.asarray(adc_ops.pq_adc_fused(lut, plane, ids, live,
                                          c_blk=c_blk))
    want = np.asarray(adc_ref.pq_adc_fused(lut, plane, ids, live))
    np.testing.assert_array_equal(np.isinf(got), np.isinf(want))
    fin = np.isfinite(want)
    np.testing.assert_allclose(got[fin], want[fin], rtol=1e-4, atol=1e-4)


@given(b=st.integers(1, 4), c=st.integers(1, 700),
       m=st.sampled_from([1, 4, 8]), k=st.sampled_from([64, 128, 256]),
       code_i32=st.booleans(), dup=st.booleans(),
       mask_row=st.integers(0, 3))
def test_pq_adc_fused_matches_oracle_on_edge_shapes(b, c, m, k, code_i32,
                                                    dup, mask_row):
    """The ISSUE-6 edge sweep: C not a multiple of c_blk (c_blk=128,
    any C), C smaller than one block (C=1 is a boundary draw),
    duplicate candidate ids, one fully-masked (all -inf) row, and
    uint8 vs int32 code planes — all against ref.py."""
    dtype = jnp.int32 if code_i32 else jnp.uint8
    lut, plane, ids, live = _fused_case(
        b * 7919 + c, b, c, m, k, n=500, code_dtype=dtype,
        mask_row=mask_row, dup_ids=dup)
    _assert_fused_matches_ref(lut, plane, ids, live, c_blk=128)


def test_pq_adc_fused_all_rows_masked_is_all_inf():
    lut, plane, ids, live = _fused_case(0, 3, 200, 4, 64, n=100,
                                        code_dtype=jnp.uint8)
    live = jnp.zeros_like(live)
    out = np.asarray(adc_ops.pq_adc_fused(lut, plane, ids, live, c_blk=128))
    assert np.isneginf(out).all()


def test_pq_adc_fused_never_materializes_candidate_codes():
    """The fused op's whole point: no (B, C, m) — or padded
    (B, C_pad, m) — intermediate may exist anywhere in its jaxpr.  The
    unfused path is the positive control: its gather produces exactly
    that shape, so the walker provably sees such intermediates."""
    b, c, m, k, n, c_blk = 2, 384, 4, 64, 1000, 128
    lut, plane, ids, live = _fused_case(1, b, c, m, k, n=n,
                                        code_dtype=jnp.uint8)

    def shapes_of(fn, *args):
        seen = set()

        def walk(jaxpr):
            for eqn in jaxpr.eqns:
                for v in eqn.outvars:
                    aval = getattr(v, "aval", None)
                    if aval is not None and hasattr(aval, "shape"):
                        seen.add(tuple(aval.shape))
                for val in jax.tree_util.tree_leaves(
                        eqn.params, is_leaf=lambda x: hasattr(x, "eqns")):
                    if hasattr(val, "eqns"):
                        walk(val)
                    elif hasattr(val, "jaxpr"):
                        walk(val.jaxpr)
        closed = jax.make_jaxpr(fn)(*args)
        walk(closed.jaxpr)
        return seen

    def is_candidate_codes(shape):
        return (len(shape) == 3 and shape[0] == b and shape[2] == m
                and shape[1] >= c)

    fused_shapes = shapes_of(
        lambda *a: adc_ops.pq_adc_fused(*a, c_blk=c_blk),
        lut, plane, ids, live)
    offenders = sorted(s for s in fused_shapes if is_candidate_codes(s))
    assert not offenders, (
        f"fused kernel materialized candidate codes: {offenders}")

    unfused_shapes = shapes_of(
        lambda l, p, i, lv: jnp.where(lv.astype(bool),
                                      adc_ops.pq_adc(l, p[i]), -jnp.inf),
        lut, plane, ids, live)
    assert any(is_candidate_codes(s) for s in unfused_shapes), (
        "positive control failed: the walker no longer sees the "
        "unfused (B, C, m) gather — fix the walker, not the kernel")


# --------------------------------------------------------------------------
# sq8_dot_fused
# --------------------------------------------------------------------------

@given(b=st.integers(1, 4), c=st.integers(1, 700),
       h=st.sampled_from([16, 32, 64]), mask_row=st.integers(0, 3))
def test_sq8_dot_fused_matches_oracle(b, c, h, mask_row):
    key = jax.random.key(b * 31 + c)
    q = jax.random.normal(key, (b, h), jnp.float32)
    plane = jax.random.randint(jax.random.fold_in(key, 1), (400, h),
                               0, 256).astype(jnp.uint8)
    ids = jax.random.randint(jax.random.fold_in(key, 2), (b, c), 0, 400,
                             jnp.int32)
    live = jax.random.bernoulli(jax.random.fold_in(key, 3), 0.8,
                                (b, c)).astype(jnp.int32)
    live = live.at[mask_row % b].set(0)
    got = np.asarray(sq8_ops.sq8_dot_fused(q, plane, ids, live, c_blk=128))
    want = np.asarray(sq8_ref.sq8_dot_fused(q, plane, ids, live))
    np.testing.assert_array_equal(np.isinf(got), np.isinf(want))
    fin = np.isfinite(want)
    np.testing.assert_allclose(got[fin], want[fin], rtol=1e-4, atol=1e-2)


# --------------------------------------------------------------------------
# assign_topk
# --------------------------------------------------------------------------

@given(n=st.integers(1, 300), l=st.integers(2, 600),
       h=st.sampled_from([16, 32]), k=st.integers(1, 12),
       ties=st.booleans())
def test_topk_scores_matches_lax_topk(n, l, h, k, ties):
    """The dispatch kernel must be BIT-identical to ``lax.top_k`` over
    the plain inner-product plane — scores and ids, including the
    lowest-index-first tie-break (forced by duplicating rows)."""
    k = min(k, l)
    key = jax.random.key(n * 13 + l)
    x = jax.random.normal(key, (n, h), jnp.float32)
    emb = jax.random.normal(jax.random.fold_in(key, 1), (l, h),
                            jnp.float32)
    if ties:             # duplicate the first half: every score tied 2x
        emb = jnp.concatenate([emb[: (l + 1) // 2]] * 2)[:l]
    ws, wi = at_ref.topk_scores(x, emb, k)
    gs, gi = at_ops.topk_scores(x, emb, k, l_blk=128)
    np.testing.assert_array_equal(np.asarray(wi), np.asarray(gi))
    np.testing.assert_allclose(np.asarray(ws), np.asarray(gs),
                               rtol=1e-5, atol=1e-5)

@given(n=st.integers(1, 1200), l=st.integers(2, 600),
       h=st.sampled_from([16, 64, 128]))
def test_assign_argmax_matches_oracle(n, l, h):
    key = jax.random.key(n * 7 + l)
    x = jax.random.normal(key, (n, h), jnp.float32)
    c = jax.random.normal(jax.random.fold_in(key, 1), (l, h), jnp.float32)
    s, i = at_ops.assign_argmax(x, c)
    es, ei = at_ref.assign_argmax(x, c)
    np.testing.assert_allclose(np.asarray(s), np.asarray(es),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ei))


def test_assign_argmax_is_l2_argmin():
    """⟨x,c⟩ − ½‖c‖² argmax == L2 argmin (the KMeans contract)."""
    key = jax.random.key(3)
    x = jax.random.normal(key, (64, 32))
    c = jax.random.normal(jax.random.fold_in(key, 1), (40, 32))
    _, i = at_ops.assign_argmax(x, c)
    d = np.linalg.norm(np.asarray(x)[:, None] - np.asarray(c)[None], axis=-1)
    np.testing.assert_array_equal(np.asarray(i), d.argmin(axis=1))


# --------------------------------------------------------------------------
# flash_attention
# --------------------------------------------------------------------------

@given(sq=st.sampled_from([64, 200, 256]), sk=st.sampled_from([64, 256, 384]),
       d=st.sampled_from([32, 64]), causal=st.booleans(),
       window=st.sampled_from([0, 32]),
       heads=st.sampled_from([(4, 4), (4, 2), (8, 1)]))
def test_flash_attention_matches_oracle(sq, sk, d, causal, window, heads):
    if causal and sk != sq:
        sk = sq  # causal masks assume aligned positions
    hq, hkv = heads
    key = jax.random.key(sq * 31 + sk)
    q = jax.random.normal(key, (1, hq, sq, d), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, hkv, sk, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, hkv, sk, d))
    out = fa_ops.flash_attention(q, k, v, causal, window, None)
    expect = fa_ref.attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=3e-4, atol=3e-4)


def test_flash_attention_gradient_path():
    key = jax.random.key(9)
    q = jax.random.normal(key, (1, 2, 128, 32))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 2, 128, 32))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 2, 128, 32))

    def loss_kernel(q_):
        return fa_ops.flash_attention(q_, k, v, True, 0, None).sum()

    def loss_ref(q_):
        return fa_ref.attention(q_, k, v, causal=True).sum()

    g_k = jax.grad(loss_kernel)(q)
    g_r = jax.grad(loss_ref)(q)
    np.testing.assert_allclose(np.asarray(g_k), np.asarray(g_r),
                               rtol=3e-4, atol=3e-4)


@given(q_chunk=st.sampled_from([64, 128, 256]), causal=st.booleans(),
       window=st.sampled_from([0, 48]))
def test_chunked_attention_matches_dense(q_chunk, causal, window):
    key = jax.random.key(q_chunk)
    q = jax.random.normal(key, (1, 2, 512, 32))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 2, 512, 32))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 2, 512, 32))
    a = fa_ref.attention(q, k, v, causal=causal, window=window)
    b = fa_ref.attention_chunked(q, k, v, causal=causal, window=window,
                                 q_chunk=q_chunk)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-5, atol=2e-5)
