"""Per-kernel validation: shape/dtype sweeps (hypothesis) asserting
allclose against the pure-jnp oracles, in interpret mode on CPU."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # accelerator image: no pip installs; CI has the real one
    from _hypothesis_fallback import given, settings, strategies as st

from repro.kernels.assign_topk import ops as at_ops, ref as at_ref
from repro.kernels.flash_attention import ops as fa_ops, ref as fa_ref
from repro.kernels.pq_adc import ops as adc_ops, ref as adc_ref

settings.register_profile("kernels", max_examples=12, deadline=None)
settings.load_profile("kernels")


# --------------------------------------------------------------------------
# pq_adc
# --------------------------------------------------------------------------

@given(b=st.integers(1, 4), c=st.integers(1, 700), m=st.sampled_from([1, 3, 8, 16]),
       k=st.sampled_from([128, 256]))
def test_pq_adc_matches_oracle(b, c, m, k):
    key = jax.random.key(b * 1000 + c)
    lut = jax.random.normal(key, (b, m, k), jnp.float32)
    codes = jax.random.randint(jax.random.fold_in(key, 1), (b, c, m), 0, k)
    out = adc_ops.pq_adc(lut, codes, c_blk=128)
    expect = adc_ref.pq_adc(lut, codes)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-5)


def test_pq_adc_paper_scale():
    """The paper's production config: m=96, k=256."""
    key = jax.random.key(0)
    lut = jax.random.normal(key, (2, 96, 256), jnp.float32)
    codes = jax.random.randint(jax.random.fold_in(key, 1), (2, 2048, 96),
                               0, 256)
    out = adc_ops.pq_adc(lut, codes)
    expect = adc_ref.pq_adc(lut, codes)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------------------
# assign_topk
# --------------------------------------------------------------------------

@given(n=st.integers(1, 1200), l=st.integers(2, 600),
       h=st.sampled_from([16, 64, 128]))
def test_assign_argmax_matches_oracle(n, l, h):
    key = jax.random.key(n * 7 + l)
    x = jax.random.normal(key, (n, h), jnp.float32)
    c = jax.random.normal(jax.random.fold_in(key, 1), (l, h), jnp.float32)
    s, i = at_ops.assign_argmax(x, c)
    es, ei = at_ref.assign_argmax(x, c)
    np.testing.assert_allclose(np.asarray(s), np.asarray(es),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ei))


def test_assign_argmax_is_l2_argmin():
    """⟨x,c⟩ − ½‖c‖² argmax == L2 argmin (the KMeans contract)."""
    key = jax.random.key(3)
    x = jax.random.normal(key, (64, 32))
    c = jax.random.normal(jax.random.fold_in(key, 1), (40, 32))
    _, i = at_ops.assign_argmax(x, c)
    d = np.linalg.norm(np.asarray(x)[:, None] - np.asarray(c)[None], axis=-1)
    np.testing.assert_array_equal(np.asarray(i), d.argmin(axis=1))


# --------------------------------------------------------------------------
# flash_attention
# --------------------------------------------------------------------------

@given(sq=st.sampled_from([64, 200, 256]), sk=st.sampled_from([64, 256, 384]),
       d=st.sampled_from([32, 64]), causal=st.booleans(),
       window=st.sampled_from([0, 32]),
       heads=st.sampled_from([(4, 4), (4, 2), (8, 1)]))
def test_flash_attention_matches_oracle(sq, sk, d, causal, window, heads):
    if causal and sk != sq:
        sk = sq  # causal masks assume aligned positions
    hq, hkv = heads
    key = jax.random.key(sq * 31 + sk)
    q = jax.random.normal(key, (1, hq, sq, d), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, hkv, sk, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, hkv, sk, d))
    out = fa_ops.flash_attention(q, k, v, causal, window, None)
    expect = fa_ref.attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=3e-4, atol=3e-4)


def test_flash_attention_gradient_path():
    key = jax.random.key(9)
    q = jax.random.normal(key, (1, 2, 128, 32))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 2, 128, 32))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 2, 128, 32))

    def loss_kernel(q_):
        return fa_ops.flash_attention(q_, k, v, True, 0, None).sum()

    def loss_ref(q_):
        return fa_ref.attention(q_, k, v, causal=True).sum()

    g_k = jax.grad(loss_kernel)(q)
    g_r = jax.grad(loss_ref)(q)
    np.testing.assert_allclose(np.asarray(g_k), np.asarray(g_r),
                               rtol=3e-4, atol=3e-4)


@given(q_chunk=st.sampled_from([64, 128, 256]), causal=st.booleans(),
       window=st.sampled_from([0, 48]))
def test_chunked_attention_matches_dense(q_chunk, causal, window):
    key = jax.random.key(q_chunk)
    q = jax.random.normal(key, (1, 2, 512, 32))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 2, 512, 32))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 2, 512, 32))
    a = fa_ref.attention(q, k, v, causal=causal, window=window)
    b = fa_ref.attention_chunked(q, k, v, causal=causal, window=window,
                                 q_chunk=q_chunk)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-5, atol=2e-5)
