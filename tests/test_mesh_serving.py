"""2-D (data, model) serving mesh (DESIGN.md §12).

Contracts under test:
  · mesh geometry is invisible in results: every (data, model) layout —
    immutable and mutable, filtered and not — returns doc ids/scores
    bit-identical to the single-device server;
  · the serving runtime over a mesh keeps the §10 compile ledger (one
    program per bucket per mesh, never per replica) and round-robins
    computed rows across every data-axis replica;
  · shard loss degrades instead of failing: after ejecting a model-axis
    shard, results come from the survivors' document ranges flagged
    ``partial=True``, equal to a full-corpus oracle with the lost range
    tombstoned; rejoin from checkpoint restores bit-identical full
    results and every membership change bumps the cache epoch.

Multi-device cases spawn a fresh interpreter with
xla_force_host_platform_device_count (the tests/test_sharded.py
pattern); policy/validation checks run in-process on 1 device.
"""
import os
import subprocess
import sys

import pytest

from repro.launch import runtime as rt_mod

_ENV = dict(os.environ,
            XLA_FLAGS="--xla_force_host_platform_device_count=4",
            PYTHONPATH=os.environ.get("PYTHONPATH", "src"))

_PRELUDE = """
import jax, jax.numpy as jnp, numpy as np
from repro.core import hybrid_index as hi, segments as seg
from repro.core import sharded_index as shi
from repro.launch import serve
from repro.data import synthetic

assert jax.device_count() == 4
corpus = synthetic.generate(seed=0, n_docs=3000, n_queries=48,
                            hidden=32, vocab_size=1024, n_topics=16)
KW = dict(n_clusters=32, k1_terms=6, codec="sq8",
          cluster_capacity=96, term_capacity=48, kmeans_iters=5)

def assert_equal(a, b):
    # full bit-identity: comparisons WITHIN one mesh geometry
    np.testing.assert_array_equal(np.asarray(a.doc_ids),
                                  np.asarray(b.doc_ids))
    np.testing.assert_array_equal(np.asarray(a.scores),
                                  np.asarray(b.scores))

def assert_match(a, b):
    # ACROSS geometries (DESIGN.md S12): doc ids are bit-identical, but
    # scores may differ by ~1 ulp — XLA picks a different kernel tiling
    # (hence reduction order) for the smaller per-replica row blocks
    np.testing.assert_array_equal(np.asarray(a.doc_ids),
                                  np.asarray(b.doc_ids))
    np.testing.assert_allclose(np.asarray(a.scores),
                               np.asarray(b.scores), rtol=0, atol=1e-5)
"""


def _run(script: str) -> None:
    r = subprocess.run([sys.executable, "-c", _PRELUDE + script], env=_ENV,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"


def test_mesh_geometries_bit_identical():
    """Every (data, model) geometry — including under per-query
    namespace filters — equals the single-device Server."""
    _run("""
ns = np.arange(3000) % 4
idx = hi.build(jax.random.key(0), jnp.asarray(corpus.doc_emb),
               jnp.asarray(corpus.doc_tokens), corpus.vocab_size,
               doc_namespaces=ns, **KW)
base = serve.Server(idx, serve.ServeConfig(max_batch=16, n_namespaces=4))
ref = base.query(corpus.query_emb[:16], corpus.query_tokens[:16])
want = [i % 4 for i in range(16)]
ref_f = base.query(corpus.query_emb[:16], corpus.query_tokens[:16],
                   namespaces=want)
for d, m in ((2, 1), (4, 1), (2, 2), (1, 4)):
    cfg = serve.ServeConfig(max_batch=16, n_shards=m, data_parallel=d,
                            n_namespaces=4)
    srv = (serve.MeshServer(idx, cfg) if d > 1
           else serve.make_server(idx, cfg))
    out = srv.query(corpus.query_emb[:16], corpus.query_tokens[:16])
    assert_match(ref, out)
    assert out.partial is False
    out_f = srv.query(corpus.query_emb[:16], corpus.query_tokens[:16],
                      namespaces=want)
    assert_match(ref_f, out_f)
    # ragged tail batch (pads to max_batch inside the server)
    assert_match(base.query(corpus.query_emb[16:27],
                            corpus.query_tokens[16:27]),
                 srv.query(corpus.query_emb[16:27],
                           corpus.query_tokens[16:27]))
""")


def test_mutable_mesh_2d_bit_identical():
    """ShardedMutableServer on a (2, 2) mesh: add/delete/compact and
    search equal to the single-device MutableServer throughout."""
    _run("""
def build_mut():
    return seg.MutableHybridIndex.create(
        jax.random.key(0), corpus.doc_emb[:-64], corpus.doc_tokens[:-64],
        corpus.vocab_size, delta_capacity=64, **KW)

ref = serve.make_mutable_server(build_mut(), serve.ServeConfig(
    max_batch=16, mutable=True))
mesh2d = serve.make_mutable_server(build_mut(), serve.ServeConfig(
    max_batch=16, mutable=True, n_shards=2, data_parallel=2))
assert type(mesh2d).__name__ == "ShardedMutableServer"
assert mesh2d.mut.data_axis == "data"
for srv in (ref, mesh2d):
    ids = srv.add(corpus.doc_emb[-64:], corpus.doc_tokens[-64:])
    srv.delete(ids[:16])
assert_match(ref.query(corpus.query_emb[:16], corpus.query_tokens[:16]),
             mesh2d.query(corpus.query_emb[:16], corpus.query_tokens[:16]))
ref.compact(); mesh2d.compact()
assert_match(ref.query(corpus.query_emb[:16], corpus.query_tokens[:16]),
             mesh2d.query(corpus.query_emb[:16], corpus.query_tokens[:16]))
""")


def test_runtime_over_mesh_compiles_and_round_robin():
    """One compile per bucket per MESH (not per replica), zero serving
    compiles, computed rows round-robined across both replicas, and
    runtime rows bit-identical to direct mesh serving."""
    _run("""
from repro.launch import runtime as rt_mod
idx = hi.build(jax.random.key(0), jnp.asarray(corpus.doc_emb),
               jnp.asarray(corpus.doc_tokens), corpus.vocab_size, **KW)
srv = serve.make_server(idx, serve.ServeConfig(
    max_batch=16, n_shards=2, data_parallel=2))
assert type(srv).__name__ == "MeshServer" and srv.n_replicas == 2
rt = rt_mod.ServingRuntime(srv, rt_mod.RuntimeConfig())
assert rt.buckets == (4, 8, 16)      # quantum-2 ladder
rt.warmup(32, corpus.query_tokens.shape[1])
assert all(n == 1 for n in rt.warm_traces.values()), rt.warm_traces
with rt:
    for n in (1, 3, 16, 7, 2):
        rt.query(corpus.query_emb[:n], corpus.query_tokens[:n])
    rt.assert_one_compile_per_bucket()
    disp = rt.stats()["replica_dispatch"]
    assert set(disp) == {0, 1} and all(v > 0 for v in disp.values()), disp
    assert sum(disp.values()) == rt.n_served == 29
    direct = srv.query(corpus.query_emb[:16], corpus.query_tokens[:16])
    assert_equal(direct, rt.query(corpus.query_emb[:16],
                                  corpus.query_tokens[:16]))
""")


def test_shard_loss_degrades_and_rejoins_bit_identically():
    """The failover drill: eject -> partial results from the survivor
    ranges (equal to the tombstoned-oracle), runtime carries the flag
    and the epoch bump blocks stale cache replay, rejoin-from-checkpoint
    restores bit-identical full results."""
    _run("""
import tempfile
from repro.launch import runtime as rt_mod
idx = hi.build(jax.random.key(0), jnp.asarray(corpus.doc_emb),
               jnp.asarray(corpus.doc_tokens), corpus.vocab_size, **KW)
srv = serve.MeshServer(idx, serve.ServeConfig(
    max_batch=16, n_shards=2, data_parallel=2))
qe, qt = corpus.query_emb[:16], corpus.query_tokens[:16]
full = srv.query(qe, qt)
assert srv.epoch == 0 and not srv.partial

rt = rt_mod.ServingRuntime(srv, rt_mod.RuntimeConfig(cache_size=64))
rt.warmup(32, qt.shape[1])
pre = rt.query(qe, qt)
assert not pre.partial

with tempfile.TemporaryDirectory() as td:
    path = srv.checkpoint(td)
    srv.eject_shard(0)
    assert srv.partial and srv.epoch == 1
    assert srv.lost_doc_ranges() == [(0, 1500)]
    degraded = srv.query(qe, qt)
    assert degraded.partial is True
    ids = np.asarray(degraded.doc_ids)
    assert (ids[ids >= 0] >= 1500).all()      # nothing from the lost range

    # oracle: the full corpus with the lost range tombstoned (same build
    # key -> same base index; DESIGN.md S12 degradation contract)
    mut = seg.MutableHybridIndex.create(
        jax.random.key(0), corpus.doc_emb, corpus.doc_tokens,
        corpus.vocab_size, delta_capacity=16, **KW)
    mut.delete_docs(np.arange(0, 1500))
    oracle = serve.make_mutable_server(mut, serve.ServeConfig(
        max_batch=16, mutable=True))
    assert_match(oracle.query(qe, qt), degraded)

    # the runtime serves the degraded mesh: partial flag on every row,
    # and the epoch bump means NO replay of pre-failure cached rows
    hits0 = rt.cache.hits
    via_rt = rt.query(qe, qt)
    assert via_rt.partial is True and rt.cache.hits == hits0
    assert_equal(degraded, via_rt)

    # ejecting the last survivor is refused
    try:
        srv.eject_shard(1)
        raise SystemExit("ejecting the last healthy shard must fail")
    except ValueError:
        pass

    srv.rejoin(path)
assert not srv.partial and srv.epoch == 2
restored = srv.query(qe, qt)
assert restored.partial is False
assert_equal(full, restored)
post = rt.query(qe, qt)
assert not post.partial
assert_equal(full, post)
""")


def test_straggler_feed_ejects_through_the_server():
    """note_shard_latency wires fault.ShardHealth into serving: a shard
    consistently missing the rolling-median deadline is ejected after
    MAX_STRIKES, and the mesh keeps serving (partial=True)."""
    _run("""
idx = hi.build(jax.random.key(0), jnp.asarray(corpus.doc_emb),
               jnp.asarray(corpus.doc_tokens), corpus.vocab_size, **KW)
srv = serve.MeshServer(idx, serve.ServeConfig(
    max_batch=16, n_shards=2, data_parallel=1))
for _ in range(10):                    # healthy baseline for the median
    for shard in (0, 1):
        assert not srv.note_shard_latency(shard, 0.1)
ejected = False
for _ in range(5):                     # shard 1 straggles at 10x median
    srv.note_shard_latency(0, 0.1)
    if srv.note_shard_latency(1, 1.0):
        ejected = True
        break
assert ejected and srv.health.lost == [1] and srv.partial
res = srv.query(corpus.query_emb[:16], corpus.query_tokens[:16])
assert res.partial is True
ids = np.asarray(res.doc_ids)
assert (ids[ids >= 0] < 1500).all()    # only shard 0's range
""")


# --------------------------------------------------------------------------
# in-process validation (1 device)
# --------------------------------------------------------------------------

def test_serving_mesh_validation():
    from repro.launch import mesh as mesh_mod

    with pytest.raises(ValueError, match=">= 1"):
        mesh_mod.make_serving_mesh(0, 2)
    with pytest.raises(RuntimeError, match="device_count"):
        mesh_mod.make_serving_mesh(4, 4)    # 16 devices on a 1-device host


def test_mesh_server_rejects_indivisible_batch():
    from repro.launch import serve

    with pytest.raises(ValueError, match="divide"):
        serve.MeshServer(None, serve.ServeConfig(max_batch=16,
                                                 data_parallel=3))


def test_runtime_quantum_follows_server_replicas():
    class _Cfg:
        max_batch = 32
        n_namespaces = 0

    class _FakeMeshServer:
        cfg = _Cfg()
        n_replicas = 4

    rt = rt_mod.ServingRuntime(_FakeMeshServer())
    assert rt.n_replicas == 4
    assert rt.buckets == (8, 16, 32)
    # round-robin placement: injective, replica-major blocks
    place = rt._rows_idx(6, 8)
    assert place == [0, 2, 4, 6, 1, 3]
    assert rt._rows_idx(5, 8)[:4] == [0, 2, 4, 6]
