"""Equality tests for the §Perf optimized implementations: every
hillclimb variant must produce the same numbers as its paper-faithful
baseline (multi-device variants run in subprocesses)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import moe

_ENV = dict(os.environ,
            XLA_FLAGS="--xla_force_host_platform_device_count=8",
            PYTHONPATH=os.environ.get("PYTHONPATH", "src"))


def _run(script: str) -> None:
    r = subprocess.run([sys.executable, "-c", script], env=_ENV,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"


def test_moe_matches_dense_expert_oracle():
    """Capacity-dispatch MoE == per-token dense expert mixture when no
    tokens drop (the MoE layer's ground-truth semantics)."""
    mp = moe.init(jax.random.key(0), 16, 32, 4)
    x = jax.random.normal(jax.random.key(5), (2, 8, 16))
    out, stats = moe.forward(mp, x, n_experts=4, top_k=2,
                             capacity_factor=4.0)
    assert float(stats.dropped_frac) == 0.0
    logits = x.reshape(-1, 16) @ mp["router"]["w"]
    probs = jax.nn.softmax(logits, -1)
    tw, te = jax.lax.top_k(probs, 2)
    tw = tw / tw.sum(-1, keepdims=True)
    xs = x.reshape(-1, 16)
    all_out = jnp.stack(
        [(jax.nn.silu(xs @ mp["w_gate"][e]) * (xs @ mp["w_up"][e]))
         @ mp["w_down"][e] for e in range(4)], 1)
    oracle = (all_out[jnp.arange(16)[:, None], te]
              * tw[..., None]).sum(1).reshape(2, 8, 16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                               rtol=2e-4, atol=2e-4)


def test_moe_capacity_drops_tokens():
    mp = moe.init(jax.random.key(0), 16, 32, 4)
    x = jax.random.normal(jax.random.key(5), (4, 32, 16))
    _, stats = moe.forward(mp, x, n_experts=4, top_k=2,
                           capacity_factor=0.25)
    assert float(stats.dropped_frac) > 0.0


def test_shard_map_moe_equals_gspmd():
    _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.models import moe
from repro.distributed import compat, sharding as shd
mesh = compat.make_mesh((2, 4), ("data", "model"))
mp = moe.init(jax.random.key(0), 32, 64, 4)
x = jax.random.normal(jax.random.key(5), (4, 16, 32))
ref_out, _ = moe.forward(mp, x, n_experts=4, top_k=2, capacity_factor=8.0)
with shd.use_mesh(mesh):
    xs = jax.device_put(x, NamedSharding(mesh, P("data", None, None)))
    mps = jax.device_put(mp, jax.tree.map(lambda _: NamedSharding(mesh, P()), mp))
    out, _ = jax.jit(lambda m, xx: moe.forward_shard_map(
        m, xx, n_experts=4, top_k=2, capacity_factor=8.0))(mps, xs)
    g = jax.jit(jax.grad(lambda m: moe.forward_shard_map(
        m, xs, n_experts=4, top_k=2, capacity_factor=8.0)[0].sum()))(mps)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out),
                           rtol=3e-4, atol=3e-4)
assert all(np.isfinite(np.asarray(v)).all() for v in jax.tree.leaves(g))
""")


def test_partitioned_gnn_equals_baseline():
    _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.models import gnn
from repro.data import graph as gdata
from repro.distributed import compat, sharding as shd
cfg = gnn.GatedGCNConfig(n_layers=3, d_hidden=16, d_feat=8, n_classes=4,
                         remat=False)
params = gnn.init(jax.random.key(0), cfg)
g = gdata.random_graph(0, n_nodes=200, n_edges=900, d_feat=8, n_classes=4)
ref, _ = gnn.loss_fn(params, cfg, g)
mesh = compat.make_mesh((2, 4), ("data", "model"))
gp = gdata.partition_by_dst(g, 8)
with shd.use_mesh(mesh):
    loss, _ = jax.jit(lambda p, b: gnn.loss_fn_partitioned(p, cfg, b))(params, gp)
    gr = jax.jit(jax.grad(lambda p: gnn.loss_fn_partitioned(p, cfg, gp)[0]))(params)
np.testing.assert_allclose(float(ref), float(loss), rtol=1e-5)
assert all(np.isfinite(np.asarray(v)).all() for v in jax.tree.leaves(gr))
""")


def test_partition_by_dst_preserves_all_edges():
    from repro.data import graph as gdata
    g = gdata.random_graph(3, n_nodes=100, n_edges=400, d_feat=4,
                           n_classes=2)
    gp = gdata.partition_by_dst(g, 4)
    # every real edge survives, with dst in the owning shard's range
    assert float(gp.edge_mask.sum()) == float(g.edge_mask.sum())
    n_local = gp.node_feat.shape[0] // 4
    e_local = gp.edge_src.shape[0] // 4
    dst = np.asarray(gp.edge_dst).reshape(4, e_local)
    mask = np.asarray(gp.edge_mask).reshape(4, e_local)
    for s in range(4):
        owned = dst[s][mask[s] > 0]
        assert ((owned >= s * n_local) & (owned < (s + 1) * n_local)).all()


def test_rolling_cache_decode_long_context():
    """SWA decode at position far beyond the window (long_500k regime):
    rolling cache matches full-cache attention."""
    from repro.models import transformer as tfm
    cfg = tfm.TransformerConfig(n_layers=2, d_model=32, n_heads=2,
                                n_kv_heads=2, d_ff=64, vocab_size=64,
                                window=6, compute_dtype=jnp.float32,
                                remat=False)
    p = tfm.init(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (1, 40), 0, 64)
    # oracle: full forward logits at the last position
    full, _ = tfm.logits_fn(p, cfg, toks)
    # rolling decode (cache capacity = window = 6 ≪ 40)
    caches = tfm.init_decode_caches(cfg, 1, 40)
    assert caches.k.shape[3] == 6
    lg = None
    for i in range(40):
        lg, caches = tfm.serve_step(p, cfg, caches, toks[:, i:i + 1],
                                    jnp.int32(i))
    np.testing.assert_allclose(np.asarray(full[:, -1]), np.asarray(lg[:, 0]),
                               rtol=2e-4, atol=2e-4)


def test_uint8_codes_search_identical_to_int32():
    """The §Perf uint8-codes optimization cannot change results."""
    import dataclasses
    from repro.core import hybrid_index as hi
    from repro.data import synthetic
    corpus = synthetic.generate(seed=0, n_docs=2000, n_queries=64,
                                hidden=32, vocab_size=1024)
    idx = hi.build(jax.random.key(0), jnp.asarray(corpus.doc_emb),
                   jnp.asarray(corpus.doc_tokens), corpus.vocab_size,
                   n_clusters=32, k1_terms=6, codec="opq", pq_m=4, pq_k=64,
                   cluster_capacity=128, term_capacity=64, kmeans_iters=5)
    assert idx.doc_codes.dtype == jnp.uint8
    idx32 = dataclasses.replace(
        idx, doc_planes={**idx.doc_planes,
                         "codes": idx.doc_codes.astype(jnp.int32)})
    qe = jnp.asarray(corpus.query_emb)
    qt = jnp.asarray(corpus.query_tokens)
    a = hi.search(idx, qe, qt, kc=4, k2=4, top_r=20)
    b = hi.search(idx32, qe, qt, kc=4, k2=4, top_r=20)
    np.testing.assert_array_equal(np.asarray(a.doc_ids),
                                  np.asarray(b.doc_ids))
    np.testing.assert_array_equal(np.asarray(a.scores),
                                  np.asarray(b.scores))
