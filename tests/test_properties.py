"""Property-based tests (hypothesis) on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # accelerator image: no pip installs; CI has the real one
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import hybrid_index as hi, inverted_lists as il
from repro.data import synthetic

settings.register_profile("props", max_examples=8, deadline=None)
settings.load_profile("props")


@pytest.fixture(scope="module")
def small_index():
    corpus = synthetic.generate(seed=7, n_docs=3000, n_queries=64,
                                hidden=32, vocab_size=1024, n_topics=32)
    idx = hi.build(jax.random.key(0), jnp.asarray(corpus.doc_emb),
                   jnp.asarray(corpus.doc_tokens), corpus.vocab_size,
                   n_clusters=48, k1_terms=6, codec="opq", pq_m=4, pq_k=64,
                   cluster_capacity=128, term_capacity=64, kmeans_iters=5)
    return corpus, idx


@given(kc=st.integers(1, 8), k2=st.integers(1, 8), top_r=st.integers(1, 64))
def test_search_invariants(small_index, kc, k2, top_r):
    corpus, idx = small_index
    qe = jnp.asarray(corpus.query_emb[:16])
    qt = jnp.asarray(corpus.query_tokens[:16])
    res = hi.search(idx, qe, qt, kc=kc, k2=k2, top_r=top_r)
    ids = np.asarray(res.doc_ids)
    scores = np.asarray(res.scores)
    n_docs = corpus.doc_emb.shape[0]
    for q in range(ids.shape[0]):
        valid = ids[q][ids[q] != il.PAD_DOC]
        # unique results, in-range ids
        assert len(set(valid.tolist())) == len(valid)
        assert ((valid >= 0) & (valid < n_docs)).all()
        # scores sorted descending over valid prefix
        vs = scores[q][:len(valid)]
        assert np.all(np.diff(vs) <= 1e-5)
    # candidate count bounded by the static budget
    assert int(np.asarray(res.n_candidates).max()) <= \
        hi.candidate_budget(idx, kc, k2)


@pytest.fixture(scope="module")
def flat_index(small_index):
    corpus, _ = small_index
    return hi.build(jax.random.key(1), jnp.asarray(corpus.doc_emb),
                    jnp.asarray(corpus.doc_tokens), corpus.vocab_size,
                    n_clusters=48, k1_terms=6, codec="flat",
                    cluster_capacity=128, term_capacity=64, kmeans_iters=5)


@given(kc=st.integers(1, 6), k2=st.integers(1, 6))
def test_widening_dispatch_never_hurts_recall(small_index, flat_index,
                                              kc, k2):
    """Monotonicity under EXACT scoring: a superset of dispatched lists ⇒
    recall cannot drop. (hypothesis originally REFUTED this for the PQ
    codec — approximate scores can rank new candidates above the true
    positive — so the theorem is asserted where it holds: Flat codec.)"""
    from repro.core import metrics
    corpus, _ = small_index
    idx = flat_index
    qe = jnp.asarray(corpus.query_emb)
    qt = jnp.asarray(corpus.query_tokens)
    narrow = hi.search(idx, qe, qt, kc=kc, k2=k2, top_r=200)
    wide = hi.search(idx, qe, qt, kc=kc + 4, k2=k2 + 4, top_r=200)
    r_n = metrics.recall_at_k(narrow.doc_ids, corpus.qrels, 200)
    r_w = metrics.recall_at_k(wide.doc_ids, corpus.qrels, 200)
    assert r_w >= r_n - 1e-9


@given(n=st.integers(10, 200), n_lists=st.integers(2, 12))
def test_dedup_mask_is_exact_set_semantics(n, n_lists):
    rng = np.random.default_rng(n * n_lists)
    cands = rng.integers(-1, 50, size=(3, n)).astype(np.int32)
    keep = np.asarray(il.dedup_mask(jnp.asarray(cands)))
    for row in range(3):
        kept = cands[row][keep[row]]
        expected = set(cands[row][cands[row] != il.PAD_DOC].tolist())
        assert set(kept.tolist()) == expected
        assert len(kept) == len(expected)


@given(seed=st.integers(0, 5))
def test_flat_codec_search_contains_embedding_topk_of_candidates(
        small_index, seed):
    """With the Flat codec, the returned order equals exact inner-product
    order restricted to the candidate set."""
    corpus, _ = small_index
    idx = hi.build(jax.random.key(seed), jnp.asarray(corpus.doc_emb),
                   jnp.asarray(corpus.doc_tokens), corpus.vocab_size,
                   n_clusters=48, k1_terms=6, codec="flat",
                   cluster_capacity=128, term_capacity=64, kmeans_iters=3)
    qe = jnp.asarray(corpus.query_emb[:4])
    qt = jnp.asarray(corpus.query_tokens[:4])
    res = hi.search(idx, qe, qt, kc=4, k2=4, top_r=10)
    ids = np.asarray(res.doc_ids)
    scores = np.asarray(res.scores)
    emb = np.asarray(corpus.doc_emb)
    q = np.asarray(corpus.query_emb[:4])
    for i in range(4):
        valid = ids[i][ids[i] != il.PAD_DOC]
        expect = q[i] @ emb[valid].T
        np.testing.assert_allclose(scores[i][:len(valid)], expect,
                                   rtol=1e-4, atol=1e-4)
