"""The serving runtime (DESIGN.md §10): bucketed micro-batching, the
epoch-keyed LRU result cache, and admission control.

Contracts under test:
  · a query submitted through the runtime resolves to rows bit-identical
    to the same query through ``Server.query`` — cached or uncached,
    and across a mutation (the epoch bump must recompute, not replay);
  · warmup compiles exactly one program per bucket and serving compiles
    nothing further (``repro.core.exec.trace_count`` accounting);
  · completion order is FIFO for queued requests, including under
    backpressure (accepted requests complete in submission order,
    excess submissions fail fast with a retry-after hint);
  · ``close(drain=True)`` completes every accepted request — none
    dropped, none stranded.
"""
import inspect
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hybrid_index as hi
from repro.core import segments as seg
from repro.data import synthetic
from repro.launch import runtime as rt_mod
from repro.launch import serve


def _corpus():
    return synthetic.generate(seed=0, n_docs=1400, n_queries=24, hidden=32,
                              vocab_size=512, n_topics=8)


_KW = dict(n_clusters=16, k1_terms=4, codec="pq", pq_m=4, pq_k=64,
           cluster_capacity=64, term_capacity=32, kmeans_iters=3)


def _plain_server(c, max_batch=16, n_namespaces=0):
    ns = (None if not n_namespaces
          else np.arange(c.doc_emb.shape[0]) % n_namespaces)
    idx = hi.build(jax.random.key(0), jnp.asarray(c.doc_emb),
                   jnp.asarray(c.doc_tokens), c.vocab_size,
                   doc_namespaces=ns, **_KW)
    return serve.make_server(idx, serve.ServeConfig(
        max_batch=max_batch, n_namespaces=n_namespaces))


def _mutable_server(c, max_batch=16, hold=64):
    mut = seg.MutableHybridIndex.create(
        jax.random.key(0), c.doc_emb[:-hold], c.doc_tokens[:-hold],
        c.vocab_size, delta_capacity=hold, **_KW)
    return serve.make_mutable_server(
        mut, serve.ServeConfig(max_batch=max_batch, mutable=True))


def _runtime(server, c, **cfg):
    rt = rt_mod.ServingRuntime(server, rt_mod.RuntimeConfig(**cfg))
    rt.warmup(c.query_emb.shape[1], c.query_tokens.shape[1])
    return rt


def _rows_equal(row, batch_res, i):
    np.testing.assert_array_equal(np.asarray(row.doc_ids),
                                  np.asarray(batch_res.doc_ids)[i])
    np.testing.assert_array_equal(np.asarray(row.scores),
                                  np.asarray(batch_res.scores)[i])
    assert int(row.n_candidates) == int(
        np.asarray(batch_res.n_candidates)[i])


# --------------------------------------------------------------------------
# bit-identity: runtime rows == Server.query rows, cached and uncached
# --------------------------------------------------------------------------

def test_runtime_rows_bit_identical_to_server_query():
    c = _corpus()
    server = _plain_server(c)
    direct = server.query(c.query_emb[:8], c.query_tokens[:8])
    with _runtime(server, c) as rt:
        futures = [rt.submit(c.query_emb[i], c.query_tokens[i])
                   for i in range(8)]
        for i, f in enumerate(futures):
            _rows_equal(f.result(timeout=60), direct, i)
        # the batched convenience wrapper reassembles the same rows
        again = rt.query(c.query_emb[:8], c.query_tokens[:8])
        np.testing.assert_array_equal(np.asarray(again.doc_ids),
                                      np.asarray(direct.doc_ids)[:8])
        np.testing.assert_array_equal(np.asarray(again.scores),
                                      np.asarray(direct.scores)[:8])


def test_runtime_filtered_rows_bit_identical():
    c = _corpus()
    server = _plain_server(c, n_namespaces=4)
    want = [i % 4 for i in range(8)]
    direct = server.query(c.query_emb[:8], c.query_tokens[:8],
                          namespaces=want)
    with _runtime(server, c) as rt:
        got = rt.query(c.query_emb[:8], c.query_tokens[:8],
                       namespaces=want)
        np.testing.assert_array_equal(np.asarray(got.doc_ids),
                                      np.asarray(direct.doc_ids))
        np.testing.assert_array_equal(np.asarray(got.scores),
                                      np.asarray(direct.scores))
        # unfiltered requests on a namespaced server ride an allow-all
        # bitmap row — a bitwise no-op vs Server.query's filter=None
        plain = server.query(c.query_emb[:8], c.query_tokens[:8])
        got2 = rt.query(c.query_emb[:8], c.query_tokens[:8])
        np.testing.assert_array_equal(np.asarray(got2.doc_ids),
                                      np.asarray(plain.doc_ids))
        np.testing.assert_array_equal(np.asarray(got2.scores),
                                      np.asarray(plain.scores))


def test_cache_hit_is_bit_identical_and_epoch_bump_invalidates():
    c = _corpus()
    server = _mutable_server(c)
    with _runtime(server, c, cache_size=64) as rt:
        first = rt.query(c.query_emb[:4], c.query_tokens[:4])
        hits0 = rt.cache.hits
        again = rt.query(c.query_emb[:4], c.query_tokens[:4])
        assert rt.cache.hits == hits0 + 4
        np.testing.assert_array_equal(np.asarray(first.doc_ids),
                                      np.asarray(again.doc_ids))
        np.testing.assert_array_equal(np.asarray(first.scores),
                                      np.asarray(again.scores))
        # cached rows equal a fresh direct query
        direct = server.query(c.query_emb[:4], c.query_tokens[:4])
        np.testing.assert_array_equal(np.asarray(again.doc_ids),
                                      np.asarray(direct.doc_ids)[:4])

        # mutation bumps the epoch: the same queries must MISS and
        # re-execute against the mutated index
        epoch0 = server.epoch
        rt.add(c.doc_emb[-16:], c.doc_tokens[-16:])
        assert server.epoch == epoch0 + 1
        hits1, misses1 = rt.cache.hits, rt.cache.misses
        post = rt.query(c.query_emb[:4], c.query_tokens[:4])
        assert rt.cache.hits == hits1         # no stale replay
        assert rt.cache.misses == misses1 + 4
        direct_post = server.query(c.query_emb[:4], c.query_tokens[:4])
        np.testing.assert_array_equal(np.asarray(post.doc_ids),
                                      np.asarray(direct_post.doc_ids)[:4])
        np.testing.assert_array_equal(np.asarray(post.scores),
                                      np.asarray(direct_post.scores)[:4])


def test_use_kernel_serving_matches_unfused_and_cache_replays_it():
    """``--use-kernel`` threads ``ServeConfig.use_kernel`` into the
    fused Pallas scoring path (DESIGN.md §11).  Served rows must agree
    with the unfused server within the documented 1e-4 scoring
    tolerance (doc ids bit-identical at this scale), and a cache hit
    must replay the fused rows bit-identically."""
    c = _corpus()
    idx = hi.build(jax.random.key(0), jnp.asarray(c.doc_emb),
                   jnp.asarray(c.doc_tokens), c.vocab_size, **_KW)
    fused = serve.make_server(idx, serve.ServeConfig(use_kernel=True))
    plain = serve.make_server(idx, serve.ServeConfig())
    direct = plain.query(c.query_emb[:4], c.query_tokens[:4])
    with _runtime(fused, c, cache_size=32) as rt:
        first = rt.query(c.query_emb[:4], c.query_tokens[:4])
        np.testing.assert_array_equal(np.asarray(first.doc_ids),
                                      np.asarray(direct.doc_ids)[:4])
        np.testing.assert_allclose(np.asarray(first.scores),
                                   np.asarray(direct.scores)[:4],
                                   rtol=1e-4, atol=1e-4)
        hits0 = rt.cache.hits
        again = rt.query(c.query_emb[:4], c.query_tokens[:4])
        assert rt.cache.hits == hits0 + 4
        np.testing.assert_array_equal(np.asarray(first.doc_ids),
                                      np.asarray(again.doc_ids))
        np.testing.assert_array_equal(np.asarray(first.scores),
                                      np.asarray(again.scores))
    # the flag is reachable from the CLI, not just the library surface
    src = inspect.getsource(serve.main)
    assert "--use-kernel" in src and "use_kernel=args.use_kernel" in src


def test_compaction_through_runtime_rewarms_off_the_request_path():
    """compact() rebuilds the base with new plane shapes — the §8
    one-recompile-per-compaction must land in the runtime's re-warm,
    not on the next request of every bucket (which would trip the
    compile ledger)."""
    c = _corpus()
    server = _mutable_server(c)
    with _runtime(server, c, cache_size=16) as rt:
        rt.query(c.query_emb[:4], c.query_tokens[:4])
        rt.add(c.doc_emb[-8:], c.doc_tokens[-8:])
        rt.compact()
        post = rt.query(c.query_emb[:4], c.query_tokens[:4])
        assert rt.serve_traces == 0            # requests never compile
        rt.assert_one_compile_per_bucket()
        direct = server.query(c.query_emb[:4], c.query_tokens[:4])
        np.testing.assert_array_equal(np.asarray(post.doc_ids),
                                      np.asarray(direct.doc_ids)[:4])
        np.testing.assert_array_equal(np.asarray(post.scores),
                                      np.asarray(direct.scores)[:4])


def test_warmup_revives_a_closed_runtime():
    c = _corpus()
    server = _plain_server(c)
    rt = _runtime(server, c)
    first = rt.query(c.query_emb[:2], c.query_tokens[:2])
    rt.close(drain=True)
    with pytest.raises(rt_mod.RuntimeClosed):
        rt.submit(c.query_emb[0], c.query_tokens[0])
    rt.warmup(c.query_emb.shape[1], c.query_tokens.shape[1])
    again = rt.query(c.query_emb[:2], c.query_tokens[:2])
    np.testing.assert_array_equal(np.asarray(first.doc_ids),
                                  np.asarray(again.doc_ids))
    rt.close()


def test_done_callback_may_reenter_submit():
    """concurrent.futures runs done-callbacks inline on the resolving
    thread (the scheduler); a callback that submits a follow-up query —
    the natural pipelined-client pattern — must not deadlock."""
    c = _corpus()
    server = _plain_server(c)
    with _runtime(server, c, cache_size=8) as rt:
        chained, attached = [], threading.Event()

        def follow_up(_):
            chained.append(rt.submit(c.query_emb[1], c.query_tokens[1]))
            attached.set()

        f = rt.submit(c.query_emb[0], c.query_tokens[0])
        f.add_done_callback(follow_up)
        f.result(timeout=60)
        # the chained submit (issued from whichever thread ran the
        # callback — possibly the scheduler) completes, not deadlocks
        assert attached.wait(timeout=60)
        direct = server.query(c.query_emb[:2], c.query_tokens[:2])
        _rows_equal(chained[0].result(timeout=60), direct, 1)


def test_epoch_counter_semantics():
    c = _corpus()
    server = _mutable_server(c)
    assert server.epoch == 0
    ids = server.add(c.doc_emb[-8:], c.doc_tokens[-8:])
    assert server.epoch == 1
    server.delete(ids[:2])
    assert server.epoch == 2
    server.compact()
    assert server.epoch == 3     # compaction renumbers -> must invalidate
    plain = _plain_server(c)
    assert plain.epoch == 0      # immutable: never invalidates
    # the counter travels with checkpoint state: a restored index keeps
    # invalidating epoch-keyed caches where the saved one left off
    mut = server.mut
    back = seg.MutableHybridIndex.from_state(mut.state_tree(),
                                             mut.state_extra())
    assert back.epoch == mut.epoch == 3


def test_cancelled_future_does_not_poison_the_batch():
    """A client that cancel()s while queued must neither receive a
    result nor break co-riders in the same batch (the scheduler claims
    futures via set_running_or_notify_cancel before executing)."""
    c = _corpus()
    server = _plain_server(c, max_batch=4)
    rt = rt_mod.ServingRuntime(
        server, rt_mod.RuntimeConfig(linger_ms=300.0))
    rt.warmup(c.query_emb.shape[1], c.query_tokens.shape[1])
    futures = [rt.submit(c.query_emb[i], c.query_tokens[i])
               for i in range(3)]
    cancelled = futures[1].cancel()    # still queued (300ms linger)
    rt.close(drain=True)
    direct = server.query(c.query_emb[:4], c.query_tokens[:4])
    for i in (0, 2):
        _rows_equal(futures[i].result(timeout=60), direct, i)
    if cancelled:                      # raced the scheduler: either way,
        assert futures[1].cancelled()  # the future is terminal
    else:
        _rows_equal(futures[1].result(timeout=60), direct, 1)


# --------------------------------------------------------------------------
# compile accounting: one program per bucket, none after warmup
# --------------------------------------------------------------------------

def test_one_compile_per_bucket_and_none_while_serving():
    c = _corpus()
    # odd max_batch: the ladder must top out at max_batch itself
    server = _plain_server(c, max_batch=12)
    rt = rt_mod.ServingRuntime(server, rt_mod.RuntimeConfig())
    assert rt.buckets == (2, 4, 8, 12)
    rt.warmup(c.query_emb.shape[1], c.query_tokens.shape[1])
    # <= 1 compile per bucket (== 1 unless another test already
    # compiled the same shape in this process)
    assert all(n <= 1 for n in rt.warm_traces.values()), rt.warm_traces
    with rt:
        for n in (1, 3, 5, 12, 7, 2):
            rt.query(c.query_emb[:n], c.query_tokens[:n])
        assert rt.serve_traces == 0
        rt.assert_one_compile_per_bucket()
        # every request landed in a warmed bucket
        assert sum(rt.bucket_counts.values()) == rt.n_batches


def test_bucket_ladder_shapes():
    assert rt_mod.bucket_sizes(64) == (2, 4, 8, 16, 32, 64)
    assert rt_mod.bucket_sizes(48) == (2, 4, 8, 16, 32, 48)
    assert rt_mod.bucket_sizes(2) == (2,)
    assert rt_mod.bucket_sizes(1) == (1,)
    assert rt_mod.bucket_sizes(8, min_bucket=4) == (4, 8)
    with pytest.raises(ValueError):
        rt_mod.bucket_sizes(0)
    # the batch quantum of a 2-D mesh server (DESIGN.md §12): every
    # rung must split into equal per-replica row blocks
    assert rt_mod.bucket_sizes(64, quantum=2) == (4, 8, 16, 32, 64)
    assert rt_mod.bucket_sizes(32, quantum=4) == (8, 16, 32)
    assert rt_mod.bucket_sizes(4, quantum=4) == (4,)
    with pytest.raises(ValueError, match="quantum"):
        rt_mod.bucket_sizes(30, quantum=4)


# --------------------------------------------------------------------------
# admission control: FIFO under backpressure, fail-fast rejection
# --------------------------------------------------------------------------

def test_fifo_completion_under_backpressure():
    c = _corpus()
    server = _plain_server(c, max_batch=4)
    rt = rt_mod.ServingRuntime(
        server, rt_mod.RuntimeConfig(queue_depth=6, linger_ms=50.0))
    rt.warmup(c.query_emb.shape[1], c.query_tokens.shape[1])
    done_order = []
    lock = threading.Lock()

    def _track(i):
        def cb(_):
            with lock:
                done_order.append(i)
        return cb

    accepted, rejected = [], 0
    for i in range(24):
        try:
            f = rt.submit(c.query_emb[i % 24], c.query_tokens[i % 24])
        except rt_mod.RuntimeOverloaded as e:
            rejected += 1
            assert e.retry_after_ms > 0
            continue
        f.add_done_callback(_track(i))
        accepted.append((i, f))
    for _, f in accepted:
        f.result(timeout=60)
    assert rejected > 0                    # depth 6 must push back on 24
    assert rt.n_rejected == rejected
    # accepted requests complete in submission order (single scheduler,
    # FIFO batches, in-order resolution within a batch)
    assert done_order == [i for i, _ in accepted]
    rt.close()


def test_graceful_drain_leaves_no_dropped_requests():
    c = _corpus()
    server = _plain_server(c, max_batch=4)
    rt = rt_mod.ServingRuntime(
        server, rt_mod.RuntimeConfig(queue_depth=64, linger_ms=200.0))
    rt.warmup(c.query_emb.shape[1], c.query_tokens.shape[1])
    # long linger: the queue is still holding requests when close() lands
    futures = [rt.submit(c.query_emb[i], c.query_tokens[i])
               for i in range(16)]
    rt.close(drain=True)
    direct = hi.SearchResult(*[np.concatenate(planes) for planes in zip(
        *[server.query(c.query_emb[i:i + 4], c.query_tokens[i:i + 4])[:3]
          for i in range(0, 16, 4)])])
    for i, f in enumerate(futures):
        assert f.done()
        _rows_equal(f.result(), direct, i)
    with pytest.raises(rt_mod.RuntimeClosed):
        rt.submit(c.query_emb[0], c.query_tokens[0])


def test_close_without_drain_fails_pending_futures():
    c = _corpus()
    server = _plain_server(c, max_batch=4)
    rt = rt_mod.ServingRuntime(
        server, rt_mod.RuntimeConfig(linger_ms=500.0))
    rt.warmup(c.query_emb.shape[1], c.query_tokens.shape[1])
    futures = [rt.submit(c.query_emb[i], c.query_tokens[i])
               for i in range(6)]
    rt.close(drain=False)
    outcomes = []
    for f in futures:
        assert f.done()
        try:
            f.result()
            outcomes.append("ok")
        except rt_mod.RuntimeClosed:
            outcomes.append("closed")
    # every future resolved one way or the other — none stranded; and a
    # 500ms linger guarantees at least the tail was still pending
    assert "closed" in outcomes


def test_submit_validation():
    c = _corpus()
    server = _plain_server(c)                    # unfiltered
    rt = rt_mod.ServingRuntime(server, rt_mod.RuntimeConfig())
    with pytest.raises(rt_mod.RuntimeClosed, match="warmup"):
        rt.submit(c.query_emb[0], c.query_tokens[0])
    rt.warmup(c.query_emb.shape[1], c.query_tokens.shape[1])
    with rt:
        with pytest.raises(ValueError, match="namespaces"):
            rt.submit(c.query_emb[0], c.query_tokens[0], namespaces=1)
        with pytest.raises(ValueError, match="shapes"):
            rt.submit(c.query_emb[0][:8], c.query_tokens[0])
    # an out-of-range tenant id fails ITS request at submit; it must
    # never reach the scheduler where it would poison a whole batch
    server_ns = _plain_server(c, n_namespaces=4)
    with _runtime(server_ns, c) as rt:
        good = rt.submit(c.query_emb[0], c.query_tokens[0], namespaces=2)
        with pytest.raises(ValueError, match="out of range"):
            rt.submit(c.query_emb[1], c.query_tokens[1], namespaces=99)
        assert good.result(timeout=60).doc_ids.shape[0] > 0


# --------------------------------------------------------------------------
# normalized cache keys: scale-invariant hits, tenant/epoch safety
# --------------------------------------------------------------------------

def test_cache_key_normalization_scaled_query_hits():
    """The cache keys on the L2-normalized embedding quantized to
    CACHE_QUANT, so a positively scaled copy of a cached query (ranking
    is scale-invariant) hits and replays the representative's rows —
    while a genuinely different query never collides."""
    c = _corpus()
    server = _plain_server(c)
    with _runtime(server, c, cache_size=32) as rt:
        row = rt.submit(c.query_emb[0], c.query_tokens[0]).result(timeout=60)
        hits0 = rt.cache.hits
        scaled = rt.submit(np.float32(3.7) * c.query_emb[0],
                           c.query_tokens[0]).result(timeout=60)
        assert rt.cache.hits == hits0 + 1
        np.testing.assert_array_equal(np.asarray(row.doc_ids),
                                      np.asarray(scaled.doc_ids))
        np.testing.assert_array_equal(np.asarray(row.scores),
                                      np.asarray(scaled.scores))
    # distinct queries map to distinct keys at the documented quantum
    keys = {rt_mod._canon_qe(np.asarray(c.query_emb[i], np.float32))
            for i in range(c.query_emb.shape[0])}
    assert len(keys) == c.query_emb.shape[0]
    # zero-norm embeddings are keyable (no division blow-up)
    assert rt_mod._canon_qe(np.zeros(32, np.float32)) is not None


def test_cache_no_false_hits_across_tenants_or_mutations():
    """Namespace-safety of the normalized key: the same embedding under
    different tenant filters, or across a mutation epoch, must never
    replay the other's rows."""
    c = _corpus()
    server = _plain_server(c, n_namespaces=4)
    with _runtime(server, c, cache_size=64) as rt:
        a = rt.submit(c.query_emb[0], c.query_tokens[0],
                      namespaces=0).result(timeout=60)
        hits0 = rt.cache.hits
        b = rt.submit(c.query_emb[0], c.query_tokens[0],
                      namespaces=1).result(timeout=60)
        assert rt.cache.hits == hits0          # different tenant: no hit
        ids_a = np.asarray(a.doc_ids)
        ids_b = np.asarray(b.doc_ids)
        assert (ids_a[ids_a >= 0] % 4 == 0).all()
        assert (ids_b[ids_b >= 0] % 4 == 1).all()
        # same tenant, scaled embedding: hit (key is (epoch, ns, qe, qt))
        again = rt.submit(np.float32(2.0) * c.query_emb[0],
                          c.query_tokens[0], namespaces=0).result(timeout=60)
        assert rt.cache.hits == hits0 + 1
        np.testing.assert_array_equal(ids_a, np.asarray(again.doc_ids))
    # epoch safety for the scaled variant too
    mut_server = _mutable_server(c)
    with _runtime(mut_server, c, cache_size=64) as rt:
        rt.submit(c.query_emb[0], c.query_tokens[0]).result(timeout=60)
        rt.add(c.doc_emb[-8:], c.doc_tokens[-8:])
        hits0 = rt.cache.hits
        rt.submit(np.float32(2.0) * c.query_emb[0],
                  c.query_tokens[0]).result(timeout=60)
        assert rt.cache.hits == hits0          # epoch bumped: no replay


# --------------------------------------------------------------------------
# metrics endpoint: stats() scrape-able as plaintext over HTTP
# --------------------------------------------------------------------------

def test_metrics_endpoint_serves_runtime_stats():
    import urllib.error
    import urllib.request

    c = _corpus()
    server = _plain_server(c)
    with _runtime(server, c, cache_size=8) as rt:
        rt.query(c.query_emb[:4], c.query_tokens[:4])
        rt.query(c.query_emb[:4], c.query_tokens[:4])   # cache hits
        with rt.serve_metrics(port=0) as metrics:
            url = f"http://127.0.0.1:{metrics.port}/metrics"
            body = urllib.request.urlopen(url, timeout=10).read().decode()
            assert "hi2_runtime_served_total 4" in body
            assert "hi2_runtime_queue_depth 0" in body
            assert "hi2_runtime_replicas 1" in body
            assert 'hi2_runtime_bucket_compiles{bucket="4"} ' in body
            assert "hi2_runtime_cache_hits_total 4" in body
            assert "hi2_runtime_cache_hit_rate 0.5" in body
            # only COMPUTED rows dispatch to a replica; the second
            # batch replayed from the cache
            assert 'hi2_runtime_replica_dispatch_total{replica="0"} 4' \
                in body
            with pytest.raises(urllib.error.HTTPError):
                urllib.request.urlopen(
                    f"http://127.0.0.1:{metrics.port}/other", timeout=10)
        # the rendered text is exactly render_metrics(stats())
        text = rt_mod.render_metrics(rt.stats())
        assert text.endswith("\n") and "hi2_runtime_batches_total" in text
    # stats() carries the scrape fields even without a cache
    server2 = _plain_server(c)
    with _runtime(server2, c) as rt2:
        s = rt2.stats()
        assert s["cache"] is None and s["queue_depth"] == 0
        assert "hi2_runtime_cache_hits_total" not in \
            rt_mod.render_metrics(s)


# --------------------------------------------------------------------------
# auto-compaction watermarks (DESIGN.md §8): off by default, bit-identical
# --------------------------------------------------------------------------

def _mutable_server_watermark(c, fill=0.0, tomb=0.0, hold=64):
    mut = seg.MutableHybridIndex.create(
        jax.random.key(0), c.doc_emb[:-hold], c.doc_tokens[:-hold],
        c.vocab_size, delta_capacity=hold, **_KW)
    return serve.make_mutable_server(mut, serve.ServeConfig(
        max_batch=16, mutable=True, compact_fill_watermark=fill,
        compact_tombstone_watermark=tomb))


def test_auto_compaction_is_off_by_default():
    c = _corpus()
    server = _mutable_server(c)
    server.add(c.doc_emb[-64:], c.doc_tokens[-64:])    # delta 100% full
    assert server.mut.delta_count == 64                # never compacted


def test_auto_compaction_fill_watermark_bit_identical():
    """Crossing the fill watermark compacts mid-add-stream; the served
    results must be bit-identical to an explicitly compacted twin."""
    c = _corpus()
    auto = _mutable_server_watermark(c, fill=0.5)
    manual = _mutable_server_watermark(c)              # watermarks off
    for lo in (64, 48, 32, 16):                        # 4 adds of 16
        auto.add(c.doc_emb[-lo:][:16], c.doc_tokens[-lo:][:16])
        manual.add(c.doc_emb[-lo:][:16], c.doc_tokens[-lo:][:16])
        if manual.mut.needs_compact(fill_watermark=0.5):
            manual.compact()
    assert auto.mut.delta_count < 64                   # it did compact
    a = auto.query(c.query_emb[:8], c.query_tokens[:8])
    m = manual.query(c.query_emb[:8], c.query_tokens[:8])
    np.testing.assert_array_equal(np.asarray(a.doc_ids),
                                  np.asarray(m.doc_ids))
    np.testing.assert_array_equal(np.asarray(a.scores),
                                  np.asarray(m.scores))


def test_auto_compaction_tombstone_watermark():
    c = _corpus()
    server = _mutable_server_watermark(c, tomb=0.02)
    n0 = server.mut.n_base
    assert server.mut.tombstone_ratio == 0.0
    server.delete(np.arange(40))                       # ~3% of 1336 docs
    # the delete itself crossed the watermark -> compacted away (the
    # survivors are renumbered 0..n-1, so no tombstones remain)
    assert server.mut.n_deleted == 0
    assert server.mut.n_base == n0 - 40
    direct = server.query(c.query_emb[:8], c.query_tokens[:8])
    ids = np.asarray(direct.doc_ids)
    assert (ids[ids >= 0] < server.mut.n_base).all()


# --------------------------------------------------------------------------
# adaptive width rungs (DESIGN.md §14): compile ledger, cache keys, metrics
# --------------------------------------------------------------------------

def _adaptive_runtime(c, max_batch=8, **cfg):
    """A server over a hand-calibrated 2-rung ladder (median margin
    cut, so both rungs see traffic) and its warmed runtime."""
    from repro.core.exec import frontier
    idx = hi.build(jax.random.key(0), jnp.asarray(c.doc_emb),
                   jnp.asarray(c.doc_tokens), c.vocab_size, **_KW)
    m = frontier.margins(idx.cluster_sel.embeddings, c.query_emb)
    tuned = frontier.TunedWidths(
        kc=4, k2=4, refine_mult=None, recall_target=0.9, recall=0.9,
        cost=int(hi.candidate_budget(idx, 4, 4)),
        rungs=((2, 2), (4, 4)), margin_cuts=(float(np.median(m)),))
    server = serve.make_server(hi.with_tuned(idx, tuned),
                               serve.ServeConfig(adaptive=True,
                                                 max_batch=max_batch))
    rt = rt_mod.ServingRuntime(server, rt_mod.RuntimeConfig(**cfg))
    rt.warmup(c.query_emb.shape[1], c.query_tokens.shape[1])
    return server, rt


def test_adaptive_one_compile_per_bucket_rung_and_bit_identity():
    """Adaptive serving: warmup compiles exactly one program per
    (bucket, rung), serving compiles nothing, and every row equals the
    direct search at its resolved rung's widths."""
    from repro.core.exec import frontier
    c = _corpus()
    server, rt = _adaptive_runtime(c)
    assert server.width_source == "tuned"
    assert rt.rungs == ((2, 2), (4, 4))
    # the ledger is keyed (bucket, rung) in multi-rung mode and covers
    # the full product exactly once
    assert set(rt.warm_traces) == {(b, r) for b in rt.buckets
                                   for r in range(2)}
    assert all(n <= 1 for n in rt.warm_traces.values()), rt.warm_traces
    with rt:
        futures = [rt.submit(c.query_emb[i], c.query_tokens[i])
                   for i in range(24)]
        rows = [f.result(timeout=60) for f in futures]
        assert rt.serve_traces == 0
        # both rungs actually dispatched (median cut splits the sample)
        assert all(rt.rung_dispatch[r] > 0 for r in range(2)), \
            rt.rung_dispatch
        rung = frontier.resolve_rung(
            frontier.margins(server.index.cluster_sel.embeddings,
                             c.query_emb[:24]), rt.margin_cuts)
        qe, qt = jnp.asarray(c.query_emb[:24]), jnp.asarray(
            c.query_tokens[:24])
        for r, (kc, k2) in enumerate(rt.rungs):
            ref = hi.search(server.index, qe, qt, kc=kc, k2=k2,
                            top_r=server.cfg.top_r)
            for i in np.nonzero(rung == r)[0]:
                _rows_equal(rows[i], ref, i)


def test_adaptive_cache_key_separates_rungs_and_replays_within():
    c = _corpus()
    server, rt = _adaptive_runtime(c, cache_size=64)
    with rt:
        q0 = np.asarray(c.query_emb[0], np.float32)
        t0 = np.asarray(c.query_tokens[0], np.int32)
        # the key is structurally distinct across rungs: even a margin
        # flip at the cut boundary can only MISS, never replay a row
        # computed at the other rung's widths
        assert rt._key(q0, t0, None, 0) != rt._key(q0, t0, None, 1)
        # within a rung the normalized-key replay still works
        first = rt.submit(q0, t0).result(timeout=60)
        hits0 = rt.cache.hits
        again = rt.submit(np.float32(2.0) * q0, t0).result(timeout=60)
        assert rt.cache.hits == hits0 + 1
        np.testing.assert_array_equal(np.asarray(first.doc_ids),
                                      np.asarray(again.doc_ids))


def test_single_rung_ledger_and_metrics_keep_baseline_shape():
    """Without a multi-rung ladder the warm ledger keys stay plain
    bucket ints and the bucket_compiles metric keeps its pre-§14 label
    shape — the committed BENCH_serving.json baseline depends on it."""
    c = _corpus()
    server = _plain_server(c, max_batch=8)
    with _runtime(server, c) as rt:
        assert rt.rungs == ((server.kc, server.k2),)
        assert all(isinstance(k, int) for k in rt.warm_traces)
        body = rt_mod.render_metrics(rt.stats())
        assert 'hi2_runtime_bucket_compiles{bucket="2"} ' in body
        assert 'rung=' not in body.split("rung_dispatch")[0].split(
            "width_info")[0]


def test_metrics_expose_width_info_and_rung_dispatch():
    c = _corpus()
    server, rt = _adaptive_runtime(c)
    with rt:
        rt.query(c.query_emb[:8], c.query_tokens[:8])
        body = rt_mod.render_metrics(rt.stats())
        assert 'hi2_runtime_width_info{source="tuned",kc="4",k2="4"} 1' \
            in body
        assert "hi2_runtime_rungs 2" in body
        assert 'hi2_runtime_rung_dispatch_total{rung="0",kc="2",k2="2"} ' \
            in body
        assert 'hi2_runtime_rung_dispatch_total{rung="1",kc="4",k2="4"} ' \
            in body
        # multi-rung ledger lines carry both labels
        assert 'hi2_runtime_bucket_compiles{bucket="2",rung="0"} ' in body


def test_auto_compaction_through_runtime_rewarms():
    """A watermark compaction fired by a runtime add() swaps the base
    index; the runtime must re-warm its buckets (off the request path)
    so serving still never compiles."""
    c = _corpus()
    server = _mutable_server_watermark(c, fill=0.25, hold=64)
    with _runtime(server, c, cache_size=16) as rt:
        rt.query(c.query_emb[:4], c.query_tokens[:4])
        base0 = server.index
        rt.add(c.doc_emb[-32:], c.doc_tokens[-32:])    # fill 0.5 >= 0.25
        assert server.index is not base0               # auto-compacted
        post = rt.query(c.query_emb[:4], c.query_tokens[:4])
        assert rt.serve_traces == 0
        rt.assert_one_compile_per_bucket()
        direct = server.query(c.query_emb[:4], c.query_tokens[:4])
        np.testing.assert_array_equal(np.asarray(post.doc_ids),
                                      np.asarray(direct.doc_ids)[:4])
