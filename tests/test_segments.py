"""Streaming mutations (DESIGN.md §8): delta segment, tombstones,
compaction equivalence, checkpoint round-trip, sharded routing.

The §8 contracts under test:

  · compact() is bit-identical (doc ids AND scores) to a from-scratch
    build over the surviving corpus — for EVERY registered codec, on
    both single-device and document-sharded search;
  · a tombstoned doc can never surface in any top-R (not even via the
    refine stage);
  · add → delete → save → restore → search equals the in-memory mutated
    index, and compact-then-save equals rebuild-then-save.

Cross-variant bit-identity on an *unmutated* corpus (all four search
variants, with and without namespace filters) lives in
tests/test_exec.py — the §9 suite; this file keeps the checks that
need mutated state (streamed adds, tombstones, compaction).

Multi-device cases spawn a fresh interpreter with
xla_force_host_platform_device_count (the tests/test_sharded.py
pattern); everything else runs in-process on 1 device.
"""
import functools
import os
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt
from repro.core import codecs, hybrid_index as hi, segments as seg
from repro.data import synthetic

_ENV = dict(os.environ,
            XLA_FLAGS="--xla_force_host_platform_device_count=4",
            PYTHONPATH=os.environ.get("PYTHONPATH", "src"))

KW = dict(n_clusters=16, k1_terms=4, pq_m=4, pq_k=64,
          cluster_capacity=96, term_capacity=48, kmeans_iters=3)
SEARCH = dict(kc=4, k2=4, top_r=15)
HOLD = 80


def _run(script: str) -> None:
    r = subprocess.run([sys.executable, "-c", script], env=_ENV,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"


@functools.lru_cache(maxsize=1)
def _corpus():
    return synthetic.generate(seed=0, n_docs=1500, n_queries=24, hidden=32,
                              vocab_size=512, n_topics=8)


def _mutated(codec: str, delta_capacity: int = 128):
    """Base over all but the last HOLD docs, then stream them in and
    tombstone a mix of base + delta ids."""
    c = _corpus()
    mut = seg.MutableHybridIndex.create(
        jax.random.key(0), c.doc_emb[:-HOLD], c.doc_tokens[:-HOLD],
        c.vocab_size, delta_capacity=delta_capacity, codec=codec, **KW)
    ids = mut.add_docs(c.doc_emb[-HOLD:], c.doc_tokens[-HOLD:])
    mut.delete_docs(ids[:HOLD // 4])
    mut.delete_docs([3, 4, 7])
    return c, mut, ids


def _queries():
    c = _corpus()
    return jnp.asarray(c.query_emb), jnp.asarray(c.query_tokens)


def assert_results_equal(a: hi.SearchResult, b: hi.SearchResult, err=None):
    np.testing.assert_array_equal(np.asarray(a.doc_ids),
                                  np.asarray(b.doc_ids), err)
    np.testing.assert_array_equal(np.asarray(a.scores),
                                  np.asarray(b.scores), err)
    np.testing.assert_array_equal(np.asarray(a.n_candidates),
                                  np.asarray(b.n_candidates), err)


# --------------------------------------------------------------------------
# adds
# --------------------------------------------------------------------------

def test_added_docs_are_retrievable():
    """A streamed doc must be findable by its own embedding+tokens, with
    its assigned global id (n_base + slot)."""
    c, mut, ids = _mutated("flat")
    assert ids.tolist() == list(range(mut.n_base, mut.n_base + HOLD))
    probe = slice(-8, None)      # live delta docs (the doomed ones are early)
    res = mut.search(jnp.asarray(c.doc_emb[probe]),
                     jnp.asarray(c.doc_tokens[probe]), **SEARCH)
    got = np.asarray(res.doc_ids)
    for row, want in zip(got, ids[probe]):
        assert want in row, (want, row)


def test_add_overflow_raises_delta_full():
    c = _corpus()
    mut = seg.MutableHybridIndex.create(
        jax.random.key(0), c.doc_emb[:-HOLD], c.doc_tokens[:-HOLD],
        c.vocab_size, delta_capacity=10, codec="flat", **KW)
    mut.add_docs(c.doc_emb[-10:], c.doc_tokens[-10:])
    with pytest.raises(seg.DeltaFull):
        mut.add_docs(c.doc_emb[-1:], c.doc_tokens[-1:])
    # search still fine at exactly-full
    mut.search(*_queries(), **SEARCH)


def test_delete_validates_ids():
    _, mut, _ = _mutated("flat")
    with pytest.raises(ValueError):
        mut.delete_docs([mut.n_docs])     # beyond allocated ids
    with pytest.raises(ValueError):
        mut.delete_docs([-1])


# --------------------------------------------------------------------------
# tombstones
# --------------------------------------------------------------------------

def test_tombstoned_docs_never_surface_every_codec():
    """Delete docs that verifiably appeared in results; they must vanish
    from every subsequent top-R (incl. through the refine stage)."""
    qe, qt = _queries()
    for codec in codecs.registered():
        c, mut, ids = _mutated(codec)
        before = np.asarray(mut.search(qe, qt, **SEARCH).doc_ids)
        seen = np.unique(before[before >= 0])
        assert seen.size > 0
        doomed = seen[:: max(1, seen.size // 10)][:10]   # spread across ids
        mut.delete_docs(doomed)
        after = np.asarray(mut.search(qe, qt, **SEARCH).doc_ids)
        assert not np.isin(after, doomed).any(), (codec, doomed)
        # deleting reduces the live candidate pool, never grows it
        assert mut.n_live < mut.n_docs


# --------------------------------------------------------------------------
# compaction equivalence (the §8 contract, single-device half)
# --------------------------------------------------------------------------

def test_compact_equals_from_scratch_rebuild_every_codec():
    """compact() output must be bit-identical — doc ids, scores AND
    candidate counts — to hi.build over the surviving corpus."""
    qe, qt = _queries()
    c = _corpus()
    for codec in codecs.registered():
        _, mut, _ = _mutated(codec)
        compacted = mut.compact()
        emb, tok = mut.surviving_corpus()
        assert emb.shape[0] == mut.n_live == compacted.n_base
        rebuilt = hi.build(jax.random.key(0), jnp.asarray(emb),
                           jnp.asarray(tok), c.vocab_size, codec=codec,
                           **KW)
        rc = compacted.search(qe, qt, **SEARCH)
        rr = hi.search(rebuilt, qe, qt, **SEARCH)
        assert_results_equal(rc, rr, codec)
        # the rebuilt base is leaf-for-leaf identical, not just
        # search-equal (compact IS the from-scratch build)
        for a, b in zip(jax.tree.leaves(compacted.base),
                        jax.tree.leaves(rebuilt)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          codec)


def test_compact_renumbers_survivors_contiguously():
    _, mut, _ = _mutated("flat")
    surv = mut.survivors()
    assert surv.size == mut.n_live
    assert not np.isin(surv, np.flatnonzero(mut.tombstones)).any()
    compacted = mut.compact()
    assert compacted.n_base == surv.size
    assert compacted.delta_count == 0 and compacted.n_deleted == 0


# --------------------------------------------------------------------------
# checkpoint round-trip of a mutated index
# --------------------------------------------------------------------------

def test_checkpoint_roundtrip_mutated_index():
    """add → delete → save → restore → search must equal the in-memory
    mutated index, and the restored index must keep mutating
    identically (list planes, eviction scores, counters round-trip)."""
    qe, qt = _queries()
    c, mut, _ = _mutated("opq")
    ref = mut.search(qe, qt, **SEARCH)
    with tempfile.TemporaryDirectory() as d:
        path = ckpt.save_mutable(d, 7, mut)
        like = seg.MutableHybridIndex.create(
            jax.random.key(1), c.doc_emb[:-HOLD], c.doc_tokens[:-HOLD],
            c.vocab_size, delta_capacity=128, codec="opq", **KW)
        back = ckpt.restore_mutable(path, like)
        assert_results_equal(ref, back.search(qe, qt, **SEARCH))
        assert back.delta_count == mut.delta_count
        assert back.n_deleted == mut.n_deleted
        # post-restore mutations behave exactly like never-saved ones
        extra_e, extra_t = c.doc_emb[:6] + 0.01, c.doc_tokens[:6]
        np.testing.assert_array_equal(mut.add_docs(extra_e, extra_t),
                                      back.add_docs(extra_e, extra_t))
        assert_results_equal(mut.search(qe, qt, **SEARCH),
                             back.search(qe, qt, **SEARCH))


def test_checkpoint_rejects_codec_mismatch_and_plain_index():
    c, mut, _ = _mutated("sq8")
    with tempfile.TemporaryDirectory() as d:
        path = ckpt.save_mutable(d, 0, mut)
        like = seg.MutableHybridIndex.create(
            jax.random.key(0), c.doc_emb[:-HOLD], c.doc_tokens[:-HOLD],
            c.vocab_size, delta_capacity=128, codec="flat", **KW)
        with pytest.raises(ValueError, match="codec"):
            ckpt.restore_mutable(path, like)
        plain = ckpt.save_index(d, 1, mut.base)
        with pytest.raises(ValueError, match="mutable"):
            ckpt.restore_mutable(plain, mut)


def test_compact_then_save_equals_rebuild_then_save():
    """Checkpointing the compacted index must produce the same arrays as
    checkpointing a from-scratch build over the survivors."""
    c, mut, _ = _mutated("opq")
    compacted = mut.compact()
    emb, tok = mut.surviving_corpus()
    rebuilt = seg.MutableHybridIndex.create(
        jax.random.key(0), emb, tok, c.vocab_size, delta_capacity=128,
        codec="opq", **KW)
    with tempfile.TemporaryDirectory() as d:
        p_a = ckpt.save_mutable(os.path.join(d, "a"), 0, compacted)
        p_b = ckpt.save_mutable(os.path.join(d, "b"), 0, rebuilt)
        man_a, man_b = ckpt.load_manifest(p_a), ckpt.load_manifest(p_b)
        assert man_a["leaves"] == man_b["leaves"]
        # the mutation epoch is lineage metadata, not index content: it
        # deliberately survives compaction (+1, DESIGN.md §10) so
        # epoch-keyed serving caches cannot replay across the renumbering
        # — the content contract is everything else being identical
        assert man_a["extra"]["mutable"].pop("epoch") > 0
        assert man_b["extra"]["mutable"].pop("epoch") == 0
        assert man_a["extra"] == man_b["extra"]
        with np.load(os.path.join(p_a, "arrays.npz")) as za, \
                np.load(os.path.join(p_b, "arrays.npz")) as zb:
            assert sorted(za.files) == sorted(zb.files)
            for k in za.files:
                np.testing.assert_array_equal(za[k], zb[k], k)


# --------------------------------------------------------------------------
# sharded mutable search (the §8 contract, sharded half)
# --------------------------------------------------------------------------

def test_sharded_mutable_bit_identical_every_codec():
    """For EVERY registered codec: mutable search over 2 and 4 shards is
    bit-identical to single-device mutable search, and the compacted
    index served sharded equals the from-scratch rebuild — the §8
    acceptance contract on the document-sharded path."""
    _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.core import codecs, hybrid_index as hi, segments as seg
from repro.core import sharded_index as shi
from repro.data import synthetic

assert jax.device_count() == 4
c = synthetic.generate(seed=0, n_docs=1501, n_queries=16, hidden=32,
                       vocab_size=512, n_topics=8)
kw = dict(n_clusters=16, k1_terms=4, pq_m=4, pq_k=64,
          cluster_capacity=64, term_capacity=32, kmeans_iters=3)
qe, qt = jnp.asarray(c.query_emb), jnp.asarray(c.query_tokens)
hold = 80
for codec in codecs.registered():
    mut = seg.MutableHybridIndex.create(
        jax.random.key(0), c.doc_emb[:-hold], c.doc_tokens[:-hold],
        c.vocab_size, delta_capacity=100, codec=codec, **kw)
    ids = mut.add_docs(c.doc_emb[-hold:], c.doc_tokens[-hold:])
    mut.delete_docs(ids[:20]); mut.delete_docs([5, 6, 7])
    ref = mut.search(qe, qt, kc=4, k2=4, top_r=15)
    for n_shards in (2, 4):
        smut = seg.ShardedMutableIndex(mut, n_shards)
        out = smut.search(qe, qt, kc=4, k2=4, top_r=15)
        err = (codec, n_shards)
        np.testing.assert_array_equal(np.asarray(ref.doc_ids),
                                      np.asarray(out.doc_ids), err)
        np.testing.assert_array_equal(np.asarray(ref.scores),
                                      np.asarray(out.scores), err)
        np.testing.assert_array_equal(np.asarray(ref.n_candidates),
                                      np.asarray(out.n_candidates), err)
        # deleted docs absent on the sharded path too
        assert not np.isin(np.asarray(out.doc_ids),
                           np.asarray(ids[:20])).any(), err
    # compacted-then-sharded == from-scratch rebuild (single device)
    emb, tok = mut.surviving_corpus()
    rebuilt = hi.build(jax.random.key(0), jnp.asarray(emb),
                       jnp.asarray(tok), c.vocab_size, codec=codec, **kw)
    want = hi.search(rebuilt, qe, qt, kc=4, k2=4, top_r=15)
    scomp = seg.ShardedMutableIndex(mut.compact(), 4)
    got = scomp.search(qe, qt, kc=4, k2=4, top_r=15)
    np.testing.assert_array_equal(np.asarray(want.doc_ids),
                                  np.asarray(got.doc_ids), codec)
    np.testing.assert_array_equal(np.asarray(want.scores),
                                  np.asarray(got.scores), codec)
""")


def test_sharded_mutable_routes_adds_to_owning_shard():
    """Adds through the sharded wrapper land in the owning shard's delta
    split: each shard's list planes reference only its own slot range."""
    _run("""
import jax, numpy as np
from repro.core import segments as seg
from repro.data import synthetic
from repro.core.inverted_lists import PAD_DOC

c = synthetic.generate(seed=0, n_docs=1200, n_queries=8, hidden=32,
                       vocab_size=512, n_topics=8)
kw = dict(n_clusters=16, k1_terms=4, codec="flat",
          cluster_capacity=64, term_capacity=32, kmeans_iters=3)
mut = seg.MutableHybridIndex.create(
    jax.random.key(0), c.doc_emb[:-60], c.doc_tokens[:-60],
    c.vocab_size, delta_capacity=64, **kw)
smut = seg.ShardedMutableIndex(mut, 4)
ids = smut.add_docs(c.doc_emb[-60:], c.doc_tokens[-60:])
shards = smut.owning_shard(ids)
assert set(shards.tolist()) == {0, 1, 2, 3}   # blocks of dper=16 slots
state = smut._split_delta()
n_base, dper = mut.n_base, smut.dper
for s in range(4):
    for plane in ("delta_cluster_entries", "delta_term_entries"):
        e = np.asarray(state[plane][s])
        mine = e[e != PAD_DOC]
        lo = n_base + s * dper
        assert ((mine >= lo) & (mine < lo + dper)).all(), (plane, s)
# every added doc's postings landed somewhere
all_entries = np.concatenate([np.asarray(state["delta_cluster_entries"]),
                              np.asarray(state["delta_term_entries"])],
                             axis=None)
assert np.isin(ids, all_entries).all()
""")


def test_mutable_server_roundtrip():
    """launch/serve.py --mutable path: MutableServer add/delete/compact
    with the padded-batch request contract."""
    _run("""
import jax, numpy as np
from repro.core import segments as seg
from repro.launch import serve
from repro.data import synthetic

c = synthetic.generate(seed=0, n_docs=1200, n_queries=48, hidden=32,
                       vocab_size=512, n_topics=8)
kw = dict(n_clusters=16, k1_terms=4, codec="opq", pq_m=4, pq_k=64,
          cluster_capacity=64, term_capacity=32, kmeans_iters=3)
mut = seg.MutableHybridIndex.create(
    jax.random.key(0), c.doc_emb[:-60], c.doc_tokens[:-60],
    c.vocab_size, delta_capacity=64, **kw)
cfg = serve.ServeConfig(kc=4, k2=4, top_r=10, max_batch=32, mutable=True)
s = serve.make_mutable_server(mut, cfg)
r0 = s.query(c.query_emb[:32], c.query_tokens[:32])
ids = s.add(c.doc_emb[-60:], c.doc_tokens[-60:])
s.delete(ids[:10])
r1 = s.query(c.query_emb[:20], c.query_tokens[:20])   # ragged batch
assert r1.doc_ids.shape == (20, 10)
assert not np.isin(np.asarray(r1.doc_ids), ids[:10]).any()
s.compact()
r2 = s.query(c.query_emb[:32], c.query_tokens[:32])
assert s.n_served == 32 + 20 + 32
got = set(np.asarray(r2.doc_ids).ravel().tolist())
assert max(got) < s.mut.n_base     # compacted: contiguous renumbering

# the immutable server refuses mutations with a pointer to --mutable
idx = s.mut.base
srv = serve.make_server(idx, serve.ServeConfig(kc=4, k2=4, top_r=10))
try:
    srv.add(c.doc_emb[:1], c.doc_tokens[:1])
except RuntimeError as e:
    assert "mutable" in str(e)
else:
    raise AssertionError("immutable server accepted add()")
""")
