"""Sharded HI² correctness (DESIGN.md §6).

The headline test proves the acceptance criterion: search over 4
emulated CPU devices returns bit-identical top-R ids/scores to the
single-device ``search()`` on a 10k-doc corpus.  Multi-device cases
spawn a fresh interpreter with xla_force_host_platform_device_count
(same pattern as tests/test_distributed.py); partition-invariant checks
run in-process on 1 device.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hybrid_index as hi
from repro.core import sharded_index as shi
from repro.core.inverted_lists import PAD_DOC

_ENV = dict(os.environ,
            XLA_FLAGS="--xla_force_host_platform_device_count=4",
            PYTHONPATH=os.environ.get("PYTHONPATH", "src"))


def _run(script: str) -> None:
    r = subprocess.run([sys.executable, "-c", script], env=_ENV,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"


def _build_small_index(n_docs=3000, codec="opq"):
    from repro.data import synthetic
    corpus = synthetic.generate(seed=0, n_docs=n_docs, n_queries=32,
                                hidden=32, vocab_size=1024, n_topics=16)
    idx = hi.build(jax.random.key(0), jnp.asarray(corpus.doc_emb),
                   jnp.asarray(corpus.doc_tokens), corpus.vocab_size,
                   n_clusters=32, k1_terms=6, codec=codec, pq_m=4, pq_k=64,
                   cluster_capacity=96, term_capacity=48, kmeans_iters=5)
    return corpus, idx


def test_partition_preserves_lists_exactly():
    """Union of the per-shard lists == the global (truncated) lists."""
    _, idx = _build_small_index()
    for n_shards in (1, 3, 4):
        sidx = shi.partition(idx, n_shards)
        for global_lists, entries, lengths in (
                (idx.cluster_lists, sidx.cluster_entries,
                 sidx.cluster_lengths),
                (idx.term_lists, sidx.term_entries, sidx.term_lengths)):
            g = np.asarray(global_lists.entries)
            e = np.asarray(entries)
            assert e.shape == (n_shards,) + g.shape
            per = sidx.docs_per_shard
            for li in range(g.shape[0]):
                want = sorted(d for d in g[li] if d != PAD_DOC)
                got = sorted(d for s in range(n_shards)
                             for d in e[s, li] if d != PAD_DOC)
                assert got == want, (li, got, want)
                for s in range(n_shards):
                    docs = e[s, li][e[s, li] != PAD_DOC]
                    assert (docs // per == s).all()   # shard owns its range
            assert (np.asarray(lengths).sum(axis=0)
                    == np.asarray(global_lists.lengths)).all()


def test_partition_doc_planes_roundtrip():
    _, idx = _build_small_index()
    sidx = shi.partition(idx, 4)
    per = sidx.docs_per_shard
    assert sidx.n_shards == 4 and 4 * per >= idx.n_docs
    codes = np.asarray(sidx.doc_codes).reshape(4 * per, -1)[:idx.n_docs]
    np.testing.assert_array_equal(codes, np.asarray(idx.doc_codes))
    assign = np.asarray(sidx.doc_assign).reshape(-1)[:idx.n_docs]
    np.testing.assert_array_equal(assign, np.asarray(idx.doc_assign))


def test_topk_by_score_total_order():
    """The canonical top-k is permutation-invariant and breaks ties by
    doc id — the property the sharded merge relies on."""
    scores = jnp.asarray([[3.0, 1.0, 3.0, -jnp.inf, 2.0]])
    ids = jnp.asarray([[7, 5, 2, 9, 4]], dtype=jnp.int32)
    s, i = hi.topk_by_score(scores, ids, 4)
    np.testing.assert_array_equal(np.asarray(i), [[2, 7, 4, 5]])  # tie: 2<7
    np.testing.assert_array_equal(np.asarray(s), [[3.0, 3.0, 2.0, 1.0]])
    # permuting the candidate layout cannot change the selection
    perm = jnp.asarray([4, 2, 0, 3, 1])
    s2, i2 = hi.topk_by_score(scores[:, perm], ids[:, perm], 4)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(i2))
    np.testing.assert_array_equal(np.asarray(s), np.asarray(s2))
    # r larger than the row PAD-fills the tail
    s3, i3 = hi.topk_by_score(scores, ids, 7)
    assert (np.asarray(i3)[0, 5:] == PAD_DOC).all()
    assert np.isneginf(np.asarray(s3)[0, 5:]).all()


def test_sharded_search_matches_single_device_10k():
    """Acceptance criterion: 4 emulated devices, ≥10k docs, bit-identical
    top-R ids and scores vs single-device search()."""
    _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.core import hybrid_index as hi, sharded_index as shi
from repro.data import synthetic

assert jax.device_count() == 4
corpus = synthetic.generate(seed=0, n_docs=10_000, n_queries=64,
                            hidden=32, vocab_size=2048, n_topics=32)
idx = hi.build(jax.random.key(0), jnp.asarray(corpus.doc_emb),
               jnp.asarray(corpus.doc_tokens), corpus.vocab_size,
               n_clusters=64, k1_terms=8, codec="opq", pq_m=4, pq_k=64,
               cluster_capacity=128, term_capacity=64, kmeans_iters=5)
qe, qt = jnp.asarray(corpus.query_emb), jnp.asarray(corpus.query_tokens)
ref = hi.search(idx, qe, qt, kc=4, k2=4, top_r=20)

mesh = shi.make_shard_mesh(4)
sidx = shi.device_put(shi.partition(idx, 4), mesh)
out = shi.search(sidx, qe, qt, kc=4, k2=4, top_r=20, mesh=mesh)
np.testing.assert_array_equal(np.asarray(ref.doc_ids), np.asarray(out.doc_ids))
np.testing.assert_array_equal(np.asarray(ref.scores), np.asarray(out.scores))
np.testing.assert_array_equal(np.asarray(ref.n_candidates),
                              np.asarray(out.n_candidates))
""")


# NOTE: the per-codec sharded-vs-single bit-identity loop moved into
# tests/test_exec.py, which asserts it for ALL FOUR variants (single,
# mutable, sharded, sharded-mutable) with and without a namespace
# filter in one parametrized run (DESIGN.md §9).


def test_sharded_search_flat_codec_and_odd_sizes():
    """Flat codec + corpus not divisible by the shard count + top_r
    larger than the valid candidate pool (PAD-fill path)."""
    _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.core import hybrid_index as hi, sharded_index as shi
from repro.data import synthetic

corpus = synthetic.generate(seed=1, n_docs=4999, n_queries=32,
                            hidden=32, vocab_size=1024, n_topics=16)
idx = hi.build(jax.random.key(0), jnp.asarray(corpus.doc_emb),
               jnp.asarray(corpus.doc_tokens), corpus.vocab_size,
               n_clusters=32, k1_terms=6, codec="flat",
               cluster_capacity=96, term_capacity=48, kmeans_iters=5)
qe, qt = jnp.asarray(corpus.query_emb), jnp.asarray(corpus.query_tokens)
ref = hi.search(idx, qe, qt, kc=3, k2=5, top_r=400)
for n_shards in (2, 3, 4):
    mesh = shi.make_shard_mesh(n_shards)
    sidx = shi.device_put(shi.partition(idx, n_shards), mesh)
    out = shi.search(sidx, qe, qt, kc=3, k2=5, top_r=400, mesh=mesh)
    np.testing.assert_array_equal(np.asarray(ref.doc_ids),
                                  np.asarray(out.doc_ids))
    np.testing.assert_array_equal(np.asarray(ref.scores),
                                  np.asarray(out.scores))
""")


def test_sharded_serve_server():
    """launch/serve.py --shards path end-to-end (batch padding + the
    ShardedServer wrapper), equal to the single-device Server."""
    _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.core import hybrid_index as hi
from repro.launch import serve
from repro.data import synthetic

corpus = synthetic.generate(seed=0, n_docs=3000, n_queries=48,
                            hidden=32, vocab_size=1024, n_topics=16)
idx = hi.build(jax.random.key(0), jnp.asarray(corpus.doc_emb),
               jnp.asarray(corpus.doc_tokens), corpus.vocab_size,
               n_clusters=32, k1_terms=6, codec="opq", pq_m=4, pq_k=64,
               cluster_capacity=96, term_capacity=48, kmeans_iters=5)
cfg1 = serve.ServeConfig(kc=4, k2=4, top_r=10, max_batch=32)
cfg4 = serve.ServeConfig(kc=4, k2=4, top_r=10, max_batch=32, n_shards=4)
s1 = serve.make_server(idx, cfg1)
s4 = serve.make_server(idx, cfg4)
assert type(s4).__name__ == "ShardedServer"
for lo in (0, 32):   # full batch + ragged tail batch (16 queries)
    a = s1.query(corpus.query_emb[lo:lo+32], corpus.query_tokens[lo:lo+32])
    b = s4.query(corpus.query_emb[lo:lo+32], corpus.query_tokens[lo:lo+32])
    np.testing.assert_array_equal(np.asarray(a.doc_ids), np.asarray(b.doc_ids))
    np.testing.assert_array_equal(np.asarray(a.scores), np.asarray(b.scores))
assert s4.n_served == 48
""")
