"""End-to-end behaviour tests for the HI² retrieval system.

These validate the paper's claims structurally (EXPERIMENTS.md §Repro):
  RQ1: HI² beats IVF at matched candidate budget, near brute force.
  RQ2: hybrid > term-only and cluster-only ablations (complementarity).
  Table 3: Flat codec ≥ PQ codec quality.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hybrid_index as hi, metrics
from repro.core.codecs import flat
from repro.data import synthetic


@pytest.fixture(scope="module")
def corpus():
    return synthetic.generate(seed=0, n_docs=8000, n_queries=400,
                              hidden=64, vocab_size=4096, n_topics=64)


@pytest.fixture(scope="module")
def index(corpus):
    return hi.build(jax.random.key(0),
                    jnp.asarray(corpus.doc_emb),
                    jnp.asarray(corpus.doc_tokens),
                    corpus.vocab_size,
                    n_clusters=128, k1_terms=15, codec="opq",
                    pq_m=8, pq_k=128, cluster_capacity=192,
                    term_capacity=96, kmeans_iters=8)


def _r100(result, corpus):
    return metrics.recall_at_k(result.doc_ids, corpus.qrels, 100)


def test_flat_search_is_exact(corpus):
    q = jnp.asarray(corpus.query_emb[:32])
    d = jnp.asarray(corpus.doc_emb)
    scores, ids = flat.search(q, d, k=10)
    brute = np.asarray(q) @ np.asarray(d).T
    expect = np.argsort(-brute, axis=1)[:, :10]
    np.testing.assert_array_equal(np.asarray(ids), expect)


def test_hybrid_beats_ivf_at_budget(corpus, index):
    qe = jnp.asarray(corpus.query_emb)
    qt = jnp.asarray(corpus.query_tokens)
    r_hyb = hi.search(index, qe, qt, kc=6, k2=8, top_r=100)
    r_ivf = hi.search_ivf(index, qe, qt, kc=10, top_r=100)
    # IVF gets a LARGER budget and must still lose (paper RQ1)
    assert float(r_ivf.n_candidates.mean()) > float(r_hyb.n_candidates.mean())
    assert _r100(r_hyb, corpus) > _r100(r_ivf, corpus)


def test_complementarity(corpus, index):
    """RQ2: hybrid ≥ each single-list-family ablation."""
    qe = jnp.asarray(corpus.query_emb)
    qt = jnp.asarray(corpus.query_tokens)
    r_hyb = _r100(hi.search(index, qe, qt, kc=6, k2=8, top_r=100), corpus)
    r_term = _r100(hi.search_term_only(index, qe, qt, k2=8, top_r=100),
                   corpus)
    r_clus = _r100(hi.search_ivf(index, qe, qt, kc=6, top_r=100), corpus)
    assert r_hyb >= r_term - 1e-6
    assert r_hyb >= r_clus - 1e-6
    assert r_hyb > max(r_term, r_clus) - 0.02  # genuinely combines


def test_near_lossless_vs_brute_force(corpus, index):
    qe = jnp.asarray(corpus.query_emb)
    qt = jnp.asarray(corpus.query_tokens)
    _, fids = flat.search(qe, jnp.asarray(corpus.doc_emb), k=100)
    r_flat = metrics.recall_at_k(fids, corpus.qrels, 100)
    r_hyb = _r100(hi.search(index, qe, qt, kc=8, k2=8, top_r=100), corpus)
    assert r_hyb > r_flat - 0.08, (r_hyb, r_flat)


def test_flat_codec_beats_pq_codec(corpus):
    """Paper Table 3: the Flat codec recovers the PQ quantization loss."""
    common = dict(n_clusters=128, k1_terms=15, cluster_capacity=192,
                  term_capacity=96, kmeans_iters=8)
    qe = jnp.asarray(corpus.query_emb)
    qt = jnp.asarray(corpus.query_tokens)
    de = jnp.asarray(corpus.doc_emb)
    dt = jnp.asarray(corpus.doc_tokens)
    idx_pq = hi.build(jax.random.key(1), de, dt, corpus.vocab_size,
                      codec="pq", pq_m=8, pq_k=128, **common)
    idx_flat = hi.build(jax.random.key(1), de, dt, corpus.vocab_size,
                        codec="flat", **common)
    r_pq = _r100(hi.search(idx_pq, qe, qt, kc=6, k2=8, top_r=100), corpus)
    r_flat = _r100(hi.search(idx_flat, qe, qt, kc=6, k2=8, top_r=100), corpus)
    assert r_flat >= r_pq


def test_search_with_pallas_kernel_matches_oracle(corpus, index):
    qe = jnp.asarray(corpus.query_emb[:64])
    qt = jnp.asarray(corpus.query_tokens[:64])
    r_ref = hi.search(index, qe, qt, kc=6, k2=8, top_r=50, use_kernel=False)
    r_ker = hi.search(index, qe, qt, kc=6, k2=8, top_r=50, use_kernel=True)
    np.testing.assert_array_equal(np.asarray(r_ref.doc_ids),
                                  np.asarray(r_ker.doc_ids))


def test_candidate_budget_is_latency_proxy(corpus, index):
    """More dispatched lists ⇒ more candidates (monotone latency proxy)."""
    qe = jnp.asarray(corpus.query_emb)
    qt = jnp.asarray(corpus.query_tokens)
    small = hi.search(index, qe, qt, kc=2, k2=4, top_r=50)
    large = hi.search(index, qe, qt, kc=12, k2=16, top_r=50)
    assert float(large.n_candidates.mean()) > float(small.n_candidates.mean())
